"""Scalar quantizer + packing."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.quantizer import (
    QuantSpec,
    dequantize,
    find_params,
    pack_codes,
    quantize_rtn,
    quantize_weight_rtn,
    unpack_codes,
    values_per_word,
)


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
@pytest.mark.parametrize("sym", [True, False])
def test_rtn_roundtrip_error_bound(bits, sym):
    w = jax.random.normal(jax.random.key(bits), (64, 32))
    spec = QuantSpec(bits=bits, group_size=16, sym=sym)
    deq, q, s, z = quantize_weight_rtn(w, spec)
    # error bounded by half a quantization step per group
    step = jnp.repeat(s, 16, axis=0)
    assert float(jnp.max(jnp.abs(deq - w) / step)) <= 0.5 + 1e-3
    assert int(q.min()) >= 0 and int(q.max()) <= spec.maxq


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
def test_pack_unpack_identity(bits):
    q = jax.random.randint(jax.random.key(bits), (100, 24), 0, 2 ** bits)
    packed = pack_codes(q, bits)
    assert packed.dtype == jnp.uint32
    out = unpack_codes(packed, bits, 100)
    assert bool(jnp.all(out == q))


@pytest.mark.parametrize("bits", [2, 3, 4])
@pytest.mark.parametrize("d_in", [1, 9, 37, 101])
def test_pack_unpack_ragged_d_in(bits, d_in):
    """Roundtrip when d_in doesn't fill the last 32-bit word.

    3-bit is the classic overflow edge case: 10 values per word with 2 dead
    bits, so nearly every d_in is ragged and the top lane shifts by 27 —
    codes must land below bit 30, never touch the dead bits, and the pad
    rows must decode away exactly."""
    vpw = values_per_word(bits)
    q = jax.random.randint(jax.random.key(bits * 100 + d_in), (d_in, 8),
                           0, 2 ** bits)
    packed = pack_codes(q, bits)
    assert packed.shape == (-(-d_in // vpw), 8)
    assert bool(jnp.all(unpack_codes(packed, bits, d_in) == q))
    # pad lanes beyond d_in hold zero codes (the serving kernels rely on
    # deterministic padding when tiling over full words)
    tail = unpack_codes(packed, bits, packed.shape[0] * vpw)[d_in:]
    assert bool(jnp.all(tail == 0))


def test_pack_unpack_max_codes_all_lanes():
    """All-maxq codes at 3 bit: every lane saturated (incl. the <<27 one)
    must survive the uint32 round trip — the historic overflow trap."""
    for bits in (2, 3, 4, 8):
        d_in = values_per_word(bits) * 2 + 3
        q = jnp.full((d_in, 4), 2 ** bits - 1, jnp.int32)
        assert bool(jnp.all(unpack_codes(pack_codes(q, bits), bits, d_in)
                            == q))


def test_pack_unpack_batched_leading_axes():
    """Stacked-expert (E, d_in, d_out) codes pack per expert, identically
    to packing each slice — the sharded write-back path packs expert
    stacks in one call."""
    bits, d_in = 3, 23
    q = jax.random.randint(jax.random.key(0), (3, d_in, 8), 0, 2 ** bits)
    packed = pack_codes(q, bits)
    assert packed.shape == (3, -(-d_in // values_per_word(bits)), 8)
    for e in range(3):
        assert bool(jnp.all(packed[e] == pack_codes(q[e], bits)))
    assert bool(jnp.all(unpack_codes(packed, bits, d_in) == q))


def test_dequantize_packed_matches_explicit():
    """dequantize_packed == unpack + per-group dequantize, including a
    ragged d_in and >1 group."""
    from repro.core.quantizer import dequantize_packed

    bits, d_in, d_out, gs = 4, 32, 8, 16
    q = jax.random.randint(jax.random.key(1), (d_in, d_out), 0, 2 ** bits)
    s = jax.random.uniform(jax.random.key(2), (d_in // gs, d_out)) + 0.1
    z = jnp.full((d_in // gs, d_out), 7.0)
    w = dequantize_packed(pack_codes(q, bits), s, z, bits=bits, d_in=d_in)
    ref = dequantize(q.reshape(-1, gs, d_out), s[:, None], z[:, None])
    assert bool(jnp.all(w == ref.reshape(d_in, d_out)))


def test_asym_covers_range():
    w = jnp.concatenate([jnp.full((8, 4), -1.0), jnp.full((8, 4), 3.0)])
    spec = QuantSpec(bits=4, group_size=-1, sym=False)
    s, z = find_params(w, spec)
    q = quantize_rtn(w, s, z, spec)
    deq = dequantize(q, s, z)
    assert float(jnp.abs(deq - w).max()) < 0.3
