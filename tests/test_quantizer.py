"""Scalar quantizer + packing."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.quantizer import (
    QuantSpec,
    dequantize,
    find_params,
    pack_codes,
    quantize_rtn,
    quantize_weight_rtn,
    unpack_codes,
)


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
@pytest.mark.parametrize("sym", [True, False])
def test_rtn_roundtrip_error_bound(bits, sym):
    w = jax.random.normal(jax.random.key(bits), (64, 32))
    spec = QuantSpec(bits=bits, group_size=16, sym=sym)
    deq, q, s, z = quantize_weight_rtn(w, spec)
    # error bounded by half a quantization step per group
    step = jnp.repeat(s, 16, axis=0)
    assert float(jnp.max(jnp.abs(deq - w) / step)) <= 0.5 + 1e-3
    assert int(q.min()) >= 0 and int(q.max()) <= spec.maxq


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
def test_pack_unpack_identity(bits):
    q = jax.random.randint(jax.random.key(bits), (100, 24), 0, 2 ** bits)
    packed = pack_codes(q, bits)
    assert packed.dtype == jnp.uint32
    out = unpack_codes(packed, bits, 100)
    assert bool(jnp.all(out == q))


def test_asym_covers_range():
    w = jnp.concatenate([jnp.full((8, 4), -1.0), jnp.full((8, 4), 3.0)])
    spec = QuantSpec(bits=4, group_size=-1, sym=False)
    s, z = find_params(w, spec)
    q = quantize_rtn(w, s, z, spec)
    deq = dequantize(q, s, z)
    assert float(jnp.abs(deq - w).max()) < 0.3
