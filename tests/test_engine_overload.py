"""Overload behavior of the serve engine (PR 10).

The degradation contract: under slot/page pressure the engine preempts,
requeues and later *resumes* requests such that their final tokens are
still bitwise the solo batch-1 ``generate`` stream — resume re-ingests
the prompt through the exact prefill (bitwise pages) and replays the
already-emitted tokens through teacher-forced decode steps, so the
``fold_in(key(seed), j)`` sampling stream continues exactly where it
left off.  Around that core: deadlines, priority ordering, bounded-queue
backpressure (``EngineSaturated``), engine-stage fault injection reusing
``runtime.fault`` (a failed burst retries bit-identically; a poisoned
request is isolated), a stuck-round watchdog, and page accounting that
turns double frees / leaks into loud ``PageAccountingError``s.
"""
import dataclasses
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import SyntheticCorpus
from repro.models import build_model
from repro.runtime.fault import FaultPlan, InjectedFailure, RetryPolicy
from repro.serving import (Engine, EngineSaturated, EngineStuck,
                           PageAccountingError, PagedPools, RequestOutput,
                           SamplingParams, ServeRequest, poisson_trace,
                           run_trace)
from repro.serving.trace import _status_group

PAIRS = [("qwen1.5-4b", 8), ("qwen1.5-4b", 2),
         ("deepseek-v2-236b", 8), ("deepseek-v2-236b", 2)]


@functools.lru_cache(maxsize=None)
def _model_params(name, kv_bits):
    # capacity_factor=100: see test_serving — MoE capacity dropping is the
    # one batch-coupling exception to the bit-identity contract; lift it.
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32",
                              capacity_factor=100.0, kv_bits=kv_bits)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    return model, params


def _prompts(model, n, t):
    corpus = SyntheticCorpus(vocab_size=model.cfg.vocab_size, seed=0)
    return corpus.sample(jax.random.key(2), n, t)


def _baseline(model, params, prompt, n_gen, sp):
    from repro.launch.serve import generate
    import jax.numpy as jnp
    key = jax.random.key(sp.seed) if sp.temperature > 0 else None
    out = generate(model, params, jnp.asarray(prompt, jnp.int32)[None],
                   n_gen, temperature=sp.temperature, key=key)
    return out[0].tolist()


# ---------------------------------------------------------------- preemption
@pytest.mark.parametrize("name,kv_bits", PAIRS)
def test_preempted_request_bit_identical(name, kv_bits):
    """The tentpole pin: a 3-page pool cannot hold two 2-page requests, so
    admitting B preempts mid-stream A (at position 63 — mid-page) and the
    two then trade the pool until both finish; every preempt/resume cycle
    re-ingests the prompt and replays the emitted tokens across the page
    boundary, and BOTH final streams must be bitwise the solo baseline.
    A samples at temperature (the stronger pin: the resumed ``fold_in``
    stream must continue at the right draw index, not just re-argmax)."""
    model, params = _model_params(name, kv_bits)
    prompts = _prompts(model, 2, 60)
    sp_a = SamplingParams(temperature=1.3, seed=7)
    sp_b = SamplingParams()
    base_a = _baseline(model, params, prompts[0].tolist(), 12, sp_a)
    base_b = _baseline(model, params, prompts[1].tolist(), 6, sp_b)

    engine = Engine(model, params, max_slots=2, n_pages=3,
                    max_pages_per_request=2, burst_steps=3)
    ra = engine.submit(ServeRequest(tokens=prompts[0].tolist(),
                                    max_new_tokens=12, sampling=sp_a))
    engine.step()  # A admitted, emits token 0 + one burst (pos 63, mid-page)
    assert engine.load()["running"] == 1
    rb = engine.submit(ServeRequest(tokens=prompts[1].tolist(),
                                    max_new_tokens=6, sampling=sp_b))
    outs = {o.request_id: o for o in engine.drain()}

    assert outs[ra].tokens == base_a, "preempted stream diverged from solo"
    assert outs[rb].tokens == base_b
    assert outs[ra].n_preempted >= 1
    assert outs[ra].status == f"preempted_{outs[ra].n_preempted}"
    assert outs[ra].finished_ok and outs[rb].finished_ok
    assert engine.n_preemptions >= 1
    assert "preempt" in engine.events.kinds()
    assert engine.pools.free_pages() == 3


def test_preempted_chunked_prefill_resumes_bit_identical():
    """Preemption x chunked prefill: A's 150-token prompt is re-ingested
    chunk by chunk on resume (the ``_start_chunked(resume=...)`` path) and
    B's whole-prompt resume rides the exact-prefill path — both streams
    must stay bitwise the solo baselines through the pool trade."""
    model, params = _model_params("qwen1.5-4b", 8)
    pa = _prompts(model, 1, 150)[0].tolist()
    pb = _prompts(model, 2, 60)[1].tolist()
    sp = SamplingParams()
    base_a = _baseline(model, params, pa, 8, sp)
    base_b = _baseline(model, params, pb, 12, sp)

    engine = Engine(model, params, max_slots=2, n_pages=4,
                    max_pages_per_request=3, burst_steps=4,
                    prefill_chunk=64)
    ra = engine.submit(ServeRequest(tokens=pa, max_new_tokens=8))
    rb = engine.submit(ServeRequest(tokens=pb, max_new_tokens=12))
    outs = {o.request_id: o for o in engine.drain()}
    assert outs[ra].tokens == base_a
    assert outs[rb].tokens == base_b
    assert outs[ra].n_preempted >= 1, "pool pressure should preempt A"
    assert engine.pools.free_pages() == 4


def test_priority_orders_preemption_and_admission():
    """A high-priority arrival takes a slot from the *youngest* strictly
    lower-priority running request: C (younger) is preempted, A (older)
    runs undisturbed, and all three streams stay bitwise correct."""
    model, params = _model_params("qwen1.5-4b", 8)
    prompts = _prompts(model, 3, 60)
    sp = SamplingParams()
    hi = SamplingParams(priority=1)
    bases = [_baseline(model, params, prompts[0].tolist(), 8, sp),
             _baseline(model, params, prompts[1].tolist(), 8, sp),
             _baseline(model, params, prompts[2].tolist(), 4, hi)]

    engine = Engine(model, params, max_slots=2, n_pages=4,
                    max_pages_per_request=2, burst_steps=4)
    ra = engine.submit(ServeRequest(tokens=prompts[0].tolist(),
                                    max_new_tokens=8))
    rc = engine.submit(ServeRequest(tokens=prompts[1].tolist(),
                                    max_new_tokens=8))
    engine.step()  # A and C admitted, both emit fresh tokens
    rb = engine.submit(ServeRequest(tokens=prompts[2].tolist(),
                                    max_new_tokens=4, sampling=hi))
    outs = {o.request_id: o for o in engine.drain()}

    ev = next(e for e in engine.events if e["kind"] == "preempt")
    assert ev["request"] == rc and ev["for_request"] == rb
    assert outs[ra].status == "ok", "older same-priority victim chosen"
    assert outs[rc].n_preempted == 1
    assert outs[rb].status == "ok"
    assert [outs[r].tokens for r in (ra, rc, rb)] == bases


# ------------------------------------------------------------------ deadlines
def test_deadline_expires_queued_and_running_requests():
    """``deadline_s`` retires an expired request whether it is still
    queued (empty tokens) or mid-decode (partial tokens), with status
    ``deadline_exceeded`` — driven by a monkeypatched engine clock."""
    model, params = _model_params("qwen1.5-4b", 8)
    prompts = _prompts(model, 2, 12)
    engine = Engine(model, params, max_slots=1, n_pages=2,
                    max_pages_per_request=1, burst_steps=2)
    clock = {"now": 0.0}
    engine._now = lambda: clock["now"]
    ra = engine.submit(ServeRequest(
        tokens=prompts[0].tolist(), max_new_tokens=20,
        sampling=SamplingParams(deadline_s=5.0)))
    rb = engine.submit(ServeRequest(
        tokens=prompts[1].tolist(), max_new_tokens=4,
        sampling=SamplingParams(deadline_s=1.0)))
    engine.step()  # A admitted (1 slot); B waits in queue
    clock["now"] = 2.0
    outs = {o.request_id: o for o in engine.step()}
    assert outs[rb].status == "deadline_exceeded"
    assert outs[rb].tokens == [], "queued request never emitted"
    clock["now"] = 6.0
    outs = {o.request_id: o for o in engine.step()}
    assert outs[ra].status == "deadline_exceeded"
    assert 0 < len(outs[ra].tokens) < 20, "running request keeps partials"
    assert not outs[ra].finished_ok
    assert engine.events.kinds().count("request_deadline_exceeded") == 2
    engine.drain()
    assert engine.pools.free_pages() == 2


# --------------------------------------------------------------- backpressure
def test_bounded_queue_rejects_with_retry_hint():
    """``queue_depth`` bounds the queue: the rejecting ``EngineSaturated``
    carries a retry-after hint, the live occupancy and the queue length,
    and the same request is accepted once the engine drains."""
    model, params = _model_params("qwen1.5-4b", 8)
    prompts = _prompts(model, 2, 12)
    engine = Engine(model, params, max_slots=1, n_pages=4, queue_depth=1)
    engine.submit(ServeRequest(tokens=prompts[0].tolist(), max_new_tokens=4))
    req_b = ServeRequest(tokens=prompts[1].tolist(), max_new_tokens=4)
    with pytest.raises(EngineSaturated, match="retry after") as ei:
        engine.submit(req_b)
    assert ei.value.retry_after_s > 0
    assert 0.0 <= ei.value.occupancy <= 1.0
    assert ei.value.queued == 1
    assert "occupancy" in str(ei.value)
    engine.drain()
    engine.submit(req_b)  # accepted now
    assert len(engine.drain()) == 1


def test_admit_watermark_bounds_outstanding_demand():
    """``admit_watermark`` rejects a submission whose page demand (live +
    queued + incoming) exceeds the watermark fraction of the pool."""
    model, params = _model_params("qwen1.5-4b", 8)
    prompts = _prompts(model, 3, 60)
    engine = Engine(model, params, max_slots=2, n_pages=4,
                    max_pages_per_request=2, admit_watermark=1.0)
    reqs = [ServeRequest(tokens=p.tolist(), max_new_tokens=8)
            for p in prompts]
    engine.submit(reqs[0])  # demand 2 of 4
    engine.submit(reqs[1])  # demand 4 of 4
    with pytest.raises(EngineSaturated, match="admit watermark"):
        engine.submit(reqs[2])  # demand 6 > 4
    engine.drain()
    engine.submit(reqs[2])
    assert engine.drain()[0].finished_ok
    assert engine.pools.free_pages() == 4


# ------------------------------------------------------------ fault injection
@pytest.mark.parametrize("kv_bits", [8, 2])
def test_burst_fault_retries_bit_identical(kv_bits):
    """An injected burst failure fires *before* the dispatch (pools and
    slot rows untouched), so the retried burst re-runs from identical
    inputs and every stream stays bitwise the solo baseline."""
    model, params = _model_params("qwen1.5-4b", kv_bits)
    prompts = _prompts(model, 2, 60)
    sps = [SamplingParams(), SamplingParams(temperature=1.3, seed=7)]
    budgets = [10, 7]
    bases = [_baseline(model, params, prompts[i].tolist(), budgets[i],
                       sps[i]) for i in range(2)]
    plan = FaultPlan({(2, "burst"): 1})
    engine = Engine(model, params, max_slots=2, n_pages=8,
                    max_pages_per_request=2, burst_steps=4,
                    fault_plan=plan, retry=RetryPolicy(backoff_s=0.0))
    rids = [engine.submit(ServeRequest(tokens=prompts[i].tolist(),
                                       max_new_tokens=budgets[i],
                                       sampling=sps[i])) for i in range(2)]
    outs = {o.request_id: o for o in engine.drain()}
    assert plan.fired == [{"layer": 2, "stage": "burst", "batch": None}]
    assert "burst_retry" in engine.events.kinds()
    for rid, base in zip(rids, bases):
        assert outs[rid].status == "ok"
        assert outs[rid].tokens == base, "retried burst diverged"


def test_burst_retries_exhausted_isolates_batch_engine_continues():
    """A burst that keeps failing past ``max_restarts`` poisons the
    decoding requests (status ``failed``, pages released) but the engine
    itself stays serviceable for later submissions."""
    model, params = _model_params("qwen1.5-4b", 8)
    prompts = _prompts(model, 3, 60)
    base_c = _baseline(model, params, prompts[2].tolist(), 6,
                       SamplingParams())
    plan = FaultPlan({(2, "burst"): 3})  # fires through every retry
    engine = Engine(model, params, max_slots=2, n_pages=8,
                    max_pages_per_request=2, burst_steps=4, fault_plan=plan,
                    retry=RetryPolicy(max_restarts=2, backoff_s=0.0))
    ra = engine.submit(ServeRequest(tokens=prompts[0].tolist(),
                                    max_new_tokens=10))
    rb = engine.submit(ServeRequest(tokens=prompts[1].tolist(),
                                    max_new_tokens=10))
    outs = {o.request_id: o for o in engine.drain()}
    assert outs[ra].status == outs[rb].status == "failed"
    assert engine.events.kinds().count("burst_retry") == 2
    assert "burst_poisoned" in engine.events.kinds()
    rc = engine.submit(ServeRequest(tokens=prompts[2].tolist(),
                                    max_new_tokens=6))
    outs = {o.request_id: o for o in engine.drain()}
    assert outs[rc].tokens == base_c, "engine must keep serving after poison"


def test_admit_and_ingest_faults_isolate_one_request():
    """A fault at the admit / ingest stage fails only the request being
    worked on — its pages are released and every other request finishes
    bitwise clean."""
    model, params = _model_params("qwen1.5-4b", 8)
    prompts = _prompts(model, 2, 30)
    base = _baseline(model, params, prompts[1].tolist(), 6,
                     SamplingParams())
    engine = Engine(model, params, max_slots=2, n_pages=4,
                    fault_plan=FaultPlan({(1, "admit"): 1}),
                    retry=RetryPolicy(backoff_s=0.0))
    ra = engine.submit(ServeRequest(tokens=prompts[0].tolist(),
                                    max_new_tokens=6))
    rb = engine.submit(ServeRequest(tokens=prompts[1].tolist(),
                                    max_new_tokens=6))
    outs = {o.request_id: o for o in engine.drain()}
    assert outs[ra].status == "failed" and outs[ra].tokens == []
    assert outs[rb].tokens == base
    assert "request_failed" in engine.events.kinds()

    long_p = _prompts(model, 1, 150)[0].tolist()
    engine = Engine(model, params, max_slots=2, n_pages=4,
                    max_pages_per_request=3, prefill_chunk=64,
                    fault_plan=FaultPlan({(2, "ingest"): 1}),
                    retry=RetryPolicy(backoff_s=0.0))
    ra = engine.submit(ServeRequest(tokens=long_p, max_new_tokens=6))
    rb = engine.submit(ServeRequest(tokens=prompts[1].tolist(),
                                    max_new_tokens=6))
    outs = {o.request_id: o for o in engine.drain()}
    assert outs[ra].status == "failed", "chunked ingest fault isolates A"
    assert outs[rb].tokens == base
    assert engine.pools.free_pages() == 4


def test_retire_fault_defers_one_round():
    """A retire-stage fault defers retirement (idempotent bookkeeping) by
    one round; the request still finishes with its exact stream."""
    model, params = _model_params("qwen1.5-4b", 8)
    p = _prompts(model, 1, 12)[0].tolist()
    base = _baseline(model, params, p, 4, SamplingParams())
    engine = Engine(model, params, max_slots=1, n_pages=2, burst_steps=4,
                    fault_plan=FaultPlan({(1, "retire"): 1}),
                    retry=RetryPolicy(backoff_s=0.0))
    rid = engine.submit(ServeRequest(tokens=p, max_new_tokens=4))
    assert engine.step() == []  # finished, but retirement deferred
    assert "retire_deferred" in engine.events.kinds()
    outs = {o.request_id: o for o in engine.drain()}
    assert outs[rid].tokens == base and outs[rid].status == "ok"


def test_watchdog_raises_on_wedged_engine():
    """A busy engine making zero progress emits a ``stuck_round`` event at
    ``watchdog_rounds`` idle rounds and raises ``EngineStuck`` at twice
    that, so ``drain()`` fails loudly instead of spinning forever."""
    model, params = _model_params("qwen1.5-4b", 8)
    p = _prompts(model, 1, 12)[0].tolist()
    engine = Engine(model, params, max_slots=1, n_pages=2,
                    watchdog_rounds=3)
    engine.submit(ServeRequest(tokens=p, max_new_tokens=8))
    engine._burst = lambda: None  # wedge: bursts never emit anything
    with pytest.raises(EngineStuck, match="wedged"):
        for _ in range(20):
            engine.step()
    assert "stuck_round" in engine.events.kinds()


# ------------------------------------------------------------ overload traces
@pytest.mark.parametrize("name,kv_bits",
                         [("qwen1.5-4b", 8), ("deepseek-v2-236b", 2)])
def test_oversubscribed_trace_all_terminal_and_bit_identical(name, kv_bits):
    """The acceptance scenario: a Poisson trace whose hot page demand is
    2x the pool (4 slots x 2 pages against 4 pages) must drain with every
    request terminal, zero allocator errors, preemptions actually
    exercised, and every stream — preempted ones included — bitwise its
    solo baseline."""
    model, params = _model_params(name, kv_bits)
    prompts = _prompts(model, 8, 60)
    budgets = [8, 12, 9, 10, 8, 11, 12, 9]
    sps = [SamplingParams() if i % 2 == 0
           else SamplingParams(temperature=1.3, seed=i) for i in range(8)]
    reqs = [ServeRequest(tokens=prompts[i].tolist(),
                         max_new_tokens=budgets[i], sampling=sps[i])
            for i in range(8)]
    engine = Engine(model, params, max_slots=4, n_pages=4,
                    max_pages_per_request=2, burst_steps=4)
    stats = run_trace(engine, poisson_trace(reqs, rate=2.0, seed=3))

    assert stats["n_requests"] == 8
    assert sum(stats["statuses"].values()) == 8
    assert stats["n_shed"] == stats["n_deadline"] == stats["n_failed"] == 0
    assert stats["n_preemptions"] >= 1, "2x oversubscription must preempt"
    assert stats["n_preempted_requests"] >= 1
    assert "preempted" in stats["per_status"]
    outs = stats["outputs"]
    for i, rid in enumerate(sorted(outs)):  # rids issued in arrival order
        assert outs[rid].finished_ok
        assert outs[rid].ttft > 0
        base = _baseline(model, params, prompts[i].tolist(), budgets[i],
                         sps[i])
        assert outs[rid].tokens == base, \
            f"request {i} diverged under oversubscription"
    engine.pools.assert_quiescent()
    assert engine.pools.free_pages() == 4


def test_trace_sheds_over_queue_depth():
    """``run_trace`` records backpressure-rejected submissions as
    synthetic ``shed`` outputs (negative ids) so every submission is
    accounted for."""
    model, params = _model_params("qwen1.5-4b", 8)
    p = _prompts(model, 1, 12)[0].tolist()
    reqs = [ServeRequest(tokens=p, max_new_tokens=4) for _ in range(3)]
    engine = Engine(model, params, max_slots=1, n_pages=2,
                    max_pages_per_request=1, queue_depth=1)
    # rate 50: all three arrive in round 0 -> one queued, two shed
    stats = run_trace(engine, poisson_trace(reqs, rate=50.0, seed=0))
    assert stats["n_requests"] == 3
    assert stats["n_shed"] == 2 == stats["statuses"]["shed"]
    shed = [o for o in stats["outputs"].values() if o.status == "shed"]
    assert all(o.request_id < 0 and o.tokens == [] for o in shed)
    done = [o for o in stats["outputs"].values() if o.finished_ok]
    assert len(done) == 1 and len(done[0].tokens) == 4
    assert stats["per_status"]["shed"]["n"] == 2


def test_run_trace_overload_counters_on_stub_engine():
    """The summary's overload counters / per-status percentiles, pinned on
    hand-built outputs (one of each terminal status + one shed)."""
    outs = [RequestOutput(request_id=0, tokens=[1, 2], prompt_len=2,
                          submit_time=0.0, finish_time=1.0,
                          first_token_time=0.5),
            RequestOutput(request_id=1, tokens=[3], prompt_len=2,
                          submit_time=0.0, finish_time=2.0,
                          first_token_time=0.5, status="preempted_2",
                          n_preempted=2),
            RequestOutput(request_id=2, tokens=[], prompt_len=2,
                          submit_time=0.0, finish_time=3.0,
                          status="deadline_exceeded"),
            RequestOutput(request_id=3, tokens=[4], prompt_len=2,
                          submit_time=0.0, finish_time=4.0, status="failed")]

    class Stub:
        n_preemptions = 2
        admission_stall_s = 0.0

        def __init__(self):
            self._pending = list(outs)
            self._n = 0

        def submit(self, req):
            self._n += 1
            if self._n == 3:
                raise _saturated()

        @property
        def busy(self):
            return bool(self._pending)

        def step(self):
            return [self._pending.pop(0)] if self._pending else []

    def _saturated():
        e = EngineSaturated("full")
        e.retry_after_s, e.occupancy, e.queued = 0.1, 1.0, 2
        return e

    reqs = [ServeRequest(tokens=[1, 2], max_new_tokens=2)] * 5
    stats = run_trace(Stub(), poisson_trace(reqs, rate=100.0, seed=0))
    assert stats["n_requests"] == 5
    assert stats["statuses"] == {"ok": 1, "preempted_2": 1, "shed": 1,
                                 "deadline_exceeded": 1, "failed": 1}
    assert stats["n_shed"] == 1 and stats["n_deadline"] == 1
    assert stats["n_failed"] == 1
    assert stats["n_preemptions"] == 2
    assert stats["n_preempted_requests"] == 1
    assert set(stats["per_status"]) == {"ok", "preempted", "shed",
                                        "deadline_exceeded", "failed"}
    assert stats["per_status"]["preempted"]["n"] == 1
    # service percentiles cover only the completed requests
    assert stats["p50_latency_s"] == pytest.approx(
        float(np.percentile([1.0, 2.0], 50)))
    assert _status_group("preempted_7") == "preempted"
    assert _status_group("ok") == "ok"


# ------------------------------------------------------------ page accounting
def test_page_accounting_guards():
    """Double free, trash-page release, duplicate ids and post-drain leaks
    all raise ``PageAccountingError`` instead of corrupting the stack."""
    model, _ = _model_params("qwen1.5-4b", 8)
    pools = PagedPools(model, 4)
    ids = np.asarray(pools.alloc(2))
    pools.release(ids)
    with pytest.raises(PageAccountingError, match="double free"):
        pools.release(ids)
    with pytest.raises(PageAccountingError, match="trash page"):
        pools.release(np.zeros(1, np.int32))
    ids2 = np.asarray(pools.alloc(2))
    with pytest.raises(PageAccountingError, match="duplicate"):
        pools.release(np.array([ids2[0], ids2[0]], np.int32))
    with pytest.raises(PageAccountingError, match="leak"):
        pools.assert_quiescent()
    pools.release(ids2)
    pools.assert_quiescent()


def test_engine_drain_detects_leaked_pages():
    """``drain()`` ends with a free-list audit: a page that never came
    back (here: leaked by reaching around the engine) fails the drain."""
    model, params = _model_params("qwen1.5-4b", 8)
    engine = Engine(model, params, max_slots=1, n_pages=4)
    engine.pools.alloc(1, context=" (leaked on purpose)")
    engine.submit(ServeRequest(tokens=[1, 2, 3], max_new_tokens=2))
    with pytest.raises(PageAccountingError, match="leak"):
        engine.drain()


def test_exhaustion_error_carries_occupancy_and_hint():
    """The allocator's sizing error exposes need/have/occupancy (and an
    optional retry-after hint) as attributes, with one shared sizing
    sentence between submit-time and runtime failures."""
    model, params = _model_params("qwen1.5-4b", 8)
    engine = Engine(model, params, max_slots=1, n_pages=2,
                    max_pages_per_request=8)
    with pytest.raises(Exception, match="can never fit") as ei:
        engine.submit(ServeRequest(tokens=[1] * 60, max_new_tokens=200))
    err = ei.value
    assert err.need == -(-260 // engine.page) and err.have == 2
    assert err.occupancy == pytest.approx(0.0)  # empty pool, still too small
    assert "occupancy" in str(err) and "need" in str(err)
    hinted = engine.pools.exhausted(4, retry_after_s=0.25)
    assert "Retry after ~0.25s" in str(hinted)
    assert hinted.retry_after_s == 0.25
