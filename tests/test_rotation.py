"""Rotation (computational invariance) + Hadamard construction."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.rotation import (
    hadamard_matrix,
    random_hadamard,
    random_orthogonal,
    rotate_model,
)
from repro.models import build_model

FAMILIES = ["qwen1.5-4b", "mamba2-780m", "deepseek-v2-236b",
            "jamba-v0.1-52b", "llama-3.2-vision-11b", "whisper-medium",
            "command-r-35b"]


@pytest.mark.parametrize("n", [2, 8, 64, 128])
def test_hadamard_orthonormal(n):
    h = hadamard_matrix(n)
    assert jnp.allclose(h @ h.T, jnp.eye(n), atol=1e-5)


@pytest.mark.parametrize("n", [64, 96, 128, 384])  # incl. non-powers of two
def test_random_hadamard_orthogonal(n):
    q = random_hadamard(jax.random.key(0), n)
    assert jnp.allclose(q @ q.T, jnp.eye(n), atol=1e-4)
    assert jnp.allclose(q.T @ q, jnp.eye(n), atol=1e-4)


def test_random_orthogonal():
    q = random_orthogonal(jax.random.key(1), 33)
    assert jnp.allclose(q @ q.T, jnp.eye(33), atol=1e-5)


@pytest.mark.parametrize("name", FAMILIES)
def test_rotation_invariance(name):
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32",
                              capacity_factor=100.0)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    # non-trivial norm scales exercise the fusion
    params = jax.tree_util.tree_map_with_path(
        lambda p, x: x * 1.3 if "norm" in str(p) and x.ndim == 1 else x,
        params)
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == "vlm":
        kw["media"] = jax.random.normal(jax.random.key(2),
                                        (2, cfg.n_media_tokens, cfg.d_model))
    if cfg.family == "encdec":
        kw["frames"] = jax.random.normal(jax.random.key(3),
                                         (2, 32, cfg.d_model))
    base = model.logits(params, toks, **kw)
    rparams, _ = rotate_model(params, cfg, model, jax.random.key(9))
    rot = model.logits(rparams, toks, **kw)
    rel = float(jnp.abs(base - rot).max() / jnp.abs(base).max())
    assert rel < 5e-4, f"{name}: invariance broken ({rel:.2e})"


def test_rotation_reduces_outliers(tiny_cfg, tiny_model_params):
    """QuaRot's premise: rotation shrinks the weight kurtosis / max ratio."""
    model, params = tiny_model_params
    rparams, _ = rotate_model(params, tiny_cfg, model, jax.random.key(3))

    def outlier_ratio(p):
        ws = [w for path, w in
              jax.tree_util.tree_flatten_with_path(p["groups"])[0]
              if w.ndim >= 2]
        return max(float(jnp.max(jnp.abs(w)) /
                         (jnp.mean(jnp.abs(w)) + 1e-9)) for w in ws)

    assert outlier_ratio(rparams) <= outlier_ratio(params) * 1.5
