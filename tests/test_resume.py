"""Fault-tolerant quantization: kill-and-resume parity, in-process retry,
artifact corruption rejection.

The contract under test (core/resume.QuantizeRunner + the scheduler stage
hooks): a quantize run killed at ANY stage dispatch point — mid-capture,
mid-solve, mid-pack, under either scheduler — and resumed from its latest
layer-solve checkpoint by a *fresh* pipeline/runner (a new process, as far
as jax is concerned) produces a packed serving artifact whose files are
**byte-identical** to a run that never died.  File-level sha256 is the
strongest form of the claim: it covers codes, scales, zeros, the residual
tree, entry order inside the npz, and the meta.json checksums.

The fake-8-device mesh variant (subprocess, like test_distributed) repeats
the kill/resume under sharded calibration + streaming sharded Hessians +
sharded write-back on a (2 data x 4 model) mesh.

Artifact durability: a bit-flipped payload file fails its recorded sha256
at load with ArtifactCorruptError (``verify=False`` opts out) — the
serve-side gate against silently serving corrupt codes.
"""
import hashlib
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.checkpoint import ArtifactCorruptError, CheckpointManager
from repro.checkpoint import packed as cp
from repro.core import RSQConfig, RSQPipeline
from repro.core.resume import QuantizeRunner
from repro.data.calibration import calibration_set
from repro.runtime.fault import FaultPlan, InjectedFailure, RetryPolicy

REPO = Path(__file__).resolve().parent.parent

N_CALIB, SEQ, BATCH = 8, 32, 4
# the injection layer: > 0 so a layer-solve checkpoint exists to resume
# from.  Mid-capture kills need scheduler-specific layers: the overlapped
# schedule interleaves layer i+1's capture into layer i's sweep *before*
# layer i's commit, so the first capture point that lands after a durable
# commit is layer 2's (during layer 1's sweep) — hence the 3-layer stack.
FAIL_LAYER = 1
STAGES = [("capture", 1), ("solve", None), ("pack", None)]
CAPTURE_LAYER = {"sequential": 1, "overlapped": 2}


def _rsq(scheduler):
    return RSQConfig(bits=4, group_size=32, scheduler=scheduler,
                     pack_output=True)


def _calib(cfg):
    return calibration_set(cfg.vocab_size, N_CALIB, SEQ, seed=0)


@pytest.fixture(scope="module")
def mp(tiny_cfg):
    """3-layer variant of the tiny model (see CAPTURE_LAYER note)."""
    import dataclasses

    from repro.models import build_model

    cfg = dataclasses.replace(tiny_cfg, n_layers=3)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    return cfg, model, params


def _sha_dir(d: Path) -> dict:
    return {p.name: hashlib.sha256(p.read_bytes()).hexdigest()
            for p in sorted(Path(d).iterdir())}


@pytest.fixture(scope="module")
def baselines(tmp_path_factory, mp):
    """Uninterrupted-run artifacts, one per scheduler: {sched: (dir, shas)}."""
    cfg, model, params = mp
    out = {}
    for sched in ("sequential", "overlapped"):
        d = tmp_path_factory.mktemp(f"clean_{sched}")
        pipe = RSQPipeline(model, _rsq(sched))
        qp, _ = pipe.run(params, _calib(cfg), batch_size=BATCH)
        cp.save_packed_artifact(d, pipe.artifact, params=qp)
        out[sched] = (d, _sha_dir(d))
    return out


def _kill_then_resume(model, params, calib, sched, tmp, fault_key):
    """One killed 'process' (max_restarts=0 so the fault propagates), then
    a fresh pipeline+runner over the same progress dir — returns the
    resumed runner and its saved artifact dir."""
    prog = tmp / "progress"
    r1 = QuantizeRunner(RSQPipeline(model, _rsq(sched)),
                        CheckpointManager(prog),
                        policy=RetryPolicy(max_restarts=0))
    fault = FaultPlan({fault_key: 1})
    with pytest.raises(InjectedFailure):
        r1.run(params, calib, fault=fault, batch_size=BATCH)
    assert fault.fired and fault.fired[0]["layer"] == fault_key[0]
    # a layer-solve checkpoint must exist for the resume to pick up
    assert CheckpointManager(prog).latest_step() is not None

    pipe2 = RSQPipeline(model, _rsq(sched))
    r2 = QuantizeRunner(pipe2, CheckpointManager(prog),
                        policy=RetryPolicy(max_restarts=0))
    qp, report = r2.run(params, calib, batch_size=BATCH)
    art = tmp / "artifact"
    cp.save_packed_artifact(art, pipe2.artifact, params=qp)
    return r2, art, report


@pytest.mark.parametrize("sched", ["sequential", "overlapped"])
@pytest.mark.parametrize("stage,batch", STAGES,
                         ids=[s for s, _ in STAGES])
def test_kill_resume_byte_identical(tmp_path, mp, baselines, sched, stage,
                                    batch):
    cfg, model, params = mp
    layer = CAPTURE_LAYER[sched] if stage == "capture" else FAIL_LAYER
    key = (layer, stage) if batch is None else (layer, stage, batch)
    r2, art, report = _kill_then_resume(
        model, params, _calib(cfg), sched, tmp_path, key)
    assert "resume" in r2.events.kinds()
    # the solved prefix was skipped, not recomputed
    assert report["layers"]["layer0"].get("resumed") is True
    assert _sha_dir(art) == baselines[sched][1]


@pytest.mark.parametrize("sched", ["sequential", "overlapped"])
def test_in_process_retry_recovers(tmp_path, mp, baselines, sched):
    """With restarts allowed, one runner survives the injected failure by
    itself: restore -> mid-stack re-entry -> identical artifact."""
    cfg, model, params = mp
    pipe = RSQPipeline(model, _rsq(sched))
    runner = QuantizeRunner(pipe, CheckpointManager(tmp_path / "progress"),
                            policy=RetryPolicy(max_restarts=2,
                                               backoff_s=0.001))
    qp, _ = runner.run(params, _calib(cfg),
                       fault=FaultPlan({(FAIL_LAYER, "solve"): 1}),
                       batch_size=BATCH)
    assert runner.restarts == 1
    kinds = runner.events.kinds()
    assert "restart" in kinds and "resume" in kinds
    restart = next(e for e in runner.events if e["kind"] == "restart")
    assert restart["attempt"] == 1 and "backoff_s" in restart
    art = tmp_path / "artifact"
    cp.save_packed_artifact(art, pipe.artifact, params=qp)
    assert _sha_dir(art) == baselines[sched][1]


def test_unrecoverable_exception_propagates(tmp_path, mp):
    """A failure outside the policy's recoverable tuple is not retried."""
    cfg, model, params = mp
    runner = QuantizeRunner(RSQPipeline(model, _rsq("sequential")),
                            CheckpointManager(tmp_path / "p"),
                            policy=RetryPolicy(recoverable=(KeyError,),
                                               max_restarts=5))
    with pytest.raises(InjectedFailure):
        runner.run(params, _calib(cfg),
                   fault=FaultPlan({(0, "solve"): 1}), batch_size=BATCH)
    assert runner.restarts == 0


def _flip_member_byte(path: Path) -> None:
    """Flip one byte inside the first zip member's *data* region: the zip
    container stays parseable, the stored array bytes do not match the
    recorded sha256 — the silent-corruption case checksums exist for."""
    import struct
    import zipfile

    with zipfile.ZipFile(path) as zf:
        zi = zf.infolist()[0]
    raw = bytearray(path.read_bytes())
    ho = zi.header_offset
    name_len, extra_len = struct.unpack("<HH", raw[ho + 26 : ho + 30])
    data_off = ho + 30 + name_len + extra_len
    raw[data_off + zi.file_size // 2] ^= 0xFF
    path.write_bytes(bytes(raw))


def test_corrupt_artifact_rejected(tmp_path, baselines):
    """A bit-flipped payload fails its sha256 at load; verify=False and
    pre-v3 artifacts opt out."""
    src, _ = baselines["sequential"]
    d = tmp_path / "corrupt"
    d.mkdir()
    for p in Path(src).iterdir():
        (d / p.name).write_bytes(p.read_bytes())
    _flip_member_byte(d / "packed.npz")

    with pytest.raises(ArtifactCorruptError, match="re-run"):
        cp.load_packed_artifact(d)
    with pytest.raises(ArtifactCorruptError):
        cp.load_packed_forward_params(d)
    # explicit opt-out (launch.serve --no-verify) skips the sha256 gate:
    # the failure becomes whatever the deserializer hits (here zipfile's
    # member CRC, deep inside np.load) instead of the actionable
    # ArtifactCorruptError raised up front
    import zipfile
    with pytest.raises(zipfile.BadZipFile):
        cp.load_packed_artifact(d, verify=False)
    # and a pristine artifact loads fine with verification off
    entries, meta = cp.load_packed_artifact(src, verify=False)
    assert len(entries) == len(meta["entries"])

    # the residual payload is covered too
    d2 = tmp_path / "corrupt_res"
    d2.mkdir()
    for p in Path(src).iterdir():
        (d2 / p.name).write_bytes(p.read_bytes())
    _flip_member_byte(d2 / "residual.npz")
    with pytest.raises(ArtifactCorruptError):
        cp.load_packed_params(d2)


def test_artifact_checksums_recorded(baselines):
    """v3 artifacts carry a sha256 per payload file, and it matches."""
    d, shas = baselines["overlapped"]
    meta = json.loads((Path(d) / "meta.json").read_text())
    assert meta["format"] == cp.FORMAT
    for fname in ("packed.npz", "residual.npz"):
        assert meta["checksums"][fname] == shas[fname]


def test_loader_geometry_mismatch_rejected():
    from repro.data.loader import CalibrationLoader
    from repro.data.synthetic import SyntheticCorpus

    c = SyntheticCorpus(vocab_size=101, seed=1)
    ld = CalibrationLoader(c, 8, 16, batch_size=4, seed=1)
    st = ld.state()
    assert st["n_samples"] == 8 and st["batch_size"] == 4
    with pytest.raises(ValueError, match="n_samples"):
        ld.restore({**st, "n_samples": 16})
    with pytest.raises(ValueError, match="seed"):
        ld.restore({**st, "seed": 2})
    ld.restore({**st, "step": 1})
    assert ld.step == 1


# ------------------------------------------------------- fake 8-device mesh


def _run(code: str) -> dict:
    import os
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_kill_resume_byte_identical_on_mesh():
    """(2 data x 4 model) mesh, sharded calibration, streaming sharded
    Hessians, sharded write-back: kill+resume parity holds under both
    schedulers, including a mid-capture kill whose resume restores the
    overlapped schedule's checkpointed accumulators."""
    out = _run("""
    import dataclasses, hashlib, json, pathlib, tempfile
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.core import RSQConfig, RSQPipeline
    from repro.core.resume import QuantizeRunner
    from repro.checkpoint import CheckpointManager
    from repro.checkpoint import packed as cp
    from repro.data import SyntheticCorpus, CalibrationLoader
    from repro.runtime.fault import FaultPlan, InjectedFailure, RetryPolicy
    from repro.runtime.sharding import ParallelCtx

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh, dp=("data",), tp="model")
    cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                              dtype="float32", n_layers=2, d_model=64,
                              vocab_size=256)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    corpus = SyntheticCorpus(vocab_size=256, seed=0)
    N, T, B = 16, 16, 8

    def rsq(s):
        return RSQConfig(bits=4, rotate=False, scheduler=s,
                         shard_hessians=True, pack_output=True)

    def loader():
        return CalibrationLoader(corpus, N, T, ctx=ctx, batch_size=B, seed=0)

    def sha_dir(d):
        return {p.name: hashlib.sha256(p.read_bytes()).hexdigest()
                for p in sorted(pathlib.Path(d).iterdir())}

    td = pathlib.Path(tempfile.mkdtemp())
    results = {}
    for sched in ("sequential", "overlapped"):
        pipe = RSQPipeline(model, rsq(sched), ctx=ctx)
        qp, _ = pipe.run(params, loader().dataset(), batch_size=B)
        cp.save_packed_artifact(td / f"clean_{sched}", pipe.artifact,
                                params=qp)
        base = sha_dir(td / f"clean_{sched}")
        kills = [("solve", None)]
        if sched == "overlapped":
            kills.append(("capture", 1))
        for stage, batch in kills:
            key = (1, stage) if batch is None else (1, stage, batch)
            prog = td / f"prog_{sched}_{stage}"
            ld = loader()
            r1 = QuantizeRunner(RSQPipeline(model, rsq(sched), ctx=ctx),
                                CheckpointManager(prog), loader=ld,
                                policy=RetryPolicy(max_restarts=0))
            try:
                r1.run(params, ld.dataset(), fault=FaultPlan({key: 1}),
                       batch_size=B)
                raise SystemExit("fault did not fire")
            except InjectedFailure:
                pass
            ld2 = loader()
            pipe2 = RSQPipeline(model, rsq(sched), ctx=ctx)
            r2 = QuantizeRunner(pipe2, CheckpointManager(prog), loader=ld2,
                                policy=RetryPolicy(max_restarts=0))
            qp2, _ = r2.run(params, ld2.dataset(), batch_size=B)
            cp.save_packed_artifact(td / f"res_{sched}_{stage}",
                                    pipe2.artifact, params=qp2)
            results[f"{sched}_{stage}"] = (
                sha_dir(td / f"res_{sched}_{stage}") == base)
    print(json.dumps(results))
    """)
    assert out == {"sequential_solve": True, "overlapped_solve": True,
                   "overlapped_capture": True}, out
