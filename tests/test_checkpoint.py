"""Checkpointing: atomicity, retention, async, exact resume, elastic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


@pytest.fixture
def state():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
            "opt": [jnp.ones(3), {"v": jnp.zeros(2)}]}


def test_roundtrip_and_retention(tmp_path, state):
    cm = CheckpointManager(tmp_path, keep=2)
    for s in (10, 20, 30):
        cm.save(s, state, extra={"loader": {"step": s}}, blocking=True)
    assert cm.all_steps() == [20, 30]
    step, restored, extra = cm.restore()
    assert step == 30 and extra["loader"]["step"] == 30
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save(tmp_path, state):
    cm = CheckpointManager(tmp_path, keep=3)
    cm.save(1, state)
    cm.wait()
    assert cm.latest_step() == 1


def test_atomicity_no_partial_dirs(tmp_path, state):
    cm = CheckpointManager(tmp_path, keep=3)
    cm.save(5, state, blocking=True)
    assert not list(tmp_path.glob("tmp.*"))
    # the completed step carries the terminal marker and no .tmp leftovers
    d = tmp_path / "step_0000000005"
    assert (d / "DONE").exists()
    assert not list(d.glob("*.tmp*"))


def test_half_written_step_is_ignored(tmp_path, state):
    """A step dir without the terminal DONE marker (crash mid-save, e.g. a
    non-atomic rename or a partial copy) is invisible to latest_step() and
    refused by restore() — recovery falls back to the previous step."""
    cm = CheckpointManager(tmp_path, keep=5)
    cm.save(1, state, blocking=True)
    cm.save(2, state, blocking=True)
    (tmp_path / "step_0000000002" / "DONE").unlink()  # simulate the crash
    assert cm.all_steps() == [1]
    assert cm.latest_step() == 1
    step, _, _ = cm.restore()
    assert step == 1
    with pytest.raises(FileNotFoundError, match="half-written"):
        cm.restore(2)
    # with no completed step at all, restore reports no checkpoints
    (tmp_path / "step_0000000001" / "DONE").unlink()
    with pytest.raises(FileNotFoundError):
        cm.restore()


def test_restore_specific_step(tmp_path, state):
    cm = CheckpointManager(tmp_path, keep=5)
    for s in (1, 2):
        st = jax.tree.map(lambda x: x + s, state)
        cm.save(s, st, blocking=True)
    step, restored, _ = cm.restore(1)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]),
        np.asarray(state["params"]["w"] + 1))


@pytest.mark.skipif(not hasattr(jax.sharding, "AxisType"),
                    reason="jax predates jax.sharding.AxisType; runs on "
                           "CI's jax (same probe as test_distributed)")
def test_elastic_restore_new_sharding(tmp_path, state):
    """Restore onto explicit (different) shardings — the elastic path."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    cm = CheckpointManager(tmp_path)
    cm.save(1, state, blocking=True)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    _, restored, _ = cm.restore(shardings=sh)
    assert restored["params"]["w"].sharding == NamedSharding(mesh, P())
