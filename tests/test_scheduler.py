"""Scheduler + streaming-Hessian contracts.

The OverlappedScheduler only reorders *dispatch* — it must produce
bit-identical quantized parameters to the SequentialScheduler.  The
streaming sharded Hessian accumulators must (a) match the dense
accumulation numerically and (b) stay sharded on a mesh — no device ever
holds an unsharded per-layer Hessian during accumulation.
"""
import dataclasses
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    OverlappedScheduler,
    RSQConfig,
    RSQPipeline,
    SequentialScheduler,
    get_scheduler,
)
from repro.core.hessian import accumulate, reduce_shards
from repro.core.scheduler import resolve_hessian_shards

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def toy4():
    """4-layer homogeneous toy model."""
    cfg = dataclasses.replace(
        get_config("llama3-8b").reduced(), dtype="float32",
        n_layers=4, d_model=64, vocab_size=256)
    from repro.models import build_model

    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    calib = jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab_size)
    return model, params, calib


def _quantize(toy4, **kw):
    model, params, calib = toy4
    rsq = RSQConfig(bits=4, rotate=False, importance="attn_con", **kw)
    pipe = RSQPipeline(model, rsq)
    qparams, report = pipe.run(params, calib, batch_size=4)
    return qparams, report, pipe


def test_overlapped_bit_identical_to_sequential(toy4):
    q_seq, rep_seq, _ = _quantize(toy4, scheduler="sequential")
    q_ovl, rep_ovl, _ = _quantize(toy4, scheduler="overlapped")
    assert rep_seq["scheduler"] == "sequential"
    assert rep_ovl["scheduler"] == "overlapped"
    for a, b in zip(jax.tree.leaves(q_seq), jax.tree.leaves(q_ovl)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # per-weight error reports agree too (same solves, deferred sync)
    for tag, rep in rep_seq["layers"].items():
        assert rep["weights"] == rep_ovl["layers"][tag]["weights"]


def test_overlapped_bit_identical_ldlq(toy4):
    q_seq, _, _ = _quantize(toy4, scheduler="sequential", method="ldlq")
    q_ovl, _, _ = _quantize(toy4, scheduler="overlapped", method="ldlq")
    for a, b in zip(jax.tree.leaves(q_seq), jax.tree.leaves(q_ovl)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_overlapped_adds_no_compiles(toy4):
    """Pipelined dispatch reuses the per-meta trace cache: still 1/1."""
    _, _, pipe = _quantize(toy4, scheduler="overlapped")
    assert pipe.trace_counts == {"capture": 1, "apply": 1}


def test_overlapped_prewarm_heterogeneous_stack():
    """A stack with >1 distinct meta takes the concurrent-prewarm path
    (background-thread compiles): results stay bit-identical to the
    lock-step schedule and the compile accounting stays exact."""
    cfg = dataclasses.replace(
        get_config("deepseek-v2-236b").reduced(), dtype="float32",
        n_routed_experts=4, d_model=64)
    from repro.models import build_model

    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    calib = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
    outs, traces = {}, {}
    for sched in ("sequential", "overlapped"):
        rsq = RSQConfig(bits=4, rotate=False, importance="attn_con",
                        scheduler=sched)
        pipe = RSQPipeline(model, rsq)
        outs[sched], _ = pipe.run(params, calib, batch_size=4)
        traces[sched] = dict(pipe.trace_counts)
    assert traces["sequential"]["capture"] > 1  # really heterogeneous
    assert traces["overlapped"] == traces["sequential"]
    for a, b in zip(jax.tree.leaves(outs["sequential"]),
                    jax.tree.leaves(outs["overlapped"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_scheduler_registry_and_auto():
    assert isinstance(get_scheduler("sequential"), SequentialScheduler)
    assert isinstance(get_scheduler("overlapped"), OverlappedScheduler)
    auto = get_scheduler(None)
    if jax.default_backend() == "cpu":
        assert isinstance(auto, SequentialScheduler)
    with pytest.raises(ValueError):
        get_scheduler("warp-speed")


def test_resolve_hessian_shards():
    from repro.runtime.sharding import LOCAL

    assert resolve_hessian_shards(False, LOCAL) == 1
    assert resolve_hessian_shards(True, LOCAL) == 1  # no mesh -> dense
    assert resolve_hessian_shards(4, LOCAL) == 4
    assert resolve_hessian_shards(0, None) == 1


# ------------------------------------------------------- streaming hessians


def test_streaming_accumulate_matches_dense():
    x = jax.random.normal(jax.random.key(0), (96, 32))
    r = jax.random.uniform(jax.random.key(1), (96,))
    dense = accumulate(None, x, r)
    for s in (2, 3, 4):
        sharded = accumulate(None, x, r, n_shards=s)
        assert sharded.shape == (s, 32, 32)
        np.testing.assert_allclose(np.asarray(reduce_shards(sharded)),
                                   np.asarray(dense), rtol=1e-5, atol=1e-4)


def test_streaming_accumulate_pads_ragged_rows():
    """Rows that don't divide by S are zero-padded — exactly gram-neutral."""
    x = jax.random.normal(jax.random.key(2), (50, 16))  # 50 % 4 != 0
    dense = accumulate(None, x)
    sharded = accumulate(None, x, n_shards=4)
    np.testing.assert_allclose(np.asarray(reduce_shards(sharded)),
                               np.asarray(dense), rtol=1e-5, atol=1e-4)


def test_streaming_accumulate_expert_stacks():
    xe = jax.random.normal(jax.random.key(3), (4, 32, 16))
    re = jax.random.uniform(jax.random.key(4), (4, 32))
    dense = accumulate(None, xe, re)
    sharded = accumulate(None, xe, re, n_shards=2)
    assert sharded.shape == (2, 4, 16, 16)
    np.testing.assert_allclose(np.asarray(reduce_shards(sharded)),
                               np.asarray(dense), rtol=1e-5, atol=1e-4)


def test_pipeline_shard_hessians_close_to_dense(toy4):
    """Single-host streaming (int shard count): same model quality; only
    float summation order differs from the dense accumulators."""
    q_dense, _, _ = _quantize(toy4, scheduler="sequential")
    q_shard, rep, _ = _quantize(toy4, scheduler="overlapped",
                                shard_hessians=2)
    for a, b in zip(jax.tree.leaves(q_dense), jax.tree.leaves(q_shard)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0.05)


def _run_sub(code: str) -> dict:
    import os
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=2",
           "PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_vs_dense_hessian_on_fake_mesh():
    """2-device mesh: the streaming accumulator stays sharded end to end
    (each device only ever holds its own partial) and the single solve-time
    reduction matches the dense per-batch-psum path."""
    out = _run_sub("""
    import json, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.runtime.sharding import ParallelCtx
    from repro.core.distributed import make_sharded_hessian_fn

    mesh = jax.make_mesh((2,), ("data",))
    ctx = ParallelCtx(mesh=mesh, dp=("data",))
    acc, reduce_fn = make_sharded_hessian_fn(ctx, streaming=True)
    dense = make_sharded_hessian_fn(ctx)

    h, hd = None, jnp.zeros((32, 32))
    shard_shapes = []
    for s in range(3):
        x = jax.device_put(jax.random.normal(jax.random.key(s), (4, 8, 32)),
                           NamedSharding(mesh, P("data", None, None)))
        r = jax.device_put(jax.random.uniform(jax.random.key(10 + s), (4, 8)),
                           NamedSharding(mesh, P("data", None)))
        h = acc(h, x, r)
        hd = dense(hd, x, r)
        shard_shapes.append(
            [list(sh.data.shape) for sh in h.addressable_shards])
    hr = reduce_fn(h)
    rep = all(np.array_equal(np.asarray(s.data),
                             np.asarray(hr.addressable_shards[0].data))
              for s in hr.addressable_shards)
    print(json.dumps({
        "spec": str(h.sharding.spec),
        "shard_shapes": shard_shapes,
        "rel_diff": float(jnp.abs(hr - hd).max() / jnp.abs(hd).max()),
        "replicated": bool(rep),
    }))
    """)
    # every per-batch accumulator state is the (1, 32, 32) local partial —
    # the unsharded (32, 32) Hessian never exists on a device pre-reduce
    for shapes in out["shard_shapes"]:
        assert shapes == [[1, 32, 32], [1, 32, 32]]
    assert "data" in out["spec"]
    assert out["rel_diff"] < 1e-5
    assert out["replicated"]
