"""Calibration-engine performance contracts (trace cache + batched solves).

The fused engine must compile O(distinct metas) capture/apply programs —
not O(layers) — and its shape-grouped batched GPTQ solves must agree with
the sequential per-weight solver."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import RSQConfig, RSQPipeline
from repro.core.distributed import gptq_quantize_batched, ldlq_quantize_batched
from repro.core.gptq import gptq_quantize
from repro.core.hessian import accumulate
from repro.core.ldlq import ldlq_quantize
from repro.core.pipeline import quantize_layer_weights
from repro.core.quantizer import QuantSpec


@pytest.fixture(scope="module")
def toy4():
    """4-layer homogeneous toy model (one distinct BlockMeta)."""
    cfg = dataclasses.replace(
        get_config("llama3-8b").reduced(), dtype="float32",
        n_layers=4, d_model=64, vocab_size=256)
    from repro.models import build_model

    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    calib = jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab_size)
    return model, params, calib


def test_trace_cache_once_per_meta(toy4):
    model, params, calib = toy4
    pipe = RSQPipeline(model, RSQConfig(bits=4, rotate=False,
                                        importance="attn_con"))
    _, report = pipe.run(params, calib, batch_size=4)
    assert len(report["layers"]) == 4
    # homogeneous stack -> exactly one capture trace and one apply trace
    assert pipe.trace_counts == {"capture": 1, "apply": 1}


def test_trace_cache_disabled_traces_per_layer(toy4):
    """trace_cache=False restores the legacy fresh-jit-per-layer behaviour
    (the benchmark baseline) — compile count scales with depth again."""
    model, params, calib = toy4
    pipe = RSQPipeline(model, RSQConfig(bits=4, rotate=False,
                                        importance="attn_con",
                                        trace_cache=False))
    pipe.run(params, calib, batch_size=4)
    assert pipe.trace_counts == {"capture": 4, "apply": 4}


def test_ragged_tail_batch_retraces_once(toy4):
    """A ragged last batch costs one extra trace total, not one per layer."""
    model, params, calib = toy4
    pipe = RSQPipeline(model, RSQConfig(bits=4, rotate=False,
                                        importance="attn_con"))
    pipe.run(params, calib, batch_size=6)  # batches of 6 and 2
    assert pipe.trace_counts == {"capture": 2, "apply": 2}


def _solve_set(n, d_in=64, d_out=48, seed=0):
    ws, hs = [], []
    for s in range(n):
        w = jax.random.normal(jax.random.key(seed + s), (d_in, d_out)) * 0.5
        x = jax.random.normal(jax.random.key(seed + s + 100), (256, d_in))
        ws.append(w)
        hs.append(accumulate(None, x))
    return ws, hs


@pytest.mark.parametrize("spec", [
    QuantSpec(bits=3, group_size=32),
    QuantSpec(bits=4, group_size=-1),
    # 2-bit / small-group regression (ROADMAP parity note): the batched
    # CPU trsm used to accumulate in a different order than the single
    # call, and the ulp drift cascaded through per-group find_params into
    # flipped codes.  Pinned by the batch-invariant triangular inverse
    # (gptq._inv_upper) + the fused-multiply-free group-param form.
    QuantSpec(bits=2, group_size=8),
    QuantSpec(bits=2, group_size=8, sym=False),
])
def test_batched_solve_matches_sequential(spec):
    ws, hs = _solve_set(3)
    seq = [gptq_quantize(w, h, spec, block=32) for w, h in zip(ws, hs)]
    bat = gptq_quantize_batched(jnp.stack(ws), jnp.stack(hs), spec, block=32)
    for i, s in enumerate(seq):
        assert np.array_equal(np.asarray(s["q"]), np.asarray(bat["q"][i]))
        np.testing.assert_allclose(np.asarray(s["w_deq"]),
                                   np.asarray(bat["w_deq"][i]), atol=2e-6)


def test_batched_ldlq_matches_sequential():
    """The vmapped LDLQ path (satellite of the scheduler PR) must agree
    with per-weight sequential solves."""
    ws, hs = _solve_set(3)
    seq = [ldlq_quantize(w, h, block=32) for w, h in zip(ws, hs)]
    bat = ldlq_quantize_batched(jnp.stack(ws), jnp.stack(hs), block=32)
    for i, s in enumerate(seq):
        np.testing.assert_allclose(np.asarray(s["w_deq"]),
                                   np.asarray(bat["w_deq"][i]), atol=2e-5)
        assert float(bat["err"][i]) == pytest.approx(float(s["err"]),
                                                     rel=1e-3)


def test_ldlq_layer_solve_uses_batched_path():
    """quantize_layer_weights routes same-shape LDLQ solves (q/k/v) and
    stacked experts through ldlq_quantize_batched, matching sequential."""
    ws, hs = _solve_set(3)
    p_block = {"mixer": {"wq": ws[0], "wk": ws[1], "wv": ws[2]}}
    hessians = {"mixer/wq": hs[0], "mixer/wk": hs[1], "mixer/wv": hs[2]}
    rsq = RSQConfig(method="ldlq", gptq_block=32)
    new_p, report = quantize_layer_weights(p_block, hessians, rsq)
    for name, w, h in zip(("wq", "wk", "wv"), ws, hs):
        ref = ldlq_quantize(w, h, damp=rsq.damp, block=32)
        np.testing.assert_allclose(np.asarray(new_p["mixer"][name]),
                                   np.asarray(ref["w_deq"]), atol=2e-5)
        assert report[f"mixer/{name}"] == pytest.approx(float(ref["err"]),
                                                        rel=1e-3)
    # stacked experts ride the same batched solver
    w3, h3 = jnp.stack(ws), jnp.stack(hs)
    new_p, report = quantize_layer_weights(
        {"ffn": {"experts": {"wi": w3}}}, {"ffn/experts/wi": h3}, rsq)
    for e in range(3):
        ref = ldlq_quantize(w3[e], h3[e], damp=rsq.damp, block=32)
        np.testing.assert_allclose(
            np.asarray(new_p["ffn"]["experts"]["wi"][e]),
            np.asarray(ref["w_deq"]), atol=2e-5)


def test_shape_grouped_layer_solve_matches_sequential():
    """quantize_layer_weights groups q/k/v-style same-shape weights into one
    batched solve; the result must match solving each weight alone."""
    ws, hs = _solve_set(3)
    p_block = {"mixer": {"wq": ws[0], "wk": ws[1], "wv": ws[2]}}
    hessians = {"mixer/wq": hs[0], "mixer/wk": hs[1], "mixer/wv": hs[2]}
    rsq = RSQConfig(bits=3, group_size=32, gptq_block=32)
    new_p, report = quantize_layer_weights(p_block, hessians, rsq)
    for name, w, h in zip(("wq", "wk", "wv"), ws, hs):
        ref = gptq_quantize(w, h, rsq.spec(), damp=rsq.damp, block=32)
        np.testing.assert_allclose(np.asarray(new_p["mixer"][name]),
                                   np.asarray(ref["w_deq"]), atol=2e-6)
        assert report[f"mixer/{name}"] == pytest.approx(float(ref["err"]),
                                                        rel=1e-3)


def test_stacked_experts_use_batched_path():
    """(E, d_in, d_out) expert stacks solve in one batched call and match
    per-expert sequential solves."""
    ws, hs = _solve_set(4, seed=7)
    w3, h3 = jnp.stack(ws), jnp.stack(hs)
    p_block = {"ffn": {"experts": {"wi": w3}}}
    hessians = {"ffn/experts/wi": h3}
    rsq = RSQConfig(bits=3, group_size=32, gptq_block=32)
    new_p, report = quantize_layer_weights(p_block, hessians, rsq)
    deq = np.asarray(new_p["ffn"]["experts"]["wi"])
    for e in range(4):
        ref = gptq_quantize(w3[e], h3[e], rsq.spec(), damp=rsq.damp, block=32)
        np.testing.assert_allclose(deq[e], np.asarray(ref["w_deq"]),
                                   atol=2e-6)


def test_single_expert_stack_solves():
    """A lone (1, d_in, d_out) expert stack must stay on the batched path
    (regression: the lone-weight fast path once fed it to the 2-D solver)."""
    ws, hs = _solve_set(1, seed=11)
    w3, h3 = jnp.stack(ws), jnp.stack(hs)
    rsq = RSQConfig(bits=3, group_size=32, gptq_block=32)
    new_p, _ = quantize_layer_weights({"ffn": {"experts": {"wi": w3}}},
                                      {"ffn/experts/wi": h3}, rsq)
    ref = gptq_quantize(w3[0], h3[0], rsq.spec(), damp=rsq.damp, block=32)
    np.testing.assert_allclose(np.asarray(new_p["ffn"]["experts"]["wi"][0]),
                               np.asarray(ref["w_deq"]), atol=2e-6)


def test_gram_kernel_flag_matches_oracle():
    """use_gram_kernel routes hess.accumulate through the Pallas gram path
    (interpret/ref off-TPU) with identical results, incl. 3-D experts."""
    x = jax.random.normal(jax.random.key(0), (128, 64))
    r = jax.random.uniform(jax.random.key(1), (128,))
    np.testing.assert_allclose(
        np.asarray(accumulate(None, x, r, use_kernel=True)),
        np.asarray(accumulate(None, x, r, use_kernel=False)), atol=1e-3)
    xe = jax.random.normal(jax.random.key(2), (4, 32, 64))
    re = jax.random.uniform(jax.random.key(3), (4, 32))
    got = accumulate(None, xe, re, use_kernel=True)
    want = accumulate(None, xe, re, use_kernel=False)
    assert got.shape == (4, 64, 64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)
