"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizer import QuantSpec, quantize_weight_rtn
from repro.core.rotation import random_orthogonal
from repro.kernels.attn_colsum.ops import attn_colsum
from repro.kernels.attn_colsum.ref import attn_colsum_ref
from repro.kernels.gram.ops import weighted_gram
from repro.kernels.gram.ref import weighted_gram_ref
from repro.kernels.hadamard.ops import fwht, hadamard_transform
from repro.kernels.hadamard.ref import fwht_ref, hadamard_matrix
from repro.kernels.quant_matmul.ops import pack_weight, quant_matmul
from repro.kernels.quant_matmul.ref import quant_matmul_ref


@pytest.mark.parametrize("shape", [(8, 64), (16, 128), (4, 512), (3, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_vs_ref(shape, dtype):
    x = jax.random.normal(jax.random.key(sum(shape)), shape).astype(dtype)
    a = fwht(x).astype(jnp.float32)
    b = fwht_ref(x).astype(jnp.float32)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=tol,
                               rtol=tol)


def test_fwht_involution_and_kron():
    x = jax.random.normal(jax.random.key(0), (6, 256))
    np.testing.assert_allclose(np.asarray(fwht(fwht(x))), np.asarray(x),
                               atol=1e-5)
    d, m = 384, 3
    x = jax.random.normal(jax.random.key(1), (5, d))
    qm = random_orthogonal(jax.random.key(2), m)
    y = hadamard_transform(x, qm)
    ref = x @ jnp.asarray(np.kron(np.asarray(hadamard_matrix(128)),
                                  np.asarray(qm)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("n,d", [(512, 128), (1024, 256), (256, 512),
                                 (100, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_vs_ref(n, d, dtype):
    x = jax.random.normal(jax.random.key(n + d), (n, d)).astype(dtype)
    r = jax.random.uniform(jax.random.key(d), (n,))
    a = weighted_gram(x, r)
    b = weighted_gram_ref(x, r)
    rel = float(jnp.abs(a - b).max() / jnp.abs(b).max())
    assert rel < (1e-5 if dtype == jnp.float32 else 3e-2)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("k,n,m,gs", [(512, 256, 16, 128), (256, 128, 8, 64),
                                      (1024, 512, 32, 128)])
def test_quant_matmul_vs_ref(bits, k, n, m, gs):
    w = jax.random.normal(jax.random.key(bits + k), (k, n)) * 0.4
    spec = QuantSpec(bits=bits, group_size=gs, sym=False)
    deq, q, s, z = quantize_weight_rtn(w, spec)
    pw = pack_weight(q, s, z, spec)
    x = jax.random.normal(jax.random.key(m), (m, k))
    a = quant_matmul(x, pw, use_kernel=True)  # interpret-mode Pallas off-TPU
    b = quant_matmul_ref(x, pw.w_packed, s, z, bits=bits, group_size=gs)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(a), np.asarray(x @ deq), atol=1e-2,
                               rtol=1e-2)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("k,d,m,gs", [(512, 256, 16, 128), (256, 128, 5, 64),
                                      (128, 512, 3, 128)])
def test_quant_matmul_t_vs_ref(bits, k, d, m, gs):
    """Latent layout (y = x @ dequant(W)ᵀ, MLA absorbed decode): kernel
    (interpret) vs ref vs the dense transposed product.  m covers decode
    shapes below the sublane tile (padded inside the wrapper)."""
    from repro.kernels.quant_matmul.ops import quant_matmul_t
    from repro.kernels.quant_matmul.ref import quant_matmul_t_ref

    w = jax.random.normal(jax.random.key(bits + k), (k, d)) * 0.4
    spec = QuantSpec(bits=bits, group_size=gs, sym=False)
    deq, q, s, z = quantize_weight_rtn(w, spec)
    pw = pack_weight(q, s, z, spec)
    x = jax.random.normal(jax.random.key(m), (m, d))
    a = quant_matmul_t(x, pw, use_kernel=True)
    b = quant_matmul_t_ref(x, pw.w_packed, s, z, bits=bits, group_size=gs,
                           d_in=k)
    assert a.shape == (m, k)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(a), np.asarray(x @ deq.T),
                               atol=1e-2, rtol=1e-2)


@pytest.mark.parametrize("m", [1, 2, 5, 7])
def test_quant_matmul_decode_shapes_stay_on_kernel(m, monkeypatch):
    """Decode-time m (batch of generating sequences, not a sublane
    multiple of 8) must pad up inside the wrapper and stay on the Pallas
    kernel — never bounce to the unfused ref path."""
    import repro.kernels.quant_matmul.ops as ops

    k, n = 256, 128
    spec = QuantSpec(bits=4, group_size=64, sym=False)
    w = jax.random.normal(jax.random.key(m), (k, n)) * 0.4
    deq, q, s, z = quantize_weight_rtn(w, spec)
    pw = pack_weight(q, s, z, spec)

    def boom(*a, **kw):
        raise AssertionError("decode shape fell back to quant_matmul_ref")

    monkeypatch.setattr(ops, "quant_matmul_ref", boom)
    x = jax.random.normal(jax.random.key(m + 100), (m, k))
    y = quant_matmul(x, pw, use_kernel=True)
    assert y.shape == (m, n)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ deq),
                               atol=1e-2, rtol=1e-2)


def test_quant_matmul_per_tensor_groups_fall_back():
    """group_size=-1 (one group spanning d_in) with d_in > 512: no k tile
    can hold a whole group, so the wrapper must serve via ref instead of
    looping its block size down to zero."""
    k, n = 1024, 128
    spec = QuantSpec(bits=4, group_size=-1, sym=True)
    w = jax.random.normal(jax.random.key(5), (k, n)) * 0.4
    deq, q, s, z = quantize_weight_rtn(w, spec)
    pw = pack_weight(q, s, z, spec)
    assert pw.group_size == k
    x = jax.random.normal(jax.random.key(6), (8, k))
    np.testing.assert_allclose(
        np.asarray(quant_matmul(x, pw, use_kernel=True)),
        np.asarray(x @ deq), atol=1e-2, rtol=1e-2)


def test_quant_matmul_3bit_falls_back():
    k, n = 256, 128
    spec = QuantSpec(bits=3, group_size=64, sym=True)
    w = jax.random.normal(jax.random.key(3), (k, n)) * 0.4
    deq, q, s, z = quantize_weight_rtn(w, spec)
    pw = pack_weight(q, s, z, spec)
    x = jax.random.normal(jax.random.key(4), (8, k))
    np.testing.assert_allclose(np.asarray(quant_matmul(x, pw, use_kernel=True)),
                               np.asarray(x @ deq), atol=1e-2, rtol=1e-2)


@pytest.mark.parametrize("b,t,h,dh,causal", [
    (2, 128, 4, 32, True), (1, 256, 2, 64, True), (2, 64, 4, 16, False),
    (1, 96, 2, 32, True)])
def test_attn_colsum_vs_ref(b, t, h, dh, causal):
    q = jax.random.normal(jax.random.key(t), (b, t, h, dh))
    k = jax.random.normal(jax.random.key(t + 1), (b, t, h, dh))
    col = attn_colsum(q, k, causal=causal)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, t, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, t, dh)
    ref = attn_colsum_ref(qf, kf, causal=causal).reshape(b, h, t).sum(1)
    rel = float(jnp.abs(col - ref).max() / jnp.abs(ref).max())
    assert rel < 1e-5
    # column mass conserves: sum_j R_j == queries x heads
    np.testing.assert_allclose(float(col.sum()), b * t * h, rtol=1e-4)


def test_attn_colsum_gqa():
    b, t, h, kvh, dh = 1, 64, 4, 2, 16
    q = jax.random.normal(jax.random.key(0), (b, t, h, dh))
    k = jax.random.normal(jax.random.key(1), (b, t, kvh, dh))
    col = attn_colsum(q, k)
    assert col.shape == (b, t)
    np.testing.assert_allclose(float(col.sum()), b * t * h, rtol=1e-4)
