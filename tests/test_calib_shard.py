"""Sharded calibration→serving data-path contracts.

Host side: per-shard calibration slices are disjoint, cover the full set,
match the global draw bit-for-bit, and resume exactly under (seed, step).

Fake 8-device mesh (subprocess, like test_distributed): a quantize run
with sharded calib + sharded write-back produces a packed serving artifact
bit-identical to the sequential host-gather baseline, with *no* host-side
materialization of an unsharded per-layer (q, scales) tensor on the
sharded path — asserted by instrumenting the module's single host-gather
routine (checkpoint.packed._host_gather).
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import (
    CalibrationLoader,
    CalibShard,
    SyntheticCorpus,
    calibration_set,
    calibration_shard,
    shard_bounds,
)

REPO = Path(__file__).resolve().parent.parent


# ------------------------------------------------------------- host-side


def test_shard_bounds_partition():
    for n, s in [(16, 8), (10, 3), (7, 7), (5, 8), (1, 1)]:
        spans = [shard_bounds(n, s, i) for i in range(s)]
        # contiguous, disjoint, covering
        assert spans[0][0] == 0 and spans[-1][1] == n
        for (a, b), (c, d) in zip(spans, spans[1:]):
            assert b == c and a <= b and c <= d


def test_shards_disjoint_cover_and_match_global():
    g = calibration_set(131, 12, 16, seed=5)
    parts = [calibration_shard(131, 12, 16, shard=s, n_shards=4, seed=5)
             for s in range(4)]
    assert sum(p.shape[0] for p in parts) == 12
    assert bool(jnp.all(jnp.concatenate(parts) == g))
    # per-shard slices equal the global rows they claim (not just the union)
    for s, p in enumerate(parts):
        lo, hi = shard_bounds(12, 4, s)
        assert bool(jnp.all(p == g[lo:hi]))
    # deterministic in (seed, shard)
    again = calibration_shard(131, 12, 16, shard=2, n_shards=4, seed=5)
    assert bool(jnp.all(again == parts[2]))


def test_calib_shard_iterator_resume_exact():
    c = SyntheticCorpus(vocab_size=101, seed=1)
    sh = CalibShard(c, 12, 8, shard=1, n_shards=2, batch_size=4, seed=1)
    batches = list(sh)
    sh2 = CalibShard(c, 12, 8, shard=1, n_shards=2, batch_size=4, seed=1)
    sh2.restore({"step": 2, "shard": 1})
    assert bool(jnp.all(next(sh2) == batches[2]))
    # the shard iterator yields exactly its slice of each global batch
    g = calibration_set(101, 12, 8, seed=1, corpus=c)
    lo, hi = shard_bounds(12, 2, 1)
    got = jnp.concatenate([b for b in batches if b.shape[0]])
    assert bool(jnp.all(got == g[lo:hi]))


def test_calibration_loader_local_degenerates_to_global():
    c = SyntheticCorpus(vocab_size=101, seed=2)
    ld = CalibrationLoader(c, 10, 8, batch_size=4, seed=2)
    g = calibration_set(101, 10, 8, seed=2, corpus=c)
    assert bool(jnp.all(ld.dataset() == g))
    batches = list(ld)
    assert bool(jnp.all(jnp.concatenate(batches) == g))
    ld.restore({"step": 1})
    assert bool(jnp.all(next(ld) == batches[1]))


# ------------------------------------------------------- fake 8-device mesh


def _run(code: str) -> dict:
    import os
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_calib_dataset_on_mesh():
    """Each device generates exactly its disjoint slice; the assembled
    array equals the host global draw bit-for-bit."""
    out = _run("""
    import json, jax, jax.numpy as jnp
    from repro.data import SyntheticCorpus, CalibrationLoader, calibration_set
    from repro.runtime.sharding import ParallelCtx

    mesh = jax.make_mesh((8,), ("data",))
    ctx = ParallelCtx(mesh=mesh, dp=("data",))
    c = SyntheticCorpus(vocab_size=211, seed=4)
    ld = CalibrationLoader(c, 16, 8, ctx=ctx, batch_size=8, seed=4)
    ds = ld.dataset()
    g = calibration_set(211, 16, 8, seed=4, corpus=c)
    b0 = next(ld)
    print(json.dumps({
        "spec": str(ds.sharding.spec),
        "shard_shapes": sorted({tuple(s.data.shape)
                                for s in ds.addressable_shards}),
        "equal": bool(jnp.all(ds == g)),
        "batch_equal": bool(jnp.all(b0 == g[:8])),
        "batch_spec": str(b0.sharding.spec),
    }))
    """)
    assert out["equal"] and out["batch_equal"]
    assert "data" in out["spec"] and "data" in out["batch_spec"]
    assert out["shard_shapes"] == [[2, 8]]  # 16 rows / 8 devices


def test_sharded_writeback_bit_identical_to_host_gather():
    """The acceptance contract of the sharded data path: on a (2 data x 4
    model) mesh, sharded calib + streaming Hessians + ring reduce + sharded
    write-back produce a packed serving artifact bit-identical to the
    sequential host-gather baseline; the sharded run never calls the host
    gather, its artifact stays model-axis sharded on device, and the
    reconstructed serving params equal the quantized tree exactly."""
    out = _run("""
    import dataclasses, json, tempfile
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.core import RSQConfig, RSQPipeline
    from repro.data import SyntheticCorpus, CalibrationLoader, calibration_set
    from repro.models import build_model
    from repro.runtime.sharding import ParallelCtx
    from repro.checkpoint import packed as cp

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh, dp=("data",), tp="model")
    cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                              dtype="float32", n_layers=2, d_model=64,
                              vocab_size=256)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    corpus = SyntheticCorpus(vocab_size=256, seed=0)
    N, T, B = 16, 16, 8

    loader = CalibrationLoader(corpus, N, T, ctx=ctx, batch_size=B, seed=0)
    calib_sharded = loader.dataset()
    calib_host = calibration_set(256, N, T, seed=0, corpus=corpus)
    tokens_equal = bool(jnp.all(calib_sharded == calib_host))

    # instrument THE host-gather: the sharded path must never call it
    gathers = []
    orig_gather = cp._host_gather
    cp._host_gather = lambda x: (gathers.append(tuple(np.shape(x))),
                                 orig_gather(x))[1]

    rsq_a = RSQConfig(bits=4, rotate=False, importance="attn_con",
                      scheduler="overlapped", shard_hessians=True,
                      pack_output=True, pack_writeback="sharded")
    pipe_a = RSQPipeline(model, rsq_a, ctx=ctx)
    qa, _ = pipe_a.run(params, calib_sharded, batch_size=B)
    jax.block_until_ready(jax.tree.leaves(qa))
    sharded_gathers = list(gathers)

    n_sharded = 0
    for e in pipe_a.artifact["entries"].values():
        idx = {tuple(s.indices(d)[:2]
                     for s, d in zip(sh.index, e["codes"].shape))
               for sh in e["codes"].addressable_shards}
        n_sharded += len(idx) > 1
    da, db = tempfile.mkdtemp(), tempfile.mkdtemp()
    cp.save_packed_artifact(da, pipe_a.artifact, params=qa)
    post_save_gathers = list(gathers)

    rsq_b = dataclasses.replace(rsq_a, scheduler="sequential",
                                pack_writeback="host")
    pipe_b = RSQPipeline(model, rsq_b, ctx=ctx)
    calib_b = jax.device_put(calib_host,
                             NamedSharding(mesh, P("data", None)))
    qb, _ = pipe_b.run(params, calib_b, batch_size=B)
    cp.save_packed_artifact(db, pipe_b.artifact, params=qb)
    cp._host_gather = orig_gather

    ea, ma = cp.load_packed_artifact(da)
    eb, mb = cp.load_packed_artifact(db)
    bit_identical = (sorted(ea) == sorted(eb)) and all(
        np.array_equal(ea[n][f], eb[n][f])
        for n in ea for f in ("codes", "scale", "zero"))
    params_equal = all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(qa), jax.tree.leaves(qb)))

    recon, _ = cp.load_packed_params(da)
    recon_equal = all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(qa), jax.tree.leaves(recon)))

    print(json.dumps({
        "tokens_equal": tokens_equal,
        "sharded_run_gathers": sharded_gathers,
        "post_save_gathers": post_save_gathers,
        "n_entries": len(ea),
        "n_model_sharded_codes": n_sharded,
        "bit_identical": bit_identical,
        "params_equal": params_equal,
        "recon_equal": recon_equal,
        "baseline_gathered": len(gathers) > len(post_save_gathers),
    }))
    """)
    # the sharded loader reproduces the global token set exactly
    assert out["tokens_equal"]
    # no unsharded (q, scales, zeros) ever crossed to host on the sharded
    # path — neither during the run nor during the per-shard artifact save
    assert out["sharded_run_gathers"] == []
    assert out["post_save_gathers"] == []
    # ... while the host-gather baseline did gather (the path it retires)
    assert out["baseline_gathered"]
    # write-back really lands model-axis sharded on device
    assert out["n_model_sharded_codes"] > 0
    # and the two artifacts are bit-identical, as are the quantized params
    # and the serving-side reconstruction
    assert out["n_entries"] > 0
    assert out["bit_identical"]
    assert out["params_equal"]
    assert out["recon_equal"]
