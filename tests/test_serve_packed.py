"""Packed-in-HBM serving forward: parity + zero-dequant contracts.

The keep-packed serving path (``checkpoint.packed.load_packed_forward_params``
-> ``PackedWeight`` pytree nodes -> ``models.layers.linear`` ->
``quant_matmul``) must produce exactly the greedy tokens of the legacy
dequantize-at-load path, while never creating an fp array of any quantized
weight's full shape: the guard instruments ``quantizer.dequantize_packed``
and ``checkpoint.packed.dequantize_entry`` and pins both to zero calls
during ``generate`` — since PR 5 with *no* exceptions: MLA's absorbed
decode contracts packed codes through the latent-layout kernels instead
of transiently dequantizing ``wkv_b`` per step.  Runs on the single local
device here and on the fake 8-device (2 data x 4 model) mesh in
subprocesses (like test_distributed): one mesh test pins the GSPMD-ref
serving semantics (sharded codes, sharded residual write-back), a second
pins the shard_map'd Pallas route — with the kernel forced, mesh-sharded
serving must take the per-shard fused kernel with *zero* ref-GEMM
fallbacks and bit-identical outputs vs the GSPMD ref.

This test also *replaces* ``launch.serve._kernel_check`` (one projection
driven through the kernel): every 2-D artifact entry is cross-checked
against its dequantized matmul, and the full forward covers the rest.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import packed as cp
from repro.core import RSQConfig, RSQPipeline
from repro.data.synthetic import SyntheticCorpus
from repro.kernels.quant_matmul.ops import (PackedWeight,
                                            packed_weight_from_artifact,
                                            quant_matmul)
from repro.launch.serve import generate, resident_weight_bytes

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def artifact_dir(tmp_path_factory, tiny_model_params):
    model, params = tiny_model_params
    corpus = SyntheticCorpus(vocab_size=model.cfg.vocab_size, seed=0)
    calib = corpus.sample(jax.random.key(1), 8, 32)
    rsq = RSQConfig(bits=4, rotate=False, importance="attn_con",
                    pack_output=True)
    pipe = RSQPipeline(model, rsq)
    qparams, _ = pipe.run(params, calib, batch_size=4)
    d = tmp_path_factory.mktemp("packed_artifact")
    cp.save_packed_artifact(d, pipe.artifact, params=qparams,
                            extra={"arch": model.cfg.name})
    return d


class _Guard:
    """Counts every fp materialization of a packed weight — and, since
    PR 7, of the quantized KV cache (kv_dequantize / kv_log_decode are
    debug-only materializers; serving attends on codes directly through
    kernels.flash_decode)."""

    def __init__(self, monkeypatch):
        self.calls: list[str] = []
        import repro.core.quantizer as qz
        import repro.models.attention as att

        def wrap(tag, fn):
            return lambda *a, **k: (self.calls.append(tag), fn(*a, **k))[1]

        deq = wrap("dequantize_packed", qz.dequantize_packed)
        monkeypatch.setattr(qz, "dequantize_packed", deq)
        # every module-level import of the symbol
        monkeypatch.setattr(cp, "dequantize_packed", deq)
        monkeypatch.setattr(att, "dequantize_packed", deq)
        monkeypatch.setattr(cp, "dequantize_entry",
                            wrap("dequantize_entry", cp.dequantize_entry))
        monkeypatch.setattr(att, "kv_dequantize",
                            wrap("kv_dequantize", att.kv_dequantize))
        monkeypatch.setattr(att, "kv_log_decode",
                            wrap("kv_log_decode", att.kv_log_decode))


@pytest.mark.parametrize("arch", ["deepseek-v2-236b", "jamba-v0.1-52b"])
def test_packed_forward_parity_other_families(arch, tmp_path, monkeypatch):
    """Pin the non-GQA dispatch branches: deepseek-v2 smoke exercises the
    expert-stack vmapped quant_matmul (3-D PackedWeight) *and* MLA's
    absorbed decode — since PR 5 that path contracts the packed codes
    through the latent-layout ``quant_matmul_t``/``quant_matmul``
    (``mla_latent_weights`` per-head views), so the zero-dequant guard
    now covers MLA too: not a single ``dequantize_packed`` anywhere in
    the decode trace (``_materialize`` used to be the one documented
    exception); jamba smoke exercises the mamba projections."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import build_model

    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)
    calib = corpus.sample(jax.random.key(1), 8, 32)
    pipe = RSQPipeline(model, RSQConfig(bits=4, rotate=False,
                                        importance="attn_con",
                                        pack_output=True))
    qparams, _ = pipe.run(params, calib, batch_size=4)
    d = tmp_path / "artifact"
    cp.save_packed_artifact(d, pipe.artifact, params=qparams)

    deq_params, _ = cp.load_packed_params(d)
    pk_params, _ = cp.load_packed_forward_params(d)
    if arch.startswith("deepseek"):
        assert any(isinstance(w, PackedWeight) and w.w_packed.ndim >= 3
                   for w in jax.tree.leaves(
                       pk_params,
                       is_leaf=lambda x: isinstance(x, PackedWeight)))
    prompts = corpus.sample(jax.random.key(2), 2, 16)
    ref_tokens = generate(model, deq_params, prompts, 6)
    guard = _Guard(monkeypatch)
    pk_tokens = generate(model, pk_params, prompts, 6)
    assert guard.calls == [], guard.calls
    assert bool(jnp.all(ref_tokens == pk_tokens))


def test_packed_forward_parity_and_zero_dequant(artifact_dir,
                                                tiny_model_params,
                                                monkeypatch):
    model, _ = tiny_model_params
    deq_params, meta = cp.load_packed_params(artifact_dir)
    pk_params, _ = cp.load_packed_forward_params(artifact_dir)

    # every artifact entry became a PackedWeight node; nothing of a
    # quantized weight's fp footprint is resident in the tree
    pw_leaves = [x for x in jax.tree.leaves(
        pk_params, is_leaf=lambda x: isinstance(x, PackedWeight))
        if isinstance(x, PackedWeight)]
    assert pw_leaves and all(
        w.w_packed.dtype == jnp.uint32 for w in pw_leaves)
    packed_b, _ = resident_weight_bytes(pk_params)
    fp_equiv = sum(w.d_in * w.w_packed.shape[-1] *
                   int(np.prod(w.w_packed.shape[:-2], initial=1)) * 4
                   for w in pw_leaves)
    # codes ~= bits/32 of fp32 (+ group params); 4-bit -> well under half
    assert packed_b < 0.5 * fp_equiv

    corpus = SyntheticCorpus(vocab_size=model.cfg.vocab_size, seed=0)
    prompts = corpus.sample(jax.random.key(2), 2, 16)
    ref_tokens = generate(model, deq_params, prompts, 8)

    guard = _Guard(monkeypatch)
    pk_tokens = generate(model, pk_params, prompts, 8)
    assert guard.calls == [], guard.calls
    assert bool(jnp.all(ref_tokens == pk_tokens))


def test_artifact_entries_drive_quant_matmul(artifact_dir):
    """The folded-in kernel check (ex launch.serve._kernel_check): every
    dense 2-D entry's packed codes feed quant_matmul directly and match
    the on-device dequantized matmul — at a decode-ish m=5 so that any
    kernel-eligible entry also exercises the sublane padding
    (``use_kernel=True`` opts into interpret-mode Pallas on CPU for
    aligned shapes; the smoke artifact's d=64 entries take the ref).
    Entries load one at a time through ``load_packed_entry`` (the
    spot-check API _kernel_check used)."""
    meta = json.loads((Path(artifact_dir) / "meta.json").read_text())
    checked = 0
    for name, em in meta["entries"].items():
        if len(em["fields"]["codes"]["shape"]) != 2:
            continue
        entry = cp.load_packed_entry(artifact_dir, name)
        pw = packed_weight_from_artifact(entry, em, meta["spec"])
        x = jax.random.normal(jax.random.key(checked), (5, pw.d_in),
                              jnp.float32)
        y = quant_matmul(x, pw, use_kernel=True)
        ref = x @ cp.dequantize_entry(entry, em, meta["spec"])
        err = float(jnp.max(jnp.abs(y - ref)) /
                    (jnp.max(jnp.abs(ref)) + 1e-9))
        assert err < 1e-5, (name, err)
        checked += 1
    assert checked > 0


def test_v1_artifact_still_loads(artifact_dir, tmp_path):
    """Pre-PR-4 artifacts (rsq-packed-v1: whole-leaf residual, no shard
    index) must keep loading — their packed-entries section is
    byte-identical to v2."""
    import shutil
    d = tmp_path / "v1"
    shutil.copytree(artifact_dir, d)
    meta = json.loads((d / "meta.json").read_text())
    with np.load(d / "residual.npz") as z:
        whole = {f"leaf_{i}": cp._assemble_field(z, f"leaf_{i}", fm)
                 for i, fm in enumerate(meta["residual_leaves"])}
    np.savez(d / "residual.npz", **whole)
    del meta["residual_leaves"]
    meta["format"] = "rsq-packed-v1"
    (d / "meta.json").write_text(json.dumps(meta))

    v2_params, _ = cp.load_packed_params(artifact_dir)
    v1_params, _ = cp.load_packed_params(d)
    assert all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(v2_params),
                               jax.tree.leaves(v1_params)))


def test_residual_written_per_shard(artifact_dir):
    meta = json.loads((Path(artifact_dir) / "meta.json").read_text())
    assert meta["format"] == cp.FORMAT
    assert meta["residual_leaves"], "residual shard index missing"
    with np.load(Path(artifact_dir) / "residual.npz") as z:
        assert all("@" in k for k in z.files)
    for fm in meta["residual_leaves"]:
        assert fm["shards"], fm


# ------------------------------------------------------- fake 8-device mesh


def _run(code: str) -> dict:
    import os
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_packed_forward_parity_on_mesh():
    """Keep-packed serving on a (2 data x 4 model) mesh: codes load
    d_out-sharded onto the model axis, the jitted prefill+decode runs
    through the packed pytree under GSPMD, greedy tokens match the local
    dequantized forward, zero dequant calls, and the artifact save never
    gathers a full residual leaf on the controller."""
    out = _run("""
    import dataclasses, json, tempfile
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.core import RSQConfig, RSQPipeline
    from repro.data.synthetic import SyntheticCorpus
    from repro.models import build_model
    from repro.runtime.sharding import ParallelCtx
    from repro.checkpoint import packed as cp
    from repro.launch.serve import generate

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh, dp=("data",), tp="model")
    cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                              dtype="float32", n_layers=2, d_model=64,
                              vocab_size=256)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    corpus = SyntheticCorpus(vocab_size=256, seed=0)
    calib = corpus.sample(jax.random.key(1), 8, 32)
    rsq = RSQConfig(bits=4, rotate=False, importance="attn_con",
                    pack_output=True, pack_writeback="sharded")
    pipe = RSQPipeline(model, rsq, ctx=ctx)
    qa, _ = pipe.run(params, calib, batch_size=4)

    # shard one residual leaf so the per-shard residual writer is exercised
    qa["embed"] = jax.device_put(qa["embed"],
                                 NamedSharding(mesh, P("model", None)))
    gathers = []
    orig = cp._host_gather
    cp._host_gather = lambda x: (gathers.append(tuple(np.shape(x))),
                                 orig(x))[1]
    d = tempfile.mkdtemp()
    cp.save_packed_artifact(d, pipe.artifact, params=qa)
    cp._host_gather = orig
    meta = json.loads((__import__("pathlib").Path(d) / "meta.json"
                       ).read_text())
    residual_max_shards = max(len(fm["shards"])
                              for fm in meta["residual_leaves"])

    deq_params, _ = cp.load_packed_params(d)
    ref_tokens = generate(model, deq_params, prompts := corpus.sample(
        jax.random.key(2), 2, 16), 8)

    import repro.core.quantizer as qz
    import repro.models.attention as att
    calls = []
    wrap = lambda f: (lambda *a, **k: (calls.append(1), f(*a, **k))[1])
    qz.dequantize_packed = wrap(qz.dequantize_packed)
    cp.dequantize_packed = qz.dequantize_packed
    att.dequantize_packed = qz.dequantize_packed
    cp.dequantize_entry = wrap(cp.dequantize_entry)

    model_m = build_model(cfg, ctx)
    pk_params, _ = cp.load_packed_forward_params(d, ctx=ctx)
    from repro.kernels.quant_matmul.ops import PackedWeight
    n_model_sharded = 0
    flags = []
    for w in jax.tree.leaves(pk_params,
                             is_leaf=lambda x: isinstance(x, PackedWeight)):
        if isinstance(w, PackedWeight):
            idx = {tuple(s.indices(dd)[:2]
                         for s, dd in zip(sh.index, w.w_packed.shape))
                   for sh in w.w_packed.addressable_shards}
            n_model_sharded += len(idx) > 1
            flags.append(w.mesh_sharded)
    pk_tokens = generate(model_m, pk_params, prompts, 8)

    print(json.dumps({
        "save_gathers": gathers,
        "residual_max_shards": residual_max_shards,
        "n_model_sharded_codes": n_model_sharded,
        "mesh_sharded_flags_set": all(flags) and len(flags) > 0,
        "dequant_calls": len(calls),
        "tokens_equal": bool(jnp.all(ref_tokens == pk_tokens)),
    }))
    """)
    assert out["save_gathers"] == []
    assert out["residual_max_shards"] > 1
    assert out["n_model_sharded_codes"] > 0
    # partitioned codes are marked so quant_matmul keeps them off the
    # opaque Pallas call (GSPMD would all-gather it) even on TPU
    assert out["mesh_sharded_flags_set"]
    assert out["dequant_calls"] == 0
    assert out["tokens_equal"]


def test_shard_map_kernel_route_on_mesh():
    """Mesh-sharded packed serving on the shard_map'd Pallas kernel.

    Two layers of pinning on the fake (2 data x 4 model) mesh, kernel
    forced via REPRO_QMM_KERNEL=1 (interpret-mode Pallas on CPU — the
    correctness tool; on TPU the same route runs compiled):

      * unit: a synthetic d_out-sharded PackedWeight through the
        shard_map kernel route is BIT-identical to the GSPMD ref GEMM,
        stays d_out-sharded on the model axis, and triggers zero ref
        calls.
      * serving: a kernel-aligned smoke model (every quantized d_out
        splits into 128-aligned local tiles over the 4-way model axis)
        generates keep-packed with zero ref-GEMM fallbacks — mesh-sharded
        codes no longer demote to the ref when the kernel policy allows —
        and greedy tokens equal to the local dequantized forward.
    """
    out = _run("""
    import dataclasses, functools, json, os, tempfile
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    os.environ["REPRO_QMM_KERNEL"] = "1"

    from repro.configs import get_config
    from repro.core import RSQConfig, RSQPipeline
    from repro.core.quantizer import QuantSpec, quantize_weight_rtn, pack_codes
    from repro.data.synthetic import SyntheticCorpus
    from repro.kernels.quant_matmul import ops
    from repro.models import build_model
    from repro.runtime.sharding import ParallelCtx
    from repro.checkpoint import packed as cp
    from repro.launch.serve import generate

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh, dp=("data",), tp="model")

    # ---- unit: synthetic sharded weight, kernel route vs GSPMD ref
    k, n = 256, 1024
    w = jax.random.normal(jax.random.key(0), (k, n), jnp.float32)
    deq, q, scale, zero = quantize_weight_rtn(
        w, QuantSpec(bits=4, group_size=128, sym=False))
    sh = NamedSharding(mesh, P(None, "model"))
    pw = ops.PackedWeight(
        jax.device_put(pack_codes(q, 4), sh), jax.device_put(scale, sh),
        jax.device_put(zero, sh), 4, 128, k,
        mesh_sharded=True, mesh=mesh, mesh_axis="model")
    x = jax.random.normal(jax.random.key(1), (5, k), jnp.float32)
    y_ref = ops.quant_matmul(x, pw, use_kernel=False)
    ref_calls, pallas_calls = [], []
    orig_ref, orig_pal = ops.quant_matmul_ref, ops.quant_matmul_pallas
    ops.quant_matmul_ref = lambda *a, **kw: (ref_calls.append(1),
                                             orig_ref(*a, **kw))[1]
    ops.quant_matmul_pallas = lambda *a, **kw: (pallas_calls.append(1),
                                                orig_pal(*a, **kw))[1]
    y_kernel = ops.quant_matmul(x, pw)  # policy: forced kernel + shard_map
    unit = {
        "unit_ref_calls": len(ref_calls),
        "unit_pallas_called": len(pallas_calls) > 0,
        "unit_bit_identical": bool(jnp.all(y_kernel == y_ref)),
        "unit_out_model_sharded": "model" in str(y_kernel.sharding.spec),
    }

    # ---- serving: kernel-aligned smoke model end to end
    cfg = dataclasses.replace(
        get_config("llama3-8b").reduced(), dtype="float32", n_layers=2,
        d_model=512, n_heads=8, n_kv_heads=8, d_head=0, d_ff=512,
        vocab_size=256)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    corpus = SyntheticCorpus(vocab_size=256, seed=0)
    calib = corpus.sample(jax.random.key(1), 8, 32)
    pipe = RSQPipeline(model, RSQConfig(bits=4, rotate=False,
                                        importance="attn_con",
                                        pack_output=True,
                                        pack_writeback="sharded"), ctx=ctx)
    qa, _ = pipe.run(params, calib, batch_size=4)
    d = tempfile.mkdtemp()
    cp.save_packed_artifact(d, pipe.artifact, params=qa)
    deq_params, _ = cp.load_packed_params(d)
    prompts = corpus.sample(jax.random.key(2), 2, 16)
    ref_tokens = generate(model, deq_params, prompts, 8)

    model_m = build_model(cfg, ctx)
    pk_params, _ = cp.load_packed_forward_params(d, ctx=ctx)
    ref_calls.clear(); pallas_calls.clear()
    pk_tokens = generate(model_m, pk_params, prompts, 8)
    ops.quant_matmul_ref, ops.quant_matmul_pallas = orig_ref, orig_pal

    print(json.dumps({**unit,
        "serve_ref_fallbacks": len(ref_calls),
        "serve_pallas_traces": len(pallas_calls),
        "tokens_equal": bool(jnp.all(ref_tokens == pk_tokens)),
    }))
    """)
    assert out["unit_ref_calls"] == 0
    assert out["unit_pallas_called"]
    assert out["unit_bit_identical"]
    assert out["unit_out_model_sharded"]
    # the whole serving forward rides the shard_map'd kernel: zero ref
    # GEMMs traced during keep-packed generate
    assert out["serve_ref_fallbacks"] == 0
    assert out["serve_pallas_traces"] > 0
    assert out["tokens_equal"]
