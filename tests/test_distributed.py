"""Distributed behaviour on 8 fake host devices (subprocess-isolated so the
rest of the suite keeps a single device).

Every test here builds its mesh with explicit ``axis_types`` /
``jax.set_mesh`` — API that landed after this container's jax (0.4.37).
The module probes for it and skips cleanly when absent instead of
failing, so the suite stays green both locally (old jax, tests skip) and
in CI (new jax, tests run) — ROADMAP open item 6."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest

HAS_MESH_API = (hasattr(jax.sharding, "AxisType")
                and hasattr(jax, "set_mesh"))
pytestmark = pytest.mark.skipif(
    not HAS_MESH_API,
    reason="jax predates jax.sharding.AxisType / jax.set_mesh "
           f"(found {jax.__version__}); mesh tests run on CI's jax")

REPO = Path(__file__).resolve().parent.parent


def _run(code: str) -> dict:
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


PREAMBLE = """
import json
import jax, jax.numpy as jnp, dataclasses
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs import get_config
from repro.models import build_model
from repro.runtime.sharding import ParallelCtx, param_shardings
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
ctx = ParallelCtx(mesh=mesh, dp=("data",), tp="model", ep=True)
"""


@pytest.mark.slow
def test_sharded_loss_matches_local():
    out = _run(PREAMBLE + """
cfg = dataclasses.replace(get_config("qwen1.5-4b").reduced(),
                          dtype="float32", n_layers=2)
m = build_model(cfg, ctx)
sh = param_shardings(m.param_shapes(), ctx)
with jax.set_mesh(mesh):
    params = jax.jit(m.init, out_shardings=sh)(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
    batch = jax.device_put({"tokens": toks, "labels": toks},
                           NamedSharding(mesh, P("data", None)))
    loss = float(jax.jit(m.loss)(params, batch))
m_local = build_model(cfg)
p_local = jax.tree.map(jnp.asarray, jax.device_get(params))
loss_local = float(m_local.loss(p_local, jax.device_get(batch)))
print(json.dumps({"diff": abs(loss - loss_local), "loss": loss}))
""")
    assert out["diff"] < 1e-4


@pytest.mark.slow
def test_moe_ep_grads_flow():
    out = _run(PREAMBLE + """
cfg = dataclasses.replace(get_config("deepseek-v2-236b").reduced(),
                          dtype="float32", n_routed_experts=8, d_model=64)
m = build_model(cfg, ctx)
sh = param_shardings(m.param_shapes(), ctx)
with jax.set_mesh(mesh):
    params = jax.jit(m.init, out_shardings=sh)(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
    batch = jax.device_put({"tokens": toks, "labels": toks},
                           NamedSharding(mesh, P("data", None)))
    loss, grads = jax.jit(jax.value_and_grad(m.loss))(params, batch)
    g = jax.tree.leaves(grads)
    gn = float(sum(jnp.sum(jnp.abs(x)) for x in g))
print(json.dumps({"loss": float(loss), "grad_norm": gn}))
""")
    assert out["grad_norm"] > 0


@pytest.mark.slow
def test_elastic_restart_different_mesh(tmp_path):
    """Save on a (2,4) mesh, restore & step on (4,2) — elastic scaling."""
    out = _run(PREAMBLE + f"""
from repro.checkpoint import CheckpointManager
cfg = dataclasses.replace(get_config("qwen1.5-4b").reduced(),
                          dtype="float32", n_layers=2)
m = build_model(cfg, ctx)
sh = param_shardings(m.param_shapes(), ctx)
with jax.set_mesh(mesh):
    params = jax.jit(m.init, out_shardings=sh)(jax.random.key(0))
cm = CheckpointManager(r"{tmp_path}")
cm.save(1, {{"params": params}}, blocking=True)

mesh2 = jax.make_mesh((4, 2), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2)
ctx2 = ParallelCtx(mesh=mesh2, dp=("data",), tp="model", ep=True)
m2 = build_model(cfg, ctx2)
sh2 = param_shardings(m2.param_shapes(), ctx2)
_, state, _ = cm.restore(shardings={{"params": sh2}})
with jax.set_mesh(mesh2):
    toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
    batch = jax.device_put({{"tokens": toks, "labels": toks}},
                           NamedSharding(mesh2, P("data", None)))
    loss = float(jax.jit(m2.loss)(state["params"], batch))
print(json.dumps({{"loss": loss}}))
""")
    assert out["loss"] > 0


@pytest.mark.slow
def test_overlap_collectives_and_pp():
    out = _run("""
import json, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.runtime.collectives import (allgather_matmul,
                                       matmul_reducescatter,
                                       ring_allreduce_int8)
from repro.runtime.pipeline_parallel import pipeline_apply
mesh = jax.make_mesh((8,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
x = jax.random.normal(jax.random.key(0), (64, 32))
w = jax.random.normal(jax.random.key(1), (32, 48))
f = jax.jit(jax.shard_map(lambda a, b: allgather_matmul(a, b, "x"),
    mesh=mesh, in_specs=(P("x", None), P(None, "x")), out_specs=P(None, "x")))
e1 = float(jnp.abs(f(x, w) - x @ w).max())
g = jax.jit(jax.shard_map(lambda a, b: matmul_reducescatter(a, b, "x"),
    mesh=mesh, in_specs=(P(None, "x"), P("x", None)), out_specs=P("x", None)))
e2 = float(jnp.abs(g(x, w) - x @ w).max())
v = jax.random.normal(jax.random.key(2), (8, 64, 16))
h = jax.jit(jax.shard_map(lambda vs: ring_allreduce_int8(vs[0], "x"),
    mesh=mesh, in_specs=(P("x", None, None),), out_specs=P(None, None),
    check_vma=False))
ref = v.sum(0)
e3 = float(jnp.abs(h(v) - ref).max() / jnp.abs(ref).max())
S, M = 8, 4
ws = jax.random.normal(jax.random.key(3), (S, 16, 16)) * 0.3
mb = jax.random.normal(jax.random.key(4), (M, 4, 16))
stage = lambda w_, x_: jnp.tanh(x_ @ w_)
pf = jax.jit(jax.shard_map(lambda w_, x_: pipeline_apply(stage, w_[0], x_, "x"),
    mesh=mesh, in_specs=(P("x", None, None), P(None, None, None)),
    out_specs=P(None, None, None), check_vma=False))
out = pf(ws, mb); refp = mb
for s in range(S): refp = jnp.tanh(refp @ ws[s])
e4 = float(jnp.abs(out - refp).max())
print(json.dumps({"ag_mm": e1, "mm_rs": e2, "ar_int8": e3, "pp": e4}))
""")
    assert out["ag_mm"] < 1e-5 and out["mm_rs"] < 1e-4
    assert out["ar_int8"] < 0.05 and out["pp"] < 1e-5


@pytest.mark.slow
def test_dryrun_minicell():
    """The dry-run machinery on a small mesh: lower+compile+analyze."""
    out = _run("""
import json, jax, dataclasses, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.runtime.sharding import ParallelCtx
from repro.launch.steps import make_train_step, sharded_args_train
from repro.launch.specs import batch_inputs
from repro.optim import make_optimizer
from repro.runtime.hlo_analysis import analyze_hlo
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
ctx = ParallelCtx(mesh=mesh, dp=("data",), tp="model", ep=True)
cfg = dataclasses.replace(get_config("qwen1.5-4b").reduced(), n_layers=2)
model = build_model(cfg, ctx)
opt = make_optimizer("adamw", 1e-3)
shape = ShapeConfig("t", "train", 64, 4)
batch = batch_inputs(cfg, shape, ctx)
args = sharded_args_train(model, opt, batch, ctx)
with jax.set_mesh(mesh):
    compiled = jax.jit(make_train_step(model, opt),
                       donate_argnums=(0, 1)).lower(*args).compile()
ma = compiled.memory_analysis()
hlo = analyze_hlo(compiled.as_text())
print(json.dumps({"temp": ma.temp_size_in_bytes,
                  "flops": hlo["dot_flops_per_device"],
                  "coll": hlo["collective_link_bytes_per_device"]}))
""")
    assert out["flops"] > 0 and out["coll"] > 0
