"""Fault tolerance: failure-injected training resumes exactly, retry
policy semantics, fault-plan parsing, structured runner events."""
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.data.loader import DataLoader
from repro.data.synthetic import SyntheticCorpus
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import make_optimizer
from repro.runtime.fault import (FaultPlan, InjectedFailure, RetryPolicy,
                                 StepRunner)


def _run(tmp_path, tiny_cfg, fail_at, tag, **kw):
    model = build_model(tiny_cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    opt = make_optimizer("adamw", 1e-3)
    opt_state = jax.jit(opt.init)(params)
    loader = DataLoader(SyntheticCorpus(tiny_cfg.vocab_size, seed=0), 4, 32)
    ckpt = CheckpointManager(tmp_path / tag, keep=2)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
    runner = StepRunner(step_fn, ckpt, save_every=5, **kw)
    return runner.run(params, opt_state, loader, 16, fail_at=fail_at,
                      log_every=1000)


def test_failure_injection_resumes_exactly(tmp_path, tiny_cfg):
    clean = _run(tmp_path, tiny_cfg, None, "clean")
    faulty = _run(tmp_path, tiny_cfg, {12: 1}, "faulty")
    assert faulty["restarts"] == 1
    # the final params must match the never-failed run exactly
    for a, b in zip(jax.tree.leaves(clean["params"]),
                    jax.tree.leaves(faulty["params"])):
        assert bool(jnp.all(a == b))
    # the restart surfaced as a structured event, not just a counter
    restarts = [e for e in faulty["events"] if e["kind"] == "restart"]
    assert len(restarts) == 1
    assert restarts[0]["step"] == 12 and restarts[0]["attempt"] == 1


def test_non_recoverable_exception_propagates(tmp_path, tiny_cfg):
    """An exception type outside the configured recoverable tuple is never
    retried, even with restarts left."""
    with pytest.raises(InjectedFailure):
        _run(tmp_path, tiny_cfg, {3: 1}, "strict",
             recoverable=(ValueError,), max_restarts=5)


def test_backoff_between_restarts(tmp_path, tiny_cfg):
    """backoff_s paces restarts exponentially and lands in the event."""
    import time

    t0 = time.time()
    out = _run(tmp_path, tiny_cfg, {3: 2}, "backoff", backoff_s=0.1)
    assert out["restarts"] == 2
    # restart 1 sleeps 0.1s, restart 2 sleeps 0.2s
    assert time.time() - t0 >= 0.3
    backs = [e["backoff_s"] for e in out["events"]
             if e["kind"] == "restart"]
    assert backs == [pytest.approx(0.1), pytest.approx(0.2)]


def test_straggler_watchdog_emits_structured_event(tmp_path, tiny_cfg):
    """factor 0 flags every post-warmup step: the watchdog's event carries
    the step and timing payload (a metrics hook on a real pod)."""
    out = _run(tmp_path, tiny_cfg, None, "straggler", straggler_factor=0.0)
    stragglers = [e for e in out["events"] if e["kind"] == "straggler"]
    assert stragglers
    for e in stragglers:
        assert {"step", "seconds", "median_s", "factor"} <= set(e)


def test_retry_policy_backoff_curve():
    p = RetryPolicy(backoff_s=0.5, backoff_factor=2.0, max_backoff_s=3.0)
    assert [p.backoff(n) for n in (1, 2, 3, 4, 5)] == [0.5, 1.0, 2.0, 3.0, 3.0]
    assert RetryPolicy(backoff_s=0.0).backoff(4) == 0.0
    assert p.is_recoverable(InjectedFailure("x"))
    assert not p.is_recoverable(ValueError("x"))


def test_fault_plan_parse_and_check():
    plan = FaultPlan.parse(["3:solve", "0:capture:2"])
    assert plan.fail_at == {(3, "solve"): 1, (0, "capture"): 2}
    for _ in range(2):
        with pytest.raises(InjectedFailure):
            plan.check(0, "capture", batch=0)
    plan.check(0, "capture", batch=0)  # count exhausted: no longer armed
    assert [f["layer"] for f in plan.fired] == [0, 0]
    # batch-specific keys outrank the layer-wide key
    plan2 = FaultPlan({(1, "apply", 2): 1})
    plan2.check(1, "apply", batch=0)
    with pytest.raises(InjectedFailure):
        plan2.check(1, "apply", batch=2)
    with pytest.raises(ValueError, match="unknown stage"):
        FaultPlan({(0, "bogus"): 1})
    with pytest.raises(ValueError, match="LAYER:STAGE"):
        FaultPlan.parse(["nope"])
