"""Fault tolerance: failure-injected training resumes exactly."""
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data.loader import DataLoader
from repro.data.synthetic import SyntheticCorpus
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import make_optimizer
from repro.runtime.fault import StepRunner


def _run(tmp_path, tiny_cfg, fail_at, tag):
    model = build_model(tiny_cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    opt = make_optimizer("adamw", 1e-3)
    opt_state = jax.jit(opt.init)(params)
    loader = DataLoader(SyntheticCorpus(tiny_cfg.vocab_size, seed=0), 4, 32)
    ckpt = CheckpointManager(tmp_path / tag, keep=2)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
    runner = StepRunner(step_fn, ckpt, save_every=5)
    return runner.run(params, opt_state, loader, 16, fail_at=fail_at,
                      log_every=1000)


def test_failure_injection_resumes_exactly(tmp_path, tiny_cfg):
    clean = _run(tmp_path, tiny_cfg, None, "clean")
    faulty = _run(tmp_path, tiny_cfg, {12: 1}, "faulty")
    assert faulty["restarts"] == 1
    # the final params must match the never-failed run exactly
    for a, b in zip(jax.tree.leaves(clean["params"]),
                    jax.tree.leaves(faulty["params"])):
        assert bool(jnp.all(a == b))
