"""Fused scan generation loop (launch.serve PR 5): loop parity + sampling.

The scan loop compiles the whole generation into one ``lax.scan`` device
program (on-device sampling, donated KV cache).  Its contract with the
legacy per-token python loop is *bit-identical tokens* — greedy and
sampled — across every mixer family the model zoo serves (GQA dense,
MLA + MoE, Mamba hybrid): the scan is a scheduling change, not a
numerics change.  Sampling semantics are pinned too: the first token is
drawn from the prefill logits like every other token (it used to be
silently greedy), and ``temperature > 0`` without a key raises instead
of silently degrading to greedy.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.data.synthetic import SyntheticCorpus
from repro.launch.serve import generate
from repro.models import build_model


def _model_params(name):
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32",
                              capacity_factor=100.0)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)
    prompts = corpus.sample(jax.random.key(2), 2, 12)
    return model, params, prompts


@pytest.mark.parametrize("name", ["qwen1.5-4b", "deepseek-v2-236b",
                                  "jamba-v0.1-52b"])
def test_scan_matches_python_loop(name):
    """Greedy + sampled token identity, scan vs python, per mixer family:
    qwen = GQA/dense, deepseek = MLA absorbed decode + expert stacks,
    jamba = mamba state + attention hybrid."""
    model, params, prompts = _model_params(name)
    scan = generate(model, params, prompts, 6, loop="scan")
    python = generate(model, params, prompts, 6, loop="python")
    assert scan.shape == python.shape == (2, 6)
    assert bool(jnp.all(scan == python)), (scan.tolist(), python.tolist())

    key = jax.random.key(7)
    s_scan = generate(model, params, prompts, 6, temperature=1.3, key=key,
                      loop="scan")
    s_python = generate(model, params, prompts, 6, temperature=1.3,
                        key=key, loop="python")
    assert bool(jnp.all(s_scan == s_python))


def test_sampling_contract(tiny_model_params, monkeypatch):
    model, params = tiny_model_params
    corpus = SyntheticCorpus(vocab_size=model.cfg.vocab_size, seed=0)
    prompts = corpus.sample(jax.random.key(2), 2, 12)

    with pytest.raises(ValueError, match="requires a PRNG"):
        generate(model, params, prompts, 4, temperature=1.0)
    with pytest.raises(ValueError, match="loop"):
        generate(model, params, prompts, 4, loop="fused")

    # the first token is sampled from the prefill logits, not argmax'd:
    # at high temperature different keys must disagree on it (the old
    # loop emitted the same greedy first token for every key)
    greedy = generate(model, params, prompts, 1)
    firsts = {tuple(generate(model, params, prompts, 1, temperature=4.0,
                             key=jax.random.key(i))[:, 0].tolist())
              for i in range(8)}
    assert len(firsts) > 1, "first token is still greedy under sampling"
    assert any(f != tuple(greedy[:, 0].tolist()) for f in firsts)

    # determinism: same key -> same stream; temperature=0 ignores the key
    key = jax.random.key(3)
    a = generate(model, params, prompts, 5, temperature=0.9, key=key)
    b = generate(model, params, prompts, 5, temperature=0.9, key=key)
    assert bool(jnp.all(a == b))
    g1 = generate(model, params, prompts, 5, key=key)
    g2 = generate(model, params, prompts, 5)
    assert bool(jnp.all(g1 == g2))

    # temperature rides the jitted program as a traced scalar, not a
    # static closure value: sweeping it must not recompile the scan
    # (one decode_step trace serves every temperature > 0)
    from repro.launch import serve
    calls = []
    orig = type(model).decode_step
    monkeypatch.setattr(type(model), "decode_step",
                        lambda self, *a, **k: (calls.append(1),
                                               orig(self, *a, **k))[1])
    serve._scan_decode_fn.cache_clear()
    generate(model, params, prompts, 5, temperature=0.7, key=key)
    generate(model, params, prompts, 5, temperature=1.9, key=key)
    assert len(calls) == 1, f"temperature sweep retraced ({len(calls)}x)"


def test_scan_decode_is_one_dispatch(tiny_model_params, monkeypatch):
    """The fused loop must not dispatch per token: count ``decode_step``
    retraces — the scan traces the step exactly once into its body, the
    python loop's jit also traces once but dispatches n_gen times.  The
    trace count pins that generate(loop='scan') lowers the whole
    generation as a single program (a python-level per-step loop would
    re-enter decode_step n_gen times)."""
    model, params = tiny_model_params
    corpus = SyntheticCorpus(vocab_size=model.cfg.vocab_size, seed=0)
    prompts = corpus.sample(jax.random.key(2), 2, 12)
    calls = []
    orig = type(model).decode_step
    monkeypatch.setattr(type(model), "decode_step",
                        lambda self, *a, **k: (calls.append(1),
                                               orig(self, *a, **k))[1])
    from repro.launch import serve
    serve._scan_decode_fn.cache_clear()  # force a fresh trace
    generate(model, params, prompts, 7, loop="scan")
    assert len(calls) == 1, f"decode_step entered {len(calls)}x under scan"
