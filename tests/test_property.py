"""Hypothesis property tests on the system's invariants.

Skipped cleanly (not a collection error) where hypothesis isn't installed;
CI installs it (requirements-ci.txt), so both workflow legs run these."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.expansion import expand_dataset
from repro.core.gptq import prepare_hessian
from repro.core.importance import normalize_scores
from repro.core.ldlq import e8_nearest
from repro.core.quantizer import (
    QuantSpec,
    pack_codes,
    quantize_weight_rtn,
    unpack_codes,
)
from repro.core.rotation import random_hadamard

SETTINGS = dict(max_examples=20, deadline=None)


@given(bits=st.sampled_from([2, 3, 4, 8]),
       d_in=st.sampled_from([16, 32, 48]),
       d_out=st.sampled_from([8, 24]),
       seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_rtn_error_within_half_step(bits, d_in, d_out, seed):
    w = jax.random.normal(jax.random.key(seed), (d_in, d_out))
    spec = QuantSpec(bits=bits, group_size=-1, sym=False)
    deq, q, s, z = quantize_weight_rtn(w, spec)
    assert float(jnp.max(jnp.abs(deq - w) / s)) <= 0.5 + 1e-3


@given(bits=st.sampled_from([2, 3, 4, 8]),
       d_in=st.integers(1, 70), d_out=st.integers(1, 20),
       seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_pack_unpack_roundtrip(bits, d_in, d_out, seed):
    q = jax.random.randint(jax.random.key(seed), (d_in, d_out), 0, 2 ** bits)
    assert bool(jnp.all(unpack_codes(pack_codes(q, bits), bits, d_in) == q))


@given(n=st.sampled_from([16, 64, 96, 160]), seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_rotation_is_orthogonal_and_norm_preserving(n, seed):
    q = random_hadamard(jax.random.key(seed), n)
    x = jax.random.normal(jax.random.key(seed + 1), (5, n))
    np.testing.assert_allclose(np.asarray(q @ q.T), np.eye(n), atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(x @ q, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-4)


@given(m=st.sampled_from([2, 4, 8]), t=st.sampled_from([16, 32, 64]),
       seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_expansion_rows_are_rotations(m, t, seed):
    toks = jax.random.randint(jax.random.key(seed), (2, t), 0, 1000)
    out = expand_dataset(toks, m)
    assert out.shape == (2 * m, t)
    for i in range(m):
        assert bool(jnp.all(out[i] == jnp.roll(toks[0], (i * t) // m)))


@given(r_min=st.floats(0.001, 0.5), seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_normalize_scores_in_range(r_min, seed):
    raw = jax.random.normal(jax.random.key(seed), (3, 40)) * 100
    r = normalize_scores(raw, r_min, 1.0)
    assert float(r.min()) >= r_min - 1e-4
    assert float(r.max()) <= 1.0 + 1e-4


@given(seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_e8_nearest_is_idempotent_and_valid(seed):
    y = jax.random.normal(jax.random.key(seed), (16, 8)) * 3
    p = e8_nearest(y)
    # idempotent: lattice points map to themselves
    np.testing.assert_allclose(np.asarray(e8_nearest(p)), np.asarray(p),
                               atol=1e-5)
    # nearest within the two cosets actually checked: distance to p <=
    # distance to plain rounding in D8
    from repro.core.ldlq import _nearest_d8
    d_p = jnp.sum((y - p) ** 2, -1)
    d_a = jnp.sum((y - _nearest_d8(y)) ** 2, -1)
    assert bool(jnp.all(d_p <= d_a + 1e-5))


@given(n=st.sampled_from([8, 24, 32]), d=st.sampled_from([8, 16]),
       seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_prepared_hessian_is_spd(n, d, seed):
    x = jax.random.normal(jax.random.key(seed), (n, d))
    h = prepare_hessian(2.0 * x.T @ x)
    eig = jnp.linalg.eigvalsh(h)
    assert float(eig.min()) > 0.0


@functools.lru_cache(maxsize=1)
def _paged_model():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = dataclasses.replace(get_config("qwen1.5-4b").reduced(),
                              dtype="float32", kv_bits=8)
    return build_model(cfg)


@given(ops=st.lists(st.tuples(st.booleans(), st.integers(0, 7)),
                    min_size=1, max_size=16),
       n_pages=st.sampled_from([3, 5, 8]))
@settings(**SETTINGS)
def test_paged_pool_accounting_invariant(ops, n_pages):
    """Random submit/preempt/retire-shaped alloc/release interleavings
    never alias a page across live requests and always restore the free
    list: ``free + live == n_pages`` after every op, live sets stay
    disjoint, the trash page is never handed out, and releasing
    everything leaves the pool quiescent (the engine-drain audit)."""
    from repro.serving.paged import PagedPools, PageAllocatorExhausted
    pools = PagedPools(_paged_model(), n_pages)
    live: dict[int, set] = {}
    next_key = 0
    for is_alloc, k in ops:
        if is_alloc:
            n = k % 3 + 1
            if n > pools.free_pages():
                with pytest.raises(PageAllocatorExhausted):
                    pools.alloc(n)
            else:
                ids = np.asarray(pools.alloc(n)).tolist()
                held = set().union(*live.values()) if live else set()
                assert not held & set(ids), "page aliased across requests"
                assert 0 not in ids, "trash page handed out"
                live[next_key] = set(ids)
                next_key += 1
        elif live:
            key = sorted(live)[k % len(live)]
            pools.release(np.asarray(sorted(live.pop(key)), np.int32))
        n_live = sum(len(s) for s in live.values())
        assert pools.free_pages() + n_live == n_pages
    for key in sorted(live):
        pools.release(np.asarray(sorted(live.pop(key)), np.int32))
    pools.assert_quiescent()
