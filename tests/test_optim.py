"""Optimizers, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import pytest

from repro.optim import cosine_schedule, linear_warmup, make_optimizer
from repro.optim.compression import ef_compress, init_error_state


def _minimize(opt, steps=60):
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3), "m": jnp.zeros((4, 3))}

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(
            (p["m"] - 1.0) ** 2)

    state = opt.init(params)
    for s in range(steps):
        g = jax.grad(loss_fn)(params)
        params, state = opt.update(params, g, state, jnp.int32(s))
    return float(loss_fn(params))


@pytest.mark.parametrize("name", ["adamw", "adamw8bit", "adafactor", "sgd"])
def test_optimizers_minimize(name):
    opt = make_optimizer(name, 0.05)
    assert _minimize(opt) < 0.3


def test_adamw8bit_tracks_fp32():
    l_fp = _minimize(make_optimizer("adamw", 0.05))
    l_q8 = _minimize(make_optimizer("adamw8bit", 0.05))
    assert abs(l_fp - l_q8) < 0.2


def test_adafactor_state_is_factored():
    opt = make_optimizer("adafactor", 0.01)
    params = {"w": jnp.zeros((64, 32))}
    state = opt.init(params)
    n_state = sum(x.size for x in jax.tree.leaves(state))
    assert n_state <= 64 + 32  # r + c, no full moments


def test_schedules():
    f = cosine_schedule(1.0, 10, 100)
    assert float(f(0)) < 0.2
    assert float(f(10)) == pytest.approx(1.0, abs=0.01)
    assert float(f(99)) < 0.2
    g = linear_warmup(2.0, 5)
    assert float(g(100)) == 2.0


def test_error_feedback_is_unbiased_over_time():
    """sum of compressed grads -> sum of true grads (EF property)."""
    key = jax.random.key(0)
    grads = [0.01 * jax.random.normal(jax.random.fold_in(key, i), (32, 16))
             for i in range(20)]
    err = init_error_state({"g": grads[0]})
    total_c = jnp.zeros_like(grads[0])
    for g in grads:
        c, err = ef_compress({"g": g}, err)
        total_c = total_c + c["g"]
    total = sum(grads)
    rel = float(jnp.abs(total_c - total).max() /
                (jnp.abs(total).max() + 1e-9))
    assert rel < 0.05
