"""Synthetic corpus, loader seekability, dataset expansion."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.expansion import expand_dataset
from repro.data.loader import DataLoader
from repro.data.synthetic import SyntheticCorpus


def test_corpus_determinism():
    c = SyntheticCorpus(vocab_size=211, seed=3)
    a = c.sample(jax.random.key(5), 4, 32)
    b = c.sample(jax.random.key(5), 4, 32)
    assert bool(jnp.all(a == b))
    assert int(a.min()) >= 0 and int(a.max()) < 211


def test_corpus_is_learnable():
    """The Markov mixing must create sub-unigram structure."""
    c = SyntheticCorpus(vocab_size=97, seed=0, markov_strength=0.9)
    toks = np.asarray(c.sample(jax.random.key(0), 8, 256))
    p1, p2 = np.asarray(c._perms()[0]), np.asarray(c._perms()[1])
    topics = toks[:, 0] - 2  # token 0 declares the topic
    det = (p1[topics[:, None], toks[:, 1:-1]] + p2[toks[:, :-2]]) % 97
    acc = (det == toks[:, 2:]).mean()
    assert acc > 0.5


def test_corpus_topic_tokens():
    c = SyntheticCorpus(vocab_size=97, seed=0, n_topics=4)
    toks = np.asarray(c.sample(jax.random.key(1), 32, 16))
    assert set(np.unique(toks[:, 0])) <= {2, 3, 4, 5}


def test_loader_seek_exact():
    c = SyntheticCorpus(vocab_size=101, seed=1)
    l1 = DataLoader(c, 4, 16)
    batches = [next(l1) for _ in range(5)]
    l2 = DataLoader(c, 4, 16)
    l2.restore({"step": 3})
    b3 = next(l2)
    assert bool(jnp.all(b3["tokens"] == batches[3]["tokens"]))


def test_expansion_properties():
    toks = jnp.arange(2 * 16).reshape(2, 16)
    out = expand_dataset(toks, m=4)
    assert out.shape == (8, 16)
    # shift 0 = original
    assert bool(jnp.all(out[0] == toks[0]))
    # every shifted row is a circular permutation (same multiset)
    for i in range(4):
        assert sorted(out[i].tolist()) == sorted(toks[0].tolist())
    # shift k moves the tail to the front
    assert bool(jnp.all(out[1] == jnp.roll(toks[0], 4)))
    assert bool(jnp.all(expand_dataset(toks, m=1) == toks))
