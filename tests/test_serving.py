"""Continuous-batching serve engine on block-paged quantized KV (PR 8).

The engine's contract is *bit-identity*: a request's tokens are bitwise
the tokens ``launch.serve.generate`` produces for that prompt alone at
batch 1 with the same ``SamplingParams``, regardless of what shares the
batch — pinned here across GQA/MLA × kv8/kv2, heterogeneous budgets and
temperatures, page reuse after retirement (stale page contents must not
perturb later requests), and the paged kernels' tile-indirect loop
against the flat kernels at partial-tile positions.  The paged path must
also never materialize an fp copy of the cache (codes+scales end to
end), and the page allocator must fail actionably, not opaquely.

PR 9 (chunked prefill) extends the contract: admission with
``prefill_chunk=N`` streams prompts in page-aligned chunks interleaved
with decode bursts, and the default exact mode must keep every stream
bitwise identical to the solo batch-1 ``generate`` — including prompts
spanning several pages with a partial final chunk.  The paged-extend
kernels are pinned bitwise against their refs (GQA + MLA × kv8/kv2,
empty/partial-chunk edges), ``submit`` fails fast with the sizing math
when a request can never fit, and ``generate_batch`` now warns that the
engine supersedes it.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import SyntheticCorpus
from repro.launch.serve import generate, generate_batch
from repro.models import attention as att
from repro.models import build_model
from repro.serving import (Engine, PagedPools, RequestOutput, SamplingParams,
                           ServeRequest, poisson_trace, run_trace)
from repro.serving.paged import PageAllocatorExhausted

PAIRS = [("qwen1.5-4b", 8), ("qwen1.5-4b", 2),
         ("deepseek-v2-236b", 8), ("deepseek-v2-236b", 2)]


@functools.lru_cache(maxsize=None)
def _model_params(name, kv_bits):
    # capacity_factor=100: MoE capacity dropping couples tokens across a
    # batch (true of any batched serving) — lift it so deepseek's streams
    # are batch-composition-independent and bit-identity is testable.
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32",
                              capacity_factor=100.0, kv_bits=kv_bits)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    return model, params


def _prompts(model, n, t):
    corpus = SyntheticCorpus(vocab_size=model.cfg.vocab_size, seed=0)
    return corpus.sample(jax.random.key(2), n, t)


def _baseline(model, params, prompt, n_gen, sp):
    """The single-request batch-1 reference stream for one request."""
    key = jax.random.key(sp.seed) if sp.temperature > 0 else None
    out = generate(model, params, jnp.asarray(prompt, jnp.int32)[None],
                   n_gen, temperature=sp.temperature, key=key)
    return out[0].tolist()


@pytest.mark.parametrize("name,kv_bits", PAIRS)
def test_engine_bit_identical_to_single_request(name, kv_bits):
    """Heterogeneous budgets + sampling params over fewer slots than
    requests (forces queueing + admission mid-flight): every request's
    tokens must match its solo batch-1 ``generate`` stream bitwise, and
    every page must come back after the drain.  Prompt 60 + budgets up
    to 12 push every request past the 64-token page boundary, so the
    paged kernel walks a 2-entry page table mid-stream — the identity
    must survive the second-page indirection, not just page 0."""
    model, params = _model_params(name, kv_bits)
    prompts = _prompts(model, 3, 60)
    sps = [SamplingParams(), SamplingParams(),
           SamplingParams(temperature=1.3, seed=7)]
    budgets = [12, 9, 7]
    expected = [_baseline(model, params, prompts[i].tolist(), budgets[i],
                          sps[i])
                for i in range(3)]

    engine = Engine(model, params, max_slots=2, n_pages=16,
                    max_pages_per_request=2, burst_steps=4)
    rids = [engine.submit(ServeRequest(tokens=prompts[i].tolist(),
                                       max_new_tokens=budgets[i],
                                       sampling=sps[i]))
            for i in range(3)]
    outs = {o.request_id: o for o in engine.drain()}
    assert sorted(outs) == sorted(rids)
    for i, rid in enumerate(rids):
        assert outs[rid].tokens == expected[i], \
            f"request {i}: {outs[rid].tokens} != {expected[i]}"
        assert outs[rid].prompt_len == 60
    assert engine.pools.free_pages() == 16, "pages leaked after drain"


def test_engine_eos_early_stop():
    """A request stops at its eos token (inclusive) and retires early,
    releasing pages while other requests keep decoding."""
    model, params = _model_params("qwen1.5-4b", 8)
    prompts = _prompts(model, 2, 12)
    full = _baseline(model, params, prompts[0].tolist(), 10,
                     SamplingParams())
    eos = full[4]
    cut = full.index(eos) + 1  # first occurrence (may precede slot 4)
    engine = Engine(model, params, max_slots=2, n_pages=8,
                    max_pages_per_request=1, burst_steps=3)
    r0 = engine.submit(ServeRequest(
        tokens=prompts[0].tolist(), max_new_tokens=10,
        sampling=SamplingParams(eos_token=eos)))
    r1 = engine.submit(ServeRequest(tokens=prompts[1].tolist(),
                                    max_new_tokens=10))
    outs = {o.request_id: o for o in engine.drain()}
    assert outs[r0].tokens == full[:cut]
    assert outs[r1].tokens == _baseline(model, params, prompts[1].tolist(),
                                        10, SamplingParams())


def test_page_reuse_after_retirement():
    """The allocator is LIFO (freshly retired pages are reused first) and
    stale page contents from a drained batch must not perturb the next
    one — pages are reused without any zeroing."""
    model, params = _model_params("qwen1.5-4b", 8)
    pools = PagedPools(model, 8)
    a = pools.alloc(3)
    assert pools.free_pages() == 5
    pools.release(a)
    assert pools.free_pages() == 8
    b = pools.alloc(3)
    assert b.tolist() == a.tolist(), "retired pages are not reused first"
    pools.release(b)

    # engine-level: second wave decodes on pages the first wave dirtied
    prompts = _prompts(model, 4, 12)
    engine = Engine(model, params, max_slots=2, n_pages=2,
                    max_pages_per_request=1, burst_steps=4)
    for i in range(2):
        engine.submit(ServeRequest(tokens=prompts[i].tolist(),
                                   max_new_tokens=6))
    engine.drain()
    assert engine.pools.free_pages() == 2
    rids = [engine.submit(ServeRequest(tokens=prompts[i].tolist(),
                                       max_new_tokens=6))
            for i in (2, 3)]
    outs = {o.request_id: o for o in engine.drain()}
    for i, rid in zip((2, 3), rids):
        assert outs[rid].tokens == _baseline(
            model, params, prompts[i].tolist(), 6, SamplingParams()), \
            "stale page contents leaked into a reused page's stream"


def test_allocator_exhaustion_is_actionable():
    model, params = _model_params("qwen1.5-4b", 8)
    pools = PagedPools(model, 4)
    with pytest.raises(PageAllocatorExhausted, match="need 5 pages"):
        pools.alloc(5)
    with pytest.raises(PageAllocatorExhausted, match="Retire requests"):
        pools.alloc(5)

    engine = Engine(model, params, max_slots=2, n_pages=4,
                    max_pages_per_request=2, burst_steps=2)
    page = engine.page
    big = ServeRequest(tokens=list(range(2 * page)), max_new_tokens=page)
    with pytest.raises(ValueError, match="max_pages_per_request"):
        engine.submit(big)
    # submit fails fast with the sizing math when prompt + budget can
    # never fit the pool, even one with every page free (PR 9): the
    # request must be rejected at submission, not after it has queued
    # behind requests that will never unblock it
    wide = Engine(model, params, max_slots=2, n_pages=2,
                  max_pages_per_request=8, burst_steps=2)
    with pytest.raises(PageAllocatorExhausted) as ei:
        wide.submit(big)
    msg = str(ei.value)
    assert "can never fit" in msg and "need 3 pages" in msg
    assert "raise n_pages" in msg  # the actionable sizing advice
    assert f"{2 * page} prompt" in msg and f"{page} new tokens" in msg

    # kv_bits=0 has no code/scale layout to page
    fp_model, _ = _model_params("qwen1.5-4b", 0)
    with pytest.raises(ValueError, match="kv_bits=8 or kv_bits=2"):
        PagedPools(fp_model, 4)


@pytest.mark.parametrize("kv_bits", [8, 2])
def test_paged_path_never_materializes_fp_cache(kv_bits, monkeypatch):
    """Codes+scales are the paged cache's only representation: a full
    engine run (prefill scatter + burst decode + retire) must never call
    the fp cache decoders."""
    model, params = _model_params("qwen1.5-4b", kv_bits)
    prompts = _prompts(model, 2, 12)

    def boom(*a, **k):
        raise AssertionError("paged serving materialized an fp KV cache")

    monkeypatch.setattr(att, "kv_dequantize", boom)
    monkeypatch.setattr(att, "kv_log_decode", boom)
    engine = Engine(model, params, max_slots=2, n_pages=4,
                    max_pages_per_request=1, burst_steps=4)
    for i in range(2):
        engine.submit(ServeRequest(tokens=prompts[i].tolist(),
                                   max_new_tokens=6))
    outs = engine.drain()
    assert len(outs) == 2 and all(len(o.tokens) == 6 for o in outs)


def test_engine_rejects_unpageable_models():
    """SSM state is per-slot, not per-page: jamba must be rejected with a
    pointer at the flat path, not fail deep in the paged kernels."""
    cfg = dataclasses.replace(get_config("jamba-v0.1-52b").reduced(),
                              dtype="float32", kv_bits=8)
    model = build_model(cfg)
    with pytest.raises(ValueError, match="launch.serve.generate"):
        Engine(model, jax.jit(model.init)(jax.random.key(0)), n_pages=4)


def test_poisson_trace_driver():
    """Arrivals land at their scheduled rounds and the driver reports the
    sustained-throughput/latency summary the bench leg records."""
    model, params = _model_params("qwen1.5-4b", 8)
    prompts = _prompts(model, 4, 12)
    reqs = [ServeRequest(tokens=prompts[i].tolist(), max_new_tokens=4)
            for i in range(4)]
    trace = poisson_trace(reqs, rate=2.0, seed=3)
    assert [e.step for e in trace] == sorted(e.step for e in trace)
    engine = Engine(model, params, max_slots=2, n_pages=8,
                    max_pages_per_request=1, burst_steps=2)
    stats = run_trace(engine, trace)
    assert stats["n_requests"] == 4
    assert stats["n_tokens"] == 16
    assert stats["sustained_tok_s"] > 0
    assert stats["p99_latency_s"] >= stats["p50_latency_s"] >= 0
    assert engine.pools.free_pages() == 8


def test_generate_batch_wraps_generate():
    """The request-typed wrapper returns per-request truncations of the
    fixed-batch stream and rejects what only the engine can serve."""
    model, params = _model_params("qwen1.5-4b", 8)
    prompts = _prompts(model, 2, 12)
    sp = SamplingParams(temperature=0.9, seed=3)
    reqs = [ServeRequest(tokens=prompts[i].tolist(), max_new_tokens=n,
                         sampling=sp) for i, n in enumerate((4, 6))]
    out = generate_batch(model, params, reqs)
    ref = generate(model, params, prompts, 6, temperature=0.9,
                   key=jax.random.key(3))
    assert out[0] == ref[0, :4].tolist()
    assert out[1] == ref[1].tolist()
    assert generate_batch(model, params, []) == []

    mixed_len = [reqs[0], dataclasses.replace(reqs[1],
                                              tokens=prompts[1][:8])]
    with pytest.raises(ValueError, match="one prompt length"):
        generate_batch(model, params, mixed_len)
    mixed_sp = [reqs[0], dataclasses.replace(
        reqs[1], sampling=SamplingParams(temperature=0.5))]
    with pytest.raises(ValueError, match="identical SamplingParams"):
        generate_batch(model, params, mixed_sp)
    eos = [dataclasses.replace(r, sampling=SamplingParams(eos_token=3))
           for r in reqs]
    with pytest.raises(ValueError, match="serving.Engine"):
        generate_batch(model, params, eos)


# ------------------------------------------------- paged kernels vs flat


def _gqa_pool_case(kv_bits):
    """Random flat GQA cache + the same codes scattered into shuffled
    pages: flat (B, S, KV, ·) caches vs (n_pages, page, KV, ·) pools with
    per-request page tables and partial-tile positions."""
    page, b, kv, g, dh = 64, 2, 2, 2, 16
    s = 2 * page
    codec = att.kv_codec(kv_bits, page)
    key = jax.random.key(5)
    kx, vx, qx = (jax.random.normal(k, shp, jnp.float32) for k, shp in zip(
        jax.random.split(key, 3),
        [(b, s, kv, dh), (b, s, kv, dh), (b, kv, g, dh)]))
    kq, ks = codec.encode(kx)
    vq, vs = codec.encode(vx)
    # request 0 -> pages [3, 1]; request 1 -> pages [4, 2] (+ trash 0)
    tbl = np.array([[3, 1], [4, 2]], np.int32)
    n_pages = 5

    def pool(codes, scales):
        cp = jnp.zeros((n_pages,) + (page,) + codes.shape[2:], codes.dtype)
        sp = jnp.zeros((n_pages, page // codec.chunk) + scales.shape[2:],
                       scales.dtype)
        for bb in range(b):
            for t in range(2):
                pid = int(tbl[bb, t])
                cp = cp.at[pid].set(codes[bb, t * page:(t + 1) * page])
                sr = page // codec.chunk
                sp = sp.at[pid].set(scales[bb, t * sr:(t + 1) * sr])
        return cp, sp

    kqp, ksp = pool(kq, ks)
    vqp, vsp = pool(vq, vs)
    pos = np.array([70, 35], np.int32)  # both tiles partial for b=1
    tbl = jnp.asarray(tbl).at[1, 1].set(0)  # b=1 tile 1: trash page
    return codec, qx, (kq, ks, vq, vs), (kqp, ksp, vqp, vsp), tbl, pos, dh


@pytest.mark.parametrize("kv_bits", [8, 2])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_paged_gqa_matches_flat_bitwise(kv_bits, use_kernel):
    """The page-table-indirect tile loop is the flat kernel's loop with an
    indirection: same codes at tile = page -> bitwise-identical output per
    request, with partial trailing tiles and a trash-page table entry in
    play.  The flat call is pinned to ``s_blk = page`` (its dispatch may
    pick a larger tile, which reorders the streaming-softmax rescales by
    an ulp) and run at the full batch shape per request pos (the flat
    kernels share one pos across the batch)."""
    from repro.kernels.flash_decode import (flash_decode_pallas,
                                            flash_decode_ref,
                                            paged_flash_decode)

    codec, q, flat, pools, tbl, pos, dh = _gqa_pool_case(kv_bits)
    paged = paged_flash_decode(tbl, pos, q, *pools, kv_bits=kv_bits,
                               chunk=codec.chunk, dv=dh, page=64,
                               use_kernel=use_kernel)
    for bb in range(2):
        px = jnp.full((1, 1), pos[bb], jnp.int32)
        if use_kernel:
            acc, _, l = flash_decode_pallas(
                q, *flat, px, kv_bits=kv_bits, chunk=codec.chunk, dh=dh,
                dv=dh, s_blk=64, interpret=True)
        else:
            acc, _, l = flash_decode_ref(
                q, *flat, px, kv_bits=kv_bits, chunk=codec.chunk, dh=dh,
                dv=dh, s_blk=64)
        ref = acc / jnp.maximum(l, 1e-30)
        assert jnp.array_equal(paged[bb], ref[bb]), \
            f"request {bb} not bitwise equal (kernel={use_kernel})"


@pytest.mark.parametrize("kv_bits", [8, 2])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_paged_mla_matches_flat_bitwise(kv_bits, use_kernel):
    from repro.kernels.flash_decode import (mla_flash_decode_pallas,
                                            mla_flash_decode_ref,
                                            paged_mla_flash_decode)

    page, b, h, dl, dr = 64, 2, 2, 32, 16
    s = 2 * page
    codec = att.kv_codec(kv_bits, page)
    key = jax.random.key(9)
    cx, rx, qlx, qrx = (jax.random.normal(k, shp, jnp.float32)
                        for k, shp in zip(jax.random.split(key, 4),
                                          [(b, s, dl), (b, s, dr),
                                           (b, h, dl), (b, h, dr)]))
    cq, cs = codec.encode(cx)
    rq, rs = codec.encode(rx)
    tblh = np.array([[3, 1], [4, 2]], np.int32)
    n_pages = 5

    def pool(codes, scales):
        cp = jnp.zeros((n_pages, page) + codes.shape[2:], codes.dtype)
        sp = jnp.zeros((n_pages, page // codec.chunk), scales.dtype)
        for bb in range(b):
            for t in range(2):
                pid = int(tblh[bb, t])
                cp = cp.at[pid].set(codes[bb, t * page:(t + 1) * page])
                sr = page // codec.chunk
                sp = sp.at[pid].set(scales[bb, t * sr:(t + 1) * sr])
        return cp, sp

    cqp, csp = pool(cq, cs)
    rqp, rsp = pool(rq, rs)
    pos = np.array([70, 35], np.int32)
    tbl = jnp.asarray(tblh).at[1, 1].set(0)
    paged = paged_mla_flash_decode(tbl, pos, qlx, qrx, cqp, csp, rqp, rsp,
                                   kv_bits=kv_bits, chunk=codec.chunk,
                                   dl=dl, dr=dr, page=page,
                                   use_kernel=use_kernel)
    for bb in range(b):
        px = jnp.full((1, 1), pos[bb], jnp.int32)
        if use_kernel:
            acc, _, l = mla_flash_decode_pallas(
                qlx, qrx, cq, cs, rq, rs, px, kv_bits=kv_bits,
                chunk=codec.chunk, dl=dl, dr=dr, s_blk=page,
                interpret=True)
        else:
            acc, _, l = mla_flash_decode_ref(
                qlx, qrx, cq, cs, rq, rs, px, kv_bits=kv_bits,
                chunk=codec.chunk, dl=dl, dr=dr, s_blk=page)
        ref = acc / jnp.maximum(l, 1e-30)
        assert jnp.array_equal(paged[bb], ref[bb]), \
            f"request {bb} not bitwise equal (kernel={use_kernel})"


# -------------------------------------------- chunked prefill (PR 9)


def _extend_pools(codec, x, pages, n_pages, page):
    """Encode a flat past and scatter it into shuffled pages; returns the
    (n_pages, page, ...) code pool and its scale pool.  ``x`` is the full
    fp past (batch 1); pages beyond ``pages`` stay zero (trash-shaped)."""
    codes, scales = codec.encode(x)
    cp = jnp.zeros((n_pages, page) + codes.shape[2:], codes.dtype)
    sr = page // codec.chunk
    sp = jnp.zeros((n_pages, sr) + scales.shape[2:], scales.dtype)
    for t, pid in enumerate(pages):
        cp = cp.at[pid].set(codes[0, t * page:(t + 1) * page])
        sp = sp.at[pid].set(scales[0, t * sr:(t + 1) * sr])
    return cp, sp


@pytest.mark.parametrize("kv_bits", [8, 2])
@pytest.mark.parametrize("n_past,L", [(0, 17), (2, 30), (2, 64)])
def test_paged_gqa_extend_kernel_matches_ref_bitwise(kv_bits, n_past, L):
    """The extend kernel's tile loop (quantized past pages + causal fp
    within-chunk tile) must match its ref bitwise at every edge: no past
    pages, a partial final chunk, and a full page-multiple chunk."""
    from repro.kernels.flash_decode import (paged_flash_extend_pallas,
                                            paged_flash_extend_ref)

    page, kv, g, dh = 64, 2, 2, 16
    codec = att.kv_codec(kv_bits, page)
    keys = jax.random.split(jax.random.key(11), 5)
    s_past = max(n_past, 1) * page  # >= 1 page so pool shapes exist
    kx, vx = (jax.random.normal(k, (1, s_past, kv, dh), jnp.float32)
              for k in keys[:2])
    pages = [3, 1, 5][:n_past]
    kqp, ksp = _extend_pools(codec, kx, pages, 6, page)
    vqp, vsp = _extend_pools(codec, vx, pages, 6, page)
    q = jax.random.normal(keys[2], (1, L, kv * g, dh), jnp.float32)
    k_new, v_new = (jax.random.normal(k, (1, L, kv, dh), jnp.float32)
                    for k in keys[3:])
    tbl = jnp.asarray(pages, jnp.int32)
    start = jnp.int32(n_past * page)
    kw = dict(kv_bits=kv_bits, chunk=codec.chunk, dh=dh, dv=dh, page=page)
    ref = paged_flash_extend_ref(tbl, q, k_new, v_new, kqp, ksp, vqp, vsp,
                                 start, **kw)
    ker = paged_flash_extend_pallas(tbl, q, k_new, v_new, kqp, ksp, vqp,
                                    vsp, start, interpret=True, **kw)
    assert ker.shape == (1, L, kv * g, dh)
    assert jnp.array_equal(ker, ref), \
        f"extend kernel != ref (kv_bits={kv_bits}, n_past={n_past}, L={L})"


@pytest.mark.parametrize("kv_bits", [8, 2])
@pytest.mark.parametrize("n_past,L", [(0, 17), (2, 30), (2, 64)])
def test_paged_mla_extend_kernel_matches_ref_bitwise(kv_bits, n_past, L):
    from repro.kernels.flash_decode import (paged_mla_flash_extend_pallas,
                                            paged_mla_flash_extend_ref)

    page, h, dl, dr = 64, 2, 32, 16
    codec = att.kv_codec(kv_bits, page)
    keys = jax.random.split(jax.random.key(13), 6)
    s_past = max(n_past, 1) * page
    cx = jax.random.normal(keys[0], (1, s_past, dl), jnp.float32)
    rx = jax.random.normal(keys[1], (1, s_past, dr), jnp.float32)
    pages = [3, 1, 5][:n_past]
    cqp, csp = _extend_pools(codec, cx, pages, 6, page)
    rqp, rsp = _extend_pools(codec, rx, pages, 6, page)
    ql = jax.random.normal(keys[2], (L, h, dl), jnp.float32)
    qr = jax.random.normal(keys[3], (L, h, dr), jnp.float32)
    c_new = jax.random.normal(keys[4], (L, dl), jnp.float32)
    r_new = jax.random.normal(keys[5], (L, dr), jnp.float32)
    tbl = jnp.asarray(pages, jnp.int32)
    start = jnp.int32(n_past * page)
    kw = dict(kv_bits=kv_bits, chunk=codec.chunk, dl=dl, dr=dr, page=page)
    ref = paged_mla_flash_extend_ref(tbl, ql, qr, c_new, r_new, cqp, csp,
                                     rqp, rsp, start, **kw)
    ker = paged_mla_flash_extend_pallas(tbl, ql, qr, c_new, r_new, cqp,
                                        csp, rqp, rsp, start,
                                        interpret=True, **kw)
    assert ker.shape == (L, h, dl)
    assert jnp.array_equal(ker, ref), \
        f"MLA extend kernel != ref (kv_bits={kv_bits}, " \
        f"n_past={n_past}, L={L})"


@pytest.mark.parametrize("name,kv_bits", PAIRS)
def test_chunked_prefill_bit_identical_to_single_request(name, kv_bits):
    """Chunked admission (prefill_chunk=64, 150-token prompts spanning
    three pages with a partial final chunk) must keep every stream
    bitwise identical to the solo batch-1 ``generate`` — the exact-mode
    fp prefix buffers replay the flat prefill's tiles, so streaming the
    prompt through the running decode batch changes scheduling, never
    tokens.  TTFT/stall accounting and page hygiene ride along."""
    model, params = _model_params(name, kv_bits)
    prompts = _prompts(model, 3, 150)
    sps = [SamplingParams(), SamplingParams(temperature=1.3, seed=7),
           SamplingParams()]
    budgets = [12, 9, 7]
    expected = [_baseline(model, params, prompts[i].tolist(), budgets[i],
                          sps[i])
                for i in range(3)]
    engine = Engine(model, params, max_slots=2, n_pages=16,
                    max_pages_per_request=3, burst_steps=4,
                    prefill_chunk=64)
    rids = [engine.submit(ServeRequest(tokens=prompts[i].tolist(),
                                       max_new_tokens=budgets[i],
                                       sampling=sps[i]))
            for i in range(3)]
    outs = {o.request_id: o for o in engine.drain()}
    assert sorted(outs) == sorted(rids)
    for i, rid in enumerate(rids):
        assert outs[rid].tokens == expected[i], \
            f"request {i}: {outs[rid].tokens} != {expected[i]}"
        assert outs[rid].ttft > 0
        assert outs[rid].latency >= outs[rid].ttft
    assert engine.pools.free_pages() == 16, "pages leaked after drain"
    assert engine.admission_stall_s > 0


def test_chunked_prefill_paged_attention_mode_drains():
    """The opt-in ``prefill_attn="paged"`` mode re-reads earlier chunks
    from their quantized pages (documented lossy vs the flat prefill, so
    no bit-identity claim): it must admit, decode and retire cleanly
    with page hygiene intact."""
    model, params = _model_params("qwen1.5-4b", 8)
    prompts = _prompts(model, 2, 150)
    engine = Engine(model, params, max_slots=2, n_pages=8,
                    max_pages_per_request=3, burst_steps=4,
                    prefill_chunk=64, prefill_attn="paged")
    for i in range(2):
        engine.submit(ServeRequest(tokens=prompts[i].tolist(),
                                   max_new_tokens=6))
    outs = engine.drain()
    assert len(outs) == 2 and all(len(o.tokens) == 6 for o in outs)
    assert engine.pools.free_pages() == 8

    with pytest.raises(ValueError, match="prefill_attn"):
        Engine(model, params, max_slots=2, n_pages=8,
               max_pages_per_request=3, prefill_chunk=64,
               prefill_attn="bogus")


def test_generate_batch_is_deprecated():
    """``generate_batch`` survives as a thin compatibility wrapper but
    must warn that the engine supersedes it."""
    model, params = _model_params("qwen1.5-4b", 8)
    prompts = _prompts(model, 1, 12)
    req = ServeRequest(tokens=prompts[0].tolist(), max_new_tokens=2)
    with pytest.warns(DeprecationWarning, match="serving.Engine"):
        generate_batch(model, params, [req])


# ------------------------------------------------------- trace driver


def test_poisson_trace_deterministic_under_seed():
    """Fixed seed -> bitwise-identical arrival schedule (the bench's
    whole/chunked admission comparison depends on both engine runs
    seeing the same trace); different seeds -> different schedules."""
    reqs = [ServeRequest(tokens=[1], max_new_tokens=1)] * 16
    a = poisson_trace(reqs, rate=0.7, seed=11)
    b = poisson_trace(reqs, rate=0.7, seed=11)
    assert [e.step for e in a] == [e.step for e in b]
    assert [e.step for e in a] == sorted(e.step for e in a)
    c = poisson_trace(reqs, rate=0.7, seed=12)
    assert [e.step for e in c] != [e.step for e in a]


def test_run_trace_percentiles_on_hand_built_outputs():
    """The summary's p50/p99 latency, ttft percentiles and stall fields
    are plain ``np.percentile`` over per-request wall times — pinned on a
    stub engine emitting hand-built outputs with known timestamps."""
    outs = [RequestOutput(request_id=i, tokens=list(range(i + 1)),
                          prompt_len=4, submit_time=0.0,
                          finish_time=float(i + 1),
                          first_token_time=0.25 * (i + 1))
            for i in range(5)]

    class Stub:
        admission_stall_s = 0.125

        def __init__(self, pending):
            self._pending = list(pending)

        def submit(self, req):
            pass

        @property
        def busy(self):
            return bool(self._pending)

        def step(self):
            return [self._pending.pop(0)] if self._pending else []

    reqs = [ServeRequest(tokens=[1], max_new_tokens=1)] * 5
    stats = run_trace(Stub(outs), poisson_trace(reqs, rate=2.0, seed=0))
    lats = [1.0, 2.0, 3.0, 4.0, 5.0]
    ttfts = [0.25 * (i + 1) for i in range(5)]
    assert stats["n_requests"] == 5
    assert stats["n_tokens"] == 15
    assert stats["p50_latency_s"] == pytest.approx(np.percentile(lats, 50))
    assert stats["p99_latency_s"] == pytest.approx(np.percentile(lats, 99))
    assert stats["ttft_p50_s"] == pytest.approx(np.percentile(ttfts, 50))
    assert stats["ttft_p99_s"] == pytest.approx(np.percentile(ttfts, 99))
    assert stats["admission_stall_s"] == 0.125
    assert stats["p99_latency_s"] > stats["p50_latency_s"]
