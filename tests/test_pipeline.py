"""End-to-end RSQ pipeline on tiny models (all three method baselines)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import RSQConfig, quantize_model
from repro.models import build_model


def _ppl(model, params, toks):
    loss = model.loss(params, {"tokens": toks, "labels": toks})
    return float(jnp.exp(loss))


@pytest.fixture(scope="module")
def setup(tiny_cfg):
    model = build_model(tiny_cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    calib = jax.random.randint(jax.random.key(1), (8, 64), 0,
                               tiny_cfg.vocab_size)
    return model, params, calib


@pytest.mark.parametrize("rsq", [
    RSQConfig(bits=3, rotate=False, importance="uniform"),   # GPTQ
    RSQConfig(bits=3, rotate=True, importance="uniform"),    # QuaRot
    RSQConfig(bits=3, rotate=True, importance="attn_con"),   # RSQ
    RSQConfig(bits=3, rotate=True, importance="act_norm"),
    RSQConfig(bits=3, rotate=True, importance="first_n", first_n=16),
    RSQConfig(bits=4, rotate=True, importance="attn_con", expansion=2),
    RSQConfig(rotate=True, importance="attn_con", method="ldlq"),
], ids=["gptq", "quarot", "rsq", "actnorm", "firstn", "expand", "ldlq"])
def test_pipeline_produces_working_model(setup, rsq):
    model, params, calib = setup
    qparams, report = quantize_model(model, params, calib, rsq, batch_size=4)
    ppl = _ppl(model, qparams, calib)
    assert jnp.isfinite(ppl)
    # quantized model stays within a reasonable factor of the fp model
    assert ppl < _ppl(model, params, calib) * 3.0
    n_w = sum(len(l["weights"]) for l in report["layers"].values())
    assert n_w >= 7 * 2  # >= 7 weights per block x 2 layers


def test_chunk_restriction(setup):
    """Tab. 1 machinery: restricting the loss to a chunk runs and differs."""
    model, params, calib = setup
    r1 = RSQConfig(bits=3, importance="uniform", chunk_lo=0.0, chunk_hi=0.25)
    r2 = RSQConfig(bits=3, importance="uniform", chunk_lo=0.75, chunk_hi=1.0)
    q1, _ = quantize_model(model, params, calib, r1, batch_size=4)
    q2, _ = quantize_model(model, params, calib, r2, batch_size=4)
    l1 = jax.tree.leaves(q1["groups"])[0]
    l2 = jax.tree.leaves(q2["groups"])[0]
    assert not jnp.allclose(l1, l2)


@pytest.mark.parametrize("arch", ["deepseek-v2-236b", "mamba2-780m",
                                  "jamba-v0.1-52b"])
def test_pipeline_on_other_families(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    calib = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
    rsq = RSQConfig(bits=4, rotate=True, importance="attn_con")
    qparams, report = quantize_model(model, params, calib, rsq, batch_size=4)
    assert jnp.isfinite(_ppl(model, qparams, calib))
