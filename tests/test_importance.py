"""Token-importance strategies (paper Sec. 4.3)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.importance import (
    STRATEGIES,
    ImportanceInputs,
    get_strategy,
    normalize_scores,
)


def _inputs(b=2, t=64, d=16, seed=0):
    k = jax.random.key(seed)
    z = jax.random.normal(k, (b, t, d))
    return ImportanceInputs(
        z_in=z,
        z_out=z + 0.1 * jax.random.normal(jax.random.fold_in(k, 1), (b, t, d)),
        tokens=jax.random.randint(jax.random.fold_in(k, 2), (b, t), 0, 99),
        attn_colsum=jax.random.uniform(jax.random.fold_in(k, 3), (b, t)),
        token_counts=jnp.arange(1.0, 100.0),
    )


@pytest.mark.parametrize("name", sorted(STRATEGIES))
def test_strategy_shapes_and_finiteness(name):
    inp = _inputs()
    r = get_strategy(name)(inp, **({"n": 16} if "first" in name else {}))
    assert r.shape == (2, 64)
    assert bool(jnp.all(jnp.isfinite(r)))
    assert float(r.min()) >= 0.0


def test_normalize_scores_bounds():
    raw = jax.random.normal(jax.random.key(0), (3, 50)) * 10
    r = normalize_scores(raw, 0.01, 1.0)
    assert jnp.allclose(r.min(axis=-1), 0.01, atol=1e-5)
    assert jnp.allclose(r.max(axis=-1), 1.0, atol=1e-5)


def test_first_n_masks():
    inp = _inputs()
    r = get_strategy("first_n")(inp, n=16)
    assert bool(jnp.all(r[:, :16] == 1.0)) and bool(jnp.all(r[:, 16:] == 0.0))
    r = get_strategy("first_last_n")(inp, n=16)
    assert bool(jnp.all(r[:, :8] == 1.0)) and bool(jnp.all(r[:, -8:] == 1.0))
    assert float(r.sum()) == 2 * 16.0


def test_attn_con_falls_back_without_attention():
    inp = _inputs()
    inp_no_attn = ImportanceInputs(z_in=inp.z_in)
    r = get_strategy("attn_con")(inp_no_attn, r_min=0.01)
    r_norm = get_strategy("act_norm")(
        ImportanceInputs(z_in=inp.z_in), r_min=0.01)
    assert jnp.allclose(r, r_norm)


def test_token_freq_prefers_rare_tokens():
    inp = _inputs()
    r = get_strategy("token_freq")(inp, r_min=0.01)
    flat_t = inp.tokens.reshape(-1)
    flat_r = r.reshape(-1)
    rare = flat_r[jnp.argmin(flat_t)]  # counts grow with id here
    common = flat_r[jnp.argmax(flat_t)]
    assert float(rare) > float(common)


def test_token_sim_chunked_equals_direct():
    from repro.core.importance import token_sim

    inp = _inputs(t=64)
    a = token_sim(inp, chunk=16)
    b = token_sim(inp, chunk=64)
    assert jnp.allclose(a, b, atol=1e-4)
