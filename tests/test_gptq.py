"""GPTQ solver vs the naive OBC oracle + RTN comparison + RSQ Hessian."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gptq import gptq_quantize, gptq_quantize_ref
from repro.core.hessian import accumulate
from repro.core.ldlq import e8_nearest, ldlq_quantize
from repro.core.quantizer import QuantSpec, quantize_weight_rtn


def _data(d_in=64, d_out=48, n=256, seed=0):
    w = jax.random.normal(jax.random.key(seed), (d_in, d_out)) * 0.5
    x = jax.random.normal(jax.random.key(seed + 1), (n, d_in))
    return w, x, accumulate(None, x)


@pytest.mark.parametrize("spec", [
    QuantSpec(bits=4, group_size=-1),
    QuantSpec(bits=2, group_size=16, sym=False),
    QuantSpec(bits=3, group_size=32),
])
def test_blocked_matches_oracle(spec):
    w, x, h = _data()
    out = gptq_quantize(w, h, spec, block=32)
    ref = gptq_quantize_ref(np.asarray(w), np.asarray(h), spec)
    match = (np.asarray(out["q"]) == ref["q"]).mean()
    assert match > 0.995, f"codes match {match}"


@pytest.mark.parametrize("bits", [2, 3, 4])
def test_gptq_beats_rtn(bits):
    w, x, h = _data()
    spec = QuantSpec(bits=bits, group_size=32)
    out = gptq_quantize(w, h, spec, block=32)
    rtn, *_ = quantize_weight_rtn(w, spec)

    def recon(wq):
        return float(jnp.mean((x @ w - x @ wq) ** 2))

    assert recon(out["w_deq"]) < recon(rtn)


def test_weighted_hessian_prioritizes_tokens():
    """RSQ's core claim at the solver level: up-weighting a token subset
    reduces *their* reconstruction error relative to uniform weighting."""
    w, x, _ = _data(n=512)
    r = jnp.where(jnp.arange(512) < 128, 1.0, 0.01)
    h_uni = accumulate(None, x)
    h_rsq = accumulate(None, x, r)
    spec = QuantSpec(bits=2, group_size=16)
    q_uni = gptq_quantize(w, h_uni, spec, block=32)["w_deq"]
    q_rsq = gptq_quantize(w, h_rsq, spec, block=32)["w_deq"]
    hot = x[:128]

    def err(wq, xs):
        return float(jnp.mean((xs @ w - xs @ wq) ** 2))

    assert err(q_rsq, hot) < err(q_uni, hot)


def test_hessian_psd_and_symmetric():
    _, x, h = _data()
    assert jnp.allclose(h, h.T, atol=1e-3)
    eig = jnp.linalg.eigvalsh(h)
    assert float(eig.min()) > -1e-2


def test_e8_lattice_points_valid():
    y = jax.random.normal(jax.random.key(0), (64, 8)) * 2.0
    pts = e8_nearest(y)
    # E8 = D8 (integer, even sum) union D8 + 1/2
    frac = pts - jnp.floor(pts)
    is_int = jnp.all(jnp.isclose(frac, 0.0), axis=-1)
    is_half = jnp.all(jnp.isclose(frac, 0.5), axis=-1)
    assert bool(jnp.all(is_int | is_half))
    sums = jnp.sum(pts, axis=-1)
    assert bool(jnp.all(jnp.isclose(jnp.mod(sums, 2.0), 0.0) |
                        jnp.isclose(jnp.mod(sums, 2.0), 2.0)))


def test_ldlq_runs_and_reconstructs():
    w, x, h = _data(d_in=64, d_out=48)
    out = ldlq_quantize(w, h, block=32)
    base = float(jnp.mean((x @ w) ** 2))
    err = float(jnp.mean((x @ w - x @ out["w_deq"]) ** 2))
    assert err < base  # better than quantizing to zero
