"""Deliverable (f): per-assigned-arch reduced-config smoke tests — one
forward/train step on CPU asserting output shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import build_model


def _inputs(cfg, key, b=2, t=32):
    toks = jax.random.randint(key, (b, t), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["media"] = jax.random.normal(
            jax.random.fold_in(key, 1), (b, cfg.n_media_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 2), (b, t, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", list(ASSIGNED_ARCHS))
def test_smoke_forward_and_train_step(name):
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32")
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    batch = _inputs(cfg, jax.random.key(1))

    logits = model.logits(params, batch["tokens"],
                          media=batch.get("media"),
                          frames=batch.get("frames"))
    assert logits.shape == (*batch["tokens"].shape, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gnorm > 0 and not jnp.isnan(gnorm)


@pytest.mark.parametrize("name", ["qwen1.5-4b", "mamba2-780m",
                                  "deepseek-v2-236b", "jamba-v0.1-52b",
                                  "whisper-medium", "llama-3.2-vision-11b"])
def test_decode_matches_teacher_forcing(name):
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32",
                              capacity_factor=100.0)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    t = 24
    batch = _inputs(cfg, jax.random.key(1), b=2, t=t + 4)
    kw = {k: batch.get(k) for k in ("media", "frames")}
    full = model.logits(params, batch["tokens"], **kw)
    logits, cache = model.prefill(params, batch["tokens"][:, :t],
                                  cache_len=t + 4, **kw)
    assert float(jnp.abs(logits - full[:, t - 1]).max()) < 2e-3
    pos = t
    for i in range(2):
        logits, cache = model.decode_step(
            params, cache, batch["tokens"][:, t + i : t + i + 1],
            jnp.int32(pos))
        assert float(jnp.abs(logits - full[:, t + i]).max()) < 2e-3
        pos += 1
