import dataclasses
import warnings

import jax
import pytest

from repro.configs import get_config


def pytest_configure(config):
    # XLA:CPU cannot donate buffers across executions, so every
    # donate_argnums jit (fused calibration step, scan decode) emits
    # "Some donated buffers were not usable" on CPU test runs.  Donation
    # is a no-op there, not a bug — silence the known-harmless noise.
    warnings.filterwarnings(
        "ignore", message="Some donated buffers were not usable")
    config.addinivalue_line(
        "filterwarnings",
        "ignore:Some donated buffers were not usable")


@pytest.fixture(scope="session")
def tiny_cfg():
    return dataclasses.replace(
        get_config("llama3-8b").reduced(), dtype="float32",
        n_layers=2, d_model=64, vocab_size=256)


@pytest.fixture(scope="session")
def tiny_model_params(tiny_cfg):
    from repro.models import build_model

    model = build_model(tiny_cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    return model, params
