import dataclasses

import jax
import pytest

from repro.configs import get_config


@pytest.fixture(scope="session")
def tiny_cfg():
    return dataclasses.replace(
        get_config("llama3-8b").reduced(), dtype="float32",
        n_layers=2, d_model=64, vocab_size=256)


@pytest.fixture(scope="session")
def tiny_model_params(tiny_cfg):
    from repro.models import build_model

    model = build_model(tiny_cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    return model, params
