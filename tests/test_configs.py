"""Config registry + published parameter counts."""
import pytest

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, list_configs

EXPECTED_PARAMS_B = {
    "llama-3.2-vision-11b": (9.0, 11.5),
    "mamba2-780m": (0.7, 0.9),
    "minitron-4b": (4.0, 5.5),
    "command-r-plus-104b": (100.0, 108.0),
    "command-r-35b": (28.0, 36.0),
    "qwen1.5-4b": (3.5, 4.5),
    "whisper-medium": (0.7, 1.1),
    "deepseek-v2-236b": (230.0, 240.0),
    "deepseek-v3-671b": (665.0, 678.0),
    "jamba-v0.1-52b": (49.0, 53.0),
    "llama3-8b": (7.8, 8.3),
}


def test_registry_covers_all_assigned():
    assert len(ASSIGNED_ARCHS) == 10
    assert set(ASSIGNED_ARCHS) <= set(list_configs())
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}


@pytest.mark.parametrize("name", list(EXPECTED_PARAMS_B))
def test_param_counts_match_published(name):
    cfg = get_config(name)
    lo, hi = EXPECTED_PARAMS_B[name]
    n = cfg.n_params() / 1e9
    assert lo <= n <= hi, f"{name}: {n:.2f}B not in [{lo}, {hi}]"


@pytest.mark.parametrize("name", list(ASSIGNED_ARCHS))
def test_reduced_configs_are_small(name):
    r = get_config(name).reduced()
    assert r.n_params() < 5e7
    assert r.scan_period == get_config(name).scan_period or r.scan_period <= 4


def test_moe_active_params():
    v3 = get_config("deepseek-v3-671b")
    assert v3.n_params(active_only=True) / 1e9 < 40.0


def test_layer_patterns():
    jamba = get_config("jamba-v0.1-52b")
    kinds = jamba.layer_kinds()
    assert kinds.count("attn") == 4 and kinds.count("mamba") == 28
    ffns = jamba.ffn_kinds()
    assert ffns.count("moe") == 16
    vlm = get_config("llama-3.2-vision-11b")
    assert vlm.layer_kinds().count("cross") == 8
    v3 = get_config("deepseek-v3-671b")
    assert v3.ffn_kinds()[:3] == ("dense",) * 3
    assert v3.ffn_kinds().count("moe") == 58
