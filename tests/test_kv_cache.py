"""Quantized KV cache + flash decode: codecs, kernel parity, serving guards.

Pins the PR-7 contract end to end:
  * int8 (kv_bits=8) and packed 2-bit log (kv_bits=2) cache codecs —
    roundtrips, scale shapes, code monotonicity, chunk-leader updates;
  * the Pallas flash-decode kernel is bit-identical to the grouped-einsum
    ref on the same codes (GQA and MLA, both bit widths, edge positions)
    and both match a dense softmax-on-dequantized oracle;
  * serving never materializes the cache in fp: with kv_bits in {8, 2},
    ``generate`` runs with the debug materializers (``kv_dequantize`` /
    ``kv_log_decode``) monkeypatched to count — zero calls;
  * long-context (>= 2k cached tokens) fp-vs-quantized decode parity:
    prefill logits bit-identical (prefill attends in fp), kv8 greedy
    tokens match for several steps, kv2 stays directionally aligned;
  * the fake-8-device mesh leg: split-KV shard_map produces bit-identical
    greedy tokens to the meshless run with zero ref fallbacks.
"""
import dataclasses
import functools
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.flash_decode.ops as ops
from repro.configs import get_config
from repro.kernels.flash_decode.kernel import (flash_decode_pallas,
                                               mla_flash_decode_pallas)
from repro.kernels.flash_decode.ops import _s_tile
from repro.kernels.flash_decode.ref import (flash_decode_ref,
                                            mla_flash_decode_ref)
from repro.models import attention as att, build_model

REPO = Path(__file__).resolve().parent.parent


def _tiny(arch: str, kv_bits: int = 0):
    return dataclasses.replace(
        get_config(arch).reduced(), dtype="float32", n_layers=2,
        d_model=64, vocab_size=256, kv_bits=kv_bits)


def _randn(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# ------------------------------------------------------------------ codecs


def test_kv8_roundtrip_and_scale_shape():
    rng = np.random.default_rng(0)
    x = _randn(rng, 2, 96, 2, 16)
    codes, scales = att.kv_quantize(x)
    assert codes.shape == x.shape and codes.dtype == jnp.int8
    assert scales.shape == x.shape[:-1] and scales.dtype == jnp.bfloat16
    y = att.kv_dequantize(codes, scales, jnp.float32)
    amax = np.abs(np.asarray(x)).max(-1, keepdims=True)
    # half-step of the int8 grid plus the bf16 scale rounding
    assert float(jnp.max(jnp.abs(y - x) / amax)) < 1.0 / 127


def test_kv_pack_unpack_roundtrip_ragged():
    rng = np.random.default_rng(1)
    for d in (16, 17, 31, 48):
        codes = jnp.asarray(rng.integers(0, 4, size=(2, 70, d)), jnp.int32)
        words = att.kv_pack(codes)
        assert words.dtype == jnp.uint32
        assert words.shape == (2, 70, -(-d // 16))
        assert bool(jnp.all(att.kv_unpack(words, d) == codes))


def test_kv_log_codes_monotone_and_max():
    # levels (-1, -1/4, 1/4, 1): codes order by value, extremes saturate
    scale = jnp.full((1, 8, 1), 2.0, jnp.float32)
    x = jnp.linspace(-3.0, 3.0, 8, dtype=jnp.float32)[None, :, None]
    codes = att._kv_log_codes(x, scale[..., 0])
    seq = np.asarray(codes)[0, :, 0]
    assert (np.diff(seq) >= 0).all()
    assert seq.min() >= 0 and seq.max() <= 3
    assert seq[0] == 0 and seq[-1] == 3          # |x| > s/2 -> outer level
    near_zero = att._kv_log_codes(
        jnp.asarray([[[0.01], [-0.01]]], jnp.float32), scale[:, :2, 0])
    assert np.asarray(near_zero).ravel().tolist() == [2, 1]  # inner levels


def test_kv_log_roundtrip_levels_and_scale_shape():
    rng = np.random.default_rng(2)
    x = _randn(rng, 2, 130, 3, 16)
    scales = att.kv_log_scales(x, 64)
    assert scales.shape == (2, 3, 3) and scales.dtype == jnp.bfloat16
    packed = att.kv_log_encode(x, scales, 64)
    y = att.kv_log_decode(packed, scales, d=16, chunk=64)
    sx, sy = np.sign(np.asarray(x)), np.sign(np.asarray(y))
    assert (sx[sx != 0] == sy[sx != 0]).all()    # signs always survive
    # every decoded value is scale * level for a level in the log grid
    s_tok = np.repeat(np.asarray(scales, np.float32), 64, axis=1)[:, :130]
    ratio = np.abs(np.asarray(y)) / s_tok[..., None]
    assert np.allclose(np.minimum(np.abs(ratio - 0.25), np.abs(ratio - 1.0)),
                       0.0, atol=1e-2)


def test_kv_cache_update_chunk_leader():
    rng = np.random.default_rng(3)
    x = _randn(rng, 2, 192, 2, 16)
    codes, scales = att.kv_cache_quantize(x[:, :128], kv_bits=2, chunk=64)
    codes = jnp.concatenate([codes, jnp.zeros_like(codes[:, :64])], 1)
    scales = jnp.concatenate([scales, jnp.zeros_like(scales[:, :1])], 1)
    for t in range(128, 140):
        codes, scales = att.kv_cache_update(
            codes, scales, x[:, t:t + 1], jnp.int32(t), kv_bits=2, chunk=64)
    full_c, full_s = att.kv_cache_quantize(x, kv_bits=2, chunk=64)
    # prefill rows and whole-chunk scales are untouched by appends
    assert bool(jnp.all(codes[:, :128] == full_c[:, :128]))
    assert bool(jnp.all(scales[:, :2] == full_s[:, :2]))
    # the appended chunk's leader stamped its own amax as the scale
    lead = jnp.max(jnp.abs(x[:, 128].astype(jnp.float32)), -1)
    assert bool(jnp.all(scales[:, 2] == lead.astype(jnp.bfloat16)))
    # appended rows round-trip signs against the stamped scale
    y = att.kv_log_decode(codes, scales, d=16, chunk=64)[:, 128:140]
    sx = np.sign(np.asarray(x[:, 128:140]))
    assert (sx[sx != 0] == np.sign(np.asarray(y))[sx != 0]).all()


def test_model_rejects_unsupported_kv_bits():
    with pytest.raises(ValueError, match="kv_bits"):
        build_model(_tiny("llama3-8b", kv_bits=4))


# -------------------------------------------------- kernel == ref (bitwise)


@pytest.mark.parametrize("kv_bits,chunk", [(8, 1), (2, 64)])
@pytest.mark.parametrize("pos", [0, 150, 191])
def test_gqa_kernel_bitwise_matches_ref(kv_bits, chunk, pos):
    rng = np.random.default_rng(4)
    B, S, KV, G, DH = 2, 192, 2, 4, 16
    k, v = _randn(rng, B, S, KV, DH), _randn(rng, B, S, KV, DH)
    q = _randn(rng, B, KV, G, DH)
    kq, ks = att.kv_cache_quantize(k, kv_bits=kv_bits, chunk=chunk)
    vq, vs = att.kv_cache_quantize(v, kv_bits=kv_bits, chunk=chunk)
    p = jnp.full((1, 1), pos, jnp.int32)
    s_blk = _s_tile(S, chunk)
    kw = dict(kv_bits=kv_bits, chunk=chunk, dh=DH, dv=DH, s_blk=s_blk)
    a = flash_decode_pallas(q, kq, ks, vq, vs, p, **kw)
    b = flash_decode_ref(q, kq, ks, vq, vs, p, **kw)
    for x, y in zip(a, b):  # (acc, m, l) partials, bit-for-bit
        assert bool(jnp.all(x == y))


@pytest.mark.parametrize("kv_bits,chunk", [(8, 1), (2, 64)])
@pytest.mark.parametrize("pos", [0, 150, 191])
def test_mla_kernel_bitwise_matches_ref(kv_bits, chunk, pos):
    rng = np.random.default_rng(5)
    B, S, H, DL, DR = 2, 192, 4, 24, 8
    ql, qr = _randn(rng, B, H, DL), _randn(rng, B, H, DR)
    c, r = _randn(rng, B, S, DL), _randn(rng, B, S, DR)
    cq, cs = att.kv_cache_quantize(c, kv_bits=kv_bits, chunk=chunk)
    rq, rs = att.kv_cache_quantize(r, kv_bits=kv_bits, chunk=chunk)
    p = jnp.full((1, 1), pos, jnp.int32)
    kw = dict(kv_bits=kv_bits, chunk=chunk, dl=DL, dr=DR,
              s_blk=_s_tile(S, chunk))
    a = mla_flash_decode_pallas(ql, qr, cq, cs, rq, rs, p, **kw)
    b = mla_flash_decode_ref(ql, qr, cq, cs, rq, rs, p, **kw)
    for x, y in zip(a, b):
        assert bool(jnp.all(x == y))


@pytest.mark.parametrize("kv_bits,chunk", [(8, 1), (2, 64)])
def test_flash_decode_matches_dense_oracle(kv_bits, chunk):
    rng = np.random.default_rng(6)
    B, S, KV, G, DH, pos = 2, 192, 2, 4, 16, 150
    k, v = _randn(rng, B, S, KV, DH), _randn(rng, B, S, KV, DH)
    q = _randn(rng, B, KV, G, DH)
    kq, ks = att.kv_cache_quantize(k, kv_bits=kv_bits, chunk=chunk)
    vq, vs = att.kv_cache_quantize(v, kv_bits=kv_bits, chunk=chunk)
    if kv_bits == 8:
        kd = att.kv_dequantize(kq, ks, jnp.float32)
        vd = att.kv_dequantize(vq, vs, jnp.float32)
    else:
        kd = att.kv_log_decode(kq, ks, d=DH, chunk=chunk)
        vd = att.kv_log_decode(vq, vs, d=DH, chunk=chunk)
    s = jnp.einsum("bkgd,bskd->bkgs", q, kd.astype(jnp.float32))
    s = jnp.where(jnp.arange(S)[None, None, None, :] <= pos, s, -1e30)
    oracle = jnp.einsum("bkgs,bskd->bkgd", jax.nn.softmax(s, axis=-1),
                        vd.astype(jnp.float32))
    for use_kernel in (False, True):
        out = ops.flash_decode(q, kq, ks, vq, vs, jnp.int32(pos),
                               kv_bits=kv_bits, chunk=chunk, dv=DH,
                               use_kernel=use_kernel)
        # kv8's debug dequant multiplies in bf16; the kernel stays f32
        assert float(jnp.max(jnp.abs(out - oracle))) < (
            0.05 if kv_bits == 8 else 1e-4)


# ------------------------------------------------------- serving, no-fp pin


def test_cache_layout_dtypes_and_rounding():
    for arch, keys in (("llama3-8b", ("k", "ks", "v", "vs")),
                       ("deepseek-v2-236b", ("c", "cs", "r", "rs"))):
        for bits, code_dt, rows in ((8, jnp.int8, 100), (2, jnp.uint32, 2)):
            model = build_model(_tiny(arch, kv_bits=bits))
            assert model._cache_len(100) == 128  # rounds up to kv_chunk
            cache = jax.eval_shape(lambda m=model: m.init_cache(2, 100))
            entry = cache["groups"]["b0"]
            assert set(keys) <= set(entry)
            for key in keys:
                leaf = entry[key]
                want = code_dt if len(key) == 1 else jnp.bfloat16
                assert leaf.dtype == want, (arch, bits, key)
                n = leaf.shape[2]  # (n_groups, batch, rows, ...)
                assert n == (128 if len(key) == 1 else
                             128 if bits == 8 else rows), (arch, bits, key)


@pytest.mark.parametrize("arch,kv_bits", [("llama3-8b", 8), ("llama3-8b", 2),
                                          ("deepseek-v2-236b", 8),
                                          ("deepseek-v2-236b", 2)])
def test_generate_never_materializes_fp_cache(arch, kv_bits, monkeypatch):
    from repro.launch.serve import generate

    calls = []

    def wrap(tag, fn):
        return lambda *a, **k: (calls.append(tag), fn(*a, **k))[1]

    monkeypatch.setattr(att, "kv_dequantize",
                        wrap("kv_dequantize", att.kv_dequantize))
    monkeypatch.setattr(att, "kv_log_decode",
                        wrap("kv_log_decode", att.kv_log_decode))
    model = build_model(_tiny(arch, kv_bits=kv_bits))
    params = jax.jit(model.init)(jax.random.key(0))
    rng = np.random.default_rng(7)
    prompts = jnp.asarray(rng.integers(0, 256, size=(2, 33)), jnp.int32)
    toks = generate(model, params, prompts, 5)
    assert toks.shape == (2, 5)
    assert calls == []  # the cache is attended in codes, never in fp


# ------------------------------------------- long-context decode parity


@functools.lru_cache(maxsize=None)
def _long_ctx_logits(arch: str, t: int, n_steps: int = 3):
    """{kv_bits: [prefill_logits, step0, step1, ...]} greedy decode."""
    rng = np.random.default_rng(8)
    toks = jnp.asarray(rng.integers(0, 256, size=(2, t)), jnp.int32)
    out = {}
    for bits in (0, 8, 2):
        model = build_model(_tiny(arch, kv_bits=bits))
        params = jax.jit(model.init)(jax.random.key(0))
        logits, cache = jax.jit(
            lambda p, tk, m=model: m.prefill(p, tk, cache_len=t + n_steps)
        )(params, toks)
        seq = [logits]
        step = jax.jit(model.decode_step)
        for i in range(n_steps):
            tok = jnp.argmax(seq[-1], -1).astype(jnp.int32)[:, None]
            logits, cache = step(params, cache, tok, jnp.int32(t + i))
            seq.append(logits)
        out[bits] = seq
    return out


@pytest.mark.parametrize("arch,t", [("llama3-8b", 2040),
                                    ("deepseek-v2-236b", 2040),
                                    ("qwen1.5-4b", 120)])
def test_long_context_decode_parity(arch, t):
    out = _long_ctx_logits(arch, t)
    fp, kv8, kv2 = out[0], out[8], out[2]
    # prefill attends in fp: logits bit-identical for every kv_bits
    assert bool(jnp.all(fp[0] == kv8[0])) and bool(jnp.all(fp[0] == kv2[0]))
    # int8 KV: greedy tokens match step for step, logits stay tight
    for a, b in zip(fp[1:], kv8[1:]):
        assert bool(jnp.all(jnp.argmax(a, -1) == jnp.argmax(b, -1)))
        assert float(jnp.max(jnp.abs(a - b))) < 0.15
    # 2-bit KV is coarse (random-init weights, near-uniform logits): pin
    # directional alignment of the first decode step, not token identity
    a, b = fp[1], kv2[1]
    ac = a - a.mean(-1, keepdims=True)
    bc = b - b.mean(-1, keepdims=True)
    cos = jnp.sum(ac * bc, -1) / (jnp.linalg.norm(ac, axis=-1)
                                  * jnp.linalg.norm(bc, axis=-1))
    assert float(jnp.mean(cos)) > 0.5 and float(jnp.min(cos)) > 0.2


def test_long_context_cache_stays_quantized():
    # >= 2k cached tokens end to end through generate, cache dtypes pinned
    out = _long_ctx_logits("llama3-8b", 2040)
    assert len(out[8]) == 4  # prefill + 3 decode steps actually ran
    model = build_model(_tiny("llama3-8b", kv_bits=2))
    cache = jax.eval_shape(lambda: model.init_cache(1, 2048))
    entry = cache["groups"]["b0"]
    assert entry["k"].dtype == jnp.uint32 and entry["k"].shape[2] == 2048
    assert entry["ks"].dtype == jnp.bfloat16 and entry["ks"].shape[2] == 32


# ---------------------------------------------------- fake-8-device mesh


def _run(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(REPO / "src"), REPRO_FD_KERNEL="1")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=560,
                         env=env)
    assert out.returncode == 0, out.stderr
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_mesh_split_kv_zero_ref_fallbacks():
    """Aligned long-context decode on a (2, 4) mesh: the split-KV kernel
    serves every step (zero scan-ref fallbacks) and greedy tokens are
    bit-identical to the meshless run."""
    res = _run("""
        import dataclasses, json
        import numpy as np
        import jax, jax.numpy as jnp
        import repro.kernels.flash_decode.ops as ops
        from repro.configs import get_config
        from repro.models import build_model
        from repro.runtime.sharding import ParallelCtx
        from repro.launch.serve import generate

        calls = []
        for name in ("flash_decode_ref", "mla_flash_decode_ref"):
            orig = getattr(ops, name)
            setattr(ops, name, (lambda o: lambda *a, **k:
                                (calls.append(1), o(*a, **k))[1])(orig))

        cfg = dataclasses.replace(
            get_config("llama3-8b").reduced(), dtype="float32",
            n_layers=2, d_model=64, vocab_size=256, kv_bits=8)
        rng = np.random.default_rng(0)
        prompts = jnp.asarray(rng.integers(0, 256, size=(2, 1020)),
                              jnp.int32)
        model0 = build_model(cfg)
        params = jax.jit(model0.init)(jax.random.key(0))
        local = np.asarray(generate(model0, params, prompts, 4))
        n0 = len(calls)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx = ParallelCtx(mesh=mesh, dp=("data",), tp="model")
        meshed = np.asarray(generate(build_model(cfg, ctx), params,
                                     prompts, 4))
        print(json.dumps({
            "match": bool((local == meshed).all()),
            "ref_calls_local": n0,
            "ref_calls_mesh": len(calls) - n0,
        }))
    """)
    assert res["match"] is True
    assert res["ref_calls_local"] == 0  # REPRO_FD_KERNEL=1 forces the kernel
    assert res["ref_calls_mesh"] == 0   # aligned split-KV never demotes


def test_mesh_misaligned_takes_gspmd_ref():
    """A sequence the model axis can't split cleanly demotes to the
    GSPMD-partitionable scan ref — counted, and still correct."""
    res = _run("""
        import json
        import numpy as np
        import jax, jax.numpy as jnp
        import repro.kernels.flash_decode.ops as ops
        from repro.models import attention as att

        calls = []
        orig = ops.flash_decode_ref
        ops.flash_decode_ref = (
            lambda *a, **k: (calls.append(1), orig(*a, **k))[1])

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        B, S, KV, G, DH = 2, 192, 2, 4, 16  # s_loc = 48: chunk straddles
        k = jnp.asarray(rng.normal(size=(B, S, KV, DH)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, KV, DH)), jnp.float32)
        q = jnp.asarray(rng.normal(size=(B, KV, G, DH)), jnp.float32)
        kq, ks = att.kv_cache_quantize(k, kv_bits=2, chunk=64)
        vq, vs = att.kv_cache_quantize(v, kv_bits=2, chunk=64)
        args = (q, kq, ks, vq, vs, jnp.int32(100))
        kw = dict(kv_bits=2, chunk=64, dv=DH)
        ref = ops.flash_decode(*args, **kw, use_kernel=False)
        n0 = len(calls)
        out = ops.flash_decode(*args, **kw, mesh=mesh, axis="model",
                               dp="data")
        print(json.dumps({
            "ref_calls": len(calls) - n0,
            "maxdiff": float(jnp.max(jnp.abs(out - ref))),
        }))
    """)
    assert res["ref_calls"] == 1
    assert res["maxdiff"] < 1e-5
