"""Arrival traces for the engine: Poisson arrivals in scheduling-round
units, plus a driver that submits on schedule, records per-request
latency, sheds rejected submissions, and summarizes the run — including
the overload counters (preemptions / shed / deadline-expired / failed)
and per-status latency percentiles."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.serving.engine import EngineSaturated, RequestOutput


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    step: int       # scheduling round at which the request arrives
    request: object  # ServeRequest


def poisson_trace(requests, rate: float, seed: int = 0):
    """Poisson arrivals: exponential inter-arrival times at ``rate``
    requests per scheduling round (continuous arrival times floor to the
    round in which the engine first sees them)."""
    rng = np.random.default_rng(seed)
    t, events = 0.0, []
    for req in requests:
        t += rng.exponential(1.0 / rate)
        events.append(TraceEvent(step=int(t), request=req))
    return events


def _status_group(status: str) -> str:
    """Collapse ``preempted_N`` into one bucket; everything else is its
    own group (``ok`` / ``deadline_exceeded`` / ``shed`` / ``failed``)."""
    return "preempted" if status.startswith("preempted") else status


def run_trace(engine, trace):
    """Drive the engine through an arrival trace to completion.

    Submits each event at its scheduled round — a submission rejected by
    backpressure (:class:`EngineSaturated`) is recorded as a synthetic
    output with status ``shed`` (negative request id) rather than
    retried — then keeps stepping until everything drains.  Every
    submitted request ends in exactly one output with a definite status.

    Returns a summary dict: outputs (by request id), wall-clock p50/p99
    latency and time-to-first-token over the *completed* requests (status
    ``ok``/``preempted_*`` — shed and expired requests would skew the
    service-time percentiles), total emitted tokens, sustained tok/s,
    the engine's cumulative admission stall, the overload counters
    (``n_preemptions`` — preemption events, ``n_shed`` / ``n_deadline`` /
    ``n_failed`` — terminal statuses), a ``statuses`` histogram and
    ``per_status`` latency percentiles."""
    events = sorted(trace, key=lambda e: e.step)
    outputs, i, round_ix, n_shed = [], 0, 0, 0
    t0 = time.time()
    while i < len(events) or engine.busy:
        while i < len(events) and events[i].step <= round_ix:
            try:
                engine.submit(events[i].request)
            except EngineSaturated:
                n_shed += 1
                now = time.time()
                outputs.append(RequestOutput(
                    request_id=-n_shed,
                    tokens=[],
                    prompt_len=len(events[i].request.tokens),
                    submit_time=now, finish_time=now, status="shed"))
            i += 1
        outputs.extend(engine.step())
        round_ix += 1
    wall = time.time() - t0
    done = [o for o in outputs if o.finished_ok]
    lats = np.array([o.latency for o in done]) if done else np.zeros(1)
    ttfts = ([o.ttft for o in done if o.first_token_time > 0]
             or [o.ttft for o in outputs if o.first_token_time > 0])
    ttfts = np.array(ttfts) if ttfts else np.zeros(1)
    n_tok = sum(len(o.tokens) for o in outputs)
    statuses: dict = {}
    groups: dict = {}
    for o in outputs:
        statuses[o.status] = statuses.get(o.status, 0) + 1
        groups.setdefault(_status_group(o.status), []).append(o.latency)
    per_status = {
        g: {"n": len(ls),
            "p50_latency_s": float(np.percentile(ls, 50)),
            "p99_latency_s": float(np.percentile(ls, 99))}
        for g, ls in sorted(groups.items())}
    return {
        "outputs": {o.request_id: o for o in outputs},
        "n_requests": len(outputs),
        "n_tokens": n_tok,
        "wall_s": wall,
        "sustained_tok_s": n_tok / max(wall, 1e-9),
        "p50_latency_s": float(np.percentile(lats, 50)),
        "p99_latency_s": float(np.percentile(lats, 99)),
        "ttft_p50_s": float(np.percentile(ttfts, 50)),
        "ttft_p99_s": float(np.percentile(ttfts, 99)),
        "admission_stall_s": float(getattr(engine, "admission_stall_s", 0.0)),
        "rounds": round_ix,
        "n_preemptions": int(getattr(engine, "n_preemptions", 0)),
        "n_preempted_requests": sum(1 for o in outputs if o.n_preempted),
        "n_shed": statuses.get("shed", 0),
        "n_deadline": statuses.get("deadline_exceeded", 0),
        "n_failed": statuses.get("failed", 0),
        "statuses": statuses,
        "per_status": per_status,
    }
