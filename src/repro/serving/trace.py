"""Arrival traces for the engine: Poisson arrivals in scheduling-round
units, plus a driver that submits on schedule and records per-request
latency and sustained throughput."""
from __future__ import annotations

import dataclasses
import time

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    step: int       # scheduling round at which the request arrives
    request: object  # ServeRequest


def poisson_trace(requests, rate: float, seed: int = 0):
    """Poisson arrivals: exponential inter-arrival times at ``rate``
    requests per scheduling round (continuous arrival times floor to the
    round in which the engine first sees them)."""
    rng = np.random.default_rng(seed)
    t, events = 0.0, []
    for req in requests:
        t += rng.exponential(1.0 / rate)
        events.append(TraceEvent(step=int(t), request=req))
    return events


def run_trace(engine, trace):
    """Drive the engine through an arrival trace to completion.

    Submits each event at its scheduled round, then keeps stepping until
    everything drains (``engine.busy`` covers queued, *ingesting* — a
    chunked-prefill slot is live but not yet decoding — and decoding
    requests).  Returns a summary dict: outputs (by request id),
    wall-clock p50/p99 request latency and time-to-first-token, total
    emitted tokens, the sustained tok/s over the whole run (first submit
    -> last finish) and the engine's cumulative admission stall."""
    events = sorted(trace, key=lambda e: e.step)
    outputs, i, round_ix = [], 0, 0
    t0 = time.time()
    while i < len(events) or engine.busy:
        while i < len(events) and events[i].step <= round_ix:
            engine.submit(events[i].request)
            i += 1
        outputs.extend(engine.step())
        round_ix += 1
    wall = time.time() - t0
    lats = np.array([o.latency for o in outputs]) if outputs else np.zeros(1)
    ttfts = np.array([o.ttft for o in outputs]) if outputs else np.zeros(1)
    n_tok = sum(len(o.tokens) for o in outputs)
    return {
        "outputs": {o.request_id: o for o in outputs},
        "n_requests": len(outputs),
        "n_tokens": n_tok,
        "wall_s": wall,
        "sustained_tok_s": n_tok / max(wall, 1e-9),
        "p50_latency_s": float(np.percentile(lats, 50)),
        "p99_latency_s": float(np.percentile(lats, 99)),
        "ttft_p50_s": float(np.percentile(ttfts, 50)),
        "ttft_p99_s": float(np.percentile(ttfts, 99)),
        "admission_stall_s": float(getattr(engine, "admission_stall_s", 0.0)),
        "rounds": round_ix,
    }
