"""Block-paged quantized KV storage: shared code/scale pools, a
device-resident free-list allocator, and the prefill -> pages scatter.

Page size is the codec's ``page_tokens`` (= ``cfg.kv_chunk``), so a kv2
scale group never straddles a page — one page is exactly one flash-decode
tile and one scale row.  Every layer's pools are dimensioned by the same
``n_pages``; a single *logical* page allocation (one page id) addresses
that page in every layer at once, which is why one page table per request
serves the whole stack.

Page 0 is reserved as the trash page: inactive engine slots route their
(fixed-shape, unmasked) appends there, and unused page-table entries point
at it.  Tiles past a request's position are fully masked in the paged
kernels — exact no-ops of the streaming-softmax update — so trash/stale
table entries never perturb results.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("n",))
def _alloc(free, top, n: int):
    """Pop ``n`` page ids off the free stack.  ``free``: (capacity,) i32,
    ``top``: () i32 live count.  Host checks ``top >= n`` *before* calling
    (device-side slicing cannot raise)."""
    ids = jax.lax.dynamic_slice_in_dim(free, top - n, n)
    return top - jnp.int32(n), ids


@jax.jit
def _release(free, top, ids):
    """Push page ids back onto the free stack (LIFO — freshly retired
    pages are reused first, which is what the stale-page-reuse test
    leans on)."""
    free = jax.lax.dynamic_update_slice(free, ids.astype(jnp.int32), (top,))
    return free, top + jnp.int32(ids.shape[0])


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_prefill(pools, cache, ids):
    """Scatter one request's (batch-1) prefill cache into the pools.

    ``cache`` leaves are ``(n_groups, 1, S, ...)`` (group scan) or
    ``(1, S, ...)`` (prefix blocks) with S an exact multiple of the page
    row count of that leaf (codes: ``page`` rows; scales: ``page//chunk``
    rows — prefill already rounds to a page multiple); ``ids``: (n_pp,)
    i32 physical pages.  The codes move codes->codes: nothing is
    dequantized here."""
    n_pp = ids.shape[0]

    def scat_group(pool, c):
        per = c.shape[2] // n_pp
        chunked = c.reshape((c.shape[0], n_pp, per) + c.shape[3:])
        return pool.at[:, ids].set(chunked.astype(pool.dtype))

    def scat_prefix(pool, c):
        per = c.shape[1] // n_pp
        chunked = c.reshape((n_pp, per) + c.shape[2:])
        return pool.at[ids].set(chunked.astype(pool.dtype))

    new = {"groups": jax.tree.map(scat_group, pools["groups"],
                                  cache["groups"])}
    if "prefix" in pools:
        new["prefix"] = jax.tree.map(scat_prefix, pools["prefix"],
                                     cache["prefix"])
    return new


class PagedPools:
    """Shared paged KV pools + free-list allocator for one model.

    ``n_pages`` counts *allocatable* pages; one extra trash page (id 0)
    is always added on top.  ``alloc``/``release`` run on device against
    the free stack; only the exhaustion check reads the stack top back."""

    def __init__(self, model, n_pages: int):
        codec = model.codec
        if not codec.quantized:
            raise ValueError(
                "paged serving stores quantized codes — build the model "
                "with kv_bits=8 or kv_bits=2 (kv_bits=0 has no code/scale "
                "layout to page; use launch.serve.generate instead)")
        self.model = model
        self.codec = codec
        self.page = codec.page_tokens
        self.n_pages = n_pages
        cache = jax.eval_shape(lambda: model.init_cache(1, self.page))
        total = n_pages + 1  # + trash page 0
        self.pools = {"groups": jax.tree.map(
            lambda s: jnp.zeros((s.shape[0], total) + s.shape[2:], s.dtype),
            cache["groups"])}
        if "prefix" in cache:
            self.pools["prefix"] = jax.tree.map(
                lambda s: jnp.zeros((total,) + s.shape[1:], s.dtype),
                cache["prefix"])
        self.free = jnp.arange(1, total, dtype=jnp.int32)
        self.top = jnp.int32(n_pages)
        # host-side mirror of the allocated set: preemption makes
        # page-accounting bugs (double release, leaked reservations) easy
        # to write, so every alloc/release is cross-checked here and a
        # violation raises PageAccountingError instead of silently
        # corrupting the device free stack
        self._live: set[int] = set()

    def free_pages(self) -> int:
        return int(self.top)

    def occupancy(self) -> float:
        """Live fraction of the pool (0.0 empty .. 1.0 full) — the
        watermark signal the engine's admission control reads."""
        return 1.0 - self.free_pages() / self.n_pages

    def resident_bytes(self) -> int:
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(self.pools))

    def sizing(self, prompt_len: int, max_new: int) -> str:
        """One sentence of request sizing math — the single source of the
        text shared by ``Engine.submit``'s fail-fast / page-table errors
        and the allocator's exhaustion error (they used to duplicate
        it)."""
        need = -(-(prompt_len + max_new) // self.page)
        return (f"{prompt_len} prompt + {max_new} new tokens at "
                f"{self.page}/page = {need} pages")

    def exhausted(self, n: int, *, context: str = "",
                  have: int | None = None,
                  retry_after_s: float | None = None
                  ) -> "PageAllocatorExhausted":
        """Build the actionable sizing error for an allocation of ``n``
        pages that cannot be satisfied — shared by ``alloc`` (runtime
        exhaustion) and ``Engine.submit`` (fail-fast on requests that can
        never fit, where ``have`` is the pool capacity).  The message
        always carries the live occupancy; the engine passes a
        ``retry_after_s`` hint when retirements will free pages."""
        have = self.free_pages() if have is None else have
        occ = 1.0 - have / self.n_pages
        hint = (f"  Retry after ~{retry_after_s:.2f}s."
                if retry_after_s is not None else "")
        err = PageAllocatorExhausted(
            f"page allocator exhausted{context}: need {n} pages, "
            f"{have} of {self.n_pages} free (occupancy "
            f"{occ:.0%}, page = {self.page} "
            f"tokens).  Retire requests, raise n_pages (one page is "
            f"~{self.page_bytes() / 1e3:.1f}KB across all layers), or "
            f"lower max_new_tokens/prompt lengths.{hint}")
        err.need, err.have, err.occupancy = n, have, occ
        err.retry_after_s = retry_after_s
        return err

    def alloc(self, n: int, *, context: str = "") -> jax.Array:
        """Reserve ``n`` pages; raises with the actionable sizing math on
        exhaustion (the caller retires requests to make progress)."""
        if n > self.free_pages():
            raise self.exhausted(n, context=context)
        self.top, ids = _alloc(self.free, self.top, n)
        for i in np.asarray(ids).tolist():
            if i in self._live or i == 0:  # pragma: no cover - drift guard
                raise PageAccountingError(
                    f"allocator handed out page {i} which is "
                    f"{'the trash page' if i == 0 else 'already live'} — "
                    "free-stack accounting has drifted")
            self._live.add(i)
        return ids

    def release(self, ids) -> None:
        if len(ids) == 0:
            return
        ids_host = np.asarray(ids).tolist()
        for i in ids_host:
            if i == 0:
                raise PageAccountingError(
                    "attempt to release the reserved trash page (id 0)")
            if i not in self._live:
                raise PageAccountingError(
                    f"double free: page {i} is not live "
                    f"({self.free_pages()} of {self.n_pages} already free) "
                    "— releasing a free page would alias it across "
                    "requests on the next alloc")
        if len(set(ids_host)) != len(ids_host):
            raise PageAccountingError(
                f"duplicate page ids in one release: {sorted(ids_host)}")
        self._live.difference_update(ids_host)
        self.free, self.top = _release(self.free, self.top,
                                       jnp.asarray(ids, jnp.int32))

    def assert_quiescent(self) -> None:
        """Every allocated page is back on the free stack — ``Engine.
        drain()`` calls this after the last retirement so any page leak
        (or double count) fails loudly at the end of every drain, not as
        mysterious exhaustion three traces later."""
        if self._live or self.free_pages() != self.n_pages:
            live = sorted(self._live)
            tail = "..." if len(live) > 16 else ""
            raise PageAccountingError(
                f"page leak after drain: {self.free_pages()} of "
                f"{self.n_pages} pages free, {len(live)} still marked "
                f"live: {live[:16]}{tail}")

    def page_bytes(self) -> int:
        return self.resident_bytes() // (self.n_pages + 1)

    def write_prefill(self, cache, ids) -> None:
        """Scatter a batch-1 prefill cache into pages ``ids`` (only the
        first ``ceil(S/page)`` of a request's reservation; growth pages
        stay zero until decode appends into them)."""
        self.pools = _scatter_prefill(self.pools, cache, ids)


class PageAllocatorExhausted(RuntimeError):
    """Pool cannot satisfy an allocation; carries ``need`` / ``have`` /
    ``occupancy`` / ``retry_after_s`` fields for programmatic callers."""


class PageAccountingError(RuntimeError):
    """Double free, trash-page release, or a post-drain page leak — the
    free stack no longer matches the set of pages handed out, which would
    alias pages across live requests on a later alloc."""
