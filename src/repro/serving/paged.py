"""Block-paged quantized KV storage: shared code/scale pools, a
device-resident free-list allocator, and the prefill -> pages scatter.

Page size is the codec's ``page_tokens`` (= ``cfg.kv_chunk``), so a kv2
scale group never straddles a page — one page is exactly one flash-decode
tile and one scale row.  Every layer's pools are dimensioned by the same
``n_pages``; a single *logical* page allocation (one page id) addresses
that page in every layer at once, which is why one page table per request
serves the whole stack.

Page 0 is reserved as the trash page: inactive engine slots route their
(fixed-shape, unmasked) appends there, and unused page-table entries point
at it.  Tiles past a request's position are fully masked in the paged
kernels — exact no-ops of the streaming-softmax update — so trash/stale
table entries never perturb results.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("n",))
def _alloc(free, top, n: int):
    """Pop ``n`` page ids off the free stack.  ``free``: (capacity,) i32,
    ``top``: () i32 live count.  Host checks ``top >= n`` *before* calling
    (device-side slicing cannot raise)."""
    ids = jax.lax.dynamic_slice_in_dim(free, top - n, n)
    return top - jnp.int32(n), ids


@jax.jit
def _release(free, top, ids):
    """Push page ids back onto the free stack (LIFO — freshly retired
    pages are reused first, which is what the stale-page-reuse test
    leans on)."""
    free = jax.lax.dynamic_update_slice(free, ids.astype(jnp.int32), (top,))
    return free, top + jnp.int32(ids.shape[0])


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_prefill(pools, cache, ids):
    """Scatter one request's (batch-1) prefill cache into the pools.

    ``cache`` leaves are ``(n_groups, 1, S, ...)`` (group scan) or
    ``(1, S, ...)`` (prefix blocks) with S an exact multiple of the page
    row count of that leaf (codes: ``page`` rows; scales: ``page//chunk``
    rows — prefill already rounds to a page multiple); ``ids``: (n_pp,)
    i32 physical pages.  The codes move codes->codes: nothing is
    dequantized here."""
    n_pp = ids.shape[0]

    def scat_group(pool, c):
        per = c.shape[2] // n_pp
        chunked = c.reshape((c.shape[0], n_pp, per) + c.shape[3:])
        return pool.at[:, ids].set(chunked.astype(pool.dtype))

    def scat_prefix(pool, c):
        per = c.shape[1] // n_pp
        chunked = c.reshape((n_pp, per) + c.shape[2:])
        return pool.at[ids].set(chunked.astype(pool.dtype))

    new = {"groups": jax.tree.map(scat_group, pools["groups"],
                                  cache["groups"])}
    if "prefix" in pools:
        new["prefix"] = jax.tree.map(scat_prefix, pools["prefix"],
                                     cache["prefix"])
    return new


class PagedPools:
    """Shared paged KV pools + free-list allocator for one model.

    ``n_pages`` counts *allocatable* pages; one extra trash page (id 0)
    is always added on top.  ``alloc``/``release`` run on device against
    the free stack; only the exhaustion check reads the stack top back."""

    def __init__(self, model, n_pages: int):
        codec = model.codec
        if not codec.quantized:
            raise ValueError(
                "paged serving stores quantized codes — build the model "
                "with kv_bits=8 or kv_bits=2 (kv_bits=0 has no code/scale "
                "layout to page; use launch.serve.generate instead)")
        self.model = model
        self.codec = codec
        self.page = codec.page_tokens
        self.n_pages = n_pages
        cache = jax.eval_shape(lambda: model.init_cache(1, self.page))
        total = n_pages + 1  # + trash page 0
        self.pools = {"groups": jax.tree.map(
            lambda s: jnp.zeros((s.shape[0], total) + s.shape[2:], s.dtype),
            cache["groups"])}
        if "prefix" in cache:
            self.pools["prefix"] = jax.tree.map(
                lambda s: jnp.zeros((total,) + s.shape[1:], s.dtype),
                cache["prefix"])
        self.free = jnp.arange(1, total, dtype=jnp.int32)
        self.top = jnp.int32(n_pages)

    def free_pages(self) -> int:
        return int(self.top)

    def resident_bytes(self) -> int:
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(self.pools))

    def exhausted(self, n: int, *, context: str = "",
                  have: int | None = None) -> "PageAllocatorExhausted":
        """Build the actionable sizing error for an allocation of ``n``
        pages that cannot be satisfied — shared by ``alloc`` (runtime
        exhaustion) and ``Engine.submit`` (fail-fast on requests that can
        never fit, where ``have`` is the pool capacity)."""
        have = self.free_pages() if have is None else have
        return PageAllocatorExhausted(
            f"page allocator exhausted{context}: need {n} pages, "
            f"{have} of {self.n_pages} free (page = {self.page} "
            f"tokens).  Retire requests, raise n_pages (one page is "
            f"~{self.page_bytes() / 1e3:.1f}KB across all layers), or "
            f"lower max_new_tokens/prompt lengths.")

    def alloc(self, n: int, *, context: str = "") -> jax.Array:
        """Reserve ``n`` pages; raises with the actionable sizing math on
        exhaustion (the caller retires requests to make progress)."""
        if n > self.free_pages():
            raise self.exhausted(n, context=context)
        self.top, ids = _alloc(self.free, self.top, n)
        return ids

    def release(self, ids) -> None:
        if len(ids) == 0:
            return
        self.free, self.top = _release(self.free, self.top,
                                       jnp.asarray(ids, jnp.int32))

    def page_bytes(self) -> int:
        return self.resident_bytes() // (self.n_pages + 1)

    def write_prefill(self, cache, ids) -> None:
        """Scatter a batch-1 prefill cache into pages ``ids`` (only the
        first ``ceil(S/page)`` of a request's reservation; growth pages
        stay zero until decode appends into them)."""
        self.pools = _scatter_prefill(self.pools, cache, ids)


class PageAllocatorExhausted(RuntimeError):
    pass
