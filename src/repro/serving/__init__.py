from repro.serving.engine import (  # noqa: F401
    Engine,
    RequestOutput,
    SamplingParams,
    ServeRequest,
)
from repro.serving.paged import PagedPools  # noqa: F401
from repro.serving.trace import poisson_trace, run_trace  # noqa: F401
