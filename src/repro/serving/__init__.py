from repro.serving.engine import (  # noqa: F401
    Engine,
    EngineSaturated,
    EngineStuck,
    RequestOutput,
    SamplingParams,
    ServeRequest,
)
from repro.serving.paged import (  # noqa: F401
    PageAccountingError,
    PageAllocatorExhausted,
    PagedPools,
)
from repro.serving.trace import poisson_trace, run_trace  # noqa: F401
