"""Continuous-batching serve engine over block-paged quantized KV pools.

Requests arrive (``submit``), prefill into freshly allocated pages, join
the running decode batch at the next scheduling round (``step``), and
retire as soon as they hit EOS or their token budget — releasing their
pages for the next admission.  Decode runs in *bursts*: a jitted
``lax.scan`` of ``burst_steps`` paged decode steps whose carry holds every
slot's token / position / emitted-count / liveness, so the host only
intervenes at scheduling rounds, exactly like the fixed-batch scan loop
of ``launch.serve``.

Determinism contract (pinned by tests/test_serving.py): a request's
tokens are bitwise the ones ``launch.serve.generate`` produces for the
same prompt alone at batch 1 with the same ``SamplingParams`` — the
engine replicates its sampling stream exactly (token ``j`` is drawn with
``fold_in(key(seed), j)``; token 0 comes from the prefill logits) and the
paged attention matches the flat cache bitwise at tile = page.  The one
structural exception is MoE models, where expert-capacity dropping
couples tokens across the batch (true of any batched serving, the
fixed-batch loop included).

Admission policy: pages for the *whole* request (prompt + max_new_tokens,
rounded up to whole pages) are reserved at admission — a running request
can never hit the allocator mid-flight.  With ``prefill_chunk=N`` the
prompt is ingested chunk by chunk, one chunk per scheduling round per
ingesting slot, interleaved with decode bursts (see serving/README.md).

Overload policy (this module's degradation story, see serving/README.md
"Overload policy"):

* **Preemption-and-requeue** — when the head-of-queue request cannot be
  admitted (pages, or a slot held by strictly-lower priority), the
  scheduler preempts the lowest-priority / youngest eligible running
  request: releases its pages, records its emitted tokens, requeues it.
  On re-admission the prompt is re-ingested through the exact prefill
  path (rebuilding its KV pages bitwise) and the already-emitted tokens
  are *replayed* through teacher-forced decode steps inside the normal
  burst — each replayed step reproduces the original step's inputs and
  cache bits, so the resumed ``fold_in(key(seed), j)`` sampling stream
  continues bit-identically to an unpreempted run.
* **Deadlines / priority** — ``SamplingParams.deadline_s`` retires
  expired requests (queued or running) with status ``deadline_exceeded``;
  ``priority`` orders admission and bounds who may be preempted.
* **Backpressure** — ``queue_depth`` / ``admit_watermark`` bound the
  queue; a rejected ``submit`` raises :class:`EngineSaturated` with a
  retry-after hint and the pool occupancy instead of queueing unbounded.
* **Fault injection + watchdog** — ``fault_plan`` arms
  ``(round, stage in runtime.fault.SERVE_STAGES)`` failure points; a
  failed burst retries per ``RetryPolicy`` (state is untouched when a
  stage point fires, so the retry re-runs from identical inputs), a
  poisoned request is isolated with status ``failed``, and a stuck-round
  watchdog emits structured events before raising :class:`EngineStuck`.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.fault import EventLog, RetryPolicy
from repro.serving.paged import PagedPools


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling: greedy at ``temperature == 0``, categorical
    over ``logits / temperature`` otherwise, keyed by ``seed`` (the same
    stream ``launch.serve.generate`` draws for ``key(seed)``).
    ``eos_token`` stops generation early when sampled (-1: never).

    ``priority`` orders admission (higher first; FIFO within a level) and
    bounds preemption — a request only ever preempts strictly-lower
    priority for a slot, lower-or-equal-but-younger for pages.
    ``deadline_s`` (0: none) retires the request with status
    ``deadline_exceeded`` once that many seconds have passed since
    ``submit``, whether it is still queued or already decoding."""
    temperature: float = 0.0
    seed: int = 0
    eos_token: int = -1
    priority: int = 0
    deadline_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One generation request: prompt token ids + a token budget + its
    sampling params.  The single request type shared by the engine, the
    CLI and ``generate_batch``."""
    tokens: tuple
    max_new_tokens: int
    sampling: SamplingParams = SamplingParams()

    def __post_init__(self):
        object.__setattr__(self, "tokens", tuple(int(t) for t in self.tokens))
        if not self.tokens:
            raise ValueError("ServeRequest needs at least one prompt token")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}")


@dataclasses.dataclass
class RequestOutput:
    """Terminal record for one request.  ``status`` is always definite:
    ``ok`` (finished clean, never preempted), ``preempted_N`` (finished
    clean after N preemptions — tokens still bit-identical to a solo
    run), ``deadline_exceeded`` (partial tokens), ``failed`` (isolated by
    a fault), or ``shed`` (rejected at submit; synthesized by
    ``run_trace``, never by the engine itself)."""
    request_id: int
    tokens: list
    prompt_len: int
    submit_time: float
    finish_time: float
    first_token_time: float = 0.0
    status: str = "ok"
    n_preempted: int = 0

    @property
    def latency(self) -> float:
        return self.finish_time - self.submit_time

    @property
    def ttft(self) -> float:
        """Time to first token: submit -> the round that sampled token 0
        from the (last chunk of the) prefill."""
        return self.first_token_time - self.submit_time

    @property
    def finished_ok(self) -> bool:
        """Full budget / EOS reached (possibly after preemptions)."""
        return self.status == "ok" or self.status.startswith("preempted")


class EngineSaturated(RuntimeError):
    """``submit`` rejected by backpressure: the bounded queue (or the
    demand watermark) is full.  Carries ``retry_after_s`` (hint from the
    engine's service-time estimate), ``occupancy`` (live page fraction)
    and ``queued`` for programmatic callers; ``run_trace`` records such
    requests with status ``shed``."""


class EngineStuck(RuntimeError):
    """The watchdog saw no progress for twice its round budget while the
    engine was still busy — raised so a wedged engine fails loudly
    instead of hanging ``drain()`` forever."""


@dataclasses.dataclass
class _QueueEntry:
    """One queued (or preempted-and-requeued) request.  ``resume`` is
    None for a fresh submission, else the tokens already emitted before
    preemption (>= 1: token 0 was drawn at the original admission)."""
    rid: int
    req: ServeRequest
    resume: Optional[list] = None

    @property
    def key(self):
        # admission order: highest priority first, then FIFO by rid — a
        # preempted request keeps its original rid, so it re-enters ahead
        # of same-priority requests submitted after it
        return (-self.req.sampling.priority, self.rid)


@functools.lru_cache(maxsize=64)
def _prefill_fn(model, cache_len: int):
    return jax.jit(lambda p, x: model.prefill(p, x, cache_len=cache_len))


@functools.lru_cache(maxsize=64)
def _resume_prefill_fn(model, cache_len: int):
    """Prompt re-ingest for a preempted request: same prefill float ops
    (so the rebuilt KV pages are bitwise the originals) minus the head
    projection — token 0 was already drawn before preemption."""
    return jax.jit(lambda p, x: model.prefill(p, x, cache_len=cache_len,
                                              logits=False)[1])


@functools.lru_cache(maxsize=64)
def _extend_fn(model, t_total: int, last: bool):
    """One exact-mode chunk step: fp prefix buffers donated through."""
    return jax.jit(
        lambda p, x, start, state: model.paged_extend_step(
            p, x, start, state, t_total=t_total, last=last),
        donate_argnums=(3,))


@functools.lru_cache(maxsize=64)
def _extend_paged_fn(model, t_total: int, last: bool):
    """One paged-mode chunk step: reads the request's quantized pages."""
    def run(p, x, start, pools, tbl):
        logits, _, cc = model.paged_extend_step(
            p, x, start, None, t_total=t_total, last=last, pools=pools,
            page_tbl=tbl)
        return logits, cc
    return jax.jit(run)


@functools.lru_cache(maxsize=16)
def _burst_fn(model, n_steps: int):
    """One jitted program for a scheduling round: ``n_steps`` paged decode
    steps with per-slot sampling state in the scan carry, pools donated.

    Emits ``(toks, emitted)`` per step; slots deactivate in-carry on EOS /
    budget so a retired-mid-burst slot stops emitting (and its appends
    divert to the trash page) without any host round-trip.

    ``forced``/``fmask`` (n_steps, B) teacher-force the emitted token at
    masked steps — the preemption-resume replay: a replayed step feeds the
    same input token at the same position into the same cache bits as the
    original run, so its KV append (and every later logit) is bitwise the
    original; the sampling stream is untouched (``fold_in`` is stateless
    per step) and resumes exactly at the first unmasked step."""

    def run(params, pools, tbl, tok, pos, nem, act, temp, seeds, eos,
            max_new, forced, fmask):
        keys = jax.vmap(jax.random.key)(seeds)
        safe_temp = jnp.where(temp > 0, temp, 1.0)

        def sample_one(key, nem_i, logits_i, temp_i):
            sub = jax.random.fold_in(key, nem_i)
            return jax.random.categorical(
                sub, logits_i[None] / temp_i, axis=-1).astype(jnp.int32)[0]

        def body(carry, xs):
            f, m = xs
            pools, tok, pos, nem, act = carry
            logits, pools = model.paged_decode_step(params, pools, tbl, tok,
                                                    pos, act)
            sampled = jax.vmap(sample_one)(keys, nem, logits, safe_temp)
            greedy = jnp.argmax(logits, -1).astype(jnp.int32)
            nxt = jnp.where(temp > 0, sampled, greedy)
            nxt = jnp.where(m, f, nxt)  # replayed step: teacher-forced
            emitted = act
            nem2 = nem + act.astype(jnp.int32)
            done = act & ((nxt == eos) | (nem2 >= max_new))
            return (pools, nxt[:, None], pos + act.astype(jnp.int32), nem2,
                    act & ~done), (jnp.where(act, nxt, -1), emitted)

        (pools, tok, pos, nem, act), (toks, em) = jax.lax.scan(
            body, (pools, tok, pos, nem, act), (forced, fmask),
            length=n_steps)
        return pools, tok, pos, nem, act, toks, em

    return jax.jit(run, donate_argnums=(1,))


class Engine:
    """Continuous-batching engine: ``submit()`` requests, drive scheduling
    rounds with ``step()`` (or ``drain()`` to completion); each round
    expires deadlines, admits queued requests (preempting if the head
    cannot fit), advances prompt ingestion, runs one decode burst for
    every live slot at once, and retires the finished."""

    def __init__(self, model, params, *, max_slots: int = 4,
                 n_pages: int = 64, max_pages_per_request: int = 8,
                 burst_steps: int = 8, prefill_chunk: Optional[int] = None,
                 prefill_attn: str = "exact",
                 queue_depth: Optional[int] = None,
                 admit_watermark: Optional[float] = None,
                 fault_plan=None, retry: Optional[RetryPolicy] = None,
                 watchdog_rounds: int = 256, on_event=None):
        cfg = model.cfg
        metas = tuple(model.prefix_metas) + tuple(model.group_metas)
        bad = sorted({m.mixer for m in metas} - {"attn", "mla"})
        if bad:
            raise ValueError(
                f"paged serving supports attn/mla mixers, model has {bad} "
                "— ssm/cross-attention state is per-slot, not per-page; "
                "serve such models through launch.serve.generate")
        if any(m.has_cross for m in metas) or cfg.family == "encdec":
            raise ValueError(
                "paged serving does not support cross-attention caches "
                "(media/encoder KV is request-global, not paged); use "
                "launch.serve.generate")
        if getattr(model.ctx, "enabled", False):
            raise ValueError(
                "the engine is meshless — it owns the batch axis and the "
                "paged kernels take no shard_map route; build the model "
                "with the LOCAL ctx for serving")
        if prefill_attn not in ("exact", "paged"):
            raise ValueError(
                f"prefill_attn must be 'exact' or 'paged', got "
                f"{prefill_attn!r}")
        self.model = model
        self.params = params
        self.pools = PagedPools(model, n_pages)  # validates kv_bits
        self.page = self.pools.page
        self.max_slots = max_slots
        self.max_pages = max_pages_per_request
        self.burst_steps = burst_steps
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                raise ValueError(
                    f"prefill_chunk must be >= 1, got {prefill_chunk}")
            # chunk boundaries must be page-aligned (a kv2 scale group
            # never straddles a chunk, and chunk pages scatter whole)
            prefill_chunk = -(-prefill_chunk // self.page) * self.page
        self.prefill_chunk = prefill_chunk
        self.prefill_attn = prefill_attn
        self.queue_depth = queue_depth
        self.admit_watermark = admit_watermark
        self.fault_plan = fault_plan
        self.retry = retry if retry is not None else RetryPolicy()
        self.watchdog_rounds = watchdog_rounds
        self.events = EventLog(on_event, verbose=False)
        self._now = time.time  # monkeypatchable clock (deadline tests)

        # per-slot scheduling state lives on the HOST: admission writes a
        # handful of scalars per request, and as numpy rows that is free —
        # as device arrays it was ~10 tiny dispatches per admission, a
        # measurable slice of small-model serving time.  The burst uploads
        # the (tiny) state with its dispatch and the results mirror back.
        b = max_slots
        self.tbl = np.zeros((b, self.max_pages), np.int32)
        self.tok = np.zeros((b, 1), np.int32)
        self.pos = np.zeros((b,), np.int32)
        self.nem = np.zeros((b,), np.int32)
        self.act = np.zeros((b,), bool)
        self.temp = np.zeros((b,), np.float32)
        self.seeds = np.zeros((b,), np.uint32)
        self.eos = np.full((b,), -1, np.int32)
        self.max_new = np.ones((b,), np.int32)

        self._queue: list[_QueueEntry] = []
        self._next_rid = 0
        self._slot_rid = [None] * b          # rid occupying each slot
        self._slot_pages = [None] * b        # np page ids of each slot
        self._slot_tokens = [None] * b       # emitted tokens (host)
        self._slot_req = [None] * b
        self._ingest = [None] * b            # chunked-prefill progress
        self._replay = [None] * b            # forced tokens left to replay
        self._slot_base = [0] * b            # tokens held at admission
        self._submit_time = {}
        self._first_token_time = {}
        self._n_preempted = {}               # rid -> preemption count
        self._outputs = []
        self._round = 0
        self._idle_rounds = 0
        self._progress = False
        self._service_ema = None             # EMA of completed latency
        self.n_preemptions = 0
        self.admission_stall_s = 0.0

    # ------------------------------------------------------------------ API
    def submit(self, request: ServeRequest) -> int:
        """Queue a request; returns its id.  Admission happens at the next
        ``step()``.  Requests that can never fit are rejected here, and
        backpressure (``queue_depth`` / ``admit_watermark``) rejects with
        :class:`EngineSaturated` + a retry-after hint instead of queueing
        unbounded."""
        need = self._pages_for(request)
        sizing = self.pools.sizing(len(request.tokens),
                                   request.max_new_tokens)
        if need > self.max_pages:
            raise ValueError(
                f"request needs {sizing} but the page table holds "
                f"{self.max_pages} per request — raise "
                "max_pages_per_request or split the request")
        if need > self.pools.n_pages:
            # fail fast with the allocator's own sizing math: this request
            # can never fit even an empty pool, so queueing it would only
            # defer the same failure to admission time
            raise self.pools.exhausted(
                need, have=self.pools.n_pages,
                context=f" (submit: {sizing} can never fit)")
        queued = len(self._queue)
        if self.queue_depth is not None and queued >= self.queue_depth:
            occ, hint = self.pools.occupancy(), self._retry_after()
            raise self._saturated(
                f"engine saturated: {queued} queued at queue_depth="
                f"{self.queue_depth}, pool occupancy {occ:.0%} — "
                f"retry after ~{hint:.2f}s", hint, occ, queued)
        if self.admit_watermark is not None:
            cap = self.admit_watermark * self.pools.n_pages
            demand = ((self.pools.n_pages - self.pools.free_pages())
                      + sum(self._pages_for(e.req) for e in self._queue)
                      + need)
            if demand > cap:
                occ, hint = self.pools.occupancy(), self._retry_after()
                raise self._saturated(
                    f"engine saturated: outstanding demand of {demand} "
                    f"pages exceeds the admit watermark ({cap:.0f} = "
                    f"{self.admit_watermark:g} x {self.pools.n_pages} "
                    f"pages), pool occupancy {occ:.0%} — retry after "
                    f"~{hint:.2f}s", hint, occ, queued)
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(_QueueEntry(rid, request))
        self._submit_time[rid] = self._now()
        return rid

    def load(self) -> dict:
        """Live occupancy snapshot (the watermark signal): free pages,
        pool occupancy, queued / running request counts."""
        return {"free_pages": self.pools.free_pages(),
                "occupancy": self.pools.occupancy(),
                "queued": len(self._queue),
                "running": sum(r is not None for r in self._slot_rid)}

    def step(self) -> list:
        """One scheduling round: expire deadlines, admit queued requests
        into free slots (preempting when the head cannot fit), advance
        every ingesting slot by one prompt chunk, run one decode burst
        over the live batch, retire the finished.  Returns every request
        that reached a terminal status this round."""
        self._round += 1
        self._progress = False
        outs = self._expire_deadlines()
        t0 = time.time()
        self._admit(outs)
        self._advance_ingest(outs)
        self.admission_stall_s += time.time() - t0
        if self.act.any():
            self._burst_guarded(outs)
        outs.extend(self._retire_guarded())
        self._watchdog()
        return outs

    @property
    def busy(self) -> bool:
        """True while any request is queued, ingesting, decoding, or
        finished but not yet retired (a retire-stage fault defers
        retirement by one round)."""
        return (bool(self._queue) or bool(self.act.any())
                or any(r is not None for r in self._slot_rid))

    def drain(self) -> list:
        """Run ``step()`` until every submitted request has finished, then
        verify the page free list is back to its initial size — any page
        leaked (or double-counted) by admission/preemption/retirement
        fails loudly here rather than as mysterious exhaustion later."""
        out = []
        while self.busy:
            out.extend(self.step())
        self.pools.assert_quiescent()
        return out

    # ------------------------------------------------------------ internals
    def _pages_for(self, req: ServeRequest) -> int:
        return -(-(len(req.tokens) + req.max_new_tokens) // self.page)

    def _saturated(self, msg: str, hint: float, occ: float,
                   queued: int) -> EngineSaturated:
        err = EngineSaturated(msg)
        err.retry_after_s, err.occupancy, err.queued = hint, occ, queued
        return err

    def _retry_after(self) -> float:
        """Back-of-envelope retry hint: expected service time per request
        (EMA of completed latencies, 100ms floor before any completion)
        times queue-ahead-of-you, divided across the slots."""
        ema = self._service_ema if self._service_ema is not None else 0.1
        return ema * (len(self._queue) + 1) / self.max_slots

    def _check_fault(self, stage: str) -> None:
        if self.fault_plan is not None:
            self.fault_plan.check(self._round, stage)

    # ---------------------------------------------------- deadline expiry
    def _expire_deadlines(self) -> list:
        now = self._now()

        def expired(rid, req):
            d = req.sampling.deadline_s
            return d > 0 and now - self._submit_time[rid] > d

        outs, keep = [], []
        for ent in self._queue:
            if expired(ent.rid, ent.req):
                outs.append(self._finish(ent.rid, ent.req,
                                         list(ent.resume or []),
                                         "deadline_exceeded"))
            else:
                keep.append(ent)
        self._queue = keep
        for s in range(self.max_slots):
            rid = self._slot_rid[s]
            if rid is not None and expired(rid, self._slot_req[s]):
                outs.append(self._fail_slot(s, "deadline_exceeded"))
        if outs:
            self._progress = True
        return outs

    # ------------------------------------------------------------ admission
    def _admit(self, outs: list) -> None:
        while self._queue:
            ent = min(self._queue, key=lambda e: e.key)
            need = self._pages_for(ent.req)
            slot = next((s for s in range(self.max_slots)
                         if self._slot_rid[s] is None), None)
            if slot is None:
                # slot pressure: only a strict priority inversion may
                # preempt for a slot (equal-priority FIFO holds its slot)
                victims = self._victims(ent, strict=True)
                if not victims or not self._fits_after(need, victims):
                    return
                slot = victims[0]
                self._preempt(slot, ent.rid)
            if need > self.pools.free_pages():
                if not self._preempt_to_fit(need, ent):
                    if any(r is not None for r in self._slot_rid):
                        return  # wait for a retirement to free pages
                    # empty engine and still no room: raise the actionable
                    # exhaustion error (pool is simply too small)
                    self.pools.alloc(need, context=f" (request {ent.rid})")
            self._queue.remove(ent)
            try:
                self._check_fault("admit")
                ids = self.pools.alloc(need, context=f" (request {ent.rid})")
            except Exception as e:
                if not self.retry.is_recoverable(e):
                    raise
                outs.append(self._finish(ent.rid, ent.req,
                                         list(ent.resume or [])[
                                             :ent.req.max_new_tokens],
                                         "failed", error=repr(e)))
                continue
            try:
                if ent.resume is not None:
                    self._start_resume(slot, ent, ids)
                elif (self.prefill_chunk is not None
                        and len(ent.req.tokens) > self.prefill_chunk):
                    self._start_chunked(slot, ent.rid, ent.req, ids)
                else:
                    self._start(slot, ent.rid, ent.req, ids)
            except Exception as e:
                if not self.retry.is_recoverable(e):
                    raise
                # poisoned request: release its reservation, clear the
                # slot, fail it — the engine keeps serving everyone else
                self.pools.release(np.asarray(ids))
                self._clear_slot(slot)
                outs.append(self._finish(ent.rid, ent.req,
                                         list(ent.resume or [])[
                                             :ent.req.max_new_tokens],
                                         "failed", error=repr(e)))
                continue
            self._progress = True

    def _victims(self, ent: _QueueEntry, *, strict: bool) -> list:
        """Preemptable slots for admitting ``ent``, best victim first
        (lowest priority, then youngest — highest rid).  Eligibility: the
        slot holds a decoding request that has emitted >= 1 *fresh* token
        since its (re-)admission — a just-admitted or mid-replay request
        is never preempted, so every admission banks real progress before
        it can be evicted and the preempt/resume cycle always terminates
        (no livelock).  ``strict``: victim priority must be strictly
        lower (slot preemption — equal-priority FIFO keeps its slot);
        else lower or equal (page preemption: a starving head-of-queue
        may evict the youngest same-priority running request)."""
        eprio = ent.req.sampling.priority
        out = []
        for s in range(self.max_slots):
            rid = self._slot_rid[s]
            if rid is None or self._ingest[s] is not None:
                continue
            if len(self._slot_tokens[s]) - self._slot_base[s] < 1:
                continue
            vprio = self._slot_req[s].sampling.priority
            if vprio < eprio or (not strict and vprio == eprio):
                out.append((vprio, -rid, s))
        return [s for _, _, s in sorted(out)]

    def _fits_after(self, need: int, victims: list) -> bool:
        have = self.pools.free_pages()
        have += sum(len(self._slot_pages[s]) for s in victims)
        return need <= have

    def _preempt_to_fit(self, need: int, ent: _QueueEntry) -> bool:
        """Free pages for ``ent`` by preempting eligible victims, fewest
        first; preempts nobody (returns False) when even every eligible
        victim would not make it fit."""
        victims = self._victims(ent, strict=False)
        if not self._fits_after(need, victims):
            return False
        for s in victims:
            if need <= self.pools.free_pages():
                break
            self._preempt(s, ent.rid)
        return True

    def _preempt(self, slot: int, for_rid: int) -> None:
        """Evict the request in ``slot``: release its pages, record its
        emitted tokens, requeue it (it keeps its original rid, so it
        re-enters ahead of same-priority later submissions)."""
        rid = self._slot_rid[slot]
        req = self._slot_req[slot]
        tokens = list(self._slot_tokens[slot])
        self.pools.release(self._slot_pages[slot])
        self._clear_slot(slot)
        self._n_preempted[rid] = self._n_preempted.get(rid, 0) + 1
        self.n_preemptions += 1
        self._queue.append(_QueueEntry(rid, req, resume=tokens))
        self.events.emit("preempt", request=rid, for_request=for_rid,
                         round=self._round, n_tokens=len(tokens),
                         pages_freed=self.pools.free_pages())

    def _start(self, slot: int, rid: int, req: ServeRequest, ids) -> None:
        t = len(req.tokens)
        sp = req.sampling
        prompt = jnp.asarray(req.tokens, jnp.int32)[None]
        logits, cache = _prefill_fn(self.model, t)(self.params, prompt)
        n_pp = -(-self.model._cache_len(t) // self.page)
        self.pools.write_prefill(cache, ids[:n_pp])
        tok0 = self._sample_token0(logits, sp)
        self._first_token_time[rid] = self._now()
        self._claim_slot(slot, rid, req, ids)
        self._slot_tokens[slot] = [tok0]
        # token 0 is admission work, not burst progress: the slot is not
        # preemption-eligible until a burst emits a fresh token
        self._slot_base[slot] = 1
        self._arm_decode(slot, req, tok0)

    def _start_resume(self, slot: int, ent: _QueueEntry, ids) -> None:
        """Re-admit a preempted request: re-ingest the prompt through the
        exact prefill (bitwise the original pages), then queue its emitted
        tokens for teacher-forced replay inside the normal bursts — the
        replayed appends rebuild the generated-token KV codes bitwise, and
        the sampling stream resumes at ``fold_in(key(seed), n)``."""
        req, t = ent.req, len(ent.req.tokens)
        if (self.prefill_chunk is not None and t > self.prefill_chunk):
            self._start_chunked(slot, ent.rid, req, ids, resume=ent.resume)
            return
        prompt = jnp.asarray(req.tokens, jnp.int32)[None]
        cache = _resume_prefill_fn(self.model, t)(self.params, prompt)
        n_pp = -(-self.model._cache_len(t) // self.page)
        self.pools.write_prefill(cache, ids[:n_pp])
        self._claim_slot(slot, ent.rid, req, ids)
        self._arm_resume(slot, req, ent.resume)

    def _start_chunked(self, slot: int, rid: int, req: ServeRequest,
                       ids, resume: Optional[list] = None) -> None:
        """Claim a slot for chunk-by-chunk ingestion: pages are reserved
        and the slot occupied, but no prefill compute happens here — each
        ``step()`` advances the slot one chunk via ``_advance_ingest``
        (the slot's ``act`` stays False until its last chunk samples
        token 0, or — on preemption resume — arms the replay)."""
        t = len(req.tokens)
        self._claim_slot(slot, rid, req, ids)
        self._slot_tokens[slot] = []
        state = (self.model.init_ingest(t)
                 if self.prefill_attn == "exact" else None)
        self._ingest[slot] = {"start": 0, "state": state, "resume": resume}

    def _claim_slot(self, slot: int, rid: int, req: ServeRequest,
                    ids) -> None:
        ids_np = np.asarray(ids)
        self._slot_rid[slot] = rid
        self._slot_pages[slot] = ids_np
        self._slot_tokens[slot] = []
        self._slot_req[slot] = req
        self._slot_base[slot] = 0
        self.tbl[slot] = 0
        self.tbl[slot, :len(ids_np)] = ids_np

    def _clear_slot(self, slot: int) -> None:
        self._slot_rid[slot] = self._slot_pages[slot] = None
        self._slot_tokens[slot] = self._slot_req[slot] = None
        self._ingest[slot] = self._replay[slot] = None
        self._slot_base[slot] = 0
        self.act[slot] = False

    def _advance_ingest(self, outs: list) -> None:
        """Advance every ingesting slot by ONE prompt chunk — batched
        admission: the per-round ingest cost is one chunk per queued
        request, never a whole prompt, so decode bursts stay interleaved
        with long-prompt arrivals."""
        for s in range(self.max_slots):
            ing = self._ingest[s]
            if ing is None:
                continue
            try:
                self._check_fault("ingest")
            except Exception as e:
                if not self.retry.is_recoverable(e):
                    raise
                outs.append(self._fail_slot(s, "failed", error=repr(e)))
                continue
            req = self._slot_req[s]
            t = len(req.tokens)
            start = ing["start"]
            n = min(self.prefill_chunk, t - start)
            last = start + n >= t
            chunk = jnp.asarray(req.tokens[start:start + n], jnp.int32)[None]
            if ing["state"] is not None:
                logits, state, cc = _extend_fn(self.model, t, last)(
                    self.params, chunk, jnp.int32(start), ing["state"])
            else:
                tbl = jnp.asarray(self._slot_pages[s][:start // self.page],
                                  jnp.int32)
                logits, cc = _extend_paged_fn(self.model, t, last)(
                    self.params, chunk, jnp.int32(start), self.pools.pools,
                    tbl)
                state = None
            n_cp = -(-n // self.page)
            first = start // self.page
            self.pools.write_prefill(
                cc, jnp.asarray(self._slot_pages[s][first:first + n_cp],
                                jnp.int32))
            self._progress = True
            if not last:
                ing["start"] = start + n
                ing["state"] = state
                continue
            resume = ing["resume"]
            self._ingest[s] = None
            if resume is not None:
                self._arm_resume(s, req, resume)
                continue
            rid = self._slot_rid[s]
            tok0 = self._sample_token0(logits, req.sampling)
            self._first_token_time[rid] = self._now()
            self._slot_tokens[s] = [tok0]
            self._slot_base[s] = 1  # see _start: token 0 is not progress
            self._arm_decode(s, req, tok0)

    def _sample_token0(self, logits, sp: SamplingParams) -> int:
        """Token 0 from the prefill logits — the exact draw generate()
        makes (``fold_in(key(seed), 0)``), shared by whole-prompt and
        chunked admission."""
        if sp.temperature > 0:
            return int(jax.random.categorical(
                jax.random.fold_in(jax.random.key(sp.seed), 0),
                logits / jnp.float32(sp.temperature), axis=-1)[0])
        return int(jnp.argmax(logits, -1)[0])

    def _arm_decode(self, slot: int, req: ServeRequest, tok0: int) -> None:
        """Write the slot's decode-time sampling state rows after token 0."""
        sp = req.sampling
        done0 = (req.max_new_tokens == 1 or tok0 == sp.eos_token)
        self.tok[slot, 0] = tok0
        self.pos[slot] = len(req.tokens)
        self.nem[slot] = 1
        self.act[slot] = not done0
        self.temp[slot] = sp.temperature
        self.seeds[slot] = np.uint32(sp.seed & 0xFFFFFFFF)
        self.eos[slot] = sp.eos_token
        self.max_new[slot] = req.max_new_tokens

    def _arm_resume(self, slot: int, req: ServeRequest,
                    tokens: list) -> None:
        """Arm decode to continue a preempted stream: the slot re-enters
        the burst as if it had just emitted token 0 (input ``tokens[0]``
        at the prompt boundary, ``nem = 1``), with ``tokens[1:]`` queued
        as teacher-forced outputs — after the replay drains, ``nem`` has
        advanced to ``len(tokens)`` and the next draw is
        ``fold_in(key(seed), len(tokens))``, exactly where the preempted
        stream left off.  A preempted request is always mid-stream (a
        finished one retires before it could be preempted), so the slot
        arms active unconditionally."""
        self._slot_tokens[slot] = list(tokens)
        self._slot_base[slot] = len(tokens)
        self._replay[slot] = collections.deque(tokens[1:]) or None
        sp = req.sampling
        self.tok[slot, 0] = tokens[0]
        self.pos[slot] = len(req.tokens)
        self.nem[slot] = 1
        self.act[slot] = True
        self.temp[slot] = sp.temperature
        self.seeds[slot] = np.uint32(sp.seed & 0xFFFFFFFF)
        self.eos[slot] = sp.eos_token
        self.max_new[slot] = req.max_new_tokens

    # --------------------------------------------------------------- decode
    def _burst_guarded(self, outs: list) -> None:
        """Run the burst under the retry policy: an injected burst fault
        fires at the stage point *before* the dispatch (pools and slot
        rows untouched), so each retry re-runs the identical burst —
        tokens stay bit-identical through any number of retries.  Retries
        exhausted: the decoding requests are failed (isolated) and the
        engine keeps serving its queue."""
        attempt = 0
        while True:
            try:
                self._check_fault("burst")
                self._burst()
                return
            except Exception as e:
                if not self.retry.is_recoverable(e):
                    raise
                attempt += 1
                if attempt > self.retry.max_restarts:
                    self.events.emit("burst_poisoned", round=self._round,
                                     attempts=attempt, error=repr(e))
                    for s in range(self.max_slots):
                        if (self._slot_rid[s] is not None
                                and self._ingest[s] is None):
                            outs.append(self._fail_slot(s, "failed",
                                                        error=repr(e)))
                    return
                back = self.retry.backoff(attempt)
                self.events.emit("burst_retry", round=self._round,
                                 attempt=attempt, backoff_s=back,
                                 error=repr(e))
                if back:
                    time.sleep(back)

    def _burst(self) -> None:
        R, b = self.burst_steps, self.max_slots
        forced = np.zeros((R, b), np.int32)
        fmask = np.zeros((R, b), bool)
        consumed = [0] * b
        for s in range(b):
            q = self._replay[s]
            if q:
                k = min(R, len(q))
                forced[:k, s] = [q[i] for i in range(k)]
                fmask[:k, s] = True
                consumed[s] = k
        (self.pools.pools, tok, pos, nem, act,
         toks, em) = _burst_fn(self.model, self.burst_steps)(
            self.params, self.pools.pools, self.tbl, self.tok, self.pos,
            self.nem, self.act, self.temp, self.seeds, self.eos,
            self.max_new, forced, fmask)
        # np.array, not np.asarray: admission mutates these rows in place
        self.tok, self.pos = np.array(tok), np.array(pos)
        self.nem, self.act = np.array(nem), np.array(act)
        toks, em = np.asarray(toks), np.asarray(em)
        if em.any():
            self._progress = True  # replay advancing counts as progress
        for s in range(self.max_slots):
            if self._slot_rid[s] is None or self._ingest[s] is not None:
                continue
            k = consumed[s]
            if k:
                # the first k emissions are the teacher-forced replay —
                # already in _slot_tokens; only fresh tokens append
                for _ in range(k):
                    self._replay[s].popleft()
                if not self._replay[s]:
                    self._replay[s] = None
            self._slot_tokens[s].extend(int(t)
                                        for t in toks[em[:, s], s][k:])

    # --------------------------------------------------------------- retire
    def _retire_guarded(self) -> list:
        try:
            self._check_fault("retire")
        except Exception as e:
            if not self.retry.is_recoverable(e):
                raise
            # retirement is idempotent host bookkeeping: defer to the next
            # round (the finished slots simply stay resident one round)
            self.events.emit("retire_deferred", round=self._round,
                             error=repr(e))
            return []
        return self._retire()

    def _retire(self) -> list:
        finished = []
        for s in range(self.max_slots):
            rid = self._slot_rid[s]
            if rid is None or self.act[s] or self._ingest[s] is not None:
                continue
            self.pools.release(self._slot_pages[s])
            req = self._slot_req[s]
            toks = self._slot_tokens[s][:req.max_new_tokens]
            self._clear_slot(s)
            finished.append(self._finish(rid, req, toks, "ok"))
            self._progress = True
        return finished

    def _fail_slot(self, slot: int, status: str,
                   error: Optional[str] = None):
        """Terminate the request occupying ``slot`` with a non-ok status:
        release its pages, clear the slot, record the partial tokens."""
        rid = self._slot_rid[slot]
        req = self._slot_req[slot]
        toks = list(self._slot_tokens[slot] or [])[:req.max_new_tokens]
        self.pools.release(self._slot_pages[slot])
        self._clear_slot(slot)
        return self._finish(rid, req, toks, status, error=error)

    def _finish(self, rid: int, req: ServeRequest, tokens: list,
                status: str, error: Optional[str] = None) -> RequestOutput:
        """Build the terminal RequestOutput for ``rid`` (every request
        ends here exactly once, whatever its fate)."""
        n_pre = self._n_preempted.pop(rid, 0)
        if status == "ok" and n_pre:
            status = f"preempted_{n_pre}"
        out = RequestOutput(
            request_id=rid,
            tokens=tokens,
            prompt_len=len(req.tokens),
            submit_time=self._submit_time.pop(rid),
            finish_time=self._now(),
            first_token_time=self._first_token_time.pop(rid, 0.0),
            status=status,
            n_preempted=n_pre)
        if out.finished_ok:
            lat = out.latency
            self._service_ema = (lat if self._service_ema is None
                                 else 0.7 * self._service_ema + 0.3 * lat)
        else:
            self.events.emit("request_" + status, request=rid,
                             round=self._round, n_tokens=len(tokens),
                             **({"error": error} if error else {}))
        self._outputs.append(out)
        self._progress = True
        return out

    # ------------------------------------------------------------- watchdog
    def _watchdog(self) -> None:
        """Stuck-round detection: a busy engine must make progress every
        round (tokens emitted, a chunk ingested, a request admitted or
        retired).  ``watchdog_rounds`` idle rounds emit a structured
        ``stuck_round`` event; twice that raises :class:`EngineStuck` so
        ``drain()`` fails loudly instead of spinning forever."""
        if not self.busy or self._progress:
            self._idle_rounds = 0
            return
        self._idle_rounds += 1
        if self._idle_rounds == self.watchdog_rounds:
            self.events.emit("stuck_round", round=self._round,
                             idle_rounds=self._idle_rounds,
                             queued=len(self._queue),
                             free_pages=self.pools.free_pages())
        if self._idle_rounds >= 2 * self.watchdog_rounds:
            raise EngineStuck(
                f"no scheduling progress for {self._idle_rounds} rounds "
                f"(round {self._round}: {len(self._queue)} queued, "
                f"{self.pools.free_pages()} of {self.pools.n_pages} pages "
                "free) — the engine is wedged; see the stuck_round event")
