"""Continuous-batching serve engine over block-paged quantized KV pools.

Requests arrive (``submit``), prefill into freshly allocated pages, join
the running decode batch at the next scheduling round (``step``), and
retire as soon as they hit EOS or their token budget — releasing their
pages for the next admission.  Decode runs in *bursts*: a jitted
``lax.scan`` of ``burst_steps`` paged decode steps whose carry holds every
slot's token / position / emitted-count / liveness, so the host only
intervenes at scheduling rounds, exactly like the fixed-batch scan loop
of ``launch.serve``.

Determinism contract (pinned by tests/test_serving.py): a request's
tokens are bitwise the ones ``launch.serve.generate`` produces for the
same prompt alone at batch 1 with the same ``SamplingParams`` — the
engine replicates its sampling stream exactly (token ``j`` is drawn with
``fold_in(key(seed), j)``; token 0 comes from the prefill logits) and the
paged attention matches the flat cache bitwise at tile = page.  The one
structural exception is MoE models, where expert-capacity dropping
couples tokens across the batch (true of any batched serving, the
fixed-batch loop included).

Admission policy: pages for the *whole* request (prompt + max_new_tokens,
rounded up to whole pages) are reserved at admission — a running request
can never hit the allocator mid-flight, so there is no preemption/swap
path to get wrong.  With ``prefill_chunk=None`` admission is whole-prompt
(one prefill dispatch per request, stalling the decode batch for the full
prompt).  With ``prefill_chunk=N`` (rounded up to a page multiple) the
prompt is ingested chunk by chunk, one chunk per scheduling round per
ingesting slot, *interleaved* with decode bursts — the running batch
keeps emitting while long prompts stream in, and every queued request
that holds a slot advances each round (batched admission).  The default
``prefill_attn="exact"`` mode keeps transient fp K/V prefix buffers per
ingesting request so every chunk replays the flat prefill bitwise — the
determinism contract holds unchanged; ``prefill_attn="paged"`` instead
re-reads earlier chunks from their quantized pages through the paged
extend kernels (HBM-cheap, but lossy versus the flat prefill — opt-in).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.paged import PagedPools


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling: greedy at ``temperature == 0``, categorical
    over ``logits / temperature`` otherwise, keyed by ``seed`` (the same
    stream ``launch.serve.generate`` draws for ``key(seed)``).
    ``eos_token`` stops generation early when sampled (-1: never)."""
    temperature: float = 0.0
    seed: int = 0
    eos_token: int = -1


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One generation request: prompt token ids + a token budget + its
    sampling params.  The single request type shared by the engine, the
    CLI and ``generate_batch``."""
    tokens: tuple
    max_new_tokens: int
    sampling: SamplingParams = SamplingParams()

    def __post_init__(self):
        object.__setattr__(self, "tokens", tuple(int(t) for t in self.tokens))
        if not self.tokens:
            raise ValueError("ServeRequest needs at least one prompt token")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}")


@dataclasses.dataclass
class RequestOutput:
    request_id: int
    tokens: list
    prompt_len: int
    submit_time: float
    finish_time: float
    first_token_time: float = 0.0

    @property
    def latency(self) -> float:
        return self.finish_time - self.submit_time

    @property
    def ttft(self) -> float:
        """Time to first token: submit -> the round that sampled token 0
        from the (last chunk of the) prefill."""
        return self.first_token_time - self.submit_time


@functools.lru_cache(maxsize=64)
def _prefill_fn(model, cache_len: int):
    return jax.jit(lambda p, x: model.prefill(p, x, cache_len=cache_len))


@functools.lru_cache(maxsize=64)
def _extend_fn(model, t_total: int, last: bool):
    """One exact-mode chunk step: fp prefix buffers donated through."""
    return jax.jit(
        lambda p, x, start, state: model.paged_extend_step(
            p, x, start, state, t_total=t_total, last=last),
        donate_argnums=(3,))


@functools.lru_cache(maxsize=64)
def _extend_paged_fn(model, t_total: int, last: bool):
    """One paged-mode chunk step: reads the request's quantized pages."""
    def run(p, x, start, pools, tbl):
        logits, _, cc = model.paged_extend_step(
            p, x, start, None, t_total=t_total, last=last, pools=pools,
            page_tbl=tbl)
        return logits, cc
    return jax.jit(run)


@functools.lru_cache(maxsize=16)
def _burst_fn(model, n_steps: int):
    """One jitted program for a scheduling round: ``n_steps`` paged decode
    steps with per-slot sampling state in the scan carry, pools donated.

    Emits ``(toks, emitted)`` per step; slots deactivate in-carry on EOS /
    budget so a retired-mid-burst slot stops emitting (and its appends
    divert to the trash page) without any host round-trip."""

    def run(params, pools, tbl, tok, pos, nem, act, temp, seeds, eos,
            max_new):
        keys = jax.vmap(jax.random.key)(seeds)
        safe_temp = jnp.where(temp > 0, temp, 1.0)

        def sample_one(key, nem_i, logits_i, temp_i):
            sub = jax.random.fold_in(key, nem_i)
            return jax.random.categorical(
                sub, logits_i[None] / temp_i, axis=-1).astype(jnp.int32)[0]

        def body(carry, _):
            pools, tok, pos, nem, act = carry
            logits, pools = model.paged_decode_step(params, pools, tbl, tok,
                                                    pos, act)
            sampled = jax.vmap(sample_one)(keys, nem, logits, safe_temp)
            greedy = jnp.argmax(logits, -1).astype(jnp.int32)
            nxt = jnp.where(temp > 0, sampled, greedy)
            emitted = act
            nem2 = nem + act.astype(jnp.int32)
            done = act & ((nxt == eos) | (nem2 >= max_new))
            return (pools, nxt[:, None], pos + act.astype(jnp.int32), nem2,
                    act & ~done), (jnp.where(act, nxt, -1), emitted)

        (pools, tok, pos, nem, act), (toks, em) = jax.lax.scan(
            body, (pools, tok, pos, nem, act), None, length=n_steps)
        return pools, tok, pos, nem, act, toks, em

    return jax.jit(run, donate_argnums=(1,))


class Engine:
    """Continuous-batching engine: ``submit()`` requests, drive scheduling
    rounds with ``step()`` (or ``drain()`` to completion); each round
    retires finished requests, admits queued ones into free slots, and
    runs one decode burst for every live slot at once."""

    def __init__(self, model, params, *, max_slots: int = 4,
                 n_pages: int = 64, max_pages_per_request: int = 8,
                 burst_steps: int = 8, prefill_chunk: Optional[int] = None,
                 prefill_attn: str = "exact"):
        cfg = model.cfg
        metas = tuple(model.prefix_metas) + tuple(model.group_metas)
        bad = sorted({m.mixer for m in metas} - {"attn", "mla"})
        if bad:
            raise ValueError(
                f"paged serving supports attn/mla mixers, model has {bad} "
                "— ssm/cross-attention state is per-slot, not per-page; "
                "serve such models through launch.serve.generate")
        if any(m.has_cross for m in metas) or cfg.family == "encdec":
            raise ValueError(
                "paged serving does not support cross-attention caches "
                "(media/encoder KV is request-global, not paged); use "
                "launch.serve.generate")
        if getattr(model.ctx, "enabled", False):
            raise ValueError(
                "the engine is meshless — it owns the batch axis and the "
                "paged kernels take no shard_map route; build the model "
                "with the LOCAL ctx for serving")
        if prefill_attn not in ("exact", "paged"):
            raise ValueError(
                f"prefill_attn must be 'exact' or 'paged', got "
                f"{prefill_attn!r}")
        self.model = model
        self.params = params
        self.pools = PagedPools(model, n_pages)  # validates kv_bits
        self.page = self.pools.page
        self.max_slots = max_slots
        self.max_pages = max_pages_per_request
        self.burst_steps = burst_steps
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                raise ValueError(
                    f"prefill_chunk must be >= 1, got {prefill_chunk}")
            # chunk boundaries must be page-aligned (a kv2 scale group
            # never straddles a chunk, and chunk pages scatter whole)
            prefill_chunk = -(-prefill_chunk // self.page) * self.page
        self.prefill_chunk = prefill_chunk
        self.prefill_attn = prefill_attn

        # per-slot scheduling state lives on the HOST: admission writes a
        # handful of scalars per request, and as numpy rows that is free —
        # as device arrays it was ~10 tiny dispatches per admission, a
        # measurable slice of small-model serving time.  The burst uploads
        # the (tiny) state with its dispatch and the results mirror back.
        b = max_slots
        self.tbl = np.zeros((b, self.max_pages), np.int32)
        self.tok = np.zeros((b, 1), np.int32)
        self.pos = np.zeros((b,), np.int32)
        self.nem = np.zeros((b,), np.int32)
        self.act = np.zeros((b,), bool)
        self.temp = np.zeros((b,), np.float32)
        self.seeds = np.zeros((b,), np.uint32)
        self.eos = np.full((b,), -1, np.int32)
        self.max_new = np.ones((b,), np.int32)

        self._queue = collections.deque()
        self._next_rid = 0
        self._slot_rid = [None] * b          # rid occupying each slot
        self._slot_pages = [None] * b        # np page ids of each slot
        self._slot_tokens = [None] * b       # emitted tokens (host)
        self._slot_req = [None] * b
        self._ingest = [None] * b            # chunked-prefill progress
        self._submit_time = {}
        self._first_token_time = {}
        self._outputs = []
        self.admission_stall_s = 0.0

    # ------------------------------------------------------------------ API
    def submit(self, request: ServeRequest) -> int:
        """Queue a request; returns its id.  Admission happens at the next
        ``step()``.  Requests that can never fit are rejected here."""
        need = self._pages_for(request)
        if need > self.max_pages:
            raise ValueError(
                f"request needs {need} pages ({len(request.tokens)} prompt "
                f"+ {request.max_new_tokens} new tokens at {self.page}/page)"
                f" but the page table holds {self.max_pages} per request — "
                "raise max_pages_per_request or split the request")
        if need > self.pools.n_pages:
            # fail fast with the allocator's own sizing math: this request
            # can never fit even an empty pool, so queueing it would only
            # defer the same failure to admission time
            raise self.pools.exhausted(
                need, have=self.pools.n_pages,
                context=f" (submit: {len(request.tokens)} prompt + "
                        f"{request.max_new_tokens} new tokens can never "
                        f"fit)")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append((rid, request))
        self._submit_time[rid] = time.time()
        return rid

    def step(self) -> list:
        """One scheduling round: admit queued requests into free slots,
        advance every ingesting slot by one prompt chunk, run one decode
        burst over the live batch, retire the finished.  Returns the
        requests that finished this round."""
        t0 = time.time()
        self._admit()
        self._advance_ingest()
        self.admission_stall_s += time.time() - t0
        if self.act.any():
            self._burst()
        return self._retire()

    @property
    def busy(self) -> bool:
        """True while any request is queued, ingesting, or decoding."""
        return (bool(self._queue) or bool(self.act.any())
                or any(i is not None for i in self._ingest))

    def drain(self) -> list:
        """Run ``step()`` until every submitted request has finished."""
        out = []
        while self.busy:
            out.extend(self.step())
        return out

    # ------------------------------------------------------------ internals
    def _pages_for(self, req: ServeRequest) -> int:
        return -(-(len(req.tokens) + req.max_new_tokens) // self.page)

    def _admit(self) -> None:
        while self._queue:
            slot = next((s for s in range(self.max_slots)
                         if self._slot_rid[s] is None), None)
            if slot is None:
                return
            rid, req = self._queue[0]
            need = self._pages_for(req)
            if need > self.pools.free_pages():
                if any(r is not None for r in self._slot_rid):
                    return  # wait for a retirement to free pages
                # empty engine and still no room: raise the actionable
                # exhaustion error (pool is simply too small)
                self.pools.alloc(need, context=f" (request {rid})")
            self._queue.popleft()
            ids = self.pools.alloc(need, context=f" (request {rid})")
            if (self.prefill_chunk is not None
                    and len(req.tokens) > self.prefill_chunk):
                self._start_chunked(slot, rid, req, ids)
            else:
                self._start(slot, rid, req, ids)

    def _start(self, slot: int, rid: int, req: ServeRequest, ids) -> None:
        t = len(req.tokens)
        sp = req.sampling
        prompt = jnp.asarray(req.tokens, jnp.int32)[None]
        logits, cache = _prefill_fn(self.model, t)(self.params, prompt)
        n_pp = -(-self.model._cache_len(t) // self.page)
        self.pools.write_prefill(cache, ids[:n_pp])
        tok0 = self._sample_token0(logits, sp)
        self._first_token_time[rid] = time.time()
        ids_np = np.asarray(ids)
        self._slot_rid[slot] = rid
        self._slot_pages[slot] = ids_np
        self._slot_tokens[slot] = [tok0]
        self._slot_req[slot] = req
        self.tbl[slot] = 0
        self.tbl[slot, :len(ids_np)] = ids_np
        self._arm_decode(slot, req, tok0)

    def _start_chunked(self, slot: int, rid: int, req: ServeRequest,
                       ids) -> None:
        """Claim a slot for chunk-by-chunk ingestion: pages are reserved
        and the slot occupied, but no prefill compute happens here — each
        ``step()`` advances the slot one chunk via ``_advance_ingest``
        (the slot's ``act`` stays False until its last chunk samples
        token 0)."""
        t = len(req.tokens)
        ids_np = np.asarray(ids)
        self._slot_rid[slot] = rid
        self._slot_pages[slot] = ids_np
        self._slot_tokens[slot] = []
        self._slot_req[slot] = req
        self.tbl[slot] = 0
        self.tbl[slot, :len(ids_np)] = ids_np
        state = (self.model.init_ingest(t)
                 if self.prefill_attn == "exact" else None)
        self._ingest[slot] = {"start": 0, "state": state}

    def _advance_ingest(self) -> None:
        """Advance every ingesting slot by ONE prompt chunk — batched
        admission: the per-round ingest cost is one chunk per queued
        request, never a whole prompt, so decode bursts stay interleaved
        with long-prompt arrivals."""
        for s in range(self.max_slots):
            ing = self._ingest[s]
            if ing is None:
                continue
            req = self._slot_req[s]
            t = len(req.tokens)
            start = ing["start"]
            n = min(self.prefill_chunk, t - start)
            last = start + n >= t
            chunk = jnp.asarray(req.tokens[start:start + n], jnp.int32)[None]
            if ing["state"] is not None:
                logits, state, cc = _extend_fn(self.model, t, last)(
                    self.params, chunk, jnp.int32(start), ing["state"])
            else:
                tbl = jnp.asarray(self._slot_pages[s][:start // self.page],
                                  jnp.int32)
                logits, cc = _extend_paged_fn(self.model, t, last)(
                    self.params, chunk, jnp.int32(start), self.pools.pools,
                    tbl)
                state = None
            n_cp = -(-n // self.page)
            first = start // self.page
            self.pools.write_prefill(
                cc, jnp.asarray(self._slot_pages[s][first:first + n_cp],
                                jnp.int32))
            if not last:
                ing["start"] = start + n
                ing["state"] = state
                continue
            rid = self._slot_rid[s]
            tok0 = self._sample_token0(logits, req.sampling)
            self._first_token_time[rid] = time.time()
            self._slot_tokens[s] = [tok0]
            self._ingest[s] = None
            self._arm_decode(s, req, tok0)

    def _sample_token0(self, logits, sp: SamplingParams) -> int:
        """Token 0 from the prefill logits — the exact draw generate()
        makes (``fold_in(key(seed), 0)``), shared by whole-prompt and
        chunked admission."""
        if sp.temperature > 0:
            return int(jax.random.categorical(
                jax.random.fold_in(jax.random.key(sp.seed), 0),
                logits / jnp.float32(sp.temperature), axis=-1)[0])
        return int(jnp.argmax(logits, -1)[0])

    def _arm_decode(self, slot: int, req: ServeRequest, tok0: int) -> None:
        """Write the slot's decode-time sampling state rows after token 0."""
        sp = req.sampling
        done0 = (req.max_new_tokens == 1 or tok0 == sp.eos_token)
        self.tok[slot, 0] = tok0
        self.pos[slot] = len(req.tokens)
        self.nem[slot] = 1
        self.act[slot] = not done0
        self.temp[slot] = sp.temperature
        self.seeds[slot] = np.uint32(sp.seed & 0xFFFFFFFF)
        self.eos[slot] = sp.eos_token
        self.max_new[slot] = req.max_new_tokens

    def _burst(self) -> None:
        (self.pools.pools, tok, pos, nem, act,
         toks, em) = _burst_fn(self.model, self.burst_steps)(
            self.params, self.pools.pools, self.tbl, self.tok, self.pos,
            self.nem, self.act, self.temp, self.seeds, self.eos,
            self.max_new)
        # np.array, not np.asarray: admission mutates these rows in place
        self.tok, self.pos = np.array(tok), np.array(pos)
        self.nem, self.act = np.array(nem), np.array(act)
        toks, em = np.asarray(toks), np.asarray(em)
        for s in range(self.max_slots):
            if self._slot_rid[s] is None or self._ingest[s] is not None:
                continue
            self._slot_tokens[s].extend(int(t)
                                        for t in toks[em[:, s], s])

    def _retire(self) -> list:
        finished = []
        for s in range(self.max_slots):
            rid = self._slot_rid[s]
            if rid is None or self.act[s] or self._ingest[s] is not None:
                continue
            self.pools.release(self._slot_pages[s])
            req = self._slot_req[s]
            out = RequestOutput(
                request_id=rid,
                tokens=self._slot_tokens[s][:req.max_new_tokens],
                prompt_len=len(req.tokens),
                submit_time=self._submit_time.pop(rid),
                finish_time=time.time(),
                first_token_time=self._first_token_time.pop(rid, 0.0))
            finished.append(out)
            self._outputs.append(out)
            self._slot_rid[s] = self._slot_pages[s] = None
            self._slot_tokens[s] = self._slot_req[s] = None
        return finished
