"""Continuous-batching serve engine over block-paged quantized KV pools.

Requests arrive (``submit``), prefill into freshly allocated pages, join
the running decode batch at the next scheduling round (``step``), and
retire as soon as they hit EOS or their token budget — releasing their
pages for the next admission.  Decode runs in *bursts*: a jitted
``lax.scan`` of ``burst_steps`` paged decode steps whose carry holds every
slot's token / position / emitted-count / liveness, so the host only
intervenes at scheduling rounds, exactly like the fixed-batch scan loop
of ``launch.serve``.

Determinism contract (pinned by tests/test_serving.py): a request's
tokens are bitwise the ones ``launch.serve.generate`` produces for the
same prompt alone at batch 1 with the same ``SamplingParams`` — the
engine replicates its sampling stream exactly (token ``j`` is drawn with
``fold_in(key(seed), j)``; token 0 comes from the prefill logits) and the
paged attention matches the flat cache bitwise at tile = page.  The one
structural exception is MoE models, where expert-capacity dropping
couples tokens across the batch (true of any batched serving, the
fixed-batch loop included).

Admission policy: pages for the *whole* request (prompt + max_new_tokens,
rounded up to whole pages) are reserved at admission — a running request
can never hit the allocator mid-flight, so there is no preemption/swap
path to get wrong.  Admission is whole-prompt (one prefill dispatch per
request, like the flat path — bit-identity is the reason chunked
admission is not the default).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.paged import PagedPools


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling: greedy at ``temperature == 0``, categorical
    over ``logits / temperature`` otherwise, keyed by ``seed`` (the same
    stream ``launch.serve.generate`` draws for ``key(seed)``).
    ``eos_token`` stops generation early when sampled (-1: never)."""
    temperature: float = 0.0
    seed: int = 0
    eos_token: int = -1


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One generation request: prompt token ids + a token budget + its
    sampling params.  The single request type shared by the engine, the
    CLI and ``generate_batch``."""
    tokens: tuple
    max_new_tokens: int
    sampling: SamplingParams = SamplingParams()

    def __post_init__(self):
        object.__setattr__(self, "tokens", tuple(int(t) for t in self.tokens))
        if not self.tokens:
            raise ValueError("ServeRequest needs at least one prompt token")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}")


@dataclasses.dataclass
class RequestOutput:
    request_id: int
    tokens: list
    prompt_len: int
    submit_time: float
    finish_time: float

    @property
    def latency(self) -> float:
        return self.finish_time - self.submit_time


@functools.lru_cache(maxsize=64)
def _prefill_fn(model, cache_len: int):
    return jax.jit(lambda p, x: model.prefill(p, x, cache_len=cache_len))


@functools.lru_cache(maxsize=16)
def _burst_fn(model, n_steps: int):
    """One jitted program for a scheduling round: ``n_steps`` paged decode
    steps with per-slot sampling state in the scan carry, pools donated.

    Emits ``(toks, emitted)`` per step; slots deactivate in-carry on EOS /
    budget so a retired-mid-burst slot stops emitting (and its appends
    divert to the trash page) without any host round-trip."""

    def run(params, pools, tbl, tok, pos, nem, act, temp, seeds, eos,
            max_new):
        keys = jax.vmap(jax.random.key)(seeds)
        safe_temp = jnp.where(temp > 0, temp, 1.0)

        def sample_one(key, nem_i, logits_i, temp_i):
            sub = jax.random.fold_in(key, nem_i)
            return jax.random.categorical(
                sub, logits_i[None] / temp_i, axis=-1).astype(jnp.int32)[0]

        def body(carry, _):
            pools, tok, pos, nem, act = carry
            logits, pools = model.paged_decode_step(params, pools, tbl, tok,
                                                    pos, act)
            sampled = jax.vmap(sample_one)(keys, nem, logits, safe_temp)
            greedy = jnp.argmax(logits, -1).astype(jnp.int32)
            nxt = jnp.where(temp > 0, sampled, greedy)
            emitted = act
            nem2 = nem + act.astype(jnp.int32)
            done = act & ((nxt == eos) | (nem2 >= max_new))
            return (pools, nxt[:, None], pos + act.astype(jnp.int32), nem2,
                    act & ~done), (jnp.where(act, nxt, -1), emitted)

        (pools, tok, pos, nem, act), (toks, em) = jax.lax.scan(
            body, (pools, tok, pos, nem, act), None, length=n_steps)
        return pools, tok, pos, nem, act, toks, em

    return jax.jit(run, donate_argnums=(1,))


class Engine:
    """Continuous-batching engine: ``submit()`` requests, drive scheduling
    rounds with ``step()`` (or ``drain()`` to completion); each round
    retires finished requests, admits queued ones into free slots, and
    runs one decode burst for every live slot at once."""

    def __init__(self, model, params, *, max_slots: int = 4,
                 n_pages: int = 64, max_pages_per_request: int = 8,
                 burst_steps: int = 8):
        cfg = model.cfg
        metas = tuple(model.prefix_metas) + tuple(model.group_metas)
        bad = sorted({m.mixer for m in metas} - {"attn", "mla"})
        if bad:
            raise ValueError(
                f"paged serving supports attn/mla mixers, model has {bad} "
                "— ssm/cross-attention state is per-slot, not per-page; "
                "serve such models through launch.serve.generate")
        if any(m.has_cross for m in metas) or cfg.family == "encdec":
            raise ValueError(
                "paged serving does not support cross-attention caches "
                "(media/encoder KV is request-global, not paged); use "
                "launch.serve.generate")
        if getattr(model.ctx, "enabled", False):
            raise ValueError(
                "the engine is meshless — it owns the batch axis and the "
                "paged kernels take no shard_map route; build the model "
                "with the LOCAL ctx for serving")
        self.model = model
        self.params = params
        self.pools = PagedPools(model, n_pages)  # validates kv_bits
        self.page = self.pools.page
        self.max_slots = max_slots
        self.max_pages = max_pages_per_request
        self.burst_steps = burst_steps

        # per-slot scheduling state lives on the HOST: admission writes a
        # handful of scalars per request, and as numpy rows that is free —
        # as device arrays it was ~10 tiny dispatches per admission, a
        # measurable slice of small-model serving time.  The burst uploads
        # the (tiny) state with its dispatch and the results mirror back.
        b = max_slots
        self.tbl = np.zeros((b, self.max_pages), np.int32)
        self.tok = np.zeros((b, 1), np.int32)
        self.pos = np.zeros((b,), np.int32)
        self.nem = np.zeros((b,), np.int32)
        self.act = np.zeros((b,), bool)
        self.temp = np.zeros((b,), np.float32)
        self.seeds = np.zeros((b,), np.uint32)
        self.eos = np.full((b,), -1, np.int32)
        self.max_new = np.ones((b,), np.int32)

        self._queue = collections.deque()
        self._next_rid = 0
        self._slot_rid = [None] * b          # rid occupying each slot
        self._slot_pages = [None] * b        # np page ids of each slot
        self._slot_tokens = [None] * b       # emitted tokens (host)
        self._slot_req = [None] * b
        self._submit_time = {}
        self._outputs = []

    # ------------------------------------------------------------------ API
    def submit(self, request: ServeRequest) -> int:
        """Queue a request; returns its id.  Admission happens at the next
        ``step()``.  Requests that can never fit are rejected here."""
        need = self._pages_for(request)
        if need > self.max_pages:
            raise ValueError(
                f"request needs {need} pages ({len(request.tokens)} prompt "
                f"+ {request.max_new_tokens} new tokens at {self.page}/page)"
                f" but the page table holds {self.max_pages} per request — "
                "raise max_pages_per_request or split the request")
        if need > self.pools.n_pages:
            raise ValueError(
                f"request needs {need} pages but the pool only has "
                f"{self.pools.n_pages} — raise n_pages")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append((rid, request))
        self._submit_time[rid] = time.time()
        return rid

    def step(self) -> list:
        """One scheduling round: admit queued requests into free slots,
        run one decode burst over the live batch, retire the finished.
        Returns the requests that finished this round."""
        self._admit()
        if self.act.any():
            self._burst()
        return self._retire()

    def drain(self) -> list:
        """Run ``step()`` until every submitted request has finished."""
        out = []
        while self._queue or self.act.any():
            out.extend(self.step())
        return out

    # ------------------------------------------------------------ internals
    def _pages_for(self, req: ServeRequest) -> int:
        return -(-(len(req.tokens) + req.max_new_tokens) // self.page)

    def _admit(self) -> None:
        while self._queue:
            slot = next((s for s in range(self.max_slots)
                         if self._slot_rid[s] is None), None)
            if slot is None:
                return
            rid, req = self._queue[0]
            need = self._pages_for(req)
            if need > self.pools.free_pages():
                if any(r is not None for r in self._slot_rid):
                    return  # wait for a retirement to free pages
                # empty engine and still no room: raise the actionable
                # exhaustion error (pool is simply too small)
                self.pools.alloc(need, context=f" (request {rid})")
            self._queue.popleft()
            ids = self.pools.alloc(need, context=f" (request {rid})")
            self._start(slot, rid, req, ids)

    def _start(self, slot: int, rid: int, req: ServeRequest, ids) -> None:
        t = len(req.tokens)
        sp = req.sampling
        prompt = jnp.asarray(req.tokens, jnp.int32)[None]
        logits, cache = _prefill_fn(self.model, t)(self.params, prompt)
        n_pp = -(-self.model._cache_len(t) // self.page)
        self.pools.write_prefill(cache, ids[:n_pp])
        # token 0 from the prefill logits — the exact draw generate() makes
        key = jax.random.key(sp.seed)
        if sp.temperature > 0:
            tok0 = int(jax.random.categorical(
                jax.random.fold_in(key, 0),
                logits / jnp.float32(sp.temperature), axis=-1)[0])
        else:
            tok0 = int(jnp.argmax(logits, -1)[0])
        ids_np = np.asarray(ids)
        self._slot_rid[slot] = rid
        self._slot_pages[slot] = ids_np
        self._slot_tokens[slot] = [tok0]
        self._slot_req[slot] = req
        done0 = (req.max_new_tokens == 1 or tok0 == sp.eos_token)
        self.tbl[slot] = 0
        self.tbl[slot, :len(ids_np)] = ids_np
        self.tok[slot, 0] = tok0
        self.pos[slot] = t
        self.nem[slot] = 1
        self.act[slot] = not done0
        self.temp[slot] = sp.temperature
        self.seeds[slot] = np.uint32(sp.seed & 0xFFFFFFFF)
        self.eos[slot] = sp.eos_token
        self.max_new[slot] = req.max_new_tokens

    def _burst(self) -> None:
        (self.pools.pools, tok, pos, nem, act,
         toks, em) = _burst_fn(self.model, self.burst_steps)(
            self.params, self.pools.pools, self.tbl, self.tok, self.pos,
            self.nem, self.act, self.temp, self.seeds, self.eos,
            self.max_new)
        # np.array, not np.asarray: admission mutates these rows in place
        self.tok, self.pos = np.array(tok), np.array(pos)
        self.nem, self.act = np.array(nem), np.array(act)
        toks, em = np.asarray(toks), np.asarray(em)
        for s in range(self.max_slots):
            if self._slot_rid[s] is None:
                continue
            self._slot_tokens[s].extend(int(t)
                                        for t in toks[em[:, s], s])

    def _retire(self) -> list:
        finished = []
        for s in range(self.max_slots):
            rid = self._slot_rid[s]
            if rid is None or self.act[s]:
                continue
            self.pools.release(self._slot_pages[s])
            req = self._slot_req[s]
            out = RequestOutput(
                request_id=rid,
                tokens=self._slot_tokens[s][:req.max_new_tokens],
                prompt_len=len(req.tokens),
                submit_time=self._submit_time.pop(rid),
                finish_time=time.time())
            finished.append(out)
            self._outputs.append(out)
            self._slot_rid[s] = self._slot_pages[s] = None
            self._slot_tokens[s] = self._slot_req[s] = None
        return finished
