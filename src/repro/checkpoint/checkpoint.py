"""Fault-tolerant checkpointing: atomic, async, latest-k, elastic.

  * atomic     — write into ``<dir>/tmp.<step>`` then ``os.rename`` to
                 ``step_<n>``; a crash mid-write never corrupts the latest
                 checkpoint (rename is atomic on POSIX).  Within the temp
                 dir the npz payload itself is written to a ``.tmp`` path
                 and atomically renamed, and a terminal ``DONE`` marker is
                 the *last* file written before the dir rename —
                 ``latest_step()`` ignores any step dir without it, so a
                 half-written step (crash mid-rename on a non-atomic
                 filesystem, or a copied/partial dir) is never restored.
  * async      — device->host transfer happens on the caller thread (cheap,
                 and consistent with the step), serialization + fsync on a
                 background thread so training never blocks on disk.
  * latest-k   — old steps are garbage-collected after a successful save.
  * elastic    — ``restore(..., shardings=...)`` re-lays-out every leaf for
                 a *different* mesh than the one that saved it (device_put
                 against the new sharding), so a job can restart on a
                 different pod count.
  * exact      — the data-iterator state (step) is stored alongside, making
                 resume bit-exact with the run that never died.

Single-host container note: arrays are written as one .npz per checkpoint;
on a real multi-host cluster the same layout holds one shard file per host
(``addressable_shards``), which this module's format field records.
"""
from __future__ import annotations

import json
import os
import pickle
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> tuple[dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return {f"leaf_{i}": np.asarray(jax.device_get(l))
            for i, l in enumerate(leaves)}, treedef


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, extra: dict | None = None,
             blocking: bool = False) -> None:
        """state: arbitrary pytree (params, opt state, rng, loader state)."""
        self.wait()  # one outstanding async save at a time
        arrays, treedef = _flatten(state)
        meta = {
            "step": int(step),
            "treedef": pickle.dumps(treedef).hex(),
            "extra": extra or {},
            "time": time.time(),
            "format": "single-host-npz-v1",
        }

        def work():
            try:
                tmp = self.dir / f"tmp.{step}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                # npz to a temp path + atomic rename: a crash mid-savez
                # can never leave a truncated arrays.npz behind
                np.savez(tmp / "arrays.tmp.npz", **arrays)
                os.replace(tmp / "arrays.tmp.npz", tmp / "arrays.npz")
                (tmp / "meta.tmp.json").write_text(json.dumps(meta))
                os.replace(tmp / "meta.tmp.json", tmp / "meta.json")
                # terminal marker: written last, checked by all_steps()
                (tmp / "DONE").write_text("ok")
                final = self.dir / f"step_{step:010d}"
                if final.exists():
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            work()
            self.check()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self.check()

    def check(self) -> None:
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError("async checkpoint save failed") from e

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        """Completed steps only: a dir without the terminal ``DONE``
        marker is half-written (crashed mid-save) and is never offered
        for restore."""
        return sorted(int(p.name.split("_")[1])
                      for p in self.dir.glob("step_*")
                      if (p / "DONE").exists())

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, *, like: Any = None,
                shardings: Any = None) -> tuple[int, Any, dict]:
        """Returns (step, state, extra).  ``shardings``: optional pytree of
        NamedShardings (same structure as state) to re-lay-out onto a new
        mesh (elastic restart); ``like``: optional pytree whose dtypes are
        enforced (guards against dtype drift)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:010d}"
        if d.exists() and not (d / "DONE").exists():
            raise FileNotFoundError(
                f"checkpoint {d} is half-written (no DONE marker); it was "
                f"interrupted mid-save — restore an earlier step")
        meta = json.loads((d / "meta.json").read_text())
        with np.load(d / "arrays.npz") as z:
            arrays = [z[f"leaf_{i}"] for i in range(len(z.files))]
        treedef = pickle.loads(bytes.fromhex(meta["treedef"]))
        state = jax.tree_util.tree_unflatten(treedef, arrays)
        if like is not None:
            state = jax.tree.map(lambda ref, a: np.asarray(a, ref.dtype),
                                 like, state)
        if shardings is not None:
            state = jax.tree.map(
                lambda a, sh: jax.device_put(a, sh), state, shardings)
        else:
            state = jax.tree.map(jax.numpy.asarray, state)
        return int(meta["step"]), state, meta.get("extra", {})
