"""Packed serving artifact — the calibration→serving hand-off format.

The RSQ pipeline's sharded write-back emits, per quantized weight, the
*packed* int codes (``quantizer.pack_codes``) plus per-group ``(scale,
zero)`` — 4-8x smaller than the fp tensor.  This module persists and
reloads that artifact:

  * **save** — every jax leaf is written one *addressable shard at a time*
    (``Array.addressable_shards``): on a d_out-sharded code tensor the host
    never copies more than one shard's data at once into the write buffer.
    The only routine that pulls a *full* array to the host is
    :func:`_host_gather`; the sharded write-back path never calls it (the
    regression test for the pipeline asserts exactly this), it exists for
    the legacy host-gather baseline and for plain-numpy leaves.  The
    on-disk artifact is one npz written by the single controller; true
    multi-host pods need per-host shard files (one npz per host, merged
    index in meta.json) — a recorded ROADMAP item, not yet built.
  * **load** — shards are reassembled on host *in packed form* (host memory
    only ever holds compressed bytes + the small group params) and the fp
    weight first exists on device, via ``quantizer.dequantize_packed``
    inside :func:`load_packed_params` — or **never**, via
    :func:`load_packed_forward_params`, which rebuilds the serving param
    tree with every quantized matrix as a ``PackedWeight`` pytree node:
    the codes stay packed in HBM and the model's ``linear`` dispatcher
    feeds them straight to the ``quant_matmul`` kernel.

Durability (format v3)
----------------------
Artifacts are *atomic and verified*: every file is written to a temp path
and ``os.replace``'d into place (a crash mid-save never leaves a truncated
artifact where a loader could find it), the npz payloads are written with
canonical zip metadata (fixed timestamps, stored entries) so two runs that
produce the same arrays produce **byte-identical files** — the contract the
kill-and-resume parity tests pin — and ``meta.json`` records the SHA-256 of
each payload file.  Loaders verify the checksum before deserializing and
raise :class:`ArtifactCorruptError` with an actionable message on mismatch
(``verify=False`` / ``launch.serve --no-verify`` opts out); v2/v1 artifacts
predate the checksum contract and load unverified.

On-disk layout (``<dir>/``):

  meta.json     — format tag, quant spec, per-file sha256 checksums,
                  per-entry metadata (d_in, group_size, dtype, layer
                  location) and the shard index map of every saved field —
                  packed *and* residual
  packed.npz    — ``"<entry>/<field>@<k>"`` -> the k-th shard's local data
  residual.npz  — the unquantized remainder of the param tree (norms,
                  routers, embeddings, ...) with quantized leaves replaced
                  by empty markers; written per addressable shard exactly
                  like the packed leaves (``"leaf_<i>@<k>"`` + shard index
                  in meta.json), so a d_out/vocab-sharded residual leaf
                  never gathers on the controller; treedef pickled in
                  meta.json
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import pickle
import zipfile
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizer import dequantize_packed
from repro.kernels.quant_matmul.ops import PackedWeight
from repro.runtime.sharding import LOCAL, ParallelCtx

FORMAT = "rsq-packed-v3"  # v3: per-file sha256 checksums + atomic,
# byte-deterministic writes (v2: shard-indexed residual; v1: whole-leaf)
_READABLE = (FORMAT, "rsq-packed-v2", "rsq-packed-v1")
_FIELDS = ("codes", "scale", "zero")


class ArtifactCorruptError(RuntimeError):
    """A packed artifact file failed its recorded SHA-256 check."""


def _sha256_file(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _savez_atomic(path: Path, arrays: dict) -> str:
    """Write ``arrays`` as an npz at ``path`` atomically (temp file +
    ``os.replace``) and *canonically*: fixed zip timestamps and stored
    (uncompressed) members, so identical arrays written in identical
    order produce byte-identical files — ``np.savez`` stamps the current
    time into each zip header, which would break the resumed-vs-
    uninterrupted byte-parity contract.  Members are serialized one at a
    time (like ``np.savez``), so host memory still holds at most one
    shard's bytes beyond the write buffer.  Returns the file's sha256."""
    from numpy.lib import format as npformat

    tmp = path.with_suffix(path.suffix + ".tmp")
    with zipfile.ZipFile(tmp, "w", zipfile.ZIP_STORED,
                         allowZip64=True) as zf:
        for name, arr in arrays.items():
            buf = io.BytesIO()
            npformat.write_array(buf, np.asarray(arr), allow_pickle=False)
            zi = zipfile.ZipInfo(name + ".npy",
                                 date_time=(1980, 1, 1, 0, 0, 0))
            zi.compress_type = zipfile.ZIP_STORED
            zi.external_attr = 0o600 << 16
            zf.writestr(zi, buf.getvalue())
    sha = _sha256_file(tmp)
    os.replace(tmp, path)
    return sha


def _verify_file(d: Path, meta: dict, fname: str) -> None:
    """Check ``fname`` against the checksum recorded in ``meta``.

    Only v3 artifacts carry the checksum contract; earlier formats load
    unverified (they predate it)."""
    checksums = meta.get("checksums")
    if meta.get("format") != FORMAT or not checksums or fname not in checksums:
        return
    got = _sha256_file(d / fname)
    want = checksums[fname]
    if got != want:
        raise ArtifactCorruptError(
            f"{d / fname} is corrupt: sha256 {got[:16]}… does not match the "
            f"recorded {want[:16]}….  The artifact was truncated or "
            f"bit-flipped after save — re-run `launch.quantize --pack-out "
            f"{d}` to regenerate it, or pass verify=False "
            f"(launch.serve --no-verify) to serve it anyway at your own "
            f"risk.")


def _host_gather(x) -> np.ndarray:
    """Materialize a *full* (unsharded) array on host.

    This is the single host-gather of the module: the legacy write-back
    baseline (``RSQConfig.pack_writeback='host'``) routes every per-layer
    ``(q, scales, zeros)`` through it; the sharded write-back path never
    reaches it for any packed leaf."""
    return np.asarray(jax.device_get(x))


def _shard_items(x) -> list[tuple[list[list[int]], np.ndarray]]:
    """(index, local data) per distinct addressable shard of ``x``.

    Replicas beyond the first of each shard are skipped; a numpy array (the
    host-gather baseline) is a single full shard."""
    if isinstance(x, np.ndarray):
        return [([[0, d] for d in x.shape], x)]
    items, seen = [], set()
    for sh in x.addressable_shards:
        idx = tuple(s.indices(d)[:2] for s, d in zip(sh.index, x.shape))
        if idx in seen:
            continue
        seen.add(idx)
        items.append(([list(p) for p in idx], np.asarray(sh.data)))
    return items


def _save_field(arrays: dict, key: str, x) -> dict:
    """Append ``x`` to the write buffer one addressable shard at a time;
    returns the field's shard-index metadata for meta.json."""
    shards = _shard_items(x)
    for k, (idx, data) in enumerate(shards):
        arrays[f"{key}@{k}"] = data
    return {
        "shape": [int(s) for s in x.shape],
        "dtype": str(np.dtype(shards[0][1].dtype)),
        "shards": [idx for idx, _ in shards],
    }


def _assemble_field(z, key: str, fm: dict) -> np.ndarray:
    out = np.empty(tuple(fm["shape"]), np.dtype(fm["dtype"]))
    for k, idx in enumerate(fm["shards"]):
        sl = tuple(slice(lo, hi) for lo, hi in idx)
        out[sl] = z[f"{key}@{k}"]
    return out


def save_packed_artifact(directory, artifact: dict, *,
                         params: Any = None, extra: dict | None = None,
                         ) -> Path:
    """Persist a pipeline artifact (``RSQPipeline.artifact``) to ``dir``.

    ``params``: the quantized param tree; its quantized leaves are replaced
    by empty markers and the remainder is stored as the fp residual so the
    loaders can reconstruct a complete model.  Residual leaves are written
    through the same per-addressable-shard path as the packed leaves — a
    vocab-sharded embedding or d_out-sharded router is never gathered into
    one controller buffer."""
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    arrays: dict[str, np.ndarray] = {}
    meta_entries: dict[str, dict] = {}
    for name, entry in artifact["entries"].items():
        em = dict(artifact["meta"][name])
        em["fields"] = {
            field: _save_field(arrays, f"{name}/{field}", entry[field])
            for field in _FIELDS
        }
        meta_entries[name] = em

    meta = {"format": FORMAT, "spec": artifact["spec"],
            "entries": meta_entries, "extra": extra or {},
            "checksums": {}}
    if params is not None:
        residual = _strip_quantized(params, meta_entries)
        leaves, treedef = jax.tree_util.tree_flatten(residual)
        res_arrays: dict[str, np.ndarray] = {}
        meta["residual_leaves"] = [
            _save_field(res_arrays, f"leaf_{i}", leaf)
            for i, leaf in enumerate(leaves)
        ]
        meta["residual_treedef"] = pickle.dumps(treedef).hex()
        meta["checksums"]["residual.npz"] = _savez_atomic(
            d / "residual.npz", res_arrays)
    meta["checksums"]["packed.npz"] = _savez_atomic(d / "packed.npz", arrays)
    # meta.json last and atomically: its presence with checksums implies
    # the payload files it describes are complete
    tmp = d / "meta.tmp.json"
    tmp.write_text(json.dumps(meta))
    os.replace(tmp, d / "meta.json")
    return d


# --------------------------------------------------------------- tree paths


def _leaf_slot(params: Any, em: dict) -> tuple[Any, str, Optional[int]]:
    """(parent node, leaf key, stack index or None) for one entry's leaf."""
    kind = em["loc"][0]
    parts = em["path"].split("/")
    if kind == "prefix":
        node, g = params["prefix"][em["loc"][1]], None
    elif kind == "groups":
        node, g = params["groups"][f"b{em['loc'][2]}"], em["loc"][1]
    else:  # "enc"
        node, g = params["encoder"]["groups"]["b0"], em["loc"][1]
    for key in parts[:-1]:
        node = node[key]
    return node, parts[-1], g


def _strip_quantized(params: Any, meta_entries: dict) -> Any:
    """Copy of ``params`` with every packed leaf replaced by a marker (the
    whole stacked tensor, for group layers — each of its layer slices is
    reconstructed from the artifact)."""
    out = jax.tree.map(lambda x: x, params)
    if "prefix" in out:
        out["prefix"] = [jax.tree.map(lambda x: x, p) for p in out["prefix"]]
    marker = np.zeros((0,), np.int8)
    for em in meta_entries.values():
        node, leaf, _ = _leaf_slot(out, em)
        node[leaf] = marker
    return out


# -------------------------------------------------------------------- load


def _assemble_entry(z, name: str, em: dict) -> dict:
    return {field: _assemble_field(z, f"{name}/{field}", fm)
            for field, fm in em["fields"].items()}


def load_packed_artifact(directory, *, verify: bool = True
                         ) -> tuple[dict, dict]:
    """-> (entries, meta): per-entry assembled numpy ``codes/scale/zero``.

    Host memory holds only the packed representation; dequantization is the
    caller's (device-side) concern.  ``verify`` (default) checks
    ``packed.npz`` against the sha256 recorded in meta.json (v3 artifacts)
    and raises :class:`ArtifactCorruptError` on mismatch rather than
    serving silently corrupt codes."""
    d = Path(directory)
    meta = json.loads((d / "meta.json").read_text())
    assert meta["format"] in _READABLE, \
        f"unreadable artifact format {meta['format']!r}; " \
        f"re-run launch.quantize --pack-out (readable: {_READABLE})"
    if verify:
        _verify_file(d, meta, "packed.npz")
    with np.load(d / "packed.npz") as z:
        entries = {name: _assemble_entry(z, name, em)
                   for name, em in meta["entries"].items()}
    return entries, meta


def load_packed_entry(directory, name: str, *, verify: bool = False) -> dict:
    """Assemble a single entry's ``codes/scale/zero`` (npz members load
    lazily, so this reads just that weight's shards — handy for spot checks
    against a large artifact).  ``verify=True`` hashes the *whole*
    packed.npz first, which defeats the lazy read — default off here, on
    for the full-artifact loaders."""
    d = Path(directory)
    meta = json.loads((d / "meta.json").read_text())
    assert meta["format"] in _READABLE, \
        f"unreadable artifact format {meta['format']!r}; " \
        f"re-run launch.quantize --pack-out (readable: {_READABLE})"
    if verify:
        _verify_file(d, meta, "packed.npz")
    with np.load(d / "packed.npz") as z:
        return _assemble_entry(z, name, meta["entries"][name])


def dequantize_entry(entry: dict, em: dict, spec: dict) -> jax.Array:
    """One artifact entry -> fp weight, on device (packed bytes in, fp out)."""
    w = dequantize_packed(
        jnp.asarray(entry["codes"]), jnp.asarray(entry["scale"]),
        jnp.asarray(entry["zero"]), bits=int(spec["bits"]),
        d_in=int(em["d_in"]))
    return w.astype(em.get("dtype", "float32"))


def _load_residual(directory, meta: dict, *, verify: bool = True) -> Any:
    """Reassemble the fp residual tree from its per-shard members
    (v1 artifacts stored each leaf whole — load those as-is)."""
    d = Path(directory)
    if verify:
        _verify_file(d, meta, "residual.npz")
    with np.load(d / "residual.npz") as z:
        if "residual_leaves" in meta:
            leaves = [_assemble_field(z, f"leaf_{i}", fm)
                      for i, fm in enumerate(meta["residual_leaves"])]
        else:  # rsq-packed-v1
            leaves = [z[f"leaf_{i}"] for i in range(len(z.files))]
    treedef = pickle.loads(bytes.fromhex(meta["residual_treedef"]))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _stacked_slots(params: Any, meta: dict):
    """Group artifact entries by their target leaf: yields
    ``(node, leaf, em, per_layer)`` where ``per_layer`` maps the stacked
    layer index (or None for a plain leaf) to that layer's entry name."""
    def stack_key(em) -> tuple:
        o = em["loc"][2] if em["loc"][0] == "groups" else 0
        return (em["loc"][0], o, em["path"])

    stacked: dict[tuple, dict[int, str]] = {}
    stacked_em: dict[tuple, dict] = {}
    for name, em in meta["entries"].items():
        node, leaf, g = _leaf_slot(params, em)
        if g is None:
            yield node, leaf, em, {None: name}
        else:
            stacked.setdefault(stack_key(em), {})[g] = name
            stacked_em[stack_key(em)] = em
    for key, per_layer in stacked.items():
        n = max(per_layer) + 1
        assert sorted(per_layer) == list(range(n)), \
            f"artifact is missing layers for {key}: {sorted(per_layer)}"
        em = stacked_em[key]
        node, leaf, _ = _leaf_slot(params, em)
        yield node, leaf, em, {g: per_layer[g] for g in range(n)}


def load_packed_params(directory, *, verify: bool = True) -> tuple[Any, dict]:
    """-> (params, meta): a complete *dequantized* param tree for serving.

    The fp residual loads as saved; every quantized weight is rebuilt on
    device from its packed entry (group layers re-stack their per-layer
    entries along the stacked axis) — the unpacked weight never exists on
    host.  For packed-in-HBM serving (no fp weight anywhere) use
    :func:`load_packed_forward_params` instead.  ``verify`` checks both
    payload files against their recorded sha256 (v3) before loading."""
    d = Path(directory)
    entries, meta = load_packed_artifact(d, verify=verify)
    params = _load_residual(d, meta, verify=verify)
    for node, leaf, em, per_layer in _stacked_slots(params, meta):
        ws = [dequantize_entry(entries[per_layer[g]], em, meta["spec"])
              for g in sorted(per_layer, key=lambda g: -1 if g is None else g)]
        node[leaf] = ws[0] if None in per_layer else jnp.stack(ws)
    params = jax.tree.map(jnp.asarray, params)
    return params, meta


def load_packed_forward_params(directory, ctx: ParallelCtx = LOCAL, *,
                               verify: bool = True) -> tuple[Any, dict]:
    """-> (params, meta): serving params with the codes *kept packed in HBM*.

    Every quantized matrix lands in the tree as a ``PackedWeight`` pytree
    node (uint32 codes + per-group scale/zero; static quant geometry as
    aux data) that the model's ``linear`` dispatcher routes through the
    fused dequant-GEMM ``quant_matmul``.  No fp array of any quantized
    weight's full shape is ever created — not on host (shards reassemble
    in packed form) and not on device (the kernel dequantizes tile-wise
    in VMEM), with no exceptions: even MLA's absorbed decode contracts
    the per-head ``wkv_b`` views on packed codes (``mla_latent_weights``
    + the latent-layout ``quant_matmul_t`` — ``attention.mla_decode``).
    Resident weight HBM is therefore ~bits/16 of the bf16 model (bits/32
    of fp32) plus the small group params.

    Stacked layer groups re-stack per-layer *codes* along the leading
    axis, so the stacked ``PackedWeight`` rides the model's ``lax.scan``
    unchanged; expert entries keep their leading (E,) axis and dispatch
    through the vmapped kernel.  With a live mesh ``ctx``, codes / scale /
    zero are placed d_out-sharded on the model axis (the decode-serving
    layout: output-dim sharded weights, no per-token weight gathers) and
    the ``PackedWeight`` carries the (mesh, axis) placement in its aux, so
    ``quant_matmul`` can run the fused Pallas kernel per shard under
    ``shard_map`` instead of demoting sharded codes to the ref GEMM.

    ``verify`` (default) checks each payload file against the sha256
    recorded in meta.json before deserializing — a truncated or bit-
    flipped artifact fails with :class:`ArtifactCorruptError` instead of
    serving garbage codes."""
    d = Path(directory)
    entries, meta = load_packed_artifact(d, verify=verify)
    params = _load_residual(d, meta, verify=verify)
    spec = meta["spec"]

    def put(a: np.ndarray) -> tuple[jax.Array, bool]:
        a = jnp.asarray(a)
        if (ctx.enabled and ctx.tp
                and a.shape[-1] % ctx.axis_size("tp") == 0):
            from jax.sharding import NamedSharding, PartitionSpec as P
            sh = NamedSharding(ctx.mesh, P(*([None] * (a.ndim - 1)), ctx.tp))
            return jax.device_put(a, sh), ctx.axis_size("tp") > 1
        return a, False

    for node, leaf, em, per_layer in _stacked_slots(params, meta):
        order = sorted(per_layer, key=lambda g: -1 if g is None else g)
        fields = {}
        for f in _FIELDS:
            per = [entries[per_layer[g]][f] for g in order]
            fields[f] = per[0] if None in per_layer else np.stack(per)
        codes, sharded = put(fields["codes"])
        node[leaf] = PackedWeight(
            w_packed=codes, scale=put(fields["scale"])[0],
            zero=put(fields["zero"])[0], bits=int(spec["bits"]),
            group_size=int(em["group_size"]), d_in=int(em["d_in"]),
            # partitioned codes must never reach GSPMD as an opaque Pallas
            # call (it would all-gather them); the (mesh, axis) aux lets
            # quant_matmul shard_map the kernel over the model axis, with
            # the partitionable ref GEMM as its fallback
            mesh_sharded=sharded,
            mesh=ctx.mesh if sharded else None,
            mesh_axis=ctx.tp if sharded else None)
    params = jax.tree.map(jnp.asarray, params)
    return params, meta
