from repro.checkpoint.checkpoint import CheckpointManager  # noqa: F401
from repro.checkpoint.packed import (  # noqa: F401
    ArtifactCorruptError,
    load_packed_artifact,
    load_packed_forward_params,
    load_packed_params,
    save_packed_artifact,
)
