"""Calibration-set construction for the RSQ pipeline.

Two modes:

  * ``calibration_set`` — the classic single-host (n_samples, seq_len)
    token matrix (plus the paper's dataset-expansion hook, core/expansion).
  * sharded calib — ``calibration_shard`` / ``CalibShard`` draw a
    *disjoint, contiguous* slice of the exact same global set, deterministic
    in ``(seed, shard)``: every row is sampled by its global index
    (``SyntheticCorpus.sample_indexed``), so shard s materializes only rows
    ``[s·N/S, (s+1)·N/S)`` and the union over shards is bit-identical to the
    global draw.  Slices are contiguous (not strided) so that, assembled
    into a jax.Array sharded over the mesh's data axes
    (``data/loader.CalibrationLoader``), each device's rows are precisely
    the rows it generated — and the flattened token rows line up with the
    contiguous chunks of the streaming Hessian accumulators
    (``hessian.accumulate(n_shards=S)``), which is what lets a calibration
    batch feed the sharded accumulators with no global materialization and
    no per-batch collective.

``CalibShard`` is also a seekable batch iterator (``state``/``restore``),
so a pod-scale calibration pass resumes exactly under ``(seed, step)``
after a restart — same contract as ``data/loader.DataLoader``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.data.synthetic import SyntheticCorpus


def _calib_key(seed: int):
    return jax.random.fold_in(jax.random.key(seed), 777)


def calibration_set(vocab_size: int, n_samples: int, seq_len: int,
                    seed: int = 0, corpus: SyntheticCorpus | None = None):
    corpus = corpus or SyntheticCorpus(vocab_size=vocab_size, seed=seed)
    return corpus.sample_indexed(_calib_key(seed), jnp.arange(n_samples),
                                 seq_len)


def shard_bounds(n_samples: int, n_shards: int, shard: int) -> tuple[int, int]:
    """Contiguous row range [lo, hi) owned by ``shard`` of ``n_shards``.

    np.array_split semantics: the first ``n_samples % n_shards`` shards get
    one extra row, so the slices are disjoint and cover [0, n_samples)."""
    assert 0 <= shard < n_shards, (shard, n_shards)
    base, rem = divmod(n_samples, n_shards)
    lo = shard * base + min(shard, rem)
    return lo, lo + base + (1 if shard < rem else 0)


def calibration_shard(vocab_size: int, n_samples: int, seq_len: int, *,
                      shard: int, n_shards: int, seed: int = 0,
                      corpus: SyntheticCorpus | None = None):
    """Rows [lo, hi) of ``calibration_set`` — only they are materialized."""
    corpus = corpus or SyntheticCorpus(vocab_size=vocab_size, seed=seed)
    lo, hi = shard_bounds(n_samples, n_shards, shard)
    return corpus.sample_indexed(_calib_key(seed), jnp.arange(lo, hi),
                                 seq_len)


@dataclasses.dataclass
class CalibShard:
    """One data-parallel group's view of the calibration set.

    ``take(lo, hi)`` materializes an arbitrary *global* row range restricted
    to this shard; iteration yields this shard's slice of global batch
    ``step`` (rows ``[step·B, (step+1)·B) ∩ [shard range)``), deterministic
    and seekable in ``(seed, step)``."""

    corpus: SyntheticCorpus
    n_samples: int
    seq_len: int
    shard: int = 0
    n_shards: int = 1
    batch_size: int = 8
    seed: int = 0
    step: int = 0

    def __post_init__(self):
        self.lo, self.hi = shard_bounds(self.n_samples, self.n_shards,
                                        self.shard)

    # ------------------------------------------------------------- seekable
    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed, "shard": self.shard}

    def restore(self, state: dict) -> None:
        assert int(state.get("shard", self.shard)) == self.shard, \
            "restoring a different shard's loader state"
        assert int(state.get("seed", self.seed)) == self.seed, \
            "restoring a different seed's loader state (the resumed " \
            "stream would silently mix two calibration sets)"
        self.step = int(state["step"])

    # ----------------------------------------------------------- generation
    def take(self, lo: int, hi: int) -> jax.Array:
        """Global rows [lo, hi) clipped to this shard's range."""
        lo, hi = max(lo, self.lo), min(hi, self.hi)
        return self.corpus.sample_indexed(
            _calib_key(self.seed), jnp.arange(lo, max(hi, lo)), self.seq_len)

    def local(self) -> jax.Array:
        """This shard's full slice (the per-host calibration residency)."""
        return self.take(self.lo, self.hi)

    def __iter__(self):
        return self

    def __next__(self) -> jax.Array:
        lo = self.step * self.batch_size
        if lo >= self.n_samples:
            raise StopIteration
        out = self.take(lo, lo + self.batch_size)
        self.step += 1
        return out
