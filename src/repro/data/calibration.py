"""Calibration-set construction for the RSQ pipeline: n_samples x seq_len
token matrix + the paper's dataset-expansion hook (core/expansion)."""
from __future__ import annotations

import jax

from repro.data.synthetic import SyntheticCorpus


def calibration_set(vocab_size: int, n_samples: int, seq_len: int,
                    seed: int = 0, corpus: SyntheticCorpus | None = None):
    corpus = corpus or SyntheticCorpus(vocab_size=vocab_size, seed=seed)
    key = jax.random.fold_in(jax.random.key(seed), 777)
    return corpus.sample(key, n_samples, seq_len)
