from repro.data.synthetic import SyntheticCorpus, zipf_tokens  # noqa: F401
from repro.data.calibration import (  # noqa: F401
    CalibShard,
    calibration_set,
    calibration_shard,
    shard_bounds,
)
from repro.data.loader import CalibrationLoader, DataLoader  # noqa: F401
