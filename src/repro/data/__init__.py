from repro.data.synthetic import SyntheticCorpus, zipf_tokens  # noqa: F401
from repro.data.calibration import calibration_set  # noqa: F401
from repro.data.loader import DataLoader  # noqa: F401
