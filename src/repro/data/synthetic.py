"""Deterministic synthetic corpus (offline container: no WikiText).

A Zipf-distributed unigram stream is made *learnable* by a second-order
Markov mixing step: token t depends on (t-1, t-2) through fixed random
permutations, so a trained LM achieves far-below-unigram perplexity and
quantization damage is measurable — the property the paper's benchmarks
need (PPL orderings, not absolute values).

``n_topics > 1`` makes the first token(s) *globally important*: the sample's
topic (declared by token 0) selects which permutation table drives the
Markov structure, so a model must attend to the sequence start from every
position — recreating the attention-concentration-on-initial-tokens
phenomenon (StreamingLLM / Sun et al.) that RSQ's chunk observation and
AttnCon strategy exploit.  Without it, a purely local corpus cannot exhibit
the paper's "important token" structure at all.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def zipf_tokens(key, shape, vocab: int, alpha: float = 1.2) -> jax.Array:
    """Zipf(alpha) token ids in [2, vocab) (0/1 reserved bos/pad)."""
    ranks = np.arange(1, max(vocab - 2, 1) + 1, dtype=np.float64)
    probs = ranks ** -alpha
    probs /= probs.sum()
    logits = jnp.asarray(np.log(probs), jnp.float32)
    flat = jax.random.categorical(key, logits, shape=(int(np.prod(shape)),))
    return (flat + 2).reshape(shape).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class SyntheticCorpus:
    vocab_size: int
    seed: int = 0
    alpha: float = 1.2
    markov_strength: float = 0.75  # fraction of positions made predictable
    n_topics: int = 4  # topic (token 0) selects the transition table

    def _perms(self):
        rng = np.random.RandomState(self.seed + 1)
        p1 = np.stack([rng.permutation(self.vocab_size)
                       for _ in range(max(self.n_topics, 1))])
        p2 = rng.permutation(self.vocab_size)
        return jnp.asarray(p1), jnp.asarray(p2)

    def sample(self, key, batch: int, seq_len: int) -> jax.Array:
        """(batch, seq_len) int32, deterministic in (seed, key)."""
        k1, k2, k3 = jax.random.split(key, 3)
        base = zipf_tokens(k1, (batch, seq_len), self.vocab_size, self.alpha)
        p1, p2 = self._perms()
        keep = jax.random.bernoulli(k2, 1.0 - self.markov_strength,
                                    (batch, seq_len))
        n_t = max(self.n_topics, 1)
        topic = jax.random.randint(k3, (batch,), 0, n_t)
        # token 0 declares the topic (reserved ids [2, 2 + n_topics))
        topic_tok = (topic + 2).astype(jnp.int32)

        def step(carry, xs):
            t1, t2 = carry
            b, kp = xs
            det = (p1[topic, t1] + p2[t2]) % self.vocab_size
            tok = jnp.where(kp, b, det).astype(jnp.int32)
            return (tok, t1), tok

        (_, _), toks = jax.lax.scan(
            step, (topic_tok, topic_tok),
            (base[:, 1:].swapaxes(0, 1), keep[:, 1:].swapaxes(0, 1)))
        return jnp.concatenate([topic_tok[:, None], toks.swapaxes(0, 1)],
                               axis=1)

    def sample_indexed(self, key, indices, seq_len: int) -> jax.Array:
        """(len(indices), seq_len) int32 — sample rows *by global index*.

        Row ``i`` depends only on ``(key, indices[i])``: sampling any subset
        of indices yields exactly the corresponding rows of the full set.
        This is the per-sample determinism contract the sharded calibration
        loader builds on (data/calibration.py): each data-parallel group
        materializes only its own disjoint index slice, and the union over
        groups is bit-identical to the single-host global draw."""
        indices = jnp.asarray(indices, jnp.int32)
        keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(indices)
        return jax.vmap(lambda k: self.sample(k, 1, seq_len)[0])(keys)

    def batches(self, batch: int, seq_len: int, n_steps: int,
                start_step: int = 0):
        """Deterministic, seekable iterator — the data-side contract that
        makes checkpoint-resume exact and host-local (no cross-host I/O
        dependency -> no data-induced stragglers)."""
        for step in range(start_step, n_steps):
            key = jax.random.fold_in(jax.random.key(self.seed), step)
            toks = self.sample(key, batch, seq_len)
            yield {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
