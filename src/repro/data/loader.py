"""Sharded, seekable data loader.

Each host materializes only its slice of the global batch (host-local,
deterministic in (seed, step)) and the arrays are assembled into globally
sharded jax.Arrays — resume-exact after checkpoint restart and free of
cross-host data dependencies (straggler mitigation at the input layer).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp

from repro.data.synthetic import SyntheticCorpus
from repro.runtime.sharding import ParallelCtx


@dataclasses.dataclass
class DataLoader:
    corpus: SyntheticCorpus
    global_batch: int
    seq_len: int
    ctx: ParallelCtx = ParallelCtx()
    step: int = 0

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        key = jax.random.fold_in(
            jax.random.key(self.corpus.seed), self.step)
        toks = self.corpus.sample(key, self.global_batch, self.seq_len)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
        if self.ctx.enabled:
            sh = self.ctx.sharding("dp", None)
            batch = jax.device_put(batch, {k: sh for k in batch})
        self.step += 1
        return batch
