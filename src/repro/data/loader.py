"""Sharded, seekable data loaders.

Each host materializes only its slice of the global batch (host-local,
deterministic in (seed, step)) and the arrays are assembled into globally
sharded jax.Arrays — resume-exact after checkpoint restart and free of
cross-host data dependencies (straggler mitigation at the input layer).

``DataLoader`` is the training-side iterator.  ``CalibrationLoader`` is the
calib mode: each data-parallel group draws a *disjoint* contiguous slice of
the calibration set (``data/calibration.CalibShard`` — deterministic in
``(seed, shard)``) and the slices are assembled into a globally-sharded
(N, T) array via ``jax.make_array_from_callback``, so the per-device buffer
is generated from that device's global index range and the unsharded batch
is never materialized anywhere.  Because slices are contiguous and land on
the mesh's data axes, the flattened token rows coincide with the contiguous
chunks of the streaming Hessian accumulators
(``hessian.accumulate(n_shards=S)``): calibration bytes flow host-shard ->
device-shard -> sharded accumulator with zero per-batch collectives.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.calibration import CalibShard
from repro.data.synthetic import SyntheticCorpus
from repro.runtime.sharding import ParallelCtx


@dataclasses.dataclass
class DataLoader:
    corpus: SyntheticCorpus
    global_batch: int
    seq_len: int
    ctx: ParallelCtx = ParallelCtx()
    step: int = 0

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        key = jax.random.fold_in(
            jax.random.key(self.corpus.seed), self.step)
        toks = self.corpus.sample(key, self.global_batch, self.seq_len)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
        if self.ctx.enabled:
            sh = self.ctx.sharding("dp", None)
            batch = jax.device_put(batch, {k: sh for k in batch})
        self.step += 1
        return batch


@dataclasses.dataclass
class CalibrationLoader:
    """Per-group sharded calibration feed (see module docstring).

    ``dataset()`` returns the full (n_samples, seq_len) calibration set as
    one globally-sharded array; iteration yields per-step (batch, seq_len)
    sharded batches for streaming-Hessian consumers
    (``core.distributed.make_sharded_hessian_fn(streaming=True)``).  Both
    are deterministic in ``(seed, shard)`` and the iterator is seekable in
    ``(seed, step)`` — exact resume after an interrupted calibration pass.

    Without a mesh (``ctx.enabled`` False) there is a single shard and the
    loader degenerates to ``calibration_set`` exactly.
    """

    corpus: SyntheticCorpus
    n_samples: int
    seq_len: int
    ctx: ParallelCtx = ParallelCtx()
    batch_size: int = 8
    seed: int = 0
    step: int = 0

    def __post_init__(self):
        self.n_shards = max(self.ctx.axis_size("dp"), 1)
        if self.ctx.enabled and self.n_shards > 1:
            assert self.n_samples % self.n_shards == 0, (
                f"n_samples={self.n_samples} must divide over the "
                f"{self.n_shards}-way data axis for an even mesh layout")
            # every iterated batch (incl. the final partial one, whose size
            # is n_samples mod batch_size and therefore also divisible)
            # must tile over the data axis — make_array_from_callback
            # cannot shard a ragged leading dim
            assert self.batch_size % self.n_shards == 0, (
                f"batch_size={self.batch_size} must divide over the "
                f"{self.n_shards}-way data axis")
        self._shards = [
            CalibShard(self.corpus, self.n_samples, self.seq_len,
                       shard=s, n_shards=self.n_shards,
                       batch_size=self.batch_size, seed=self.seed)
            for s in range(self.n_shards)]

    # ------------------------------------------------------------- seekable
    def state(self) -> dict:
        """Everything needed to reseek *and* to catch a mis-configured
        restart: a checkpoint taken with one calibration geometry must not
        silently resume under another (the Hessian partial sums would mix
        token sets)."""
        return {"step": self.step, "seed": self.seed,
                "n_samples": self.n_samples, "seq_len": self.seq_len,
                "batch_size": self.batch_size}

    def restore(self, state: dict) -> None:
        for field, mine in (("seed", self.seed),
                            ("n_samples", self.n_samples),
                            ("seq_len", self.seq_len),
                            ("batch_size", self.batch_size)):
            theirs = state.get(field)
            if theirs is not None and int(theirs) != mine:
                raise ValueError(
                    f"loader state mismatch: checkpoint has {field}="
                    f"{theirs}, this loader has {mine} — resuming would "
                    f"feed a different calibration stream")
        self.step = int(state["step"])

    # ------------------------------------------------------------- assembly
    def _assemble(self, n_rows: int, gen) -> jax.Array:
        """Build a globally-sharded (n_rows, seq_len) int32 array where each
        device's buffer comes from ``gen(lo, hi)`` over its own global row
        range only — the unsharded array is never formed."""
        if not self.ctx.enabled or self.n_shards == 1:
            return gen(0, n_rows)
        sharding = self.ctx.sharding("dp", None)
        cache: dict[tuple[int, int], np.ndarray] = {}

        def cb(index):
            rs = index[0]
            lo = rs.start or 0
            hi = rs.stop if rs.stop is not None else n_rows
            if (lo, hi) not in cache:  # one generation per distinct slice
                cache[(lo, hi)] = np.asarray(gen(lo, hi))
            return cache[(lo, hi)]

        return jax.make_array_from_callback(
            (n_rows, self.seq_len), sharding, cb)

    def _rows(self, lo: int, hi: int) -> jax.Array:
        """Global rows [lo, hi), pulled from the owning shard(s)."""
        parts = [sh.take(lo, hi) for sh in self._shards
                 if sh.hi > lo and sh.lo < hi]
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts)

    def dataset(self) -> jax.Array:
        """The full calibration set, sharded over the data axes.

        Device d generates rows from the shard(s) covering its index range
        (contiguous, disjoint, deterministic in ``(seed, shard)``); on a
        multi-host pod each host only ever touches its addressable slices.
        """
        return self._assemble(self.n_samples, self._rows)

    # ------------------------------------------------------------ iteration
    def __iter__(self) -> Iterator[jax.Array]:
        return self

    def __next__(self) -> jax.Array:
        lo = self.step * self.batch_size
        if lo >= self.n_samples:
            raise StopIteration
        hi = min(lo + self.batch_size, self.n_samples)
        out = self._assemble(
            hi - lo, lambda b_lo, b_hi: self._rows(lo + b_lo, lo + b_hi))
        self.step += 1
        return out
