from repro.optim.optimizers import Optimizer, make_optimizer  # noqa: F401
from repro.optim.schedule import cosine_schedule, linear_warmup  # noqa: F401
