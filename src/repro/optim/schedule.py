"""LR schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(peak_lr: float, warmup_steps: int):
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        return peak_lr * jnp.minimum(1.0, (s + 1) / max(warmup_steps, 1))

    return f


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, (s + 1) / max(warmup_steps, 1))
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup_steps, warm, peak_lr * cos)

    return f
