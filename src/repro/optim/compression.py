"""Gradient compression with error feedback.

``ef_compress`` quantize-dequantizes gradients to int8 (per-row scales) and
carries the residual to the next step (error feedback, Seide et al. /
1-bit-SGD lineage) — converges like fp32 while the wire format is 4x
smaller.  The matching on-wire collective is
runtime/collectives.ring_allreduce_int8; under pure pjit the compression is
applied before the (GSPMD-inserted) reduction over the pod axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _q8_roundtrip(x):
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    s = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / s), -127, 127)
    return q * s


def ef_compress(grads, err_state):
    """Returns (compressed_grads, new_err_state)."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        c = _q8_roundtrip(gf)
        return c.astype(g.dtype), gf - c

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))
