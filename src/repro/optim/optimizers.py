"""Optimizers from scratch: AdamW, 8-bit AdamW (int8 moments + per-row
scales — the memory trick that lets 100B+ models train in one pod), and
Adafactor (factored second moment, optional momentum-free mode — the only
optimizer whose state fits a 671B model on 256 x 16 GB chips), plus SGD.

All are pure pytree transforms: ``state = opt.init(params)``;
``new_params, new_state = opt.update(params, grads, state, step)``.
Master weights are kept in the param dtype (bf16 training uses bf16 params +
fp32 update math, matching the dry-run memory budget).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]


def _lr_at(lr, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


# ------------------------------------------------------------------ int8 pack

def _q8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Quantize fp tensor to int8 with per-row (last-axis) scales."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dq8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


# --------------------------------------------------------------------- AdamW


def make_adamw(lr, *, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0,
               bits8: bool = False) -> Optimizer:
    def init(params):
        def zero(p):
            if bits8:
                q, s = _q8(jnp.zeros(p.shape, jnp.float32))
                return {"q": q, "s": s}
            return jnp.zeros(p.shape, jnp.float32)

        return {
            "m": jax.tree.map(zero, params),
            "v": jax.tree.map(zero, params),
        }

    def update(params, grads, state, step):
        lr_t = _lr_at(lr, step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            mf = _dq8(m["q"], m["s"]) if bits8 else m
            vf = _dq8(v["q"], v["s"]) if bits8 else v
            mf = b1 * mf + (1 - b1) * gf
            vf = b2 * vf + (1 - b2) * gf * gf
            upd = (mf / c1) / (jnp.sqrt(vf / c2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr_t * upd).astype(p.dtype)
            if bits8:
                mq, ms = _q8(mf)
                vq, vs = _q8(vf)
                return new_p, {"q": mq, "s": ms}, {"q": vq, "s": vs}
            return new_p, mf, vf

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer("adamw8bit" if bits8 else "adamw", init, update)


# ------------------------------------------------------------------ Adafactor


def make_adafactor(lr, *, b1=0.0, eps=1e-30, weight_decay=0.0,
                   clip_threshold=1.0) -> Optimizer:
    """Factored second moment over the last two axes; momentum optional
    (b1=0 stores no first moment at all)."""

    def factored(p):
        return p.ndim >= 2

    def init(params):
        def zero_v(p):
            if factored(p):
                return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return jnp.zeros(p.shape, jnp.float32)

        state = {"v": jax.tree.map(zero_v, params)}
        if b1:
            state["m"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return state

    def update(params, grads, state, step):
        lr_t = _lr_at(lr, step)
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** -0.8  # Adafactor's schedule

        def upd(p, g, v, m):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if factored(p):
                r = beta2 * v["r"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                c = beta2 * v["c"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.mean(r, axis=-1, keepdims=True)
                new_v = {"r": r, "c": c}
                # u = g / sqrt(vhat) computed as elementwise products of g
                # with broadcast row/col factors — never materializing the
                # (unsharded!) r (x) c outer product
                u = (gf
                     * jax.lax.rsqrt(jnp.maximum(r, eps))[..., None]
                     * jax.lax.rsqrt(jnp.maximum(c, eps))[..., None, :]
                     * jnp.sqrt(jnp.maximum(denom, eps))[..., None])
            else:
                vhat = beta2 * v + (1 - beta2) * g2
                new_v = vhat
                u = gf * jax.lax.rsqrt(vhat + eps)
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if b1:
                m = b1 * m + (1 - b1) * u
                u = m
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)
            return new_p, new_v, m

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        flat_m = (tdef.flatten_up_to(state["m"]) if b1
                  else [None] * len(flat_p))
        out = [upd(p, g, v, m) for p, g, v, m in
               zip(flat_p, flat_g, flat_v, flat_m)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_state = {"v": tdef.unflatten([o[1] for o in out])}
        if b1:
            new_state["m"] = tdef.unflatten([o[2] for o in out])
        return new_p, new_state

    return Optimizer("adafactor", init, update)


# ----------------------------------------------------------------------- SGD


def make_sgd(lr, *, momentum=0.9, weight_decay=0.0) -> Optimizer:
    def init(params):
        if not momentum:
            return {}
        return {"m": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(params, grads, state, step):
        lr_t = _lr_at(lr, step)

        def upd(p, g, m):
            gf = g.astype(jnp.float32)
            if weight_decay:
                gf = gf + weight_decay * p.astype(jnp.float32)
            if momentum:
                m = momentum * m + gf
                gf = m
            return (p.astype(jnp.float32) - lr_t * gf).astype(p.dtype), m

        if momentum:
            flat_p, tdef = jax.tree.flatten(params)
            flat_g = tdef.flatten_up_to(grads)
            flat_m = tdef.flatten_up_to(state["m"])
            out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
            return (tdef.unflatten([o[0] for o in out]),
                    {"m": tdef.unflatten([o[1] for o in out])})
        new_p = jax.tree.map(lambda p, g: upd(p, g, None)[0], params, grads)
        return new_p, {}

    return Optimizer("sgd", init, update)


def make_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "adamw":
        return make_adamw(lr, **kw)
    if name == "adamw8bit":
        return make_adamw(lr, bits8=True, **kw)
    if name == "adafactor":
        return make_adafactor(lr, **kw)
    if name == "sgd":
        return make_sgd(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
