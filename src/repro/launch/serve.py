"""Batched serving driver: prefill + decode loop with a KV cache, optional
weight-only quantized execution (RSQ output + quant_matmul kernel).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b-smoke \
      --batch 4 --prompt-len 32 --gen 16

``--packed DIR`` serves from a packed RSQ artifact (written by
launch.quantize --pack-out): host memory only ever holds the packed int
codes + group scales; every fp weight is reconstructed on device
(``checkpoint.packed.load_packed_params``), and ``--kernel-check``
additionally runs one projection through the ``quant_matmul`` kernel
straight from the packed codes (no unpacking anywhere on host).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import SyntheticCorpus
from repro.models import build_model


def generate(model, params, prompts, n_gen: int, *, media=None, frames=None,
             temperature: float = 0.0, key=None):
    """prompts: (B, T). Greedy (or sampled) generation of n_gen tokens."""
    b, t = prompts.shape
    logits, cache = jax.jit(
        lambda p, x: model.prefill(p, x, media=media, frames=frames,
                                   cache_len=t + n_gen))(params, prompts)
    step = jax.jit(model.decode_step, donate_argnums=(1,))
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    pos = t
    for i in range(n_gen):
        toks.append(tok)
        logits, cache = step(params, cache, tok, jnp.int32(pos))
        if temperature > 0.0 and key is not None:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / temperature, axis=-1).astype(jnp.int32)[:, None]
        else:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        pos += 1
    return jnp.concatenate(toks, axis=1)


def _kernel_check(packed_dir: str, meta: dict) -> None:
    """Drive ``quant_matmul`` straight from packed artifact codes and
    cross-check against the on-device dequantized matmul.  Loads just the
    one entry it checks (the full artifact was already loaded for params).
    """
    from repro.checkpoint.packed import dequantize_entry, load_packed_entry
    from repro.kernels.quant_matmul.ops import (packed_weight_from_artifact,
                                                quant_matmul)

    name = next((n for n, em in meta["entries"].items()
                 if len(em["fields"]["codes"]["shape"]) == 2), None)
    if name is None:  # all-expert-stack artifact: nothing 2-D to drive
        print("kernel-check: no dense 2-D weight in the artifact; skipped")
        return
    em = meta["entries"][name]
    entry = load_packed_entry(packed_dir, name)
    pw = packed_weight_from_artifact(entry, em, meta["spec"])
    x = jax.random.normal(jax.random.key(7), (8, pw.d_in), jnp.float32)
    y = quant_matmul(x, pw)
    ref = x @ dequantize_entry(entry, em, meta["spec"])
    err = float(jnp.max(jnp.abs(y - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    print(f"kernel-check [{name}]: quant_matmul vs dequant rel_err={err:.2e}")
    assert err < 1e-5, err


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--packed", default=None, metavar="DIR",
                    help="serve from a packed RSQ artifact (written by "
                    "launch.quantize --pack-out): weights travel host->"
                    "device as packed int codes and dequantize on device")
    ap.add_argument("--kernel-check", action="store_true",
                    help="with --packed: also run one projection through "
                    "the quant_matmul kernel directly from the packed codes")
    args = ap.parse_args(argv)
    if args.kernel_check and not args.packed:
        ap.error("--kernel-check requires --packed (it drives the kernel "
                 "from the packed artifact's codes)")

    cfg = dataclasses.replace(get_config(args.arch), dtype=args.dtype)
    model = build_model(cfg)
    if args.packed:
        from repro.checkpoint.packed import load_packed_params

        params, meta = load_packed_params(args.packed)
        arch = meta.get("extra", {}).get("arch")
        assert arch in (None, args.arch), \
            f"artifact was quantized for --arch {arch}, serving {args.arch}"
        import math

        n_packed = len(meta["entries"])
        packed_mb = sum(
            math.prod(em["fields"]["codes"]["shape"]) * 4
            for em in meta["entries"].values()) / 1e6
        print(f"packed artifact: {n_packed} weights, codes {packed_mb:.1f}MB "
              f"(bits={meta['spec']['bits']})")
        if args.kernel_check:
            _kernel_check(args.packed, meta)
    else:
        params = jax.jit(model.init)(jax.random.key(args.seed))
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=args.seed)
    prompts = corpus.sample(jax.random.key(1), args.batch, args.prompt_len)

    t0 = time.time()
    out = generate(model, params, prompts, args.gen)
    jax.block_until_ready(out)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", out[0][:16].tolist())
    return out


if __name__ == "__main__":
    main()
