"""Batched serving driver: prefill + decode loop with a KV cache, optional
weight-only quantized execution (RSQ output + quant_matmul kernel).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b-smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import SyntheticCorpus
from repro.models import build_model


def generate(model, params, prompts, n_gen: int, *, media=None, frames=None,
             temperature: float = 0.0, key=None):
    """prompts: (B, T). Greedy (or sampled) generation of n_gen tokens."""
    b, t = prompts.shape
    logits, cache = jax.jit(
        lambda p, x: model.prefill(p, x, media=media, frames=frames,
                                   cache_len=t + n_gen))(params, prompts)
    step = jax.jit(model.decode_step, donate_argnums=(1,))
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    pos = t
    for i in range(n_gen):
        toks.append(tok)
        logits, cache = step(params, cache, tok, jnp.int32(pos))
        if temperature > 0.0 and key is not None:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / temperature, axis=-1).astype(jnp.int32)[:, None]
        else:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        pos += 1
    return jnp.concatenate(toks, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = dataclasses.replace(get_config(args.arch), dtype=args.dtype)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(args.seed))
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=args.seed)
    prompts = corpus.sample(jax.random.key(1), args.batch, args.prompt_len)

    t0 = time.time()
    out = generate(model, params, prompts, args.gen)
    jax.block_until_ready(out)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", out[0][:16].tolist())
    return out


if __name__ == "__main__":
    main()
