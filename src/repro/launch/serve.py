"""Batched serving driver: prefill + fused decode loop with a KV cache,
optional weight-only quantized execution (RSQ output + quant_matmul kernel).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b-smoke \
      --batch 4 --prompt-len 32 --gen 16

``--packed DIR`` serves from a packed RSQ artifact (written by
launch.quantize --pack-out).  The default is **keep-packed** serving
(``--keep-packed``): the param tree holds the uint32 codes as
``PackedWeight`` pytree nodes and every dense projection runs through the
fused dequant-GEMM ``quant_matmul`` — no fp array of any quantized
weight's full shape is ever created, on host or in HBM (MLA's absorbed
decode included: its per-head ``wkv_b`` contractions run on the packed
codes through the latent-layout ``quant_matmul_t``), so resident weight
memory is ~bits/16 of the bf16 model.  ``--no-keep-packed`` restores the
legacy load-time device-side dequantization
(``checkpoint.packed.load_packed_params``) for A/B comparisons; both
paths jit prefill and decode through the same model code
(``models.layers.linear`` dispatches per weight type).

Serving performance
-------------------

* ``--loop scan`` (default): the whole generation is ONE device program —
  a jitted ``lax.scan`` over decode steps with the KV cache donated into
  it and sampling (greedy argmax or ``--temperature`` categorical, keys
  derived per step via ``jax.random.fold_in``) on device.  The per-token
  host round-trip + dispatch of the old loop is gone, which is what let
  packed decode overtake fp (decode is memory-bound; the packed kernel's
  16/bits weight-traffic win only shows once dispatch stops dominating).
  ``--loop python`` keeps the legacy one-jitted-dispatch-per-token loop
  as a debug mode; greedy tokens are bit-identical between the two
  (pinned by tests/test_serve_scan.py).
* Kernel policy: ``quant_matmul`` auto-selects the fused Pallas kernel on
  TPU and the fused-XLA ref elsewhere; ``REPRO_QMM_KERNEL=1`` forces the
  kernel (interpret mode off-TPU — a correctness/CI tool, not a fast
  path), ``=0`` forces the ref.
* Mesh behaviour: with a live mesh the artifact's codes load d_out-sharded
  on the model axis and ``quant_matmul`` runs the kernel per shard under
  ``shard_map`` — no code all-gather, no ref-GEMM fallback; ragged local
  tiles and expert stacks under vmap fall back to the GSPMD ref.
* Quantized KV cache (``--kv-bits {0,8,2}``): long-context decode is bound
  by KV-cache HBM traffic — the whole cache is re-read per generated
  token per layer.  ``--kv-bits 8`` stores int8 codes + per-(token, head)
  scales, ``--kv-bits 2`` packed LogQuant-style log codes + one bf16
  scale per (``kv_chunk`` tokens, head) — ~1/2 and ~1/8 the bf16 cache
  bytes.  Prefill writes the cache already quantized and decode appends
  codes, so codes+scales is the cache's *only* representation end to end:
  attention consumes them directly through ``kernels.flash_decode``
  (in-register tile dequant, streaming-softmax (m, l, acc), no fp copy of
  the cache at any size — the zero-dequant guard of
  tests/test_kv_cache.py pins it, MLA's latent cache included).  Under a
  mesh the cache's sequence axis is split across the model axis and each
  device flash-decodes its shard; the shards merge by one tiny
  max/sum-shifted partial-softmax collective — zero cache collectives.

Engine serving (``--mode engine``)
----------------------------------

``--mode engine`` swaps the fixed-shape batch for the request-oriented
serving engine (``repro.serving``): requests are ``ServeRequest`` objects
with their own budget and ``SamplingParams``, arrive on a Poisson trace
(``--arrival-rate`` per scheduling round), prefill into pages popped off
a shared block-paged quantized KV pool (``--n-pages``; page = ``kv_chunk``
tokens across every layer), decode continuously in bursts of
``--burst-steps`` alongside whatever else is in flight, and retire by
releasing their pages for reuse.  ``--prefill-chunk N`` streams prompt
ingestion through the running batch in page-aligned chunks (one chunk
per scheduling round per ingesting request) instead of stalling decode
on whole-prompt prefills — see serving/README.md "Chunked prefill".
Per-request token streams are bit-identical to a single-request
``generate()`` call either way (pinned by tests/test_serving.py).  Requires ``--kv-bits 8`` or ``2`` — the pools
store codes+scales, never fp.  See src/repro/serving/README.md for the
API and the page-size math.

Overload controls: ``--deadline-s`` retires expired requests with status
``deadline_exceeded``, ``--queue-depth`` bounds the submission queue
(rejected submissions are recorded as ``shed``), and ``--fail-at-round
ROUND:STAGE[:COUNT]`` injects failures at the engine's scheduling stage
points (admit/ingest/burst/retire) to exercise the retry/isolation path
— under page pressure the engine preempts-and-requeues rather than
stalling, and every preempted request's tokens stay bit-identical to its
solo run (serving/README.md "Overload policy").

``--kernel-check`` is deprecated: the keep-packed forward now routes
*every* projection through ``quant_matmul`` and the full-forward parity
is pinned by tests/test_serve_packed.py.  The flag survives as a thin
alias that still runs its original cheap startup integrity check (one
artifact entry through ``quant_matmul`` vs the dequantized matmul)
before keep-packed serving; combining it with ``--no-keep-packed`` is an
error.
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import functools
import time
import warnings

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import SyntheticCorpus
from repro.models import build_model
from repro.serving import (Engine, RequestOutput, SamplingParams,  # noqa: F401
                           ServeRequest, poisson_trace, run_trace)


def _sample_token(logits, temperature: float, key, step) -> jax.Array:
    """(B, V) logits -> (B, 1) int32 token; greedy at temperature 0,
    categorical at ``logits / temperature`` otherwise with the step's key
    derived by ``fold_in`` (deterministic in (key, step) — the python and
    scan loops draw identical streams)."""
    if temperature > 0.0:
        sub = jax.random.fold_in(key, step)
        tok = jax.random.categorical(sub, logits / temperature, axis=-1)
        return tok.astype(jnp.int32)[:, None]
    return jnp.argmax(logits, -1).astype(jnp.int32)[:, None]


@functools.lru_cache(maxsize=64)
def _prefill_fn(model, cache_len: int):
    return jax.jit(lambda p, x, media, frames: model.prefill(
        p, x, media=media, frames=frames, cache_len=cache_len))


@functools.lru_cache(maxsize=64)
def _scan_decode_fn(model, n_gen: int, sampled: bool):
    """One jitted program for the whole generation: ``lax.scan`` over the
    decode steps, KV cache donated in (the scan's double-buffered carry is
    the only cache storage), sampling on device — a single dispatch and a
    single host sync for ``n_gen`` tokens.

    Only the *mode* (greedy vs sampled) is static; the temperature rides
    in as a traced scalar so sweeping it costs zero recompiles — at most
    two programs exist per (model, n_gen).

    Token 0 comes from the prefill logits, so only n_gen - 1 decode
    steps run: each scan iteration emits the token it just *produced*
    and the prefill token is prepended — no trailing decode_step whose
    outputs nothing consumes."""

    def run(params, cache, tok0, pos0, key, temperature):
        def body(carry, step):
            cache, tok, pos = carry
            logits, cache = model.decode_step(params, cache, tok, pos)
            if sampled:
                sub = jax.random.fold_in(key, step + 1)
                nxt = jax.random.categorical(
                    sub, logits / temperature, axis=-1
                ).astype(jnp.int32)[:, None]
            else:
                nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            return (cache, nxt, pos + jnp.int32(1)), nxt[:, 0]

        (_, _, _), toks = jax.lax.scan(
            body, (cache, tok0, pos0), jnp.arange(n_gen - 1))
        return jnp.concatenate([tok0, toks.T], axis=1)  # (B, n_gen)

    return jax.jit(run, donate_argnums=(1,))


def generate(model, params, prompts, n_gen: int, *, media=None, frames=None,
             temperature: float = 0.0, key=None, loop: str = "scan"):
    """prompts: (B, T) -> (B, n_gen) generated tokens.

    .. deprecated:: the request-oriented serving API is the primary
       surface now — build ``ServeRequest`` objects and drive them
       through :func:`generate_batch` (fixed batch, this loop) or
       ``serving.Engine`` (continuous batching over paged KV pools).
       ``generate`` stays as the thin fixed-shape core both share: a
       homogeneous batch, one prompt length, one temperature, one key.

    Greedy when ``temperature == 0``; otherwise categorical sampling of
    *every* token — including the first one, drawn from the prefill
    logits — with per-step keys ``fold_in(key, step)`` (``key`` is then
    required).  ``loop="scan"`` (default) runs the fused on-device
    generation loop; ``loop="python"`` is the legacy per-token dispatch
    loop, kept as a debug mode — greedy tokens are bit-identical between
    the two."""
    if loop not in ("scan", "python"):
        raise ValueError(f"loop must be 'scan' or 'python', got {loop!r}")
    if temperature > 0.0 and key is None:
        raise ValueError("temperature > 0 requires a PRNG `key`; pass "
                         "key=jax.random.key(seed) (silently degrading to "
                         "greedy was a bug)")
    b, t = prompts.shape
    logits, cache = _prefill_fn(model, t + n_gen)(params, prompts,
                                                  media, frames)
    if key is None:
        key = jax.random.key(0)  # unused at temperature 0 (DCE'd)
    tok = _sample_token(logits, temperature, key, 0)
    if loop == "scan":
        return _scan_decode_fn(model, n_gen, temperature > 0.0)(
            params, cache, tok, jnp.int32(t), key,
            jnp.float32(temperature))
    step = jax.jit(model.decode_step, donate_argnums=(1,))
    toks = [tok]
    pos = t
    for i in range(n_gen - 1):  # token 0 is the prefill sample
        logits, cache = step(params, cache, tok, jnp.int32(pos))
        tok = _sample_token(logits, temperature, key, i + 1)
        toks.append(tok)
        pos += 1
    return jnp.concatenate(toks, axis=1)


def generate_batch(model, params, requests, *, loop: str = "scan"):
    """Serve a list of ``ServeRequest`` through the fixed-batch scan loop.

    .. deprecated:: use ``serving.Engine`` — the engine serves the same
       ``ServeRequest`` objects with bit-identical per-request streams,
       without this loop's homogeneity restrictions, and with continuous
       batching / paged KV reuse on top.  This wrapper emits a
       ``DeprecationWarning`` and will be removed once the CLI's batch
       mode moves over.

    The request-oriented twin of :func:`generate`: one request type shared
    with ``serving.Engine``, same per-request token streams.  The
    fixed-shape loop can only batch *homogeneous* requests — equal prompt
    length and identical ``SamplingParams`` (one temperature / seed / eos
    for the whole batch; per-request budgets are fine, longer requests
    simply own the trailing tokens).  Heterogeneous workloads belong on
    the engine, which exists precisely because this shape restriction is
    what continuous batching removes.

    Returns one token list per request, truncated to its
    ``max_new_tokens`` (eos handling too is engine-only here: the fixed
    batch runs to the longest budget regardless)."""
    warnings.warn(
        "generate_batch is deprecated: serve ServeRequest objects through "
        "serving.Engine (continuous batching, same bit-identical streams, "
        "no homogeneous-batch restrictions)",
        DeprecationWarning, stacklevel=2)
    if not requests:
        return []
    t0 = len(requests[0].tokens)
    sp0 = requests[0].sampling
    if any(len(r.tokens) != t0 for r in requests):
        raise ValueError(
            "generate_batch needs one prompt length per batch (got "
            f"{sorted({len(r.tokens) for r in requests})}); mixed-length "
            "workloads belong on serving.Engine")
    if any(r.sampling != sp0 for r in requests):
        raise ValueError(
            "generate_batch needs identical SamplingParams across the "
            "batch; per-request sampling belongs on serving.Engine")
    if sp0.eos_token >= 0:
        raise ValueError(
            "generate_batch ignores eos_token (the fixed batch runs to "
            "its budget); requests that stop at eos belong on "
            "serving.Engine")
    prompts = jnp.asarray([r.tokens for r in requests], jnp.int32)
    n_gen = max(r.max_new_tokens for r in requests)
    key = (jax.random.key(sp0.seed) if sp0.temperature > 0 else None)
    out = generate(model, params, prompts, n_gen, loop=loop,
                   temperature=sp0.temperature, key=key)
    return [out[i, :r.max_new_tokens].tolist()
            for i, r in enumerate(requests)]


def kv_cache_resident_bytes(cache) -> int:
    """Total bytes resident in a KV-cache tree (codes + scales for a
    quantized cache, fp activations otherwise) — the per-token decode
    HBM traffic is proportional to this."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(cache))


def resident_weight_bytes(params) -> tuple[int, int]:
    """(packed_bytes, fp_bytes) resident in the tree: bytes held by
    ``PackedWeight`` leaves vs plain fp leaves."""
    from repro.kernels.quant_matmul.ops import PackedWeight

    packed = fp = 0
    for leaf in jax.tree.leaves(
            params, is_leaf=lambda x: isinstance(x, PackedWeight)):
        if isinstance(leaf, PackedWeight):
            packed += leaf.nbytes
        else:
            fp += leaf.size * leaf.dtype.itemsize
    return packed, fp


def _kernel_spot_check(packed_dir: str, meta: dict) -> None:
    """One artifact entry through ``quant_matmul`` vs its dequantized
    matmul — the deprecated ``--kernel-check`` startup integrity check
    (a corrupt/stale artifact fails loudly before serving; the full
    per-projection parity lives in tests/test_serve_packed.py)."""
    from repro.checkpoint.packed import dequantize_entry, load_packed_entry
    from repro.kernels.quant_matmul.ops import (packed_weight_from_artifact,
                                                quant_matmul)

    name = next((n for n, em in meta["entries"].items()
                 if len(em["fields"]["codes"]["shape"]) == 2), None)
    if name is None:  # all-expert-stack artifact: nothing 2-D to drive
        print("kernel-check: no dense 2-D weight in the artifact; skipped")
        return
    em = meta["entries"][name]
    entry = load_packed_entry(packed_dir, name)
    pw = packed_weight_from_artifact(entry, em, meta["spec"])
    x = jax.random.normal(jax.random.key(7), (8, pw.d_in), jnp.float32)
    y = quant_matmul(x, pw)
    ref = x @ dequantize_entry(entry, em, meta["spec"])
    err = float(jnp.max(jnp.abs(y - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    print(f"kernel-check [{name}]: quant_matmul vs dequant rel_err={err:.2e}")
    assert err < 1e-5, err


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--loop", choices=("scan", "python"), default="scan",
                    help="generation loop: 'scan' (default) fuses all "
                    "decode steps into one jitted lax.scan device program "
                    "with on-device sampling and a donated KV cache; "
                    "'python' is the legacy per-token dispatch loop "
                    "(debug; greedy tokens are bit-identical)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy); every token "
                    "including the first is sampled, keyed by --seed")
    ap.add_argument("--mode", choices=("batch", "engine"), default="batch",
                    help="'batch' (default): one fixed-shape generate() "
                    "call; 'engine': continuous batching on block-paged "
                    "quantized KV pools — requests arrive on a Poisson "
                    "trace, prefill into freshly allocated pages, and "
                    "retire by releasing them (requires --kv-bits 8|2)")
    ap.add_argument("--max-slots", type=int, default=4,
                    help="engine mode: concurrent decode slots")
    ap.add_argument("--n-pages", type=int, default=64,
                    help="engine mode: allocatable KV pages shared by all "
                    "requests (page = kv_chunk tokens, every layer)")
    ap.add_argument("--burst-steps", type=int, default=8,
                    help="engine mode: decode steps per scheduling round "
                    "(one jitted scan between admissions/retirements)")
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="engine mode: Poisson arrivals per scheduling "
                    "round")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="engine mode: admit prompts in chunks of this "
                    "many tokens (rounded up to a page multiple), "
                    "interleaved with decode bursts — long prompts stop "
                    "stalling the running batch; 0 (default) admits "
                    "whole prompts in one prefill.  Tokens stay "
                    "bit-identical either way (exact chunked prefill)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="engine mode: per-request deadline in seconds "
                    "from submit — expired requests (queued or decoding) "
                    "retire with status deadline_exceeded; 0 (default) "
                    "disables deadlines")
    ap.add_argument("--queue-depth", type=int, default=0,
                    help="engine mode: bounded submission queue — a "
                    "submit beyond this depth is rejected (EngineSaturated "
                    "with a retry-after hint; the trace driver records it "
                    "as status shed); 0 (default) queues unbounded")
    ap.add_argument("--fail-at-round", action="append", default=[],
                    metavar="ROUND:STAGE[:COUNT]",
                    help="engine mode: inject COUNT failures (default 1) "
                    "at a scheduling-round stage point, stage in "
                    "{admit, ingest, burst, retire} — a failed burst "
                    "retries with backoff, a poisoned request is isolated "
                    "with status failed; repeatable (same spec format as "
                    "launch.quantize --fail-at)")
    ap.add_argument("--kv-bits", type=int, default=None,
                    help="KV-cache precision: 0 = activation dtype "
                    "(default), 8 = int8 codes + per-token scales, 2 = "
                    "packed log codes + per-chunk scales; decode attends "
                    "on the codes directly (kernels.flash_decode)")
    ap.add_argument("--packed", default=None, metavar="DIR",
                    help="serve from a packed RSQ artifact (written by "
                    "launch.quantize --pack-out): weights travel host->"
                    "device as packed int codes")
    ap.add_argument("--keep-packed", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="with --packed: keep codes packed in HBM and run "
                    "every dense projection through quant_matmul (default); "
                    "--no-keep-packed dequantizes whole weights on device "
                    "at load time instead")
    ap.add_argument("--no-verify", action="store_true",
                    help="with --packed: skip the SHA-256 artifact "
                    "integrity check at load time (format v3 artifacts "
                    "record per-file checksums; a corrupt file otherwise "
                    "fails with ArtifactCorruptError before serving)")
    ap.add_argument("--kernel-check", action="store_true",
                    help="deprecated: keep-packed serving (the default) "
                    "already runs every projection through quant_matmul "
                    "(full-forward parity lives in tests/test_serve_packed); "
                    "retained as a one-entry startup integrity check")
    args = ap.parse_args(argv)
    if args.kernel_check:
        if not args.packed:
            ap.error("--kernel-check requires --packed")
        if not args.keep_packed:
            ap.error("--kernel-check checks the keep-packed path; it "
                     "cannot be combined with --no-keep-packed")
        print("--kernel-check is deprecated: keep-packed serving (the "
              "default) routes every projection through quant_matmul; "
              "running the one-entry startup check anyway")

    cfg = dataclasses.replace(get_config(args.arch), dtype=args.dtype)
    if args.kv_bits is not None:
        if args.kv_bits not in (0, 2, 8):
            ap.error(f"--kv-bits {args.kv_bits} is not supported — use 0 "
                     "(KV cache in the activation dtype), 8 (int8 + "
                     "per-token scales) or 2 (packed log codes + "
                     "per-chunk scales)")
        cfg = dataclasses.replace(cfg, kv_bits=args.kv_bits)
    model = build_model(cfg)
    if args.packed:
        from repro.checkpoint.packed import (load_packed_forward_params,
                                             load_packed_params)

        loader = (load_packed_forward_params if args.keep_packed
                  else load_packed_params)
        params, meta = loader(args.packed, verify=not args.no_verify)
        arch = meta.get("extra", {}).get("arch")
        assert arch in (None, args.arch), \
            f"artifact was quantized for --arch {arch}, serving {args.arch}"
        n_packed = len(meta["entries"])
        packed_b, fp_b = resident_weight_bytes(params)
        mode = "keep-packed" if args.keep_packed else "dequantized"
        print(f"packed artifact: {n_packed} weights ({mode}, "
              f"bits={meta['spec']['bits']}); resident bytes: "
              f"{packed_b / 1e6:.1f}MB packed + {fp_b / 1e6:.1f}MB fp")
        if args.kernel_check:
            _kernel_spot_check(args.packed, meta)
    else:
        params = jax.jit(model.init)(jax.random.key(args.seed))
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=args.seed)
    prompts = corpus.sample(jax.random.key(1), args.batch, args.prompt_len)

    if args.mode == "engine":
        if not cfg.kv_bits:
            ap.error("--mode engine pages *quantized* KV codes — pass "
                     "--kv-bits 8 or --kv-bits 2")
        from repro.runtime.fault import FaultPlan

        reqs = [ServeRequest(
            tokens=prompts[i].tolist(),
            max_new_tokens=args.gen,
            sampling=SamplingParams(temperature=args.temperature,
                                    seed=args.seed + i,
                                    deadline_s=args.deadline_s),
        ) for i in range(args.batch)]
        need = -(-(args.prompt_len + args.gen) // model.codec.page_tokens)
        plan = (FaultPlan.parse(args.fail_at_round)
                if args.fail_at_round else None)
        engine = Engine(model, params, max_slots=args.max_slots,
                        n_pages=args.n_pages,
                        max_pages_per_request=max(need, 1),
                        burst_steps=args.burst_steps,
                        prefill_chunk=args.prefill_chunk or None,
                        queue_depth=args.queue_depth or None,
                        fault_plan=plan)
        stats = run_trace(engine, poisson_trace(
            reqs, rate=args.arrival_rate, seed=args.seed))
        admit = ("chunked (%d tokens/chunk)" % engine.prefill_chunk
                 if engine.prefill_chunk else "whole-prompt")
        print(f"engine [{admit} admission]: {stats['n_requests']} requests, "
              f"{stats['n_tokens']} tokens in {stats['wall_s']:.2f}s over "
              f"{stats['rounds']} rounds "
              f"({stats['sustained_tok_s']:.1f} sustained tok/s)")
        print(f"latency: p50={stats['p50_latency_s']:.3f}s "
              f"p99={stats['p99_latency_s']:.3f}s; "
              f"ttft: p50={stats['ttft_p50_s']:.3f}s "
              f"p99={stats['ttft_p99_s']:.3f}s; "
              f"admission stall {stats['admission_stall_s']:.2f}s; "
              f"free pages after drain: {engine.pools.free_pages()}"
              f"/{args.n_pages}")
        print(f"statuses: {stats['statuses']}; "
              f"preemptions: {stats['n_preemptions']} "
              f"({stats['n_preempted_requests']} requests); "
              f"shed: {stats['n_shed']}; deadline: {stats['n_deadline']}; "
              f"failed: {stats['n_failed']}")
        if engine.events.events:
            print(f"engine events: "
                  f"{dict(collections.Counter(engine.events.kinds()))}")
        # every submitted request must have reached a definite terminal
        # status — zero hangs is the overload contract, CI asserts on it
        assert stats["n_requests"] == args.batch, \
            (f"{args.batch - stats['n_requests']} of {args.batch} requests "
             "never reached a terminal status")
        engine.pools.assert_quiescent()
        print(f"all {args.batch} requests terminal; pages quiescent")
        first = next((o for o in stats["outputs"].values()
                      if o.finished_ok), None)
        if first is not None:
            print("sample:", first.tokens[:16])
        return stats

    key = (jax.random.key(args.seed) if args.temperature > 0.0 else None)
    t0 = time.time()
    out = generate(model, params, prompts, args.gen, loop=args.loop,
                   temperature=args.temperature, key=key)
    jax.block_until_ready(out)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s, loop={args.loop})")
    print("sample:", out[0][:16].tolist())
    if cfg.kv_bits:
        s = args.prompt_len + args.gen
        fp_model = build_model(dataclasses.replace(cfg, kv_bits=0))
        qb = kv_cache_resident_bytes(
            jax.eval_shape(lambda: model.init_cache(args.batch, s)))
        fb = kv_cache_resident_bytes(
            jax.eval_shape(lambda: fp_model.init_cache(args.batch, s)))
        print(f"kv cache resident: {qb / 1e6:.2f}MB (kv_bits="
              f"{cfg.kv_bits}) vs {fb / 1e6:.2f}MB fp — ratio {qb / fb:.3f}")
    return out


if __name__ == "__main__":
    main()
