"""Batched serving driver: prefill + decode loop with a KV cache, optional
weight-only quantized execution (RSQ output + quant_matmul kernel).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b-smoke \
      --batch 4 --prompt-len 32 --gen 16

``--packed DIR`` serves from a packed RSQ artifact (written by
launch.quantize --pack-out).  The default is **keep-packed** serving
(``--keep-packed``): the param tree holds the uint32 codes as
``PackedWeight`` pytree nodes and every dense projection runs through the
fused dequant-GEMM ``quant_matmul`` — no fp array of any quantized
weight's full shape is ever created, on host or in HBM (one exception:
MLA's absorbed decode dequantizes ``wkv_b`` transiently per step inside
the trace — ``models.attention._materialize``), so resident weight
memory is ~bits/16 of the bf16 model.  ``--no-keep-packed``
restores the legacy load-time device-side dequantization
(``checkpoint.packed.load_packed_params``) for A/B comparisons; both
paths jit prefill and decode through the same model code
(``models.layers.linear`` dispatches per weight type).

``--kernel-check`` is deprecated: the keep-packed forward now routes
*every* projection through ``quant_matmul`` and the full-forward parity
is pinned by tests/test_serve_packed.py.  The flag survives as a thin
alias that still runs its original cheap startup integrity check (one
artifact entry through ``quant_matmul`` vs the dequantized matmul)
before keep-packed serving; combining it with ``--no-keep-packed`` is an
error.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import SyntheticCorpus
from repro.models import build_model


def generate(model, params, prompts, n_gen: int, *, media=None, frames=None,
             temperature: float = 0.0, key=None):
    """prompts: (B, T). Greedy (or sampled) generation of n_gen tokens."""
    b, t = prompts.shape
    logits, cache = jax.jit(
        lambda p, x: model.prefill(p, x, media=media, frames=frames,
                                   cache_len=t + n_gen))(params, prompts)
    step = jax.jit(model.decode_step, donate_argnums=(1,))
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    pos = t
    for i in range(n_gen):
        toks.append(tok)
        logits, cache = step(params, cache, tok, jnp.int32(pos))
        if temperature > 0.0 and key is not None:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / temperature, axis=-1).astype(jnp.int32)[:, None]
        else:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        pos += 1
    return jnp.concatenate(toks, axis=1)


def resident_weight_bytes(params) -> tuple[int, int]:
    """(packed_bytes, fp_bytes) resident in the tree: bytes held by
    ``PackedWeight`` leaves vs plain fp leaves."""
    from repro.kernels.quant_matmul.ops import PackedWeight

    packed = fp = 0
    for leaf in jax.tree.leaves(
            params, is_leaf=lambda x: isinstance(x, PackedWeight)):
        if isinstance(leaf, PackedWeight):
            packed += leaf.nbytes
        else:
            fp += leaf.size * leaf.dtype.itemsize
    return packed, fp


def _kernel_spot_check(packed_dir: str, meta: dict) -> None:
    """One artifact entry through ``quant_matmul`` vs its dequantized
    matmul — the deprecated ``--kernel-check`` startup integrity check
    (a corrupt/stale artifact fails loudly before serving; the full
    per-projection parity lives in tests/test_serve_packed.py)."""
    from repro.checkpoint.packed import dequantize_entry, load_packed_entry
    from repro.kernels.quant_matmul.ops import (packed_weight_from_artifact,
                                                quant_matmul)

    name = next((n for n, em in meta["entries"].items()
                 if len(em["fields"]["codes"]["shape"]) == 2), None)
    if name is None:  # all-expert-stack artifact: nothing 2-D to drive
        print("kernel-check: no dense 2-D weight in the artifact; skipped")
        return
    em = meta["entries"][name]
    entry = load_packed_entry(packed_dir, name)
    pw = packed_weight_from_artifact(entry, em, meta["spec"])
    x = jax.random.normal(jax.random.key(7), (8, pw.d_in), jnp.float32)
    y = quant_matmul(x, pw)
    ref = x @ dequantize_entry(entry, em, meta["spec"])
    err = float(jnp.max(jnp.abs(y - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    print(f"kernel-check [{name}]: quant_matmul vs dequant rel_err={err:.2e}")
    assert err < 1e-5, err


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--packed", default=None, metavar="DIR",
                    help="serve from a packed RSQ artifact (written by "
                    "launch.quantize --pack-out): weights travel host->"
                    "device as packed int codes")
    ap.add_argument("--keep-packed", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="with --packed: keep codes packed in HBM and run "
                    "every dense projection through quant_matmul (default); "
                    "--no-keep-packed dequantizes whole weights on device "
                    "at load time instead")
    ap.add_argument("--kernel-check", action="store_true",
                    help="deprecated: keep-packed serving (the default) "
                    "already runs every projection through quant_matmul "
                    "(full-forward parity lives in tests/test_serve_packed); "
                    "retained as a one-entry startup integrity check")
    args = ap.parse_args(argv)
    if args.kernel_check:
        if not args.packed:
            ap.error("--kernel-check requires --packed")
        if not args.keep_packed:
            ap.error("--kernel-check checks the keep-packed path; it "
                     "cannot be combined with --no-keep-packed")
        print("--kernel-check is deprecated: keep-packed serving (the "
              "default) routes every projection through quant_matmul; "
              "running the one-entry startup check anyway")

    cfg = dataclasses.replace(get_config(args.arch), dtype=args.dtype)
    model = build_model(cfg)
    if args.packed:
        from repro.checkpoint.packed import (load_packed_forward_params,
                                             load_packed_params)

        loader = (load_packed_forward_params if args.keep_packed
                  else load_packed_params)
        params, meta = loader(args.packed)
        arch = meta.get("extra", {}).get("arch")
        assert arch in (None, args.arch), \
            f"artifact was quantized for --arch {arch}, serving {args.arch}"
        n_packed = len(meta["entries"])
        packed_b, fp_b = resident_weight_bytes(params)
        mode = "keep-packed" if args.keep_packed else "dequantized"
        print(f"packed artifact: {n_packed} weights ({mode}, "
              f"bits={meta['spec']['bits']}); resident bytes: "
              f"{packed_b / 1e6:.1f}MB packed + {fp_b / 1e6:.1f}MB fp")
        if args.kernel_check:
            _kernel_spot_check(args.packed, meta)
    else:
        params = jax.jit(model.init)(jax.random.key(args.seed))
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=args.seed)
    prompts = corpus.sample(jax.random.key(1), args.batch, args.prompt_len)

    t0 = time.time()
    out = generate(model, params, prompts, args.gen)
    jax.block_until_ready(out)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", out[0][:16].tolist())
    return out


if __name__ == "__main__":
    main()
