"""ShapeDtypeStruct stand-ins (with shardings) for every model input of every
(architecture x input-shape) cell — nothing is allocated; ``jit.lower`` takes
these directly.

Cache shardings follow a memory-first rule set:
  * batch-dim -> data axes when divisible;
  * KV sequence dim -> model axis (context parallelism) when divisible;
  * for global_batch=1 long-context decode, the sequence dim is sharded over
    *all* mesh axes (the only way a 500k-token cache fits per chip);
  * SSM states shard heads on the model axis.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.runtime.sharding import ParallelCtx


def _sds(shape, dtype, ctx: ParallelCtx, spec: P | None):
    if not ctx.enabled:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(ctx.mesh, spec or P()))


def _axis_size(ctx: ParallelCtx, axes) -> int:
    if not ctx.enabled:
        return 1
    return math.prod(ctx.mesh.shape[a] for a in axes)


def batch_inputs(cfg: ModelConfig, shape: ShapeConfig, ctx: ParallelCtx,
                 dtype=jnp.bfloat16) -> dict[str, Any]:
    """Training / prefill batch specs."""
    b, t = shape.global_batch, shape.seq_len
    dp = ctx.dp
    tok_spec = P(dp if len(dp) != 1 else dp[0], None) if dp else None
    batch = {"tokens": _sds((b, t), jnp.int32, ctx, tok_spec)}
    if shape.kind == "train":
        batch["labels"] = _sds((b, t), jnp.int32, ctx, tok_spec)
    if cfg.family == "vlm":
        mspec = P(dp if len(dp) != 1 else dp[0], None, None) if dp else None
        batch["media"] = _sds((b, cfg.n_media_tokens, cfg.d_model), dtype,
                              ctx, mspec)
    if cfg.family == "encdec":
        fspec = P(dp if len(dp) != 1 else dp[0], None, None) if dp else None
        batch["frames"] = _sds((b, t, cfg.d_model), dtype, ctx, fspec)
    return batch


def _dp_entry(ctx: ParallelCtx):
    return ctx.dp if len(ctx.dp) != 1 else ctx.dp[0]


def cache_shardings(cache_shapes, cfg: ModelConfig, shape: ShapeConfig,
                    ctx: ParallelCtx):
    """Assign a NamedSharding to every cache leaf (by key name + shape)."""
    b = shape.global_batch
    dp_size = _axis_size(ctx, ctx.dp)
    tp_size = _axis_size(ctx, (ctx.tp,)) if ctx.tp else 1
    all_axes = tuple(ctx.dp) + ((ctx.tp,) if ctx.tp else ())
    all_size = dp_size * tp_size
    dp_ok = dp_size > 0 and b % dp_size == 0
    dp_e = _dp_entry(ctx)

    def seq_entry(s):
        """sharding entry for a KV sequence dim of size s"""
        if dp_ok:
            return ctx.tp if (ctx.tp and s % tp_size == 0) else None
        if s % all_size == 0:
            return all_axes
        if ctx.tp and s % tp_size == 0:
            return ctx.tp
        return None

    def f(path, leaf):
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        name = next((k for k in reversed(keys) if isinstance(k, str)), "")
        nd = leaf.ndim
        stacked = "groups" in keys
        off = 1 if stacked else 0  # leading n_groups axis
        base = [None] * (nd - off)
        bdim = 0
        if name in ("k", "v", "c", "r", "ks", "vs", "cs", "rs"):
            if dp_ok:
                base[bdim] = dp_e
            base[1] = seq_entry(leaf.shape[off + 1])
        elif name == "conv":
            if dp_ok:
                base[bdim] = dp_e
        elif name == "ssm":
            if dp_ok:
                base[bdim] = dp_e
            nh = leaf.shape[off + 1]
            if ctx.tp and nh % tp_size == 0:
                base[1] = ctx.tp
        elif name in ("kv", "cross_kv") or "kv" in keys or "cross_kv" in keys:
            if dp_ok:
                base[bdim] = dp_e
        elif name == "media":
            if dp_ok:
                base[bdim] = dp_e
        spec = P(*([None] * off + base))
        return NamedSharding(ctx.mesh, spec)

    return jax.tree_util.tree_map_with_path(f, cache_shapes)


def decode_inputs(model, cfg: ModelConfig, shape: ShapeConfig,
                  ctx: ParallelCtx, dtype=jnp.bfloat16):
    """(cache_sds, token_sds, pos_sds) for serve_step lowering."""
    b, s = shape.global_batch, shape.seq_len
    media = None
    if cfg.family == "vlm":
        media = jax.ShapeDtypeStruct((b, cfg.n_media_tokens, cfg.d_model),
                                     dtype)
    elif cfg.family == "encdec":
        # encoder output held as the cross-attention cache (30 s ~ 1500 frames)
        media = jax.ShapeDtypeStruct((b, 1500, cfg.d_model), dtype)
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(b, s, media=media))
    if ctx.enabled:
        shardings = cache_shardings(cache_shapes, cfg, shape, ctx)
        cache = jax.tree.map(
            lambda sh, nsh: jax.ShapeDtypeStruct(sh.shape, sh.dtype,
                                                 sharding=nsh),
            cache_shapes, shardings)
    else:
        cache = cache_shapes
    dp_size = _axis_size(ctx, ctx.dp)
    tok_spec = (P(_dp_entry(ctx), None)
                if ctx.enabled and ctx.dp and b % dp_size == 0 else P())
    token = _sds((b, 1), jnp.int32, ctx, tok_spec)
    pos = _sds((), jnp.int32, ctx, P())
    return cache, token, pos
