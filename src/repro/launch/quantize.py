"""RSQ quantization driver — the paper's main entry point.

Loads (or trains) a model, builds the calibration set, runs the
Rotate-Scale-Quantize pipeline, reports perplexity deltas vs the fp model,
and optionally packs the quantized weights for the serving kernel.

  PYTHONPATH=src python -m repro.launch.quantize --arch llama3-8b-smoke \
      --bits 3 --importance attn_con --expansion 8

Pod-scale data path: ``--shard-calib`` draws the calibration set as
disjoint per-data-group shards assembled into a globally-sharded array
(no host ever materializes the unsharded batch) and turns the streaming
sharded Hessian accumulators on; ``--pack-out DIR`` writes the packed
serving artifact (codes packed on device, sharded write-back) that
``launch.serve --packed DIR`` loads without unpacking on host.

Fault tolerance (resume / verify workflow)
------------------------------------------
``--save-every-layers N`` runs the pipeline under a
``core.resume.QuantizeRunner``: progress is checkpointed at layer-solve
granularity (solved params, propagated activations, packed entries,
loader state) into ``--progress-dir`` (default ``<pack-out>.progress``).
A killed job restarts with the *same* command plus ``--resume``: the
runner restores the latest layer checkpoint, reseeks the calibration
loader, skips the solved prefix and continues mid-stack — the final
packed artifact is byte-identical to a run that never died (pinned by
tests/test_resume.py).  ``--fail-at LAYER:STAGE[:COUNT]`` (repeatable,
``STAGE in {capture, solve, apply, pack}``) injects failures at stage
dispatch points to exercise the recovery path; ``--max-restarts`` bounds
the in-process retry loop (exponential backoff between attempts).

The packed artifact itself is durable: every file is written to a temp
path and atomically renamed, and ``meta.json`` records per-file SHA-256
checksums (format v3) that ``launch.serve --packed`` re-verifies at load
(``--no-verify`` opts out; corrupt files fail with
``checkpoint.ArtifactCorruptError``).
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.checkpoint.packed import save_packed_artifact
from repro.configs import get_config
from repro.core import RSQConfig, RSQPipeline
from repro.data.calibration import calibration_set
from repro.data.loader import CalibrationLoader
from repro.data.synthetic import SyntheticCorpus
from repro.models import build_model
from repro.runtime.sharding import LOCAL, ParallelCtx


def eval_ppl(model, params, tokens, batch: int = 8) -> float:
    losses, n = 0.0, 0
    loss_fn = jax.jit(model.loss)
    for i in range(0, tokens.shape[0], batch):
        b = tokens[i : i + batch]
        losses += float(loss_fn(params, {"tokens": b, "labels":
                                         jnp.roll(b, -1, axis=1)})) * b.shape[0]
        n += b.shape[0]
    return float(jnp.exp(losses / n))


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b-smoke")
    ap.add_argument("--ckpt", default=None, help="trained checkpoint dir")
    ap.add_argument("--bits", type=int, default=3)
    ap.add_argument("--group-size", type=int, default=128)
    ap.add_argument("--importance", default="attn_con")
    ap.add_argument("--r-min", type=float, default=0.01)
    ap.add_argument("--no-rotate", action="store_true")
    ap.add_argument("--method", default="gptq", choices=["gptq", "ldlq"])
    ap.add_argument("--scheduler", default="auto",
                    choices=["auto", "sequential", "overlapped"],
                    help="layer scheduler (auto: sequential on CPU, "
                    "overlapped on accelerators)")
    ap.add_argument("--shard-hessians", type=int, default=0,
                    help="0: dense accumulators; S>1: S streaming "
                    "partial-sum shards (single-host streaming; on a mesh "
                    "the shard axis lands on the data axes via the "
                    "pipeline's ParallelCtx)")
    ap.add_argument("--shard-calib", action="store_true",
                    help="sharded calibration loading: every data-parallel "
                    "group draws its own disjoint, (seed, shard)-"
                    "deterministic slice of the calibration set and the "
                    "slices assemble into one globally-sharded array — the "
                    "unsharded batch never exists on any host.  With >1 "
                    "local device this builds a data mesh over all devices "
                    "and also enables the streaming sharded Hessian "
                    "accumulators; with 1 device it degenerates to the "
                    "global draw (bit-identical tokens either way)")
    ap.add_argument("--pack-out", default=None, metavar="DIR",
                    help="write the packed serving artifact here: per-"
                    "weight int codes packed on device (sharded write-back "
                    "— no host copy of any unsharded (q, scales) tensor) "
                    "plus the fp residual tree; load with launch.serve "
                    "--packed DIR or checkpoint.packed.load_packed_params")
    ap.add_argument("--save-every-layers", type=int, default=0, metavar="N",
                    help="checkpoint quantization progress every N layer "
                    "solves into --progress-dir (0: no progress "
                    "checkpointing).  A killed run restarts with --resume "
                    "and continues mid-stack, byte-identical to an "
                    "uninterrupted run")
    ap.add_argument("--progress-dir", default=None, metavar="DIR",
                    help="progress-checkpoint directory (default: "
                    "<pack-out>.progress, or ./quantize_progress without "
                    "--pack-out)")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest progress checkpoint in "
                    "--progress-dir (without this flag an existing progress "
                    "dir is an error, not a silent restart)")
    ap.add_argument("--fail-at", action="append", default=[],
                    metavar="LAYER:STAGE[:COUNT]",
                    help="inject a failure at a stage dispatch point "
                    "(stage: capture|solve|apply|pack); repeatable — "
                    "exercises the recovery path end to end")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="bound on in-process recovery restarts "
                    "(exponential backoff between attempts)")
    ap.add_argument("--kv-bits", type=int, default=None,
                    help="serving KV-cache precision recorded in the model "
                    "config and the packed artifact's metadata: 0 = fp "
                    "cache, 8 = int8 + per-token scales, 2 = packed log "
                    "codes + per-chunk scales (weight quantization itself "
                    "is unaffected; launch.serve --kv-bits applies it at "
                    "serving time)")
    ap.add_argument("--expansion", type=int, default=1)
    ap.add_argument("--n-calib", type=int, default=32)
    ap.add_argument("--calib-seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write report JSON here")
    args = ap.parse_args(argv)

    cfg = dataclasses.replace(get_config(args.arch), dtype=args.dtype)
    if args.kv_bits is not None:
        if args.kv_bits not in (0, 2, 8):
            ap.error(f"--kv-bits {args.kv_bits} is not supported — use 0 "
                     "(KV cache in the activation dtype), 8 (int8 + "
                     "per-token scales) or 2 (packed log codes + "
                     "per-chunk scales)")
        cfg = dataclasses.replace(cfg, kv_bits=args.kv_bits)
    model = build_model(cfg)
    if args.ckpt:
        _, state, _ = CheckpointManager(args.ckpt).restore()
        params = state["params"]
    else:
        params = jax.jit(model.init)(jax.random.key(args.seed))

    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=args.seed)
    ctx = LOCAL
    loader = None
    if args.shard_calib:
        n_dev = jax.device_count()
        if n_dev > 1:
            mesh = jax.make_mesh((n_dev,), ("data",))
            ctx = ParallelCtx(mesh=mesh, dp=("data",))
        loader = CalibrationLoader(corpus, args.n_calib, args.calib_seq,
                                   ctx=ctx, batch_size=args.batch,
                                   seed=args.seed)
        calib = loader.dataset()
    else:
        calib = calibration_set(cfg.vocab_size, args.n_calib, args.calib_seq,
                                seed=args.seed, corpus=corpus)
    heldout = corpus.sample(jax.random.key(12345), args.n_calib,
                            args.calib_seq)

    if args.shard_hessians == -1 and not ctx.enabled:
        # True (shard over mesh data axes) needs a mesh-enabled ParallelCtx;
        # only --shard-calib with >1 local device builds one — refuse
        # rather than silently falling back to dense accumulators
        ap.error("--shard-hessians -1 (mesh mode) needs --shard-calib and "
                 ">1 local device (which build the data mesh); or pass an "
                 "explicit shard count S>1")
    shard_h = (True if args.shard_hessians == -1
               else args.shard_hessians if args.shard_hessians > 1 else False)
    if args.shard_calib and ctx.enabled and not shard_h:
        shard_h = True  # sharded batches feed sharded accumulators directly
    rsq = RSQConfig(bits=args.bits, group_size=args.group_size,
                    rotate=not args.no_rotate, importance=args.importance,
                    r_min=args.r_min, expansion=args.expansion,
                    method=args.method, seed=args.seed,
                    scheduler=(None if args.scheduler == "auto"
                               else args.scheduler),
                    shard_hessians=shard_h,
                    pack_output=args.pack_out is not None)
    base_ppl = eval_ppl(model, params, heldout, args.batch)
    pipe = RSQPipeline(model, rsq, ctx=ctx)
    use_runner = (args.resume or args.save_every_layers > 0
                  or args.progress_dir is not None or bool(args.fail_at))
    runner = None
    if use_runner:
        from repro.core.resume import QuantizeRunner
        from repro.runtime.fault import FaultPlan, RetryPolicy

        progress = args.progress_dir or (
            args.pack_out + ".progress" if args.pack_out
            else "quantize_progress")
        ckpt = CheckpointManager(progress)
        if ckpt.latest_step() is not None and not args.resume:
            ap.error(f"progress dir {progress!r} holds checkpoints from a "
                     f"previous run; pass --resume to continue it, or "
                     f"remove the directory to start over")
        fault = FaultPlan.parse(args.fail_at) if args.fail_at else None
        runner = QuantizeRunner(
            pipe, ckpt, save_every_layers=max(args.save_every_layers, 1),
            policy=RetryPolicy(max_restarts=args.max_restarts),
            loader=loader, resume=args.resume, verbose=True)
        qparams, report = runner.run(params, calib, fault=fault,
                                     batch_size=args.batch, verbose=True)
    else:
        qparams, report = pipe.run(params, calib, batch_size=args.batch,
                                   verbose=True)
    q_ppl = eval_ppl(model, qparams, heldout, args.batch)
    summary = {
        "arch": args.arch, "rsq": dataclasses.asdict(rsq),
        "ppl_fp": base_ppl, "ppl_quant": q_ppl,
        "ppl_ratio": q_ppl / base_ppl,
        "n_weights": sum(len(l["weights"]) for l in report["layers"].values()),
    }
    if runner is not None:
        summary["fault_tolerance"] = {
            "restarts": runner.restarts,
            "ckpt_overhead_s": round(runner.ckpt_overhead_s, 4),
            "events": [e["kind"] for e in runner.events],
        }
    if args.pack_out:
        save_packed_artifact(args.pack_out, pipe.artifact, params=qparams,
                             extra={"arch": args.arch,
                                    "rsq": dataclasses.asdict(rsq),
                                    "kv_bits": cfg.kv_bits})
        summary["pack_out"] = args.pack_out
    print(json.dumps(summary, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"summary": summary, "report": report}, f, indent=2,
                      default=str)
    return {"params": qparams, "summary": summary, "report": report}


if __name__ == "__main__":
    main()
