import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
# placeholder devices and record memory / cost / collective statistics.
#
# The two lines above MUST stay first — jax locks the device count on first
# initialization (hence also: no ``from __future__`` here).
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-4b \
#       --shape train_4k --mesh single --out results/dryrun
#   PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, get_shape
from repro.launch.mesh import make_ctx, make_production_mesh
from repro.launch.specs import batch_inputs, decode_inputs
from repro.launch.steps import (
    make_prefill_step,
    make_rsq_calib_step,
    make_serve_step,
    make_train_step,
    rsq_calib_inputs,
    sharded_args_train,
    sharded_params,
)
from repro.models import build_model
from repro.optim import cosine_schedule, make_optimizer
from repro.runtime.hlo_analysis import analyze_hlo


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: Path, *, save_hlo: bool = False,
             overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    if shape_name == "rsq_calib":
        # the paper-technique cell: one layer's calibration pass at the
        # paper's setup (256 samples x 4096 tokens)
        from repro.configs.base import ShapeConfig
        shape = ShapeConfig("rsq_calib", "train", 4096, 256)
    else:
        shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    ctx = make_ctx(mesh, ep=True)
    model = build_model(cfg, ctx)
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape), "status": "ok",
        "n_params": cfg.n_params(),
        "n_params_active": cfg.n_params(active_only=True),
        "optimizer": cfg.optimizer,
    }
    t0 = time.time()
    with jax.set_mesh(mesh):
        if shape_name == "rsq_calib":
            step_fn = make_rsq_calib_step(model)
            args = rsq_calib_inputs(model, shape, ctx)
            jitted = jax.jit(step_fn, donate_argnums=(2,))
        elif shape.kind == "train":
            opt = make_optimizer(cfg.optimizer,
                                 cosine_schedule(3e-4, 100, 10_000))
            step_fn = make_train_step(model, opt)
            batch = batch_inputs(cfg, shape, ctx)
            args = sharded_args_train(model, opt, batch, ctx)
            jitted = jax.jit(step_fn, donate_argnums=(0, 1))
        elif shape.kind == "prefill":
            step_fn = make_prefill_step(model, shape.seq_len)
            params = sharded_params(model, ctx)
            batch = batch_inputs(cfg, shape, ctx)
            args = (params, batch)
            jitted = jax.jit(step_fn)
        else:  # decode: serving-specific (2-D weight) sharding rules
            import dataclasses as _dc
            ctx_d = _dc.replace(ctx, mode="decode")
            model = build_model(cfg, ctx_d)
            step_fn = make_serve_step(model)
            params = sharded_params(model, ctx_d)
            cache, token, pos = decode_inputs(model, cfg, shape, ctx_d)
            args = (params, cache, token, pos)
            jitted = jax.jit(step_fn, donate_argnums=(1,))

        lowered = jitted.lower(*args)
        record["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 2)

        ma = compiled.memory_analysis()
        record["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_per_device_bytes": (ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        record["cost_analysis"] = {
            k: ca.get(k) for k in ("flops", "bytes accessed") if k in ca}
        hlo_text = compiled.as_text()
        record["hlo"] = analyze_hlo(hlo_text)
        if save_hlo:
            import gzip
            with gzip.open(
                    out_dir / f"{arch}__{shape_name}__{mesh_kind}.hlo.txt.gz",
                    "wt") as f:
                f.write(hlo_text)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--kv-bits", type=int, default=0)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = list(ASSIGNED_ARCHS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                name = f"{arch}__{shape_name}__{mesh_kind}"
                path = out_dir / f"{name}.json"
                if args.skip_existing and path.exists():
                    print(f"[skip] {name}")
                    continue
                print(f"[cell] {name} ...", flush=True)
                try:
                    over = {"kv_bits": args.kv_bits} if args.kv_bits else None
                    rec = run_cell(arch, shape_name, mesh_kind, out_dir,
                                   save_hlo=args.save_hlo, overrides=over)
                except Exception as e:  # record the failure, keep going
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                path.write_text(json.dumps(rec, indent=2, default=str))
                status = rec["status"]
                mem = rec.get("memory", {}).get("peak_per_device_bytes")
                mem_s = f" peak/dev={mem/2**30:.2f}GiB" if mem else ""
                print(f"[done] {name}: {status}"
                      f" lower={rec.get('lower_s')}s"
                      f" compile={rec.get('compile_s')}s{mem_s}", flush=True)


if __name__ == "__main__":
    main()
