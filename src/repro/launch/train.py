"""End-to-end training driver.

Local mode (default) trains a reduced config on the synthetic corpus on
whatever devices exist; ``--production`` builds the 16x16 (or 2x16x16) mesh
for real deployments.  Fault tolerance is on by default: async atomic
checkpoints every ``--save-every`` steps, exact resume (``--resume``),
failure injection for drills (``--fail-at``).

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b-smoke \
      --steps 200 --batch 8 --seq 128 --ckpt /tmp/run1
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.loader import DataLoader
from repro.data.synthetic import SyntheticCorpus
from repro.launch.mesh import make_ctx, make_production_mesh
from repro.launch.steps import make_train_step, sharded_args_train
from repro.models import build_model
from repro.optim import cosine_schedule, make_optimizer
from repro.runtime.fault import StepRunner
from repro.runtime.sharding import LOCAL, param_shardings


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b-smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt", default="/tmp/repro_train")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject one failure at this step (drill)")
    ap.add_argument("--production", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dtype", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.dtype:
        cfg = dataclasses.replace(cfg, dtype=args.dtype)
    if args.production:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        ctx = make_ctx(mesh)
    else:
        ctx = LOCAL
    model = build_model(cfg, ctx)
    opt = make_optimizer(cfg.optimizer,
                         cosine_schedule(args.lr, args.warmup, args.steps))

    key = jax.random.key(args.seed)
    if ctx.enabled:
        shardings = param_shardings(model.param_shapes(), ctx)
        params = jax.jit(model.init, out_shardings=shardings)(key)
    else:
        params = jax.jit(model.init)(key)
    opt_state = jax.jit(opt.init)(params)

    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=args.seed)
    loader = DataLoader(corpus, args.batch, args.seq, ctx)
    ckpt = CheckpointManager(args.ckpt, keep=3)
    if args.resume and ckpt.latest_step() is not None:
        _, state, extra = ckpt.restore()
        params, opt_state = state["params"], state["opt_state"]
        loader.restore(extra["loader"])
        print(f"resumed at step {loader.step}")

    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
    runner = StepRunner(step_fn, ckpt, save_every=args.save_every)
    fail_at = {args.fail_at: 1} if args.fail_at is not None else None
    out = runner.run(params, opt_state, loader, args.steps, fail_at=fail_at)
    print(f"final loss {out['losses'][-1]:.4f} "
          f"(restarts: {out['restarts']})")
    return out


if __name__ == "__main__":
    main()
