"""Step builders (train / prefill / serve) plus optimizer-state sharding
derivation.  These are the exact functions the dry-run lowers and the real
drivers execute."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.lm import Model
from repro.optim import Optimizer
from repro.runtime.sharding import ParallelCtx, param_specs


def make_train_step(model: Model, opt: Optimizer):
    ctx = model.ctx
    pspecs = model.param_specs() if ctx.enabled else None
    n_mb = max(1, model.cfg.microbatch)
    acc_dtype = jnp.dtype(model.cfg.grad_accum_dtype)

    def constrain_grads(grads):
        if not ctx.enabled:
            return grads
        # pin gradient (and hence optimizer-temp) sharding to the param
        # sharding — keeps fp32 update intermediates distributed
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(
                g, NamedSharding(ctx.mesh, s)), grads, pspecs)

    def train_step(params, opt_state, batch, step):
        if n_mb == 1:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            grads = constrain_grads(grads)
        else:
            # gradient accumulation: activations / backward stash scale with
            # the microbatch, the accumulator lives in `grad_accum_dtype`
            mbs = jax.tree.map(
                lambda x: x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:]),
                batch)

            def body(carry, mbatch):
                acc, loss_sum = carry
                loss, grads = jax.value_and_grad(model.loss)(params, mbatch)
                grads = constrain_grads(grads)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype), acc, grads)
                return (acc, loss_sum + loss), None

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            acc0 = constrain_grads(acc0)
            (grads, loss_sum), _ = jax.lax.scan(body, (acc0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / n_mb, grads)
            loss = loss_sum / n_mb
        new_params, new_state = opt.update(params, grads, opt_state, step)
        return new_params, new_state, loss

    return train_step


def make_prefill_step(model: Model, seq_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch["tokens"],
                             media=batch.get("media"),
                             frames=batch.get("frames"),
                             cache_len=seq_len)

    return prefill_step


def make_serve_step(model: Model):
    def serve_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    return serve_step


def make_rsq_calib_step(model: Model):
    """One distributed RSQ calibration step for a representative layer:
    capture per-weight inputs, compute AttnCon importance, accumulate the
    weighted Hessians H_w += 2·X R² Xᵀ.  Calibration tokens shard over the
    data axes; the (d, d) Hessians come out replicated (GSPMD reduces the
    token contraction with one psum per weight) — the RSQ-specific cell of
    the dry-run/roofline tables."""
    from repro.core.importance import ImportanceInputs, attn_con
    from repro.models.lm import capture_block
    import jax.numpy as jnp

    cfg = model.cfg
    meta = model.group_metas[-1]

    def rsq_calib_step(p_block, x, hessians):
        t = x.shape[1]
        pos = jnp.arange(t)
        y, caps, dom, colsum = capture_block(p_block, cfg, meta, x,
                                             positions=pos)
        r = attn_con(ImportanceInputs(z_in=x, attn_colsum=colsum),
                     r_min=0.01)
        new_h = {}
        for path, x_c in caps.items():
            if path.endswith("__moe_slot_token") or path not in hessians:
                continue
            if x_c.ndim == 3 and dom.get(path) == "expert":
                e = x_c.shape[0]
                rf = jnp.concatenate([r.reshape(-1), jnp.zeros((1,))])
                r_slots = rf[caps["ffn/__moe_slot_token"]]
                xr = (x_c.reshape(e, -1, x_c.shape[-1]).astype(jnp.float32)
                      * r_slots.reshape(e, -1, 1))
                new_h[path] = hessians[path] + 2.0 * jnp.einsum(
                    "ecd,ecf->edf", xr, xr)
            else:
                x2 = x_c.reshape(-1, x_c.shape[-1]).astype(jnp.float32)
                if dom.get(path) in ("stream", "hidden"):
                    x2 = x2 * r.reshape(-1, 1)
                new_h[path] = hessians[path] + 2.0 * x2.T @ x2
        return new_h, y

    return rsq_calib_step


def rsq_calib_inputs(model: Model, shape, ctx: ParallelCtx):
    """SDS args for make_rsq_calib_step: (block params, x, hessians)."""
    import jax.numpy as jnp
    from repro.models.lm import capture_block

    cfg = model.cfg
    meta = model.group_metas[-1]
    pshapes = model.param_shapes()
    block_shapes = jax.tree.map(lambda a: jax.ShapeDtypeStruct(
        a.shape[1:], a.dtype), pshapes["groups"][f"b{model.period - 1}"])
    pspecs = param_specs(pshapes, ctx)["groups"][f"b{model.period - 1}"]

    def strip(s, spec):
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=NamedSharding(ctx.mesh, P(*list(spec)[1:])))

    p_block = jax.tree.map(strip, block_shapes, pspecs)
    b, t = shape.global_batch, shape.seq_len
    dp_e = ctx.dp if len(ctx.dp) != 1 else ctx.dp[0]
    x = jax.ShapeDtypeStruct((b, t, cfg.d_model), jnp.dtype(cfg.dtype),
                             sharding=NamedSharding(ctx.mesh,
                                                    P(dp_e, None, None)))
    # derive Hessian shapes from an abstract capture (dom is static strings
    # collected during tracing; caps come back as ShapeDtypeStructs)
    dom: dict = {}

    def _cap(p, xx):
        y, caps, d, _ = capture_block(p, cfg, meta, xx,
                                      positions=jnp.arange(32))
        dom.update(d)
        return caps

    caps = jax.eval_shape(
        _cap, block_shapes,
        jax.ShapeDtypeStruct((2, 32, cfg.d_model), jnp.dtype(cfg.dtype)))
    # §Perf iteration (rsq_calib cell): store H sharded over the model axis
    # — the per-batch token-contraction reduction lowers to reduce-scatter
    # (half the link bytes of the replicated-H all-reduce) and the (d, d)
    # state is 1/16 per chip; the solver gathers H once per layer.
    import os
    dp_e = ctx.dp if len(ctx.dp) != 1 else ctx.dp[0]
    h_spec = (P() if os.environ.get("REPRO_BASELINE")
              else P(dp_e, ctx.tp))
    hessians = {}
    for path, c in caps.items():
        if path.endswith("__moe_slot_token"):
            continue
        d = c.shape[-1]
        if c.ndim == 3 and dom.get(path) == "expert":
            hessians[path] = jax.ShapeDtypeStruct(
                (c.shape[0], d, d), jnp.float32,
                sharding=NamedSharding(ctx.mesh, P(ctx.tp, None, None)))
        else:
            sp = (h_spec if d % ctx.axis_size("tp") == 0
                  and d % ctx.axis_size("dp") == 0 else P())
            hessians[path] = jax.ShapeDtypeStruct(
                (d, d), jnp.float32,
                sharding=NamedSharding(ctx.mesh, sp))
    return p_block, x, hessians


# ----------------------------------------------------- optimizer state specs


def _adapt_spec(spec: P, pshape, sshape) -> P:
    """Map a param PartitionSpec onto an optimizer-state leaf of a possibly
    reduced shape (scales / factored moments)."""
    entries = list(spec) + [None] * (len(pshape) - len(spec))
    if sshape == pshape:
        out = entries
    elif sshape == tuple(pshape[:-1]) + (1,):  # int8 per-row scales
        out = entries[:-1] + [None]
    elif sshape == tuple(pshape[:-1]):  # adafactor row stats
        out = entries[:-1]
    elif len(pshape) >= 2 and sshape == tuple(pshape[:-2]) + (pshape[-1],):
        out = entries[:-2] + [entries[-1]]  # adafactor col stats
    else:
        out = [None] * len(sshape)
    return P(*out)


def opt_state_shardings(opt_state_shapes, params_shapes, ctx: ParallelCtx):
    """Shardings for the optimizer state, derived from the param specs."""
    pspecs = param_specs(params_shapes, ctx)
    flat_p = jax.tree.leaves(params_shapes)
    flat_s = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    specs = {}
    for key, subtree in opt_state_shapes.items():
        # each of m/v mirrors params exactly (possibly with {"q","s"} or
        # {"r","c"} leaf expansion) -> per-param positional match is safe
        sub_leaves, sub_def = jax.tree.flatten(
            subtree, is_leaf=lambda x: isinstance(x, dict) and (
                set(x) <= {"q", "s"} or set(x) <= {"r", "c"}))
        out = []
        for p, pspec, sl in zip(flat_p, flat_s, sub_leaves):
            if isinstance(sl, dict):
                out.append({k: _adapt_spec(pspec, p.shape, v.shape)
                            for k, v in sl.items()})
            else:
                out.append(_adapt_spec(pspec, p.shape, sl.shape))
        specs[key] = sub_def.unflatten(out)

    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


def sharded_args_train(model: Model, opt: Optimizer, batch_sds,
                       ctx: ParallelCtx):
    """(params, opt_state, batch, step) ShapeDtypeStructs with shardings."""
    pshapes = model.param_shapes()
    pspecs = param_specs(pshapes, ctx)
    params = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(ctx.mesh, sp)),
        pshapes, pspecs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    ostate_shapes = jax.eval_shape(opt.init, pshapes)
    oshardings = opt_state_shardings(ostate_shapes, pshapes, ctx)
    opt_state = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        ostate_shapes, oshardings)
    step = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(ctx.mesh, P()))
    return params, opt_state, batch_sds, step


def sharded_params(model: Model, ctx: ParallelCtx):
    pshapes = model.param_shapes()
    pspecs = param_specs(pshapes, ctx)
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(ctx.mesh, sp)),
        pshapes, pspecs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
