"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets ``--xla_force_host_platform_device_count=512``
*before* calling these.
"""
from __future__ import annotations

import jax

from repro.runtime.sharding import ParallelCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(shape))


def make_ctx(mesh, *, ep: bool = True) -> ParallelCtx:
    axes = mesh.axis_names
    dp = tuple(a for a in axes if a in ("pod", "data"))
    tp = "model" if "model" in axes else None
    return ParallelCtx(mesh=mesh, dp=dp, tp=tp, ep=ep)


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for host-device-count tests (not the production shape)."""
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
