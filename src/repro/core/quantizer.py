"""Scalar weight quantizer: 2/3/4/8-bit, symmetric/asymmetric, per-group
scales along the input dim, plus int32 bit-packing for the serving kernel.

Layout convention: weights are (d_in, d_out); GPTQ iterates the d_in rows
(the "columns" of the transposed GPTQ paper view).  A group is ``group_size``
consecutive d_in rows sharing one (scale, zero) pair per output column.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    bits: int = 3
    group_size: int = 128  # -1: one group spanning all of d_in
    sym: bool = True

    @property
    def maxq(self) -> int:
        return 2 ** self.bits - 1

    def groups_for(self, d_in: int) -> int:
        gs = d_in if self.group_size == -1 else self.group_size
        assert d_in % gs == 0, (d_in, gs)
        return d_in // gs


def find_params(w_group: jax.Array, spec: QuantSpec):
    """w_group: (gs, d_out) -> (scale, zero) each (d_out,).

    The group-param computation is kept fused-multiply-free: every sym
    scale is produced by a *single* rounded floating op on ``amax`` (one
    division by an exactly-representable python constant), never a mul+div
    chain XLA could contract or reassociate.  Together with the
    batch-invariant triangular inverse in ``gptq._inv_upper`` (the actual
    seed of the historic vmap drift) this pins batched solves to
    bit-identical codes vs the sequential solver at 2-bit/small-group
    settings; tests/test_pipeline_perf.py regresses the parity."""
    wf = w_group.astype(jnp.float32)
    maxq = spec.maxq
    if spec.sym:
        amax = jnp.max(jnp.abs(wf), axis=0)
        # maxq/2 = (2^bits - 1)/2 is exact in fp32, so this is one
        # correctly-rounded division (vs two rounded ops for 2*amax/maxq)
        scale = jnp.maximum(amax / (maxq * 0.5), 1e-9)
        zero = jnp.full_like(scale, (maxq + 1) // 2)
    else:
        lo = jnp.minimum(jnp.min(wf, axis=0), 0.0)
        hi = jnp.maximum(jnp.max(wf, axis=0), 0.0)
        scale = jnp.maximum((hi - lo) / maxq, 1e-9)
        zero = jnp.round(-lo / scale)
    return scale, zero


def quantize_rtn(w: jax.Array, scale, zero, spec: QuantSpec):
    """Round-to-nearest. w: (..., d_out); scale/zero broadcastable."""
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale) + zero, 0, spec.maxq)
    return q.astype(jnp.int32)


def dequantize(q: jax.Array, scale, zero):
    return scale * (q.astype(jnp.float32) - zero)


def quantize_weight_rtn(w: jax.Array, spec: QuantSpec):
    """Plain RTN over the whole weight (baseline / no Hessian).

    Returns (w_deq, q, scales, zeros); scales/zeros: (n_groups, d_out)."""
    d_in, d_out = w.shape
    g = spec.groups_for(d_in)
    gs = d_in // g
    wg = w.reshape(g, gs, d_out)
    scale, zero = jax.vmap(lambda x: find_params(x, spec))(wg)
    q = quantize_rtn(wg, scale[:, None], zero[:, None], spec)
    deq = dequantize(q, scale[:, None], zero[:, None])
    return (deq.reshape(d_in, d_out).astype(w.dtype),
            q.reshape(d_in, d_out), scale, zero)


# ------------------------------------------------------------------- packing


def values_per_word(bits: int) -> int:
    return 32 // bits  # 3-bit stores 10 values (2 bits wasted)


def pack_codes(q: jax.Array, bits: int) -> jax.Array:
    """q: (..., d_in, d_out) int codes -> (..., ceil(d_in/vpw), d_out) uint32.

    Rows that don't fill the last word (``d_in % values_per_word(bits)``,
    the classic 3-bit edge case) are padded with zero codes, which the
    ``d_in`` argument of :func:`unpack_codes` strips again.  Leading batch
    axes (stacked expert weights) pack independently, and packing touches
    only the d_in axis — a d_out-sharded ``q`` packs shard-locally, which is
    what lets the pipeline's sharded write-back emit the serving artifact
    without ever gathering an unsharded code tensor."""
    vpw = values_per_word(bits)
    d_in, d_out = q.shape[-2:]
    pad = (-d_in) % vpw
    if pad:
        q = jnp.concatenate(
            [q, jnp.zeros(q.shape[:-2] + (pad, d_out), q.dtype)], axis=-2)
    qw = q.reshape(q.shape[:-2] + (-1, vpw, d_out)).astype(jnp.uint32)
    shifts = (jnp.arange(vpw, dtype=jnp.uint32) * bits)[:, None]
    return jnp.sum(qw << shifts, axis=-2).astype(jnp.uint32)


def unpack_codes(packed: jax.Array, bits: int, d_in: int) -> jax.Array:
    """(..., n_words, d_out) uint32 -> (..., d_in, d_out) int32 codes."""
    vpw = values_per_word(bits)
    shifts = (jnp.arange(vpw, dtype=jnp.uint32) * bits)[:, None]
    mask = jnp.uint32(2 ** bits - 1)
    vals = (packed[..., :, None, :] >> shifts) & mask
    out = vals.reshape(packed.shape[:-2] + (-1, packed.shape[-1]))
    return out[..., :d_in, :].astype(jnp.int32)


def dequantize_packed(packed: jax.Array, scale: jax.Array, zero: jax.Array,
                      *, bits: int, d_in: int) -> jax.Array:
    """Packed codes + per-group params -> fp weight, entirely on device.

    packed: (..., n_words, d_out); scale/zero: (..., n_groups, d_out) with
    the group size implied by ``d_in // n_groups``.  This is the serving
    loader's reconstruction path (checkpoint/packed): host memory only ever
    holds the packed artifact; the fp tensor first exists on device."""
    q = unpack_codes(packed, bits, d_in)
    g = scale.shape[-2]
    assert d_in % g == 0, (d_in, g)
    qg = q.reshape(q.shape[:-2] + (g, d_in // g, q.shape[-1]))
    deq = dequantize(qg, scale[..., :, None, :], zero[..., :, None, :])
    return deq.reshape(q.shape)
