"""RSQ layer-wise quantization pipeline (Rotate -> Scale -> Quantize).

Drives the whole recipe over a model:
  0. dataset expansion (circular shifts, Sec 4.4)
  1. fuse norms + rotate the model (QuaRot step; skippable -> GPTQ baseline)
  2. layer-by-layer: capture per-weight inputs (with attention column sums),
     compute token importance R, accumulate H_w = 2 X R^2 X^T per weight,
     run GPTQ (or LDLQ+E8 VQ), write back, propagate *quantized* outputs to
     the next layer (standard GPTQ error-feedback scheme).

Baselines are config points: GPTQ = no rotation + uniform; QuaRot =
rotation + uniform; RSQ = rotation + a token-importance strategy.

Scale notes: calibration batches stream through jitted capture functions;
Hessian accumulation is O(d^2) state per weight (one layer's worth at a
time).  The distributed variants (data-parallel Hessians, weight-parallel
solves) live in core/distributed.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import hessian as hess
from repro.core.expansion import expand_dataset
from repro.core.gptq import gptq_quantize
from repro.core.importance import ImportanceInputs, get_strategy
from repro.core.ldlq import ldlq_quantize
from repro.core.quantizer import QuantSpec
from repro.core.rotation import rotate_model
from repro.models.layers import rms_norm
from repro.models.lm import Model, apply_block, capture_block


@dataclasses.dataclass(frozen=True)
class RSQConfig:
    bits: int = 3
    group_size: int = 128
    sym: bool = True
    rotate: bool = True
    importance: str = "attn_con"  # see core.importance.STRATEGIES
    r_min: float = 0.01
    r_max: float = 1.0
    first_n: int = 1024  # for the First-N / First&Last-N heuristics
    expansion: int = 1  # dataset expansion factor M (paper: 8)
    damp: float = 0.01
    method: str = "gptq"  # gptq | ldlq (E8 vector quantization)
    gptq_block: int = 128
    seed: int = 0
    # restrict the loss to a token chunk (Tab. 1 reproduction):
    chunk_lo: float = 0.0
    chunk_hi: float = 1.0

    def spec(self) -> QuantSpec:
        return QuantSpec(bits=self.bits, group_size=self.group_size,
                         sym=self.sym)


def _strategy_kwargs(rsq: RSQConfig) -> dict:
    if rsq.importance in ("first_n", "first_last_n"):
        return {"n": rsq.first_n}
    if rsq.importance == "uniform":
        return {}
    return {"r_min": rsq.r_min, "r_max": rsq.r_max}


def _chunk_mask(r: jax.Array, rsq: RSQConfig) -> jax.Array:
    """Tab.-1 style chunk restriction on top of any strategy."""
    if rsq.chunk_lo <= 0.0 and rsq.chunk_hi >= 1.0:
        return r
    t = r.shape[-1]
    idx = jnp.arange(t)
    mask = (idx >= int(rsq.chunk_lo * t)) & (idx < int(rsq.chunk_hi * t))
    return r * mask.astype(r.dtype)


_QUANT_SKIP = ("router",)  # routers stay fp32 (standard MoE practice)


def _is_quantizable(path: str, arr) -> bool:
    if any(s in path for s in _QUANT_SKIP):
        return False
    return arr.ndim >= 2 and min(arr.shape[-2:]) >= 16


def quantize_layer_weights(p_block: dict, hessians: dict[str, Any],
                           rsq: RSQConfig) -> tuple[dict, dict]:
    """Solve GPTQ/LDLQ for every captured weight of one block."""
    report = {}
    new_p = jax.tree.map(lambda x: x, p_block)

    def solve(w, h):
        d_in = w.shape[0]
        block = min(rsq.gptq_block, d_in)
        if rsq.method == "ldlq":
            out = ldlq_quantize(w, h, damp=rsq.damp, block=block)
        else:
            spec = rsq.spec()
            gs = spec.group_size
            if gs != -1 and (gs > block or block % gs or d_in % gs):
                spec = dataclasses.replace(spec, group_size=-1)
            out = gptq_quantize(w, h, spec, damp=rsq.damp, block=block)
        return out["w_deq"], float(out["err"])

    for path, h in hessians.items():
        parts = path.split("/")
        # resolve the weight inside the block params
        node = new_p
        for key in parts[:-1]:
            node = node[key]
        name = parts[-1]
        w = node[name]
        if not _is_quantizable(path, w):
            continue
        if w.ndim == 3:  # stacked experts: batched solve (vmapped on TPU)
            outs = [solve(w[e], h[e]) for e in range(w.shape[0])]
            node[name] = jnp.stack([o[0] for o in outs]).astype(w.dtype)
            report[path] = float(np.mean([o[1] for o in outs]))
        else:
            deq, err = solve(w, h)
            node[name] = deq.astype(w.dtype)
            report[path] = err
    return new_p, report


class RSQPipeline:
    def __init__(self, model: Model, rsq: RSQConfig):
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.rsq = rsq
        self.strategy = get_strategy(rsq.importance)
        self.skw = _strategy_kwargs(rsq)

    # ---------------------------------------------------------------- utils
    def _importance(self, z_in, z_out, tokens, colsum, counts):
        inp = ImportanceInputs(z_in=z_in, z_out=z_out, tokens=tokens,
                               attn_colsum=colsum, token_counts=counts)
        r = self.strategy(inp, **self.skw)
        return _chunk_mask(r, self.rsq)

    def _accumulate(self, hessians, caps, dom, r):
        """Add one batch's contribution to every weight Hessian."""
        slot_token = caps.get("ffn/__moe_slot_token")
        for path, x_c in caps.items():
            if path.endswith("__moe_slot_token"):
                continue
            d = dom[path]
            if d in ("stream", "hidden"):
                r_rows = r.reshape(-1)
            elif d == "media":
                r_rows = None
            else:  # expert buffers (E, C, d): scatter r into slots
                rf = jnp.concatenate([r.reshape(-1), jnp.zeros((1,))])
                r_rows = rf[slot_token]  # (E*C,)
            if x_c.ndim == 3 and d == "expert":
                e, c, din = x_c.shape
                xr = (x_c.reshape(e * c, din).astype(jnp.float32)
                      * r_rows[:, None]).reshape(e, c, din)
                upd = 2.0 * jnp.einsum("ecd,ecf->edf", xr, xr)
                hessians[path] = upd if path not in hessians else (
                    hessians[path] + upd)
            else:
                x2 = x_c.reshape(-1, x_c.shape[-1])
                hessians[path] = hess.accumulate(
                    hessians.get(path), x2, r_rows)
        return hessians

    # ----------------------------------------------------------------- main
    def run(self, params: dict, calib_tokens, *, batch_size: int = 8,
            media=None, frames=None, verbose: bool = False):
        """Quantize `params`. calib_tokens: (N, T) int32 (pre-expansion).

        Returns (new_params, report)."""
        model, cfg, rsq = self.model, self.cfg, self.rsq
        key = jax.random.key(rsq.seed)
        report: dict[str, Any] = {"layers": {}, "rsq": dataclasses.asdict(rsq)}

        calib = expand_dataset(jnp.asarray(calib_tokens), rsq.expansion)
        counts = jnp.bincount(calib.reshape(-1),
                              length=cfg.vocab_size).astype(jnp.float32)

        if rsq.rotate:
            params, rotations = rotate_model(params, cfg, model, key)
            report["rotated"] = True
        else:
            params = dict(params)
            rotations = {}
        # decouple the mutable containers we write into from the caller's
        new_params = dict(params)
        if "prefix" in new_params:
            new_params["prefix"] = list(new_params["prefix"])
        new_params["groups"] = dict(new_params["groups"])
        if "encoder" in new_params:
            new_params["encoder"] = dict(new_params["encoder"])

        n = calib.shape[0]
        batches = [calib[i : i + batch_size]
                   for i in range(0, n, batch_size)]
        embed = params["embed"]
        acts = [jnp.asarray(embed[b_]).astype(model.dtype) for b_ in batches]
        t = calib.shape[1]
        positions = jnp.arange(t)

        media_b = None
        if media is not None:
            media_b = [media[i : i + batch_size] for i in range(0, n, batch_size)]

        # ---------- encoder stack (enc-dec models) then decoder stack
        enc_out = None
        if cfg.family == "encdec":
            assert frames is not None
            frames = jnp.asarray(frames)
            if "frame_proj" in params:
                frames = frames @ params["frame_proj"].astype(frames.dtype)
            enc_acts = [frames[i : i + batch_size]
                        for i in range(0, n, batch_size)]
            for li in range(cfg.n_encoder_layers):
                p_blk = jax.tree.map(lambda a: a[li],
                                     params["encoder"]["groups"])["b0"]
                p_new, enc_acts, rep = self._quantize_one_layer(
                    p_blk, model.enc_metas[0], enc_acts, None, calib,
                    batch_size, counts, positions, verbose,
                    tag=f"enc{li}")
                report["layers"][f"enc{li}"] = rep
                new_params["encoder"]["groups"] = jax.tree.map(
                    lambda full, nw: full.at[li].set(nw),
                    new_params["encoder"]["groups"], {"b0": p_new})
            enc_acts = [rms_norm(a, params["encoder"]["final_norm"],
                                 cfg.norm_eps) for a in enc_acts]
            media_b = enc_acts

        # ---------- decoder prefix + groups
        def layer_params(li):
            if li < len(model.prefix_metas):
                return params["prefix"][li], model.prefix_metas[li], ("prefix", li)
            j = li - len(model.prefix_metas)
            g, o = divmod(j, model.period)
            blk = jax.tree.map(lambda a: a[g], params["groups"])[f"b{o}"]
            return blk, model.group_metas[o], ("groups", g, o)

        n_layers = len(model.prefix_metas) + model.n_groups * model.period
        for li in range(n_layers):
            p_blk, meta, loc = layer_params(li)
            p_new, acts, rep = self._quantize_one_layer(
                p_blk, meta, acts, media_b, calib, batch_size, counts,
                positions, verbose, tag=f"layer{li}")
            report["layers"][f"layer{li}"] = rep
            if loc[0] == "prefix":
                new_params["prefix"][loc[1]] = p_new
            else:
                _, g, o = loc
                stacked = new_params["groups"]

                def set_at(full, nw, g=g):
                    return full.at[g].set(nw.astype(full.dtype))

                stacked[f"b{o}"] = jax.tree.map(
                    set_at, stacked[f"b{o}"], p_new)
                new_params["groups"] = stacked

        report["rotations"] = {k: (None if v is None else "set")
                               for k, v in rotations.items()}
        return new_params, report

    def _quantize_one_layer(self, p_blk, meta, acts, media_b, calib,
                            batch_size, counts, positions, verbose, tag=""):
        cfg, rsq = self.cfg, self.rsq
        t0 = time.time()
        dom_holder: dict[str, str] = {}

        def _cap(p, x, med):
            y, caps, dom, colsum = capture_block(p, cfg, meta, x,
                                                 positions=positions,
                                                 media=med)
            dom_holder.update(dom)  # static strings — captured at trace time
            return y, caps, colsum

        cap_fn = jax.jit(_cap)
        app_fn = jax.jit(
            lambda p, x, med: apply_block(p, cfg, meta, x,
                                          positions=positions, media=med)[0])
        hessians: dict[str, Any] = {}
        importances = []
        for bi, x_b in enumerate(acts):
            med = media_b[bi] if media_b is not None else None
            tok = calib[bi * batch_size : bi * batch_size + x_b.shape[0]]
            y_b, caps, colsum = cap_fn(p_blk, x_b, med)
            r = self._importance(x_b, y_b, tok, colsum, counts)
            importances.append(r)
            hessians = self._accumulate(hessians, caps, dom_holder, r)
        p_new, rep = quantize_layer_weights(p_blk, hessians, rsq)
        # propagate quantized outputs
        new_acts = [app_fn(p_new, x_b,
                           media_b[bi] if media_b is not None else None)
                    for bi, x_b in enumerate(acts)]
        rep = {"weights": rep, "seconds": round(time.time() - t0, 2)}
        if verbose:
            print(f"  [{tag}] {len(rep['weights'])} weights quantized "
                  f"in {rep['seconds']}s", flush=True)
        return p_new, new_acts, rep


def quantize_model(model: Model, params: dict, calib_tokens,
                   rsq: RSQConfig, **kw):
    return RSQPipeline(model, rsq).run(params, calib_tokens, **kw)
