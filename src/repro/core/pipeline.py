"""RSQ layer-wise quantization pipeline (Rotate -> Scale -> Quantize).

Drives the whole recipe over a model:
  0. dataset expansion (circular shifts, Sec 4.4)
  1. fuse norms + rotate the model (QuaRot step; skippable -> GPTQ baseline)
  2. layer-by-layer: capture per-weight inputs (with attention column sums),
     compute token importance R, accumulate H_w = 2 X R^2 X^T per weight,
     run GPTQ (or LDLQ+E8 VQ), write back, propagate *quantized* outputs to
     the next layer (standard GPTQ error-feedback scheme).

Baselines are config points: GPTQ = no rotation + uniform; QuaRot =
rotation + uniform; RSQ = rotation + a token-importance strategy.

Calibration engine
------------------
The hot path is a single fused, trace-cached pass:

  * **Per-meta jit cache** — capture/apply closures are built and jitted
    once per distinct ``(BlockMeta, param-shape)`` signature, not once per
    layer.  A stack of L homogeneous layers compiles O(distinct metas)
    XLA programs instead of O(L).  ``RSQPipeline.trace_counts`` records
    actual retraces (the regression tests and ``benchmarks/pipeline_bench``
    assert on it).  ``RSQConfig.trace_cache=False`` restores the legacy
    fresh-jit-per-layer behaviour (used as the benchmark baseline).
  * **Fused calibration step** — capture, token importance, and Hessian
    accumulation run as ONE jitted program per batch with the Hessian dict
    donated (``donate_argnums``), so the O(d^2)-per-weight accumulator
    state is updated in place instead of round-tripping through fresh
    buffers.  Dense and stacked-expert updates both route through
    ``hess.accumulate``, which dispatches the Pallas ``gram`` kernel when
    ``use_gram_kernel`` resolves on (auto-on for the TPU backend).
  * **Batched solves** — GPTQ solves are shape-grouped: weights sharing
    ``(d_in, d_out)`` (q/k/v, gate/up) stack into one vmapped
    ``gptq_quantize_batched`` call and stacked experts go through the
    batched path directly, instead of a sequential Python loop.

Scale notes: calibration batches stream through jitted capture functions;
Hessian accumulation is O(d^2) state per weight (one layer's worth at a
time).  The distributed variants (data-parallel Hessians, weight-parallel
solves) live in core/distributed.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import hessian as hess
from repro.core.distributed import gptq_quantize_batched
from repro.core.expansion import expand_dataset
from repro.core.gptq import gptq_quantize
from repro.core.importance import ImportanceInputs, get_strategy
from repro.core.ldlq import ldlq_quantize
from repro.core.quantizer import QuantSpec
from repro.core.rotation import rotate_model
from repro.models.layers import rms_norm
from repro.models.lm import Model, apply_block, capture_block


@dataclasses.dataclass(frozen=True)
class RSQConfig:
    bits: int = 3
    group_size: int = 128
    sym: bool = True
    rotate: bool = True
    importance: str = "attn_con"  # see core.importance.STRATEGIES
    r_min: float = 0.01
    r_max: float = 1.0
    first_n: int = 1024  # for the First-N / First&Last-N heuristics
    expansion: int = 1  # dataset expansion factor M (paper: 8)
    damp: float = 0.01
    method: str = "gptq"  # gptq | ldlq (E8 vector quantization)
    gptq_block: int = 128
    seed: int = 0
    # restrict the loss to a token chunk (Tab. 1 reproduction):
    chunk_lo: float = 0.0
    chunk_hi: float = 1.0
    # Pallas gram kernel for Hessian accumulation (None: auto-on for TPU)
    use_gram_kernel: Optional[bool] = None
    # per-meta jit cache for capture/apply (False: legacy per-layer jits,
    # kept as the benchmark baseline)
    trace_cache: bool = True

    def spec(self) -> QuantSpec:
        return QuantSpec(bits=self.bits, group_size=self.group_size,
                         sym=self.sym)


def _strategy_kwargs(rsq: RSQConfig) -> dict:
    if rsq.importance in ("first_n", "first_last_n"):
        return {"n": rsq.first_n}
    if rsq.importance == "uniform":
        return {}
    return {"r_min": rsq.r_min, "r_max": rsq.r_max}


def _chunk_mask(r: jax.Array, rsq: RSQConfig) -> jax.Array:
    """Tab.-1 style chunk restriction on top of any strategy."""
    if rsq.chunk_lo <= 0.0 and rsq.chunk_hi >= 1.0:
        return r
    t = r.shape[-1]
    idx = jnp.arange(t)
    mask = (idx >= int(rsq.chunk_lo * t)) & (idx < int(rsq.chunk_hi * t))
    return r * mask.astype(r.dtype)


_QUANT_SKIP = ("router",)  # routers stay fp32 (standard MoE practice)


def _is_quantizable(path: str, arr) -> bool:
    if any(s in path for s in _QUANT_SKIP):
        return False
    return arr.ndim >= 2 and min(arr.shape[-2:]) >= 16


def _solve_spec(rsq: RSQConfig, d_in: int) -> tuple[QuantSpec, int]:
    """Per-d_in GPTQ block size + group-size fallback (shared by the
    sequential and batched paths so their outputs are identical)."""
    block = min(rsq.gptq_block, d_in)
    spec = rsq.spec()
    gs = spec.group_size
    if gs != -1 and (gs > block or block % gs or d_in % gs):
        spec = dataclasses.replace(spec, group_size=-1)
    return spec, block


def quantize_layer_weights(p_block: dict, hessians: dict[str, Any],
                           rsq: RSQConfig) -> tuple[dict, dict]:
    """Solve GPTQ/LDLQ for every captured weight of one block.

    GPTQ solves are shape-grouped: all weights sharing ``(d_in, d_out)``
    (q/k/v, gate/up, every expert of a stacked (E, d_in, d_out) tensor)
    are stacked into a single ``gptq_quantize_batched`` call — one vmapped
    program per distinct shape instead of one dispatch per weight."""
    report = {}
    new_p = jax.tree.map(lambda x: x, p_block)

    items = []  # (path, node, name, w, h) for every quantizable weight
    for path, h in hessians.items():
        parts = path.split("/")
        node = new_p
        for key in parts[:-1]:
            node = node[key]
        name = parts[-1]
        w = node[name]
        if not _is_quantizable(path, w):
            continue
        items.append((path, node, name, w, h))

    if rsq.method == "ldlq":
        def solve(w, h):
            block = min(rsq.gptq_block, w.shape[0])
            out = ldlq_quantize(w, h, damp=rsq.damp, block=block)
            return out["w_deq"], float(out["err"])

        for path, node, name, w, h in items:
            if w.ndim == 3:  # stacked experts
                outs = [solve(w[e], h[e]) for e in range(w.shape[0])]
                node[name] = jnp.stack([o[0] for o in outs]).astype(w.dtype)
                report[path] = float(np.mean([o[1] for o in outs]))
            else:
                deq, err = solve(w, h)
                node[name] = deq.astype(w.dtype)
                report[path] = err
        return new_p, report

    # ---- GPTQ: group by (d_in, d_out); one batched solve per group
    groups: dict[tuple, list] = {}
    for it in items:
        groups.setdefault(tuple(it[3].shape[-2:]), []).append(it)
    for (d_in, d_out), its in groups.items():
        spec, block = _solve_spec(rsq, d_in)
        n_solves = sum(1 if it[3].ndim == 2 else it[3].shape[0] for it in its)
        if n_solves == 1 and its[0][3].ndim == 2:  # lone 2-D weight: no
            # batch dim to vmap over (a lone (1, d_in, d_out) expert stack
            # stays on the batched path — it already carries the lead axis)
            path, node, name, w, h = its[0]
            out = gptq_quantize(w, h, spec, damp=rsq.damp, block=block)
            node[name] = out["w_deq"].astype(w.dtype)
            report[path] = float(out["err"])
            continue
        ws = jnp.concatenate(
            [it[3][None] if it[3].ndim == 2 else it[3] for it in its])
        hs = jnp.concatenate(
            [it[4][None] if it[4].ndim == 2 else it[4] for it in its])
        out = gptq_quantize_batched(ws, hs, spec, damp=rsq.damp, block=block)
        errs = np.asarray(out["err"])
        o = 0
        for path, node, name, w, h in its:
            if w.ndim == 2:
                node[name] = out["w_deq"][o].astype(w.dtype)
                report[path] = float(errs[o])
                o += 1
            else:
                e = w.shape[0]
                node[name] = out["w_deq"][o : o + e].astype(w.dtype)
                report[path] = float(errs[o : o + e].mean())
                o += e
    return new_p, report


@dataclasses.dataclass
class _LayerFns:
    """One cache entry of the calibration engine: the jitted fused
    calibration step, the jitted quantized-forward, and the zero Hessian
    initializer (shapes precomputed via eval_shape, no tracing)."""
    fused: Callable  # (p, x, med, tok, counts, hessians) -> hessians
    apply: Callable  # (p, x, med) -> y
    hess_init: Callable  # () -> {path: zeros}


class RSQPipeline:
    def __init__(self, model: Model, rsq: RSQConfig):
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.rsq = rsq
        self.strategy = get_strategy(rsq.importance)
        self.skw = _strategy_kwargs(rsq)
        self.use_kernel = (rsq.use_gram_kernel
                           if rsq.use_gram_kernel is not None
                           else jax.default_backend() == "tpu")
        self._layer_fns: dict[Any, _LayerFns] = {}
        # retraces of the cached capture/apply programs; a homogeneous
        # L-layer stack should end a run at 1/1, not L/L
        self.trace_counts = {"capture": 0, "apply": 0}

    # ---------------------------------------------------------------- utils
    def _importance(self, z_in, z_out, tokens, colsum, counts):
        inp = ImportanceInputs(z_in=z_in, z_out=z_out, tokens=tokens,
                               attn_colsum=colsum, token_counts=counts)
        r = self.strategy(inp, **self.skw)
        return _chunk_mask(r, self.rsq)

    def _accumulate(self, hessians, caps, dom, r):
        """Add one batch's contribution to every weight Hessian."""
        slot_token = caps.get("ffn/__moe_slot_token")
        for path, x_c in caps.items():
            if path.endswith("__moe_slot_token"):
                continue
            d = dom[path]
            if d in ("stream", "hidden"):
                r_rows = r.reshape(-1)
            elif d == "media":
                r_rows = None
            else:  # expert buffers (E, C, d): scatter r into slots
                rf = jnp.concatenate([r.reshape(-1), jnp.zeros((1,), r.dtype)])
                r_rows = rf[slot_token].reshape(x_c.shape[0], x_c.shape[1])
            if not (x_c.ndim == 3 and d == "expert"):
                x_c = x_c.reshape(-1, x_c.shape[-1])
            hessians[path] = hess.accumulate(
                hessians.get(path), x_c, r_rows, use_kernel=self.use_kernel)
        return hessians

    def _layer_key(self, meta, p_blk):
        p_sig = tuple((tuple(a.shape), str(a.dtype))
                      for a in jax.tree.leaves(p_blk))
        return (meta, p_sig)

    def _get_layer_fns(self, meta, p_blk, x, med) -> _LayerFns:
        """Build (or fetch) the jitted fused/apply programs for one block
        signature.  The jits themselves handle batch-shape polymorphism
        (e.g. a ragged tail batch) by retracing, so the cache key only
        carries what changes the *captured structure*: the meta and the
        block's parameter shapes."""
        key = self._layer_key(meta, p_blk)
        if self.rsq.trace_cache and key in self._layer_fns:
            return self._layer_fns[key]
        cfg, meta_ = self.cfg, meta
        dom: dict[str, str] = {}

        def _probe(p, x, med):
            _, caps, d, _ = capture_block(p, cfg, meta_, x, media=med)
            dom.update(d)
            return caps

        caps_s = jax.eval_shape(_probe, p_blk, x, med)
        hshapes = {}
        for path, s in caps_s.items():
            if path.endswith("__moe_slot_token"):
                continue
            if s.ndim == 3 and dom[path] == "expert":
                hshapes[path] = (s.shape[0], s.shape[-1], s.shape[-1])
            else:
                hshapes[path] = (s.shape[-1], s.shape[-1])

        def hess_init():
            return {p_: jnp.zeros(sh, jnp.float32)
                    for p_, sh in hshapes.items()}

        def _fused(p, x, med, tok, counts, hessians):
            # python side effect at trace time: counts XLA compilations
            self.trace_counts["capture"] += 1
            y, caps, dom_t, colsum = capture_block(p, cfg, meta_, x,
                                                   media=med)
            r = self._importance(x, y, tok, colsum, counts)
            return self._accumulate(hessians, caps, dom_t, r)

        def _apply(p, x, med):
            self.trace_counts["apply"] += 1
            return apply_block(p, cfg, meta_, x, media=med)[0]

        fns = _LayerFns(fused=jax.jit(_fused, donate_argnums=(5,)),
                        apply=jax.jit(_apply), hess_init=hess_init)
        if self.rsq.trace_cache:
            self._layer_fns[key] = fns
        return fns

    # ----------------------------------------------------------------- main
    def run(self, params: dict, calib_tokens, *, batch_size: int = 8,
            media=None, frames=None, verbose: bool = False):
        """Quantize `params`. calib_tokens: (N, T) int32 (pre-expansion).

        Returns (new_params, report)."""
        model, cfg, rsq = self.model, self.cfg, self.rsq
        key = jax.random.key(rsq.seed)
        # per-run compile accounting (cached jits from a previous run on the
        # same pipeline legitimately contribute 0 traces to this run)
        self.trace_counts.update(capture=0, apply=0)
        report: dict[str, Any] = {"layers": {}, "rsq": dataclasses.asdict(rsq)}

        calib = expand_dataset(jnp.asarray(calib_tokens), rsq.expansion)
        counts = jnp.bincount(calib.reshape(-1),
                              length=cfg.vocab_size).astype(jnp.float32)

        if rsq.rotate:
            params, rotations = rotate_model(params, cfg, model, key)
            report["rotated"] = True
        else:
            params = dict(params)
            rotations = {}
        # decouple the mutable containers we write into from the caller's
        new_params = dict(params)
        if "prefix" in new_params:
            new_params["prefix"] = list(new_params["prefix"])
        new_params["groups"] = dict(new_params["groups"])
        if "encoder" in new_params:
            new_params["encoder"] = dict(new_params["encoder"])

        n = calib.shape[0]
        batches = [calib[i : i + batch_size]
                   for i in range(0, n, batch_size)]
        embed = params["embed"]
        acts = [jnp.asarray(embed[b_]).astype(model.dtype) for b_ in batches]

        media_b = None
        if media is not None:
            media_b = [media[i : i + batch_size] for i in range(0, n, batch_size)]

        # ---------- encoder stack (enc-dec models) then decoder stack
        if cfg.family == "encdec":
            assert frames is not None
            frames = jnp.asarray(frames)
            if "frame_proj" in params:
                frames = frames @ params["frame_proj"].astype(frames.dtype)
            enc_acts = [frames[i : i + batch_size]
                        for i in range(0, n, batch_size)]
            for li in range(cfg.n_encoder_layers):
                p_blk = jax.tree.map(lambda a: a[li],
                                     params["encoder"]["groups"])["b0"]
                p_new, enc_acts, rep = self._quantize_one_layer(
                    p_blk, model.enc_metas[0], enc_acts, None, calib,
                    batch_size, counts, verbose, tag=f"enc{li}")
                report["layers"][f"enc{li}"] = rep
                new_params["encoder"]["groups"] = jax.tree.map(
                    lambda full, nw: full.at[li].set(nw),
                    new_params["encoder"]["groups"], {"b0": p_new})
            enc_acts = [rms_norm(a, params["encoder"]["final_norm"],
                                 cfg.norm_eps) for a in enc_acts]
            media_b = enc_acts

        # ---------- decoder prefix + groups
        def layer_params(li):
            if li < len(model.prefix_metas):
                return params["prefix"][li], model.prefix_metas[li], ("prefix", li)
            j = li - len(model.prefix_metas)
            g, o = divmod(j, model.period)
            blk = jax.tree.map(lambda a: a[g], params["groups"])[f"b{o}"]
            return blk, model.group_metas[o], ("groups", g, o)

        n_layers = len(model.prefix_metas) + model.n_groups * model.period
        for li in range(n_layers):
            p_blk, meta, loc = layer_params(li)
            p_new, acts, rep = self._quantize_one_layer(
                p_blk, meta, acts, media_b, calib, batch_size, counts,
                verbose, tag=f"layer{li}")
            report["layers"][f"layer{li}"] = rep
            if loc[0] == "prefix":
                new_params["prefix"][loc[1]] = p_new
            else:
                _, g, o = loc
                stacked = new_params["groups"]

                def set_at(full, nw, g=g):
                    return full.at[g].set(nw.astype(full.dtype))

                stacked[f"b{o}"] = jax.tree.map(
                    set_at, stacked[f"b{o}"], p_new)
                new_params["groups"] = stacked

        report["rotations"] = {k: (None if v is None else "set")
                               for k, v in rotations.items()}
        report["trace_counts"] = dict(self.trace_counts)
        return new_params, report

    def _quantize_one_layer(self, p_blk, meta, acts, media_b, calib,
                            batch_size, counts, verbose, tag=""):
        rsq = self.rsq
        t0 = time.perf_counter()
        fns = self._get_layer_fns(
            meta, p_blk, acts[0], media_b[0] if media_b is not None else None)
        # fused capture+importance+accumulate per batch; the Hessian dict is
        # donated, so the accumulator state updates in place
        hessians = fns.hess_init()
        for bi, x_b in enumerate(acts):
            med = media_b[bi] if media_b is not None else None
            tok = calib[bi * batch_size : bi * batch_size + x_b.shape[0]]
            hessians = fns.fused(p_blk, x_b, med, tok, counts, hessians)
        p_new, rep = quantize_layer_weights(p_blk, hessians, rsq)
        # propagate quantized outputs
        new_acts = [fns.apply(p_new, x_b,
                              media_b[bi] if media_b is not None else None)
                    for bi, x_b in enumerate(acts)]
        # 4 decimals: warm trace-cached layers run in the 10 ms range, and
        # BENCH_pipeline.json regresses against these values
        rep = {"weights": rep,
               "seconds": round(time.perf_counter() - t0, 4)}
        if verbose:
            print(f"  [{tag}] {len(rep['weights'])} weights quantized "
                  f"in {rep['seconds']}s", flush=True)
        return p_new, new_acts, rep


def quantize_model(model: Model, params: dict, calib_tokens,
                   rsq: RSQConfig, **kw):
    return RSQPipeline(model, rsq).run(params, calib_tokens, **kw)
