"""RSQ layer-wise quantization pipeline (Rotate -> Scale -> Quantize).

Drives the whole recipe over a model:
  0. dataset expansion (circular shifts, Sec 4.4)
  1. fuse norms + rotate the model (QuaRot step; skippable -> GPTQ baseline)
  2. layer-by-layer: capture per-weight inputs (with attention column sums),
     compute token importance R, accumulate H_w = 2 X R^2 X^T per weight,
     run GPTQ (or LDLQ+E8 VQ), write back, propagate *quantized* outputs to
     the next layer (standard GPTQ error-feedback scheme).

Baselines are config points: GPTQ = no rotation + uniform; QuaRot =
rotation + uniform; RSQ = rotation + a token-importance strategy.

Calibration engine
------------------
The hot path is a scheduled stack of fused, trace-cached per-layer stages:

  * **Per-meta jit cache** — capture/apply closures are built and jitted
    once per distinct ``(BlockMeta, param-shape)`` signature, not once per
    layer.  A stack of L homogeneous layers compiles O(distinct metas)
    XLA programs instead of O(L).  ``RSQPipeline.trace_counts`` records
    actual retraces (the regression tests and ``benchmarks/pipeline_bench``
    assert on it).  ``RSQConfig.trace_cache=False`` restores the legacy
    fresh-jit-per-layer behaviour (used as the benchmark baseline).
  * **Fused calibration step** — capture, token importance, and Hessian
    accumulation run as ONE jitted program per batch with the Hessian dict
    donated (``donate_argnums``), so the O(d^2)-per-weight accumulator
    state is updated in place.  Dense and stacked-expert updates both route
    through ``hess.accumulate`` (Pallas ``gram`` kernel auto-on for TPU).
  * **Layer scheduler** — the layer loop itself is pluggable
    (``core/scheduler``): the pipeline exposes its per-layer stages as
    engine hooks (``layer_begin`` / ``layer_capture`` / ``layer_solve`` /
    ``layer_apply`` / ``layer_finalize``) and ``RSQConfig.scheduler``
    selects who drives them.  ``SequentialScheduler`` is the classic
    lock-step loop; ``OverlappedScheduler`` software-pipelines dispatch so
    layer i's GPTQ/LDLQ solve executes while layer i+1's fused capture is
    already being issued over double-buffered activations, with every host
    sync (error-report floats) deferred to one drain — bit-identical
    results, no per-layer pipeline bubble.  Because capture/apply are
    trace-cached per meta, the overlapped schedule adds zero compilations.
  * **Streamed sharded Hessians** — ``RSQConfig.shard_hessians`` switches
    the accumulators to the streaming layout: (S, d, d) partial sums with
    the shard axis on the mesh's data axes (``ParallelCtx.shard_leading``),
    so each device accumulates only its local token chunk and no device
    ever materializes an unsharded per-layer Hessian during accumulation;
    ``hess.reduce_shards`` performs the single solve-time reduction (one
    psum under GSPMD; the standalone streaming API with an explicit ring
    all-reduce lives in ``core/distributed.make_sharded_hessian_fn``).
  * **Batched solves** — GPTQ *and* LDLQ solves are shape-grouped: weights
    sharing ``(d_in, d_out)`` (q/k/v, gate/up) stack into one vmapped
    ``gptq_quantize_batched`` / ``ldlq_quantize_batched`` call and stacked
    experts go through the batched path directly, instead of a sequential
    Python loop.  Solve error reports are built lazily (jax scalars) so
    schedulers decide when the host pays the sync.
  * **Sharded write-back** — ``RSQConfig.pack_output`` folds every solve's
    ``(q, scale, zero)`` straight into the packed serving artifact
    (``RSQPipeline.artifact``; persist via
    ``checkpoint.packed.save_packed_artifact``): codes are packed by a
    jitted ``quantizer.pack_codes`` (a d_in-axis op, so d_out shards pack
    locally) and constrained onto the mesh's model axis — no host ever
    holds an unsharded per-layer ``(q, scales)`` tensor, and the artifact
    is saved one addressable shard at a time.  Input side, the pipeline
    accepts a globally-sharded calibration array from
    ``data.loader.CalibrationLoader`` (disjoint per-data-group slices)
    whose rows feed the streaming accumulators chunk-aligned; the
    solve-time shard reduction routes through the explicit ring collective
    (``distributed.make_shard_reducer``) whenever a live mesh is present.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import hessian as hess
from repro.core.distributed import (gptq_quantize_batched,
                                    ldlq_quantize_batched,
                                    make_shard_reducer)
from repro.core.expansion import expand_dataset
from repro.core.gptq import gptq_quantize
from repro.core.importance import ImportanceInputs, get_strategy
from repro.core.ldlq import ldlq_quantize
from repro.core.quantizer import QuantSpec, pack_codes
from repro.core.rotation import rotate_model
from repro.core.scheduler import get_scheduler, resolve_hessian_shards
from repro.models.layers import rms_norm
from repro.models.lm import Model, apply_block, capture_block
from repro.runtime.sharding import LOCAL, ParallelCtx


@dataclasses.dataclass(frozen=True)
class RSQConfig:
    bits: int = 3
    group_size: int = 128
    sym: bool = True
    rotate: bool = True
    importance: str = "attn_con"  # see core.importance.STRATEGIES
    r_min: float = 0.01
    r_max: float = 1.0
    first_n: int = 1024  # for the First-N / First&Last-N heuristics
    expansion: int = 1  # dataset expansion factor M (paper: 8)
    damp: float = 0.01
    method: str = "gptq"  # gptq | ldlq (E8 vector quantization)
    gptq_block: int = 128
    seed: int = 0
    # restrict the loss to a token chunk (Tab. 1 reproduction):
    chunk_lo: float = 0.0
    chunk_hi: float = 1.0
    # Pallas gram kernel for Hessian accumulation (None: auto-on for TPU)
    use_gram_kernel: Optional[bool] = None
    # per-meta jit cache for capture/apply (False: legacy per-layer jits,
    # kept as the benchmark baseline)
    trace_cache: bool = True
    # layer scheduler: "sequential" | "overlapped" | None (auto: sequential
    # on CPU, overlapped on accelerators) — see core/scheduler
    scheduler: Optional[str] = None
    # streaming sharded Hessian accumulators: False = dense (d, d) dicts;
    # True = shard over the mesh's data axes (S = dp size); int S > 1 = S
    # partial-sum shards regardless of mesh — see hessian.accumulate
    shard_hessians: Any = False
    # packed serving artifact: collect every solve's (q, scale, zero) as
    # packed int codes during write-back (``RSQPipeline.artifact``; persist
    # via checkpoint.packed.save_packed_artifact).  GPTQ only — the LDLQ/E8
    # lattice rounder has no integer codes to pack.
    pack_output: bool = False
    # write-back path for the packed artifact: "sharded" packs on device
    # and keeps codes/scales sharded over the model axis end to end (no
    # host ever holds an unsharded per-layer tensor); "host" is the legacy
    # gather-to-host baseline, kept for bit-parity tests
    pack_writeback: str = "sharded"

    def spec(self) -> QuantSpec:
        return QuantSpec(bits=self.bits, group_size=self.group_size,
                         sym=self.sym)


def _strategy_kwargs(rsq: RSQConfig) -> dict:
    if rsq.importance in ("first_n", "first_last_n"):
        return {"n": rsq.first_n}
    if rsq.importance == "uniform":
        return {}
    return {"r_min": rsq.r_min, "r_max": rsq.r_max}


def _chunk_mask(r: jax.Array, rsq: RSQConfig) -> jax.Array:
    """Tab.-1 style chunk restriction on top of any strategy."""
    if rsq.chunk_lo <= 0.0 and rsq.chunk_hi >= 1.0:
        return r
    t = r.shape[-1]
    idx = jnp.arange(t)
    mask = (idx >= int(rsq.chunk_lo * t)) & (idx < int(rsq.chunk_hi * t))
    return r * mask.astype(r.dtype)


_QUANT_SKIP = ("router",)  # routers stay fp32 (standard MoE practice)


def _is_quantizable(path: str, arr) -> bool:
    if any(s in path for s in _QUANT_SKIP):
        return False
    return arr.ndim >= 2 and min(arr.shape[-2:]) >= 16


def _solve_spec(rsq: RSQConfig, d_in: int) -> tuple[QuantSpec, int]:
    """Per-d_in GPTQ block size + group-size fallback (shared by the
    sequential and batched paths so their outputs are identical)."""
    block = min(rsq.gptq_block, d_in)
    spec = rsq.spec()
    gs = spec.group_size
    if gs != -1 and (gs > block or block % gs or d_in % gs):
        spec = dataclasses.replace(spec, group_size=-1)
    return spec, block


def finalize_layer_report(report: dict) -> dict:
    """Materialize a deferred solve report (jax scalars -> floats).

    This is the host sync of the solve stage; schedulers choose when to pay
    it (per layer for sequential, once at the drain for overlapped)."""
    return {path: float(v) for path, v in report.items()}


def quantize_layer_weights(p_block: dict, hessians: dict[str, Any],
                           rsq: RSQConfig, *, defer: bool = False,
                           collect: Optional[dict] = None) -> tuple[dict, dict]:
    """Solve GPTQ/LDLQ for every captured weight of one block.

    Solves are shape-grouped for both methods: all weights sharing
    ``(d_in, d_out)`` (q/k/v, gate/up, every expert of a stacked
    (E, d_in, d_out) tensor) are stacked into a single
    ``gptq_quantize_batched`` / ``ldlq_quantize_batched`` call — one
    vmapped program per distinct shape instead of one dispatch per weight.

    ``defer=True`` leaves the per-weight error report as jax scalars (no
    host sync); call :func:`finalize_layer_report` to materialize floats.

    ``collect`` (GPTQ only): a dict that receives, per weight path, the raw
    solve outputs ``{"q", "scale", "zero", "dtype"}`` as *device* arrays —
    the feed of the packed serving artifact.  Slicing a batched solve's
    output is a lazy device op, so nothing is gathered here.
    """
    report: dict[str, Any] = {}
    new_p = jax.tree.map(lambda x: x, p_block)

    items = []  # (path, node, name, w, h) for every quantizable weight
    for path, h in hessians.items():
        parts = path.split("/")
        node = new_p
        for key in parts[:-1]:
            node = node[key]
        name = parts[-1]
        w = node[name]
        if not _is_quantizable(path, w):
            continue
        items.append((path, node, name, w, h))

    use_ldlq = rsq.method == "ldlq"

    # ---- group by (d_in, d_out); one batched solve per group
    groups: dict[tuple, list] = {}
    for it in items:
        groups.setdefault(tuple(it[3].shape[-2:]), []).append(it)
    for (d_in, d_out), its in groups.items():
        if use_ldlq:
            spec, block = None, min(rsq.gptq_block, d_in)
        else:
            spec, block = _solve_spec(rsq, d_in)
        n_solves = sum(1 if it[3].ndim == 2 else it[3].shape[0] for it in its)
        if n_solves == 1 and its[0][3].ndim == 2:  # lone 2-D weight: no
            # batch dim to vmap over (a lone (1, d_in, d_out) expert stack
            # stays on the batched path — it already carries the lead axis)
            path, node, name, w, h = its[0]
            out = (ldlq_quantize(w, h, damp=rsq.damp, block=block)
                   if use_ldlq else
                   gptq_quantize(w, h, spec, damp=rsq.damp, block=block))
            node[name] = out["w_deq"].astype(w.dtype)
            report[path] = out["err"]
            if collect is not None and not use_ldlq:
                collect[path] = {"q": out["q"], "scale": out["scale"],
                                 "zero": out["zero"], "dtype": str(w.dtype)}
            continue
        ws = jnp.concatenate(
            [it[3][None] if it[3].ndim == 2 else it[3] for it in its])
        hs = jnp.concatenate(
            [it[4][None] if it[4].ndim == 2 else it[4] for it in its])
        out = (ldlq_quantize_batched(ws, hs, damp=rsq.damp, block=block)
               if use_ldlq else
               gptq_quantize_batched(ws, hs, spec, damp=rsq.damp,
                                     block=block))
        o = 0
        for path, node, name, w, h in its:
            sl = slice(o, o + 1) if w.ndim == 2 else slice(o, o + w.shape[0])
            if w.ndim == 2:
                node[name] = out["w_deq"][o].astype(w.dtype)
                report[path] = out["err"][o]
            else:
                node[name] = out["w_deq"][sl].astype(w.dtype)
                report[path] = out["err"][sl].mean()
            if collect is not None and not use_ldlq:
                idx = o if w.ndim == 2 else sl
                collect[path] = {"q": out["q"][idx],
                                 "scale": out["scale"][idx],
                                 "zero": out["zero"][idx],
                                 "dtype": str(w.dtype)}
            o = sl.stop
    if not defer:
        report = finalize_layer_report(report)
    return new_p, report


@dataclasses.dataclass
class _LayerFns:
    """One cache entry of the calibration engine: the jitted fused
    calibration step, the jitted quantized-forward, and the zero Hessian
    initializer (shapes precomputed via eval_shape, no tracing)."""
    fused: Callable  # (p, x, med, tok, counts, hessians) -> hessians
    apply: Callable  # (p, x, med) -> y
    hess_init: Callable  # () -> {path: zeros}


@dataclasses.dataclass(frozen=True)
class LayerTask:
    """One unit of scheduler work: quantize one block (original params).

    ``index`` is the task's global position in the decoder stack — the
    coordinate the fault-injection (``stage_point``) and checkpointing
    (``layer_commit``) hooks key on.  ``None`` (encoder tasks) opts the
    task out of both."""
    tag: str
    p_blk: Any
    meta: Any
    index: Optional[int] = None


@dataclasses.dataclass
class _RunCtx:
    """Per-run state shared by all engine hooks of one ``run`` call."""
    calib: Any
    counts: Any
    batch_size: int
    media_b: Any
    verbose: bool


class RSQPipeline:
    def __init__(self, model: Model, rsq: RSQConfig,
                 ctx: ParallelCtx = LOCAL):
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.rsq = rsq
        self.ctx = ctx
        self.strategy = get_strategy(rsq.importance)
        self.skw = _strategy_kwargs(rsq)
        self.use_kernel = (rsq.use_gram_kernel
                           if rsq.use_gram_kernel is not None
                           else jax.default_backend() == "tpu")
        self.n_hshards = resolve_hessian_shards(rsq.shard_hessians, ctx)
        # solve-time reduction of the streaming (S, d, d) accumulators: the
        # explicit collective path (one ring all-reduce over the data axis,
        # core/distributed.make_shard_reducer) whenever a live mesh is
        # present; the plain shard-sum (GSPMD-free) otherwise
        if ctx.enabled and ctx.dp and ctx.axis_size("dp") > 1:
            self._hreduce = make_shard_reducer(ctx)
        else:
            self._hreduce = jax.jit(hess.reduce_shards)
        if rsq.pack_output:
            if rsq.method != "gptq":
                raise ValueError("pack_output needs integer codes; the "
                                 "LDLQ/E8 rounder has none (method='gptq')")
            if rsq.pack_writeback not in ("sharded", "host"):
                raise ValueError(f"unknown pack_writeback "
                                 f"{rsq.pack_writeback!r}")
        self.artifact: Optional[dict] = None
        self._art_entries: dict[str, dict] = {}
        self._art_meta: dict[str, dict] = {}
        self._pack = jax.jit(self._pack_sharded)
        self._layer_fns: dict[Any, _LayerFns] = {}
        self._prewarm: dict[Any, Any] = {}  # layer key -> compile future
        self._rc: Optional[_RunCtx] = None
        # fault-tolerance state (per run): an optional FaultPlan checked at
        # every stage_point, an optional commit callback (QuantizeRunner),
        # restored Hessian accumulators keyed by layer index, and the last
        # decoder index (marks the commit that completes the stack)
        self._fault = None
        self._commit_cb: Optional[Callable] = None
        self._resume_hess: dict[int, dict] = {}
        self._last_index: Optional[int] = None
        # retraces of the cached capture/apply programs; a homogeneous
        # L-layer stack should end a run at 1/1, not L/L.  The lock keeps
        # the counts exact when prewarm traces programs on worker threads.
        self.trace_counts = {"capture": 0, "apply": 0}
        self._trace_lock = threading.Lock()

    # ---------------------------------------------------------------- utils
    def _importance(self, z_in, z_out, tokens, colsum, counts):
        inp = ImportanceInputs(z_in=z_in, z_out=z_out, tokens=tokens,
                               attn_colsum=colsum, token_counts=counts)
        r = self.strategy(inp, **self.skw)
        return _chunk_mask(r, self.rsq)

    def _accumulate(self, hessians, caps, dom, r):
        """Add one batch's contribution to every weight Hessian.

        With ``shard_hessians`` on, accumulators carry a leading (S,) shard
        axis constrained to the mesh's data axes — updates stay local."""
        slot_token = caps.get("ffn/__moe_slot_token")
        for path, x_c in caps.items():
            if path.endswith("__moe_slot_token"):
                continue
            d = dom[path]
            if d in ("stream", "hidden"):
                r_rows = r.reshape(-1)
            elif d == "media":
                r_rows = None
            else:  # expert buffers (E, C, d): scatter r into slots
                rf = jnp.concatenate([r.reshape(-1), jnp.zeros((1,), r.dtype)])
                r_rows = rf[slot_token].reshape(x_c.shape[0], x_c.shape[1])
            if not (x_c.ndim == 3 and d == "expert"):
                x_c = x_c.reshape(-1, x_c.shape[-1])
            h_new = hess.accumulate(
                hessians.get(path), x_c, r_rows, use_kernel=self.use_kernel,
                n_shards=self.n_hshards)
            if self.n_hshards > 1:
                h_new = self.ctx.shard_leading(h_new)
            hessians[path] = h_new
        return hessians

    def _pack_sharded(self, q, scale, zero):
        """On-device pack for the sharded write-back: codes are produced by
        the jitted ``pack_codes`` (a d_in-axis op, so a d_out shard packs
        locally) and all three artifact tensors are constrained onto the
        model axis when divisible — they stay sharded until the per-shard
        artifact save and no host copy of the unsharded tensor ever
        exists."""
        ctx = self.ctx
        outs = []
        for a in (pack_codes(q, self.rsq.bits), scale, zero):
            if (ctx.enabled and ctx.tp
                    and a.shape[-1] % ctx.axis_size("tp") == 0):
                a = ctx.constrain(a, *([None] * (a.ndim - 1)), "tp")
            outs.append(a)
        return tuple(outs)

    def _put_act(self, a):
        """Re-place one restored (host) activation batch for resume: leading
        batch axis back onto the mesh's data axes when divisible, so the
        resumed run's capture/apply programs see the same input layout (and
        therefore compile to the same partitioning) as the run that died."""
        a = jnp.asarray(a)
        ctx = self.ctx
        if ctx.enabled and ctx.dp and a.shape[0] % ctx.axis_size("dp") == 0:
            a = jax.device_put(
                a, ctx.sharding("dp", *([None] * (a.ndim - 1))))
        return a

    def _put_entry(self, a):
        """Re-place one restored packed-artifact tensor onto the model axis
        (mirrors ``_pack_sharded``'s constraint) so the per-addressable-shard
        artifact save emits the identical shard layout after a resume."""
        a = jnp.asarray(a)
        ctx = self.ctx
        if (ctx.enabled and ctx.tp
                and a.shape[-1] % ctx.axis_size("tp") == 0):
            a = jax.device_put(
                a, ctx.sharding(*([None] * (a.ndim - 1)), "tp"))
        return a

    def _collect_packed(self, task: LayerTask, collect: dict) -> None:
        """Fold one layer's solve outputs into the serving artifact."""
        from repro.checkpoint.packed import _host_gather

        self.stage_point(task.index, "pack")
        tag = task.tag
        for path, sol in collect.items():
            q, scale, zero = sol["q"], sol["scale"], sol["zero"]
            if self.rsq.pack_writeback == "host":
                # legacy baseline: the unsharded (q, scales, zeros) land on
                # host, then get packed — the path the sharded write-back
                # retires (and is regression-tested against, bit for bit)
                q_np = _host_gather(q)
                entry = {"codes": np.asarray(pack_codes(q_np, self.rsq.bits)),
                         "scale": _host_gather(scale),
                         "zero": _host_gather(zero)}
            else:
                codes, s, z = self._pack(q, scale, zero)
                entry = {"codes": codes, "scale": s, "zero": z}
            name = f"{tag}/{path}"
            self._art_entries[name] = entry
            self._art_meta[name] = {
                "path": path, "tag": tag, "d_in": int(q.shape[-2]),
                "group_size": int(q.shape[-2]) // int(scale.shape[-2]),
                "dtype": sol["dtype"]}

    def _layer_key(self, meta, p_blk):
        p_sig = tuple((tuple(a.shape), str(a.dtype))
                      for a in jax.tree.leaves(p_blk))
        return (meta, p_sig)

    def _get_layer_fns(self, meta, p_blk, x, med) -> _LayerFns:
        """Build (or fetch) the jitted fused/apply programs for one block
        signature.  The jits themselves handle batch-shape polymorphism
        (e.g. a ragged tail batch) by retracing, so the cache key only
        carries what changes the *captured structure*: the meta and the
        block's parameter shapes."""
        key = self._layer_key(meta, p_blk)
        if self.rsq.trace_cache and key in self._layer_fns:
            return self._layer_fns[key]
        cfg, meta_ = self.cfg, meta
        dom: dict[str, str] = {}

        def _probe(p, x, med):
            _, caps, d, _ = capture_block(p, cfg, meta_, x, media=med)
            dom.update(d)
            return caps

        caps_s = jax.eval_shape(_probe, p_blk, x, med)
        hshapes = {}
        shard = (self.n_hshards,) if self.n_hshards > 1 else ()
        for path, s in caps_s.items():
            if path.endswith("__moe_slot_token"):
                continue
            if s.ndim == 3 and dom[path] == "expert":
                hshapes[path] = shard + (s.shape[0], s.shape[-1], s.shape[-1])
            else:
                hshapes[path] = shard + (s.shape[-1], s.shape[-1])

        def hess_init():
            zeros = {p_: jnp.zeros(sh, jnp.float32)
                     for p_, sh in hshapes.items()}
            if self.n_hshards > 1:
                zeros = {p_: self.ctx.shard_leading(z)
                         for p_, z in zeros.items()}
            return zeros

        def _fused(p, x, med, tok, counts, hessians):
            # python side effect at trace time: counts XLA compilations
            with self._trace_lock:
                self.trace_counts["capture"] += 1
            y, caps, dom_t, colsum = capture_block(p, cfg, meta_, x,
                                                   media=med)
            r = self._importance(x, y, tok, colsum, counts)
            return self._accumulate(hessians, caps, dom_t, r)

        def _apply(p, x, med):
            with self._trace_lock:
                self.trace_counts["apply"] += 1
            return apply_block(p, cfg, meta_, x, media=med)[0]

        fns = _LayerFns(fused=jax.jit(_fused, donate_argnums=(5,)),
                        apply=jax.jit(_apply), hess_init=hess_init)
        if self.rsq.trace_cache:
            self._layer_fns[key] = fns
        return fns

    # ----------------------------------------------- scheduler engine hooks
    # A LayerScheduler (core/scheduler) drives these five stages.  All of
    # them only *dispatch* device work; the lone host sync lives in
    # layer_sync/layer_finalize, which is why the overlapped scheduler can
    # defer it.

    def prewarm(self, tasks, acts) -> None:
        """Compile every distinct layer program concurrently.

        On a heterogeneous stack (K distinct metas — hybrid attn/mamba
        models, prefix + group stacks) the lock-step schedule pays the K
        XLA compilations serially, one at each first encounter.  This
        builds + compiles all of them on a thread pool up front (tracing
        contends on the GIL but the multi-second XLA compile releases it),
        so cold calibration wall-clock drops from ~sum(compiles) to
        ~max(compiles).  Shape-matched dummy executions force the
        compilation into the jit call cache; real calls then hit it.
        No-op for homogeneous stacks and with ``trace_cache=False``."""
        if not self.rsq.trace_cache:
            return
        rc = self._rc
        med0 = rc.media_b[0] if rc.media_b is not None else None
        jobs, seen = [], set()
        for task in tasks:
            key = self._layer_key(task.meta, task.p_blk)
            if key in self._layer_fns or key in seen:
                continue
            seen.add(key)
            jobs.append((key, task))
        if len(jobs) < 2:  # single meta: nothing to overlap
            return
        import concurrent.futures as cf
        import os

        x0 = acts[0]
        tok0 = rc.calib[: x0.shape[0]]

        def build(task):
            fns = self._get_layer_fns(task.meta, task.p_blk, x0, med0)
            # dummy one-batch executions: compile capture AND apply now
            # (values discarded; the donated dict is a throwaway).  A real
            # call is required — on this jax, AOT lower().compile() does
            # NOT populate the jit call cache, so the later real call
            # would recompile from scratch
            fns.fused(task.p_blk, x0, med0, tok0, rc.counts,
                      fns.hess_init())
            fns.apply(task.p_blk, x0, med0)
            return fns

        ex = cf.ThreadPoolExecutor(
            max_workers=min(len(jobs), os.cpu_count() or 4))
        self._prewarm = {key: ex.submit(build, task) for key, task in jobs}
        ex.shutdown(wait=False)

    def stage_point(self, index: Optional[int], stage: str,
                    batch: Optional[int] = None) -> None:
        """Per-stage dispatch boundary (see ``core/scheduler`` docstring).
        No-op unless a ``FaultPlan`` was passed to ``run`` — then an armed
        ``(layer, stage[, batch])`` coordinate raises here."""
        if self._fault is not None and index is not None:
            self._fault.check(index, stage, batch)

    def layer_commit(self, task: LayerTask, state: dict, p_new, acts,
                     next_state: Optional[dict] = None) -> None:
        """Durable-progress hook, called once per layer after its apply
        sweep is dispatched.  Forwards everything a checkpointing runner
        needs: the solved params, the propagated activations (= the next
        layer's inputs), the artifact entries folded so far, and — under
        the overlapped schedule — the next layer's already-complete Hessian
        accumulators.  No-op without a runner."""
        if self._commit_cb is None or task.index is None:
            return
        nh, nidx = None, None
        if next_state is not None and next_state.get("hessians") is not None:
            nh = next_state["hessians"]
            nidx = next_state["task"].index
        self._commit_cb(
            index=task.index, state=state, p_new=p_new, acts=acts,
            art_entries=self._art_entries, art_meta=self._art_meta,
            next_hessians=nh, next_index=nidx,
            last=task.index == self._last_index)

    def layer_begin(self, task: LayerTask, acts) -> dict:
        """Resolve the trace-cached programs and fresh accumulators."""
        rc = self._rc
        med0 = rc.media_b[0] if rc.media_b is not None else None
        fut = self._prewarm.pop(self._layer_key(task.meta, task.p_blk), None)
        if fut is not None:
            fut.result()  # join the background compile; fns now cached
        fns = self._get_layer_fns(task.meta, task.p_blk, acts[0], med0)
        st = {"task": task, "fns": fns,
              "t0": time.perf_counter(), "pending": None}
        rh = (self._resume_hess.pop(task.index, None)
              if task.index is not None else None)
        if rh is None:
            st["hessians"] = fns.hess_init()
        else:
            # checkpointed accumulators (exact float32 partial sums): put
            # them back in the streaming layout and skip the capture sweep
            hs = {}
            for p_, a in rh.items():
                a = jnp.asarray(a)
                if self.n_hshards > 1:
                    a = self.ctx.shard_leading(a)
                hs[p_] = a
            st["hessians"] = hs
            st["capture_done"] = True
        return st

    def layer_capture(self, state: dict, bi: int, x_b) -> None:
        """Fused capture+importance+accumulate for one calibration batch
        (the Hessian dict is donated, so state updates in place)."""
        if state.get("capture_done"):  # accumulators restored from a
            return                     # checkpoint — nothing to add
        rc = self._rc
        med = rc.media_b[bi] if rc.media_b is not None else None
        tok = rc.calib[bi * rc.batch_size : bi * rc.batch_size + x_b.shape[0]]
        state["hessians"] = state["fns"].fused(
            state["task"].p_blk, x_b, med, tok, rc.counts, state["hessians"])

    def layer_solve(self, state: dict):
        """Reduce Hessian shards (one explicit ring all-reduce on a live
        mesh, a plain shard-sum otherwise) and dispatch the batched
        GPTQ/LDLQ solves.  Returns the quantized block params; the error
        report stays deferred in ``state`` (no host sync here).  With
        ``pack_output`` the solve's (q, scale, zero) also flow straight
        into the packed serving artifact — per the configured write-back,
        packed on device and still sharded (default) or gathered to host
        (legacy baseline)."""
        hessians = state.pop("hessians")
        if self.n_hshards > 1:
            hessians = {p: self._hreduce(h) for p, h in hessians.items()}
        collect: Optional[dict] = {} if self.rsq.pack_output else None
        p_new, state["pending"] = quantize_layer_weights(
            state["task"].p_blk, hessians, self.rsq, defer=True,
            collect=collect)
        if collect:
            self._collect_packed(state["task"], collect)
        return p_new

    def layer_apply(self, state: dict, p_new, bi: int, x_b):
        """Propagate one batch through the quantized block."""
        rc = self._rc
        med = rc.media_b[bi] if rc.media_b is not None else None
        return state["fns"].apply(p_new, x_b, med)

    def layer_sync(self, state: dict) -> None:
        """Materialize the deferred error report now (host sync;
        idempotent).  The sequential scheduler calls this right after the
        solve — the classic lock-step timeline; the overlapped scheduler
        skips it and pays one drain at the end of the stack instead."""
        if not state.get("synced"):
            state["pending"] = finalize_layer_report(state["pending"])
            state["synced"] = True

    def layer_finalize(self, state: dict) -> dict:
        """Assemble the layer report (syncing if not already done).  Under
        the overlapped scheduler ``seconds`` spans dispatch-to-drain and
        overlaps across layers — the stack total is the meaningful time."""
        rc = self._rc
        self.layer_sync(state)
        rep = {"weights": state["pending"],
               "seconds": round(time.perf_counter() - state["t0"], 4)}
        if rc.verbose:
            print(f"  [{state['task'].tag}] {len(rep['weights'])} weights "
                  f"quantized in {rep['seconds']}s", flush=True)
        return rep

    # ----------------------------------------------------------------- main
    def run(self, params: dict, calib_tokens, *, batch_size: int = 8,
            media=None, frames=None, verbose: bool = False,
            fault=None, commit: Optional[Callable] = None,
            resume: Optional[dict] = None):
        """Quantize `params`. calib_tokens: (N, T) int32 (pre-expansion).

        Fault tolerance (see ``core.resume.QuantizeRunner``, which drives
        all three):
          * ``fault`` — a ``runtime.fault.FaultPlan``; armed
            ``(layer, stage)`` coordinates raise at that dispatch boundary.
          * ``commit`` — callback invoked once per decoder layer with the
            solved params, propagated acts, artifact entries and (overlapped
            schedule) the next layer's complete Hessians.
          * ``resume`` — progress restored from a checkpoint:
            ``{"start", "solved", "acts", "art", "art_meta", "hessians",
            "reports"}``; layers below ``start`` are skipped and the stack
            continues from the restored activations, bit-identical to a run
            that never died.

        Returns (new_params, report)."""
        model, cfg, rsq = self.model, self.cfg, self.rsq
        key = jax.random.key(rsq.seed)
        # per-run compile accounting (cached jits from a previous run on the
        # same pipeline legitimately contribute 0 traces to this run)
        self.trace_counts.update(capture=0, apply=0)
        self._art_entries, self._art_meta, self.artifact = {}, {}, None
        self._fault, self._commit_cb = fault, commit
        self._resume_hess, self._last_index = {}, None
        if resume is not None and cfg.family == "encdec":
            raise NotImplementedError(
                "resume covers the decoder stack only; encoder-decoder "
                "calibration restarts from scratch")
        tag2loc: dict[str, tuple] = {}
        report: dict[str, Any] = {"layers": {}, "rsq": dataclasses.asdict(rsq)}
        scheduler = get_scheduler(rsq.scheduler)
        report["scheduler"] = scheduler.name

        calib = expand_dataset(jnp.asarray(calib_tokens), rsq.expansion)
        counts = jnp.bincount(calib.reshape(-1),
                              length=cfg.vocab_size).astype(jnp.float32)

        if rsq.rotate:
            params, rotations = rotate_model(params, cfg, model, key)
            report["rotated"] = True
        else:
            params = dict(params)
            rotations = {}
        # decouple the mutable containers we write into from the caller's
        new_params = dict(params)
        if "prefix" in new_params:
            new_params["prefix"] = list(new_params["prefix"])
        new_params["groups"] = dict(new_params["groups"])
        if "encoder" in new_params:
            new_params["encoder"] = dict(new_params["encoder"])

        n = calib.shape[0]
        batches = [calib[i : i + batch_size]
                   for i in range(0, n, batch_size)]
        embed = params["embed"]
        acts = [jnp.asarray(embed[b_]).astype(model.dtype) for b_ in batches]

        media_b = None
        if media is not None:
            media_b = [media[i : i + batch_size] for i in range(0, n, batch_size)]
        self._rc = _RunCtx(calib=calib, counts=counts, batch_size=batch_size,
                           media_b=media_b, verbose=verbose)

        # ---------- encoder stack (enc-dec models) then decoder stack
        if cfg.family == "encdec":
            assert frames is not None
            frames = jnp.asarray(frames)
            if "frame_proj" in params:
                frames = frames @ params["frame_proj"].astype(frames.dtype)
            enc_acts = [frames[i : i + batch_size]
                        for i in range(0, n, batch_size)]
            self._rc.media_b = None  # encoder blocks take no media input
            enc_tasks = [
                LayerTask(tag=f"enc{li}",
                          p_blk=jax.tree.map(lambda a, li=li: a[li],
                                             params["encoder"]["groups"])["b0"],
                          meta=model.enc_metas[0])
                for li in range(cfg.n_encoder_layers)]
            # the encoder's final activations feed the decoder as media, so
            # the last encoder layer must still propagate
            enc_acts, enc_outs = scheduler.run(self, enc_tasks, enc_acts,
                                               propagate_last=True)
            for li, (p_new, rep) in enumerate(enc_outs):
                tag2loc[f"enc{li}"] = ("enc", li)
                report["layers"][f"enc{li}"] = rep
                new_params["encoder"]["groups"] = jax.tree.map(
                    lambda full, nw, li=li: full.at[li].set(nw),
                    new_params["encoder"]["groups"], {"b0": p_new})
            enc_acts = [rms_norm(a, params["encoder"]["final_norm"],
                                 cfg.norm_eps) for a in enc_acts]
            media_b = enc_acts
            self._rc.media_b = media_b

        # ---------- decoder prefix + groups
        def layer_params(li):
            if li < len(model.prefix_metas):
                return params["prefix"][li], model.prefix_metas[li], ("prefix", li)
            j = li - len(model.prefix_metas)
            g, o = divmod(j, model.period)
            blk = jax.tree.map(lambda a: a[g], params["groups"])[f"b{o}"]
            return blk, model.group_metas[o], ("groups", g, o)

        n_layers = len(model.prefix_metas) + model.n_groups * model.period
        tasks, locs = [], []
        for li in range(n_layers):
            p_blk, meta, loc = layer_params(li)
            tasks.append(LayerTask(tag=f"layer{li}", p_blk=p_blk, meta=meta,
                                   index=li))
            locs.append(loc)
        self._last_index = n_layers - 1
        start, pre_outs = 0, []
        if resume is not None:
            start = int(resume["start"])
            solved = {int(k): v for k, v in resume["solved"].items()}
            assert sorted(solved) == list(range(start)), (
                f"resume state is not a contiguous solved prefix: "
                f"{sorted(solved)} vs start={start}")
            reps = resume.get("reports") or {}
            for li in range(start):
                p_new = jax.tree.map(jnp.asarray, solved[li])
                rep = dict(reps.get(f"layer{li}")
                           or {"weights": {}, "seconds": 0.0})
                rep["resumed"] = True
                pre_outs.append((p_new, rep))
            # re-place the already-solved layers' propagated activations —
            # the scheduler continues the stack from these
            acts = [self._put_act(a) for a in resume["acts"]]
            # packed entries folded before the crash: restore in artifact
            # order (art_meta, carried through JSON, preserves insertion
            # order; the checkpointed array tree does not)
            for name, em in (resume.get("art_meta") or {}).items():
                self._art_meta[name] = dict(em)
                self._art_entries[name] = {
                    k: self._put_entry(v)
                    for k, v in resume["art"][name].items()}
            for li, hs in (resume.get("hessians") or {}).items():
                self._resume_hess[int(li)] = hs
        # nothing consumes the last decoder layer's outputs — skip its
        # apply pass (one full batch sweep of dispatched-and-discarded work)
        acts, outs = scheduler.run(self, tasks[start:], acts,
                                   propagate_last=False)
        outs = pre_outs + outs
        for task, loc, (p_new, rep) in zip(tasks, locs, outs):
            tag2loc[task.tag] = loc
            report["layers"][task.tag] = rep
            if loc[0] == "prefix":
                new_params["prefix"][loc[1]] = p_new
            else:
                _, g, o = loc
                stacked = new_params["groups"]

                def set_at(full, nw, g=g):
                    return full.at[g].set(nw.astype(full.dtype))

                stacked[f"b{o}"] = jax.tree.map(
                    set_at, stacked[f"b{o}"], p_new)
                new_params["groups"] = stacked

        self._rc = None
        self._fault = self._commit_cb = None
        if rsq.pack_output:
            for name, em in self._art_meta.items():
                em["loc"] = list(tag2loc[em["tag"]])
            self.artifact = {
                "entries": self._art_entries, "meta": self._art_meta,
                "spec": {"bits": rsq.bits, "sym": rsq.sym,
                         "group_size": rsq.group_size,
                         "method": rsq.method}}
            report["packed"] = {"entries": len(self._art_entries),
                                "writeback": rsq.pack_writeback}
        report["rotations"] = {k: (None if v is None else "set")
                               for k, v in rotations.items()}
        report["trace_counts"] = dict(self.trace_counts)
        return new_params, report


def quantize_model(model: Model, params: dict, calib_tokens,
                   rsq: RSQConfig, *, ctx: ParallelCtx = LOCAL, **kw):
    return RSQPipeline(model, rsq, ctx=ctx).run(params, calib_tokens, **kw)
