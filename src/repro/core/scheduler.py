"""Layer schedulers for the RSQ calibration loop.

The per-layer recipe (capture -> solve -> apply) has a strict data
dependency — layer i+1's calibration inputs are layer i's *quantized*
outputs (GPTQ error feedback) — but the *dispatch* of that chain does not
have to be lock-step.  The pipeline exposes its per-layer stages as engine
hooks (``prewarm`` / ``layer_begin`` / ``layer_capture`` / ``layer_solve``
/ ``layer_sync`` / ``layer_apply`` / ``layer_finalize``, see
``RSQPipeline``) and a scheduler decides the order in which they are
issued:

``SequentialScheduler``
    The classic loop: capture every batch, solve, materialize the per-weight
    error report (a host sync), apply every batch, move on.  One full
    host<->device round-trip per layer.  Default on CPU.

``OverlappedScheduler``
    Software-pipelined dispatch.  All distinct layer programs of the stack
    compile concurrently up front (``engine.prewarm`` — the cold-start win
    on heterogeneous stacks).  Then layer i's GPTQ/LDLQ solve is
    *dispatched* (never synced) and, relying on async dispatch, layer i's
    apply and layer i+1's fused capture are interleaved batch-by-batch over
    double-buffered activation lists (buffer A holds layer i inputs, buffer
    B fills with layer i+1 inputs; they swap at the layer boundary).  The
    device executes solve(i) while the host is already tracing/dispatching
    layer i+1's programs, and every host sync (the ``float(err)``
    materializations) is deferred to one drain at the end of the stack.
    Because the same jitted programs run on the same values in the same
    data-dependency order, the quantized parameters are bit-identical to the
    sequential schedule — only the dispatch timeline differs.

Both schedulers reuse the per-meta trace cache (PR 1): on a homogeneous
stack, capture(i+1) and apply(i) are the *same* XLA programs for every i,
so overlapping them adds zero compilations.

With ``RSQConfig.pack_output`` the solve stage also folds each layer's
``(q, scale, zero)`` into the packed serving artifact
(``engine.layer_solve`` -> ``RSQPipeline._collect_packed``).  The default
sharded write-back only *dispatches* device work (pack + model-axis
constraint), so it is scheduler-neutral: the overlapped timeline keeps its
single end-of-stack drain.  The legacy ``pack_writeback="host"`` baseline
host-gathers inside the solve stage — one more reason it is retired to a
parity-test role.

Fault tolerance hooks
---------------------
Both schedulers thread two extra engine hooks through the stack:

``engine.stage_point(index, stage, batch=None)``
    Called right before each stage's device work is dispatched —
    per batch for ``capture``/``apply``, once per layer for ``solve``
    (the pipeline itself raises the ``pack`` point inside its packed
    write-back).  This is where a ``runtime.fault.FaultPlan`` injects
    failures at any ``(layer, stage)`` of the stack, so the recovery
    path (``core.resume.QuantizeRunner``) is testable at every dispatch
    boundary of either schedule.

``engine.layer_commit(task, state, p_new, acts, next_state=)``
    Called once per layer after its apply sweep has been dispatched:
    ``acts`` are the layer's propagated outputs (= the next layer's
    inputs) and, under the overlapped schedule, ``next_state`` already
    carries the next layer's fully-accumulated Hessians.  A
    ``QuantizeRunner`` checkpoints pipeline progress here; without a
    runner the hook is a no-op, so neither schedule pays anything.
"""
from __future__ import annotations

from typing import Any, Optional

import jax


class LayerScheduler:
    """Interface: drive the engine hooks over a stack of layer tasks.

    ``run`` receives the engine (an ``RSQPipeline``), the ordered list of
    layer tasks, and the per-batch activation list; it returns the
    propagated activations and one ``(p_new, report)`` pair per task.
    """

    name = "base"
    # whether this scheduler may elide the final layer's apply pass when
    # the caller marks it dead (propagate_last=False).  The sequential
    # scheduler keeps it for dispatch-stream fidelity with the legacy
    # engine; the overlapped scheduler's contract is minimal dispatch.
    skip_dead_apply = False

    def run(self, engine, tasks: list, acts: list, *,
            propagate_last: bool = True) -> tuple[list, list]:
        """Drive the stack.  ``propagate_last=False`` tells the scheduler
        the final layer's apply outputs feed nothing (the decoder case —
        the pipeline only keeps the quantized params), so a scheduler with
        ``skip_dead_apply`` may elide that whole batch sweep; the encoder
        stack passes True because its outputs become the decoder's media
        stream."""
        raise NotImplementedError


class SequentialScheduler(LayerScheduler):
    """Strictly sequential dispatch (the pre-scheduler behavior)."""

    name = "sequential"

    def run(self, engine, tasks, acts, *, propagate_last=True):
        outs = []
        for task in tasks:
            st = engine.layer_begin(task, acts)
            for bi, x_b in enumerate(acts):
                engine.stage_point(task.index, "capture", bi)
                engine.layer_capture(st, bi, x_b)
            engine.stage_point(task.index, "solve")
            p_new = engine.layer_solve(st)
            # classic lock-step semantics: the per-weight error report is
            # materialized (host sync) before any propagation is dispatched,
            # and every layer propagates (even a dead final sweep) —
            # exactly the pre-scheduler pipeline's dispatch stream
            engine.layer_sync(st)
            buf = []
            for bi, x_b in enumerate(acts):
                engine.stage_point(task.index, "apply", bi)
                buf.append(engine.layer_apply(st, p_new, bi, x_b))
            acts = buf
            outs.append((p_new, engine.layer_finalize(st)))
            engine.layer_commit(task, st, p_new, acts)
        return acts, outs


class OverlappedScheduler(LayerScheduler):
    """Double-buffered software pipeline over the layer stack.

    Before the loop, every *distinct* layer program of the stack is
    compiled concurrently on background threads (``engine.prewarm``): a
    heterogeneous stack (hybrid attn/mamba, prefix + groups, K distinct
    metas) pays its K XLA compilations serially under the lock-step
    schedule but ~max(compiles) here — the dominant cold-start win.

    Dispatch order for layer i (all asynchronous, no host syncs):

        solve(i)                          # device: big GPTQ/LDLQ program
        begin(i+1)                        # host: trace-cache lookup/trace
        for each batch b:
            y_b   = apply(i, b)           # reads solve(i) output
            capture(i+1, y_b)             # reads apply(i, b) output
        swap activation buffers

    and the error-report materializations for *every* layer run once at the
    end (the drain).  The host therefore never waits for solve(i) before
    issuing layer i+1's work, which keeps the device queue full across
    layer boundaries.
    """

    name = "overlapped"
    skip_dead_apply = True

    def run(self, engine, tasks, acts, *, propagate_last=True):
        if not tasks:
            return acts, []
        engine.prewarm(tasks, acts)
        pending = []  # (state, p_new) awaiting the drain
        st = engine.layer_begin(tasks[0], acts)
        for bi, x_b in enumerate(acts):
            engine.stage_point(tasks[0].index, "capture", bi)
            engine.layer_capture(st, bi, x_b)
        for i in range(len(tasks)):
            engine.stage_point(tasks[i].index, "solve")
            p_new = engine.layer_solve(st)  # dispatched, not synced
            last = i + 1 >= len(tasks)
            st_next = None if last else engine.layer_begin(tasks[i + 1], acts)
            if not (last and not propagate_last and self.skip_dead_apply):
                buf = []  # double buffer: fills while `acts` is still read
                for bi, x_b in enumerate(acts):
                    engine.stage_point(tasks[i].index, "apply", bi)
                    y_b = engine.layer_apply(st, p_new, bi, x_b)
                    if st_next is not None:
                        engine.stage_point(tasks[i + 1].index, "capture", bi)
                        engine.layer_capture(st_next, bi, y_b)
                    buf.append(y_b)
                acts = buf
            # else: minimal dispatch — the caller marked the final apply
            # sweep dead, so it is never enqueued
            pending.append((st, p_new))
            # commit AFTER the interleaved capture sweep: under this
            # schedule the next layer's Hessians are complete here, so a
            # checkpointing runner can persist them alongside the acts
            engine.layer_commit(tasks[i], st, p_new, acts,
                                next_state=st_next)
            st = st_next
        # drain: every layer's device work is enqueued; materialize reports
        outs = [(p_new, engine.layer_finalize(st_)) for st_, p_new in pending]
        return acts, outs


SCHEDULERS: dict[str, type[LayerScheduler]] = {
    "sequential": SequentialScheduler,
    "overlapped": OverlappedScheduler,
}


def get_scheduler(name: Optional[str] = None) -> LayerScheduler:
    """Resolve a scheduler by name.

    ``None`` auto-selects: sequential on CPU (whose lighter async dispatch
    gains little from pipelining and whose debuggability benefits from
    lock-step order), overlapped on accelerator backends.
    """
    if name is None or name == "auto":
        name = ("sequential" if jax.default_backend() == "cpu"
                else "overlapped")
    try:
        return SCHEDULERS[name]()
    except KeyError:
        raise ValueError(
            f"unknown scheduler {name!r}; one of {sorted(SCHEDULERS)}"
        ) from None


def resolve_hessian_shards(shard_hessians: Any, ctx=None) -> int:
    """Resolve the ``RSQConfig.shard_hessians`` knob to a shard count.

    ``False``/``0``/``None`` -> 1 (dense accumulators); ``True`` -> the
    data-axis size of ``ctx``'s mesh (dense when un-meshed); an int S > 1
    pins S shards (useful for single-host streaming and for numerics tests
    of the sharded reduction).  On a mesh an explicit S is rounded up to a
    multiple of the data-axis size — otherwise the leading shard axis could
    not be placed on the data axes and GSPMD would silently replicate the
    accumulator, breaking the never-an-unsharded-Hessian invariant.
    """
    if shard_hessians is None or shard_hessians is False:
        return 1
    dp = (max(ctx.axis_size("dp"), 1)
          if ctx is not None and getattr(ctx, "enabled", False) else 1)
    if shard_hessians is True:
        return dp
    s = int(shard_hessians)
    if s <= 1:
        return 1
    return -(-s // dp) * dp  # round up to a data-axis multiple
