"""Dataset expansion (paper Sec. 4.4): augment each calibration sample with
M-1 circular shifts by k·T/M so every token visits the "important"
positions (initial/final) that position-biased strategies favor."""
from __future__ import annotations

import jax.numpy as jnp


def expand_dataset(tokens: jnp.ndarray, m: int = 8) -> jnp.ndarray:
    """tokens: (N, T) -> (N * M, T); shift k inserts the last k·T/M tokens at
    the beginning (circular roll)."""
    if m <= 1:
        return tokens
    n, t = tokens.shape
    shifts = [(k * t) // m for k in range(m)]
    rolled = [jnp.roll(tokens, s, axis=1) for s in shifts]
    return jnp.stack(rolled, axis=1).reshape(n * m, t)
