"""Step 1 of RSQ: Rotate — randomized-Hadamard orthogonal transforms of the
residual stream (QuaRot / SliceGPT computational invariance).

Convention (see DESIGN.md §8): the stream is rotated ``x -> x @ Q``; weights
that *consume* the stream become ``Qᵀ W``; weights that *produce* it become
``W Q``; the embedding table becomes ``E Q`` (tied LM heads follow for free).
RMSNorm commutes with orthogonal Q only when its scale is 1, so ``fuse_norms``
must run first (it folds every norm's γ into the consuming weights).

Non-power-of-two dims use the Kronecker factorization H_{2^k} (x) Q_m with a
random orthogonal Q_m — keeping the fast-Hadamard structure on the 2^k part
(see kernels/hadamard for the TPU kernel).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def hadamard_matrix(n: int, dtype=jnp.float32) -> jax.Array:
    """Orthonormal Hadamard matrix, n must be a power of two."""
    assert n & (n - 1) == 0 and n > 0, f"{n} not a power of two"
    h = jnp.ones((1, 1), dtype)
    while h.shape[0] < n:
        h = jnp.block([[h, h], [h, -h]])
    return h / jnp.sqrt(jnp.asarray(n, dtype))


def _pow2_factor(n: int) -> tuple[int, int]:
    k = 1
    while n % (2 * k) == 0:
        k *= 2
    return k, n // k


def random_orthogonal(key, n: int, dtype=jnp.float32) -> jax.Array:
    a = jax.random.normal(key, (n, n), jnp.float32)
    q, r = jnp.linalg.qr(a)
    # sign-fix for a uniform (Haar) distribution
    q = q * jnp.sign(jnp.diag(r))[None, :]
    return q.astype(dtype)


def random_hadamard(key, n: int, dtype=jnp.float32) -> jax.Array:
    """Randomized (Hadamard (x) orthogonal) rotation with a random ±1
    diagonal: Q = diag(s) · (H_{2^k} (x) Q_m)."""
    k2, m = _pow2_factor(n)
    kd, km = jax.random.split(key)
    h = hadamard_matrix(k2, dtype)
    if m > 1:
        q_m = random_orthogonal(km, m, dtype)
        h = jnp.kron(h, q_m)
    s = jax.random.rademacher(kd, (n,), jnp.float32).astype(dtype)
    return s[:, None] * h


# ----------------------------------------------------------------- norm fuse


def _scale_in(w, g):
    """W' = diag(g) @ W for a stream-consuming weight (d_in, d_out)."""
    return (w.astype(jnp.float32) * g.astype(jnp.float32)[:, None]).astype(w.dtype)


# weights consuming the residual stream, per block sub-module
_MIXER_IN = ("wq", "wk", "wv", "wq_a", "wkv_a", "wzx", "wbc", "wdt")
_MIXER_IN_NOLORA = ("wq",)  # MLA without q_lora uses "wq" directly
_MIXER_OUT = ("wo", "out_proj")
_FFN_IN = ("wi", "wu")
_FFN_OUT = ("wd",)


def fuse_norms_block(p: dict, cfg: ModelConfig) -> dict:
    """Fold every RMSNorm γ of one block into its consuming weights."""
    p = jax.tree.map(lambda x: x, p)  # shallow-ish copy
    g = p["mixer_norm"].astype(jnp.float32)
    mixer = dict(p["mixer"])
    for name in _MIXER_IN:
        if name in mixer:
            mixer[name] = _scale_in(mixer[name], g)
    p["mixer"] = mixer
    p["mixer_norm"] = jnp.ones_like(p["mixer_norm"])
    if "cross_norm" in p:
        gc = p["cross_norm"].astype(jnp.float32)
        cross = dict(p["cross"])
        cross["wq"] = _scale_in(cross["wq"], gc)
        p["cross"] = cross
        p["cross_norm"] = jnp.ones_like(p["cross_norm"])
    if "ffn_norm" in p:
        gf = p["ffn_norm"].astype(jnp.float32)
        ffn = dict(p["ffn"])
        for name in _FFN_IN:
            if name in ffn:
                ffn[name] = _scale_in(ffn[name], gf)
        if "router" in ffn:
            ffn["router"] = _scale_in(ffn["router"], gf)
            experts = dict(ffn["experts"])
            for name in ("wi", "wu"):
                experts[name] = (experts[name].astype(jnp.float32)
                                 * gf[None, :, None]).astype(experts[name].dtype)
            ffn["experts"] = experts
            if "shared" in ffn:
                sh = dict(ffn["shared"])
                for name in _FFN_IN:
                    sh[name] = _scale_in(sh[name], gf)
                ffn["shared"] = sh
        p["ffn"] = ffn
        p["ffn_norm"] = jnp.ones_like(p["ffn_norm"])
    # MLA internal norms fold into the up-projections
    if "q_norm" in p.get("mixer", {}):
        mixer = dict(p["mixer"])
        mixer["wq_b"] = _scale_in(mixer["wq_b"], mixer["q_norm"])
        mixer["q_norm"] = jnp.ones_like(mixer["q_norm"])
        p["mixer"] = mixer
    if "kv_norm" in p.get("mixer", {}):
        mixer = dict(p["mixer"])
        mixer["wkv_b"] = _scale_in(mixer["wkv_b"], mixer["kv_norm"])
        mixer["kv_norm"] = jnp.ones_like(mixer["kv_norm"])
        p["mixer"] = mixer
    return p


def rotate_block(p: dict, cfg: ModelConfig, meta, q: jax.Array,
                 q_media: jax.Array | None = None) -> dict:
    """Apply the stream rotation to one block (norms must be fused first).

    ``meta``: the block's BlockMeta — cross-attention mixers consume the
    (unrotated or q_media-rotated) media stream on their K/V side, so only
    their wq/wo touch the residual rotation."""
    qf = q.astype(jnp.float32)
    p = jax.tree.map(lambda x: x, p)

    def rot_in(w):  # (d_model, d_out) -> Qᵀ W
        return (qf.T @ w.astype(jnp.float32)).astype(w.dtype)

    def rot_out(w):  # (d_in, d_model) -> W Q
        return (w.astype(jnp.float32) @ qf).astype(w.dtype)

    def rot_cross(c):
        c = dict(c)
        c["wq"] = rot_in(c["wq"])
        c["wo"] = rot_out(c["wo"])
        if q_media is not None:
            qm = q_media.astype(jnp.float32)
            for name in ("wk", "wv"):
                c[name] = (qm.T @ c[name].astype(jnp.float32)
                           ).astype(c[name].dtype)
        return c

    if meta.mixer == "cross":
        p["mixer"] = rot_cross(p["mixer"])
    else:
        mixer = dict(p["mixer"])
        for name in _MIXER_IN:
            if name in mixer:
                mixer[name] = rot_in(mixer[name])
        for name in _MIXER_OUT:
            if name in mixer:
                mixer[name] = rot_out(mixer[name])
        p["mixer"] = mixer
    if "cross" in p:
        p["cross"] = rot_cross(p["cross"])

    if p.get("ffn") is not None:
        ffn = dict(p["ffn"])
        for name in _FFN_IN:
            if name in ffn:
                ffn[name] = rot_in(ffn[name])
        for name in _FFN_OUT:
            if name in ffn:
                ffn[name] = rot_out(ffn[name])
        if "router" in ffn:
            ffn["router"] = rot_in(ffn["router"])
            experts = dict(ffn["experts"])
            experts["wi"] = jnp.einsum(
                "de,aef->adf", qf.T,
                experts["wi"].astype(jnp.float32)).astype(experts["wi"].dtype)
            experts["wu"] = jnp.einsum(
                "de,aef->adf", qf.T,
                experts["wu"].astype(jnp.float32)).astype(experts["wu"].dtype)
            experts["wd"] = jnp.einsum(
                "afd,de->afe", experts["wd"].astype(jnp.float32),
                qf).astype(experts["wd"].dtype)
            ffn["experts"] = experts
            if "shared" in ffn:
                sh = dict(ffn["shared"])
                for name in _FFN_IN:
                    sh[name] = rot_in(sh[name])
                sh["wd"] = rot_out(sh["wd"])
                ffn["shared"] = sh
        p["ffn"] = ffn
    return p


def rotate_model(params: dict, cfg: ModelConfig, model, key) -> tuple[dict, dict]:
    """Fuse norms then rotate the whole model. Returns (params, rotations).

    Enc-dec models get separate rotations per stream (Q_dec, Q_enc); the
    decoder's cross-attention K/V side uses Q_enc as q_media.  VLM media is
    an external stub -> media side stays unrotated (q_media=None)."""
    kd, ke = jax.random.split(jax.random.fold_in(key, 7))
    q = random_hadamard(kd, cfg.d_model)
    q_enc = random_hadamard(ke, cfg.d_model) if cfg.family == "encdec" else None
    params = dict(params)

    # ---- fuse norms everywhere
    if "prefix" in params:
        params["prefix"] = [fuse_norms_block(b, cfg) for b in params["prefix"]]
    params["groups"] = jax.vmap(
        lambda g: {k: fuse_norms_block(g[k], cfg) for k in g})(params["groups"])
    head = params.get("head")
    fg = params["final_norm"].astype(jnp.float32)
    if head is not None:
        params["head"] = _scale_in(head, fg)
    else:
        # tied embeddings: head = embedᵀ -> fold γ into the embedding copy
        # is NOT output-preserving for the embedding side; keep a separate
        # head instead
        params["head"] = _scale_in(params["embed"].T, fg)
    params["final_norm"] = jnp.ones_like(params["final_norm"])
    if "encoder" in params:
        enc = dict(params["encoder"])
        enc["groups"] = jax.vmap(
            lambda g: {k: fuse_norms_block(g[k], cfg) for k in g})(enc["groups"])
        # the encoder final norm feeds every decoder cross-attn K/V: fold its
        # γ into those consumers so the encoder stream can be rotated
        ge = enc["final_norm"].astype(jnp.float32)

        def fold_cross(b):
            b = dict(b)
            cross = dict(b["cross"])
            cross["wk"] = _scale_in(cross["wk"], ge)
            cross["wv"] = _scale_in(cross["wv"], ge)
            b["cross"] = cross
            return b

        params["groups"] = jax.vmap(
            lambda g: {k: fold_cross(g[k]) for k in g})(params["groups"])
        enc["final_norm"] = jnp.ones_like(enc["final_norm"])
        params["encoder"] = enc

    # ---- rotate
    media_q = q_enc if cfg.family == "encdec" else None
    metas = model.group_metas
    if "prefix" in params:
        params["prefix"] = [
            rotate_block(b, cfg, m, q, media_q)
            for b, m in zip(params["prefix"], model.prefix_metas)]
    params["groups"] = jax.vmap(
        lambda g: {f"b{i}": rotate_block(g[f"b{i}"], cfg, metas[i], q, media_q)
                   for i in range(len(metas))})(params["groups"])
    params["embed"] = (params["embed"].astype(jnp.float32)
                       @ q.astype(jnp.float32)).astype(params["embed"].dtype)
    params["head"] = (q.astype(jnp.float32).T
                      @ params["head"].astype(jnp.float32)
                      ).astype(params["head"].dtype)
    if "encoder" in params and q_enc is not None:
        enc = dict(params["encoder"])
        em = model.enc_metas[0]
        enc["groups"] = jax.vmap(
            lambda g: {"b0": rotate_block(g["b0"], cfg, em, q_enc)})(enc["groups"])
        params["encoder"] = enc
        # encoder input is a frontend stub: materialize the rotation the real
        # conv frontend's output projection would absorb
        params["frame_proj"] = q_enc.astype(params["embed"].dtype)
    rotations = {"q": q, "q_enc": q_enc}
    return params, rotations
