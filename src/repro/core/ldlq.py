"""LDLQ + E8-lattice vector quantization (paper Sec. 5.4, Tab. 6).

LDLQ is the QuIP form of the GPTQ recursion (shown equivalent in the QuIP
paper); the difference exploited here is the *rounder*: instead of a scalar
grid, each weight row (d_out,) is quantized as d_out/8 8-dim vectors to the
E8 lattice (nearest-point via the D8 / D8+½ coset decomposition), the
construction underlying QuIP#'s 2-bit E8P codebook.  Simplification vs the
paper (noted in DESIGN.md): we use the unbounded scaled E8 lattice rather
than the pruned 2^16-entry E8P ball, and report the proxy bitrate.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.gptq import hinv_cholesky, prepare_hessian


def _nearest_d8(y: jax.Array) -> jax.Array:
    """Nearest point of D8 = {x in Z^8 : sum even}; y: (..., 8)."""
    f = jnp.round(y)
    delta = y - f
    parity = jnp.mod(jnp.sum(f, axis=-1), 2.0)  # 0 even / 1 odd
    idx = jnp.argmax(jnp.abs(delta), axis=-1)
    sgn = jnp.where(jnp.take_along_axis(delta, idx[..., None], -1)[..., 0] >= 0,
                    1.0, -1.0)
    flip = jax.nn.one_hot(idx, 8, dtype=y.dtype) * sgn[..., None]
    return f + flip * parity[..., None]


def e8_nearest(y: jax.Array) -> jax.Array:
    """Nearest point of E8 = D8 U (D8 + 1/2); y: (..., 8)."""
    a = _nearest_d8(y)
    b = _nearest_d8(y - 0.5) + 0.5
    da = jnp.sum((y - a) ** 2, axis=-1, keepdims=True)
    db = jnp.sum((y - b) ** 2, axis=-1, keepdims=True)
    return jnp.where(da <= db, a, b)


def e8_quantize_row(row: jax.Array, scale: jax.Array) -> jax.Array:
    """row: (d_out,) -> dequantized row via scaled-E8 rounding."""
    y = (row / scale).reshape(-1, 8)
    return (e8_nearest(y) * scale).reshape(row.shape)


@partial(jax.jit, static_argnames=("block",))
def ldlq_quantize(w: jax.Array, h: jax.Array, *, damp: float = 0.01,
                  block: int = 128, scale_mult: float = 0.5):
    """LDLQ with the E8 rounder. w: (d_in, d_out), d_out % 8 == 0."""
    d_in, d_out = w.shape
    assert d_out % 8 == 0, d_out
    block = min(block, d_in)
    assert d_in % block == 0
    n_blocks = d_in // block

    hf = prepare_hessian(h, damp)
    u = hinv_cholesky(hf)
    w0 = w.astype(jnp.float32)
    # per-row scales from the original weights (rms * scale_mult)
    scales = jnp.sqrt(jnp.mean(w0 * w0, axis=1, keepdims=True)) * scale_mult
    scales = jnp.maximum(scales, 1e-8)

    def block_step(wc, b):
        wb = jax.lax.dynamic_slice(wc, (b * block, 0), (block, d_out))
        ub = jax.lax.dynamic_slice(u, (b * block, b * block), (block, block))
        sb = jax.lax.dynamic_slice(scales, (b * block, 0), (block, 1))

        def row_step(i, state):
            wb, deqb, errb = state
            row = jax.lax.dynamic_slice(wb, (i, 0), (1, d_out))[0]
            deq = e8_quantize_row(row, sb[i])
            err = (row - deq) / ub[i, i]
            mask = (jnp.arange(block) > i).astype(jnp.float32)
            wb = wb - (mask * ub[i])[:, None] * err[None, :]
            return (wb, deqb.at[i].set(deq), errb.at[i].set(err))

        z = jnp.zeros((block, d_out), jnp.float32)
        wb, deqb, errb = jax.lax.fori_loop(0, block, row_step, (wb, z, z))
        u_rows = jax.lax.dynamic_slice(u, (b * block, 0), (block, d_in))
        col_mask = (jnp.arange(d_in) >= (b + 1) * block).astype(jnp.float32)
        wc = wc - (u_rows * col_mask[None, :]).T @ errb
        wc = jax.lax.dynamic_update_slice(wc, deqb, (b * block, 0))
        return wc, (deqb, jnp.sum(errb * errb))

    _, (deqs, errs) = jax.lax.scan(block_step, w0, jnp.arange(n_blocks))
    w_deq = deqs.reshape(d_in, d_out).astype(w.dtype)
    return {"w_deq": w_deq, "err": jnp.sum(errs), "scales": scales}
