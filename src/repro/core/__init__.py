"""RSQ (Rotate, Scale, then Quantize) — the paper's primary contribution.

Public names resolve lazily (PEP 562): the model zoo now imports
``repro.core.quantizer`` (via ``kernels.quant_matmul``'s ``PackedWeight``),
so eagerly importing the pipeline here — which itself imports the model
zoo — would be a circular import.  ``from repro.core import RSQConfig``
and friends keep working unchanged.
"""
import importlib

from repro.core.quantizer import QuantSpec, quantize_weight_rtn  # noqa: F401

_LAZY = {
    "gptq_quantize": "repro.core.gptq",
    "gptq_quantize_ref": "repro.core.gptq",
    "STRATEGIES": "repro.core.importance",
    "get_strategy": "repro.core.importance",
    "RSQConfig": "repro.core.pipeline",
    "RSQPipeline": "repro.core.pipeline",
    "quantize_model": "repro.core.pipeline",
    "QuantizeRunner": "repro.core.resume",
    "random_hadamard": "repro.core.rotation",
    "rotate_model": "repro.core.rotation",
    "SCHEDULERS": "repro.core.scheduler",
    "LayerScheduler": "repro.core.scheduler",
    "OverlappedScheduler": "repro.core.scheduler",
    "SequentialScheduler": "repro.core.scheduler",
    "get_scheduler": "repro.core.scheduler",
}

__all__ = ["QuantSpec", "quantize_weight_rtn", *_LAZY]


def __getattr__(name: str):
    if name in _LAZY:
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
