"""RSQ (Rotate, Scale, then Quantize) — the paper's primary contribution."""
from repro.core.gptq import gptq_quantize, gptq_quantize_ref  # noqa: F401
from repro.core.importance import STRATEGIES, get_strategy  # noqa: F401
from repro.core.pipeline import RSQConfig, RSQPipeline, quantize_model  # noqa: F401
from repro.core.quantizer import QuantSpec, quantize_weight_rtn  # noqa: F401
from repro.core.rotation import random_hadamard, rotate_model  # noqa: F401
from repro.core.scheduler import (  # noqa: F401
    SCHEDULERS,
    LayerScheduler,
    OverlappedScheduler,
    SequentialScheduler,
    get_scheduler,
)
