"""Step 3 input: weighted second-order statistics H = 2 · X R² Xᵀ.

``accumulate`` is the pure-jnp oracle; the Pallas ``gram`` kernel
(kernels/gram) computes the same tiled product on TPU.  The distributed
variant shards calibration tokens over the data axes and psums the (d, d)
Hessian — see core/distributed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def accumulate(h: jax.Array | None, x: jax.Array, r: jax.Array | None = None,
               *, use_kernel: bool = False) -> jax.Array:
    """h: (d, d) fp32 or None; x: (N, d) tokens-by-features;
    r: (N,) token importances (None = uniform).  Returns h + 2·XᵀR²X."""
    xf = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    if r is not None:
        xf = xf * r.reshape(-1, 1).astype(jnp.float32)
    if use_kernel:
        from repro.kernels.gram import ops as gram_ops
        upd = 2.0 * gram_ops.weighted_gram(xf)
    else:
        upd = 2.0 * xf.T @ xf
    if h is None:
        return upd
    return h + upd


def hessian_diag_mean(h: jax.Array) -> jax.Array:
    return jnp.mean(jnp.diag(h))
