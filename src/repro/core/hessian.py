"""Step 3 input: weighted second-order statistics H = 2 · X R² Xᵀ.

``accumulate`` is the single entry point the calibration engine routes every
dense *and* stacked-expert update through: 2-D inputs ``(N, d)`` produce one
``(d, d)`` gram; 3-D inputs ``(E, C, d)`` (per-expert capacity buffers)
produce a batch of ``(E, d, d)`` independent grams.  ``use_kernel=True``
dispatches the tiled Pallas ``gram`` kernel (kernels/gram) instead of the
pure-jnp contraction — the pipeline turns this on automatically on TPU.
The distributed variant shards calibration tokens over the data axes and
psums the (d, d) Hessian — see core/distributed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def accumulate(h: jax.Array | None, x: jax.Array, r: jax.Array | None = None,
               *, use_kernel: bool = False) -> jax.Array:
    """h: (d, d) fp32 (or (E, d, d) for stacked experts) or None;
    x: (N, d) tokens-by-features or (E, C, d) expert capacity buffers;
    r: (N,) / (E, C) token importances (None = uniform).
    Returns h + 2·XᵀR²X (batched over the leading expert axis for 3-D x)."""
    lead = x.shape[:-2] if x.ndim >= 3 else ()
    xf = x.reshape((-1,) + x.shape[-2:]).astype(jnp.float32)  # (B, N, d)
    if r is not None:
        xf = xf * r.reshape(xf.shape[0], xf.shape[1], 1).astype(jnp.float32)
    if use_kernel:
        from repro.kernels.gram import ops as gram_ops
        upd = 2.0 * gram_ops.weighted_gram(xf)
    else:
        upd = 2.0 * jnp.einsum("bnd,bne->bde", xf, xf)
    upd = upd.reshape(lead + upd.shape[-2:])
    if h is None:
        return upd
    return h + upd


def hessian_diag_mean(h: jax.Array) -> jax.Array:
    return jnp.mean(jnp.diag(h))
