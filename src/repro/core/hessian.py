"""Step 3 input: weighted second-order statistics H = 2 · X R² Xᵀ.

``accumulate`` is the single entry point the calibration engine routes every
dense *and* stacked-expert update through: 2-D inputs ``(N, d)`` produce one
``(d, d)`` gram; 3-D inputs ``(E, C, d)`` (per-expert capacity buffers)
produce a batch of ``(E, d, d)`` independent grams.  ``use_kernel=True``
dispatches the tiled Pallas ``gram`` kernel (kernels/gram) instead of the
pure-jnp contraction — the pipeline turns this on automatically on TPU.

Streaming sharded accumulation
------------------------------
``n_shards=S > 1`` switches the accumulator to its *streaming* layout: the
token rows are split into S contiguous chunks and each chunk contributes its
own partial gram, so ``h`` carries a leading shard axis — ``(S, d, d)`` for
dense weights, ``(S, E, d, d)`` for expert stacks.  When that leading axis
is placed on the data axis of a mesh (``ParallelCtx.shard_leading``), every
device accumulates only its local partial and *no* per-batch cross-device
reduction happens; ``reduce_shards`` performs the single solve-time
reduction (one psum under GSPMD, or the explicit ring in
``core/distributed.make_sharded_hessian_fn``).  Rows that don't divide by S
are zero-padded — zero rows contribute nothing to a gram, so the padding is
exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def accumulate(h: jax.Array | None, x: jax.Array, r: jax.Array | None = None,
               *, use_kernel: bool = False, n_shards: int = 1) -> jax.Array:
    """h: (d, d) fp32 (or (E, d, d) for stacked experts) or None;
    x: (N, d) tokens-by-features or (E, C, d) expert capacity buffers;
    r: (N,) / (E, C) token importances (None = uniform).
    Returns h + 2·XᵀR²X (batched over the leading expert axis for 3-D x).
    With ``n_shards=S > 1`` the result carries a leading (S,) partial-sum
    axis instead of being fully reduced — see module docstring."""
    lead = x.shape[:-2] if x.ndim >= 3 else ()
    xf = x.reshape((-1,) + x.shape[-2:]).astype(jnp.float32)  # (B, N, d)
    if r is not None:
        xf = xf * r.reshape(xf.shape[0], xf.shape[1], 1).astype(jnp.float32)
    if n_shards > 1:
        b, n, d = xf.shape
        pad = (-n) % n_shards
        if pad:  # zero rows are gram-neutral (r already folded into xf)
            xf = jnp.concatenate(
                [xf, jnp.zeros((b, pad, d), xf.dtype)], axis=1)
        # (B, S, N/S, d) -> (S, B, N/S, d): shard axis leads so it can sit
        # on the data axis of a mesh; chunks are contiguous token ranges,
        # matching a P("dp", ...)-sharded batch
        xf = xf.reshape(b, n_shards, -1, d).transpose(1, 0, 2, 3)
        lead = (n_shards,) + lead
        xf = xf.reshape((-1,) + xf.shape[-2:])
    if use_kernel:
        from repro.kernels.gram import ops as gram_ops
        upd = 2.0 * gram_ops.weighted_gram(xf)
    else:
        upd = 2.0 * jnp.einsum("bnd,bne->bde", xf, xf)
    upd = upd.reshape(lead + upd.shape[-2:])
    if h is None:
        return upd
    return h + upd


def reduce_shards(h: jax.Array) -> jax.Array:
    """Collapse a streaming ``(S, ...)`` accumulator to the dense Hessian.

    This is the *one* solve-time reduction of the sharded path: when the
    leading axis is mesh-sharded, GSPMD lowers the sum to a single psum per
    weight (vs one per calibration batch for replicated accumulators)."""
    return jnp.sum(h, axis=0)


def hessian_diag_mean(h: jax.Array) -> jax.Array:
    return jnp.mean(jnp.diag(h))
