"""GPTQ / OBC solver, TPU-adapted.

The GPU reference runs a per-column loop with rank-1 updates.  Here the
mathematically identical recursion is restructured for the MXU: a
``lax.scan`` over 128-row blocks; inside a block a ``fori_loop`` performs the
(cheap, VPU-bound) per-row quantize+compensate; across blocks the deferred
compensation is one dense (B, d_in) x (B, d_out) matmul.  Everything is
jittable with static shapes and vmaps over batched weights.

Math (paper Eq. 2): quantize row q, then
    delta = -(w_q - quant(w_q)) / [H^-1]_qq * [H^-1]_{q,:}
implemented via the upper-Cholesky factor U of H^-1 (H^-1 = U^T U), exactly
as in the reference implementation.

RSQ enters only through the Hessian: H = 2 X R^2 X^T (see hessian.py); the
solver is oblivious to token scaling — that is what makes the paper's
technique integrate "seamlessly" into GPTQ.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quantizer import QuantSpec, dequantize, find_params, quantize_rtn


def prepare_hessian(h: jax.Array, damp: float = 0.01) -> jax.Array:
    """Symmetrize, fix dead rows, dampen."""
    hf = h.astype(jnp.float32)
    hf = 0.5 * (hf + hf.T)
    d = jnp.diag(hf)
    dead = d <= 0.0
    hf = hf + jnp.diag(jnp.where(dead, 1.0, 0.0))
    mean_d = jnp.mean(jnp.where(dead, 0.0, d))
    hf = hf + damp * jnp.maximum(mean_d, 1e-8) * jnp.eye(hf.shape[0])
    return hf


def _inv_upper(u: jax.Array, block: int = 64) -> jax.Array:
    """Batch-invariant inverse of an upper-triangular matrix.

    Recursive 2x2 block back-substitution: diagonal blocks <= ``block``
    invert by masked row back-substitution, off-diagonal blocks combine as
    -A⁻¹·C·D⁻¹.  Everything is matmuls + elementwise ops, which XLA rounds
    identically whether the program is vmapped or not — unlike the library
    ``solve_triangular`` (trsm), whose batched CPU path accumulates in a
    different order than the single-matrix call and seeds the ulp-level
    drift that flips GPTQ codes at 2-bit/small-group settings (the vmap
    parity regression in tests/test_pipeline_perf.py)."""
    d = u.shape[-1]
    if d <= block:
        eye = jnp.eye(d, dtype=u.dtype)

        def body(k, v):
            i = d - 1 - k
            ui = jax.lax.dynamic_slice_in_dim(u, i, 1, 0)[0]
            e_i = jax.lax.dynamic_slice_in_dim(eye, i, 1, 0)[0]
            uii = jax.lax.dynamic_slice(u, (i, i), (1, 1))[0, 0]
            row = (e_i - ui @ v) / uii
            return jax.lax.dynamic_update_slice_in_dim(v, row[None], i, 0)

        return jax.lax.fori_loop(0, d, body, jnp.zeros_like(u))
    m = d // 2
    a, c, dd = u[:m, :m], u[:m, m:], u[m:, m:]
    ai, di = _inv_upper(a, block), _inv_upper(dd, block)
    tr = -(ai @ c) @ di
    top = jnp.concatenate([ai, tr], axis=1)
    bot = jnp.concatenate([jnp.zeros((d - m, m), u.dtype), di], axis=1)
    return jnp.concatenate([top, bot], axis=0)


def hinv_cholesky(h: jax.Array) -> jax.Array:
    """Upper-triangular U with H^-1 = U^T U.

    Direct formulation: factor the index-reversed H as J·H·J = L̃ L̃^T, so
    H = Ũ Ũ^T with Ũ = J·L̃·J upper-triangular (a "UL" factorization), and
    H^-1 = Ũ^-T Ũ^-1, i.e. U = Ũ^-1.  One Cholesky + one triangular inverse
    — versus the naive Cholesky → full inverse → re-Cholesky chain, this
    halves the O(d^3) setup work per solve.  U equals the upper Cholesky
    factor of H^-1 (unique for a positive diagonal) up to rounding.

    The triangular inverse uses the batch-invariant blocked form
    (``_inv_upper``) so batched (vmapped) and sequential solves produce
    bit-identical U — a precondition for exact batched-vs-sequential code
    parity (Cholesky itself is already batch-invariant on all backends we
    run)."""
    lr = jnp.linalg.cholesky(h[::-1, ::-1])
    ut = lr[::-1, ::-1]  # upper, H = ut @ ut.T
    return _inv_upper(ut)


@partial(jax.jit, static_argnames=("spec", "block"))
def gptq_quantize(w: jax.Array, h: jax.Array, spec: QuantSpec,
                  *, damp: float = 0.01, block: int = 128):
    """w: (d_in, d_out); h: (d_in, d_in) (already includes token scaling).

    Returns dict with:
      ``w_deq``  (d_in, d_out) dequantized weight (same dtype as w)
      ``q``      (d_in, d_out) int32 codes
      ``scale``/``zero`` (n_groups, d_out)
      ``err``    scalar proxy loss sum_i ||(w_i - q_i)/U_ii||^2
    """
    d_in, d_out = w.shape
    block = min(block, d_in)
    assert d_in % block == 0, (d_in, block)
    gs = d_in if spec.group_size == -1 else spec.group_size
    # group boundaries must align with block boundaries; groups larger than a
    # block are only supported as the single global group (group_size == -1)
    assert (gs <= block and block % gs == 0) or spec.group_size == -1, \
        (gs, block)
    rows_per_group = min(gs, block)
    n_blocks = d_in // block

    hf = prepare_hessian(h, damp)
    u = hinv_cholesky(hf)  # (d_in, d_in) upper

    w0 = w.astype(jnp.float32)
    groups_per_block = block // rows_per_group if gs <= block else 0

    def block_step(carry, b):
        wc = carry
        wb = jax.lax.dynamic_slice(wc, (b * block, 0), (block, d_out))
        ub = jax.lax.dynamic_slice(u, (b * block, b * block), (block, block))

        def row_step(i, state):
            wb, qb, deqb, errb, scaleb, zerob = state
            row = jax.lax.dynamic_slice(wb, (i, 0), (1, d_out))[0]
            if gs <= block:
                # entering a new group? -> (re)compute params from the
                # *current* (already-compensated) rows of this group
                grp = jax.lax.dynamic_slice(
                    wb, ((i // rows_per_group) * rows_per_group, 0),
                    (rows_per_group, d_out))
                s_new, z_new = find_params(grp, spec)
                at_boundary = (i % rows_per_group) == 0
                g_idx = i // rows_per_group
                s_cur = jnp.where(at_boundary, s_new, scaleb[g_idx])
                z_cur = jnp.where(at_boundary, z_new, zerob[g_idx])
                scaleb = scaleb.at[g_idx].set(s_cur)
                zerob = zerob.at[g_idx].set(z_cur)
            else:
                # group spans multiple blocks: params fixed from the global
                # precomputed scale (see below); scaleb holds a single row
                s_cur, z_cur = scaleb[0], zerob[0]
            qrow = quantize_rtn(row, s_cur, z_cur, spec)
            deq = dequantize(qrow, s_cur, z_cur)
            d_ii = ub[i, i]
            err = (row - deq) / d_ii
            # compensate the remaining rows of this block (j > i)
            mask = (jnp.arange(block) > i).astype(jnp.float32)
            wb = wb - (mask * ub[i])[:, None] * err[None, :]
            qb = qb.at[i].set(qrow)
            deqb = deqb.at[i].set(deq)
            errb = errb.at[i].set(err)
            return wb, qb, deqb, errb, scaleb, zerob

        qb0 = jnp.zeros((block, d_out), jnp.int32)
        deqb0 = jnp.zeros((block, d_out), jnp.float32)
        errb0 = jnp.zeros((block, d_out), jnp.float32)
        if gs <= block:
            sb0 = jnp.zeros((groups_per_block, d_out), jnp.float32)
            zb0 = jnp.zeros((groups_per_block, d_out), jnp.float32)
        else:
            # one global group: compute once from the original weight
            s_all, z_all = find_params(w0, spec)
            sb0, zb0 = s_all[None], z_all[None]
        wb, qb, deqb, errb, sb, zb = jax.lax.fori_loop(
            0, block, row_step, (wb, qb0, deqb0, errb0, sb0, zb0))

        # deferred compensation of all rows after this block (one matmul)
        u_rows = jax.lax.dynamic_slice(u, (b * block, 0), (block, d_in))
        col_mask = (jnp.arange(d_in) >= (b + 1) * block).astype(jnp.float32)
        wc = wc - (u_rows * col_mask[None, :]).T @ errb
        # write the final (dequantized) rows back
        wc = jax.lax.dynamic_update_slice(wc, deqb, (b * block, 0))
        return wc, (qb, deqb, sb, zb, jnp.sum(errb * errb))

    wc, (qs, deqs, ss, zs, errs) = jax.lax.scan(
        block_step, w0, jnp.arange(n_blocks))
    w_deq = deqs.reshape(d_in, d_out).astype(w.dtype)
    q = qs.reshape(d_in, d_out)
    if gs <= block:
        scale = ss.reshape(-1, d_out)
        zero = zs.reshape(-1, d_out)
    else:
        scale, zero = ss[0], zs[0]
    return {"w_deq": w_deq, "q": q, "scale": scale, "zero": zero,
            "err": jnp.sum(errs)}


def gptq_quantize_ref(w, h, spec: QuantSpec, damp: float = 0.01):
    """Naive OBC recursion (explicit H^-1 downdating) — the oracle the
    blocked solver is tested against.  O(d_in) python loop; tiny inputs."""
    import numpy as np

    d_in, d_out = w.shape
    hf = np.asarray(prepare_hessian(jnp.asarray(h), damp), np.float64)
    hinv = np.linalg.inv(hf)
    wf = np.asarray(w, np.float64).copy()
    gs = d_in if spec.group_size == -1 else spec.group_size
    q = np.zeros((d_in, d_out), np.int32)
    deq = np.zeros((d_in, d_out), np.float64)
    scale = np.zeros((d_in // gs, d_out))
    zero = np.zeros((d_in // gs, d_out))
    if spec.group_size == -1:
        s, z = find_params(jnp.asarray(wf, jnp.float32), spec)
        scale[0], zero[0] = np.asarray(s), np.asarray(z)
    for i in range(d_in):
        g = i // gs
        if spec.group_size != -1 and i % gs == 0:
            s, z = find_params(jnp.asarray(wf[i : i + gs], jnp.float32), spec)
            scale[g], zero[g] = np.asarray(s), np.asarray(z)
        qi = np.asarray(quantize_rtn(jnp.asarray(wf[i], jnp.float32),
                                     jnp.asarray(scale[g], jnp.float32),
                                     jnp.asarray(zero[g], jnp.float32), spec))
        di = scale[g] * (qi - zero[g])
        err = (wf[i] - di) / hinv[i, i]
        wf -= np.outer(hinv[:, i], err)
        # downdate H^-1 (remove row/col i)
        hinv = hinv - np.outer(hinv[:, i], hinv[i, :]) / hinv[i, i]
        hinv[i, :] = 0.0
        hinv[:, i] = 0.0
        hinv[i, i] = 1.0
        q[i], deq[i] = qi, di
        wf[i] = di
    return {"w_deq": deq.astype(np.float32), "q": q,
            "scale": scale.astype(np.float32), "zero": zero.astype(np.float32)}
