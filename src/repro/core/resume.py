"""Resumable quantization: layer-granular checkpoints for the RSQ pipeline.

``QuantizeRunner`` generalizes the training-side ``runtime.fault.StepRunner``
to the calibrate->solve->pack pipeline.  The unit of durable progress is one
*layer solve*: after layer i's apply sweep is dispatched the pipeline calls
back (``RSQPipeline.layer_commit``) with everything needed to continue the
stack from layer i+1, and the runner persists it through the crash-safe
``CheckpointManager``:

  * the solved (quantized) block params of every layer so far,
  * the propagated activations (= layer i+1's calibration inputs),
  * the packed-artifact entries folded so far (plus their metadata, which
    also carries the artifact's entry *order* — npz member order matters
    for the byte-identical-artifact contract),
  * under the overlapped schedule, layer i+1's already-complete Hessian
    accumulators (so the resume skips that capture sweep entirely),
  * the ``CalibrationLoader`` state (seed, step), reseeked on restore.

On restart the runner restores the latest checkpoint, validates/reseeks the
loader, and re-enters ``RSQPipeline.run(resume=...)``: solved layers are
skipped, the stack continues from the restored activations, and the final
packed artifact is **bit-identical** to an uninterrupted run — the parity
tests in ``tests/test_resume.py`` compare file SHA-256s under both
schedulers, with and without a device mesh.

Failure handling reuses the shared :class:`repro.runtime.fault.RetryPolicy`
(recoverable exception tuple, bounded restarts, exponential backoff) and
reports structured events through :class:`repro.runtime.fault.EventLog`.
Failures are injected at stage granularity via
:class:`repro.runtime.fault.FaultPlan` — any ``(layer, stage)`` with
``stage in {"capture", "solve", "apply", "pack"}``.
"""
from __future__ import annotations

import time
from typing import Any, Optional

from repro.checkpoint import CheckpointManager
from repro.core.pipeline import RSQPipeline
from repro.runtime.fault import EventLog, RetryPolicy


class QuantizeRunner:
    """Drive ``RSQPipeline.run`` with layer-solve-granular checkpointing.

    Parameters
    ----------
    pipeline : RSQPipeline
    ckpt : CheckpointManager
        Progress directory (distinct from the packed-artifact directory).
    save_every_layers : int
        Checkpoint cadence; the stack-completing commit always saves
        (blocking) regardless.
    policy : RetryPolicy
        Recoverable-exception tuple + bounded restarts + backoff for the
        in-process retry loop.  Out-of-process recovery (a new process
        pointing at the same progress dir) goes through the same restore
        path without the loop.
    save_hessians : bool
        Also persist the next layer's complete accumulators when the
        schedule provides them (overlapped), skipping that capture sweep on
        resume.  Values are exact float32 partial sums, so this is a pure
        wall-clock trade — parity is unaffected either way.
    loader : CalibrationLoader, optional
        Recorded via ``state()`` at every save and ``restore()``d (seed
        validation + reseek) before a resumed run.
    resume : bool
        ``False`` ignores any existing checkpoints (clean-run semantics);
        in-process retries then also restart from scratch, which still
        terminates because a ``FaultPlan`` decrements its counters.

    After ``run``: ``restarts``, ``events`` (structured ``checkpoint`` /
    ``restart`` / ``resume`` records) and ``ckpt_overhead_s`` (total time
    spent in commit bookkeeping + checkpoint saves — the bench field).
    """

    def __init__(self, pipeline: RSQPipeline, ckpt: CheckpointManager, *,
                 save_every_layers: int = 1,
                 policy: Optional[RetryPolicy] = None,
                 save_hessians: bool = True,
                 loader: Any = None,
                 resume: bool = True,
                 on_event=None, verbose: bool = False):
        self.pipeline = pipeline
        self.ckpt = ckpt
        self.save_every_layers = max(int(save_every_layers), 1)
        self.policy = policy or RetryPolicy()
        self.save_hessians = save_hessians
        self.loader = loader
        self.resume = resume
        self.events = EventLog(on_event, verbose=verbose)
        self.restarts = 0
        self.ckpt_overhead_s = 0.0
        self._solved: dict[str, Any] = {}
        self._reports: dict[str, dict] = {}
        self._last_saved = 0

    # ------------------------------------------------------------ commit hook
    def _commit(self, *, index: int, state: dict, p_new, acts,
                art_entries: dict, art_meta: dict,
                next_hessians: Optional[dict], next_index: Optional[int],
                last: bool) -> None:
        """``RSQPipeline.layer_commit`` callback: record layer ``index`` as
        solved and checkpoint on cadence (always on the final layer)."""
        t0 = time.perf_counter()
        self._solved[str(index)] = p_new
        self.pipeline.layer_sync(state)  # floats for the JSON report
        self._reports[f"layer{index}"] = {
            "weights": dict(state["pending"]),
            "seconds": round(time.perf_counter() - state["t0"], 4)}
        if last or index + 1 - self._last_saved >= self.save_every_layers:
            ckpt_state: dict[str, Any] = {
                "solved": dict(self._solved),
                "acts": list(acts),
                "art": {n: dict(e) for n, e in art_entries.items()},
            }
            extra = {
                "next": index + 1,
                "complete": bool(last),
                "reports": dict(self._reports),
                "art_meta": {n: dict(m) for n, m in art_meta.items()},
                "loader": (self.loader.state()
                           if self.loader is not None else None),
                "hess_layer": None,
            }
            if self.save_hessians and next_hessians is not None and not last:
                ckpt_state["hessians"] = {str(next_index): dict(next_hessians)}
                extra["hess_layer"] = int(next_index)
            self.ckpt.save(index + 1, ckpt_state, extra=extra, blocking=last)
            self._last_saved = index + 1
            self.events.emit("checkpoint", layer=index, next=index + 1,
                             complete=bool(last),
                             entries=len(art_entries))
        self.ckpt_overhead_s += time.perf_counter() - t0

    # --------------------------------------------------------------- restore
    def _load_resume(self) -> Optional[dict]:
        """Latest checkpoint -> ``RSQPipeline.run(resume=...)`` dict (None
        when there is none).  Also reseeks/validates the loader."""
        self.ckpt.wait()
        if self.ckpt.latest_step() is None:
            return None
        step, state, extra = self.ckpt.restore()
        resume = {
            "start": int(extra["next"]),
            "solved": state.get("solved", {}),
            "acts": list(state.get("acts", [])),
            "art": state.get("art", {}),
            "art_meta": extra.get("art_meta") or {},
            "reports": extra.get("reports") or {},
        }
        hl = extra.get("hess_layer")
        if hl is not None and "hessians" in state:
            resume["hessians"] = {int(hl): state["hessians"][str(hl)]}
        if self.loader is not None and extra.get("loader") is not None:
            self.loader.restore(extra["loader"])
        # seed the in-memory mirrors so the next save carries the full prefix
        self._solved = dict(resume["solved"])
        self._reports = dict(resume["reports"])
        self._last_saved = int(step)
        self.events.emit("resume", step=int(step), start=resume["start"],
                         complete=bool(extra.get("complete")))
        return resume

    # -------------------------------------------------------------------- run
    def run(self, params: dict, calib_tokens, *, fault=None, **kw):
        """Run the pipeline to completion, surviving recoverable failures.

        Any exception matching ``policy.recoverable`` triggers: structured
        ``restart`` event, exponential backoff, restore of the latest
        layer-solve checkpoint, and re-entry mid-stack.  Everything else
        propagates.  Returns ``(new_params, report)`` exactly like
        ``RSQPipeline.run``."""
        while True:
            self._solved, self._reports, self._last_saved = {}, {}, 0
            resume = self._load_resume() if self.resume else None
            try:
                return self.pipeline.run(
                    params, calib_tokens, fault=fault,
                    commit=self._commit, resume=resume, **kw)
            except Exception as e:
                # drain any in-flight async save first: an exception unwind
                # is an orderly death (unlike SIGKILL), so progress already
                # handed to the checkpointer must land before we re-raise —
                # the next process resumes from it deterministically
                try:
                    self.ckpt.wait()
                except Exception:
                    pass  # already raising; a failed save just means an
                    # older checkpoint (or none) greets the next attempt
                if not self.policy.is_recoverable(e):
                    raise
                self.restarts += 1
                if self.restarts > self.policy.max_restarts:
                    raise
                b = self.policy.backoff(self.restarts)
                self.events.emit("restart", error=repr(e),
                                 attempt=self.restarts,
                                 backoff_s=round(b, 4))
                if b:
                    time.sleep(b)
