"""Distributed RSQ: how the calibration pipeline scales to a pod.

Two independent axes of parallelism, matching DESIGN.md §5:

  * data-parallel Hessians — calibration tokens shard over the data axes.
    The classic mode keeps H replicated: the (d, d) weighted gram update is
    a contraction over the sharded token dim, so GSPMD reduces it with one
    psum per *batch*.  The streaming mode (``streaming=True``) instead
    keeps the accumulator itself sharded — shape (S, d, d) with the shard
    axis on the data axes, each device adding only its local partial gram —
    and defers the cross-device reduction to a single solve-time
    ``reduce`` (a ring all-reduce, ``runtime.collectives.ring_psum``).
    Per-batch collective traffic drops to zero and no device ever holds an
    unsharded per-layer Hessian during accumulation, which is what lets
    calibration batches stream at pod scale.

  * weight-parallel solves — GPTQ/LDLQ solves for different weights (all
    experts of a layer, or same-shaped weights across layers) are
    independent: ``gptq_quantize_batched`` / ``ldlq_quantize_batched`` vmap
    the blocked solvers so one pjit call distributes the batch over the
    model axis.  These are the solvers the calibration engine's
    shape-grouped solves dispatch to (see pipeline.quantize_layer_weights):
    q/k/v-style same-shape weights and stacked (E, d_in, d_out) expert
    tensors arrive pre-stacked along the leading axis.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.4.35
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.core import hessian as hess
from repro.core.gptq import gptq_quantize
from repro.core.ldlq import ldlq_quantize
from repro.core.quantizer import QuantSpec
from repro.runtime.collectives import ring_psum
from repro.runtime.sharding import ParallelCtx


def make_shard_reducer(ctx: ParallelCtx):
    """Jitted ``(S, ...) -> (...)`` reduction of a streaming accumulator
    whose leading shard axis lives on ``ctx``'s data axes, via the explicit
    collective path: a local shard-sum followed by ONE exact all-reduce over
    the data axis (bandwidth-optimal ``ring_psum`` for a single data axis, a
    plain psum for pod x data meshes).  This is the reduce the calibration
    pipeline dispatches at solve time when a live mesh is present —
    replacing the GSPMD ``jnp.sum`` fallback — and the reduce
    ``make_sharded_hessian_fn(streaming=True)`` returns.

    Requires a mesh with a non-trivial data axis; callers without one keep
    ``hessian.reduce_shards``."""
    assert ctx.enabled and ctx.dp and ctx.axis_size("dp") > 1, \
        "make_shard_reducer needs a live mesh with a data axis"
    axes = ctx.dp if len(ctx.dp) > 1 else ctx.dp[0]

    def local_reduce(hs):
        # local shard-sum then ONE exact all-reduce over the data
        # axis — the only collective of the whole accumulation stream.
        # Single data axis: bandwidth-optimal ring, chunked over the
        # leading rows of the summed (d, d) / (E, d, d) partial;
        # multi-axis (pod x data) meshes: a plain psum over both.
        part = jnp.sum(hs, axis=0)
        if isinstance(axes, str):
            return ring_psum(part, axes)
        return jax.lax.psum(part, axes)

    def reduce_fn(h):
        spec = P(axes, *([None] * (h.ndim - 1)))
        out = P(*([None] * (h.ndim - 1)))
        # replication checking is off: chunks of the ring all-reduce are
        # each finalized on one owner device, so the output is
        # numerically identical everywhere but not provably "replicated"
        # to the tracer (kwarg name varies across jax versions)
        for kw in ({"check_vma": False}, {"check_rep": False}, {}):
            try:
                f = _shard_map(local_reduce, mesh=ctx.mesh,
                               in_specs=(spec,), out_specs=out, **kw)
                break
            except TypeError:
                continue
        return f(h)

    return jax.jit(reduce_fn)


def make_sharded_hessian_fn(ctx: ParallelCtx, *, streaming: bool = False,
                            n_shards: int | None = None):
    """Sharded Hessian accumulation over ``ctx``'s data axes.

    ``streaming=False`` (classic): returns jitted ``f(h, x, r) -> h`` with X
    token-sharded and H replicated (one psum per batch).

    ``streaming=True``: returns ``(acc, reduce)``.  ``acc(h, x, r)``
    maintains a *sharded* (S, d, d) partial-sum accumulator (S = data-axis
    size unless ``n_shards`` overrides it; pass ``h=None`` to start) with no
    per-batch collectives; ``reduce(h) -> (d, d)`` performs the one
    solve-time reduction — a ring all-reduce over the data axis when the
    mesh is live, a plain shard-sum otherwise.
    """

    def acc_dense(h, x, r):
        xf = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        xf = xf * r.reshape(-1, 1)
        upd = 2.0 * xf.T @ xf
        return (h + upd if h is not None else upd)

    if not streaming:
        if not ctx.enabled:
            return jax.jit(acc_dense)
        x_sh = ctx.sharding("dp", None, None)
        h_sh = ctx.sharding(None, None)
        r_sh = ctx.sharding("dp", None)
        return jax.jit(acc_dense, in_shardings=(h_sh, x_sh, r_sh),
                       out_shardings=h_sh)

    s = n_shards or (max(ctx.axis_size("dp"), 1) if ctx.enabled else 1)
    s = max(s, 1)

    def acc_stream(h, x, r):
        upd = hess.accumulate(None, x.reshape(-1, x.shape[-1]),
                              None if r is None else r.reshape(-1),
                              n_shards=s)
        out = upd if h is None else h + upd
        return ctx.shard_leading(out)

    acc = jax.jit(acc_stream)

    if ctx.enabled and ctx.dp and ctx.axis_size("dp") > 1:
        return acc, make_shard_reducer(ctx)
    return acc, jax.jit(hess.reduce_shards)


@partial(jax.jit, static_argnames=("spec", "block"))
def gptq_quantize_batched(ws: jax.Array, hs: jax.Array, spec: QuantSpec,
                          *, damp: float = 0.01, block: int = 128):
    """ws: (N, d_in, d_out); hs: (N, d_in, d_in) — batched independent
    solves (vmapped; under pjit the N axis shards over the model axis)."""
    fn = lambda w, h: gptq_quantize(w, h, spec, damp=damp, block=block)
    return jax.vmap(fn)(ws, hs)


@partial(jax.jit, static_argnames=("block",))
def ldlq_quantize_batched(ws: jax.Array, hs: jax.Array, *,
                          damp: float = 0.01, block: int = 128):
    """LDLQ/E8 twin of ``gptq_quantize_batched``: one vmapped program for a
    (N, d_in, d_out) weight stack instead of a per-expert Python loop."""
    fn = lambda w, h: ldlq_quantize(w, h, damp=damp, block=block)
    return jax.vmap(fn)(ws, hs)
