"""Distributed RSQ: how the calibration pipeline scales to a pod.

Two independent axes of parallelism, matching DESIGN.md §5:

  * data-parallel Hessians — calibration tokens shard over the data axes;
    the (d, d) weighted gram update is a contraction over the sharded token
    dim, so GSPMD reduces it with one psum per batch.  H stays replicated
    (it is consumed by a device-local Cholesky).

  * weight-parallel solves — GPTQ solves for different weights (all
    experts of a layer, or same-shaped weights across layers) are
    independent: `gptq_quantize_batched` vmaps the blocked solver so one
    pjit call distributes the batch over the model axis.  This is the
    solver the calibration engine's shape-grouped solves dispatch to
    (see pipeline.quantize_layer_weights): q/k/v-style same-shape weights
    and stacked (E, d_in, d_out) expert tensors arrive pre-stacked along
    the leading axis.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.gptq import gptq_quantize
from repro.core.quantizer import QuantSpec
from repro.runtime.sharding import ParallelCtx


def make_sharded_hessian_fn(ctx: ParallelCtx):
    """Returns jitted f(h, x, r) -> h + 2 XᵀR²X with X token-sharded."""

    def acc(h, x, r):
        xf = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        xf = xf * r.reshape(-1, 1)
        upd = 2.0 * xf.T @ xf
        return (h + upd if h is not None else upd)

    if not ctx.enabled:
        return jax.jit(acc)
    x_sh = ctx.sharding("dp", None, None)
    h_sh = ctx.sharding(None, None)
    r_sh = ctx.sharding("dp", None)
    return jax.jit(acc, in_shardings=(h_sh, x_sh, r_sh), out_shardings=h_sh)


@partial(jax.jit, static_argnames=("spec", "block"))
def gptq_quantize_batched(ws: jax.Array, hs: jax.Array, spec: QuantSpec,
                          *, damp: float = 0.01, block: int = 128):
    """ws: (N, d_in, d_out); hs: (N, d_in, d_in) — batched independent
    solves (vmapped; under pjit the N axis shards over the model axis)."""
    fn = lambda w, h: gptq_quantize(w, h, spec, damp=damp, block=block)
    return jax.vmap(fn)(ws, hs)
