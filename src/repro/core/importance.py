"""Step 2 of RSQ: Scale — token-importance strategies (paper Sec. 4.3).

Every strategy maps per-layer inputs Z (B, T, d) (+ optional attention
column sums / token ids) to importances R (B, T).  Dynamic strategies are
normalized into [r_min, r_max] per sample (paper Eq. 4).  Heuristics
(First-N / First&Last-N) emit {0, 1} masks.

``AttnCon`` — the adopted default — is the per-token attention column mass
sum_{m,i} A[m, i, j], computed streamingly by the attention layer (see
models/attention.flash_attention(colsum=True) and the attn_colsum Pallas
kernel); attention-free layers (Mamba) fall back to ActNorm, per
DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class ImportanceInputs:
    z_in: jax.Array  # (B, T, d) layer input features
    z_out: Optional[jax.Array] = None  # (B, T, d) layer output (ActDiff)
    tokens: Optional[jax.Array] = None  # (B, T) token ids (TokenFreq)
    attn_colsum: Optional[jax.Array] = None  # (B, T) attention column mass
    token_counts: Optional[jax.Array] = None  # (vocab,) corpus counts


def normalize_scores(r: jax.Array, r_min: float, r_max: float) -> jax.Array:
    """Paper Eq. 4: per-sample linear map into [r_min, r_max]."""
    lo = jnp.min(r, axis=-1, keepdims=True)
    hi = jnp.max(r, axis=-1, keepdims=True)
    return r_min + (r - lo) / jnp.maximum(hi - lo, 1e-12) * (r_max - r_min)


def uniform(inp: ImportanceInputs, **kw) -> jax.Array:
    b, t, _ = inp.z_in.shape
    return jnp.ones((b, t), jnp.float32)


def first_n(inp: ImportanceInputs, *, n: int = 1024, **kw) -> jax.Array:
    b, t, _ = inp.z_in.shape
    return jnp.broadcast_to((jnp.arange(t) < n).astype(jnp.float32), (b, t))


def first_last_n(inp: ImportanceInputs, *, n: int = 1024, **kw) -> jax.Array:
    b, t, _ = inp.z_in.shape
    idx = jnp.arange(t)
    mask = (idx < n // 2) | (idx >= t - n // 2)
    return jnp.broadcast_to(mask.astype(jnp.float32), (b, t))


def token_freq(inp: ImportanceInputs, *, r_min: float = 0.01,
               r_max: float = 1.0, **kw) -> jax.Array:
    assert inp.tokens is not None and inp.token_counts is not None
    raw = -inp.token_counts[inp.tokens].astype(jnp.float32)
    return normalize_scores(raw, r_min, r_max)


def act_norm(inp: ImportanceInputs, *, r_min: float = 0.005,
             r_max: float = 1.0, **kw) -> jax.Array:
    raw = jnp.linalg.norm(inp.z_in.astype(jnp.float32), axis=-1)
    return normalize_scores(raw, r_min, r_max)


def act_diff(inp: ImportanceInputs, *, r_min: float = 0.01,
             r_max: float = 1.0, **kw) -> jax.Array:
    assert inp.z_out is not None
    diff = (inp.z_out - inp.z_in).astype(jnp.float32)
    raw = -jnp.linalg.norm(diff, axis=-1)
    return normalize_scores(raw, r_min, r_max)


def token_sim(inp: ImportanceInputs, *, r_min: float = 0.005,
              r_max: float = 1.0, chunk: int = 512, **kw) -> jax.Array:
    """Sum of pairwise L2 distances to all other tokens (chunked over T)."""
    z = inp.z_in.astype(jnp.float32)
    b, t, d = z.shape
    sq = jnp.sum(z * z, axis=-1)  # (B, T)

    def dist_to_all(z_c, sq_c):
        # z_c: (B, c, d) -> sum_j ||z_c_i - z_j||
        d2 = (sq_c[:, :, None] + sq[:, None, :]
              - 2.0 * jnp.einsum("bcd,btd->bct", z_c, z))
        return jnp.sum(jnp.sqrt(jnp.maximum(d2, 0.0)), axis=-1)  # (B, c)

    chunk = min(chunk, t)
    if t % chunk == 0:
        n = t // chunk
        zc = z.reshape(b, n, chunk, d).swapaxes(0, 1)
        sc = sq.reshape(b, n, chunk).swapaxes(0, 1)
        raw = jax.lax.map(lambda xs: dist_to_all(*xs), (zc, sc))
        raw = raw.swapaxes(0, 1).reshape(b, t)
    else:
        raw = dist_to_all(z, sq)
    return normalize_scores(raw, r_min, r_max)


def attn_con(inp: ImportanceInputs, *, r_min: float = 0.01,
             r_max: float = 1.0, **kw) -> jax.Array:
    if inp.attn_colsum is None:  # attention-free layer -> ActNorm fallback
        return act_norm(inp, r_min=r_min, r_max=r_max)
    return normalize_scores(inp.attn_colsum.astype(jnp.float32), r_min, r_max)


STRATEGIES: dict[str, Callable] = {
    "uniform": uniform,
    "first_n": first_n,
    "first_last_n": first_last_n,
    "token_freq": token_freq,
    "act_norm": act_norm,
    "act_diff": act_diff,
    "token_sim": token_sim,
    "attn_con": attn_con,
}


def get_strategy(name: str) -> Callable:
    if name not in STRATEGIES:
        raise KeyError(f"unknown importance strategy {name!r}; "
                       f"known: {sorted(STRATEGIES)}")
    return STRATEGIES[name]
