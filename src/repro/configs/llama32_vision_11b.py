"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; cross-attn image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision tower is a STUB: ``input_specs()`` supplies precomputed patch
embeddings of shape (batch, n_media_tokens, d_model)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    cross_attn_period=5,
    cross_attn_offset=3,
    n_media_tokens=6404,
)
