"""whisper-medium [audio] — 24L d_model=1024 16H d_ff=4096 vocab=51865 —
enc-dec, conv frontend (STUB: ``input_specs()`` supplies precomputed frame
embeddings (batch, seq, d_model)). [arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    d_head=64,
    rope_theta=10_000.0,
    qkv_bias=True,
)
