"""LLaMA3-8B-Instruct — the paper's own evaluation backbone (Tab. 1/2).
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
[arXiv:2407.21783]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
)
