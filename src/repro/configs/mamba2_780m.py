"""mamba2-780m [ssm] — 48L d_model=1536 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,  # no attention heads; SSD heads derive from d_inner
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    attn_kind="none",
    tie_embeddings=True,
    ssm_d_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
)
