"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff=1536(moe)
vocab=102400, MoE 160e top-6 — MLA kv_lora=512, 2 shared + 160 routed.
[arXiv:2405.04434; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,  # dense FFN of the first layer(s)
    vocab_size=102400,
    attn_kind="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    rope_theta=10_000.0,
    n_routed_experts=160,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1536,
    first_dense_layers=1,
    optimizer="adamw8bit",
    microbatch=4,
)
