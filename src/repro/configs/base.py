"""Base model configuration covering every assigned architecture family.

One frozen dataclass describes dense / MoE / SSM / hybrid / enc-dec / VLM
transformers.  Per-arch files under ``repro/configs`` instantiate it with the
exact published hyper-parameters; ``reduced()`` derives a smoke-test-sized
config of the same family (same layer pattern, tiny dims).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""

    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # --- identity ---------------------------------------------------------
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    # --- core dims --------------------------------------------------------
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads
    # --- attention flavour ------------------------------------------------
    attn_kind: str = "gqa"  # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MLA (DeepSeek) ----------------------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- MoE ----------------------------------------------------------------
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    moe_period: int = 1  # MoE FFN every `moe_period` layers (jamba: 2)
    moe_offset: int = 0  # offset of the MoE layer within the period
    capacity_factor: float = 1.25
    # --- SSM (Mamba-2 / SSD) -------------------------------------------------
    ssm_d_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # --- hybrid (Jamba): 1 attention layer per `attn_period` ---------------
    attn_period: int = 0
    attn_offset: int = 0
    # --- VLM (Llama-3.2-Vision): 1 cross-attn layer per period --------------
    cross_attn_period: int = 0
    cross_attn_offset: int = 0
    n_media_tokens: int = 0
    # --- enc-dec (Whisper) ---------------------------------------------------
    n_encoder_layers: int = 0
    # --- numerics / training -------------------------------------------------
    dtype: str = "bfloat16"
    optimizer: str = "adamw"  # adamw | adamw8bit | adafactor
    remat: str = "full"  # none | full
    microbatch: int = 1  # gradient-accumulation steps inside train_step
    grad_accum_dtype: str = "float32"  # accumulation buffer dtype
    # --- serving-time quantization (RSQ output) ------------------------------
    quant_bits: int = 0  # 0 = no quantization
    quant_group: int = 128
    kv_bits: int = 0  # 0 = kv cache in activation dtype; 8 = int8 codes +
    #     per-(token, head) scales; 2 = packed log codes (LogQuant-style)
    #     + per-(kv_chunk, head) scales — see kernels/flash_decode
    kv_chunk: int = 64  # tokens per 2-bit KV scale group (and the cache-
    #     length alignment unit for any quantized cache)

    # ------------------------------------------------------------------ dims
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def v_dim(self) -> int:
        return self.v_head_dim if self.attn_kind == "mla" else self.head_dim

    @property
    def qk_dim(self) -> int:
        if self.attn_kind == "mla":
            return self.qk_nope_dim + self.qk_rope_dim
        return self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def uses_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def uses_moe(self) -> bool:
        return self.n_routed_experts > 0

    # --------------------------------------------------------- layer pattern
    def layer_kinds(self) -> Tuple[str, ...]:
        """Mixer kind per decoder layer: 'attn' | 'mamba' | 'cross'."""
        kinds = []
        for i in range(self.n_layers):
            if self.family == "hybrid":
                k = "attn" if i % self.attn_period == self.attn_offset else "mamba"
            elif self.family == "ssm":
                k = "mamba"
            elif self.family == "vlm" and (
                i % self.cross_attn_period == self.cross_attn_offset
            ):
                k = "cross"
            else:
                k = "attn"
            kinds.append(k)
        return tuple(kinds)

    def ffn_kinds(self) -> Tuple[str, ...]:
        """FFN kind per decoder layer: 'dense' | 'moe' | 'none'."""
        kinds = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                kinds.append("none")  # Mamba-2 backbone has no separate FFN
            elif self.uses_moe and i >= self.first_dense_layers and (
                i % self.moe_period == self.moe_offset
            ):
                kinds.append("moe")
            else:
                kinds.append("dense")
        return tuple(kinds)

    @property
    def scan_period(self) -> int:
        """Length of the repeating layer pattern (scan group size)."""
        p = 1
        if self.family == "hybrid":
            p = math.lcm(p, self.attn_period, self.moe_period or 1)
        if self.family == "vlm":
            p = math.lcm(p, self.cross_attn_period)
        if self.uses_moe and self.moe_period > 1:
            p = math.lcm(p, self.moe_period)
        return p

    # ------------------------------------------------------------ param math
    def n_embedding_params(self) -> int:
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n *= 2
        return n

    def _attn_params(self) -> int:
        d = self.d_model
        if self.attn_kind == "mla":
            n = 0
            q_in = self.q_lora_rank if self.q_lora_rank else d
            if self.q_lora_rank:
                n += d * self.q_lora_rank
            n += q_in * self.n_heads * self.qk_dim
            n += d * (self.kv_lora_rank + self.qk_rope_dim)
            n += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
            n += self.n_heads * self.v_head_dim * d
            return n
        h, kv, hd = self.n_heads, self.n_kv_heads, self.head_dim
        return d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d

    def _dense_ffn_params(self) -> int:
        return 3 * self.d_model * self.d_ff

    def _moe_ffn_params(self, active_only: bool = False) -> int:
        e = (self.moe_top_k if active_only else self.n_routed_experts)
        n = e * 3 * self.d_model * self.moe_d_ff
        n += self.n_shared_experts * 3 * self.d_model * self.moe_d_ff
        n += self.d_model * self.n_routed_experts  # router
        return n

    def _mamba_params(self) -> int:
        d, di, st = self.d_model, self.d_inner, self.ssm_d_state
        nh = self.ssm_n_heads
        n = d * (2 * di + 2 * st + nh)  # in_proj -> (x, z, B, C, dt)
        n += self.ssm_conv_width * (di + 2 * st)  # depthwise conv
        n += nh * 2  # A_log, D
        n += di * d  # out_proj
        return n

    def n_params(self, active_only: bool = False) -> int:
        """Total (or active, for MoE) parameter count."""
        total = self.n_embedding_params()
        for kind, ffn in zip(self.layer_kinds(), self.ffn_kinds()):
            if kind == "mamba":
                total += self._mamba_params()
            elif kind == "cross":
                total += self._attn_params()
            else:
                total += self._attn_params()
            if ffn == "dense":
                total += self._dense_ffn_params()
            elif ffn == "moe":
                total += self._moe_ffn_params(active_only=active_only)
        if self.family == "encdec":
            for _ in range(self.n_encoder_layers):
                total += self._attn_params() + self._dense_ffn_params()
            # decoder cross-attention per layer
            total += self.n_layers * self._attn_params()
        return total

    # ----------------------------------------------------------------- smoke
    def reduced(self) -> "ModelConfig":
        """Same family/pattern, tiny dims — runnable on 1 CPU device."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=max(2, self.scan_period),
            d_model=64,
            n_heads=4,
            n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab_size=512,
            d_head=16,
        )
        if self.attn_kind == "mla":
            kw.update(
                q_lora_rank=32 if self.q_lora_rank else 0,
                kv_lora_rank=32,
                qk_nope_dim=16,
                qk_rope_dim=8,
                v_head_dim=16,
                d_head=0,
            )
        if self.uses_moe:
            kw.update(
                n_routed_experts=4,
                n_shared_experts=min(self.n_shared_experts, 1),
                moe_top_k=2,
                moe_d_ff=64,
                first_dense_layers=min(self.first_dense_layers, 1),
            )
            kw["n_layers"] = max(kw["n_layers"], self.first_dense_layers and 2 or 2)
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_d_state=16, ssm_head_dim=16, ssm_chunk=32)
        if self.family == "hybrid":
            kw.update(attn_period=self.attn_period and 4, attn_offset=1,
                      moe_period=2, moe_offset=1, n_layers=4)
        if self.family == "vlm":
            kw.update(cross_attn_period=2, cross_attn_offset=1,
                      n_media_tokens=8, n_layers=4)
        if self.family == "encdec":
            kw.update(n_encoder_layers=2)
        return dataclasses.replace(self, **kw)
