"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE every other
layer. [arXiv:2403.19887; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    rope_theta=10_000.0,
    # 1 attention layer per 8 (position 4 of each period), rest Mamba
    attn_period=8,
    attn_offset=4,
    # MoE FFN every other layer (odd positions)
    n_routed_experts=16,
    n_shared_experts=0,
    moe_top_k=2,
    moe_d_ff=14336,
    moe_period=2,
    moe_offset=1,
    # Mamba sublayers (Jamba uses state=16, conv=4)
    ssm_d_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
    optimizer="adamw8bit",
    microbatch=2,
)
