"""deepseek-v3-671b [moe] — 61L d_model=7168 128H d_ff=2048(moe)
vocab=129280, MoE 256e top-8 — MLA, 1 shared + 256 routed, MTP.
[arXiv:2412.19437; hf]

Multi-Token-Prediction (MTP) is exposed as the auxiliary next-next-token head
used during training (one extra projection + shared embedding)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,  # dense FFN of the first 3 layers
    vocab_size=129280,
    attn_kind="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    rope_theta=10_000.0,
    n_routed_experts=256,
    n_shared_experts=1,
    moe_top_k=8,
    moe_d_ff=2048,
    first_dense_layers=3,
    optimizer="adafactor",  # fits 671B train state in a 256-chip pod
    microbatch=8,
    grad_accum_dtype="bfloat16",
)
