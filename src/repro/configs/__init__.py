"""Config registry: ``get_config(name)`` / ``list_configs()`` / SHAPES."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES  # noqa: F401

_MODULES = {
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "mamba2-780m": "mamba2_780m",
    "minitron-4b": "minitron_4b",
    "command-r-plus-104b": "command_r_plus_104b",
    "command-r-35b": "command_r_35b",
    "qwen1.5-4b": "qwen15_4b",
    "whisper-medium": "whisper_medium",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "llama3-8b": "rsq_llama3_8b",
}

ASSIGNED_ARCHS = tuple(k for k in _MODULES if k != "llama3-8b")


def list_configs() -> tuple[str, ...]:
    return tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).reduced()
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]
