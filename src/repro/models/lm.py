"""Unified decoder-LM assembly for every assigned architecture family.

A model is a stack of *blocks*; each block = mixer (attn | MLA | Mamba |
cross-attn) + optional cross-attention sub-layer (enc-dec decoders) +
optional FFN (dense | MoE).  Layers are scanned in *groups* of
``cfg.scan_period`` blocks so heterogeneous patterns (Jamba 1:7, VLM every
5th cross) still lower to one compact ``lax.scan`` — O(1) HLO in depth.

Three entry points per model, matching the dry-run cells:
  * ``loss``         — training forward + chunked CE (train_4k)
  * ``prefill``      — forward returning last-token logits + KV/state cache
  * ``decode_step``  — one token against the cache (decode_32k / long_500k)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as att
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    apply_dense_ffn,
    embed_lookup,
    capture_dense_ffn,
    cross_entropy_chunked,
    dense_init,
    init_dense_ffn,
    init_embedding,
    linear,
    rms_norm,
)
from repro.runtime.sharding import LOCAL, ParallelCtx, param_specs

try:  # jax >= 0.4.35
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class BlockMeta:
    mixer: str  # "attn" | "mla" | "mamba" | "cross"
    ffn: str  # "dense" | "moe" | "none"
    has_cross: bool = False  # enc-dec decoder blocks
    causal: bool = True


def decoder_metas(cfg: ModelConfig) -> tuple[BlockMeta, ...]:
    metas = []
    for kind, ffn in zip(cfg.layer_kinds(), cfg.ffn_kinds()):
        mixer = kind
        if kind == "attn" and cfg.attn_kind == "mla":
            mixer = "mla"
        metas.append(
            BlockMeta(mixer=mixer, ffn=ffn, has_cross=(cfg.family == "encdec"))
        )
    return tuple(metas)


def encoder_metas(cfg: ModelConfig) -> tuple[BlockMeta, ...]:
    return tuple(
        BlockMeta(mixer="attn", ffn="dense", causal=False)
        for _ in range(cfg.n_encoder_layers)
    )


# ------------------------------------------------------------------- blocks


def init_block(key, cfg: ModelConfig, meta: BlockMeta, dtype):
    keys = jax.random.split(key, 4)
    d = cfg.d_model
    p: dict[str, Any] = {"mixer_norm": jnp.ones((d,), dtype)}
    if meta.mixer == "attn":
        p["mixer"] = att.init_gqa(keys[0], cfg, dtype)
    elif meta.mixer == "mla":
        p["mixer"] = att.init_mla(keys[0], cfg, dtype)
    elif meta.mixer == "mamba":
        p["mixer"] = ssm_lib.init_mamba(keys[0], cfg, dtype)
    elif meta.mixer == "cross":
        p["mixer"] = att.init_cross_attn(keys[0], cfg, dtype)
    else:
        raise ValueError(meta.mixer)
    if meta.has_cross:
        p["cross_norm"] = jnp.ones((d,), dtype)
        p["cross"] = att.init_cross_attn(keys[1], cfg, dtype)
    if meta.ffn == "dense":
        p["ffn_norm"] = jnp.ones((d,), dtype)
        p["ffn"] = init_dense_ffn(keys[2], cfg.d_model, cfg.d_ff, dtype)
    elif meta.ffn == "moe":
        p["ffn_norm"] = jnp.ones((d,), dtype)
        p["ffn"] = moe_lib.init_moe(keys[2], cfg, dtype)
    return p


def _routed_moe(p_ffn, cfg, h, ctx: ParallelCtx):
    """Routed-expert part, through shard_map EP when enabled."""
    routed = {"router": p_ffn["router"], "experts": p_ffn["experts"]}
    if ctx.enabled and ctx.ep and ctx.tp is not None:
        import math
        dp_size = math.prod(ctx.mesh.shape[a] for a in ctx.dp) if ctx.dp else 1
        # decode with tiny batches: tokens replicated over the data axes
        dp_ok = ctx.dp and h.shape[0] % dp_size == 0
        dp_entry = (ctx.dp if len(ctx.dp) != 1 else ctx.dp[0]) if dp_ok else None
        pspecs = {
            "router": P(None, None),
            "experts": {
                "wi": P(ctx.tp, None, None),
                "wu": P(ctx.tp, None, None),
                "wd": P(ctx.tp, None, None),
            },
        }
        act = P(dp_entry, None, None)

        def fn(pm, xx):
            y, aux = moe_lib.apply_moe(pm, cfg, xx, axis=ctx.tp)
            return y, jax.lax.pmean(aux, ctx.dp)

        y, aux = _shard_map(
            fn,
            mesh=ctx.mesh,
            in_specs=(pspecs, act),
            out_specs=(act, P()),
            check_vma=False,
        )(routed, h)
        return y, aux
    return moe_lib.apply_moe(routed, cfg, h, axis=None)


def _gather_seq(x, ctx: ParallelCtx):
    """Gather the sequence-sharded residual to full T once per sub-layer.

    §Perf iteration (train cells): with a seq-sharded x entering the
    matmuls, GSPMD replicates the *weights* over every mesh axis (full
    1.6 GB f32 all-gathers per use at command-r-plus scale); gathering the
    (much smaller) activation instead lets weights gather over the data
    axes only — the standard Megatron sequence-parallel schedule."""
    import os
    if os.environ.get("REPRO_BASELINE") or not ctx.enabled:
        return x
    if x.shape[1] == 1:  # decode: handled by constrain_act
        return x
    return ctx.constrain(x, "dp", None, None)


def apply_block(p, cfg, meta: BlockMeta, x, *, positions=None, media=None,
                ctx: ParallelCtx = LOCAL):
    """Full-sequence forward (train / prefill). Returns (x, aux, cache).

    ``positions=None`` defaults to ``arange(T)`` — lets callers that jit
    over varying sequence lengths (the calibration engine's per-meta trace
    cache) derive positions inside the trace instead of threading them."""
    if positions is None:
        positions = jnp.arange(x.shape[1])
    aux = jnp.zeros((), jnp.float32)
    # attention input stays sequence-sharded (QKV weights are the small
    # ones); only the FFN gathers full-T activations — see _gather_seq
    h = rms_norm(x, p["mixer_norm"], cfg.norm_eps)
    cache = {}
    codec = att.kv_codec(cfg.kv_bits, cfg.kv_chunk)
    if meta.mixer == "attn":
        b, t, _ = h.shape
        q, k, v = att.gqa_qkv(p["mixer"], cfg, h, positions)
        out = att.flash_attention(q, k, v, causal=meta.causal,
                                  kv_chunk=min(512, t))
        mix = linear(out.reshape(b, t, -1), p["mixer"]["wo"])
        if codec.quantized:
            # prefill writes the cache already quantized — decode appends
            # stay quantized too, so codes+scales is the *only* cache
            # representation end-to-end (training/calib forwards discard
            # the cache and XLA dead-code-eliminates the quantize)
            kq, ks = codec.encode(k)
            vq, vs = codec.encode(v)
            cache = {"k": kq, "ks": ks, "v": vq, "vs": vs}
        else:
            cache = {"k": k, "v": v}
    elif meta.mixer == "mla":
        b, t, _ = h.shape
        q, k, v, c_kv, k_rope = att.mla_qkv(p["mixer"], cfg, h, positions)
        out = att.flash_attention(q, k, v, causal=meta.causal,
                                  kv_chunk=min(512, t))
        mix = linear(out.reshape(b, t, -1), p["mixer"]["wo"])
        if codec.quantized:
            cq, cs = codec.encode(c_kv)
            rq, rs = codec.encode(k_rope)
            cache = {"c": cq, "cs": cs, "r": rq, "rs": rs}
        else:
            cache = {"c": c_kv, "r": k_rope}
    elif meta.mixer == "mamba":
        mix, (conv_s, ssm_s) = ssm_lib.apply_mamba(p["mixer"], cfg, h,
                                                   return_state=True)
        cache = {"conv": conv_s, "ssm": ssm_s}
    elif meta.mixer == "cross":
        mix = att.apply_cross_attn(p["mixer"], cfg, h, media=media)
        cache = {"kv": att.cross_kv(p["mixer"], cfg, media)}
    x = ctx.constrain_act(x + mix)

    if meta.has_cross:
        h = rms_norm(_gather_seq(x, ctx), p["cross_norm"], cfg.norm_eps)
        x = x + att.apply_cross_attn(p["cross"], cfg, h, media=media)
        cache["cross_kv"] = att.cross_kv(p["cross"], cfg, media)

    if meta.ffn != "none":
        h = rms_norm(_gather_seq(x, ctx), p["ffn_norm"], cfg.norm_eps)
        if meta.ffn == "dense":
            y = apply_dense_ffn(p["ffn"], h)
        else:
            y, aux = _routed_moe(p["ffn"], cfg, h, ctx)
            if "shared" in p["ffn"]:
                b, t, d = h.shape
                y = y + apply_dense_ffn(p["ffn"]["shared"],
                                        h.reshape(b * t, d)).reshape(b, t, d)
        x = ctx.constrain_act(x + y)
    return x, aux, cache


def decode_block(p, cfg, meta: BlockMeta, x, cache, pos,
                 ctx: ParallelCtx = LOCAL):
    """One-token step. x: (B, 1, D). Returns (x, new_cache)."""
    b = x.shape[0]
    codec = att.kv_codec(cfg.kv_bits, cfg.kv_chunk)
    h = rms_norm(x, p["mixer_norm"], cfg.norm_eps)
    new_cache = dict(cache)
    if meta.mixer == "attn":
        q, k, v = att.gqa_qkv(p["mixer"], cfg, h, pos[None])
        if codec.quantized:
            # quantized cache: append the new token's codes+scales and
            # attend directly on the codes (flash_decode dequantizes tile
            # by tile in-register) — no fp copy of the cache, ever; the
            # old path's per-step full-cache kv_dequantize was 3x the
            # fundamental decode HBM traffic per layer per token
            kc, ks = codec.append(cache["k"], cache["ks"], k, pos)
            vc, vs = codec.append(cache["v"], cache["vs"], v, pos)
            new_cache.update(k=kc, ks=ks, v=vc, vs=vs)
            out = att.decode_attention_quantized(
                q, kc, ks, vc, vs, pos, kv_bits=codec.kv_bits,
                chunk=codec.chunk, ctx=ctx)
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, 1)
            out = att.decode_attention(q, k_cache, v_cache, pos)
            new_cache.update(k=k_cache, v=v_cache)
        mix = linear(out.reshape(b, 1, -1), p["mixer"]["wo"])
    elif meta.mixer == "mla":
        _, _, _, c_kv, k_rope = att.mla_qkv(p["mixer"], cfg, h, pos[None])
        if codec.quantized:
            cc, cs = codec.append(cache["c"], cache["cs"], c_kv, pos)
            rc, rs = codec.append(cache["r"], cache["rs"], k_rope, pos)
            mix = att.mla_decode(p["mixer"], cfg, h, cc, rc, pos, c_scale=cs,
                                 r_scale=rs, kv_bits=codec.kv_bits,
                                 chunk=codec.chunk, ctx=ctx)
            new_cache.update(c=cc, cs=cs, r=rc, rs=rs)
        else:
            c_cache = jax.lax.dynamic_update_slice_in_dim(cache["c"], c_kv,
                                                          pos, 1)
            r_cache = jax.lax.dynamic_update_slice_in_dim(cache["r"], k_rope,
                                                          pos, 1)
            mix = att.mla_decode(p["mixer"], cfg, h, c_cache, r_cache, pos)
            new_cache.update(c=c_cache, r=r_cache)
    elif meta.mixer == "mamba":
        mix, (conv_s, ssm_s) = ssm_lib.mamba_decode(
            p["mixer"], cfg, h, cache["conv"], cache["ssm"])
        new_cache.update(conv=conv_s, ssm=ssm_s)
    elif meta.mixer == "cross":
        mix = att.apply_cross_attn(p["mixer"], cfg, h, kv=cache["kv"])
    x = x + mix
    if meta.has_cross:
        h = rms_norm(x, p["cross_norm"], cfg.norm_eps)
        x = x + att.apply_cross_attn(p["cross"], cfg, h, kv=cache["cross_kv"])
    if meta.ffn != "none":
        h = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
        if meta.ffn == "dense":
            y = apply_dense_ffn(p["ffn"], h)
        else:
            y, _ = _routed_moe(p["ffn"], cfg, h, ctx)
            if "shared" in p["ffn"]:
                t = h.shape[1]
                y = y + apply_dense_ffn(
                    p["ffn"]["shared"], h.reshape(b * t, -1)
                ).reshape(b, t, -1)
        x = x + y
    return x, new_cache


def paged_decode_block(p, cfg, meta: BlockMeta, x, pools, page_tbl, pos,
                       active):
    """One-token step against block-paged quantized pools (serving engine).

    x: (B, 1, D) — one engine slot per row; pools: this block's shared
    code/scale pools (no batch axis — pages are the unit of allocation);
    page_tbl: (B, n_tiles) i32; pos: (B,) i32 per-slot positions; active:
    (B,) bool.  Per-slot rope positions and the per-slot position mask in
    the paged kernels are the only differences from :func:`decode_block` —
    the projection/append/attention math is shared, so a slot's output is
    bitwise the flat B=1 step at the same position.  Meshless by design
    (the engine owns the batch axis)."""
    b = x.shape[0]
    codec = att.kv_codec(cfg.kv_bits, cfg.kv_chunk)
    h = rms_norm(x, p["mixer_norm"], cfg.norm_eps)
    new_pools = dict(pools)
    pid = page_tbl[jnp.arange(b), (pos // codec.page_tokens).astype(jnp.int32)]
    if meta.mixer == "attn":
        q, k, v = att.gqa_qkv(p["mixer"], cfg, h, pos[:, None])
        kc, ks = att.kv_paged_append(codec, pools["k"], pools["ks"], k, pid,
                                     pos, active)
        vc, vs = att.kv_paged_append(codec, pools["v"], pools["vs"], v, pid,
                                     pos, active)
        new_pools.update(k=kc, ks=ks, v=vc, vs=vs)
        out = att.paged_decode_attention_quantized(
            q, kc, ks, vc, vs, page_tbl, pos, kv_bits=codec.kv_bits,
            chunk=codec.chunk)
        mix = linear(out.reshape(b, 1, -1), p["mixer"]["wo"])
    elif meta.mixer == "mla":
        _, _, _, c_kv, k_rope = att.mla_qkv(p["mixer"], cfg, h, pos[:, None])
        cc, cs = att.kv_paged_append(codec, pools["c"], pools["cs"], c_kv,
                                     pid, pos, active)
        rc, rs = att.kv_paged_append(codec, pools["r"], pools["rs"], k_rope,
                                     pid, pos, active)
        new_pools.update(c=cc, cs=cs, r=rc, rs=rs)
        mix = att.mla_decode_paged(p["mixer"], cfg, h, cc, cs, rc, rs,
                                   page_tbl, pos, kv_bits=codec.kv_bits,
                                   chunk=codec.chunk)
    else:
        raise NotImplementedError(
            f"paged decode supports attn/mla mixers, got {meta.mixer!r} — "
            "ssm/cross state is per-slot, not per-page; serve such models "
            "through the flat generate() path")
    x = x + mix
    if meta.ffn != "none":
        h = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
        if meta.ffn == "dense":
            y = apply_dense_ffn(p["ffn"], h)
        else:
            y, _ = _routed_moe(p["ffn"], cfg, h, LOCAL)
            if "shared" in p["ffn"]:
                t = h.shape[1]
                y = y + apply_dense_ffn(
                    p["ffn"]["shared"], h.reshape(b * t, -1)
                ).reshape(b, t, -1)
        x = x + y
    return x, new_pools


def pad_cache_entry(c, codec, s: int):
    """Zero-pad one block's sequence-indexed cache entries to length ``s``
    (codes) / ``scale_rows(s)`` (scales); everything else passes through.
    Shared by whole-prompt prefill (s = rounded prompt length) and chunked
    prefill (s = rounded chunk length) — zero rows match what the paged
    kernels mask out, and codes are padded *after* encoding real rows (a
    zero kv2 row would encode to code 2, not 0)."""
    def f(key, a):
        if key in ("k", "v", "c", "r"):
            tgt = s
        elif key in ("ks", "vs", "cs", "rs"):
            tgt = codec.scale_rows(s)
        else:
            return a
        pad = [(0, 0)] * a.ndim
        pad[1] = (0, tgt - a.shape[1])
        return jnp.pad(a, pad)
    return {k: (f(k, v) if not isinstance(v, (dict, tuple)) else v)
            for k, v in c.items()}


def ingest_block(p, cfg, meta: BlockMeta, x, buf, start, positions,
                 t_total: int):
    """One prompt chunk through one block against fp prefix buffers (exact
    chunked prefill).

    x: (1, L, D) chunk rows; buf: fp K/V buffers of full prompt length
    ``t_total`` (GQA: post-rope K/V; MLA: the *expanded* per-head K/V —
    flash_attention's operands); start: () i32 page-aligned chunk offset.
    The chunk's rows are sliced into the buffers, then flash_attention runs
    with ``q_offset=start`` and ``kv_chunk=min(512, t_total)`` — the same
    kv tiles, in the same order, under the same causal mask as the flat
    prefill's pass over the whole prompt, and every other op here is
    row-wise.  Hidden rows, cache codes and the final chunk's logits are
    therefore bitwise the whole-prompt prefill's.  Returns
    (x, new_buf, chunk_cache) with chunk_cache holding codes for the L
    chunk rows only."""
    codec = att.kv_codec(cfg.kv_bits, cfg.kv_chunk)
    h = rms_norm(x, p["mixer_norm"], cfg.norm_eps)
    b, t, _ = h.shape
    if meta.mixer == "attn":
        q, k, v = att.gqa_qkv(p["mixer"], cfg, h, positions)
        k_buf = jax.lax.dynamic_update_slice_in_dim(buf["k"], k, start, 1)
        v_buf = jax.lax.dynamic_update_slice_in_dim(buf["v"], v, start, 1)
        out = att.flash_attention(q, k_buf, v_buf, causal=True,
                                  kv_chunk=min(512, t_total), q_offset=start)
        mix = linear(out.reshape(b, t, -1), p["mixer"]["wo"])
        if codec.quantized:
            kq, ks = codec.encode(k)
            vq, vs = codec.encode(v)
            cache = {"k": kq, "ks": ks, "v": vq, "vs": vs}
        else:
            cache = {"k": k, "v": v}
        new_buf = {"k": k_buf, "v": v_buf}
    elif meta.mixer == "mla":
        q, k, v, c_kv, k_rope = att.mla_qkv(p["mixer"], cfg, h, positions)
        k_buf = jax.lax.dynamic_update_slice_in_dim(buf["k"], k, start, 1)
        v_buf = jax.lax.dynamic_update_slice_in_dim(buf["v"], v, start, 1)
        out = att.flash_attention(q, k_buf, v_buf, causal=True,
                                  kv_chunk=min(512, t_total), q_offset=start)
        mix = linear(out.reshape(b, t, -1), p["mixer"]["wo"])
        if codec.quantized:
            cq, cs = codec.encode(c_kv)
            rq, rs = codec.encode(k_rope)
            cache = {"c": cq, "cs": cs, "r": rq, "rs": rs}
        else:
            cache = {"c": c_kv, "r": k_rope}
        new_buf = {"k": k_buf, "v": v_buf}
    else:
        raise NotImplementedError(
            f"chunked prefill supports attn/mla mixers, got {meta.mixer!r} — "
            "ssm/cross state is sequential, not per-page; serve such models "
            "through the flat generate() path")
    x = x + mix
    if meta.ffn != "none":
        h = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
        if meta.ffn == "dense":
            y = apply_dense_ffn(p["ffn"], h)
        else:
            y, _ = _routed_moe(p["ffn"], cfg, h, LOCAL)
            if "shared" in p["ffn"]:
                t = h.shape[1]
                y = y + apply_dense_ffn(
                    p["ffn"]["shared"], h.reshape(b * t, -1)
                ).reshape(b, t, -1)
        x = x + y
    return x, new_buf, cache


def paged_extend_block(p, cfg, meta: BlockMeta, x, pools, tbl, start,
                       positions):
    """One prompt chunk through one block against the request's quantized
    pages (opt-in "paged" chunked prefill).

    No fp prefix buffer exists: earlier chunks are read back as codes
    through the paged extend kernels (in-register dequant, same tile math
    as paged decode), the chunk's own rows attend causally in fp.
    HBM-cheap — the only per-request state is the pages themselves — but
    *lossy* versus the flat prefill, since past keys have already been
    through the codec.  tbl: (n_past,) i32 pages of the already-ingested
    chunks.  Returns (x, chunk_cache)."""
    codec = att.kv_codec(cfg.kv_bits, cfg.kv_chunk)
    h = rms_norm(x, p["mixer_norm"], cfg.norm_eps)
    b, t, _ = h.shape
    if meta.mixer == "attn":
        q, k, v = att.gqa_qkv(p["mixer"], cfg, h, positions)
        out = att.paged_extend_attention_quantized(
            q, k, v, pools["k"], pools["ks"], pools["v"], pools["vs"], tbl,
            start, kv_bits=codec.kv_bits, chunk=codec.chunk)
        mix = linear(out.reshape(b, t, -1), p["mixer"]["wo"])
        kq, ks = codec.encode(k)
        vq, vs = codec.encode(v)
        cache = {"k": kq, "ks": ks, "v": vq, "vs": vs}
    elif meta.mixer == "mla":
        _, _, _, c_kv, k_rope = att.mla_qkv(p["mixer"], cfg, h, positions)
        mix = att.mla_extend_paged(
            p["mixer"], cfg, h, c_kv, k_rope, pools, tbl, start, positions,
            kv_bits=codec.kv_bits, chunk=codec.chunk)
        cq, cs = codec.encode(c_kv)
        rq, rs = codec.encode(k_rope)
        cache = {"c": cq, "cs": cs, "r": rq, "rs": rs}
    else:
        raise NotImplementedError(
            f"chunked prefill supports attn/mla mixers, got {meta.mixer!r} — "
            "ssm/cross state is sequential, not per-page; serve such models "
            "through the flat generate() path")
    x = x + mix
    if meta.ffn != "none":
        h = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
        if meta.ffn == "dense":
            y = apply_dense_ffn(p["ffn"], h)
        else:
            y, _ = _routed_moe(p["ffn"], cfg, h, LOCAL)
            if "shared" in p["ffn"]:
                t = h.shape[1]
                y = y + apply_dense_ffn(
                    p["ffn"]["shared"], h.reshape(b * t, -1)
                ).reshape(b, t, -1)
        x = x + y
    return x, cache


def capture_block(p, cfg, meta: BlockMeta, x, *, positions=None, media=None):
    """Calibration forward of one block for the RSQ pipeline.

    Returns (y, caps, domains, colsum):
      caps     — weight path -> input matrix X (stream shapes (B, T, d_in);
                 expert entries (E, C, d))
      domains  — weight path -> "stream" | "media" | "expert" | "hidden"
                 ("stream"/"hidden" rows are token-aligned and get scaled by
                 R; "media" rows are media tokens; "expert" buffers carry
                 their own slot->token map in caps["__moe_slot_token"])
      colsum   — (B, T) attention-concentration scores or None
    """
    if positions is None:
        positions = jnp.arange(x.shape[1])
    caps: dict[str, Any] = {}
    dom: dict[str, str] = {}
    colsum = None
    b, t, _ = x.shape
    h = rms_norm(x, p["mixer_norm"], cfg.norm_eps)
    if meta.mixer == "attn":
        q, k, v = att.gqa_qkv(p["mixer"], cfg, h, positions)
        out, colsum = att.flash_attention(q, k, v, causal=meta.causal,
                                          kv_chunk=min(512, t), colsum=True)
        attn_out = out.reshape(b, t, -1)
        mix = linear(attn_out, p["mixer"]["wo"])
        caps.update({"mixer/wq": h, "mixer/wk": h, "mixer/wv": h,
                     "mixer/wo": attn_out})
        dom.update({k_: "stream" for k_ in
                    ("mixer/wq", "mixer/wk", "mixer/wv", "mixer/wo")})
    elif meta.mixer == "mla":
        pm = p["mixer"]
        dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        kvr = cfg.kv_lora_rank
        if "wq_a" in pm:
            ql = rms_norm(linear(h, pm["wq_a"]), pm["q_norm"], cfg.norm_eps)
            q = linear(ql, pm["wq_b"]).reshape(b, t, cfg.n_heads, dn + dr)
            caps.update({"mixer/wq_a": h, "mixer/wq_b": ql})
            dom.update({"mixer/wq_a": "stream", "mixer/wq_b": "stream"})
        else:
            q = linear(h, pm["wq"]).reshape(b, t, cfg.n_heads, dn + dr)
            caps["mixer/wq"] = h
            dom["mixer/wq"] = "stream"
        from repro.models.layers import apply_rope
        q_nope, q_rope = q[..., :dn], q[..., dn:]
        q = jnp.concatenate(
            [q_nope, apply_rope(q_rope, positions, cfg.rope_theta)], axis=-1)
        kv = linear(h, pm["wkv_a"])
        c_kv = rms_norm(kv[..., :kvr], pm["kv_norm"], cfg.norm_eps)
        k_rope = apply_rope(kv[..., None, kvr:], positions, cfg.rope_theta)
        kvb = linear(c_kv, pm["wkv_b"]).reshape(b, t, cfg.n_heads, dn + dv)
        k = jnp.concatenate(
            [kvb[..., :dn],
             jnp.broadcast_to(k_rope, (b, t, cfg.n_heads, dr))], axis=-1)
        out, colsum = att.flash_attention(q, k, kvb[..., dn:],
                                          causal=meta.causal,
                                          kv_chunk=min(512, t), colsum=True)
        ctx_out = out.reshape(b, t, -1)
        mix = linear(ctx_out, pm["wo"])
        caps.update({"mixer/wkv_a": h, "mixer/wkv_b": c_kv,
                     "mixer/wo": ctx_out})
        dom.update({"mixer/wkv_a": "stream", "mixer/wkv_b": "stream",
                    "mixer/wo": "stream"})
    elif meta.mixer == "mamba":
        mix, m_caps = ssm_lib.capture_mamba(p["mixer"], cfg, h)
        caps.update({f"mixer/{k_}": v_ for k_, v_ in m_caps.items()})
        dom.update({f"mixer/{k_}": "stream" for k_ in m_caps})
    elif meta.mixer == "cross":
        pm = p["mixer"]
        q = linear(h, pm["wq"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
        kv = att.cross_kv(pm, cfg, media)
        out = att.flash_attention(q, *kv, causal=False,
                                  kv_chunk=min(512, kv[0].shape[1]))
        attn_out = out.reshape(b, t, -1)
        mix = linear(attn_out, pm["wo"])
        caps.update({"mixer/wq": h, "mixer/wk": media, "mixer/wv": media,
                     "mixer/wo": attn_out})
        dom.update({"mixer/wq": "stream", "mixer/wk": "media",
                    "mixer/wv": "media", "mixer/wo": "stream"})
    x = x + mix

    if meta.has_cross:
        h2 = rms_norm(x, p["cross_norm"], cfg.norm_eps)
        pc = p["cross"]
        q = linear(h2, pc["wq"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
        kv = att.cross_kv(pc, cfg, media)
        out = att.flash_attention(q, *kv, causal=False,
                                  kv_chunk=min(512, kv[0].shape[1]))
        attn_out = out.reshape(b, t, -1)
        x = x + linear(attn_out, pc["wo"])
        caps.update({"cross/wq": h2, "cross/wk": media, "cross/wv": media,
                     "cross/wo": attn_out})
        dom.update({"cross/wq": "stream", "cross/wk": "media",
                    "cross/wv": "media", "cross/wo": "stream"})

    if meta.ffn != "none":
        hf = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
        if meta.ffn == "dense":
            y, f_caps = capture_dense_ffn(p["ffn"], hf)
            caps.update({f"ffn/{k_}": v_ for k_, v_ in f_caps.items()})
            dom.update({f"ffn/{k_}": ("hidden" if k_ == "wd" else "stream")
                        for k_ in f_caps})
        else:
            y, _aux, m_caps = moe_lib.capture_moe(p["ffn"], cfg, hf)
            for k_, v_ in m_caps.items():
                if k_.startswith("experts/"):
                    caps[f"ffn/{k_}"] = v_
                    dom[f"ffn/{k_}"] = "expert"
                elif k_ == "__slot_token":
                    caps["ffn/__moe_slot_token"] = v_
                else:  # shared expert
                    caps[f"ffn/{k_}"] = v_
                    dom[f"ffn/{k_}"] = ("hidden" if k_.endswith("wd")
                                        else "stream")
        x = x + y
    return x, caps, dom, colsum


# --------------------------------------------------------------- full stacks


def _group_metas(metas, period):
    groups = [metas[i : i + period] for i in range(0, len(metas), period)]
    assert all(g == groups[0] for g in groups), "layer pattern must repeat"
    return groups[0]


class Model:
    """Functional model wrapper for one ModelConfig."""

    def __init__(self, cfg: ModelConfig, ctx: ParallelCtx = LOCAL):
        if cfg.kv_bits not in (0, 2, 8):
            raise ValueError(
                f"kv_bits={cfg.kv_bits} is not supported — use 0 (KV cache "
                "in the activation dtype), 8 (int8 codes + per-token-head "
                "scales) or 2 (packed log codes + per-chunk scales)")
        self.cfg = cfg
        self.ctx = ctx
        self.dtype = jnp.dtype(cfg.dtype)
        # one codec instance owns every rounding/layout decision — flat
        # cache, paged pools and serve-time capacity math all derive from
        # it, so they cannot drift
        self.codec = att.kv_codec(cfg.kv_bits, cfg.kv_chunk)
        metas = decoder_metas(cfg)
        self.prefix_metas = metas[: cfg.first_dense_layers]
        body = metas[cfg.first_dense_layers :]
        self.period = cfg.scan_period
        assert len(body) % self.period == 0, (len(body), self.period)
        self.n_groups = len(body) // self.period
        self.group_metas = _group_metas(body, self.period)
        self.enc_metas = encoder_metas(cfg) if cfg.family == "encdec" else ()

    # ------------------------------------------------------------------ init
    def init(self, key) -> dict:
        cfg, dtype = self.cfg, self.dtype
        keys = jax.random.split(key, 8)
        params: dict[str, Any] = {
            "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dtype),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
        if not cfg.tie_embeddings:
            params["head"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size,
                                        dtype)
        if self.prefix_metas:
            pkeys = jax.random.split(keys[2], len(self.prefix_metas))
            params["prefix"] = [
                init_block(k, cfg, m, dtype)
                for k, m in zip(pkeys, self.prefix_metas)
            ]

        def init_group(k):
            ks = jax.random.split(k, self.period)
            return {
                f"b{i}": init_block(ks[i], cfg, self.group_metas[i], dtype)
                for i in range(self.period)
            }

        gkeys = jax.random.split(keys[3], self.n_groups)
        params["groups"] = jax.vmap(init_group)(gkeys)

        if self.enc_metas:
            def init_enc_group(k):
                return {"b0": init_block(k, cfg, self.enc_metas[0], dtype)}

            ekeys = jax.random.split(keys[4], len(self.enc_metas))
            params["encoder"] = {
                "groups": jax.vmap(init_enc_group)(ekeys),
                "final_norm": jnp.ones((cfg.d_model,), dtype),
            }
        return params

    def param_shapes(self):
        return jax.eval_shape(self.init, jax.random.key(0))

    def param_specs(self):
        return param_specs(self.param_shapes(), self.ctx)

    # --------------------------------------------------------------- encoder
    def _encode(self, params, frames):
        cfg, ctx = self.cfg, self.ctx
        if "frame_proj" in params:
            # rotation folded into the (stubbed) conv frontend's output
            # projection — see core/rotation.rotate_model
            frames = frames @ params["frame_proj"].astype(frames.dtype)
        t = frames.shape[1]
        positions = jnp.arange(t)

        def body(x, gp):
            x, _, _ = apply_block(gp["b0"], cfg, self.enc_metas[0], x,
                                  positions=positions, ctx=ctx)
            return x, None

        x, _ = jax.lax.scan(jax.checkpoint(body), frames,
                            params["encoder"]["groups"])
        return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)

    # --------------------------------------------------------------- forward
    def hidden_states(self, params, tokens, *, media=None, frames=None):
        """(B, T) tokens -> (B, T, D) final hidden states (post final norm)."""
        cfg, ctx = self.cfg, self.ctx
        x = embed_lookup(params["embed"], tokens).astype(self.dtype)
        x = ctx.constrain_act(x)
        t = tokens.shape[1]
        positions = jnp.arange(t)
        if cfg.family == "encdec":
            media = self._encode(params, frames)
        aux = jnp.zeros((), jnp.float32)
        for p_blk, meta in zip(params.get("prefix", []), self.prefix_metas):
            x, a, _ = apply_block(p_blk, cfg, meta, x, positions=positions,
                                  media=media, ctx=ctx)
            aux = aux + a

        def body(carry, gp):
            x, aux = carry
            for i in range(self.period):
                x, a, _ = apply_block(gp[f"b{i}"], cfg, self.group_metas[i], x,
                                      positions=positions, media=media, ctx=ctx)
                aux = aux + a
            return (x, aux), None

        body_fn = jax.checkpoint(body) if cfg.remat != "none" else body
        (x, aux), _ = jax.lax.scan(body_fn, (x, aux), params["groups"])
        return rms_norm(x, params["final_norm"], cfg.norm_eps), aux

    def head_weight(self, params):
        # rotation/quantization may untie the head (norm-γ fusion breaks the
        # tie), in which case an explicit "head" entry takes precedence
        if "head" in params:
            return params["head"]
        return params["embed"].T

    def head_logits(self, params, x: jax.Array) -> jax.Array:
        """(..., D) hidden states -> (..., V) fp32 logits.

        Tied-embedding models contract the (V, D) table over D directly
        instead of going through ``head_weight``'s ``embed.T``: with the
        whole generation fused into one ``lax.scan`` device program
        (launch.serve), a materialized (D, V) transpose would sit *inside*
        the per-token loop body — at real vocab sizes that is a
        full-table-sized copy per generated token."""
        if "head" in params:
            return linear(x, params["head"]).astype(jnp.float32)
        return jax.lax.dot_general(
            x, params["embed"].astype(x.dtype),
            (((x.ndim - 1,), (1,)), ((), ()))).astype(jnp.float32)

    def loss(self, params, batch) -> jax.Array:
        """batch: {"tokens", "labels", opt "media"/"frames"} -> scalar loss."""
        x, aux = self.hidden_states(params, batch["tokens"],
                                    media=batch.get("media"),
                                    frames=batch.get("frames"))
        ce = cross_entropy_chunked(x, self.head_weight(params),
                                   batch["labels"])
        return ce + 0.01 * aux

    def logits(self, params, tokens, **kw) -> jax.Array:
        x, _ = self.hidden_states(params, tokens, **kw)
        return self.head_logits(params, x)

    def _cache_len(self, s: int) -> int:
        """Allocated cache length — the codec's ``round_len``: quantized
        caches round up to a ``kv_chunk`` multiple so flash_decode always
        has an aligned sequence tile (scale rows stay whole; the tail is
        position-masked).  Pages use the same rounding (page = kv_chunk),
        so flat-cache and page-capacity math share one source of truth."""
        return self.codec.round_len(s)

    # --------------------------------------------------------------- prefill
    def prefill(self, params, tokens, *, media=None, frames=None,
                cache_len: Optional[int] = None, logits: bool = True):
        """Returns (last_logits (B, V), cache). Cache length ``cache_len``
        (defaults to T; quantized caches round up to a kv_chunk multiple).

        ``logits=False`` is the resume-ingest entry for the serve engine's
        preemption path: re-admitting a preempted request replays the
        prompt through this exact prefill to rebuild its KV pages bitwise,
        but its token 0 was already drawn before preemption — skipping the
        head projection drops the one vocab-sized matmul the resume would
        otherwise waste (returns ``(None, cache)``)."""
        cfg, ctx = self.cfg, self.ctx
        b, t = tokens.shape
        s = self._cache_len(cache_len or t)
        x = embed_lookup(params["embed"], tokens).astype(self.dtype)
        x = ctx.constrain_act(x)
        positions = jnp.arange(t)
        if cfg.family == "encdec":
            media = self._encode(params, frames)

        def pad_entry(c):
            # only sequence-indexed entries (self-attn KV, MLA latents) grow;
            # quantized caches also carry scale rows — the codec's
            # ``scale_rows`` (s is already a chunk multiple)
            return pad_cache_entry(c, self.codec, s)

        caches_prefix = []
        for p_blk, meta in zip(params.get("prefix", []), self.prefix_metas):
            x, _, c = apply_block(p_blk, cfg, meta, x, positions=positions,
                                  media=media, ctx=ctx)
            caches_prefix.append(pad_entry(c))

        def body(x, gp):
            caches = {}
            for i in range(self.period):
                x, _, c = apply_block(gp[f"b{i}"], cfg, self.group_metas[i], x,
                                      positions=positions, media=media, ctx=ctx)
                caches[f"b{i}"] = pad_entry(c)
            return x, caches

        x, group_caches = jax.lax.scan(body, x, params["groups"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        out = self.head_logits(params, x[:, -1]) if logits else None
        cache = {"groups": group_caches}
        if caches_prefix:
            cache["prefix"] = caches_prefix
        if cfg.family == "encdec":
            cache["media"] = media
        return out, cache

    def init_cache(self, batch: int, cache_len: int, *, media=None):
        """Zero cache for pure-decode lowering (decode_32k / long_500k)."""
        cfg = self.cfg
        kvh, dh = cfg.n_kv_heads, cfg.head_dim
        dt = self.dtype
        cache_len = self._cache_len(cache_len)
        codec = self.codec

        def qkv_entry(d: int):
            """(codes, scales) zero pair for one quantized cache tensor of
            feature width d (head axes supplied by the caller) — widths,
            dtypes and scale-row counts all come from the codec layout."""
            return (((cache_len, codec.code_cols(d)), codec.code_dtype),
                    ((codec.scale_rows(cache_len),), codec.scale_dtype))

        def entry(meta: BlockMeta):
            c = {}
            if meta.mixer == "attn":
                if cfg.kv_bits in (8, 2):
                    (cd, cdt), (sd, sdt) = qkv_entry(dh)
                    codes = jnp.zeros((batch, cd[0], kvh) + cd[1:], cdt)
                    scales = jnp.zeros((batch, sd[0], kvh), sdt)
                    c = {"k": codes, "v": codes, "ks": scales, "vs": scales}
                else:
                    c = {"k": jnp.zeros((batch, cache_len, kvh, dh), dt),
                         "v": jnp.zeros((batch, cache_len, kvh, dh), dt)}
            elif meta.mixer == "mla":
                if cfg.kv_bits in (8, 2):
                    (cd, cdt), (sd, sdt) = qkv_entry(cfg.kv_lora_rank)
                    (rd, rdt), _ = qkv_entry(cfg.qk_rope_dim)
                    c = {"c": jnp.zeros((batch,) + cd, cdt),
                         "cs": jnp.zeros((batch,) + sd, sdt),
                         "r": jnp.zeros((batch,) + rd, rdt),
                         "rs": jnp.zeros((batch,) + sd, sdt)}
                else:
                    c = {"c": jnp.zeros((batch, cache_len, cfg.kv_lora_rank),
                                        dt),
                         "r": jnp.zeros((batch, cache_len, cfg.qk_rope_dim),
                                        dt)}
            elif meta.mixer == "mamba":
                c = {"conv": jnp.zeros(
                        (batch, cfg.ssm_conv_width - 1,
                         cfg.d_inner + 2 * cfg.ssm_d_state), dt),
                     "ssm": jnp.zeros(
                        (batch, cfg.ssm_n_heads, cfg.ssm_head_dim,
                         cfg.ssm_d_state), jnp.float32)}
            elif meta.mixer == "cross":
                tm = media.shape[1]
                c = {"kv": (jnp.zeros((batch, tm, kvh, dh), dt),
                            jnp.zeros((batch, tm, kvh, dh), dt))}
            if meta.has_cross:
                tm = media.shape[1]
                c["cross_kv"] = (jnp.zeros((batch, tm, kvh, dh), dt),
                                 jnp.zeros((batch, tm, kvh, dh), dt))
            return c

        def stack(e):
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a, (self.n_groups,) + a.shape), e)

        cache = {"groups": {f"b{i}": stack(entry(self.group_metas[i]))
                            for i in range(self.period)}}
        if self.prefix_metas:
            cache["prefix"] = [entry(m) for m in self.prefix_metas]
        return cache

    # ---------------------------------------------------------------- decode
    def decode_step(self, params, cache, token, pos):
        """token: (B, 1) int32; pos: () int32. Returns (logits (B, V), cache)."""
        cfg, ctx = self.cfg, self.ctx
        x = embed_lookup(params["embed"], token).astype(self.dtype)
        x = ctx.constrain(x, "dp", None, None)
        new_cache = dict(cache)
        if "prefix" in cache:
            new_prefix = []
            for p_blk, meta, c in zip(params["prefix"], self.prefix_metas,
                                      cache["prefix"]):
                x, c2 = decode_block(p_blk, cfg, meta, x, c, pos, ctx=ctx)
                new_prefix.append(c2)
            new_cache["prefix"] = new_prefix

        def body(x, xs):
            gp, gc = xs
            new_gc = {}
            for i in range(self.period):
                x, c2 = decode_block(gp[f"b{i}"], cfg, self.group_metas[i], x,
                                     gc[f"b{i}"], pos, ctx=ctx)
                new_gc[f"b{i}"] = c2
            return x, new_gc

        x, new_groups = jax.lax.scan(body, x, (params["groups"],
                                               cache["groups"]))
        new_cache["groups"] = new_groups
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self.head_logits(params, x[:, 0])
        return logits, new_cache

    def paged_decode_step(self, params, pools, page_tbl, token, pos, active):
        """One decode step for every engine slot against paged pools.

        token: (B, 1) int32; page_tbl: (B, n_tiles) int32; pos/active:
        (B,) per-slot positions and liveness.  Returns
        (logits (B, V), pools) — the same group-scan schedule as
        :func:`decode_step`, with the ONE page table shared by every
        layer (all layers of a request occupy the same logical tiles;
        each layer owns its pools)."""
        cfg = self.cfg
        x = embed_lookup(params["embed"], token).astype(self.dtype)
        new_pools = dict(pools)
        if "prefix" in pools:
            new_prefix = []
            for p_blk, meta, c in zip(params["prefix"], self.prefix_metas,
                                      pools["prefix"]):
                x, c2 = paged_decode_block(p_blk, cfg, meta, x, c, page_tbl,
                                           pos, active)
                new_prefix.append(c2)
            new_pools["prefix"] = new_prefix

        def body(x, xs):
            gp, gc = xs
            new_gc = {}
            for i in range(self.period):
                x, c2 = paged_decode_block(gp[f"b{i}"], cfg,
                                           self.group_metas[i], x,
                                           gc[f"b{i}"], page_tbl, pos, active)
                new_gc[f"b{i}"] = c2
            return x, new_gc

        x, new_groups = jax.lax.scan(body, x, (params["groups"],
                                               pools["groups"]))
        new_pools["groups"] = new_groups
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self.head_logits(params, x[:, 0])
        return logits, new_pools

    # ------------------------------------------------------- chunked prefill
    def init_ingest(self, t_total: int):
        """Transient fp prefix buffers for exact chunked prefill of ONE
        request of prompt length ``t_total``.

        GQA blocks keep the post-rope K/V rows, MLA blocks the *expanded*
        per-head K/V (flash_attention's operands), so each chunk's
        attention replays the flat prefill bitwise — see
        :func:`ingest_block`.  The buffers live only while the request is
        ingesting; the steady-state cache representation stays quantized
        pages."""
        cfg = self.cfg
        dt = self.dtype
        kvh, dh = cfg.n_kv_heads, cfg.head_dim

        def entry(meta: BlockMeta):
            if meta.mixer == "attn":
                return {"k": jnp.zeros((1, t_total, kvh, dh), dt),
                        "v": jnp.zeros((1, t_total, kvh, dh), dt)}
            if meta.mixer == "mla":
                dq = cfg.qk_nope_dim + cfg.qk_rope_dim
                return {"k": jnp.zeros((1, t_total, cfg.n_heads, dq), dt),
                        "v": jnp.zeros((1, t_total, cfg.n_heads,
                                        cfg.v_head_dim), dt)}
            raise NotImplementedError(
                f"chunked prefill supports attn/mla mixers, got "
                f"{meta.mixer!r}")

        def stack(e):
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a, (self.n_groups,) + a.shape), e)

        state = {"groups": {f"b{i}": stack(entry(self.group_metas[i]))
                            for i in range(self.period)}}
        if self.prefix_metas:
            state["prefix"] = [entry(m) for m in self.prefix_metas]
        return state

    def paged_extend_step(self, params, tokens, start, state, *,
                          t_total: int, last: bool, pools=None,
                          page_tbl=None):
        """Ingest one page-aligned prompt chunk of one request.

        tokens: (1, L) i32 chunk tokens; start: () i32 chunk offset
        (multiple of the page size); ``state``: fp prefix buffers from
        :meth:`init_ingest` (exact mode) — or None with ``pools`` +
        ``page_tbl`` (the request's already-written pages, (n_past,) i32)
        for the opt-in paged mode that attends earlier chunks' quantized
        pages through the extend kernels.  Returns
        (logits, new_state, chunk_cache): logits (1, V) when ``last`` else
        None — the same draw whole-prompt prefill would produce; and
        chunk_cache in prefill-cache layout, padded to a page multiple,
        ready for ``PagedPools.write_prefill`` on the chunk's pages."""
        cfg = self.cfg
        _, L = tokens.shape
        s_pad = self._cache_len(L)
        x = embed_lookup(params["embed"], tokens).astype(self.dtype)
        positions = start + jnp.arange(L)
        exact = state is not None

        caches_prefix = []
        new_prefix = []
        for p_blk, meta, c in zip(params.get("prefix", []),
                                  self.prefix_metas,
                                  (state or pools).get("prefix", [])):
            if exact:
                x, nb, cc = ingest_block(p_blk, cfg, meta, x, c, start,
                                         positions, t_total)
                new_prefix.append(nb)
            else:
                x, cc = paged_extend_block(p_blk, cfg, meta, x, c, page_tbl,
                                           start, positions)
            caches_prefix.append(pad_cache_entry(cc, self.codec, s_pad))

        if exact:
            def body(x, xs):
                gp, gb = xs
                new_gb, caches = {}, {}
                for i in range(self.period):
                    x, nb, cc = ingest_block(gp[f"b{i}"], cfg,
                                             self.group_metas[i], x,
                                             gb[f"b{i}"], start, positions,
                                             t_total)
                    new_gb[f"b{i}"] = nb
                    caches[f"b{i}"] = pad_cache_entry(cc, self.codec, s_pad)
                return x, (new_gb, caches)

            x, (new_groups, group_caches) = jax.lax.scan(
                body, x, (params["groups"], state["groups"]))
            new_state = {"groups": new_groups}
            if new_prefix:
                new_state["prefix"] = new_prefix
        else:
            def body(x, xs):
                gp, gpools = xs
                caches = {}
                for i in range(self.period):
                    x, cc = paged_extend_block(gp[f"b{i}"], cfg,
                                               self.group_metas[i], x,
                                               gpools[f"b{i}"], page_tbl,
                                               start, positions)
                    caches[f"b{i}"] = pad_cache_entry(cc, self.codec, s_pad)
                return x, caches

            x, group_caches = jax.lax.scan(
                body, x, (params["groups"], pools["groups"]))
            new_state = None

        chunk_cache = {"groups": group_caches}
        if caches_prefix:
            chunk_cache["prefix"] = caches_prefix
        logits = None
        if last:
            x = rms_norm(x, params["final_norm"], cfg.norm_eps)
            logits = self.head_logits(params, x[:, -1])
        return logits, new_state, chunk_cache


def build_model(cfg: ModelConfig, ctx: ParallelCtx = LOCAL) -> Model:
    return Model(cfg, ctx)
