"""Mamba-2 (SSD, state-space duality) mixer — chunked matmul formulation.

TPU adaptation: the intra-chunk quadratic term and inter-chunk state
recurrence are expressed as dense einsums (MXU-friendly) inside a
``lax.scan`` over chunks — the (chunk × chunk) decay matrix only ever exists
for one chunk at a time, so memory is O(T · d) like the Triton kernel,
without the Triton kernel.

Projections are split so that tensor-parallel sharding is natural:
``wzx`` (z and x branches, column-parallel), ``wdt`` (per-head dt,
column-parallel with heads), ``wbc`` (shared B/C, replicated — groups = 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, linear, rms_norm


def init_mamba(key, cfg, dtype):
    d, di, st, nh = cfg.d_model, cfg.d_inner, cfg.ssm_d_state, cfg.ssm_n_heads
    w = cfg.ssm_conv_width
    keys = jax.random.split(key, 6)
    return {
        "wzx": dense_init(keys[0], d, 2 * di, dtype),
        "wbc": dense_init(keys[1], d, 2 * st, dtype),
        "wdt": dense_init(keys[2], d, nh, dtype),
        "conv_x": (jax.random.normal(keys[3], (w, di), jnp.float32) * 0.1).astype(dtype),
        "conv_bc": (jax.random.normal(keys[4], (w, 2 * st), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di + 2 * st,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(keys[5], di, d, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B, T, C), w (W, C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    t = x.shape[1]
    out = b
    for i in range(width):
        out = out + xp[:, i : i + t] * w[i]
    return out


def _ssd_scan(x, dt, B, C, A, chunk: int):
    """Chunked SSD. x: (B, T, nh, hd); dt: (B, T, nh); B/C: (B, T, st).

    Returns y: (B, T, nh, hd) and final state (B, nh, hd, st)."""
    b, t, nh, hd = x.shape
    st = B.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0, f"T={t} not divisible by chunk={chunk}"
    nc = t // chunk

    log_a = dt * A  # (B, T, nh), negative
    xdt = (x * dt[..., None]).astype(jnp.float32)

    def to_chunks(a):
        return a.reshape(b, nc, chunk, *a.shape[2:]).swapaxes(0, 1)

    xs = (to_chunks(xdt), to_chunks(log_a),
          to_chunks(B.astype(jnp.float32)), to_chunks(C.astype(jnp.float32)))

    def body(h, args):
        x_c, la_c, b_c, c_c = args  # (B, cl, ...)
        cs = jnp.cumsum(la_c, axis=1)  # (B, cl, nh)
        # intra-chunk decay matrix L[l, s] = exp(cs_l - cs_s), l >= s
        diff = cs[:, :, None, :] - cs[:, None, :, :]  # (B, l, s, nh)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        L = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bln,bsn->bls", c_c, b_c)  # shared across heads
        y_diag = jnp.einsum("bls,blsh,bshp->blhp", scores, L, x_c)
        # contribution of the carried state
        decay_out = jnp.exp(cs)  # (B, cl, nh)
        y_off = jnp.einsum("bln,bhpn->blhp", c_c, h) * decay_out[..., None]
        # new carried state
        chunk_end = cs[:, -1, :]  # (B, nh)
        decay_in = jnp.exp(chunk_end[:, None, :] - cs)  # (B, cl, nh)
        s_c = jnp.einsum("bln,blh,blhp->bhpn", b_c, decay_in, x_c)
        h_new = jnp.exp(chunk_end)[..., None, None] * h + s_c
        return h_new, y_diag + y_off

    h0 = jnp.zeros((b, nh, hd, st), jnp.float32)
    h_final, ys = jax.lax.scan(jax.checkpoint(body), h0, xs)
    y = ys.swapaxes(0, 1).reshape(b, t, nh, hd)
    return y, h_final


def apply_mamba(p, cfg, x, *, return_state: bool = False):
    """x: (B, T, D) -> (B, T, D). Optionally returns (conv_state, ssm_state)."""
    b, t, _ = x.shape
    di, st, nh, hd = cfg.d_inner, cfg.ssm_d_state, cfg.ssm_n_heads, cfg.ssm_head_dim
    zx = linear(x, p["wzx"])
    z, xin = zx[..., :di], zx[..., di:]
    bc = linear(x, p["wbc"])
    dt_raw = linear(x, p["wdt"]).astype(jnp.float32)

    xbc = jnp.concatenate([xin, bc], axis=-1)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_bc"]], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, conv_w, p["conv_b"]))
    xc, Bc, Cc = xbc[..., :di], xbc[..., di : di + st], xbc[..., di + st :]

    dt = jax.nn.softplus(dt_raw + p["dt_bias"])  # (B, T, nh)
    A = -jnp.exp(p["A_log"])  # (nh,)
    xh = xc.reshape(b, t, nh, hd)
    y, h_final = _ssd_scan(xh, dt, Bc, Cc, A, cfg.ssm_chunk)
    y = y + p["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(b, t, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = linear(y, p["out_proj"])
    if return_state:
        w = cfg.ssm_conv_width
        pre_act = jnp.concatenate([xin, bc], axis=-1)
        conv_state = pre_act[:, t - (w - 1):, :] if t >= w - 1 else jnp.pad(
            pre_act, ((0, 0), (w - 1 - t, 0), (0, 0)))
        return out, (conv_state, h_final)
    return out


def capture_mamba(p, cfg, x):
    """Forward returning the per-weight calibration inputs RSQ needs:
    wzx/wbc/wdt see the (normed) stream; out_proj sees the gated output."""
    b, t, _ = x.shape
    di, st, nh, hd = cfg.d_inner, cfg.ssm_d_state, cfg.ssm_n_heads, cfg.ssm_head_dim
    zx = linear(x, p["wzx"])
    z, xin = zx[..., :di], zx[..., di:]
    bc = linear(x, p["wbc"])
    dt_raw = linear(x, p["wdt"]).astype(jnp.float32)
    xbc = jnp.concatenate([xin, bc], axis=-1)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_bc"]], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, conv_w, p["conv_b"]))
    xc, Bc, Cc = xbc[..., :di], xbc[..., di : di + st], xbc[..., di + st :]
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xc.reshape(b, t, nh, hd)
    y, _ = _ssd_scan(xh, dt, Bc, Cc, A, cfg.ssm_chunk)
    y = y + p["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(b, t, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = linear(y, p["out_proj"])
    caps = {"wzx": x, "wbc": x, "wdt": x, "out_proj": y}
    return out, caps


def mamba_decode(p, cfg, x, conv_state, ssm_state):
    """Single-token step. x: (B, 1, D); conv_state: (B, W-1, di+2st);
    ssm_state: (B, nh, hd, st)."""
    b = x.shape[0]
    di, st, nh, hd = cfg.d_inner, cfg.ssm_d_state, cfg.ssm_n_heads, cfg.ssm_head_dim
    w = cfg.ssm_conv_width
    zx = linear(x, p["wzx"])
    z, xin = zx[..., :di], zx[..., di:]
    bc = linear(x, p["wbc"])
    dt_raw = linear(x, p["wdt"]).astype(jnp.float32)[:, 0]  # (B, nh)

    xbc_t = jnp.concatenate([xin, bc], axis=-1)[:, 0]  # (B, di+2st)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_bc"]], axis=-1)  # (W, C)
    window = jnp.concatenate([conv_state, xbc_t[:, None]], axis=1)  # (B, W, C)
    conv_out = jnp.einsum("bwc,wc->bc", window, conv_w) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    xc, Bc, Cc = (conv_out[:, :di], conv_out[:, di : di + st],
                  conv_out[:, di + st :])

    dt = jax.nn.softplus(dt_raw + p["dt_bias"])  # (B, nh)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)  # (B, nh)
    xh = xc.reshape(b, nh, hd).astype(jnp.float32)
    upd = jnp.einsum("bhp,bn->bhpn", xh * dt[..., None], Bc.astype(jnp.float32))
    h_new = a[..., None, None] * ssm_state + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, Cc.astype(jnp.float32))
    y = y + p["D"][:, None] * xh
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = linear(y, p["out_proj"])
    new_conv_state = window[:, 1:]
    return out, (new_conv_state, h_new)
