"""Top-k MoE with shared + routed experts (DeepSeek/Jamba style).

Expert parallelism (EP) maps experts onto the ``model`` mesh axis.  Inside a
``shard_map`` block, activations arrive token-sharded over the data axes and
*replicated* over ``model``; every device (a) runs the (tiny) router
redundantly, (b) gathers the tokens routed to its local experts into a
fixed-``capacity`` buffer (static shapes — sort + scatter), (c) runs the
expert FFNs as batched einsums, and (d) scatter-adds its partial output,
combined with one ``psum`` over ``model``.  Communication is therefore one
(T_local, d) reduction — the same cost as a row-parallel matmul — instead of
an all-to-all; overflow beyond capacity is dropped (GShard semantics).

The same gather/compute/scatter core also runs unsharded (``axis=None``) for
single-device smoke tests and for RSQ calibration capture.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import (apply_dense_ffn, dense_init, init_dense_ffn,
                                 linear)


def init_moe(key, cfg, dtype):
    e, d, f = cfg.n_routed_experts, cfg.d_model, cfg.moe_d_ff
    keys = jax.random.split(key, 5)

    def experts_init(k, din, dout):
        ks = jax.random.split(k, e)
        return jnp.stack([dense_init(kk, din, dout, dtype) for kk in ks])

    p = {
        "router": dense_init(keys[0], d, e, jnp.float32),
        "experts": {
            "wi": experts_init(keys[1], d, f),
            "wu": experts_init(keys[2], d, f),
            "wd": experts_init(keys[3], f, d),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = init_dense_ffn(keys[4], d, cfg.n_shared_experts * f, dtype)
    return p


def route(router_w, x2d, top_k: int):
    """Returns (top_idx (T,k), top_w (T,k), gates (T,E))."""
    logits = (x2d.astype(jnp.float32) @ router_w)  # router kept fp32
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(gates, top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    return top_idx, top_w, gates


def load_balance_loss(gates, top_idx, n_experts: int):
    """Switch-style aux loss: E * sum_e f_e * P_e."""
    t, k = top_idx.shape
    dispatch = jax.nn.one_hot(top_idx, n_experts, dtype=jnp.float32).sum(1)
    f = dispatch.mean(0)  # fraction of tokens hitting e
    p = gates.mean(0)
    return n_experts * jnp.sum(f * p)


def _expert_buffers(x2d, top_idx, top_w, e_start, e_local, capacity):
    """Gather routed tokens into (e_local, capacity, d) with drop-overflow.

    Slot-major formulation: scatter *token indices* (cheap int ops) into the
    (e_local * capacity) slot table, then gather feature rows directly into
    the buffer.  Nothing of size (T * top_k, d) is ever materialized — the
    naive gather/scatter forms a 15 GB intermediate per layer at DeepSeek-V3
    scale.

    Returns (buf, slot_token, slot_w) where slot_token (e_local*capacity,)
    maps each slot to its source token (== T for empty slots) and slot_w are
    the routing weights per slot (0 for empty)."""
    t, k = top_idx.shape
    d = x2d.shape[-1]
    flat_e = top_idx.reshape(-1)  # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_w = top_w.reshape(-1)
    # rank of each assignment within its expert (stable order over tokens):
    # sort by expert id; rank = position - first index of that expert id
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    rank_sorted = jnp.arange(flat_e.shape[0]) - jnp.searchsorted(sorted_e, sorted_e)
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)

    local = (flat_e >= e_start) & (flat_e < e_start + e_local)
    keep = local & (rank < capacity)
    n_slots = e_local * capacity
    dest = jnp.where(keep, (flat_e - e_start) * capacity + rank, n_slots)
    slot_token = jnp.full((n_slots,), t, jnp.int32).at[dest].set(
        flat_t.astype(jnp.int32), mode="drop")
    slot_w = jnp.zeros((n_slots,), flat_w.dtype).at[dest].set(
        flat_w, mode="drop")
    # gather rows per slot; empty slots (token == T) read zeros
    x_pad = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
    buf = x_pad[slot_token].reshape(e_local, capacity, d)
    return buf, slot_token, slot_w


def _expert_ffn(experts, buf):
    """Batched per-expert SwiGLU over (E, C, d) capacity buffers.

    ``linear`` keeps the leading expert axis batched for both weight
    representations: fp (E, d, f) stacks contract as a batched matmul
    (== einsum "ecd,edf->ecf"), packed stacks vmap the quant_matmul
    kernel over E — the serving artifact's batched pack layout."""
    gate = jax.nn.silu(linear(buf, experts["wi"]))
    up = linear(buf, experts["wu"])
    return linear(gate * up, experts["wd"])


def moe_capacity(cfg, n_tokens: int) -> int:
    cap = n_tokens * cfg.moe_top_k / cfg.n_routed_experts * cfg.capacity_factor
    return max(8, int(math.ceil(cap / 8) * 8))


def apply_moe(p, cfg, x, *, axis: str | None = None):
    """x: (B, T, D) -> (y, aux_loss).  ``axis``: EP mesh axis (inside
    shard_map) or None for the local full-expert path."""
    b, t, d = x.shape
    x2d = x.reshape(b * t, d)
    e = cfg.n_routed_experts
    top_idx, top_w, gates = route(p["router"], x2d, cfg.moe_top_k)
    aux = load_balance_loss(gates, top_idx, e)
    capacity = moe_capacity(cfg, b * t)

    if axis is None:
        e_start, e_local = 0, e
        experts = p["experts"]
    else:
        n_shards = jax.lax.axis_size(axis)
        e_local = e // n_shards
        e_start = jax.lax.axis_index(axis) * e_local
        experts = p["experts"]  # shard_map already hands us the local slice

    buf, slot_token, slot_w = _expert_buffers(
        x2d, top_idx, top_w, e_start, e_local, capacity)
    h = _expert_ffn(experts, buf).reshape(e_local * capacity, d)
    # scatter-add slot outputs back to their tokens (empty slots drop)
    y = jnp.zeros((b * t, d), x.dtype).at[slot_token].add(
        h * slot_w[:, None].astype(h.dtype), mode="drop")
    if axis is not None:
        y = jax.lax.psum(y, axis)
    if "shared" in p:
        y = y + apply_dense_ffn(p["shared"], x2d)
    return y.reshape(b, t, d), aux


def capture_moe(p, cfg, x):
    """Local forward returning per-weight calibration inputs for RSQ.

    Returns (y, captures) where captures maps weight path -> (tokens, d_in)
    input matrix: router and shared FFN see all tokens; each expert's
    wi/wu/wd see only its routed capacity buffer."""
    b, t, d = x.shape
    x2d = x.reshape(b * t, d)
    e = cfg.n_routed_experts
    top_idx, top_w, gates = route(p["router"], x2d, cfg.moe_top_k)
    capacity = moe_capacity(cfg, b * t)
    buf, slot_token, slot_w = _expert_buffers(
        x2d, top_idx, top_w, 0, e, capacity)
    gate = jax.nn.silu(linear(buf, p["experts"]["wi"]))
    up = linear(buf, p["experts"]["wu"])
    hidden = gate * up
    out = linear(hidden, p["experts"]["wd"])
    h = out.reshape(e * capacity, d)
    y = jnp.zeros((b * t, d), x.dtype).at[slot_token].add(
        h * slot_w[:, None].astype(h.dtype), mode="drop")
    caps = {
        "experts/wi": buf,     # (E, C, d)
        "experts/wu": buf,
        "experts/wd": hidden,  # (E, C, f)
        "__slot_token": slot_token,  # (E*C,) slot -> source token (T = empty)
    }
    if "shared" in p:
        sh, sh_caps = _capture_shared(p["shared"], x2d)
        y = y + sh.reshape(b * t, d)
        caps.update({f"shared/{k}": v for k, v in sh_caps.items()})
    aux = load_balance_loss(gates, top_idx, e)
    return y.reshape(b, t, d), aux, caps


def _capture_shared(p, x2d):
    h = jax.nn.silu(linear(x2d, p["wi"])) * linear(x2d, p["wu"])
    return linear(h, p["wd"]), {"wi": x2d, "wu": x2d, "wd": h}
