"""Attention: chunked (flash-style) prefill/train attention, decode attention,
GQA / MLA / cross-attention projections, and attention-concentration capture
for RSQ's AttnCon importance.

The chunked attention never materializes the (T, T) score matrix: it scans
over KV chunks with a running (max, denominator, accumulator) triple — the
TPU-native analogue of FlashAttention.  The scan body is checkpointed so the
backward pass recomputes per-chunk scores instead of storing them.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantizer import dequantize_packed
from repro.kernels.flash_decode.ops import (flash_decode, mla_flash_decode,
                                            paged_flash_decode,
                                            paged_flash_extend,
                                            paged_mla_flash_decode,
                                            paged_mla_flash_extend)
from repro.kernels.quant_matmul.ops import (is_packed, mla_latent_weights,
                                            quant_matmul, quant_matmul_t)
from repro.models.layers import apply_rope, dense_init, linear, rms_norm

NEG_INF = -1e30


def _materialize(w):
    """fp view of a projection weight, for math that contracts the weight
    per-head instead of as a plain GEMM (MLA's absorbed decode).

    Historical note: this used to dequantize a packed ``wkv_b`` inside the
    jitted decode step — the one transient fp materialization left in the
    packed decode path.  Absorbed decode now contracts packed codes
    directly through the latent-layout kernels
    (``quant_matmul_t``/``quant_matmul`` over ``mla_latent_weights``
    views), so a ``PackedWeight`` reaching here means a dispatch bug: the
    zero-dequant serving contract (tests/test_serve_packed.py) counts any
    ``dequantize_packed`` call during generate as a failure."""
    if is_packed(w):  # pragma: no cover - guarded against by mla_decode
        assert w.w_packed.ndim == 2, w.w_packed.shape
        return dequantize_packed(w.w_packed, w.scale, w.zero,
                                 bits=w.bits, d_in=w.d_in)
    return w


def _repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    """(B, T, KV, Dh) -> (B, T, KV*n_rep, Dh)."""
    if n_rep == 1:
        return x
    b, t, kv, dh = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, t, kv, n_rep, dh))
    return x.reshape(b, t, kv * n_rep, dh)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    kv_chunk: int = 512,
    q_offset: int = 0,
    colsum: bool = False,
):
    """Chunked attention.

    q: (B, Tq, H, Dh); k: (B, Tk, KV, Dh); v: (B, Tk, KV, Dv), H % KV == 0.
    Returns (B, Tq, H, Dv) and, when ``colsum`` is set, the per-token
    attention-concentration scores sum_{h,i} A[h, i, j] of shape (B, Tk)
    (the AttnCon importance of the paper, computed streamingly).
    """
    b, tq, h, dh = q.shape
    _, tk, kv_heads, _ = k.shape
    dv = v.shape[-1]
    n_rep = h // kv_heads
    kv_chunk = min(kv_chunk, tk)
    valid_tk = tk
    pad = (-tk) % kv_chunk
    if pad:  # ragged KV length (media/cross): pad + mask
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        tk = tk + pad
    n_chunks = tk // kv_chunk

    qf = q.astype(jnp.float32) * (dh ** -0.5)
    q_pos = q_offset + jnp.arange(tq)

    def body(carry, xs):
        m, l, acc = carry
        k_c, v_c, off = xs
        k_r = _repeat_kv(k_c, n_rep).astype(jnp.float32)
        v_r = _repeat_kv(v_c, n_rep).astype(jnp.float32)
        s = jnp.einsum("bthd,bchd->bthc", qf, k_r)  # (B, Tq, H, c)
        kv_pos = off + jnp.arange(kv_chunk)
        if causal:
            mask = (q_pos[:, None] >= kv_pos[None, :]) & (
                kv_pos < valid_tk)[None, :]  # (Tq, c)
            s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        elif pad:
            s = jnp.where((kv_pos < valid_tk)[None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bthc,bchd->bthd", p, v_r)
        # Column sums of the *normalized* probabilities require the final
        # (m, l); accumulate unnormalized stats + the per-chunk max instead.
        return (m_new, l_new, acc_new), (m_new, p if colsum else None)

    m0 = jnp.full((b, tq, h), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, tq, h), jnp.float32)
    a0 = jnp.zeros((b, tq, h, dv), jnp.float32)
    ks = k.reshape(b, n_chunks, kv_chunk, kv_heads, dh).swapaxes(0, 1)
    vs = v.reshape(b, n_chunks, kv_chunk, kv_heads, dv).swapaxes(0, 1)
    offs = jnp.arange(n_chunks) * kv_chunk

    if not colsum:
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(lambda c, x: body(c, x)), (m0, l0, a0), (ks, vs, offs)
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)

    # Capture path (calibration only; tiny models) — keeps per-chunk p.
    (m, l, acc), (ms, ps) = jax.lax.scan(body, (m0, l0, a0), (ks, vs, offs))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    # renormalize each chunk's p by exp(m_chunk - m_final)/l_final and
    # column-sum over (query, head): ps: (nc, B, Tq, H, c)
    scale = jnp.exp(ms - m[None]) / jnp.maximum(l[None], 1e-30)  # (nc,B,Tq,H)
    col = jnp.einsum("nbthc,nbth->nbc", ps, scale)  # (nc, B, c)
    col = col.swapaxes(0, 1).reshape(b, tk)[:, :valid_tk]
    return out.astype(q.dtype), col


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
) -> jax.Array:
    """Single-token attention against a (B, S, KV, Dh) cache; positions > pos
    are masked.  q: (B, 1, H, Dh) -> (B, 1, H, Dv).

    GQA-aware: the query is reshaped to (KV, G) groups and contracted
    against the cache directly — materializing a head-repeated (B, S, H, Dh)
    cache view (16x the cache for KV=8 -> H=128!) is exactly what makes
    long-context decode memory/collective-bound, and it breaks sequence
    sharding of the cache under SPMD."""
    import os
    b, _, h, dh = q.shape
    _, s, kv_heads, _ = k_cache.shape
    if os.environ.get("REPRO_BASELINE"):  # pre-optimization path (§Perf)
        k_r = _repeat_kv(k_cache, h // kv_heads)
        v_r = _repeat_kv(v_cache, h // kv_heads)
        qf = q.astype(jnp.float32) * (dh ** -0.5)
        scores = jnp.einsum("bthd,bshd->bths", qf, k_r.astype(jnp.float32))
        valid = jnp.arange(s)[None, None, None, :] <= pos
        scores = jnp.where(valid, scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bths,bshd->bthd", p, v_r.astype(jnp.float32))
        return out.astype(q.dtype)
    g = h // kv_heads
    qf = (q.astype(jnp.float32) * (dh ** -0.5)).reshape(b, kv_heads, g, dh)
    # bf16 operands + fp32 accumulation (MXU-native): casting the cache to
    # f32 would write a 2x-sized copy of the entire KV cache per layer per
    # token — 3x the fundamental decode HBM traffic
    scores = jnp.einsum("bkgd,bskd->bkgs", qf.astype(k_cache.dtype), k_cache,
                        preferred_element_type=jnp.float32)
    valid = jnp.arange(s)[None, None, None, :] <= pos
    scores = jnp.where(valid, scores, NEG_INF)
    # streaming-stable softmax over the (possibly sequence-sharded) S axis
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(denom, 1e-30)
    return out.reshape(b, 1, h, v_cache.shape[-1]).astype(q.dtype)


# ------------------------------------------------------- quantized KV cache


def kv_quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(B, T, KV, Dh) -> int8 codes + per-(token, head) scales.

    Halves (vs bf16) the fundamental long-context decode HBM traffic — the
    whole cache is read per generated token (KVQuant/KIVI-style, symmetric
    per-token-per-head)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0].astype(jnp.bfloat16)


def kv_dequantize(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    """Full-tensor fp materialization of an int8 cache.

    Debug/test-only: the serving decode path consumes codes directly
    (``decode_attention_quantized``); the zero-dequant guard counts any
    call to this during generate as a failure."""
    return (q.astype(jnp.bfloat16) * scale[..., None].astype(jnp.bfloat16)
            ).astype(dtype)


# 2-bit log-distributed codes (LogQuant-style): value = scale * LEVELS[code].
# Codes 0..3 are sign x {outer, inner} log levels; one bf16 scale per
# (chunk-of-tokens, head) group; 16 codes packed per uint32 word along the
# feature axis.
KV_LOG_LEVELS = (-1.0, -0.25, 0.25, 1.0)


def kv_pack(codes: jax.Array) -> jax.Array:
    """Pack (..., D) 2-bit codes into (..., ceil(D/16)) uint32 words
    (code j of a word at bits [2j, 2j+2); ragged D zero-padded)."""
    d = codes.shape[-1]
    pad = (-d) % 16
    if pad:
        widths = [(0, 0)] * (codes.ndim - 1) + [(0, pad)]
        codes = jnp.pad(codes, widths)
    c = codes.astype(jnp.uint32).reshape(*codes.shape[:-1], -1, 16)
    shifts = jnp.arange(16, dtype=jnp.uint32) * 2
    return jnp.sum(c << shifts, axis=-1).astype(jnp.uint32)


def kv_unpack(words: jax.Array, d: int) -> jax.Array:
    """(..., ceil(D/16)) uint32 -> (..., D) int32 codes in 0..3."""
    shifts = jnp.arange(16, dtype=jnp.uint32) * 2
    c = ((words[..., None] >> shifts) & jnp.uint32(3)).astype(jnp.int32)
    return c.reshape(*words.shape[:-1], -1)[..., :d]


def kv_log_scales(x: jax.Array, chunk: int) -> jax.Array:
    """Per-(chunk, head) log scales: amax of |x| over each ``chunk``-token
    group and the feature axis.  x: (B, T, ..., D) -> (B, ceil(T/chunk), ...)
    bf16 (ragged T zero-padded — padded rows never decode)."""
    xf = jnp.abs(x.astype(jnp.float32))
    b, t = x.shape[:2]
    pad = (-t) % chunk
    if pad:
        widths = [(0, 0), (0, pad)] + [(0, 0)] * (xf.ndim - 2)
        xf = jnp.pad(xf, widths)
    xf = xf.reshape(b, -1, chunk, *x.shape[2:])
    amax = jnp.max(xf, axis=(2, -1))
    return jnp.maximum(amax, 1e-8).astype(jnp.bfloat16)


def _kv_log_codes(xf: jax.Array, scale: jax.Array) -> jax.Array:
    """Encode f32 values against a per-(token, head) scale (shape
    ``xf.shape[:-1]``): |x|/scale > 0.5 picks the outer level, sign picks
    the half — code = 2 + magcode for x >= 0, 1 - magcode otherwise.
    Values beyond the scale clip to the outer level (the chunk-leader
    rule: decode-appended tokens reuse their chunk's first-token scale)."""
    s = jnp.maximum(scale.astype(jnp.float32), 1e-8)[..., None]
    magcode = (jnp.abs(xf) / s > 0.5).astype(jnp.int32)
    return jnp.where(xf >= 0, 2 + magcode, 1 - magcode)


def kv_log_encode(x: jax.Array, scales: jax.Array, chunk: int) -> jax.Array:
    """x: (B, T, ..., D) + per-chunk scales -> (B, T, ..., ceil(D/16))
    packed uint32 codes."""
    t = x.shape[1]
    s_tok = jnp.repeat(scales, chunk, axis=1)[:, :t]
    return kv_pack(_kv_log_codes(x.astype(jnp.float32), s_tok))


def kv_log_decode(packed: jax.Array, scales: jax.Array, *, d: int,
                  chunk: int, dtype=jnp.float32) -> jax.Array:
    """Full-tensor fp materialization of a 2-bit cache — debug/test-only,
    same guard contract as ``kv_dequantize``."""
    c = kv_unpack(packed, d)
    t = packed.shape[1]
    s_tok = jnp.repeat(scales.astype(jnp.float32), chunk, axis=1)[:, :t]
    lut = jnp.array(KV_LOG_LEVELS, jnp.float32)
    return (lut[c] * s_tok[..., None]).astype(dtype)


# --------------------------------------------------------------- KV codecs
#
# One protocol for every KV-cache representation.  A codec owns three
# things so the flat (B, S, ...) cache, the paged (n_pages, page, ...)
# pools and the kernels can never drift on layout or rounding:
#
#   * ``encode``       — prefill-length tensor -> (codes, scales)
#   * ``encode_token`` / ``append`` — one-token quantize (+ the kv2
#     chunk-leader scale rule) and its flat-cache write
#   * layout           — ``round_len`` (cache-length alignment, the old
#     ``models.lm._cache_len``), ``code_cols``/``code_dtype``/
#     ``scale_rows``/``scale_dtype`` (allocation shapes) and
#     ``page_tokens`` (the paged-cache page size: one ``align`` group of
#     tokens, so 2-bit scale groups never straddle pages)
#
# The legacy free functions (``kv_cache_quantize`` / ``kv_cache_update``)
# survive as thin wrappers — call sites and tests keep working — but the
# logic lives here once.


@dataclasses.dataclass(frozen=True)
class FpCodec:
    """KV cache held in the activation dtype — no codes, no scales."""

    kv_bits: int = 0
    chunk: int = 1  # tokens per scale row (no scales: nominal)
    align: int = 1  # cache-length alignment unit
    quantized: bool = False

    def round_len(self, s: int) -> int:
        return s

    def scale_rows(self, s: int) -> int:
        return 0


@dataclasses.dataclass(frozen=True)
class Kv8Codec:
    """int8 codes + per-(token, head) bf16 scales (``kv_quantize``)."""

    align: int  # cfg.kv_chunk — tile/page alignment even though chunk=1
    kv_bits: int = 8
    chunk: int = 1
    quantized: bool = True
    code_dtype = jnp.int8
    scale_dtype = jnp.bfloat16

    def round_len(self, s: int) -> int:
        return -(-s // self.align) * self.align

    def scale_rows(self, s: int) -> int:
        return s // self.chunk

    def code_cols(self, d: int) -> int:
        return d

    @property
    def page_tokens(self) -> int:
        return self.align

    def encode(self, x):
        return kv_quantize(x)

    def encode_token(self, x, pos, cur_scale):
        """One token (B, 1, ..., D) -> (codes, scale row); ``pos`` and the
        current scale are irrelevant at per-token granularity."""
        del pos, cur_scale
        return kv_quantize(x)

    def append(self, codes, scales, x, pos):
        q, sc = self.encode_token(x, pos, None)
        codes = jax.lax.dynamic_update_slice_in_dim(codes, q, pos, 1)
        scales = jax.lax.dynamic_update_slice_in_dim(scales, sc, pos, 1)
        return codes, scales


@dataclasses.dataclass(frozen=True)
class Kv2Codec:
    """Packed LogQuant-style 2-bit codes + per-(chunk, head) log scales.

    Chunk-leader rule: the token at a chunk boundary stamps the chunk's
    scale from its own amax; later tokens in the chunk reuse it (their
    overflow clips to the outer log level).  Revisiting the scale would
    re-code earlier tokens — a full-cache rewrite per step, exactly the
    traffic this cache layout removes."""

    align: int  # cfg.kv_chunk == scale-group size == page size
    kv_bits: int = 2
    quantized: bool = True
    code_dtype = jnp.uint32
    scale_dtype = jnp.bfloat16

    @property
    def chunk(self) -> int:
        return self.align

    def round_len(self, s: int) -> int:
        return -(-s // self.align) * self.align

    def scale_rows(self, s: int) -> int:
        return s // self.chunk

    def code_cols(self, d: int) -> int:
        return -(-d // 16)

    @property
    def page_tokens(self) -> int:
        return self.align

    def encode(self, x):
        scales = kv_log_scales(x, self.chunk)
        return kv_log_encode(x, scales, self.chunk), scales

    def encode_token(self, x, pos, cur_scale):
        """One token (B, 1, ..., D) against the current scale of its chunk
        (shape (B, 1, ...)); ``pos`` may be a scalar (flat cache, shared
        across the batch) or per-slot (B,) (paged cache)."""
        xf = x.astype(jnp.float32)
        amax = jnp.max(jnp.abs(xf), axis=-1)
        lead = jnp.maximum(amax, 1e-8).astype(cur_scale.dtype)
        stamp = jnp.reshape(pos % self.chunk == 0,
                            (-1,) + (1,) * (cur_scale.ndim - 1))
        sc = jnp.where(stamp, lead, cur_scale)
        return kv_pack(_kv_log_codes(xf, sc)), sc

    def append(self, codes, scales, x, pos):
        ci = pos // self.chunk
        cur = jax.lax.dynamic_slice_in_dim(scales, ci, 1, 1)
        tok, sc = self.encode_token(x, pos, cur)
        codes = jax.lax.dynamic_update_slice_in_dim(codes, tok, pos, 1)
        scales = jax.lax.dynamic_update_slice_in_dim(scales, sc, ci, 1)
        return codes, scales


@functools.lru_cache(maxsize=None)
def kv_codec(kv_bits: int = 0, kv_chunk: int = 64):
    """The codec for a (kv_bits, kv_chunk) cache config — cached so every
    call site shares one instance per config."""
    if kv_bits == 0:
        return FpCodec()
    if kv_bits == 8:
        return Kv8Codec(align=kv_chunk)
    if kv_bits == 2:
        return Kv2Codec(align=kv_chunk)
    raise ValueError(
        f"kv_bits={kv_bits} is not supported — use 0 (KV cache in the "
        "activation dtype), 8 (int8 codes + per-token-head scales) or 2 "
        "(packed log codes + per-chunk scales)")


def kv_cache_quantize(x: jax.Array, *, kv_bits: int,
                      chunk: int = 1) -> tuple[jax.Array, jax.Array]:
    """Quantize a prefill-length KV tensor into (codes, scales) as stored
    in the cache — thin wrapper over :func:`kv_codec`'s ``encode``."""
    return kv_codec(kv_bits, chunk if kv_bits == 2 else 64).encode(x)


def kv_cache_update(codes: jax.Array, scales: jax.Array, x: jax.Array,
                    pos: jax.Array, *, kv_bits: int,
                    chunk: int = 1) -> tuple[jax.Array, jax.Array]:
    """Quantize one new token x: (B, 1, ..., D) and write it into the
    (codes, scales) cache at ``pos`` — thin wrapper over :func:`kv_codec`'s
    ``append``; the decode append never leaves the quantized domain."""
    return kv_codec(kv_bits, chunk if kv_bits == 2 else 64).append(
        codes, scales, x, pos)


def _fd_mesh_args(ctx, batch: int) -> dict:
    """ParallelCtx -> flash_decode mesh kwargs: split the cache sequence
    axis over the model axis; include the data axes in the specs only when
    the batch actually divides over them (else GSPMD would have to
    re-gather the dp-sharded cache batch into the shard_map)."""
    if ctx is None or not getattr(ctx, "enabled", False) or ctx.tp is None:
        return {"mesh": None, "axis": None, "dp": None}
    dp = None
    if ctx.dp and ctx.axis_size("dp") > 1 and batch % ctx.axis_size("dp") == 0:
        dp = ctx.dp if len(ctx.dp) != 1 else ctx.dp[0]
    return {"mesh": ctx.mesh, "axis": ctx.tp, "dp": dp}


def decode_attention_quantized(q: jax.Array, k_codes: jax.Array,
                               k_scales: jax.Array, v_codes: jax.Array,
                               v_scales: jax.Array, pos: jax.Array, *,
                               kv_bits: int, chunk: int = 1,
                               ctx=None) -> jax.Array:
    """Single-token attention directly against the quantized cache.

    q: (B, 1, H, Dh); codes/scales as stored by ``kv_cache_update``.
    Same GQA contraction discipline as ``decode_attention`` ((KV, G)
    groups, never a head-repeated cache) but the cache stays codes all the
    way into the kernel tile — no fp copy of any size S tensor."""
    b, _, h, dh = q.shape
    kv_heads = k_codes.shape[2]
    g = h // kv_heads
    qf = (q.astype(jnp.float32) * (dh ** -0.5)).reshape(b, kv_heads, g, dh)
    out = flash_decode(qf, k_codes, k_scales, v_codes, v_scales, pos,
                       kv_bits=kv_bits, chunk=chunk, dv=dh,
                       **_fd_mesh_args(ctx, b))
    return out.reshape(b, 1, h, dh).astype(q.dtype)


def paged_decode_attention_quantized(q: jax.Array, k_pool: jax.Array,
                                     ks_pool: jax.Array, v_pool: jax.Array,
                                     vs_pool: jax.Array, page_tbl: jax.Array,
                                     pos: jax.Array, *, kv_bits: int,
                                     chunk: int = 1) -> jax.Array:
    """Single-token GQA attention against block-paged quantized pools.

    q: (B, 1, H, Dh) — one slot per engine request; k_pool/v_pool:
    (n_pages, page, KV, w·) code pools, ks_pool/vs_pool:
    (n_pages, page // chunk, KV) scale pools; page_tbl: (B, n_tiles) i32
    per-slot page table (trash page 0 in unused entries); pos: (B,) i32
    per-slot positions.  Same scale folding and (KV, G) grouping as
    :func:`decode_attention_quantized`, so paged == flat stays bitwise at
    a matched tile size (tile = page)."""
    b, _, h, dh = q.shape
    kv_heads = k_pool.shape[2]
    g = h // kv_heads
    qf = (q.astype(jnp.float32) * (dh ** -0.5)).reshape(b, kv_heads, g, dh)
    out = paged_flash_decode(page_tbl, pos, qf, k_pool, ks_pool, v_pool,
                             vs_pool, kv_bits=kv_bits, chunk=chunk, dv=dh,
                             page=k_pool.shape[1])
    return out.reshape(b, 1, h, dh).astype(q.dtype)


def kv_paged_append(codec, c_pool, s_pool, x, page_ids, pos, active):
    """Quantize one new token per slot and scatter it into paged pools.

    x: (B, 1, ..., D) new cache entries (time axis 1, same layout as the
    flat ``append``); page_ids: (B,) i32 — the physical page holding each
    slot's current tile; pos: (B,) i32 global positions; active: (B,)
    bool.  Inactive slots are routed to the reserved trash page 0, so a
    fixed-shape scatter needs no masking and never perturbs live pages.
    The per-token quantization math is the codec's ``encode_token`` — the
    same routine the flat cache appends with — so paged and flat caches
    hold bit-identical codes for the same token stream."""
    page = c_pool.shape[1]
    row = (pos % page).astype(jnp.int32)
    srow = row // codec.chunk
    pid = jnp.where(active, page_ids, 0).astype(jnp.int32)
    cur = s_pool[pid, srow][:, None]          # (B, 1, ...) current scales
    tok, sc = codec.encode_token(x, pos, cur)
    c_pool = c_pool.at[pid, row].set(tok[:, 0])
    s_pool = s_pool.at[pid, srow].set(sc[:, 0])
    return c_pool, s_pool


# ------------------------------------------------------------------ GQA block


def init_gqa(key, cfg, dtype):
    d, h, kvh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    keys = jax.random.split(key, 4)
    p = {
        "wq": dense_init(keys[0], d, h * dh, dtype),
        "wk": dense_init(keys[1], d, kvh * dh, dtype),
        "wv": dense_init(keys[2], d, kvh * dh, dtype),
        "wo": dense_init(keys[3], h * dh, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((kvh * dh,), dtype)
        p["bv"] = jnp.zeros((kvh * dh,), dtype)
    return p


def gqa_qkv(p, cfg, x, positions, *, rope: bool = True):
    b, t, _ = x.shape
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(x, p["wq"]) + (p["bq"] if "bq" in p else 0.0)
    k = linear(x, p["wk"]) + (p["bk"] if "bk" in p else 0.0)
    v = linear(x, p["wv"]) + (p["bv"] if "bv" in p else 0.0)
    q = q.reshape(b, t, h, dh)
    k = k.reshape(b, t, kvh, dh)
    v = v.reshape(b, t, kvh, dh)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def apply_gqa(p, cfg, x, positions, *, causal=True, kv_chunk=512, colsum=False):
    b, t, _ = x.shape
    q, k, v = gqa_qkv(p, cfg, x, positions)
    res = flash_attention(q, k, v, causal=causal, kv_chunk=min(kv_chunk, t),
                          colsum=colsum)
    if colsum:
        out, col = res
    else:
        out, col = res, None
    y = linear(out.reshape(b, t, -1), p["wo"])
    return (y, col) if colsum else y


# ------------------------------------------------------------------ MLA block


def init_mla(key, cfg, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    keys = jax.random.split(key, 6)
    p = {}
    if qr:
        p["wq_a"] = dense_init(keys[0], d, qr, dtype)
        p["q_norm"] = jnp.ones((qr,), dtype)
        p["wq_b"] = dense_init(keys[1], qr, h * (dn + dr), dtype)
    else:
        p["wq"] = dense_init(keys[1], d, h * (dn + dr), dtype)
    p["wkv_a"] = dense_init(keys[2], d, kvr + dr, dtype)
    p["kv_norm"] = jnp.ones((kvr,), dtype)
    p["wkv_b"] = dense_init(keys[3], kvr, h * (dn + dv), dtype)
    p["wo"] = dense_init(keys[4], h * dv, d, dtype)
    return p


def mla_qkv(p, cfg, x, positions):
    """Returns expanded per-head q, k, v plus the latent cache entries."""
    b, t, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    if "wq_a" in p:
        ql = rms_norm(linear(x, p["wq_a"]), p["q_norm"], cfg.norm_eps)
        q = linear(ql, p["wq_b"]).reshape(b, t, h, dn + dr)
    else:
        q = linear(x, p["wq"]).reshape(b, t, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    kv = linear(x, p["wkv_a"])  # (B, T, kvr + dr)
    c_kv = rms_norm(kv[..., :kvr], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., None, kvr:], positions, cfg.rope_theta)  # 1 head
    kvb = linear(c_kv, p["wkv_b"]).reshape(b, t, h, dn + dv)
    k_nope, v = kvb[..., :dn], kvb[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, t, h, dr))], axis=-1
    )
    return q, k, v, c_kv, k_rope[..., 0, :]


def apply_mla(p, cfg, x, positions, *, causal=True, kv_chunk=512, colsum=False):
    b, t, _ = x.shape
    q, k, v, _, _ = mla_qkv(p, cfg, x, positions)
    res = flash_attention(q, k, v, causal=causal, kv_chunk=min(kv_chunk, t),
                          colsum=colsum)
    if colsum:
        out, col = res
    else:
        out, col = res, None
    y = linear(out.reshape(b, t, -1), p["wo"])
    return (y, col) if colsum else y


def _mla_q_and_expand(p, cfg, x, positions):
    """Absorbed-MLA query projection shared by the flat and paged decode
    paths: latent/rope queries plus the W_v expansion closure.

    Pure code motion out of :func:`mla_decode` — both paths run the exact
    same ops here, so per-request results stay bitwise identical between
    the flat cache and the paged engine.  ``positions`` is whatever
    ``apply_rope`` broadcasts against (..., T, ...): ``pos[None]`` on the
    flat path, per-slot ``pos[:, None]`` on the paged path, a chunk's
    ``start + arange(L)`` on the extend path (T = L rows)."""
    b, t, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    if "wq_a" in p:
        ql = rms_norm(linear(x, p["wq_a"]), p["q_norm"], cfg.norm_eps)
        q = linear(ql, p["wq_b"]).reshape(b, t, h, dn + dr)
    else:
        q = linear(x, p["wq"]).reshape(b, t, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    if is_packed(p["wkv_b"]):
        pw_k, pw_v = mla_latent_weights(p["wkv_b"], h, dn, dv)

        def absorb_k(qn):  # (B, T, H, dn) -> (B, T, H, kvr)
            bt = qn.shape[0] * qn.shape[1]
            qh = qn.astype(jnp.float32).reshape(bt, h, dn)
            qh = qh.transpose(1, 0, 2)  # (H, B*T, dn)
            lat = jax.vmap(quant_matmul_t)(qh, pw_k)  # (H, B*T, kvr)
            return lat.transpose(1, 0, 2).reshape(qn.shape[0], qn.shape[1],
                                                  h, kvr)

        def expand_v(cl):  # (B, T, H, kvr) -> (B, T, H, dv)
            b_, t_ = cl.shape[0], cl.shape[1]
            ch = cl.reshape(b_ * t_, h, kvr).transpose(1, 0, 2)
            out = jax.vmap(functools.partial(quant_matmul, shard=False))(
                ch, pw_v)
            return out.transpose(1, 0, 2).reshape(b_, t_, h, dv)
    else:
        wkv_b = _materialize(p["wkv_b"]).reshape(kvr, h, dn + dv)
        w_k, w_v = wkv_b[..., :dn], wkv_b[..., dn:]

        def absorb_k(qn):  # (B,1,H,dn) x (kvr,H,dn) -> (B,1,H,kvr)
            return jnp.einsum("bthd,khd->bthk", qn.astype(jnp.float32),
                              w_k.astype(jnp.float32))

        def expand_v(cl):
            return jnp.einsum("bthk,khd->bthd", cl, w_v.astype(jnp.float32))

    return absorb_k(q_nope), q_rope, expand_v


def mla_decode(p, cfg, x, c_cache, rope_cache, pos, *, c_scale=None,
               r_scale=None, kv_bits: int = 0, chunk: int = 1, ctx=None):
    """Latent-space ("absorbed") MLA decode: the KV cache stores only the
    compressed c_kv (kvr) + shared rope key (dr) per token.

    x: (B, 1, D); c_cache: (B, S, kvr); rope_cache: (B, S, dr).

    With ``kv_bits`` in {8, 2} the caches are codes (+ ``c_scale`` /
    ``r_scale``) and the latent attention runs through
    ``mla_flash_decode`` — MLA's absorbed decode is 1-kv-head attention in
    latent space (scores q_lat.c + q_rope.r, values the latents), so the
    quantized path consumes the c and r codes as separate operands and
    never materializes the latent cache (or a concat of it) in fp.

    The absorbed trick contracts ``wkv_b`` per-head (two contractions
    against the latent cache) rather than as one GEMM.  A packed
    ``wkv_b`` stays packed even here: the per-head column views
    (``mla_latent_weights`` — exact slices of the codes, packing runs
    along kvr) feed ``quant_matmul_t`` (absorb W_k into q: contraction
    over dn, output in latent space) and ``quant_matmul`` (expand the
    latent attention output through W_v), so the last per-step fp weight
    materialization of the decode path is gone — no
    ``dequantize_packed`` anywhere in the step trace; the q and output
    projections stay on ``quant_matmul`` via ``linear`` as before."""
    b, _, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    q_lat, q_rope, expand_v = _mla_q_and_expand(p, cfg, x, pos[None])
    scale = (dn + dr) ** -0.5
    if kv_bits in (8, 2):
        # quantized latent cache: fold the scale into the queries, attend
        # on codes, normalize once in the wrapper
        ql = (q_lat.astype(jnp.float32) * scale)[:, 0]
        qr = (q_rope.astype(jnp.float32) * scale)[:, 0]
        ctx_lat = mla_flash_decode(
            ql, qr, c_cache, c_scale, rope_cache, r_scale, pos,
            kv_bits=kv_bits, chunk=chunk, dl=kvr, dr=dr,
            **_fd_mesh_args(ctx, b))[:, None]  # (B, 1, H, kvr)
        y = linear(expand_v(ctx_lat).reshape(b, 1, h * dv).astype(x.dtype),
                   p["wo"])
        return y
    s_lat = jnp.einsum("bthk,bsk->bths", q_lat, c_cache.astype(jnp.float32))
    s_rope = jnp.einsum("bthd,bsd->bths", q_rope.astype(jnp.float32),
                        rope_cache.astype(jnp.float32))
    scores = (s_lat + s_rope) * scale
    s = c_cache.shape[1]
    valid = jnp.arange(s)[None, None, None, :] <= pos
    scores = jnp.where(valid, scores, NEG_INF)
    prob = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bths,bsk->bthk", prob, c_cache.astype(jnp.float32))
    ctx = expand_v(ctx_lat)
    y = linear(ctx.reshape(b, 1, h * dv).astype(x.dtype), p["wo"])
    return y


def mla_decode_paged(p, cfg, x, c_pool, cs_pool, r_pool, rs_pool, page_tbl,
                     pos, *, kv_bits: int, chunk: int):
    """Absorbed MLA decode against block-paged quantized latent pools.

    x: (B, 1, D) — one slot per engine request; c_pool/r_pool:
    (n_pages, page, w·) latent/rope code pools, cs_pool/rs_pool:
    (n_pages, page // chunk) scale pools; page_tbl: (B, n_tiles) i32;
    pos: (B,) i32 per-slot positions.  Query math is shared with
    :func:`mla_decode` via :func:`_mla_q_and_expand` and the tile loop
    with :func:`paged_mla_flash_decode`, so paged == flat per request."""
    b, _, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    q_lat, q_rope, expand_v = _mla_q_and_expand(p, cfg, x, pos[:, None])
    scale = (dn + dr) ** -0.5
    ql = (q_lat.astype(jnp.float32) * scale)[:, 0]
    qr = (q_rope.astype(jnp.float32) * scale)[:, 0]
    ctx_lat = paged_mla_flash_decode(
        page_tbl, pos, ql, qr, c_pool, cs_pool, r_pool, rs_pool,
        kv_bits=kv_bits, chunk=chunk, dl=kvr, dr=dr,
        page=c_pool.shape[1])[:, None]          # (B, 1, H, kvr)
    return linear(expand_v(ctx_lat).reshape(b, 1, h * dv).astype(x.dtype),
                  p["wo"])


def paged_extend_attention_quantized(q, k_new, v_new, k_pool, ks_pool,
                                     v_pool, vs_pool, tbl, start, *,
                                     kv_bits: int, chunk: int):
    """One prompt chunk's GQA attention against the request's own quantized
    pages plus the fp within-chunk rows (opt-in "paged" chunked prefill).

    q: (1, L, H, Dh); k_new/v_new: (1, L, KV, Dh) this chunk's fp keys and
    values; tbl: (n_past,) i32 — the pages holding the already-ingested
    chunks (earlier rows are read back as codes, dequantized in-register by
    the extend kernel, so this route is HBM-cheap but *lossy* versus the
    flat prefill); start: () i32 page-aligned chunk offset."""
    out = paged_flash_extend(tbl, q, k_new, v_new, k_pool, ks_pool, v_pool,
                             vs_pool, start, kv_bits=kv_bits, chunk=chunk,
                             dh=q.shape[-1], dv=v_new.shape[-1],
                             page=k_pool.shape[1])
    return out.astype(q.dtype)


def mla_extend_paged(p, cfg, x, c_new, r_new, pools, tbl, start, positions, *,
                     kv_bits: int, chunk: int):
    """One prompt chunk's absorbed MLA attention against quantized latent
    pages plus the chunk's fp latents (opt-in "paged" chunked prefill).

    x: (1, L, D) chunk rows; c_new/r_new: (1, L, kvr)/(1, L, dr) this
    chunk's fp latent/rope cache rows; tbl: (n_past,) i32 pages of the
    already-ingested chunks.  Queries come through the same
    :func:`_mla_q_and_expand` absorption as decode (generalized to L rows),
    so the chunk attends in latent space end to end."""
    b, t, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    q_lat, q_rope, expand_v = _mla_q_and_expand(p, cfg, x, positions)
    scale = (dn + dr) ** -0.5
    ql = (q_lat.astype(jnp.float32) * scale)[0]    # (L, H, kvr)
    qr = (q_rope.astype(jnp.float32) * scale)[0]   # (L, H, dr)
    ctx_lat = paged_mla_flash_extend(
        tbl, ql, qr, c_new[0].astype(jnp.float32),
        r_new[0].astype(jnp.float32), pools["c"], pools["cs"], pools["r"],
        pools["rs"], start, kv_bits=kv_bits, chunk=chunk, dl=kvr, dr=dr,
        page=pools["c"].shape[1])[None]             # (1, L, H, kvr)
    return linear(expand_v(ctx_lat).reshape(b, t, h * dv).astype(x.dtype),
                  p["wo"])


# ------------------------------------------------------------- cross-attention


def init_cross_attn(key, cfg, dtype):
    """Cross-attention (VLM media layers / enc-dec): queries from the decoder
    stream, keys/values from (stub) media or encoder output at d_model."""
    d, h, kvh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    keys = jax.random.split(key, 4)
    return {
        "wq": dense_init(keys[0], d, h * dh, dtype),
        "wk": dense_init(keys[1], d, kvh * dh, dtype),
        "wv": dense_init(keys[2], d, kvh * dh, dtype),
        "wo": dense_init(keys[3], h * dh, d, dtype),
    }


def cross_kv(p, cfg, media):
    b, tm, _ = media.shape
    kvh, dh = cfg.n_kv_heads, cfg.head_dim
    k = linear(media, p["wk"]).reshape(b, tm, kvh, dh)
    v = linear(media, p["wv"]).reshape(b, tm, kvh, dh)
    return k, v


def apply_cross_attn(p, cfg, x, media=None, kv=None, kv_chunk=512):
    """media: (B, Tm, D) stub embeddings; or precomputed kv (decode path)."""
    b, t, _ = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q = linear(x, p["wq"]).reshape(b, t, h, dh)
    if kv is None:
        kv = cross_kv(p, cfg, media)
    k, v = kv
    out = flash_attention(q, k, v, causal=False,
                          kv_chunk=min(kv_chunk, k.shape[1]))
    return linear(out.reshape(b, t, -1), p["wo"])
