"""Shared primitives: RMSNorm, RoPE, the ``linear`` projection dispatcher,
SwiGLU FFN, inits.

Conventions: weights are ``(in, out)``; forward is ``y = linear(x, W) (+ b)``.
Norm/softmax math runs in fp32 regardless of activation dtype.

``linear`` is the single seam between the model zoo and the weight
representation: an fp array multiplies as ``x @ w``; a
``kernels.quant_matmul.PackedWeight`` (packed-in-HBM quantized serving
params, ``checkpoint.packed.load_packed_forward_params``) routes through
the fused dequant-GEMM ``quant_matmul`` without the fp weight ever
existing.  Every dense projection in lm/attention/moe/ssm calls it, so a
params pytree holding packed codes jits through prefill and decode
unchanged.  Mesh-sharded packed weights carry their (mesh, axis)
placement in the ``PackedWeight`` aux, so the dispatch needs no
``ParallelCtx`` plumbing: ``quant_matmul`` wraps the Pallas kernel in
shard_map over the model axis by itself; only the vmapped expert-stack
branch opts out (``shard=False`` — shard_map can't nest under vmap) and
stays on the GSPMD ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quant_matmul.ops import is_packed, quant_matmul


def linear(x: jax.Array, w) -> jax.Array:
    """Dense projection dispatcher: ``x @ w`` for fp arrays, the packed
    ``quant_matmul`` kernel for ``PackedWeight``.

    Handles the model's activation ranks in one place: (B, T, D) streams
    flatten to 2-D around the GEMM (the kernel wrapper itself pads
    decode-time small-m shapes to the sublane tile), and expert-stacked
    weights — leaves with a leading (E,) axis — contract batched, matching
    ``einsum('ecd,edf->ecf')`` on the fp side (per-expert kernel via vmap,
    with the shard_map mesh route disabled inside the vmap)."""
    if not is_packed(w):
        return x @ w
    if w.w_packed.ndim == 3:  # expert stack: (E, C, d) x (E, ...) per-expert
        return jax.vmap(functools.partial(quant_matmul, shard=False))(x, w)
    if x.ndim == 2:
        return quant_matmul(x, w)
    lead = x.shape[:-1]
    y = quant_matmul(x.reshape(-1, x.shape[-1]), w)
    return y.reshape(*lead, y.shape[-1])


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def rms_norm(x: jax.Array, g: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * g.astype(jnp.float32)
    return out.astype(x.dtype)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., T, H, Dh); positions: broadcastable to (..., T)."""
    dh = x.shape[-1]
    inv_freq = rope_frequencies(dh, theta)
    angles = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., T, Dh/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., T, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., : dh // 2], xf[..., dh // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- FFN


def init_dense_ffn(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d_model, d_ff, dtype),  # gate
        "wu": dense_init(k2, d_model, d_ff, dtype),  # up
        "wd": dense_init(k3, d_ff, d_model, dtype),
    }


def apply_dense_ffn(p, x: jax.Array) -> jax.Array:
    gate = jax.nn.silu(linear(x, p["wi"]))
    return linear(gate * linear(x, p["wu"]), p["wd"])


def capture_dense_ffn(p, x: jax.Array):
    """Forward returning per-weight inputs for RSQ Hessian accumulation."""
    h = jax.nn.silu(linear(x, p["wi"])) * linear(x, p["wu"])
    y = linear(h, p["wd"])
    return y, {"wi": x, "wu": x, "wd": h}


def init_embedding(key, vocab: int, d_model: int, dtype):
    return (jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02).astype(dtype)


@jax.custom_vjp
def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    """Gather forward; matmul backward.

    The natural backward of a gather is a scatter-add into (V, D), which
    GSPMD replicates (a multi-GB fp32 buffer per device at 256k vocab).  The
    custom backward instead accumulates one-hot x grad chunk matmuls under a
    checkpointed scan — plain dots that partition cleanly over the vocab
    axis."""
    return table[tokens]


def _embed_fwd(table, tokens):
    # keeping `table` in the residuals is free: it is a parameter that is
    # live anyway, and it carries the (V, D)/dtype statics the bwd needs
    return table[tokens], (tokens, table)


def _embed_bwd(res, g):
    tokens, table = res
    (v, d), dtype = table.shape, table.dtype
    b, t = tokens.shape
    chunk = min(512, t)
    pad = (-t) % chunk
    if pad:
        tokens = jnp.pad(tokens, ((0, 0), (0, pad)))
        g = jnp.pad(g, ((0, 0), (0, pad), (0, 0)))
    n = tokens.shape[1] // chunk
    toks = tokens.reshape(b, n, chunk).swapaxes(0, 1)
    gs = g.reshape(b, n, chunk, d).swapaxes(0, 1)

    def body(acc, xs):
        tok_c, g_c = xs
        oh = jax.nn.one_hot(tok_c, v, dtype=g.dtype)  # (b, c, V)
        return acc + jnp.einsum("bcv,bcd->vd", oh, g_c), None

    acc0 = jnp.zeros((v, d), g.dtype)
    d_table, _ = jax.lax.scan(jax.checkpoint(body), acc0, (toks, gs))
    return d_table.astype(dtype), None


embed_lookup.defvjp(_embed_fwd, _embed_bwd)


def cross_entropy_chunked(
    x: jax.Array,
    head_w: jax.Array,
    labels: jax.Array,
    mask: jax.Array | None = None,
    chunk: int = 512,
) -> jax.Array:
    """Mean token cross-entropy without materializing (B, T, V) logits.

    x: (B, T, D) final hidden states; head_w: (D, V); labels: (B, T).
    Scans over T chunks; each chunk's logits are formed, reduced and freed —
    with the body checkpointed, the backward recomputes them chunk by chunk.
    """
    b, t, d = x.shape
    chunk = min(chunk, t)
    n = t // chunk
    rem = t - n * chunk
    if mask is None:
        mask = jnp.ones((b, t), dtype=jnp.float32)

    def chunk_loss(x_c, y_c, m_c):
        logits = (x_c @ head_w).astype(jnp.float32)  # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y_c[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * m_c), jnp.sum(m_c)

    def body(carry, args):
        tot, cnt = carry
        l, c = chunk_loss(*args)
        return (tot + l, cnt + c), None

    xs = (
        x[:, : n * chunk].reshape(b, n, chunk, d).swapaxes(0, 1),
        labels[:, : n * chunk].reshape(b, n, chunk).swapaxes(0, 1),
        mask[:, : n * chunk].reshape(b, n, chunk).swapaxes(0, 1),
    )
    (total, count), _ = jax.lax.scan(jax.checkpoint(body), (0.0, 0.0), xs)
    if rem:
        l, c = chunk_loss(x[:, n * chunk :], labels[:, n * chunk :], mask[:, n * chunk :])
        total, count = total + l, count + c
    return total / jnp.maximum(count, 1.0)
