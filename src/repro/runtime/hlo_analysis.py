"""Post-SPMD HLO analysis for the roofline report.

``compiled.cost_analysis()`` counts each loop body exactly once, so scanned-layer
models would be under-counted by ``n_layers``x.  This parser walks the
scheduled HLO text, extracts ``known_trip_count`` from every ``while`` op's
backend_config, and multiplies per-instruction costs by the product of
enclosing loop trip counts.  It reports, per device:

  * ``dot_flops``      — 2 * prod(out) * prod(contracting dims) per dot
  * ``bytes_accessed`` — resolved operand bytes + output bytes per
                         top-level instruction (fusion internals excluded —
                         a fusion's operands/outputs are its HBM traffic)
  * ``collectives``    — per-op byte totals + estimated link bytes using
                         ring-algorithm formulas (all-reduce 2S(n-1)/n, ...)
"""
from __future__ import annotations

import json
import math
import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "after-all", "partition-id", "replica-id",
    "call", "iota",
}

# HBM-traffic proxy: only ops that form fusion boundaries on TPU count for
# the memory term.  Standalone elementwise ops (converts/adds/selects the
# CPU backend leaves unfused, incl. its f32-staging of bf16) would be fused
# into neighbours by the TPU compiler, so counting them overstates bytes.
_BYTES_OPS = {
    "dot", "convolution", "fusion", "gather", "scatter", "dynamic-slice",
    "dynamic-update-slice", "reduce", "reduce-window", "sort", "cholesky",
    "triangular-solve", "all-reduce", "all-gather", "reduce-scatter",
    "all-to-all", "collective-permute", "copy", "copy-start", "concatenate",
    "pad", "select-and-scatter", "rng-bit-generator", "custom-call",
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _parse_shape(type_str: str):
    """-> (bytes, [list of (dtype, dims)]) for possibly-tuple type strings."""
    total = 0.0
    shapes = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            n = math.prod(int(d) for d in dims.split(","))
        total += DTYPE_BYTES[dt] * n
        shapes.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return total, shapes


def parse_module(text: str):
    """-> dict comp_name -> list of instruction dicts."""
    comps: dict[str, list[dict]] = {}
    current = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and (m := _COMP_RE.match(line)):
            current = m.group(1)
            comps[current] = []
            continue
        if line.strip() == "}":
            continue
        if current is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name, type_str, op, rest = im.groups()
        # operands: up to the closing paren at depth 0
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands_str = rest[:end]
        attrs = rest[end + 1:]
        out_bytes, out_shapes = _parse_shape(type_str)
        comps[current].append({
            "name": name, "op": op, "type": type_str,
            "out_bytes": out_bytes, "out_shapes": out_shapes,
            "operands": re.findall(r"%([\w\.\-]+)", operands_str),
            "attrs": attrs, "line": line,
        })
    return comps


def analyze_hlo(text: str) -> dict:
    comps = parse_module(text)
    if not comps:
        return {"error": "no computations parsed"}

    # entry = last ENTRY computation in text; find via 'ENTRY' marker
    entry = None
    for raw in text.splitlines():
        if raw.startswith("ENTRY"):
            m = _COMP_RE.match(raw)
            if m:
                entry = m.group(1)
    if entry is None:
        entry = next(iter(comps))

    # instruction name -> (out_bytes, out_shapes), global fallback map
    shape_of: dict[str, tuple] = {}
    for instrs in comps.values():
        for ins in instrs:
            shape_of.setdefault(ins["name"], (ins["out_bytes"],
                                              ins["out_shapes"]))

    # multipliers: entry x1; while bodies/conditions x trip_count (nested ok)
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    stack = [entry]
    seen = set()
    while stack:
        cname = stack.pop()
        if cname in seen:
            continue
        seen.add(cname)
        m = mult[cname]
        for ins in comps.get(cname, []):
            if ins["op"] == "while":
                tm = _TRIP_RE.search(ins["attrs"])
                trips = float(tm.group(1)) if tm else 1.0
                for key in ("body", "condition"):
                    cm = re.search(key + r"=%?([\w\.\-]+)", ins["attrs"])
                    if cm:
                        mult[cm.group(1)] += m * trips
                        stack.append(cm.group(1))
            elif ins["op"] == "conditional":
                for cm in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                      r"(?:true|false)_computation=%?([\w\.\-]+))",
                                      ins["attrs"]):
                    names = (cm.group(1) or cm.group(2) or "")
                    for n in re.findall(r"%?([\w\.\-]+)", names):
                        mult[n] += m
                        stack.append(n)

    dot_flops = 0.0
    bytes_accessed = 0.0
    coll = defaultdict(lambda: {"count": 0, "bytes": 0.0, "link_bytes": 0.0})

    for cname in mult:
        m = mult[cname]
        local = {i["name"]: (i["out_bytes"], i["out_shapes"])
                 for i in comps.get(cname, [])}

        def resolve(name):
            return local.get(name) or shape_of.get(name)

        for ins in comps.get(cname, []):
            op = ins["op"]
            if op == "dot":
                lhs = resolve(ins["operands"][0]) if ins["operands"] else None
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                                  ins["attrs"] + ins["line"])
                k = 1
                if lhs and cdims and lhs[1]:
                    dims = lhs[1][0][1]
                    for d in cdims.group(1).split(","):
                        if d and int(d) < len(dims):
                            k *= dims[int(d)]
                out_elems = 0
                for dt, dims in ins["out_shapes"]:
                    out_elems += math.prod(dims) if dims else 1
                dot_flops += m * 2.0 * out_elems * k
            if op in COLLECTIVES:
                n = 0
                gm = _GROUPS_RE.search(ins["line"])
                if gm:
                    n = int(gm.group(2))
                else:
                    gl = _GROUPS_LIST_RE.search(ins["line"])
                    if gl:
                        n = len(gl.group(1).split(","))
                n = max(n, 2)
                s = ins["out_bytes"]
                if op == "all-reduce":
                    link = 2.0 * s * (n - 1) / n
                elif op == "all-gather":
                    link = s * (n - 1) / n
                elif op == "reduce-scatter":
                    link = s * (n - 1)  # input = out * n
                elif op == "all-to-all":
                    link = s * (n - 1) / n
                else:  # collective-permute
                    link = s
                c = coll[op]
                c["count"] += m
                c["bytes"] += m * s
                c["link_bytes"] += m * link
            if op in _BYTES_OPS and not ins["type"].startswith("("):
                b = ins["out_bytes"]
                for o in ins["operands"]:
                    r = resolve(o)
                    if r:
                        b += r[0]
                bytes_accessed += m * b

    return {
        "entry": entry,
        "dot_flops_per_device": dot_flops,
        "bytes_accessed_per_device": bytes_accessed,
        "collectives": {k: dict(v) for k, v in coll.items()},
        "collective_link_bytes_per_device": sum(
            v["link_bytes"] for v in coll.values()),
        "n_computations": len(comps),
    }


if __name__ == "__main__":
    import sys

    print(json.dumps(analyze_hlo(open(sys.argv[1]).read()), indent=2))
