"""Logical-axis sharding rules -> NamedSharding, and the ParallelCtx threaded
through the model zoo.

Rules follow the Megatron/MaxText recipe:
  * column-parallel (d_in, d_out) weights: (fsdp=data, tensor=model)
  * row-parallel (d_out, d_in->d_model) weights: (tensor=model, fsdp=data)
  * experts (E, ...) : E on the model axis (expert parallelism)
  * vocab-parallel embedding: (model, None); LM head: (None, model)
  * activations: batch on (pod, data); heads/ff on model via GSPMD
    propagation with explicit residual-stream constraints.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: Mesh | None = None
    dp: tuple = ()  # data axes, e.g. ("data",) or ("pod", "data")
    tp: str | None = None  # tensor/expert axis, e.g. "model"
    ep: bool = False  # route MoE through the shard_map EP path
    mode: str = "train"  # "train" | "decode" (serving-specific param rules)

    @property
    def enabled(self) -> bool:
        return self.mesh is not None

    def resolve(self, *entries) -> P:
        """entries: "dp" | "tp" | "2d" | None -> PartitionSpec."""
        out = []
        for e in entries:
            if e == "dp":
                if not self.dp:
                    out.append(None)
                else:
                    out.append(self.dp if len(self.dp) != 1 else self.dp[0])
            elif e == "tp":
                out.append(self.tp)
            elif e == "2d":  # all mesh axes on one dim (decode weights)
                axes = ((self.tp,) if self.tp else ()) + tuple(self.dp)
                out.append(axes if axes else None)
            else:
                out.append(None)
        return P(*out)

    def axis_size(self, entry) -> int:
        if not self.enabled or entry is None:
            return 1
        import math
        if entry == "dp":
            return math.prod(self.mesh.shape[a] for a in self.dp) if self.dp else 1
        if entry == "tp":
            return self.mesh.shape[self.tp] if self.tp else 1
        if entry == "2d":
            return self.axis_size("dp") * self.axis_size("tp")
        return 1

    def sharding(self, *entries) -> NamedSharding:
        return NamedSharding(self.mesh, self.resolve(*entries))

    def constrain(self, x, *entries):
        if not self.enabled:
            return x
        return jax.lax.with_sharding_constraint(x, self.sharding(*entries))

    def shard_leading(self, x, entry="dp"):
        """Constrain only the leading axis of ``x`` to a mesh entry.

        Rank-agnostic — used by the streaming Hessian accumulators, whose
        partial-sum arrays are (S, d, d) for dense weights and (S, E, d, d)
        for expert stacks: the shard axis lands on the data axes and every
        trailing dim stays unsharded, so accumulation is device-local until
        the one solve-time reduction."""
        if not self.enabled or x.ndim < 1:
            return x
        if x.shape[0] % max(self.axis_size(entry), 1) != 0:
            return x
        return self.constrain(x, entry, *([None] * (x.ndim - 1)))

    def constrain_act(self, x):
        """Sequence-parallel residual-stream constraint for (B, T, D)
        activations: batch over data axes and, when divisible, sequence over
        the model axis — this is what keeps the per-layer backward stash
        O(1/n_chips) instead of O(1/n_data).

        Decode (T == 1): shard D over the data axes instead.  The
        activation's d_model sharding then *matches* the FSDP (d_in) shard
        of every consuming weight, so matmuls run as local partials + one
        tiny psum(dp) of the activation — instead of all-gathering GBs of
        weights per generated token."""
        if not self.enabled:
            return x
        b, t, d = x.shape[0], x.shape[1], x.shape[-1]
        import math
        dp_size = math.prod(self.mesh.shape[a] for a in self.dp) if self.dp else 1
        tp_size = self.mesh.shape[self.tp] if self.tp else 1
        import os
        if t == 1 and b < dp_size and not os.environ.get("REPRO_BASELINE"):
            if self.mode == "decode" and d % self.axis_size("2d") == 0:
                e_d = "2d"
            elif self.dp and d % dp_size == 0:
                e_d = "dp"
            else:
                e_d = None
            return jax.lax.with_sharding_constraint(
                x, self.sharding(None, None, e_d))
        e_b = "dp" if (self.dp and b % dp_size == 0) else None
        e_t = "tp" if (self.tp and t % tp_size == 0 and t > 1) else None
        return jax.lax.with_sharding_constraint(
            x, self.sharding(e_b, e_t, None))


LOCAL = ParallelCtx()


# ---------------------------------------------------------------- param rules

_RULES: list[tuple[str, tuple]] = [
    # order matters: first match wins (patterns against the "/"-joined path)
    (r"embed$", ("tp", None)),  # vocab-parallel embedding table
    (r"head$", (None, "tp")),
    (r"experts/(wi|wu)$", ("tp", "dp", None)),
    (r"experts/wd$", ("tp", None, "dp")),
    (r"router$", (None, None)),
    (r"(wq|wk|wv|wi|wu|wzx|wdt|wq_b|wkv_b)$", ("dp", "tp")),
    (r"(wo|wd|out_proj)$", ("tp", "dp")),
    (r"(wq_a|wkv_a|wbc)$", ("dp", None)),
    (r"conv_x$", (None, "tp")),
    (r"conv_bc$", (None, None)),
    (r"(A_log|D|dt_bias)$", ("tp",)),
    (r"(bq|bk|bv)$", ("tp",)),
    (r".*", ()),  # norms / scalars / anything 1-D: replicated
]

# Decode-serving rules (§Perf iteration 2): weights are 2-D sharded on
# their OUTPUT dim over all mesh axes — no weight is ever gathered; the
# only per-matmul communication is a psum of the (B=small, 1, d) activation
# on the row-parallel side.  FSDP's d_in sharding is a *training* trade
# (grads reduce-scatter); at one token per step it turns into GBs of
# weight all-gathers per generated token.
_DECODE_RULES: list[tuple[str, tuple]] = [
    (r"embed$", ("tp", None)),
    (r"head$", (None, "2d")),
    (r"experts/(wi|wu)$", ("tp", None, "dp")),
    (r"experts/wd$", ("tp", "dp", None)),
    (r"router$", (None, None)),
    (r"(wq|wk|wv|wi|wu|wzx|wdt|wq_b|wkv_b)$", (None, "2d")),
    (r"(wo|wd|out_proj)$", ("2d", None)),
    (r"(wq_a|wkv_a|wbc)$", (None, "2d")),
    (r"conv_x$", (None, "tp")),
    (r"conv_bc$", (None, None)),
    (r"(A_log|D|dt_bias)$", ("tp",)),
    (r"(bq|bk|bv)$", ("2d",)),
    (r".*", ()),
]


def _spec_for(path: str, shape: tuple, stacked: bool, ctx: ParallelCtx) -> P:
    import os
    ndim = len(shape)
    rules = (_DECODE_RULES if ctx.mode == "decode"
             and not os.environ.get("REPRO_BASELINE") else _RULES)
    for pat, entries in rules:
        if re.search(pat, path):
            entries = list(entries)
            break
    if stacked:
        entries = [None] + entries
    # pad / trim to rank
    entries = (entries + [None] * ndim)[:ndim]
    # divisibility guard: drop axes the dim size can't be tiled over
    entries = [e if shape[i] % ctx.axis_size(e) == 0 else None
               for i, e in enumerate(entries)]
    # vocab-parallel embedding fallback: odd vocab -> shard d_model instead
    if re.search(r"embed$", path) and entries[0] is None and ctx.tp:
        if shape[1] % ctx.axis_size("tp") == 0:
            entries[1] = "tp"
    return ctx.resolve(*entries)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(params_tree: Any, ctx: ParallelCtx):
    """PartitionSpec pytree for a param (shape) tree.

    Leaves under a ``groups`` subtree carry a stacked leading layer axis."""

    def f(path, leaf):
        p = _path_str(path)
        return _spec_for(p, tuple(leaf.shape), stacked="groups" in p, ctx=ctx)

    return jax.tree_util.tree_map_with_path(f, params_tree)


def param_shardings(params_tree: Any, ctx: ParallelCtx):
    specs = param_specs(params_tree, ctx)
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
