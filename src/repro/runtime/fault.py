"""Fault-tolerant step runner: checkpoint/restart, failure injection,
straggler watchdog.

On a real cluster the failure signal is a lost host / NCCL-equivalent
timeout; here failures are injected as exceptions so the recovery path
(restore latest checkpoint -> reseek the data iterator -> continue) is
exercised end-to-end in tests.  Data is host-local + deterministic in
(seed, step) (see data/loader.py), so recovery needs no data service.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from repro.checkpoint import CheckpointManager


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class StepRunner:
    """Wraps a jitted train step with checkpointing + crash recovery."""

    step_fn: Callable  # (params, opt_state, batch, step) -> (p, s, loss)
    ckpt: CheckpointManager
    save_every: int = 50
    max_restarts: int = 3
    straggler_factor: float = 3.0  # warn when a step takes 3x the median

    def run(self, params, opt_state, loader, n_steps: int,
            fail_at: Optional[dict[int, int]] = None,
            log_every: int = 10) -> dict:
        """fail_at: {step: how_many_times_to_fail} — failure injection."""
        fail_at = dict(fail_at or {})
        restarts = 0
        losses = []
        times = []
        step = loader.step
        while step < n_steps:
            try:
                batch = next(loader)
                if fail_at.get(step, 0) > 0:
                    fail_at[step] -= 1
                    raise InjectedFailure(f"injected failure at step {step}")
                t0 = time.time()
                params, opt_state, loss = self.step_fn(
                    params, opt_state, batch, jax.numpy.int32(step))
                jax.block_until_ready(loss)
                dt = time.time() - t0
                times.append(dt)
                med = sorted(times)[len(times) // 2]
                if len(times) > 5 and dt > self.straggler_factor * med:
                    print(f"[straggler-watchdog] step {step} took {dt:.2f}s "
                          f"(median {med:.2f}s)", flush=True)
                losses.append(float(loss))
                if step % log_every == 0:
                    print(f"step {step}: loss {float(loss):.4f}", flush=True)
                step += 1
                loader.step = step
                if step % self.save_every == 0:
                    self.ckpt.save(step, {"params": params,
                                          "opt_state": opt_state},
                                   extra={"loader": loader.state()})
            except InjectedFailure as e:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                print(f"[fault] {e}; restoring latest checkpoint", flush=True)
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    # no checkpoint yet -> restart from the initial state is
                    # the caller's job; here we simply retry the step
                    continue
                _, state, extra = self.ckpt.restore()
                params, opt_state = state["params"], state["opt_state"]
                loader.restore(extra["loader"])
                step = loader.step
        self.ckpt.wait()
        self.ckpt.save(n_steps, {"params": params, "opt_state": opt_state},
                       extra={"loader": loader.state()}, blocking=True)
        return {"params": params, "opt_state": opt_state,
                "losses": losses, "restarts": restarts}
