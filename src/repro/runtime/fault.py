"""Fault-tolerant step running: checkpoint/restart, failure injection,
retry policy, straggler watchdog.

On a real cluster the failure signal is a lost host / NCCL-equivalent
timeout; here failures are injected as exceptions so the recovery path
(restore latest checkpoint -> reseek the data iterator -> continue) is
exercised end-to-end in tests.  Data is host-local + deterministic in
(seed, step) (see data/loader.py), so recovery needs no data service.

Shared policy objects (used by both the training ``StepRunner`` and the
quantize-path ``core.resume.QuantizeRunner``):

  * :class:`RetryPolicy` — which exception types are recoverable, how many
    restarts are allowed, and the exponential backoff between them.
  * :class:`FaultPlan` — stage-level failure injection for the quantize
    pipeline: arm a failure at any ``(layer, stage)`` point with
    ``stage in {"capture", "solve", "apply", "pack"}`` (optionally down to
    a batch index for the per-batch stages).  The schedulers
    (``core/scheduler``) call ``engine.stage_point`` at every stage
    dispatch point and the pipeline routes that into ``FaultPlan.check``.
  * :class:`EventLog` — structured events (restarts, stragglers,
    checkpoints) instead of bare prints: each event is a dict with a
    ``kind`` plus payload fields, collected on the runner and optionally
    forwarded to an ``on_event`` callback (a metrics hook on a real pod).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from repro.checkpoint import CheckpointManager


class InjectedFailure(RuntimeError):
    pass


STAGES = ("capture", "solve", "apply", "pack")

# Serving-engine stage points (repro.serving.engine): one scheduling round
# visits admit -> ingest -> burst -> retire, and the engine calls
# ``FaultPlan.check(round, stage)`` at each — same plan object, same CLI
# spec format (``ROUND:STAGE[:COUNT]`` via ``--fail-at-round``), so the
# quantize-side and serve-side fault matrices share one vocabulary.
SERVE_STAGES = ("admit", "ingest", "burst", "retire")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Which failures are survivable, and how to pace the restarts.

    ``recoverable`` is the exception-type tuple a runner treats as
    transient (preemption, injected failure, flaky collective); anything
    else propagates immediately.  Restart ``n`` (1-based) sleeps
    ``backoff_s * backoff_factor**(n-1)`` seconds, capped at
    ``max_backoff_s`` — exponential backoff so a persistently failing
    stage doesn't hot-loop the stack."""

    recoverable: tuple = (InjectedFailure,)
    max_restarts: int = 3
    backoff_s: float = 0.05
    backoff_factor: float = 2.0
    max_backoff_s: float = 30.0

    def is_recoverable(self, e: BaseException) -> bool:
        return isinstance(e, tuple(self.recoverable))

    def backoff(self, attempt: int) -> float:
        """Seconds to sleep before restart ``attempt`` (1-based)."""
        if self.backoff_s <= 0:
            return 0.0
        return min(self.backoff_s * self.backoff_factor ** max(attempt - 1, 0),
                   self.max_backoff_s)


class EventLog:
    """Structured runner events: appended dicts, optional sink callback."""

    def __init__(self, on_event: Optional[Callable[[dict], None]] = None,
                 verbose: bool = True):
        self.events: list[dict] = []
        self.on_event = on_event
        self.verbose = verbose

    def emit(self, kind: str, **fields) -> dict:
        ev = {"kind": kind, "time": time.time(), **fields}
        self.events.append(ev)
        if self.on_event is not None:
            self.on_event(ev)
        if self.verbose:
            body = " ".join(f"{k}={v}" for k, v in fields.items())
            print(f"[{kind}] {body}", flush=True)
        return ev

    def __iter__(self):
        return iter(self.events)

    def kinds(self) -> list[str]:
        return [e["kind"] for e in self.events]


@dataclasses.dataclass
class FaultPlan:
    """Stage-level failure injection for the quantize pipeline.

    ``fail_at`` maps an injection point to how many times it should fire:
    keys are ``(layer, stage)`` or — for the per-batch ``capture`` /
    ``apply`` stages — ``(layer, stage, batch)``.  ``check`` is called by
    ``RSQPipeline.stage_point`` right before the stage's device work is
    dispatched; an armed point raises ``exc`` (default
    :class:`InjectedFailure`) and records the firing in ``fired``.

    The serving engine reuses the same plan with ``layer`` meaning the
    scheduling *round* and ``stage`` one of :data:`SERVE_STAGES` — every
    check happens host-side before the stage's device dispatch, so state
    (pools, slot rows) is untouched when an injected failure fires and a
    retry re-runs the stage from identical inputs."""

    fail_at: dict
    exc: type = InjectedFailure
    fired: list = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.fail_at = dict(self.fail_at)
        for key in self.fail_at:
            stage = key[1]
            if stage not in STAGES + SERVE_STAGES:
                raise ValueError(f"unknown stage {stage!r}; one of "
                                 f"{STAGES + SERVE_STAGES}")

    def check(self, layer: int, stage: str, batch: Optional[int] = None
              ) -> None:
        keys = [(layer, stage)]
        if batch is not None:
            keys.insert(0, (layer, stage, batch))
        for key in keys:
            if self.fail_at.get(key, 0) > 0:
                self.fail_at[key] -= 1
                self.fired.append(
                    {"layer": layer, "stage": stage, "batch": batch})
                raise self.exc(
                    f"injected failure at layer {layer} stage {stage}"
                    + (f" batch {batch}" if batch is not None else ""))

    @classmethod
    def parse(cls, specs: list[str], **kw) -> "FaultPlan":
        """Build a plan from CLI specs ``LAYER:STAGE[:COUNT]``."""
        fail_at: dict = {}
        for s in specs:
            parts = s.split(":")
            if len(parts) not in (2, 3):
                raise ValueError(f"--fail-at wants LAYER:STAGE[:COUNT], "
                                 f"got {s!r}")
            layer, stage = int(parts[0]), parts[1]
            count = int(parts[2]) if len(parts) == 3 else 1
            fail_at[(layer, stage)] = count
        return cls(fail_at, **kw)


@dataclasses.dataclass
class StepRunner:
    """Wraps a jitted train step with checkpointing + crash recovery.

    Recovery policy is configurable: ``recoverable`` names the exception
    types that trigger a restore-latest-checkpoint restart (anything else
    propagates), with exponential backoff between restarts — the same
    policy object the quantize-path ``QuantizeRunner`` reuses.  The
    straggler watchdog emits a structured ``straggler`` event (see
    :class:`EventLog`) instead of a bare print."""

    step_fn: Callable  # (params, opt_state, batch, step) -> (p, s, loss)
    ckpt: CheckpointManager
    save_every: int = 50
    max_restarts: int = 3
    straggler_factor: float = 3.0  # warn when a step takes 3x the median
    recoverable: tuple = (InjectedFailure,)
    backoff_s: float = 0.0  # 0: restart immediately (test-friendly default)
    on_event: Optional[Callable[[dict], None]] = None

    def __post_init__(self):
        self.policy = RetryPolicy(recoverable=tuple(self.recoverable),
                                  max_restarts=self.max_restarts,
                                  backoff_s=self.backoff_s)
        self.events = EventLog(self.on_event, verbose=True)

    def run(self, params, opt_state, loader, n_steps: int,
            fail_at: Optional[dict[int, int]] = None,
            log_every: int = 10) -> dict:
        """fail_at: {step: how_many_times_to_fail} — failure injection."""
        fail_at = dict(fail_at or {})
        restarts = 0
        losses = []
        times = []
        step = loader.step
        while step < n_steps:
            try:
                batch = next(loader)
                if fail_at.get(step, 0) > 0:
                    fail_at[step] -= 1
                    raise InjectedFailure(f"injected failure at step {step}")
                t0 = time.time()
                params, opt_state, loss = self.step_fn(
                    params, opt_state, batch, jax.numpy.int32(step))
                jax.block_until_ready(loss)
                dt = time.time() - t0
                times.append(dt)
                med = sorted(times)[len(times) // 2]
                if len(times) > 5 and dt > self.straggler_factor * med:
                    self.events.emit("straggler", step=step,
                                     seconds=round(dt, 4),
                                     median_s=round(med, 4),
                                     factor=self.straggler_factor)
                losses.append(float(loss))
                if step % log_every == 0:
                    print(f"step {step}: loss {float(loss):.4f}", flush=True)
                step += 1
                loader.step = step
                if step % self.save_every == 0:
                    self.ckpt.save(step, {"params": params,
                                          "opt_state": opt_state},
                                   extra={"loader": loader.state()})
            except Exception as e:
                if not self.policy.is_recoverable(e):
                    raise
                restarts += 1
                if restarts > self.policy.max_restarts:
                    raise
                self.events.emit("restart", step=step, error=repr(e),
                                 attempt=restarts,
                                 backoff_s=self.policy.backoff(restarts))
                b = self.policy.backoff(restarts)
                if b:
                    time.sleep(b)
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    # no checkpoint yet -> restart from the initial state is
                    # the caller's job; here we simply retry the step
                    continue
                _, state, extra = self.ckpt.restore()
                params, opt_state = state["params"], state["opt_state"]
                loader.restore(extra["loader"])
                step = loader.step
        self.ckpt.wait()
        self.ckpt.save(n_steps, {"params": params, "opt_state": opt_state},
                       extra={"loader": loader.state()}, blocking=True)
        return {"params": params, "opt_state": opt_state,
                "losses": losses, "restarts": restarts,
                "events": list(self.events)}
