"""Manual-collective building blocks (shard_map level).

``allgather_matmul`` / ``matmul_reducescatter`` implement the
collective-matmul overlap (ring ppermute interleaved with partial matmuls —
the TPU analogue of Megatron's overlapped TP, and what the XLA latency
hiding scheduler pipelines on real hardware).

``ring_allreduce_int8`` is the gradient-compression collective: a ring
reduce-scatter that re-quantizes each hop to int8 with per-chunk scales,
followed by an all-gather of the int8 result; combined with the error
feedback in optim/compression it gives 4x cheaper gradient reduction over
the slow (DCN / inter-pod) axis.

``ring_psum`` is the exact (fp-on-the-wire) sibling: the same
bandwidth-optimal reduce-scatter + all-gather ring without requantization.
It is what the streaming-Hessian path uses for its *single* solve-time
reduction of per-device partial accumulators (core/distributed
``make_sharded_hessian_fn(streaming=True)``) — each chunk's sum is
computed on exactly one device and then broadcast, so every device ends
with bit-identical copies regardless of ring position.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _ring_perm(n: int, reverse: bool = False):
    if reverse:
        return [(i, (i - 1) % n) for i in range(n)]
    return [(i, (i + 1) % n) for i in range(n)]


def varying(x, axis_name):
    """Mark a device-invariant value as device-varying along ``axis_name``
    (needed for loop carries that become varying inside ring loops)."""
    try:
        return lax.pcast(x, (axis_name,), to="varying")
    except (AttributeError, TypeError, ValueError):
        return x  # already varying, or vma checking unavailable


def allgather_matmul(x: jax.Array, w: jax.Array, axis_name: str) -> jax.Array:
    """y = all_gather(x, axis) @ w, overlapped.

    x: (t_loc, d) — the local sequence shard; w: (d, f_loc) — the local
    column shard.  Returns (t_loc * n, f_loc).  Each ring step multiplies
    the currently-held shard while the next one is in flight."""
    n = lax.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    t_loc = x.shape[0]
    out = varying(jnp.zeros((t_loc * n, w.shape[1]), x.dtype), axis_name)

    def body(i, carry):
        x_cur, out = carry
        src = (idx - i) % n  # owner of the shard we currently hold
        part = x_cur @ w
        out = lax.dynamic_update_slice_in_dim(out, part, src * t_loc, 0)
        x_nxt = lax.ppermute(x_cur, axis_name, _ring_perm(n))
        return x_nxt, out

    _, out = lax.fori_loop(0, n, body, (x, out))
    return out


def matmul_reducescatter(x: jax.Array, w: jax.Array,
                         axis_name: str) -> jax.Array:
    """y = reduce_scatter(x @ w, axis) over the row dim, overlapped.

    x: (t, d_loc); w: (d_loc, f).  Returns the caller's (t/n, f) shard of
    sum_axis(x @ w): partial products for remote shards are computed first
    and accumulated around the ring."""
    n = lax.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    t = x.shape[0]
    assert t % n == 0
    t_loc = t // n

    def chunk(i):
        # row chunk owned by device (idx + i) % n
        owner = (idx + i) % n
        return lax.dynamic_slice_in_dim(x, owner * t_loc, t_loc, 0)

    # ring reduce-scatter recurrence: at step s device j works on chunk
    # (j + 1 + s) mod n; the value received from j+1 covers the same chunk
    acc = chunk(1) @ w

    def body(s, acc):
        acc = lax.ppermute(acc, axis_name, _ring_perm(n, reverse=True))
        return acc + chunk(s + 2) @ w

    acc = lax.fori_loop(0, n - 1, body, acc)
    return acc


def ring_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Exact ring all-reduce (reduce-scatter + all-gather, fp on the wire).

    x: per-device partial sums of identical shape (any leading dim — chunks
    are zero-padded to divide by the axis size).  Each chunk is reduced in
    a fixed ring-arrival order on its owner device and the finished chunk is
    then gathered, so all devices hold the *same* floats (no per-device
    summation-order skew), which is what lets the Hessian consumers treat
    the result as replicated."""
    n = lax.psum(1, axis_name)  # static axis size on every jax version
    if n == 1:
        return x
    orig = x.shape[0]
    pad = (-orig) % n
    xp = (jnp.concatenate(
        [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)]) if pad else x)
    idx = lax.axis_index(axis_name)
    c = xp.shape[0] // n

    def chunk(i):
        owner = (idx + i) % n
        return lax.dynamic_slice_in_dim(xp, owner * c, c, 0)

    # reduce-scatter: after n-1 hops device idx holds the full sum of its
    # own chunk (accumulated in ring order, identical for every device)
    acc = chunk(1)

    def rs_body(i, acc):
        acc = lax.ppermute(acc, axis_name, _ring_perm(n, reverse=True))
        return acc + chunk(i + 2)

    acc = lax.fori_loop(0, n - 1, rs_body, acc)

    # all-gather the reduced chunks
    out = varying(jnp.zeros_like(xp), axis_name)

    def ag_body(i, carry):
        acc, out = carry
        src = (idx - i) % n
        out = lax.dynamic_update_slice_in_dim(out, acc, src * c, 0)
        acc = lax.ppermute(acc, axis_name, _ring_perm(n))
        return acc, out

    _, out = lax.fori_loop(0, n, ag_body, (acc, out))
    return out[:orig] if pad else out


def ring_allreduce_int8(x: jax.Array, axis_name: str) -> jax.Array:
    """All-reduce with int8 on-the-wire (per-hop requantization).

    x: (n * c, ...) — the leading dim must divide by the axis size.  Each
    hop moves int8 codes + one fp32 scale per chunk instead of fp32 data."""
    n = lax.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    assert x.shape[0] % n == 0
    c = x.shape[0] // n
    xf = x.astype(jnp.float32)

    def q8(v):
        amax = jnp.max(jnp.abs(v))
        s = jnp.maximum(amax, 1e-12) / 127.0
        return jnp.clip(jnp.round(v / s), -127, 127).astype(jnp.int8), s

    def chunk(i):
        owner = (idx + i) % n
        return lax.dynamic_slice_in_dim(xf, owner * c, c, 0)

    # ring reduce-scatter: after n-1 hops, device idx holds the full sum of
    # chunk idx (quantized on every hop)
    q, s = q8(chunk(1))

    def rs_body(i, carry):
        q, s = carry
        q = lax.ppermute(q, axis_name, _ring_perm(n, reverse=True))
        s = lax.ppermute(s, axis_name, _ring_perm(n, reverse=True))
        acc = q.astype(jnp.float32) * s + chunk(i + 2)
        return q8(acc)

    if n > 1:
        q, s = lax.fori_loop(0, n - 1, rs_body, (q, s))
    else:
        q, s = q8(chunk(0))
    own = q.astype(jnp.float32) * s  # fully reduced local chunk

    # all-gather the int8-coded chunks back
    out = varying(jnp.zeros_like(xf), axis_name)
    qg, sg = q8(own)

    def ag_body(i, carry):
        qg, sg, out = carry
        src = (idx - i) % n
        out = lax.dynamic_update_slice_in_dim(
            out, qg.astype(jnp.float32) * sg, src * c, 0)
        qg = lax.ppermute(qg, axis_name, _ring_perm(n))
        sg = lax.ppermute(sg, axis_name, _ring_perm(n))
        return qg, sg, out

    _, _, out = lax.fori_loop(0, n, ag_body, (qg, sg, out))
    return out.astype(x.dtype)
