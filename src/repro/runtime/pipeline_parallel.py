"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

Stages hold disjoint layer slices; microbatches stream through a ring of
``ppermute`` transfers.  The schedule runs M + S - 1 ticks; stage s is
active for microbatches t - s in [0, M).  Bubble fraction = (S-1)/(M+S-1).

This is an optional runtime feature (the required production meshes are
DP x TP); it composes: wrap the per-stage step in shard_map over
("stage",) and keep DP/TP sharding inside each stage.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax


def pipeline_apply(stage_fn: Callable, params_stage, x_microbatches,
                   axis_name: str = "stage"):
    """Run inside shard_map over the stage axis.

    stage_fn(params_stage, x) -> y; params_stage: this device's stage
    params; x_microbatches: (M, mb, ...) — identical on every stage (only
    stage 0 consumes them).  Returns (M, mb, ...) outputs of the LAST stage
    (other stages return zeros)."""
    s = lax.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    m = x_microbatches.shape[0]
    mb_shape = x_microbatches.shape[1:]
    ticks = m + s - 1

    from repro.runtime.collectives import varying

    out = varying(jnp.zeros((m,) + mb_shape, x_microbatches.dtype), axis_name)
    carry_in = varying(jnp.zeros(mb_shape, x_microbatches.dtype), axis_name)

    def tick(t, state):
        carry_in, out = state
        mb_idx = t - idx  # microbatch this stage works on at tick t
        active = (mb_idx >= 0) & (mb_idx < m)
        # stage 0 reads fresh microbatches; others use the ring input
        x_in = jnp.where(
            idx == 0,
            x_microbatches[jnp.clip(mb_idx, 0, m - 1)],
            carry_in)
        y = stage_fn(params_stage, x_in)
        y = jnp.where(active, y, jnp.zeros_like(y))
        # last stage records its result
        out = jnp.where(
            (idx == s - 1) & active,
            out.at[jnp.clip(mb_idx, 0, m - 1)].set(y),
            out)
        # ring transfer to the next stage
        carry_next = lax.ppermute(
            y, axis_name, [(i, (i + 1) % s) for i in range(s)])
        return carry_next, out

    _, out = lax.fori_loop(0, ticks, tick, (carry_in, out))
    # only the last stage holds real outputs; broadcast them ring-wise
    out = lax.psum(jnp.where(idx == s - 1, out, jnp.zeros_like(out)),
                   axis_name)
    return out
