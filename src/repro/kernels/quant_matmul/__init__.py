from repro.kernels.quant_matmul.ops import quant_matmul  # noqa: F401
