from repro.kernels.quant_matmul.ops import (  # noqa: F401
    PackedWeight,
    is_packed,
    pack_weight,
    packed_weight_from_artifact,
    quant_matmul,
)
