from repro.kernels.quant_matmul.ops import (  # noqa: F401
    PackedWeight,
    is_packed,
    mla_latent_weights,
    pack_weight,
    packed_weight_from_artifact,
    quant_matmul,
    quant_matmul_t,
)
