"""Pure-jnp oracle: unpack + dequantize + matmul.

Off-TPU this *is* the serving path (``quant_matmul`` auto-selects it, the
Pallas kernel only runs interpret-mode there), so it matters that its
peak intermediate stays at the (k, n) fp32 dequantized weight — the same
footprint as the old load-time-dequant serving path — and never grows
with the token count: the per-group (scale, zero) are applied by
broadcasting over a (g, group_size, n) view of the codes (no
materialized ``jnp.repeat`` of the group params to (k, n), and no
token-dependent (m, g, n) partial-product blowup), followed by one plain
matmul that XLA partitions/fuses like any other GEMM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantizer import unpack_codes


def quant_matmul_ref(x: jax.Array, w_packed: jax.Array, scale: jax.Array,
                     zero: jax.Array, *, bits: int, group_size: int,
                     d_in: int | None = None) -> jax.Array:
    k = d_in if d_in is not None else x.shape[-1]
    n = w_packed.shape[-1]
    g = scale.shape[-2]
    assert g * group_size == k, (g, group_size, k)
    codes = unpack_codes(w_packed, bits, k).astype(jnp.float32)  # (k, n)
    wg = (codes.reshape(g, group_size, n) - zero.astype(jnp.float32)[:, None])
    w = (wg * scale.astype(jnp.float32)[:, None]).reshape(k, n)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def quant_matmul_t_ref(x: jax.Array, w_packed: jax.Array, scale: jax.Array,
                       zero: jax.Array, *, bits: int, group_size: int,
                       d_in: int) -> jax.Array:
    """Transposed (latent-layout) oracle: y = x @ dequant(W)ᵀ.

    x: (m, d); w_packed: (d_in // vpw, d) packed along its first axis;
    scale/zero: (d_in // gs, d).  Returns (m, d_in).  Mirrors
    :func:`quant_matmul_ref`'s footprint discipline: the peak intermediate
    is the (d_in, d) fp32 dequantized weight formed through the grouped
    (g, gs, d) view — never an (m, g, d) partial-product blowup — followed
    by one transposed contraction XLA partitions like any GEMM (this *is*
    the MLA absorbed-decode path off-TPU and under GSPMD-sharded codes)."""
    k = d_in
    d = w_packed.shape[-1]
    g = scale.shape[-2]
    assert g * group_size == k, (g, group_size, k)
    codes = unpack_codes(w_packed, bits, k).astype(jnp.float32)  # (k, d)
    wg = (codes.reshape(g, group_size, d) - zero.astype(jnp.float32)[:, None])
    w = (wg * scale.astype(jnp.float32)[:, None]).reshape(k, d)
    return jax.lax.dot_general(
        x.astype(jnp.float32), w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)
