"""Pure-jnp oracle: unpack + dequantize + matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantizer import unpack_codes


def quant_matmul_ref(x: jax.Array, w_packed: jax.Array, scale: jax.Array,
                     zero: jax.Array, *, bits: int, group_size: int,
                     d_in: int | None = None) -> jax.Array:
    k = d_in if d_in is not None else x.shape[-1]
    codes = unpack_codes(w_packed, bits, k).astype(jnp.float32)
    s = jnp.repeat(scale.astype(jnp.float32), group_size, axis=0)[:k]
    z = jnp.repeat(zero.astype(jnp.float32), group_size, axis=0)[:k]
    w = s * (codes - z)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)
