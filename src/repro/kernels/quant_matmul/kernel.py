"""Packed weight-only quantized matmul: y = x @ dequant(W_packed).

TPU analogue of Marlin-style CUDA WoQ GEMMs: int codes are packed
``values_per_word`` per uint32 along d_in; the kernel unpacks a
(k_blk, n_blk) weight tile in VMEM with shift/mask VPU ops, applies the
per-group (scale, zero), and feeds the MXU in the compute dtype.  Packing
cuts HBM weight traffic by 16/bits vs bf16 — decode-shape GEMMs are
memory-bound, so that factor is the speedup bound.

Two kernels share the tile dequant:

  * :func:`quant_matmul_pallas`     — y = x @ dequant(W), the forward GEMM
    (contraction over the packed d_in axis).
  * :func:`quant_matmul_t_pallas`   — y = x @ dequant(W)ᵀ, the *latent
    layout* used by MLA's absorbed decode: W stays packed along its first
    axis (kvr), the contraction runs over the columns (per-head dn / dv),
    and the packed axis becomes the output.  Same HBM story — the weight
    is read packed either way — so absorbed decode stops being the one
    step that re-materialized an fp weight per token.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams


def _dequant_tile(wq_ref, scale_ref, zero_ref, *, bits: int, vpw: int,
                  rows: int):
    """Unpack + dequantize one (rows, cols) weight tile in VMEM.

    ``wq_ref``: (rows // vpw, cols) uint32; ``scale_ref``/``zero_ref``:
    (rows // gs, cols).  Shift/mask unpack on the VPU, then the per-group
    affine — shared by the forward and the transposed (latent) kernel."""
    wq = wq_ref[...]
    shifts = (jnp.arange(vpw, dtype=jnp.uint32) * bits)[None, :, None]
    mask = jnp.uint32(2 ** bits - 1)
    codes = ((wq[:, None, :] >> shifts) & mask).astype(jnp.float32)
    codes = codes.reshape(rows, -1)
    scale = scale_ref[...].astype(jnp.float32)
    zero = zero_ref[...].astype(jnp.float32)
    reps = rows // scale.shape[0]
    scale = jnp.repeat(scale, reps, axis=0)
    zero = jnp.repeat(zero, reps, axis=0)
    return scale * (codes - zero)


def _qmm_kernel(x_ref, wq_ref, scale_ref, zero_ref, o_ref, *,
                bits: int, vpw: int, group_size: int, k_blk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (m_blk, k_blk)
    w = _dequant_tile(wq_ref, scale_ref, zero_ref, bits=bits, vpw=vpw,
                      rows=k_blk)  # (k_blk, n_blk)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=(
    "bits", "group_size", "m_blk", "n_blk", "k_blk", "interpret"))
def quant_matmul_pallas(x: jax.Array, w_packed: jax.Array, scale: jax.Array,
                        zero: jax.Array, *, bits: int, group_size: int,
                        m_blk: int = 128, n_blk: int = 256, k_blk: int = 512,
                        interpret: bool = True) -> jax.Array:
    """x: (m, k); w_packed: (k // vpw, n) uint32; scale/zero: (k // gs, n).

    Returns (m, n) in x.dtype (fp32 accumulation)."""
    m, k = x.shape
    vpw = 32 // bits
    n = w_packed.shape[1]
    m_blk = min(m_blk, m)
    n_blk = min(n_blk, n)
    k_blk = min(k_blk, k)
    assert m % m_blk == 0 and n % n_blk == 0 and k % k_blk == 0
    assert k_blk % vpw == 0 and k_blk % group_size == 0
    kernel = functools.partial(_qmm_kernel, bits=bits, vpw=vpw,
                               group_size=group_size, k_blk=k_blk)
    grid = (m // m_blk, n // n_blk, k // k_blk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m_blk, k_blk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((k_blk // vpw, n_blk), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((k_blk // group_size, n_blk),
                         lambda i, j, kk: (kk, j)),
            pl.BlockSpec((k_blk // group_size, n_blk),
                         lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((m_blk, n_blk), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_packed, scale, zero)
    return out.astype(x.dtype)


def _qmm_t_kernel(x_ref, wq_ref, scale_ref, zero_ref, o_ref, *,
                  bits: int, vpw: int, k_blk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (m_blk, d_blk)
    w = _dequant_tile(wq_ref, scale_ref, zero_ref, bits=bits, vpw=vpw,
                      rows=k_blk)  # (k_blk, d_blk)
    # contract the (unpacked) columns: (m, d) x (k, d) -> (m, k)
    o_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=(
    "bits", "group_size", "m_blk", "k_blk", "d_blk", "interpret"))
def quant_matmul_t_pallas(x: jax.Array, w_packed: jax.Array,
                          scale: jax.Array, zero: jax.Array, *, bits: int,
                          group_size: int, m_blk: int = 128,
                          k_blk: int = 256, d_blk: int = 512,
                          interpret: bool = True) -> jax.Array:
    """Transposed (latent-layout) packed GEMM: y = x @ dequant(W)ᵀ.

    x: (m, d); w_packed: (k // vpw, d) uint32 packed along its *first*
    axis (the quantized d_in, e.g. MLA's kv_lora_rank); scale/zero:
    (k // gs, d).  Returns (m, k) in x.dtype (fp32 accumulation) — the
    packed axis is the *output* here, the reduction runs over the weight's
    columns, and the codes are never unpacked outside a VMEM tile."""
    m, d = x.shape
    vpw = 32 // bits
    k = w_packed.shape[0] * vpw
    m_blk = min(m_blk, m)
    k_blk = min(k_blk, k)
    d_blk = min(d_blk, d)
    assert m % m_blk == 0 and k % k_blk == 0 and d % d_blk == 0
    assert k_blk % vpw == 0 and k_blk % group_size == 0
    kernel = functools.partial(_qmm_t_kernel, bits=bits, vpw=vpw,
                               k_blk=k_blk)
    grid = (m // m_blk, k // k_blk, d // d_blk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m_blk, d_blk), lambda i, j, dd: (i, dd)),
            pl.BlockSpec((k_blk // vpw, d_blk), lambda i, j, dd: (j, dd)),
            pl.BlockSpec((k_blk // group_size, d_blk),
                         lambda i, j, dd: (j, dd)),
            pl.BlockSpec((k_blk // group_size, d_blk),
                         lambda i, j, dd: (j, dd)),
        ],
        out_specs=pl.BlockSpec((m_blk, k_blk), lambda i, j, dd: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_packed, scale, zero)
    return out.astype(x.dtype)
