"""Public wrapper: weight-only quantized GEMM for serving.

Use ``pack_weight`` once offline (after the RSQ pipeline), then
``quant_matmul(x, packed)`` at serving time.  Only power-of-two bit widths
ride the packed kernel (int3 packing wastes 2 bits/word and breaks the
k-tiling alignment; 3-bit deployments dequantize via ref — documented in
DESIGN.md)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.quantizer import QuantSpec, pack_codes
from repro.kernels.quant_matmul.kernel import quant_matmul_pallas
from repro.kernels.quant_matmul.ref import quant_matmul_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@dataclasses.dataclass
class PackedWeight:
    w_packed: jax.Array  # (k // vpw, n) uint32
    scale: jax.Array  # (k // gs, n)
    zero: jax.Array
    bits: int
    group_size: int
    d_in: int


def pack_weight(q: jax.Array, scale: jax.Array, zero: jax.Array,
                spec: QuantSpec) -> PackedWeight:
    d_in = q.shape[0]
    gs = d_in if spec.group_size == -1 else spec.group_size
    return PackedWeight(
        w_packed=pack_codes(q, spec.bits), scale=scale, zero=zero,
        bits=spec.bits, group_size=gs, d_in=d_in)


def packed_weight_from_artifact(entry: dict, em: dict,
                                spec: dict) -> PackedWeight:
    """Packed-artifact entry (``checkpoint.packed``) -> ``PackedWeight``.

    The codes move host->device still packed and ``quant_matmul`` consumes
    them directly — the serving path never unpacks on host.  ``entry`` is
    one ``load_packed_artifact`` entry, ``em``/``spec`` its per-entry and
    artifact-level metadata."""
    codes = jnp.asarray(entry["codes"])
    assert codes.ndim == 2, "quant_matmul serves dense 2-D weights " \
        f"(expert stacks dequantize via checkpoint.packed): {codes.shape}"
    return PackedWeight(
        w_packed=codes, scale=jnp.asarray(entry["scale"]),
        zero=jnp.asarray(entry["zero"]), bits=int(spec["bits"]),
        group_size=int(em["group_size"]), d_in=int(em["d_in"]))


def quant_matmul(x: jax.Array, pw: PackedWeight) -> jax.Array:
    m, k = x.shape
    vpw = 32 // pw.bits
    aligned = (32 % pw.bits == 0 and pw.d_in % vpw == 0
               and k % 128 == 0 and pw.w_packed.shape[1] % 128 == 0
               and m % 8 == 0)
    if not aligned or pw.bits == 3:
        return quant_matmul_ref(x, pw.w_packed, pw.scale, pw.zero,
                                bits=pw.bits, group_size=pw.group_size,
                                d_in=pw.d_in)
    k_blk = 512
    while k % k_blk or k_blk % pw.group_size:
        k_blk //= 2
    m_blk = 128
    while m % m_blk:
        m_blk //= 2
    n = pw.w_packed.shape[1]
    n_blk = 256
    while n % n_blk:
        n_blk //= 2
    return quant_matmul_pallas(
        x, pw.w_packed, pw.scale, pw.zero, bits=pw.bits,
        group_size=pw.group_size, m_blk=m_blk, n_blk=n_blk, k_blk=k_blk,
        interpret=_interpret())
