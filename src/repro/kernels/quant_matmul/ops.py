"""Public wrapper: weight-only quantized GEMM for serving.

``PackedWeight`` is the serving-side parameter type for a quantized dense
projection: a registered JAX pytree whose leaves are the packed uint32
codes plus the per-group ``(scale, zero)`` and whose aux data carries the
static quant geometry ``(bits, group_size, d_in)``.  Because it is a
pytree it drops into a param tree anywhere an fp ``(d_in, d_out)`` matrix
used to live — ``jax.jit``/``lax.scan``/``jax.vmap`` trace straight
through it (a stacked group of layers is simply a ``PackedWeight`` whose
leaves carry a leading layer axis, sliced by the scan like any other
param), and the model's ``linear`` dispatcher
(``models.layers.linear``) routes it through :func:`quant_matmul` instead
of ``x @ w``.

Use ``pack_weight`` once offline (after the RSQ pipeline), or build one
straight from a packed serving artifact with
``packed_weight_from_artifact``; then ``quant_matmul(x, packed)`` at
serving time.  Only power-of-two bit widths ride the packed kernel (int3
packing wastes 2 bits/word and breaks the k-tiling alignment; 3-bit
deployments dequantize via ref — documented in DESIGN.md).  Decode-shape
inputs (m = batch, not a sublane multiple of 8) are padded up to 8 inside
the wrapper and the output sliced back, so single-token decode stays on
the Pallas kernel instead of bouncing to the slow ref path.

Kernel policy (shared by every entry point here): the Pallas kernel on
TPU, the fused-XLA ref elsewhere — same as the gram kernel.  The
``REPRO_QMM_KERNEL`` env var overrides the default (``1`` forces the
kernel — interpret-mode off-TPU, a correctness/CI tool; ``0`` forces the
ref); an explicit ``use_kernel=`` argument beats both.

Mesh-sharded weights (``PackedWeight.mesh_sharded``, set by
``checkpoint.packed.load_packed_forward_params``) used to be ref-only:
the Pallas kernel is an opaque custom call GSPMD would service by
all-gathering the full codes per device.  They now run the fused kernel
through ``shard_map`` over the model axis instead (:func:`quant_matmul`
routes there automatically): the codes are d_out-sharded, so each shard
runs the kernel on its local (k, n/axis) tile with *zero* weight
collectives — no weight ever moves, which is the decode-serving
layout's whole point.  The GSPMD-partitionable ref remains
the fallback for meshless callers, non-TPU backends (unless the kernel is
forced), misaligned local tiles, 3-bit, and expert stacks under ``vmap``.
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.quantizer import QuantSpec, pack_codes
from repro.kernels.quant_matmul.kernel import (quant_matmul_pallas,
                                               quant_matmul_t_pallas)
from repro.kernels.quant_matmul.ref import (quant_matmul_ref,
                                            quant_matmul_t_ref)

try:  # jax >= 0.4.35
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _kernel_default() -> bool:
    """Backend kernel policy with the ``REPRO_QMM_KERNEL`` env override."""
    env = os.environ.get("REPRO_QMM_KERNEL")
    if env is not None:
        return env.strip().lower() not in ("", "0", "false", "off")
    return jax.default_backend() == "tpu"


def _smap(fn, mesh, in_specs, out_specs):
    """shard_map with replication checking off, across the jax rename
    (``check_rep`` -> ``check_vma``): the Pallas custom call has no
    replication rule for the checker to consult."""
    try:
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    except TypeError:  # pragma: no cover - depends on jax version
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class PackedWeight:
    """Packed quantized projection: pytree leaves (w_packed, scale, zero),
    static aux (bits, group_size, d_in).

    ``w_packed``: (..., d_in // vpw, d_out) uint32; ``scale``/``zero``:
    (..., d_in // group_size, d_out).  Leading batch axes (stacked layer
    groups, expert stacks) are carried by the leaves and stay invisible to
    the static aux — exactly what lets a stacked ``PackedWeight`` ride a
    ``lax.scan`` over layers or a ``jax.vmap`` over experts."""

    w_packed: jax.Array  # (k // vpw, n) uint32
    scale: jax.Array  # (k // gs, n)
    zero: jax.Array
    bits: int
    group_size: int
    d_in: int
    # codes partitioned across a live mesh (set by
    # checkpoint.packed.load_packed_forward_params): ``mesh``/``mesh_axis``
    # name the d_out shard placement so quant_matmul can wrap the Pallas
    # kernel in shard_map over that axis (per-shard fused GEMMs, no code
    # all-gather); when the shard_map route can't run (no kernel, ragged
    # local tile, expert stacks under vmap) the flag keeps the codes on
    # the GSPMD-partitionable ref GEMM instead of the opaque custom call.
    mesh_sharded: bool = False
    mesh: Mesh | None = None
    mesh_axis: str | None = None

    def tree_flatten_with_keys(self):
        children = tuple(
            (jax.tree_util.GetAttrKey(f), getattr(self, f))
            for f in ("w_packed", "scale", "zero"))
        return children, (self.bits, self.group_size, self.d_in,
                          self.mesh_sharded, self.mesh, self.mesh_axis)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def nbytes(self) -> int:
        """Resident HBM bytes of the packed representation."""
        return sum(int(a.size) * a.dtype.itemsize
                   for a in (self.w_packed, self.scale, self.zero))


def is_packed(w) -> bool:
    return isinstance(w, PackedWeight)


def pack_weight(q: jax.Array, scale: jax.Array, zero: jax.Array,
                spec: QuantSpec) -> PackedWeight:
    d_in = q.shape[-2]
    gs = d_in if spec.group_size == -1 else spec.group_size
    return PackedWeight(
        w_packed=pack_codes(q, spec.bits), scale=scale, zero=zero,
        bits=spec.bits, group_size=gs, d_in=d_in)


def packed_weight_from_artifact(entry: dict, em: dict,
                                spec: dict) -> PackedWeight:
    """Packed-artifact entry (``checkpoint.packed``) -> ``PackedWeight``.

    The codes move host->device still packed and ``quant_matmul`` consumes
    them directly — the serving path never unpacks on host.  ``entry`` is
    one ``load_packed_artifact`` entry, ``em``/``spec`` its per-entry and
    artifact-level metadata.  Expert stacks arrive with a leading (E,)
    axis on every leaf and dispatch through the vmapped kernel
    (``models.layers.linear``)."""
    codes = jnp.asarray(entry["codes"])
    assert codes.ndim in (2, 3), \
        f"dense (k/vpw, n) or expert-stacked (E, k/vpw, n) codes: {codes.shape}"
    return PackedWeight(
        w_packed=codes, scale=jnp.asarray(entry["scale"]),
        zero=jnp.asarray(entry["zero"]), bits=int(spec["bits"]),
        group_size=int(em["group_size"]), d_in=int(em["d_in"]))


def _k_tile(k: int, group_size: int) -> int:
    """Largest power-of-two reduction tile <= 512 that divides k and holds
    whole quant groups (0 when none exists — kernel can't tile)."""
    k_blk = 512
    while k_blk and (k % k_blk or k_blk % group_size):
        k_blk //= 2
    return k_blk


def _shard_map_matmul(x: jax.Array, pw: PackedWeight) -> jax.Array | None:
    """Mesh-sharded fused route: the Pallas kernel per d_out shard.

    The codes (and the per-group scale/zero) are partitioned on their last
    axis over ``pw.mesh_axis``; ``shard_map`` hands each device its local
    (k/vpw, n_local) tile and the kernel runs on it exactly as in the
    unsharded case — no code all-gather (the very collective GSPMD would
    insert around the opaque custom call), no output collective (the
    result stays d_out-sharded, the decode activation layout).  The
    activation is the only replicated operand — a deliberate trade: row-
    sharding m over the data axes would save the dp-fold duplicate GEMM
    work at prefill, but XLA's GEMM accumulation order depends on m, so
    the per-row results stop being bit-identical to the GSPMD ref and
    greedy tokens drift off the dequantized reference (measured:
    ~4e-5 logit deltas, token flips within 8 steps).  Decode — the shape
    this kernel exists for — has a tiny m where replication is the right
    layout anyway; revisiting prefill row-sharding under a tolerance-
    based parity contract is a recorded ROADMAP item.  Returns None when
    the local tile can't align to the kernel (caller falls back to the
    ref GEMM, which partitions under GSPMD like any GEMM)."""
    mesh, axis = pw.mesh, pw.mesh_axis
    n = pw.w_packed.shape[1]
    axis_size = mesh.shape[axis]
    if n % axis_size:
        return None
    n_loc = n // axis_size
    m, k = x.shape
    vpw = 32 // pw.bits
    k_blk = _k_tile(k, pw.group_size)
    aligned = (pw.d_in % vpw == 0 and k % 128 == 0 and n_loc % 128 == 0
               and k_blk)
    if not aligned:
        return None
    m_pad = (-m) % 8
    if m_pad:
        x = jnp.concatenate([x, jnp.zeros((m_pad, k), x.dtype)], axis=0)
    m_blk = 128
    while x.shape[0] % m_blk:
        m_blk //= 2
    n_blk = 256
    while n_loc % n_blk:
        n_blk //= 2

    def local(xs, wq, sc, zr):
        return quant_matmul_pallas(
            xs, wq, sc, zr, bits=pw.bits, group_size=pw.group_size,
            m_blk=m_blk, n_blk=n_blk, k_blk=k_blk, interpret=_interpret())

    out = _smap(local, mesh,
                in_specs=(P(None, None), P(None, axis), P(None, axis),
                          P(None, axis)),
                out_specs=P(None, axis))(x, pw.w_packed, pw.scale, pw.zero)
    return out[:m] if m_pad else out


def quant_matmul(x: jax.Array, pw: PackedWeight, *,
                 use_kernel: bool | None = None,
                 shard: bool = True) -> jax.Array:
    """y = x @ dequant(pw).  x: (m, k) -> (m, n), fp32 accumulation.

    Decode shapes (m not a multiple of the 8-row sublane tile) are padded
    up to 8 and the output sliced back — a single generated token per
    sequence must not demote the GEMM to the unfused ref path, since the
    packed kernel's 16/bits HBM-traffic win is exactly what decode (a
    memory-bound shape) is serving for.

    ``use_kernel``: None (default) auto-selects the Pallas kernel on TPU
    and the jnp ref elsewhere — the same policy as the gram kernel
    (``RSQConfig.use_gram_kernel``); the ``REPRO_QMM_KERNEL`` env var
    overrides the default (interpret mode off-TPU is a correctness tool
    that would serialize the serving hot loop).  Mesh-sharded codes
    (``pw.mesh_sharded``) run the kernel *per shard* under shard_map over
    the model axis (see :func:`_shard_map_matmul`) — an opaque custom
    call must never reach GSPMD, which would all-gather the codes —
    falling back to the ref when the local tile is ragged.  ``shard=False``
    disables the shard_map route (the vmapped expert-stack dispatch sets
    it: shard_map can't nest under vmap).  The ref is a fused XLA
    unpack+dequant+matmul on the same packed codes — resident HBM stays
    packed either way."""
    m, k = x.shape
    vpw = 32 // pw.bits
    if use_kernel is None:
        use_kernel = _kernel_default()
    if pw.mesh_sharded:
        if (shard and use_kernel and pw.mesh is not None and pw.mesh_axis
                and pw.bits != 3 and 32 % pw.bits == 0
                and pw.w_packed.ndim == 2):
            out = _shard_map_matmul(x, pw)
            if out is not None:
                return out
        return quant_matmul_ref(x, pw.w_packed, pw.scale, pw.zero,
                                bits=pw.bits, group_size=pw.group_size,
                                d_in=pw.d_in)
    aligned = (32 % pw.bits == 0 and pw.d_in % vpw == 0
               and k % 128 == 0 and pw.w_packed.shape[1] % 128 == 0)
    # the k tile must divide k and contain whole quant groups; when no
    # power-of-two tile <= 512 does both (per-tensor groups with a large
    # d_in, group_size > 512, non-power-of-two groups) the kernel can't
    # tile the reduction — serve via ref like the 3-bit case
    k_blk = _k_tile(k, pw.group_size)
    if not (aligned and use_kernel and k_blk) or pw.bits == 3:
        return quant_matmul_ref(x, pw.w_packed, pw.scale, pw.zero,
                                bits=pw.bits, group_size=pw.group_size,
                                d_in=pw.d_in)
    m_pad = (-m) % 8
    if m_pad:
        x = jnp.concatenate([x, jnp.zeros((m_pad, k), x.dtype)], axis=0)
    m_blk = 128
    while x.shape[0] % m_blk:
        m_blk //= 2
    n = pw.w_packed.shape[1]
    n_blk = 256
    while n % n_blk:
        n_blk //= 2
    out = quant_matmul_pallas(
        x, pw.w_packed, pw.scale, pw.zero, bits=pw.bits,
        group_size=pw.group_size, m_blk=m_blk, n_blk=n_blk, k_blk=k_blk,
        interpret=_interpret())
    return out[:m] if m_pad else out


def quant_matmul_t(x: jax.Array, pw: PackedWeight, *,
                   use_kernel: bool | None = None) -> jax.Array:
    """Latent-layout GEMM: y = x @ dequant(pw)ᵀ.  x: (m, d) -> (m, d_in).

    The contraction runs over the weight's *columns* while the codes stay
    packed along d_in (which becomes the output axis) — the layout MLA's
    absorbed decode needs to contract the per-head-reshaped ``wkv_b``
    against queries/attention outputs without ever materializing the fp
    weight (``models.attention.mla_decode``).  Kernel policy matches
    :func:`quant_matmul`; mesh-sharded codes take the GSPMD ref (the
    per-head latent contractions are tiny and column-sharded)."""
    m, d = x.shape
    vpw = 32 // pw.bits
    if use_kernel is None:
        use_kernel = _kernel_default()
    k_blk = _k_tile(pw.d_in, pw.group_size)
    aligned = (32 % pw.bits == 0 and pw.d_in % vpw == 0
               and pw.d_in % 128 == 0 and d % 128 == 0
               and pw.w_packed.shape[0] * vpw == pw.d_in
               and k_blk and k_blk % vpw == 0)
    if (not (aligned and use_kernel) or pw.bits == 3 or pw.mesh_sharded):
        return quant_matmul_t_ref(x, pw.w_packed, pw.scale, pw.zero,
                                  bits=pw.bits, group_size=pw.group_size,
                                  d_in=pw.d_in)
    m_pad = (-m) % 8
    if m_pad:
        x = jnp.concatenate([x, jnp.zeros((m_pad, d), x.dtype)], axis=0)
    m_blk = 128
    while x.shape[0] % m_blk:
        m_blk //= 2
    d_blk = 512
    while d % d_blk:
        d_blk //= 2
    out = quant_matmul_t_pallas(
        x, pw.w_packed, pw.scale, pw.zero, bits=pw.bits,
        group_size=pw.group_size, m_blk=m_blk, k_blk=k_blk,
        d_blk=d_blk, interpret=_interpret())
    return out[:m] if m_pad else out


def mla_latent_weights(pw: PackedWeight, n_heads: int, dn: int, dv: int,
                       ) -> tuple[PackedWeight, PackedWeight]:
    """Per-head latent views of a packed MLA ``wkv_b``: (pw_k, pw_v).

    ``wkv_b`` is quantized as one (kvr, H*(dn+dv)) matrix; absorbed decode
    contracts its two halves per head.  Packing runs along d_in (=kvr), so
    slicing/reshaping the *column* axis is exact on the packed codes — no
    unpack, no dequant: both views share the original leaves' memory
    modulo the transpose.  Returns

      * ``pw_k`` — (H, kvr//vpw, dn) codes (+ per-head group params), fed
        to ``vmap(quant_matmul_t)``: absorb W_k into the per-head query,
        q_lat = q_nope @ W_kᵀ, output in latent space (kvr).
      * ``pw_v`` — (H, kvr//vpw, dv) codes, fed to ``vmap(quant_matmul)``:
        expand the per-head latent attention output, ctx = ctx_lat @ W_v.

    Mesh placement: the parent's last axis (H*(dn+dv)) shards over the
    model axis, so the per-head views inherit head-sharded leaves; they
    keep ``mesh_sharded`` (the vmapped ops stay on the GSPMD ref — the
    shard_map route doesn't nest under vmap)."""
    codes = pw.w_packed.reshape(pw.w_packed.shape[0], n_heads, dn + dv)
    scale = pw.scale.reshape(pw.scale.shape[0], n_heads, dn + dv)
    zero = pw.zero.reshape(pw.zero.shape[0], n_heads, dn + dv)

    def head_view(lo, hi):
        return (codes[:, :, lo:hi].transpose(1, 0, 2),
                scale[:, :, lo:hi].transpose(1, 0, 2),
                zero[:, :, lo:hi].transpose(1, 0, 2))

    def mk(leaves):
        return PackedWeight(
            w_packed=leaves[0], scale=leaves[1], zero=leaves[2],
            bits=pw.bits, group_size=pw.group_size, d_in=pw.d_in,
            mesh_sharded=pw.mesh_sharded, mesh=pw.mesh,
            mesh_axis=pw.mesh_axis)

    return mk(head_view(0, dn)), mk(head_view(dn, dn + dv))
