"""Public wrapper: weight-only quantized GEMM for serving.

``PackedWeight`` is the serving-side parameter type for a quantized dense
projection: a registered JAX pytree whose leaves are the packed uint32
codes plus the per-group ``(scale, zero)`` and whose aux data carries the
static quant geometry ``(bits, group_size, d_in)``.  Because it is a
pytree it drops into a param tree anywhere an fp ``(d_in, d_out)`` matrix
used to live — ``jax.jit``/``lax.scan``/``jax.vmap`` trace straight
through it (a stacked group of layers is simply a ``PackedWeight`` whose
leaves carry a leading layer axis, sliced by the scan like any other
param), and the model's ``linear`` dispatcher
(``models.layers.linear``) routes it through :func:`quant_matmul` instead
of ``x @ w``.

Use ``pack_weight`` once offline (after the RSQ pipeline), or build one
straight from a packed serving artifact with
``packed_weight_from_artifact``; then ``quant_matmul(x, packed)`` at
serving time.  Only power-of-two bit widths ride the packed kernel (int3
packing wastes 2 bits/word and breaks the k-tiling alignment; 3-bit
deployments dequantize via ref — documented in DESIGN.md).  Decode-shape
inputs (m = batch, not a sublane multiple of 8) are padded up to 8 inside
the wrapper and the output sliced back, so single-token decode stays on
the Pallas kernel instead of bouncing to the slow ref path.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.quantizer import QuantSpec, pack_codes
from repro.kernels.quant_matmul.kernel import quant_matmul_pallas
from repro.kernels.quant_matmul.ref import quant_matmul_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class PackedWeight:
    """Packed quantized projection: pytree leaves (w_packed, scale, zero),
    static aux (bits, group_size, d_in).

    ``w_packed``: (..., d_in // vpw, d_out) uint32; ``scale``/``zero``:
    (..., d_in // group_size, d_out).  Leading batch axes (stacked layer
    groups, expert stacks) are carried by the leaves and stay invisible to
    the static aux — exactly what lets a stacked ``PackedWeight`` ride a
    ``lax.scan`` over layers or a ``jax.vmap`` over experts."""

    w_packed: jax.Array  # (k // vpw, n) uint32
    scale: jax.Array  # (k // gs, n)
    zero: jax.Array
    bits: int
    group_size: int
    d_in: int
    # codes are partitioned across a live mesh (set by
    # checkpoint.packed.load_packed_forward_params): the Pallas kernel is
    # an opaque custom call GSPMD would service by all-gathering the full
    # codes per device, so mesh-sharded weights stay on the jnp ref,
    # which partitions like any GEMM.  A shard_map-wrapped kernel (the
    # gram-kernel precedent) is the recorded ROADMAP follow-up.
    mesh_sharded: bool = False

    def tree_flatten_with_keys(self):
        children = tuple(
            (jax.tree_util.GetAttrKey(f), getattr(self, f))
            for f in ("w_packed", "scale", "zero"))
        return children, (self.bits, self.group_size, self.d_in,
                          self.mesh_sharded)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def nbytes(self) -> int:
        """Resident HBM bytes of the packed representation."""
        return sum(int(a.size) * a.dtype.itemsize
                   for a in (self.w_packed, self.scale, self.zero))


def is_packed(w) -> bool:
    return isinstance(w, PackedWeight)


def pack_weight(q: jax.Array, scale: jax.Array, zero: jax.Array,
                spec: QuantSpec) -> PackedWeight:
    d_in = q.shape[-2]
    gs = d_in if spec.group_size == -1 else spec.group_size
    return PackedWeight(
        w_packed=pack_codes(q, spec.bits), scale=scale, zero=zero,
        bits=spec.bits, group_size=gs, d_in=d_in)


def packed_weight_from_artifact(entry: dict, em: dict,
                                spec: dict) -> PackedWeight:
    """Packed-artifact entry (``checkpoint.packed``) -> ``PackedWeight``.

    The codes move host->device still packed and ``quant_matmul`` consumes
    them directly — the serving path never unpacks on host.  ``entry`` is
    one ``load_packed_artifact`` entry, ``em``/``spec`` its per-entry and
    artifact-level metadata.  Expert stacks arrive with a leading (E,)
    axis on every leaf and dispatch through the vmapped kernel
    (``models.layers.linear``)."""
    codes = jnp.asarray(entry["codes"])
    assert codes.ndim in (2, 3), \
        f"dense (k/vpw, n) or expert-stacked (E, k/vpw, n) codes: {codes.shape}"
    return PackedWeight(
        w_packed=codes, scale=jnp.asarray(entry["scale"]),
        zero=jnp.asarray(entry["zero"]), bits=int(spec["bits"]),
        group_size=int(em["group_size"]), d_in=int(em["d_in"]))


def quant_matmul(x: jax.Array, pw: PackedWeight, *,
                 use_kernel: bool | None = None) -> jax.Array:
    """y = x @ dequant(pw).  x: (m, k) -> (m, n), fp32 accumulation.

    Decode shapes (m not a multiple of the 8-row sublane tile) are padded
    up to 8 and the output sliced back — a single generated token per
    sequence must not demote the GEMM to the unfused ref path, since the
    packed kernel's 16/bits HBM-traffic win is exactly what decode (a
    memory-bound shape) is serving for.

    ``use_kernel``: None (default) auto-selects the Pallas kernel on TPU
    for unsharded weights and the jnp ref elsewhere — the same policy as
    the gram kernel (``RSQConfig.use_gram_kernel``): off-TPU the kernel
    only runs in interpret mode, a correctness tool that would serialize
    the serving hot loop, and mesh-sharded codes (``pw.mesh_sharded``)
    must not hit an opaque custom call GSPMD would all-gather.  The ref
    is a fused XLA unpack+dequant+matmul on the same packed codes —
    resident HBM stays packed either way."""
    m, k = x.shape
    vpw = 32 // pw.bits
    aligned = (32 % pw.bits == 0 and pw.d_in % vpw == 0
               and k % 128 == 0 and pw.w_packed.shape[1] % 128 == 0)
    if use_kernel is None:
        use_kernel = (jax.default_backend() == "tpu"
                      and not pw.mesh_sharded)
    # the k tile must divide k and contain whole quant groups; when no
    # power-of-two tile <= 512 does both (per-tensor groups with a large
    # d_in, group_size > 512, non-power-of-two groups) the kernel can't
    # tile the reduction — serve via ref like the 3-bit case
    k_blk = 512
    while k_blk and (k % k_blk or k_blk % pw.group_size):
        k_blk //= 2
    if not (aligned and use_kernel and k_blk) or pw.bits == 3:
        return quant_matmul_ref(x, pw.w_packed, pw.scale, pw.zero,
                                bits=pw.bits, group_size=pw.group_size,
                                d_in=pw.d_in)
    m_pad = (-m) % 8
    if m_pad:
        x = jnp.concatenate([x, jnp.zeros((m_pad, k), x.dtype)], axis=0)
    m_blk = 128
    while x.shape[0] % m_blk:
        m_blk //= 2
    n = pw.w_packed.shape[1]
    n_blk = 256
    while n % n_blk:
        n_blk //= 2
    out = quant_matmul_pallas(
        x, pw.w_packed, pw.scale, pw.zero, bits=pw.bits,
        group_size=pw.group_size, m_blk=m_blk, n_blk=n_blk, k_blk=k_blk,
        interpret=_interpret())
    return out[:m] if m_pad else out
