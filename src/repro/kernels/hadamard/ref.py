"""Pure-jnp oracle for the FWHT kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hadamard_matrix(n: int) -> jax.Array:
    h = jnp.ones((1, 1), jnp.float32)
    while h.shape[0] < n:
        h = jnp.block([[h, h], [h, -h]])
    return h / jnp.sqrt(jnp.asarray(n, jnp.float32))


def fwht_ref(x: jax.Array) -> jax.Array:
    """Orthonormal Walsh-Hadamard transform along the last dim."""
    d = x.shape[-1]
    return (x.astype(jnp.float32) @ hadamard_matrix(d)).astype(x.dtype)
