from repro.kernels.hadamard.ops import fwht, hadamard_transform  # noqa: F401
