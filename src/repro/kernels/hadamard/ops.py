"""Public wrapper: fast Hadamard transform for arbitrary dims.

d = 2^k * m is handled as H_{2^k} (x) Q_m (Q_m: caller-supplied orthogonal
factor, e.g. from core.rotation.random_orthogonal): reshape to (..., m, 2^k),
FWHT the power-of-two axis with the Pallas kernel, then one dense matmul
over the m axis.  On non-TPU backends the kernel runs in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hadamard.kernel import fwht_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fwht(x: jax.Array, rows_blk: int = 256) -> jax.Array:
    """Orthonormal FWHT over the last dim (power of two)."""
    shape = x.shape
    d = shape[-1]
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    blk = rows_blk
    while n % blk:
        blk //= 2
    out = fwht_pallas(x2, rows_blk=max(blk, 1), interpret=_interpret())
    return out.reshape(shape)


def hadamard_transform(x: jax.Array, q_m: jax.Array | None = None) -> jax.Array:
    """Apply (H_{2^k} (x) Q_m) to the last dim of x; d = 2^k * m.

    Matches core.rotation conventions: y = x @ (H (x) Q_m) where the
    Kronecker factors act as  (x) -> reshape (…, 2^k, m)."""
    d = x.shape[-1]
    k2 = 1
    while d % (2 * k2) == 0:
        k2 *= 2
    m = d // k2
    if m == 1:
        return fwht(x)
    assert q_m is not None and q_m.shape == (m, m)
    lead = x.shape[:-1]
    xr = x.reshape(*lead, k2, m)
    # Q_m on the trailing (m) axis
    xr = jnp.einsum("...km,mn->...kn", xr.astype(jnp.float32),
                    q_m.astype(jnp.float32))
    # FWHT on the 2^k axis
    xr = jnp.swapaxes(xr, -1, -2)  # (..., m, k2)
    xr = fwht(xr.reshape(-1, k2)).reshape(*lead, m, k2)
    out = jnp.swapaxes(xr, -1, -2).reshape(*lead, d)
    return out.astype(x.dtype)
