"""Blocked fast Walsh-Hadamard transform (Pallas TPU).

TPU adaptation of the CUDA warp-shuffle FWHT: each grid row tile lives in
VMEM; the first log2(LANE_BLOCK) butterfly stages are one dense (MXU)
matmul against H_{LANE_BLOCK}; the remaining stages are VMEM-resident
reshape-butterflies over the leading factor — so the arithmetic is
matmul-rich (MXU) instead of shuffle-rich (warps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE_BLOCK = 128  # the MXU/lane-aligned base transform size


def _h_matrix(n: int) -> jnp.ndarray:
    h = jnp.ones((1, 1), jnp.float32)
    while h.shape[0] < n:
        h = jnp.block([[h, h], [h, -h]])
    return h


def _fwht_kernel(x_ref, o_ref, *, d: int, base: int):
    """x_ref: (rows_blk, d) VMEM tile; applies the orthonormal FWHT."""
    x = x_ref[...].astype(jnp.float32)
    rows = x.shape[0]
    # stage 1: base-sized transform on the trailing dim via one MXU matmul
    hb = _h_matrix(base)
    xg = x.reshape(rows * (d // base), base)
    xg = jnp.dot(xg, hb, preferred_element_type=jnp.float32)
    x = xg.reshape(rows, d)
    # stage 2: butterflies over the leading factor (d // base stages)
    m = d // base
    step = base
    while step < d:
        xr = x.reshape(rows, d // (2 * step), 2, step)
        a = xr[:, :, 0, :]
        b = xr[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2).reshape(rows, d)
        step *= 2
    # H_base entries are ±1 (factor sqrt(base)) and each butterfly stage is
    # unnormalized (factor sqrt(2) each, sqrt(m) total): normalize by sqrt(d)
    o_ref[...] = (x * jnp.float32(1.0 / jnp.sqrt(d))).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rows_blk", "interpret"))
def fwht_pallas(x: jax.Array, *, rows_blk: int = 256,
                interpret: bool = True) -> jax.Array:
    """x: (n, d), d a power of two (>= LANE_BLOCK uses the MXU base path).

    Orthonormal transform: fwht(fwht(x)) == x."""
    n, d = x.shape
    assert d & (d - 1) == 0, f"d={d} must be a power of two"
    base = min(d, LANE_BLOCK)
    rows_blk = min(rows_blk, n)
    assert n % rows_blk == 0, (n, rows_blk)
    kernel = functools.partial(_fwht_kernel, d=d, base=base)
    return pl.pallas_call(
        kernel,
        grid=(n // rows_blk,),
        in_specs=[pl.BlockSpec((rows_blk, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows_blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x)
