"""Public wrapper: multi-head AttnCon scores for the RSQ pipeline.

Accepts (B, T, H, Dh) q/k (GQA k is repeated to H), returns the paper's
R_j = sum_{heads, queries} A[h, i, j] of shape (B, T)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.attn_colsum.kernel import attn_colsum_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def attn_colsum(q: jax.Array, k: jax.Array, *, causal: bool = True,
                blk: int = 256) -> jax.Array:
    b, t, h, dh = q.shape
    kvh = k.shape[2]
    if kvh != h:
        k = jnp.repeat(k, h // kvh, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, t, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, t, dh)
    while t % blk:
        blk //= 2
    col = attn_colsum_pallas(qf, kf, causal=causal, blk=max(blk, 1),
                             interpret=_interpret())
    return col.reshape(b, h, t).sum(axis=1)
