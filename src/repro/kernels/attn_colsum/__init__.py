from repro.kernels.attn_colsum.ops import attn_colsum  # noqa: F401
