"""Streaming attention-concentration kernel (AttnCon importance).

The paper's AttnCon scores are column sums of the softmax attention map:
R_j = sum_{heads, i} A[h, i, j].  Materializing (H, T, T) at T = 4096+ is
exactly what RSQ's calibration cannot afford, so this kernel computes the
sums in two flash-style passes that never form the map:

  pass 1 — per-query running (max m_i, denominator l_i), standard
           streaming-softmax over KV blocks;
  pass 2 — col[j] += sum_i exp(q_i·k_j - m_i) / l_i, accumulated over query
           blocks with the (m, l) from pass 1.

O(T^2) FLOPs (MXU qk^T tiles), O(T) memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _rowstats_kernel(q_ref, k_ref, m_ref, l_ref, *, blk_q, blk_k, scale,
                     causal):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale  # (blk_q, d)
    k = k_ref[0].astype(jnp.float32)  # (blk_k, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    if causal:
        q_pos = pl.program_id(1) * blk_q + jax.lax.broadcasted_iota(
            jnp.int32, (blk_q, blk_k), 0)
        k_pos = pl.program_id(2) * blk_k + jax.lax.broadcasted_iota(
            jnp.int32, (blk_q, blk_k), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)
    m_old = m_ref[...]  # (1, blk_q)
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1)[None])
    alpha = jnp.exp(m_old - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(
        jnp.exp(s - m_new[0][:, None]), axis=-1)[None]
    m_ref[...] = m_new


def _colsum_kernel(q_ref, k_ref, m_ref, l_ref, o_ref, *, blk_q, blk_k,
                   scale, causal):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0].astype(jnp.float32) * scale
    k = k_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    if causal:
        q_pos = pl.program_id(2) * blk_q + jax.lax.broadcasted_iota(
            jnp.int32, (blk_q, blk_k), 0)
        k_pos = pl.program_id(1) * blk_k + jax.lax.broadcasted_iota(
            jnp.int32, (blk_q, blk_k), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)
    m = m_ref[...][0]  # (blk_q,)
    l = jnp.maximum(l_ref[...][0], 1e-30)
    p = jnp.exp(s - m[:, None]) / l[:, None]
    o_ref[...] += jnp.sum(p, axis=0)[None]


@functools.partial(jax.jit, static_argnames=("causal", "blk", "interpret"))
def attn_colsum_pallas(q: jax.Array, k: jax.Array, *, causal: bool = True,
                       blk: int = 256, interpret: bool = True) -> jax.Array:
    """q, k: (BH, T, d). Returns (BH, T) column sums of softmax(q kᵀ)."""
    bh, t, d = q.shape
    blk = min(blk, t)
    assert t % blk == 0, (t, blk)
    scale = d ** -0.5
    grid = (bh, t // blk, t // blk)
    qspec = pl.BlockSpec((1, blk, d), lambda b, i, j: (b, i, 0))
    kspec = pl.BlockSpec((1, blk, d), lambda b, i, j: (b, j, 0))
    statspec_q = pl.BlockSpec((1, blk), lambda b, i, j: (b, i))

    m, l = pl.pallas_call(
        functools.partial(_rowstats_kernel, blk_q=blk, blk_k=blk,
                          scale=scale, causal=causal),
        grid=grid,
        in_specs=[qspec, kspec],
        out_specs=[statspec_q, statspec_q],
        out_shape=[jax.ShapeDtypeStruct((bh, t), jnp.float32),
                   jax.ShapeDtypeStruct((bh, t), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k)

    # pass 2: grid (bh, kv blocks, q blocks) — innermost q accumulates
    col = pl.pallas_call(
        functools.partial(_colsum_kernel, blk_q=blk, blk_k=blk,
                          scale=scale, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk, d), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, blk, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, blk), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, blk), lambda b, j, i: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, blk), lambda b, j, i: (b, j)),
        out_shape=jax.ShapeDtypeStruct((bh, t), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, m, l)
    return col
