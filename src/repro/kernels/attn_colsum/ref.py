"""Pure-jnp oracle: materialize softmax(q kᵀ) and column-sum it."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attn_colsum_ref(q: jax.Array, k: jax.Array, *,
                    causal: bool = True) -> jax.Array:
    """q, k: (BH, T, d) -> (BH, T)."""
    bh, t, d = q.shape
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((t, t), bool))
        s = jnp.where(mask[None], s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    return jnp.sum(a, axis=1)  # sum over queries -> per-key mass
