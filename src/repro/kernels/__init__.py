"""Pallas TPU kernels for RSQ's compute hot-spots.

Each subpackage holds kernel.py (pl.pallas_call + BlockSpec), ops.py (the
jit'd public wrapper; interpret=True off-TPU) and ref.py (pure-jnp oracle).

  hadamard     — blocked fast Walsh-Hadamard transform (the Rotate step)
  gram         — weighted Hessian accumulation 2·XR²Xᵀ (the Scale step)
  quant_matmul — packed int4/int2/int8 dequant-matmul (quantized serving)
  attn_colsum  — streaming attention column sums (AttnCon importance)
  flash_decode — split-KV decode attention on int8/2-bit quantized KV
"""
