"""Version shims for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; accept
either so the kernels run on both sides of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams
