"""Weighted gram / Hessian accumulation kernel: H = (X·r)ᵀ (X·r).

TPU adaptation of the cuBLAS syrk call in GPU GPTQ: the (d x d) output is
tiled over a 2-D grid; the token dim streams through VMEM in chunks along
the innermost grid axis with the r scaling fused into the load, and fp32
accumulation lives in the output tile across the reduction steps
(dimension_semantics marks the token axis "arbitrary" = sequential).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams


def _gram_kernel(x_i_ref, x_j_ref, r_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    r = r_ref[...].astype(jnp.float32)  # (t_blk, 1)
    xi = x_i_ref[...].astype(jnp.float32) * r  # (t_blk, d_blk_i)
    xj = x_j_ref[...].astype(jnp.float32) * r
    o_ref[...] += jnp.dot(xi.T, xj, preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("d_blk", "t_blk", "interpret"))
def weighted_gram_pallas(x: jax.Array, r: jax.Array, *, d_blk: int = 256,
                         t_blk: int = 512, interpret: bool = True):
    """x: (n, d); r: (n,). Returns (d, d) fp32 = (X·r)ᵀ(X·r)."""
    n, d = x.shape
    d_blk = min(d_blk, d)
    t_blk = min(t_blk, n)
    assert d % d_blk == 0 and n % t_blk == 0, (n, d, t_blk, d_blk)
    r2 = r.reshape(n, 1).astype(jnp.float32)
    grid = (d // d_blk, d // d_blk, n // t_blk)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_blk, d_blk), lambda i, j, k: (k, i)),
            pl.BlockSpec((t_blk, d_blk), lambda i, j, k: (k, j)),
            pl.BlockSpec((t_blk, 1), lambda i, j, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((d_blk, d_blk), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, x, r2)
