from repro.kernels.gram.ops import weighted_gram  # noqa: F401
