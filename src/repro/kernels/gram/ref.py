"""Pure-jnp oracle for the weighted gram kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def weighted_gram_ref(x: jax.Array, r: jax.Array | None = None) -> jax.Array:
    xf = x.astype(jnp.float32)
    if r is not None:
        xf = xf * r.reshape(-1, 1).astype(jnp.float32)
    return xf.T @ xf
