"""Public wrapper for the weighted-gram Hessian kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gram.kernel import weighted_gram_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def weighted_gram(x: jax.Array, r: jax.Array | None = None) -> jax.Array:
    """(X·r)ᵀ(X·r) with fp32 accumulation; pads to kernel-aligned tiles.

    A 3-D ``x`` of shape (E, N, d) (stacked-expert capacity buffers) maps
    to E independent grams via vmap over the Pallas grid."""
    if x.ndim == 3:
        if r is None:
            return jax.vmap(weighted_gram)(x)
        return jax.vmap(weighted_gram)(x, r)
    n, d = x.shape
    if r is None:
        r = jnp.ones((n,), jnp.float32)
    d_blk = 256 if d % 256 == 0 else (128 if d % 128 == 0 else None)
    t_blk = 512
    while n % t_blk and t_blk > 1:
        t_blk //= 2
    if d_blk is None or t_blk < 8:
        # shape not tileable: fall back to the oracle (still fp32 gram)
        from repro.kernels.gram.ref import weighted_gram_ref
        return weighted_gram_ref(x, r)
    return weighted_gram_pallas(x, r, d_blk=d_blk, t_blk=t_blk,
                                interpret=_interpret())
