"""Quantized-KV flash decode: single-token attention against int8 / 2-bit
log-quantized KV tiles, dequantized in-register.

Long-context decode is bound by KV-cache HBM traffic: the whole cache is
read once per generated token per layer.  Storing the cache as quant
codes + scales cuts that traffic to ~bits/16 of a bf16 cache — but only
if attention consumes the codes *directly*.  These kernels stream
(s_blk, d) KV tiles into VMEM still packed, unpack + dequantize them on
the VPU, and feed the MXU — the cache is never materialized in fp, and a
running max/sum-shifted ``(m, l, acc)`` triple (flash-decode softmax)
carries the result across tiles via output-ref accumulation over the
"arbitrary" grid axis, the same pattern as ``quant_matmul``'s o_ref.

Two kernels share the tile dequant + streaming update:

  * :func:`flash_decode_pallas`     — GQA-aware: one grid step per
    (batch, kv_head, kv_tile), the (G, Dh) query group contracted against
    the *un-repeated* cache tile (head-repeating the cache is exactly the
    memory blowup this path exists to avoid).
  * :func:`mla_flash_decode_pallas` — MLA's absorbed decode is 1-kv-head
    attention in latent space: scores are q_lat·c + q_rope·r over the
    compressed cache, values are the latents themselves.  Taking the
    c and r codes as separate operands avoids materializing a concat of
    cache codes per step.

Both return raw partials ``(acc, m, l)`` (acc unnormalized) so the same
kernel serves the local path and the split-KV ``shard_map`` path (ops.py
merges shard partials with one tiny collective and normalizes once).

Quantized formats (produced by ``models.attention``):

  * ``kv_bits=8`` — int8 codes, per-(token, head) bf16 scales
    (``kv_quantize``; ``chunk=1`` here).
  * ``kv_bits=2`` — LogQuant-style log-distributed codes
    value = scale * [-1, -0.25, +0.25, +1][code], packed 16 codes per
    uint32 along the feature axis, one bf16 scale per (chunk, head)
    group of tokens (``kv_log_encode``; ``chunk=cfg.kv_chunk``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _dequant_kv(codes, scale, *, kv_bits: int, chunk: int, d: int):
    """Dequantize one (rows, d) KV tile in-register.

    ``codes``: (rows, d) int8 or (rows, ceil(d/16)) uint32 2-bit packed;
    ``scale``: (rows // chunk,) or (rows // chunk, 1) bf16, broadcast to
    per-row.  Shift/mask unpack on the VPU (same idiom as
    ``quant_matmul._dequant_tile``), fp32 result."""
    scale = scale.reshape(-1, 1).astype(jnp.float32)
    if chunk > 1:
        scale = jnp.repeat(scale, chunk, axis=0)
    if kv_bits == 8:
        # kv_quantize folds the /127 into the stored scale
        return codes.astype(jnp.float32) * scale
    shifts = (jnp.arange(16, dtype=jnp.uint32) * 2)[None, None, :]
    c = ((codes[:, :, None] >> shifts) & jnp.uint32(3)).astype(jnp.int32)
    c = c.reshape(codes.shape[0], -1)[:, :d]
    # log levels scale*[-1, -0.25, +0.25, +1] for codes 0..3, branch-free
    mag = jnp.where((c == 1) | (c == 2), 0.25, 1.0).astype(jnp.float32)
    sgn = jnp.where(c >= 2, 1.0, -1.0).astype(jnp.float32)
    return sgn * mag * scale


def _tile_update(scores, v, valid, m_prev, l_prev, acc_prev):
    """One tile's streaming-softmax update of the (m, l, acc) triple.

    ``scores``: (rows_q, s_blk) raw (unmasked) scores; ``v``: (s_blk, dv)
    dequantized values; ``valid``: (1, s_blk) position mask.  Shared
    verbatim by the Pallas kernels and the grouped-einsum refs — the
    bit-parity contract between them holds by construction."""
    s = jnp.where(valid, scores, NEG_INF)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # In decode the masked region is the *tail* (pos < S), so without the
    # explicit zero exp(NEG_INF - NEG_INF) = 1 garbage would survive — no
    # later tile's alpha ever rescales the final tiles away.
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = alpha * acc_prev + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    return m_new, l_new, acc_new


def _fd_kernel(q_ref, kq_ref, ks_ref, vq_ref, vs_ref, pos_ref,
               acc_ref, m_ref, l_ref, *, kv_bits: int, chunk: int,
               dh: int, dv: int, s_blk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, Dh), scale pre-folded
    k = _dequant_kv(kq_ref[0, :, 0], ks_ref[0, :, 0], kv_bits=kv_bits,
                    chunk=chunk, d=dh)   # (s_blk, Dh)
    v = _dequant_kv(vq_ref[0, :, 0], vs_ref[0, :, 0], kv_bits=kv_bits,
                    chunk=chunk, d=dv)   # (s_blk, Dv)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (G, s_blk)
    idx = (pl.program_id(2) * s_blk
           + jax.lax.broadcasted_iota(jnp.int32, (1, s_blk), 1))
    valid = idx <= pos_ref[0, 0]
    m_new, l_new, acc_new = _tile_update(
        scores, v, valid, m_ref[0, 0], l_ref[0, 0], acc_ref[0, 0])
    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_new
    acc_ref[0, 0] = acc_new


@functools.partial(jax.jit, static_argnames=(
    "kv_bits", "chunk", "dh", "dv", "s_blk", "interpret"))
def flash_decode_pallas(q, kq, ks, vq, vs, pos, *, kv_bits: int, chunk: int,
                        dh: int, dv: int, s_blk: int,
                        interpret: bool = True):
    """GQA flash decode over a quantized cache -> raw partials.

    q: (B, KV, G, Dh) — query groups, attention scale already folded in;
    kq/vq: (B, S, KV, Dh) int8 or (B, S, KV, ceil(D/16)) uint32;
    ks/vs: (B, S // chunk, KV) bf16; pos: (1, 1) int32.
    Returns f32 ``(acc, m, l)``: (B, KV, G, Dv) unnormalized accumulator
    plus (B, KV, G, 1) running max / denominator."""
    b, kv, g, _ = q.shape
    s = kq.shape[1]
    assert s % s_blk == 0 and s_blk % chunk == 0, (s, s_blk, chunk)
    rows_c = s_blk // chunk
    wk, wv = kq.shape[-1], vq.shape[-1]
    kernel = functools.partial(_fd_kernel, kv_bits=kv_bits, chunk=chunk,
                               dh=dh, dv=dv, s_blk=s_blk)
    grid = (b, kv, s // s_blk)
    acc, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, q.shape[-1]), lambda i, j, kk: (i, j, 0, 0)),
            pl.BlockSpec((1, s_blk, 1, wk), lambda i, j, kk: (i, kk, j, 0)),
            pl.BlockSpec((1, rows_c, 1), lambda i, j, kk: (i, kk, j)),
            pl.BlockSpec((1, s_blk, 1, wv), lambda i, j, kk: (i, kk, j, 0)),
            pl.BlockSpec((1, rows_c, 1), lambda i, j, kk: (i, kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, dv), lambda i, j, kk: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda i, j, kk: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda i, j, kk: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kv, g, dv), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, g, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, kq, ks, vq, vs, pos)
    return acc, m, l


# --------------------------------------------------- paged (page-table) GQA


def _paged_fd_kernel(tbl_ref, pos_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref,
                     acc_ref, m_ref, l_ref, *, kv_bits: int, chunk: int,
                     dh: int, dv: int, page: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    i, kk = pl.program_id(0), pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)  # (G, Dh), scale pre-folded
    k = _dequant_kv(kq_ref[0, :, 0], ks_ref[0, :, 0], kv_bits=kv_bits,
                    chunk=chunk, d=dh)   # (page, Dh)
    v = _dequant_kv(vq_ref[0, :, 0], vs_ref[0, :, 0], kv_bits=kv_bits,
                    chunk=chunk, d=dv)   # (page, Dv)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (G, page)
    # logical position of tile kk's rows is kk*page regardless of which
    # physical page the table routed here — fully masked (trailing) tiles
    # are exact no-ops of _tile_update, so stale/trash table entries past
    # a request's pos never perturb the result
    idx = kk * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    valid = idx <= pos_ref[i, 0]
    m_new, l_new, acc_new = _tile_update(
        scores, v, valid, m_ref[0, 0], l_ref[0, 0], acc_ref[0, 0])
    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_new
    acc_ref[0, 0] = acc_new


@functools.partial(jax.jit, static_argnames=(
    "kv_bits", "chunk", "dh", "dv", "page", "interpret"))
def paged_flash_decode_pallas(tbl, pos, q, kq, ks, vq, vs, *, kv_bits: int,
                              chunk: int, dh: int, dv: int, page: int,
                              interpret: bool = True):
    """GQA flash decode over a block-paged quantized cache -> raw partials.

    The sequence axis is indirected through a per-request page table: tile
    ``kk`` of request ``i`` streams physical page ``tbl[i, kk]`` from the
    shared pools.  ``tbl``/``pos`` ride in as scalar-prefetch operands
    (SMEM) so the page id is available to the BlockSpec index_map — the
    kernel walks the table, it never sees a contiguous sequence axis.

    tbl: (B, n_tiles) int32; pos: (B, 1) int32 per-request last valid row;
    q: (B, KV, G, Dh) with the attention scale folded in;
    kq/vq: (n_pages, page, KV, wk|wv) code pools; ks/vs:
    (n_pages, page // chunk, KV) scale pools.  Returns the same f32
    ``(acc, m, l)`` triple as :func:`flash_decode_pallas` — with identical
    tile math, so paged == flat holds bitwise at a matched tile size."""
    b, kv, g, _ = q.shape
    n_tiles = tbl.shape[1]
    assert page % chunk == 0, (page, chunk)
    rows_c = page // chunk
    wk, wv = kq.shape[-1], vq.shape[-1]
    kernel = functools.partial(_paged_fd_kernel, kv_bits=kv_bits,
                               chunk=chunk, dh=dh, dv=dv, page=page)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kv, n_tiles),
        in_specs=[
            pl.BlockSpec((1, 1, g, q.shape[-1]),
                         lambda i, j, kk, tbl, pos: (i, j, 0, 0)),
            pl.BlockSpec((1, page, 1, wk),
                         lambda i, j, kk, tbl, pos: (tbl[i, kk], 0, j, 0)),
            pl.BlockSpec((1, rows_c, 1),
                         lambda i, j, kk, tbl, pos: (tbl[i, kk], 0, j)),
            pl.BlockSpec((1, page, 1, wv),
                         lambda i, j, kk, tbl, pos: (tbl[i, kk], 0, j, 0)),
            pl.BlockSpec((1, rows_c, 1),
                         lambda i, j, kk, tbl, pos: (tbl[i, kk], 0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, dv), lambda i, j, kk, tbl, pos: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda i, j, kk, tbl, pos: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda i, j, kk, tbl, pos: (i, j, 0, 0)),
        ],
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kv, g, dv), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, g, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tbl, pos, q, kq, ks, vq, vs)
    return acc, m, l


# ------------------------------------------- chunked-prefill (extend) GQA


def _paged_fe_kernel(tbl_ref, q_ref, kf_ref, vf_ref, kq_ref, ks_ref, vq_ref,
                     vs_ref, acc_ref, m_ref, l_ref, *, kv_bits: int,
                     chunk: int, dh: int, dv: int, page: int, n_past: int,
                     g: int):
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]  # (L*g, Dh) f32, scale pre-folded

    @pl.when(kk < n_past)
    def _past_page():
        k = _dequant_kv(kq_ref[0, :, 0], ks_ref[0, :, 0], kv_bits=kv_bits,
                        chunk=chunk, d=dh)   # (page, Dh)
        v = _dequant_kv(vq_ref[0, :, 0], vs_ref[0, :, 0], kv_bits=kv_bits,
                        chunk=chunk, d=dv)   # (page, Dv)
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)  # (L*g, page)
        valid = jnp.ones((1, page), bool)  # past pages are full
        m_new, l_new, acc_new = _tile_update(
            scores, v, valid, m_ref[0], l_ref[0], acc_ref[0])
        m_ref[0] = m_new
        l_ref[0] = l_new
        acc_ref[0] = acc_new

    @pl.when(kk == n_past)
    def _chunk_tile():
        kf = kf_ref[0]  # (Lp, Dh) f32 — this chunk's fresh keys (padded)
        vf = vf_ref[0]  # (Lp, Dv)
        rows, cols = q.shape[0], kf.shape[0]
        scores = jax.lax.dot_general(
            q, kf, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)  # (L*g, Lp)
        # within-chunk causal: query row i is token i // g of the chunk,
        # key column j is token j — the page-aligned ``start`` offsets
        # both sides identically and cancels; padded key rows (j >= L)
        # exceed every query token and mask out for free
        causal = (jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 0) // g
                  >= jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 1))
        m_new, l_new, acc_new = _tile_update(
            scores, vf, causal, m_ref[0], l_ref[0], acc_ref[0])
        m_ref[0] = m_new
        l_ref[0] = l_new
        acc_ref[0] = acc_new


@functools.partial(jax.jit, static_argnames=(
    "kv_bits", "chunk", "dh", "dv", "page", "interpret"))
def paged_flash_extend_pallas(tbl, q, k_new, v_new, kq, ks, vq, vs, start, *,
                              kv_bits: int, chunk: int, dh: int, dv: int,
                              page: int, interpret: bool = True):
    """Chunked-prefill GQA extend over a block-paged quantized cache.

    Same contract as ``paged_flash_extend_ref`` (bit-identical at
    tile = page, pinned in tests): an L-token chunk attends to the
    request's quantized past pages (``tbl``: (n_past,) int32, every page
    full because chunk boundaries are page-aligned) plus its own fp
    keys/values with a within-chunk causal mask.  The grid walks
    (kv_head, past pages + 1 fp tile); past pages dequantize in-register
    exactly like :func:`paged_flash_decode_pallas`.  q: (1, L, H, Dh)
    *unscaled*; k_new/v_new: (1, L, KV, Dh|Dv) fp.  Returns (1, L, H, Dv)
    f32 normalized output."""
    _, L, h, _ = q.shape
    kv = k_new.shape[2]
    g = h // kv
    n_past = tbl.shape[0]
    assert page % chunk == 0, (page, chunk)
    rows_c = page // chunk
    wk, wv = kq.shape[-1], vq.shape[-1]
    qf = (q.astype(jnp.float32) * (dh ** -0.5))[0]          # (L, H, Dh)
    qf = jnp.moveaxis(qf.reshape(L, kv, g, dh), 1, 0)       # (KV, L, g, Dh)
    qf = qf.reshape(kv, L * g, dh)                          # rows = (l, g)
    kf = jnp.moveaxis(k_new[0].astype(jnp.float32), 1, 0)   # (KV, L, Dh)
    vf = jnp.moveaxis(v_new[0].astype(jnp.float32), 1, 0)   # (KV, L, Dv)
    # pad the fp tile to a sublane multiple: tiny L would hand XLA a
    # degenerate contraction it rewrites (fma) differently per context,
    # breaking kernel == ref bit-parity; padded rows mask out causally
    # and are exact no-ops of _tile_update
    Lp = -(-L // 8) * 8
    if Lp != L:
        kf = jnp.pad(kf, ((0, 0), (0, Lp - L), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, Lp - L), (0, 0)))
    del start  # page-aligned: cancels from the causal mask
    # the fp tile's grid step still maps a (never-read) page block; clamp
    # its table lookup in range, with a trash entry when there is no past
    tbl_x = tbl if n_past else jnp.zeros((1,), jnp.int32)

    def _pg(kk, tbl):
        return tbl[jnp.maximum(jnp.minimum(kk, n_past - 1), 0)]

    kernel = functools.partial(_paged_fe_kernel, kv_bits=kv_bits,
                               chunk=chunk, dh=dh, dv=dv, page=page,
                               n_past=n_past, g=g)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(kv, n_past + 1),
        in_specs=[
            pl.BlockSpec((1, L * g, dh), lambda i, kk, tbl: (i, 0, 0)),
            pl.BlockSpec((1, Lp, dh), lambda i, kk, tbl: (i, 0, 0)),
            pl.BlockSpec((1, Lp, dv), lambda i, kk, tbl: (i, 0, 0)),
            pl.BlockSpec((1, page, 1, wk),
                         lambda i, kk, tbl: (_pg(kk, tbl), 0, i, 0)),
            pl.BlockSpec((1, rows_c, 1),
                         lambda i, kk, tbl: (_pg(kk, tbl), 0, i)),
            pl.BlockSpec((1, page, 1, wv),
                         lambda i, kk, tbl: (_pg(kk, tbl), 0, i, 0)),
            pl.BlockSpec((1, rows_c, 1),
                         lambda i, kk, tbl: (_pg(kk, tbl), 0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, L * g, dv), lambda i, kk, tbl: (i, 0, 0)),
            pl.BlockSpec((1, L * g, 1), lambda i, kk, tbl: (i, 0, 0)),
            pl.BlockSpec((1, L * g, 1), lambda i, kk, tbl: (i, 0, 0)),
        ],
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((kv, L * g, dv), jnp.float32),
            jax.ShapeDtypeStruct((kv, L * g, 1), jnp.float32),
            jax.ShapeDtypeStruct((kv, L * g, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tbl_x, qf, kf, vf, kq, ks, vq, vs)
    out = acc / jnp.maximum(l, 1e-30)                       # (KV, L*g, Dv)
    out = jnp.moveaxis(out.reshape(kv, L, g, dv), 0, 1)     # (L, KV, g, Dv)
    return out.reshape(L, h, dv)[None]


def _mla_fd_kernel(ql_ref, qr_ref, cq_ref, cs_ref, rq_ref, rs_ref, pos_ref,
                   acc_ref, m_ref, l_ref, *, kv_bits: int, chunk: int,
                   dl: int, dr: int, s_blk: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    ql = ql_ref[0].astype(jnp.float32)  # (H, dl), scale pre-folded
    qr = qr_ref[0].astype(jnp.float32)  # (H, dr)
    c = _dequant_kv(cq_ref[0], cs_ref[0], kv_bits=kv_bits, chunk=chunk,
                    d=dl)               # (s_blk, dl) — keys *and* values
    r = _dequant_kv(rq_ref[0], rs_ref[0], kv_bits=kv_bits, chunk=chunk,
                    d=dr)               # (s_blk, dr)
    scores = (jax.lax.dot_general(ql, c, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
              + jax.lax.dot_general(qr, r, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32))
    idx = (pl.program_id(1) * s_blk
           + jax.lax.broadcasted_iota(jnp.int32, (1, s_blk), 1))
    valid = idx <= pos_ref[0, 0]
    m_new, l_new, acc_new = _tile_update(
        scores, c, valid, m_ref[0], l_ref[0], acc_ref[0])
    m_ref[0] = m_new
    l_ref[0] = l_new
    acc_ref[0] = acc_new


@functools.partial(jax.jit, static_argnames=(
    "kv_bits", "chunk", "dl", "dr", "s_blk", "interpret"))
def mla_flash_decode_pallas(ql, qr, cq, cs, rq, rs, pos, *, kv_bits: int,
                            chunk: int, dl: int, dr: int, s_blk: int,
                            interpret: bool = True):
    """MLA (absorbed, latent-space) flash decode -> raw partials.

    ql: (B, H, dl) latent queries, qr: (B, H, dr) rope queries — the
    (dn + dr)^-0.5 attention scale already folded in; cq: (B, S, dl) int8
    or (B, S, ceil(dl/16)) uint32 latent codes; cs: (B, S // chunk) bf16;
    rq/rs likewise for the shared rope key.  Values are the latents
    themselves (v = c).  Returns f32 ``(acc, m, l)``: (B, H, dl) + 2x
    (B, H, 1)."""
    b, h, _ = ql.shape
    s = cq.shape[1]
    assert s % s_blk == 0 and s_blk % chunk == 0, (s, s_blk, chunk)
    rows_c = s_blk // chunk
    kernel = functools.partial(_mla_fd_kernel, kv_bits=kv_bits, chunk=chunk,
                               dl=dl, dr=dr, s_blk=s_blk)
    grid = (b, s // s_blk)
    acc, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, h, ql.shape[-1]), lambda i, kk: (i, 0, 0)),
            pl.BlockSpec((1, h, qr.shape[-1]), lambda i, kk: (i, 0, 0)),
            pl.BlockSpec((1, s_blk, cq.shape[-1]), lambda i, kk: (i, kk, 0)),
            pl.BlockSpec((1, rows_c), lambda i, kk: (i, kk)),
            pl.BlockSpec((1, s_blk, rq.shape[-1]), lambda i, kk: (i, kk, 0)),
            pl.BlockSpec((1, rows_c), lambda i, kk: (i, kk)),
            pl.BlockSpec((1, 1), lambda i, kk: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, dl), lambda i, kk: (i, 0, 0)),
            pl.BlockSpec((1, h, 1), lambda i, kk: (i, 0, 0)),
            pl.BlockSpec((1, h, 1), lambda i, kk: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, dl), jnp.float32),
            jax.ShapeDtypeStruct((b, h, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, h, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(ql, qr, cq, cs, rq, rs, pos)
    return acc, m, l


# --------------------------------------------------- paged (page-table) MLA


def _paged_mla_fd_kernel(tbl_ref, pos_ref, ql_ref, qr_ref, cq_ref, cs_ref,
                         rq_ref, rs_ref, acc_ref, m_ref, l_ref, *,
                         kv_bits: int, chunk: int, dl: int, dr: int,
                         page: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    i, kk = pl.program_id(0), pl.program_id(1)
    ql = ql_ref[0].astype(jnp.float32)  # (H, dl), scale pre-folded
    qr = qr_ref[0].astype(jnp.float32)  # (H, dr)
    c = _dequant_kv(cq_ref[0], cs_ref[0], kv_bits=kv_bits, chunk=chunk,
                    d=dl)               # (page, dl) — keys *and* values
    r = _dequant_kv(rq_ref[0], rs_ref[0], kv_bits=kv_bits, chunk=chunk,
                    d=dr)               # (page, dr)
    scores = (jax.lax.dot_general(ql, c, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
              + jax.lax.dot_general(qr, r, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32))
    idx = kk * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    valid = idx <= pos_ref[i, 0]
    m_new, l_new, acc_new = _tile_update(
        scores, c, valid, m_ref[0], l_ref[0], acc_ref[0])
    m_ref[0] = m_new
    l_ref[0] = l_new
    acc_ref[0] = acc_new


@functools.partial(jax.jit, static_argnames=(
    "kv_bits", "chunk", "dl", "dr", "page", "interpret"))
def paged_mla_flash_decode_pallas(tbl, pos, ql, qr, cq, cs, rq, rs, *,
                                  kv_bits: int, chunk: int, dl: int,
                                  dr: int, page: int,
                                  interpret: bool = True):
    """MLA (absorbed, latent-space) flash decode over block-paged pools.

    tbl: (B, n_tiles) int32; pos: (B, 1) int32; ql/qr: (B, H, dl|dr) with
    the attention scale folded in; cq/rq: (n_pages, page, wc|wr) latent /
    rope code pools; cs/rs: (n_pages, page // chunk) scale pools.  Same
    tile math as :func:`mla_flash_decode_pallas` (paged == flat bitwise at
    a matched tile); values are the latents (v = c).  Returns f32
    ``(acc, m, l)``: (B, H, dl) + 2x (B, H, 1)."""
    b, h, _ = ql.shape
    n_tiles = tbl.shape[1]
    assert page % chunk == 0, (page, chunk)
    rows_c = page // chunk
    kernel = functools.partial(_paged_mla_fd_kernel, kv_bits=kv_bits,
                               chunk=chunk, dl=dl, dr=dr, page=page)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_tiles),
        in_specs=[
            pl.BlockSpec((1, h, ql.shape[-1]),
                         lambda i, kk, tbl, pos: (i, 0, 0)),
            pl.BlockSpec((1, h, qr.shape[-1]),
                         lambda i, kk, tbl, pos: (i, 0, 0)),
            pl.BlockSpec((1, page, cq.shape[-1]),
                         lambda i, kk, tbl, pos: (tbl[i, kk], 0, 0)),
            pl.BlockSpec((1, rows_c), lambda i, kk, tbl, pos: (tbl[i, kk], 0)),
            pl.BlockSpec((1, page, rq.shape[-1]),
                         lambda i, kk, tbl, pos: (tbl[i, kk], 0, 0)),
            pl.BlockSpec((1, rows_c), lambda i, kk, tbl, pos: (tbl[i, kk], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, dl), lambda i, kk, tbl, pos: (i, 0, 0)),
            pl.BlockSpec((1, h, 1), lambda i, kk, tbl, pos: (i, 0, 0)),
            pl.BlockSpec((1, h, 1), lambda i, kk, tbl, pos: (i, 0, 0)),
        ],
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, dl), jnp.float32),
            jax.ShapeDtypeStruct((b, h, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, h, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tbl, pos, ql, qr, cq, cs, rq, rs)
    return acc, m, l


# ------------------------------------------- chunked-prefill (extend) MLA


def _paged_mla_fe_kernel(tbl_ref, ql_ref, qr_ref, cf_ref, rf_ref, cq_ref,
                         cs_ref, rq_ref, rs_ref, acc_ref, m_ref, l_ref, *,
                         kv_bits: int, chunk: int, dl: int, dr: int,
                         page: int, n_past: int, h: int):
    kk = pl.program_id(0)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    ql = ql_ref[...]  # (L*h, dl) f32, scale pre-folded
    qr = qr_ref[...]  # (L*h, dr)

    @pl.when(kk < n_past)
    def _past_page():
        c = _dequant_kv(cq_ref[0], cs_ref[0], kv_bits=kv_bits, chunk=chunk,
                        d=dl)               # (page, dl) — keys *and* values
        r = _dequant_kv(rq_ref[0], rs_ref[0], kv_bits=kv_bits, chunk=chunk,
                        d=dr)               # (page, dr)
        scores = (jax.lax.dot_general(ql, c, (((1,), (1,)), ((), ())),
                                      preferred_element_type=jnp.float32)
                  + jax.lax.dot_general(qr, r, (((1,), (1,)), ((), ())),
                                        preferred_element_type=jnp.float32))
        valid = jnp.ones((1, page), bool)  # past pages are full
        m_new, l_new, acc_new = _tile_update(
            scores, c, valid, m_ref[...], l_ref[...], acc_ref[...])
        m_ref[...] = m_new
        l_ref[...] = l_new
        acc_ref[...] = acc_new

    @pl.when(kk == n_past)
    def _chunk_tile():
        cf = cf_ref[...]  # (Lp, dl) f32 — this chunk's latents (padded)
        rf = rf_ref[...]  # (Lp, dr)
        rows, cols = ql.shape[0], cf.shape[0]
        scores = (jax.lax.dot_general(ql, cf, (((1,), (1,)), ((), ())),
                                      preferred_element_type=jnp.float32)
                  + jax.lax.dot_general(qr, rf, (((1,), (1,)), ((), ())),
                                        preferred_element_type=jnp.float32))
        # query row i is chunk token i // h, key column j is chunk token
        # j — the page-aligned ``start`` cancels from both sides; padded
        # key rows (j >= L) exceed every query token and mask out free
        causal = (jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 0) // h
                  >= jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 1))
        m_new, l_new, acc_new = _tile_update(
            scores, cf, causal, m_ref[...], l_ref[...], acc_ref[...])
        m_ref[...] = m_new
        l_ref[...] = l_new
        acc_ref[...] = acc_new


@functools.partial(jax.jit, static_argnames=(
    "kv_bits", "chunk", "dl", "dr", "page", "interpret"))
def paged_mla_flash_extend_pallas(tbl, ql, qr, c_new, r_new, cq, cs, rq, rs,
                                  start, *, kv_bits: int, chunk: int,
                                  dl: int, dr: int, page: int,
                                  interpret: bool = True):
    """Chunked-prefill MLA latent extend over block-paged latent pools.

    Same contract as ``paged_mla_flash_extend_ref`` (bit-identical at
    tile = page, pinned in tests): an L-token chunk's absorbed queries
    attend to the request's quantized latent pages plus the fp
    within-chunk latents (causal); values are the latents (v = c).
    ql/qr: (L, H, dl|dr) *scaled* queries; c_new/r_new: (L, dl|dr) fp.
    Returns (L, H, dl) f32 latent context."""
    L, h, _ = ql.shape
    n_past = tbl.shape[0]
    assert page % chunk == 0, (page, chunk)
    rows_c = page // chunk
    wc, wr = cq.shape[-1], rq.shape[-1]
    qlf = ql.astype(jnp.float32).reshape(L * h, dl)
    qrf = qr.astype(jnp.float32).reshape(L * h, dr)
    cf = c_new.astype(jnp.float32)                          # (L, dl)
    rf = r_new.astype(jnp.float32)                          # (L, dr)
    # pad the fp tile to a sublane multiple (see the GQA extend wrapper)
    Lp = -(-L // 8) * 8
    if Lp != L:
        cf = jnp.pad(cf, ((0, Lp - L), (0, 0)))
        rf = jnp.pad(rf, ((0, Lp - L), (0, 0)))
    del start  # page-aligned: cancels from the causal mask
    tbl_x = tbl if n_past else jnp.zeros((1,), jnp.int32)

    def _pg(kk, tbl):
        return tbl[jnp.maximum(jnp.minimum(kk, n_past - 1), 0)]

    kernel = functools.partial(_paged_mla_fe_kernel, kv_bits=kv_bits,
                               chunk=chunk, dl=dl, dr=dr, page=page,
                               n_past=n_past, h=h)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_past + 1,),
        in_specs=[
            pl.BlockSpec((L * h, dl), lambda kk, tbl: (0, 0)),
            pl.BlockSpec((L * h, dr), lambda kk, tbl: (0, 0)),
            pl.BlockSpec((Lp, dl), lambda kk, tbl: (0, 0)),
            pl.BlockSpec((Lp, dr), lambda kk, tbl: (0, 0)),
            pl.BlockSpec((1, page, wc),
                         lambda kk, tbl: (_pg(kk, tbl), 0, 0)),
            pl.BlockSpec((1, rows_c), lambda kk, tbl: (_pg(kk, tbl), 0)),
            pl.BlockSpec((1, page, wr),
                         lambda kk, tbl: (_pg(kk, tbl), 0, 0)),
            pl.BlockSpec((1, rows_c), lambda kk, tbl: (_pg(kk, tbl), 0)),
        ],
        out_specs=[
            pl.BlockSpec((L * h, dl), lambda kk, tbl: (0, 0)),
            pl.BlockSpec((L * h, 1), lambda kk, tbl: (0, 0)),
            pl.BlockSpec((L * h, 1), lambda kk, tbl: (0, 0)),
        ],
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((L * h, dl), jnp.float32),
            jax.ShapeDtypeStruct((L * h, 1), jnp.float32),
            jax.ShapeDtypeStruct((L * h, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(tbl_x, qlf, qrf, cf, rf, cq, cs, rq, rs)
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(L, h, dl)
