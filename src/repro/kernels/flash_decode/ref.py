"""Grouped-einsum reference for quantized-KV flash decode.

Bit-parity contract: mirrors the Pallas kernels tile for tile — the same
``s_blk`` tiling, the same ``_dequant_kv`` / ``_tile_update`` helpers
(imported from kernel.py), the same ``dot_general`` dimension numbers
with fp32 accumulation — so kernel == ref holds *bitwise* on the same
codes (pinned in tests/test_kv_cache.py).

Also the serving fallback with the same footprint discipline as
``quant_matmul.ref``: a ``lax.scan`` over KV tiles that dequantizes only
the active (s_blk, d) tile in-register — the full cache is never
materialized in fp here either (this replaces the per-step full-cache
``kv_dequantize`` the old int8 path did), and the scan is plain jnp, so
GSPMD partitions it like any einsum.  That makes it the route used under
a mesh whenever the split-KV ``shard_map`` can't run (misaligned local
tiles): an opaque Pallas custom call there would make GSPMD all-gather
the cache — the quant_matmul fallback policy, applied to the cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.kernel import (NEG_INF, _dequant_kv,
                                               _tile_update)


def _pad_tiles(x, blk: int):
    """Pad the sequence axis (1) up to a tile multiple — padded rows are
    code/scale zeros and always position-masked."""
    pad = (-x.shape[1]) % blk
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[1] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=(
    "kv_bits", "chunk", "dh", "dv", "s_blk"))
def flash_decode_ref(q, kq, ks, vq, vs, pos, *, kv_bits: int, chunk: int,
                     dh: int, dv: int, s_blk: int):
    """GQA partials (acc, m, l) matching ``flash_decode_pallas`` bitwise.

    Same signature/layouts as the kernel (pos may be any int shape); S is
    padded up to an ``s_blk`` multiple when ragged (masking covers it)."""
    b, kv, g, _ = q.shape
    rows_c = s_blk // chunk
    kq, vq = _pad_tiles(kq, s_blk), _pad_tiles(vq, s_blk)
    ks, vs = _pad_tiles(ks, rows_c), _pad_tiles(vs, rows_c)
    n_tiles = kq.shape[1] // s_blk
    qf = q.astype(jnp.float32)
    px = jnp.reshape(pos, (-1,))[0].astype(jnp.int32)

    def one(kk, qh, kc, ksc, vc, vsc, m1, l1, acc1):
        # identical per-(batch, kv_head) tile math to _fd_kernel
        k = _dequant_kv(kc, ksc, kv_bits=kv_bits, chunk=chunk, d=dh)
        v = _dequant_kv(vc, vsc, kv_bits=kv_bits, chunk=chunk, d=dv)
        scores = jax.lax.dot_general(
            qh, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        idx = kk * s_blk + jax.lax.broadcasted_iota(jnp.int32, (1, s_blk), 1)
        return _tile_update(scores, v, idx <= px, m1, l1, acc1)

    def step(carry, kk):
        acc, m, l = carry
        # slice the active tile *first*, then transpose the tiny tile to
        # (B, KV, s_blk, ·) — never a full-cache copy
        k_t = jnp.moveaxis(
            jax.lax.dynamic_slice_in_dim(kq, kk * s_blk, s_blk, 1), 1, 2)
        v_t = jnp.moveaxis(
            jax.lax.dynamic_slice_in_dim(vq, kk * s_blk, s_blk, 1), 1, 2)
        ks_t = jnp.moveaxis(
            jax.lax.dynamic_slice_in_dim(ks, kk * rows_c, rows_c, 1), 1, 2)
        vs_t = jnp.moveaxis(
            jax.lax.dynamic_slice_in_dim(vs, kk * rows_c, rows_c, 1), 1, 2)
        f = jax.vmap(jax.vmap(functools.partial(one, kk)))
        m_new, l_new, acc_new = f(qf, k_t, ks_t, v_t, vs_t, m, l, acc)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, kv, g, dv), jnp.float32)
    m0 = jnp.full((b, kv, g, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, 1), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0),
                                  jnp.arange(n_tiles))
    return acc, m, l


@functools.partial(jax.jit, static_argnames=(
    "kv_bits", "chunk", "dl", "dr", "s_blk"))
def mla_flash_decode_ref(ql, qr, cq, cs, rq, rs, pos, *, kv_bits: int,
                         chunk: int, dl: int, dr: int, s_blk: int):
    """MLA partials (acc, m, l) matching ``mla_flash_decode_pallas``."""
    b, h, _ = ql.shape
    rows_c = s_blk // chunk
    cq, rq = _pad_tiles(cq, s_blk), _pad_tiles(rq, s_blk)
    cs, rs = _pad_tiles(cs, rows_c), _pad_tiles(rs, rows_c)
    n_tiles = cq.shape[1] // s_blk
    qlf, qrf = ql.astype(jnp.float32), qr.astype(jnp.float32)
    px = jnp.reshape(pos, (-1,))[0].astype(jnp.int32)

    def one(kk, qlh, qrh, cc, csc, rc, rsc, m1, l1, acc1):
        c = _dequant_kv(cc, csc, kv_bits=kv_bits, chunk=chunk, d=dl)
        r = _dequant_kv(rc, rsc, kv_bits=kv_bits, chunk=chunk, d=dr)
        scores = (jax.lax.dot_general(qlh, c, (((1,), (1,)), ((), ())),
                                      preferred_element_type=jnp.float32)
                  + jax.lax.dot_general(qrh, r, (((1,), (1,)), ((), ())),
                                        preferred_element_type=jnp.float32))
        idx = kk * s_blk + jax.lax.broadcasted_iota(jnp.int32, (1, s_blk), 1)
        return _tile_update(scores, c, idx <= px, m1, l1, acc1)

    def step(carry, kk):
        acc, m, l = carry
        c_t = jax.lax.dynamic_slice_in_dim(cq, kk * s_blk, s_blk, 1)
        r_t = jax.lax.dynamic_slice_in_dim(rq, kk * s_blk, s_blk, 1)
        cs_t = jax.lax.dynamic_slice_in_dim(cs, kk * rows_c, rows_c, 1)
        rs_t = jax.lax.dynamic_slice_in_dim(rs, kk * rows_c, rows_c, 1)
        f = jax.vmap(functools.partial(one, kk))
        m_new, l_new, acc_new = f(qlf, qrf, c_t, cs_t, r_t, rs_t, m, l, acc)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, h, dl), jnp.float32)
    m0 = jnp.full((b, h, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, 1), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), jnp.arange(n_tiles))
    return acc, m, l


# --------------------------------------------------- paged (page-table) refs


@functools.partial(jax.jit, static_argnames=(
    "kv_bits", "chunk", "dh", "dv", "page"))
def paged_flash_decode_ref(tbl, pos, q, kq, ks, vq, vs, *, kv_bits: int,
                           chunk: int, dh: int, dv: int, page: int):
    """GQA partials over block-paged pools, matching
    ``paged_flash_decode_pallas`` bitwise.

    Same page-table indirection as the kernel — the scan walks
    ``tbl[:, kk]`` and gathers one physical page per request per step
    (``jnp.take`` of a (B, page, ·) slice: codes move, never fp) — and the
    same ``_dequant_kv`` / ``_tile_update`` tile math.  ``pos`` is
    per-request: (B,) or (B, 1) int32."""
    b, kv, g, _ = q.shape
    n_tiles = tbl.shape[1]
    rows_c = page // chunk
    qf = q.astype(jnp.float32)
    px = jnp.reshape(pos, (b,)).astype(jnp.int32)

    def one(kk, qh, kc, ksc, vc, vsc, p1, m1, l1, acc1):
        # identical per-(batch, kv_head) tile math to _paged_fd_kernel
        k = _dequant_kv(kc, ksc, kv_bits=kv_bits, chunk=chunk, d=dh)
        v = _dequant_kv(vc, vsc, kv_bits=kv_bits, chunk=chunk, d=dv)
        scores = jax.lax.dot_general(
            qh, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        idx = kk * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        return _tile_update(scores, v, idx <= p1, m1, l1, acc1)

    def step(carry, kk):
        acc, m, l = carry
        pid = jax.lax.dynamic_slice_in_dim(tbl, kk, 1, 1)[:, 0]  # (B,)
        k_t = jnp.moveaxis(jnp.take(kq, pid, axis=0), 1, 2)  # (B, KV, page, wk)
        v_t = jnp.moveaxis(jnp.take(vq, pid, axis=0), 1, 2)
        ks_t = jnp.moveaxis(jnp.take(ks, pid, axis=0), 1, 2)
        vs_t = jnp.moveaxis(jnp.take(vs, pid, axis=0), 1, 2)
        f = jax.vmap(jax.vmap(functools.partial(one, kk),
                              in_axes=(0, 0, 0, 0, 0, None, 0, 0, 0)))
        m_new, l_new, acc_new = f(qf, k_t, ks_t, v_t, vs_t, px, m, l, acc)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, kv, g, dv), jnp.float32)
    m0 = jnp.full((b, kv, g, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, 1), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), jnp.arange(n_tiles))
    return acc, m, l


@functools.partial(jax.jit, static_argnames=(
    "kv_bits", "chunk", "dl", "dr", "page"))
def paged_mla_flash_decode_ref(tbl, pos, ql, qr, cq, cs, rq, rs, *,
                               kv_bits: int, chunk: int, dl: int, dr: int,
                               page: int):
    """MLA partials over block-paged pools, matching
    ``paged_mla_flash_decode_pallas`` bitwise; ``pos`` per-request."""
    b, h, _ = ql.shape
    n_tiles = tbl.shape[1]
    rows_c = page // chunk
    qlf, qrf = ql.astype(jnp.float32), qr.astype(jnp.float32)
    px = jnp.reshape(pos, (b,)).astype(jnp.int32)

    def one(kk, qlh, qrh, cc, csc, rc, rsc, p1, m1, l1, acc1):
        c = _dequant_kv(cc, csc, kv_bits=kv_bits, chunk=chunk, d=dl)
        r = _dequant_kv(rc, rsc, kv_bits=kv_bits, chunk=chunk, d=dr)
        scores = (jax.lax.dot_general(qlh, c, (((1,), (1,)), ((), ())),
                                      preferred_element_type=jnp.float32)
                  + jax.lax.dot_general(qrh, r, (((1,), (1,)), ((), ())),
                                        preferred_element_type=jnp.float32))
        idx = kk * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        return _tile_update(scores, c, idx <= p1, m1, l1, acc1)

    def step(carry, kk):
        acc, m, l = carry
        pid = jax.lax.dynamic_slice_in_dim(tbl, kk, 1, 1)[:, 0]
        c_t = jnp.take(cq, pid, axis=0)   # (B, page, wc)
        r_t = jnp.take(rq, pid, axis=0)
        cs_t = jnp.take(cs, pid, axis=0)  # (B, rows_c)
        rs_t = jnp.take(rs, pid, axis=0)
        f = jax.vmap(functools.partial(one, kk))
        m_new, l_new, acc_new = f(qlf, qrf, c_t, cs_t, r_t, rs_t, px, m, l,
                                  acc)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, h, dl), jnp.float32)
    m0 = jnp.full((b, h, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, 1), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), jnp.arange(n_tiles))
    return acc, m, l


# ------------------------------------------------- chunked-prefill extension


@functools.partial(jax.jit, static_argnames=(
    "kv_bits", "chunk", "dh", "dv", "page"))
def paged_flash_extend_ref(tbl, q, k_new, v_new, kq, ks, vq, vs, start, *,
                           kv_bits: int, chunk: int, dh: int, dv: int,
                           page: int):
    """Chunked-prefill GQA attention: an L-token query chunk attends to
    the quantized pages of its *own request's* earlier chunks plus its fp
    within-chunk keys/values (causal).

    tbl: (n_past_tiles,) int32 page ids of the request's previous chunks
    (``start = n_past_tiles * page`` — chunk boundaries are page-aligned,
    so every past page is full and unmasked); q: (1, L, H, Dh) *unscaled*
    queries; k_new/v_new: (1, L, KV, Dh|Dv) this chunk's fp keys/values.
    Past pages dequantize tile-by-tile in-register (``_dequant_kv``) and
    stream through the same ``_tile_update`` as decode; the fp chunk is
    the final "tile" with a causal mask.  Every tile — the fp chunk
    included — runs as one scan step so the ``(m, l, acc)`` triple
    materializes through the carry between tiles exactly as it does
    through the kernel's output refs (a top-level final update would let
    XLA fuse it with the finalize and break bit-parity by an ulp).
    Returns (1, L, H, Dv)."""
    _, L, h, _ = q.shape
    kv = k_new.shape[2]
    g = h // kv
    n_past = tbl.shape[0]
    qf = (q.astype(jnp.float32) * (dh ** -0.5))[0]          # (L, H, Dh)
    qf = jnp.moveaxis(qf.reshape(L, kv, g, dh), 1, 0)       # (KV, L, g, Dh)
    qf = qf.reshape(kv, L * g, dh)                          # rows = (l, g)
    row_pos = jnp.repeat(start + jnp.arange(L), g)          # (L*g,)

    # final tile: this chunk's fp keys/values, causal within the chunk.
    # Padded to a sublane multiple like the kernel wrapper — a tiny L
    # hands XLA a degenerate contraction it rewrites (fma) differently
    # per context, breaking bit-parity; padded key rows sit causally
    # after every query row and mask out for free.
    Lp = -(-L // 8) * 8
    kf = jnp.moveaxis(k_new[0].astype(jnp.float32), 1, 0)   # (KV, L, Dh)
    vf = jnp.moveaxis(v_new[0].astype(jnp.float32), 1, 0)   # (KV, L, Dv)
    if Lp != L:
        kf = jnp.pad(kf, ((0, 0), (0, Lp - L), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, Lp - L), (0, 0)))
    kv_pos = start + jnp.arange(Lp)
    causal = row_pos[:, None] >= kv_pos[None, :]            # (L*g, Lp)
    tbl_x = tbl if n_past else jnp.zeros((1,), jnp.int32)

    def step(carry, kk):
        m, l, acc = carry
        pid = tbl_x[jnp.maximum(jnp.minimum(kk, n_past - 1), 0)]
        kc, vc = jnp.take(kq, pid, axis=0), jnp.take(vq, pid, axis=0)
        ksc, vsc = jnp.take(ks, pid, axis=0), jnp.take(vs, pid, axis=0)

        def past(m, l, acc):
            def per_head(qh, kcj, kscj, vcj, vscj, m1, l1, acc1):
                k = _dequant_kv(kcj, kscj, kv_bits=kv_bits, chunk=chunk,
                                d=dh)
                v = _dequant_kv(vcj, vscj, kv_bits=kv_bits, chunk=chunk,
                                d=dv)
                scores = jax.lax.dot_general(
                    qh, k, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32)  # (L*g, page)
                valid = jnp.ones((1, page), bool)  # past pages are full
                return _tile_update(scores, v, valid, m1, l1, acc1)

            return jax.vmap(per_head)(
                qf, jnp.moveaxis(kc, 1, 0), jnp.moveaxis(ksc, 1, 0),
                jnp.moveaxis(vc, 1, 0), jnp.moveaxis(vsc, 1, 0), m, l, acc)

        def fp_chunk(m, l, acc):
            def final(qh, kh, vh, m1, l1, acc1):
                scores = jax.lax.dot_general(
                    qh, kh, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32)
                return _tile_update(scores, vh, causal, m1, l1, acc1)

            return jax.vmap(final)(qf, kf, vf, m, l, acc)

        return jax.lax.cond(kk < n_past, past, fp_chunk, m, l, acc), None

    m0 = jnp.full((kv, L * g, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((kv, L * g, 1), jnp.float32)
    acc0 = jnp.zeros((kv, L * g, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0),
                                  jnp.arange(n_past + 1))
    out = acc / jnp.maximum(l, 1e-30)                       # (KV, L*g, Dv)
    out = jnp.moveaxis(out.reshape(kv, L, g, dv), 0, 1)     # (L, KV, g, Dv)
    return out.reshape(L, h, dv)[None]


@functools.partial(jax.jit, static_argnames=(
    "kv_bits", "chunk", "dl", "dr", "page"))
def paged_mla_flash_extend_ref(tbl, ql, qr, c_new, r_new, cq, cs, rq, rs,
                               start, *, kv_bits: int, chunk: int, dl: int,
                               dr: int, page: int):
    """Chunked-prefill MLA latent attention: an L-token chunk's absorbed
    queries attend to quantized latent pages of earlier chunks plus the fp
    within-chunk latents (causal).  ql/qr: (L, H, dl|dr) *scaled* queries;
    c_new/r_new: (L, dl|dr) fp latents of this chunk.  Like the GQA
    extend ref, the fp chunk runs as the last scan step so every tile's
    ``(m, l, acc)`` materializes through the carry (bit-parity with the
    kernel's output refs).  Returns (L, H, dl) latent context."""
    L, h, _ = ql.shape
    n_past = tbl.shape[0]
    qlf = ql.astype(jnp.float32).reshape(L * h, dl)
    qrf = qr.astype(jnp.float32).reshape(L * h, dr)
    row_pos = jnp.repeat(start + jnp.arange(L), h)

    # fp tile padded to a sublane multiple (see the GQA extend ref)
    Lp = -(-L // 8) * 8
    cf = c_new.astype(jnp.float32)
    rf = r_new.astype(jnp.float32)
    if Lp != L:
        cf = jnp.pad(cf, ((0, Lp - L), (0, 0)))
        rf = jnp.pad(rf, ((0, Lp - L), (0, 0)))
    kv_pos = start + jnp.arange(Lp)
    causal = row_pos[:, None] >= kv_pos[None, :]
    tbl_x = tbl if n_past else jnp.zeros((1,), jnp.int32)

    def step(carry, kk):
        m, l, acc = carry
        pid = tbl_x[jnp.maximum(jnp.minimum(kk, n_past - 1), 0)]

        def past(m, l, acc):
            c = _dequant_kv(jnp.take(cq, pid, axis=0),
                            jnp.take(cs, pid, axis=0), kv_bits=kv_bits,
                            chunk=chunk, d=dl)
            r = _dequant_kv(jnp.take(rq, pid, axis=0),
                            jnp.take(rs, pid, axis=0), kv_bits=kv_bits,
                            chunk=chunk, d=dr)
            scores = (jax.lax.dot_general(
                qlf, c, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
                + jax.lax.dot_general(
                    qrf, r, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32))
            valid = jnp.ones((1, page), bool)
            return _tile_update(scores, c, valid, m, l, acc)

        def fp_chunk(m, l, acc):
            scores = (jax.lax.dot_general(
                qlf, cf, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
                + jax.lax.dot_general(
                    qrf, rf, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32))
            return _tile_update(scores, cf, causal, m, l, acc)

        return jax.lax.cond(kk < n_past, past, fp_chunk, m, l, acc), None

    m0 = jnp.full((L * h, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((L * h, 1), jnp.float32)
    acc0 = jnp.zeros((L * h, dl), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0),
                                  jnp.arange(n_past + 1))
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(L, h, dl)
