"""Grouped-einsum reference for quantized-KV flash decode.

Bit-parity contract: mirrors the Pallas kernels tile for tile — the same
``s_blk`` tiling, the same ``_dequant_kv`` / ``_tile_update`` helpers
(imported from kernel.py), the same ``dot_general`` dimension numbers
with fp32 accumulation — so kernel == ref holds *bitwise* on the same
codes (pinned in tests/test_kv_cache.py).

Also the serving fallback with the same footprint discipline as
``quant_matmul.ref``: a ``lax.scan`` over KV tiles that dequantizes only
the active (s_blk, d) tile in-register — the full cache is never
materialized in fp here either (this replaces the per-step full-cache
``kv_dequantize`` the old int8 path did), and the scan is plain jnp, so
GSPMD partitions it like any einsum.  That makes it the route used under
a mesh whenever the split-KV ``shard_map`` can't run (misaligned local
tiles): an opaque Pallas custom call there would make GSPMD all-gather
the cache — the quant_matmul fallback policy, applied to the cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.kernel import (NEG_INF, _dequant_kv,
                                               _tile_update)


def _pad_tiles(x, blk: int):
    """Pad the sequence axis (1) up to a tile multiple — padded rows are
    code/scale zeros and always position-masked."""
    pad = (-x.shape[1]) % blk
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[1] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=(
    "kv_bits", "chunk", "dh", "dv", "s_blk"))
def flash_decode_ref(q, kq, ks, vq, vs, pos, *, kv_bits: int, chunk: int,
                     dh: int, dv: int, s_blk: int):
    """GQA partials (acc, m, l) matching ``flash_decode_pallas`` bitwise.

    Same signature/layouts as the kernel (pos may be any int shape); S is
    padded up to an ``s_blk`` multiple when ragged (masking covers it)."""
    b, kv, g, _ = q.shape
    rows_c = s_blk // chunk
    kq, vq = _pad_tiles(kq, s_blk), _pad_tiles(vq, s_blk)
    ks, vs = _pad_tiles(ks, rows_c), _pad_tiles(vs, rows_c)
    n_tiles = kq.shape[1] // s_blk
    qf = q.astype(jnp.float32)
    px = jnp.reshape(pos, (-1,))[0].astype(jnp.int32)

    def one(kk, qh, kc, ksc, vc, vsc, m1, l1, acc1):
        # identical per-(batch, kv_head) tile math to _fd_kernel
        k = _dequant_kv(kc, ksc, kv_bits=kv_bits, chunk=chunk, d=dh)
        v = _dequant_kv(vc, vsc, kv_bits=kv_bits, chunk=chunk, d=dv)
        scores = jax.lax.dot_general(
            qh, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        idx = kk * s_blk + jax.lax.broadcasted_iota(jnp.int32, (1, s_blk), 1)
        return _tile_update(scores, v, idx <= px, m1, l1, acc1)

    def step(carry, kk):
        acc, m, l = carry
        # slice the active tile *first*, then transpose the tiny tile to
        # (B, KV, s_blk, ·) — never a full-cache copy
        k_t = jnp.moveaxis(
            jax.lax.dynamic_slice_in_dim(kq, kk * s_blk, s_blk, 1), 1, 2)
        v_t = jnp.moveaxis(
            jax.lax.dynamic_slice_in_dim(vq, kk * s_blk, s_blk, 1), 1, 2)
        ks_t = jnp.moveaxis(
            jax.lax.dynamic_slice_in_dim(ks, kk * rows_c, rows_c, 1), 1, 2)
        vs_t = jnp.moveaxis(
            jax.lax.dynamic_slice_in_dim(vs, kk * rows_c, rows_c, 1), 1, 2)
        f = jax.vmap(jax.vmap(functools.partial(one, kk)))
        m_new, l_new, acc_new = f(qf, k_t, ks_t, v_t, vs_t, m, l, acc)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, kv, g, dv), jnp.float32)
    m0 = jnp.full((b, kv, g, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, 1), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0),
                                  jnp.arange(n_tiles))
    return acc, m, l


@functools.partial(jax.jit, static_argnames=(
    "kv_bits", "chunk", "dl", "dr", "s_blk"))
def mla_flash_decode_ref(ql, qr, cq, cs, rq, rs, pos, *, kv_bits: int,
                         chunk: int, dl: int, dr: int, s_blk: int):
    """MLA partials (acc, m, l) matching ``mla_flash_decode_pallas``."""
    b, h, _ = ql.shape
    rows_c = s_blk // chunk
    cq, rq = _pad_tiles(cq, s_blk), _pad_tiles(rq, s_blk)
    cs, rs = _pad_tiles(cs, rows_c), _pad_tiles(rs, rows_c)
    n_tiles = cq.shape[1] // s_blk
    qlf, qrf = ql.astype(jnp.float32), qr.astype(jnp.float32)
    px = jnp.reshape(pos, (-1,))[0].astype(jnp.int32)

    def one(kk, qlh, qrh, cc, csc, rc, rsc, m1, l1, acc1):
        c = _dequant_kv(cc, csc, kv_bits=kv_bits, chunk=chunk, d=dl)
        r = _dequant_kv(rc, rsc, kv_bits=kv_bits, chunk=chunk, d=dr)
        scores = (jax.lax.dot_general(qlh, c, (((1,), (1,)), ((), ())),
                                      preferred_element_type=jnp.float32)
                  + jax.lax.dot_general(qrh, r, (((1,), (1,)), ((), ())),
                                        preferred_element_type=jnp.float32))
        idx = kk * s_blk + jax.lax.broadcasted_iota(jnp.int32, (1, s_blk), 1)
        return _tile_update(scores, c, idx <= px, m1, l1, acc1)

    def step(carry, kk):
        acc, m, l = carry
        c_t = jax.lax.dynamic_slice_in_dim(cq, kk * s_blk, s_blk, 1)
        r_t = jax.lax.dynamic_slice_in_dim(rq, kk * s_blk, s_blk, 1)
        cs_t = jax.lax.dynamic_slice_in_dim(cs, kk * rows_c, rows_c, 1)
        rs_t = jax.lax.dynamic_slice_in_dim(rs, kk * rows_c, rows_c, 1)
        f = jax.vmap(functools.partial(one, kk))
        m_new, l_new, acc_new = f(qlf, qrf, c_t, cs_t, r_t, rs_t, m, l, acc)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, h, dl), jnp.float32)
    m0 = jnp.full((b, h, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, 1), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), jnp.arange(n_tiles))
    return acc, m, l
