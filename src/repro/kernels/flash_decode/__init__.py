from repro.kernels.flash_decode.ops import (  # noqa: F401
    flash_decode,
    mla_flash_decode,
)
from repro.kernels.flash_decode.kernel import (  # noqa: F401
    flash_decode_pallas,
    mla_flash_decode_pallas,
)
from repro.kernels.flash_decode.ref import (  # noqa: F401
    flash_decode_ref,
    mla_flash_decode_ref,
)
