from repro.kernels.flash_decode.ops import (  # noqa: F401
    flash_decode,
    mla_flash_decode,
    paged_flash_decode,
    paged_flash_extend,
    paged_mla_flash_decode,
    paged_mla_flash_extend,
)
from repro.kernels.flash_decode.kernel import (  # noqa: F401
    flash_decode_pallas,
    mla_flash_decode_pallas,
    paged_flash_decode_pallas,
    paged_flash_extend_pallas,
    paged_mla_flash_decode_pallas,
    paged_mla_flash_extend_pallas,
)
from repro.kernels.flash_decode.ref import (  # noqa: F401
    flash_decode_ref,
    mla_flash_decode_ref,
    paged_flash_decode_ref,
    paged_flash_extend_ref,
    paged_mla_flash_decode_ref,
    paged_mla_flash_extend_ref,
)
