"""Public wrapper: flash decode against a quantized KV cache.

Dispatch policy (shared with ``quant_matmul``): the Pallas kernel on TPU,
the tile-matched jnp ref elsewhere; the ``REPRO_FD_KERNEL`` env var
overrides the default (``1`` forces the kernel — interpret mode off-TPU,
a correctness/CI tool; ``0`` forces the ref); an explicit ``use_kernel=``
argument beats both.

Split-KV sharding: when the caller is on a live mesh (``mesh``/``axis``
from the model's ``ParallelCtx``), the KV sequence axis of the cache is
already model-axis-sharded (``launch.specs.cache_shardings`` — context
parallelism), and :func:`flash_decode` runs the kernel *per shard* under
``shard_map``: each device computes flash-decode partials over its local
sequence slice, then the shards merge with a max/sum-shifted partial
softmax.  The only collective is one ``all_gather`` of the concatenated
``(acc, m, l)`` triple — a few hundred bytes per (batch, head) — and
*zero* cache collectives: the codes never move, which is the whole point
of sharding a long cache.  When the local tile can't align (sequence not
divisible by the axis, a 2-bit scale chunk straddling shards) the call
falls back to the GSPMD-partitionable scan ref — an opaque Pallas custom
call under GSPMD would make XLA all-gather the cache, exactly the
quant_matmul mesh policy.  The CI mesh leg counts ref calls and asserts
zero, so a silently demoted serving config fails the bench.

Tile selection: ``_s_tile`` picks the largest sequence tile <= 512 that
divides S and holds whole scale chunks.  ``models.lm`` rounds quantized
cache lengths up to a ``cfg.kv_chunk`` multiple at allocation, so a
healthy serving config always tiles at >= 64 rows.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.flash_decode.kernel import (
    flash_decode_pallas, mla_flash_decode_pallas,
    paged_flash_decode_pallas, paged_flash_extend_pallas,
    paged_mla_flash_decode_pallas, paged_mla_flash_extend_pallas)
from repro.kernels.flash_decode.ref import (
    flash_decode_ref, mla_flash_decode_ref, paged_flash_decode_ref,
    paged_flash_extend_ref, paged_mla_flash_decode_ref,
    paged_mla_flash_extend_ref)

try:  # jax >= 0.4.35
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _kernel_default() -> bool:
    """Backend kernel policy with the ``REPRO_FD_KERNEL`` env override."""
    env = os.environ.get("REPRO_FD_KERNEL")
    if env is not None:
        return env.strip().lower() not in ("", "0", "false", "off")
    return jax.default_backend() == "tpu"


def _smap(fn, mesh, in_specs, out_specs):
    """shard_map with replication checking off, across the jax rename
    (``check_rep`` -> ``check_vma``): the Pallas custom call has no
    replication rule for the checker to consult."""
    try:
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    except TypeError:  # pragma: no cover - depends on jax version
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)


def _s_tile(s: int, chunk: int) -> int:
    """Largest sequence tile <= 512 that divides s and holds whole scale
    chunks (0 when none exists — caller pads or takes the ref)."""
    best, t = 0, chunk
    lim = min(s, 512)
    while t <= lim:
        if s % t == 0:
            best = t
        t += chunk
    return best


def _finalize(acc, l):
    return acc / jnp.maximum(l, 1e-30)


def _merge_partials(parts, dv: int):
    """Merge per-shard (acc | m | l) partials gathered on a leading axis:
    shift every shard's unnormalized accumulator/denominator to the global
    max and sum — the distributed-softmax identity."""
    accs, ms, ls = parts[..., :dv], parts[..., dv:dv + 1], parts[..., dv + 1:]
    m_g = jnp.max(ms, axis=0)
    w = jnp.exp(ms - m_g)
    return jnp.sum(w * accs, axis=0) / jnp.maximum(jnp.sum(w * ls, axis=0),
                                                   1e-30)


def _pos2d(pos) -> jax.Array:
    return jnp.reshape(jnp.asarray(pos).astype(jnp.int32), (-1,))[:1][None]


# ------------------------------------------------------------------- GQA


def _split_kv_gqa(q, kq, ks, vq, vs, px, *, mesh, axis, dp, kv_bits, chunk,
                  dh, dv):
    """Split-KV shard_map route; None when the local tile can't align."""
    axis_size = mesh.shape[axis]
    s = kq.shape[1]
    if axis_size < 2 or s % axis_size or ks.shape[1] % axis_size:
        return None
    s_loc = s // axis_size
    if chunk > 1 and s_loc % chunk:
        return None
    s_blk = _s_tile(s_loc, chunk)
    if not s_blk:
        return None

    def local(qx, kqx, ksx, vqx, vsx, pxx):
        # local positions: this shard holds rows [rank*s_loc, ...)
        p_loc = pxx - jax.lax.axis_index(axis) * s_loc
        acc, m, l = flash_decode_pallas(
            qx, kqx, ksx, vqx, vsx, p_loc, kv_bits=kv_bits, chunk=chunk,
            dh=dh, dv=dv, s_blk=s_blk, interpret=_interpret())
        # the ONE collective: tiny (acc, m, l) partials, zero cache bytes
        parts = jax.lax.all_gather(jnp.concatenate([acc, m, l], axis=-1),
                                   axis)
        return _merge_partials(parts, dv)

    qspec, cspec = P(dp), P(dp, axis)
    return _smap(local, mesh,
                 in_specs=(qspec, cspec, cspec, cspec, cspec, P()),
                 out_specs=qspec)(q, kq, ks, vq, vs, px)


def flash_decode(q, kq, ks, vq, vs, pos, *, kv_bits: int, chunk: int,
                 dv: int | None = None, mesh=None, axis=None, dp=None,
                 use_kernel: bool | None = None):
    """Single-token GQA attention directly on quantized KV.

    q: (B, KV, G, Dh) f32 query groups with the attention scale folded in;
    kq/ks/vq/vs: codes + scales as stored in the cache (``models.lm``);
    pos: () int32 — last valid cache row.  Returns (B, KV, G, Dv) f32
    normalized attention output.  ``mesh``/``axis``/``dp`` (from the
    model's ParallelCtx) enable the split-KV shard_map route."""
    dh = q.shape[-1]
    if dv is None:
        assert kv_bits == 8, "dv is required for packed 2-bit codes"
        dv = vq.shape[-1]
    if use_kernel is None:
        use_kernel = _kernel_default()
    px = _pos2d(pos)
    if mesh is not None and axis is not None and use_kernel:
        out = _split_kv_gqa(q, kq, ks, vq, vs, px, mesh=mesh, axis=axis,
                            dp=dp, kv_bits=kv_bits, chunk=chunk, dh=dh,
                            dv=dv)
        if out is not None:
            return out
    s = kq.shape[1]
    s_blk = _s_tile(s, chunk)
    if mesh is None and use_kernel and s_blk:
        acc, _, l = flash_decode_pallas(
            q, kq, ks, vq, vs, px, kv_bits=kv_bits, chunk=chunk, dh=dh,
            dv=dv, s_blk=s_blk, interpret=_interpret())
        return _finalize(acc, l)
    # meshless non-kernel path, or on-mesh misalignment (GSPMD partitions
    # the scan ref; it must never see the opaque kernel custom call)
    acc, _, l = flash_decode_ref(
        q, kq, ks, vq, vs, px, kv_bits=kv_bits, chunk=chunk, dh=dh, dv=dv,
        s_blk=s_blk or min(s, 512))
    return _finalize(acc, l)


# ------------------------------------------------------------------- MLA


def _split_kv_mla(ql, qr, cq, cs, rq, rs, px, *, mesh, axis, dp, kv_bits,
                  chunk, dl, dr):
    axis_size = mesh.shape[axis]
    s = cq.shape[1]
    if axis_size < 2 or s % axis_size or cs.shape[1] % axis_size:
        return None
    s_loc = s // axis_size
    if chunk > 1 and s_loc % chunk:
        return None
    s_blk = _s_tile(s_loc, chunk)
    if not s_blk:
        return None

    def local(qlx, qrx, cqx, csx, rqx, rsx, pxx):
        p_loc = pxx - jax.lax.axis_index(axis) * s_loc
        acc, m, l = mla_flash_decode_pallas(
            qlx, qrx, cqx, csx, rqx, rsx, p_loc, kv_bits=kv_bits,
            chunk=chunk, dl=dl, dr=dr, s_blk=s_blk, interpret=_interpret())
        parts = jax.lax.all_gather(jnp.concatenate([acc, m, l], axis=-1),
                                   axis)
        return _merge_partials(parts, dl)

    qspec, cspec = P(dp), P(dp, axis)
    return _smap(local, mesh,
                 in_specs=(qspec, qspec, cspec, cspec, cspec, cspec, P()),
                 out_specs=qspec)(ql, qr, cq, cs, rq, rs, px)


def mla_flash_decode(ql, qr, cq, cs, rq, rs, pos, *, kv_bits: int,
                     chunk: int, dl: int, dr: int, mesh=None, axis=None,
                     dp=None, use_kernel: bool | None = None):
    """Single-token MLA latent attention directly on quantized c/r codes.

    ql: (B, H, dl), qr: (B, H, dr) — absorbed queries with the attention
    scale folded in; values are the latents (v = c).  Returns (B, H, dl)
    f32 normalized latent context."""
    if use_kernel is None:
        use_kernel = _kernel_default()
    px = _pos2d(pos)
    if mesh is not None and axis is not None and use_kernel:
        out = _split_kv_mla(ql, qr, cq, cs, rq, rs, px, mesh=mesh,
                            axis=axis, dp=dp, kv_bits=kv_bits, chunk=chunk,
                            dl=dl, dr=dr)
        if out is not None:
            return out
    s = cq.shape[1]
    s_blk = _s_tile(s, chunk)
    if mesh is None and use_kernel and s_blk:
        acc, _, l = mla_flash_decode_pallas(
            ql, qr, cq, cs, rq, rs, px, kv_bits=kv_bits, chunk=chunk,
            dl=dl, dr=dr, s_blk=s_blk, interpret=_interpret())
        return _finalize(acc, l)
    acc, _, l = mla_flash_decode_ref(
        ql, qr, cq, cs, rq, rs, px, kv_bits=kv_bits, chunk=chunk, dl=dl,
        dr=dr, s_blk=s_blk or min(s, 512))
    return _finalize(acc, l)


# ----------------------------------------------------------------- paged


def paged_flash_decode(tbl, pos, q, kq, ks, vq, vs, *, kv_bits: int,
                       chunk: int, dv: int, page: int,
                       use_kernel: bool | None = None):
    """Single-token GQA attention over a block-paged quantized pool.

    tbl: (B, n_tiles) int32 per-request page table (pad slots with the
    trash page 0); pos: (B,) int32 per-request last valid position;
    q: (B, KV, G, Dh) f32 scaled queries; kq/vq: (n_pages, page, KV, w·)
    code pools; ks/vs: (n_pages, page // chunk, KV) scale pools.  Returns
    (B, KV, G, Dv) f32.

    Serving engines are meshless by design (the engine owns the batch
    axis); there is deliberately no shard_map route here — the split-KV
    policy of :func:`flash_decode` does not apply to paged pools, whose
    sequence axis is virtual (the page table).
    """
    dh = q.shape[-1]
    if use_kernel is None:
        use_kernel = _kernel_default()
    px = jnp.reshape(jnp.asarray(pos).astype(jnp.int32), (q.shape[0], 1))
    if use_kernel:
        acc, _, l = paged_flash_decode_pallas(
            tbl, px, q, kq, ks, vq, vs, kv_bits=kv_bits, chunk=chunk,
            dh=dh, dv=dv, page=page, interpret=_interpret())
    else:
        acc, _, l = paged_flash_decode_ref(
            tbl, px, q, kq, ks, vq, vs, kv_bits=kv_bits, chunk=chunk,
            dh=dh, dv=dv, page=page)
    return _finalize(acc, l)


def paged_mla_flash_decode(tbl, pos, ql, qr, cq, cs, rq, rs, *,
                           kv_bits: int, chunk: int, dl: int, dr: int,
                           page: int, use_kernel: bool | None = None):
    """Single-token MLA latent attention over block-paged latent pools.

    tbl: (B, n_tiles) int32; pos: (B,) int32; ql/qr: (B, H, dl|dr) scaled
    absorbed queries; cq/rq: (n_pages, page, w·) code pools; cs/rs:
    (n_pages, page // chunk) scale pools.  Returns (B, H, dl) f32.
    Meshless, like :func:`paged_flash_decode`."""
    if use_kernel is None:
        use_kernel = _kernel_default()
    px = jnp.reshape(jnp.asarray(pos).astype(jnp.int32), (ql.shape[0], 1))
    if use_kernel:
        acc, _, l = paged_mla_flash_decode_pallas(
            tbl, px, ql, qr, cq, cs, rq, rs, kv_bits=kv_bits, chunk=chunk,
            dl=dl, dr=dr, page=page, interpret=_interpret())
    else:
        acc, _, l = paged_mla_flash_decode_ref(
            tbl, px, ql, qr, cq, cs, rq, rs, kv_bits=kv_bits, chunk=chunk,
            dl=dl, dr=dr, page=page)
    return _finalize(acc, l)


# ---------------------------------------------- paged (chunked-prefill) extend


def paged_flash_extend(tbl, q, k_new, v_new, kq, ks, vq, vs, start, *,
                       kv_bits: int, chunk: int, dh: int, dv: int,
                       page: int, use_kernel: bool | None = None):
    """Chunked-prefill GQA attention over a block-paged quantized pool.

    An L-token query chunk attends to its own request's quantized past
    pages (``tbl``: (n_past,) int32 — chunk boundaries are page-aligned so
    every past page is full) plus its fp within-chunk keys/values
    (causal).  q: (1, L, H, Dh) *unscaled*; k_new/v_new: (1, L, KV, ·) fp;
    start = n_past * page.  Returns (1, L, H, Dv) f32.  Meshless, like
    :func:`paged_flash_decode` (the engine owns the batch axis)."""
    if use_kernel is None:
        use_kernel = _kernel_default()
    if use_kernel:
        return paged_flash_extend_pallas(
            tbl, q, k_new, v_new, kq, ks, vq, vs, start, kv_bits=kv_bits,
            chunk=chunk, dh=dh, dv=dv, page=page, interpret=_interpret())
    return paged_flash_extend_ref(
        tbl, q, k_new, v_new, kq, ks, vq, vs, start, kv_bits=kv_bits,
        chunk=chunk, dh=dh, dv=dv, page=page)


def paged_mla_flash_extend(tbl, ql, qr, c_new, r_new, cq, cs, rq, rs, start,
                           *, kv_bits: int, chunk: int, dl: int, dr: int,
                           page: int, use_kernel: bool | None = None):
    """Chunked-prefill MLA latent attention over block-paged latent pools.

    ql/qr: (L, H, dl|dr) *scaled* absorbed queries; c_new/r_new:
    (L, dl|dr) fp latents of this chunk; values are the latents (v = c).
    Returns (L, H, dl) f32 latent context.  Meshless."""
    if use_kernel is None:
        use_kernel = _kernel_default()
    if use_kernel:
        return paged_mla_flash_extend_pallas(
            tbl, ql, qr, c_new, r_new, cq, cs, rq, rs, start,
            kv_bits=kv_bits, chunk=chunk, dl=dl, dr=dr, page=page,
            interpret=_interpret())
    return paged_mla_flash_extend_ref(
        tbl, ql, qr, c_new, r_new, cq, cs, rq, rs, start, kv_bits=kv_bits,
        chunk=chunk, dl=dl, dr=dr, page=page)
