"""Full RSQ workflow on a TRAINED model: train -> quantize (GPTQ / QuaRot /
RSQ) -> evaluate held-out perplexity.  This is the paper's Tab. 2 in
miniature (the benchmark suite runs the full grid; this example shows the
workflow through the public API).

    PYTHONPATH=src:. python examples/quantize_then_eval.py --steps 300
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.common import (calib_and_heldout, eval_ppl,
                               get_trained_model, quantize_and_eval)
from repro.core import RSQConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--bits", type=int, default=2)
    args = ap.parse_args()

    model, params, corpus = get_trained_model(steps=args.steps)
    _, heldout = calib_and_heldout(corpus)
    print(f"fp32 held-out ppl: {eval_ppl(model, params, heldout):.3f}")
    for name, rsq in {
        "GPTQ": RSQConfig(bits=args.bits, group_size=32, rotate=False,
                          importance="uniform"),
        "QuaRot": RSQConfig(bits=args.bits, group_size=32, rotate=True,
                            importance="uniform"),
        "RSQ": RSQConfig(bits=args.bits, group_size=32, rotate=True,
                         importance="attn_con", expansion=2),
        # same recipe through the overlapped scheduler + streaming sharded
        # Hessian accumulators: identical quality (the scheduler is
        # bit-exact; sharding only reorders float sums), faster dispatch
        "RSQ-ovl": RSQConfig(bits=args.bits, group_size=32, rotate=True,
                             importance="attn_con", expansion=2,
                             scheduler="overlapped", shard_hessians=2),
    }.items():
        res = quantize_and_eval(model, params, corpus, rsq)
        print(f"{name:7s} {args.bits}-bit: ppl={res['ppl']:.3f} "
              f"({res['seconds']}s)")


if __name__ == "__main__":
    main()
