"""Quickstart: quantize a small LM with RSQ and compare against GPTQ/QuaRot.

    PYTHONPATH=src python examples/quickstart.py

Trains nothing — uses a randomly initialized tiny model so it finishes in
~2 minutes; see examples/quantize_then_eval.py for the trained-model
version whose perplexities are meaningful.

Scaling knobs on ``RSQConfig`` (all orthogonal to the recipe itself):

  * ``scheduler=`` — how the layer loop is dispatched.  ``"sequential"``
    is the classic lock-step loop; ``"overlapped"`` software-pipelines
    layer i's GPTQ solve with layer i+1's capture pass (bit-identical
    output, faster wall-clock); ``None`` auto-picks (sequential on CPU,
    overlapped on accelerators).
  * ``shard_hessians=`` — ``False`` keeps dense per-weight (d, d) Hessian
    accumulators; ``True`` shards them over the mesh's data axes (each
    device accumulates only its local calibration shard, one psum at solve
    time); an int S > 1 keeps S streaming partial-sum shards even without
    a mesh.
  * ``trace_cache=`` / ``use_gram_kernel=`` — per-meta jit reuse and the
    Pallas gram kernel for the Hessian update (auto-on for TPU).

The RSQ demo below runs with ``scheduler="overlapped"`` to exercise the
pipelined dispatch path; the printed perplexities are identical to the
sequential schedule by construction.
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import RSQConfig, quantize_model
from repro.data.synthetic import SyntheticCorpus
from repro.models import build_model


def main():
    cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                              dtype="float32")
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)
    calib = corpus.sample(jax.random.key(1), 16, 128)

    def ppl(p):
        return float(jnp.exp(model.loss(
            p, {"tokens": calib, "labels": jnp.roll(calib, -1, 1)})))

    print(f"fp32 model: ppl={ppl(params):.2f}")
    for name, rsq in {
        "GPTQ  (no rotation, uniform)": RSQConfig(bits=3, rotate=False,
                                                  importance="uniform"),
        "QuaRot (rotation, uniform)  ": RSQConfig(bits=3, rotate=True,
                                                  importance="uniform"),
        "RSQ   (rotation + AttnCon)  ": RSQConfig(bits=3, rotate=True,
                                                  importance="attn_con",
                                                  scheduler="overlapped"),
    }.items():
        qparams, report = quantize_model(model, params, calib, rsq,
                                         batch_size=8)
        n_w = sum(len(l["weights"]) for l in report["layers"].values())
        print(f"{name}: ppl={ppl(qparams):.2f}  ({n_w} weights @ "
              f"{rsq.bits}-bit)")


if __name__ == "__main__":
    main()
