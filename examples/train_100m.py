"""End-to-end driver: train a ~100M-param LLaMA-style model for a few
hundred steps on the synthetic corpus, with checkpointing + fault tolerance.

    PYTHONPATH=src python examples/train_100m.py --steps 300

On this CPU container a step takes a few seconds (use ``--smoke`` for CI
sizes); the same code path with a production mesh context trains on a real
pod.  ~100M params: 12 layers x d_model 768 x d_ff 2048, vocab 32k.
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.loader import DataLoader
from repro.data.synthetic import SyntheticCorpus
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import cosine_schedule, make_optimizer
from repro.runtime.fault import StepRunner

CFG_100M = ModelConfig(
    name="llama-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32768, d_head=64,
    rope_theta=10_000.0, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink the model for CI (seconds, not minutes)")
    args = ap.parse_args()

    cfg = CFG_100M if not args.smoke else dataclasses.replace(
        CFG_100M, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab_size=1024)
    print(f"{cfg.name}: {cfg.n_params() / 1e6:.1f}M params", flush=True)

    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    opt = make_optimizer("adamw", cosine_schedule(6e-4, 40, args.steps),
                         weight_decay=0.01)
    opt_state = jax.jit(opt.init)(params)
    loader = DataLoader(SyntheticCorpus(cfg.vocab_size, seed=0),
                        args.batch, args.seq)
    ckpt = CheckpointManager(args.ckpt, keep=2)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
    runner = StepRunner(step_fn, ckpt, save_every=100)
    out = runner.run(params, opt_state, loader, args.steps)
    print(f"final loss {out['losses'][-1]:.4f}", flush=True)


if __name__ == "__main__":
    main()
