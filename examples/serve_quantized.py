"""Serve a small RSQ-quantized model with batched requests.

Pipeline: init -> RSQ-quantize (3-bit) -> prefill a batch of prompts ->
greedy decode with the KV cache.  Shows that the quantized parameter tree
drops into the exact same serving path, plus the packed int4 path through
the quant_matmul kernel for one projection.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import RSQConfig, quantize_model
from repro.core.quantizer import QuantSpec, quantize_weight_rtn
from repro.data.synthetic import SyntheticCorpus
from repro.kernels.quant_matmul.ops import pack_weight, quant_matmul
from repro.launch.serve import generate
from repro.models import build_model


def main():
    cfg = dataclasses.replace(get_config("qwen1.5-4b").reduced(),
                              dtype="float32")
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)
    calib = corpus.sample(jax.random.key(1), 16, 64)
    qparams, _ = quantize_model(
        model, params, calib,
        RSQConfig(bits=3, rotate=True, importance="attn_con"), batch_size=8)

    prompts = corpus.sample(jax.random.key(2), 4, 32)
    for tag, p in (("fp32", params), ("rsq-3bit", qparams)):
        t0 = time.time()
        out = generate(model, p, prompts, 16)
        jax.block_until_ready(out)
        print(f"{tag}: {out.shape[0] * out.shape[1]} tokens in "
              f"{time.time() - t0:.2f}s; sample {out[0][:8].tolist()}")

    # the packed-kernel serving path for one projection (int4 example)
    w = jax.tree.leaves(qparams["groups"])  # any quantized matrix
    w = next(x for x in w if x.ndim == 3 and min(x.shape[1:]) >= 64)[0]
    spec = QuantSpec(bits=4, group_size=32, sym=False)
    _, q, s, z = quantize_weight_rtn(w, spec)
    pw = pack_weight(q, s, z, spec)
    x = jax.random.normal(jax.random.key(3), (8, w.shape[0]))
    y = quant_matmul(x, pw)
    print(f"packed int4 GEMM: x{tuple(x.shape)} @ W{tuple(w.shape)} -> "
          f"{tuple(y.shape)}; weight bytes {pw.w_packed.nbytes} vs fp32 "
          f"{w.nbytes} ({w.nbytes / pw.w_packed.nbytes:.1f}x smaller)")


if __name__ == "__main__":
    main()
