"""Serve a small RSQ-quantized model with batched requests, keep-packed.

Pipeline: init -> RSQ-quantize (4-bit, ``pack_output``) -> persist the
packed serving artifact -> reload it with the codes *kept packed in HBM*
(``load_packed_forward_params``) -> prefill a batch of prompts -> greedy
decode with the KV cache.  The packed parameter tree drops into the exact
same serving path as the fp one: every dense projection dispatches
through ``models.layers.linear``, which feeds ``PackedWeight`` nodes to
the fused dequant-GEMM ``quant_matmul`` — no fp copy of any quantized
weight is ever created (MLA's absorbed decode included), so resident
weight memory is ~bits/32 of the fp32 model.

Generation runs the fused **scan loop** (``generate(..., loop="scan")``,
the default): prefill plus one jitted ``lax.scan`` device program for the
whole decode — on-device sampling, donated KV cache, no per-token host
round-trip.  The example times the legacy ``loop="python"`` dispatch
loop alongside so the fusion win is visible next to the quantization
win.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import dataclasses
import shutil
import tempfile
import time

import jax

from repro.checkpoint.packed import (load_packed_forward_params,
                                     save_packed_artifact)
from repro.configs import get_config
from repro.core import RSQConfig, RSQPipeline
from repro.data.synthetic import SyntheticCorpus
from repro.launch.serve import generate, resident_weight_bytes
from repro.models import build_model


def main():
    cfg = dataclasses.replace(get_config("qwen1.5-4b").reduced(),
                              dtype="float32")
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)
    calib = corpus.sample(jax.random.key(1), 16, 64)

    # quantize + emit the packed serving artifact during write-back
    pipe = RSQPipeline(model, RSQConfig(bits=4, rotate=True,
                                        importance="attn_con",
                                        pack_output=True))
    qparams, _ = pipe.run(params, calib, batch_size=8)
    artifact_dir = tempfile.mkdtemp(prefix="rsq_artifact_")
    try:
        save_packed_artifact(artifact_dir, pipe.artifact, params=qparams,
                             extra={"arch": cfg.name})

        # keep-packed serving: uint32 codes live in the param tree; every
        # projection runs through quant_matmul
        packed_params, meta = load_packed_forward_params(artifact_dir)
    finally:
        shutil.rmtree(artifact_dir, ignore_errors=True)
    packed_b, fp_b = resident_weight_bytes(packed_params)
    print(f"artifact: {len(meta['entries'])} packed weights "
          f"(bits={meta['spec']['bits']}); resident bytes "
          f"{packed_b / 1e6:.2f}MB packed + {fp_b / 1e6:.2f}MB fp residual")

    prompts = corpus.sample(jax.random.key(2), 4, 32)
    for tag, p in (("fp32", params), ("rsq-4bit-keep-packed", packed_params)):
        for loop in ("scan", "python"):
            out = generate(model, p, prompts, 16, loop=loop)  # compile
            jax.block_until_ready(out)
            t0 = time.time()
            out = generate(model, p, prompts, 16, loop=loop)
            jax.block_until_ready(out)
            print(f"{tag} [loop={loop}]: {out.shape[0] * out.shape[1]} "
                  f"tokens in {time.time() - t0:.2f}s; "
                  f"sample {out[0][:8].tolist()}")


if __name__ == "__main__":
    main()
