"""Fig. 4 reproduction: dataset expansion (M=4 here) helps position-biased
strategies."""
from __future__ import annotations

from repro.core import RSQConfig

from benchmarks.common import Table, get_trained_model, quantize_and_eval


def run(bits: int = 2, m: int = 4, table: Table | None = None) -> dict:
    table = table or Table("fig4_expansion")
    model, params, corpus = get_trained_model()
    out = {}
    for strat in ("first_n", "attn_con"):
        for exp in (1, m):
            rsq = RSQConfig(bits=bits, group_size=64, rotate=True,
                            importance=strat, first_n=32, expansion=exp)
            ppl = quantize_and_eval(model, params, corpus, rsq)["ppl"]
            out[f"{strat}_M{exp}"] = ppl
            table.add(f"{strat}_M{exp}", 0.0, f"ppl={ppl:.3f}")
    table.add("claims", 0.0,
              f"expansion helps first_n: "
              f"{out[f'first_n_M{m}'] <= out['first_n_M1']}")
    return out


if __name__ == "__main__":
    run()
