"""Tab. 2 reproduction (proxy): GPTQ vs QuaRot vs RSQ.

Paper claim: RSQ < QuaRot < GPTQ in perplexity (3-bit)."""
from __future__ import annotations

from repro.core import RSQConfig

from benchmarks.common import (Table, eval_ppl, get_trained_model,
                               calib_and_heldout, quantize_and_eval)

METHODS = {
    "gptq": RSQConfig(rotate=False, importance="uniform"),
    "quarot": RSQConfig(rotate=True, importance="uniform"),
    # scale-only (paper Fig. 9 "SQ"): isolates the token-importance
    # contribution from rotation — our from-scratch proxy has no weight
    # outliers, so rotation itself is expected to be ~neutral here
    "sq": RSQConfig(rotate=False, importance="attn_con", r_min=0.5,
                    expansion=2),
    "rsq": RSQConfig(rotate=True, importance="attn_con", r_min=0.5,
                     expansion=2),
}


def run(bits: int = 2, seeds=(0, 1), table: Table | None = None) -> dict:
    import dataclasses

    table = table or Table("table2_main")
    model, params, corpus = get_trained_model()
    _, heldout = calib_and_heldout(corpus)
    fp = eval_ppl(model, params, heldout)
    table.add("full_model", 0.0, f"ppl={fp:.3f}")
    out = {}
    for name, base in METHODS.items():
        ppls = []
        for s in seeds:
            rsq = dataclasses.replace(base, bits=bits, group_size=64, seed=s)
            ppls.append(quantize_and_eval(model, params, corpus, rsq)["ppl"])
        mean = sum(ppls) / len(ppls)
        std = (sum((p - mean) ** 2 for p in ppls) / len(ppls)) ** 0.5
        out[name] = mean
        table.add(name, 0.0, f"ppl={mean:.3f} std={std:.3f}")
    table.add("claims", 0.0,
              f"rsq<quarot: {out['rsq'] < out['quarot']}; "
              f"sq<gptq (scaling helps): {out['sq'] < out['gptq']}; "
              f"quarot-vs-gptq (outlier-free proxy, ~neutral expected): "
              f"{out['quarot'] - out['gptq']:+.3f}")
    return out


if __name__ == "__main__":
    run()
