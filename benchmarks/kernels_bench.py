"""Kernel micro-benchmarks.

Wall times on this container are CPU-interpret-mode (NOT TPU performance);
the derived column therefore also reports the *analytic TPU roofline time*
per call from the kernel's bytes/FLOPs — the number the TPU deployment is
judged against."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.quantizer import QuantSpec, quantize_weight_rtn
from repro.kernels.attn_colsum.ops import attn_colsum
from repro.kernels.gram.ops import weighted_gram
from repro.kernels.hadamard.ops import fwht
from repro.kernels.quant_matmul.ops import pack_weight, quant_matmul

from benchmarks.common import Table

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _time(fn, *args, reps=3) -> float:
    jax.block_until_ready(fn(*args))  # compile/warm, fully retired
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(table: Table | None = None):
    table = table or Table("kernels")

    # hadamard: (n, d)
    n, d = 512, 512
    x = jax.random.normal(jax.random.key(0), (n, d))
    us = _time(fwht, x)
    flops = n * d * jnp.log2(d) * 2
    tpu_us = max(float(flops) / PEAK_FLOPS, 4 * n * d * 2 / HBM_BW) * 1e6
    table.add("fwht_512x512", us, f"tpu_roofline_us={tpu_us:.2f}")

    # gram: (n, d)
    n, d = 2048, 256
    x = jax.random.normal(jax.random.key(1), (n, d))
    r = jax.random.uniform(jax.random.key(2), (n,))
    us = _time(weighted_gram, x, r)
    flops = 2 * n * d * d
    tpu_us = max(flops / PEAK_FLOPS, (n * d * 4 + d * d * 4) / HBM_BW) * 1e6
    table.add("gram_2048x256", us, f"tpu_roofline_us={tpu_us:.2f}")

    # quant matmul: decode-ish shape
    m, k, nn = 8, 1024, 1024
    w = jax.random.normal(jax.random.key(3), (k, nn)) * 0.3
    spec = QuantSpec(bits=4, group_size=128, sym=False)
    _, q, s, z = quantize_weight_rtn(w, spec)
    pw = pack_weight(q, s, z, spec)
    xx = jax.random.normal(jax.random.key(4), (m, k))
    us = _time(lambda a: quant_matmul(a, pw, use_kernel=True), xx)
    bytes_w = k * nn / 2  # int4
    tpu_us = max(2 * m * k * nn / PEAK_FLOPS, bytes_w / HBM_BW) * 1e6
    bf16_us = (k * nn * 2) / HBM_BW * 1e6
    table.add("quant_matmul_w4_8x1024x1024", us,
              f"tpu_roofline_us={tpu_us:.2f} vs bf16 {bf16_us:.2f} "
              f"(4x weight-traffic win)")

    # attn colsum
    b, t, h, dh = 2, 512, 4, 64
    q4 = jax.random.normal(jax.random.key(5), (b, t, h, dh))
    k4 = jax.random.normal(jax.random.key(6), (b, t, h, dh))
    us = _time(lambda a, c: attn_colsum(a, c), q4, k4)
    flops = 2 * 2 * b * h * t * t * dh  # two passes
    tpu_us = max(flops / PEAK_FLOPS,
                 2 * b * h * t * dh * 4 / HBM_BW) * 1e6
    table.add("attn_colsum_2x512x4x64", us,
              f"tpu_roofline_us={tpu_us:.2f} (O(T) memory vs O(T^2) naive)")
    return table


if __name__ == "__main__":
    run()
