"""Fig. 2 reproduction: First-N and First&Last-N vs number of used tokens.

Paper claims: a sweet spot exists below T (fewest tokens is NOT best), and
First&Last-N >= First-N at the optimum."""
from __future__ import annotations

from repro.core import RSQConfig

from benchmarks.common import Table, get_trained_model, quantize_and_eval

NS = (8, 32, 64, 128)  # T = 128 -> "all"


def run(bits: int = 2, table: Table | None = None) -> dict:
    table = table or Table("fig2_heuristics")
    model, params, corpus = get_trained_model()
    out = {}
    for strat in ("first_n", "first_last_n"):
        for n in NS:
            rsq = RSQConfig(bits=bits, group_size=64, rotate=True,
                            importance=strat, first_n=n)
            ppl = quantize_and_eval(model, params, corpus, rsq)["ppl"]
            out[f"{strat}_{n}"] = ppl
            table.add(f"{strat}_N{n}", 0.0, f"ppl={ppl:.3f}")
    best_first = min(out[f"first_n_{n}"] for n in NS)
    table.add("claims", 0.0,
              f"sweet spot below T: "
              f"{best_first <= out['first_n_128'] + 1e-6 and out['first_n_8'] >= best_first}")
    return out


if __name__ == "__main__":
    run()
