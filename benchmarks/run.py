"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per row.  Times reported for
quantization runs are pipeline wall-times on CPU; the scientific payload is
the derived ppl / claim fields (see benchmarks/common.py docstring).

  PYTHONPATH=src python -m benchmarks.run            # all tables
  PYTHONPATH=src python -m benchmarks.run --only table2_main,roofline
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import Table


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benches")
    args = ap.parse_args()

    from benchmarks import (fig2_heuristics, fig3_dynamic, fig4_expansion,
                            kernels_bench, pipeline_bench, roofline,
                            table1_chunks, table2_main, table4_calib,
                            table5_bits, table6_vq)

    benches = {
        "table1_chunks": lambda t: table1_chunks.run(table=t),
        "table2_main": lambda t: table2_main.run(table=t),
        "fig2_heuristics": lambda t: fig2_heuristics.run(table=t),
        "fig3_dynamic": lambda t: fig3_dynamic.run(table=t),
        "fig4_expansion": lambda t: fig4_expansion.run(table=t),
        "table4_calib": lambda t: table4_calib.run(table=t),
        "table5_bits": lambda t: table5_bits.run(table=t),
        "table6_vq": lambda t: table6_vq.run(table=t),
        "kernels": lambda t: kernels_bench.run(table=t),
        "pipeline": lambda t: pipeline_bench.run(table=t),
        "roofline": lambda t: roofline.run(table=t),
    }
    selected = (args.only.split(",") if args.only else list(benches))
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in selected:
        if name not in benches:
            print(f"unknown bench {name!r}", file=sys.stderr)
            continue
        t = Table(name)
        try:
            benches[name](t)
        except Exception as e:  # keep the suite going
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}", flush=True)
    print(f"# total {time.time() - t0:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
