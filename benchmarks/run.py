"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per row.  Times reported for
quantization runs are pipeline wall-times on CPU; the scientific payload is
the derived ppl / claim fields (see benchmarks/common.py docstring).

  PYTHONPATH=src python -m benchmarks.run            # all tables
  PYTHONPATH=src python -m benchmarks.run --only table2_main,roofline

Benches that persist a ``BENCH_*.json`` at the repo root (the pipeline
and serve benches) are regression-guarded: the checked-in JSON is snapshotted
before the run and every *steady-state* timing field (``steady_total_s``)
of the fresh result is compared against it — any steady wall-time >20%
over the baseline fails the run loudly (exit 1).  Cold/compile-inclusive
fields (``cold_total_s``, ``compile_s``) are recorded for the trajectory
but never gated: compile time is XLA-version and cache-state noise, and
gating on it made the guard cry wolf (see ROADMAP).

``BENCH_serve.json`` additionally gates same-box *ratios* of the fresh
result at ``SERVE_RATIO_TOL`` (25%): packed vs fp decode, quantized-KV
vs fp decode at the longest context, and (PR 8) the serve engine's
sustained tok/s vs the fixed-batch baseline at equal load.  Unlike the
wall-time gate these are machine-independent — both sides of each ratio
run on the same box in the same bench — and each is exactly the
regression its subsystem exists to prevent (PR-4's python-dispatch
decode loop shipped packed slower than fp and the guard passed
silently; see ROADMAP).

CI runs this gate as a non-blocking job (.github/workflows/ci.yml).
``--no-regression-check`` skips the guard (e.g. when moving the
baselines to a new machine on purpose).  A bench that *raises* fails the
run (exit 2) even with the guard skipped — in-bench assertions like
serve_bench's zero-ref-fallback mesh check are gates in their own right.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from benchmarks.common import Table

REPO = Path(__file__).resolve().parent.parent
REGRESSION_TOL = 1.20  # fail when fresh steady_total_s > baseline * this
# packed/fp decode ratio tolerance (BENCH_serve.json): wider than the
# wall-time gate because even best-of-reps ratios wobble ~20% on a shared
# box, but still strict enough that PR-4's packed-slower-than-fp decode
# (1.29x) and any structural slowdown (ref fallback, de-fused loop) fail.
# Known trade-off: per-process XLA compile variance on a loaded shared
# container can push a healthy run's ratio past this (observed up to
# ~1.4x on the dev box) — the guard is non-blocking in CI by design, and
# a rerun on a quiet machine settles it; tightening past PR-4's 1.29
# matters more than eliminating the flake.
SERVE_RATIO_TOL = 1.25
GATED_FIELD = "steady_total_s"  # steady-state only; cold totals are noise


def _timing_fields(payload, prefix=""):
    """Yield (dotted_path, value) for every gated steady-state leaf."""
    if isinstance(payload, dict):
        for k, v in payload.items():
            p = f"{prefix}.{k}" if prefix else k
            if k == GATED_FIELD and isinstance(v, (int, float)):
                yield p, float(v)
            else:
                yield from _timing_fields(v, p)


def snapshot_baselines() -> dict[str, dict]:
    out = {}
    for f in sorted(REPO.glob("BENCH_*.json")):
        try:
            out[f.name] = json.loads(f.read_text())
        except (OSError, json.JSONDecodeError):
            continue
    return out


def check_serve_ratio(fresh: dict) -> list[str]:
    """packed-vs-fp decode throughput gate on a fresh BENCH_serve.json:
    packed decode may not fall more than the regression tolerance below
    fp decode.  A same-machine interleaved comparison, so (unlike the
    wall-time fields) it gates meaningfully on any box.  Prefers the
    bench's ``decode_vs_fp_ratio`` (best packed rep over best fp rep —
    the uncontended quantity on both sides; structural slowdowns hit
    every rep including the best); pre-PR-5 results only carry the
    throughput fields, whose ratio is gated the same way (PR-4's
    packed-slower-than-fp decode fails).  The ``long_context`` leg's
    quantized-KV ``decode_vs_fp_ratio`` fields (PR 7), the engine leg's
    ``sustained_vs_fixed_ratio`` (PR 8) and the chunked-admission ratios
    (PR 9) are gated at the same tolerance when present.  The overload
    leg (PR 10) gates ``overload.all_terminal`` as a hard boolean —
    terminality under oversubscription is a correctness invariant, not a
    timing ratio."""
    try:
        ratio = fresh["packed"].get("decode_vs_fp_ratio")
        if ratio is None:
            ratio = (float(fresh["fp"]["decode_tok_s"])
                     / float(fresh["packed"]["decode_tok_s"]))
        ratio = float(ratio)
    except (KeyError, TypeError, ValueError, ZeroDivisionError):
        return ["BENCH_serve.json: decode ratio fields missing — cannot "
                "gate the packed/fp decode ratio"]
    bad = []
    if ratio > SERVE_RATIO_TOL:
        bad.append(
            f"BENCH_serve.json: packed decode is {ratio:.2f}x slower "
            f"than fp (tolerance {SERVE_RATIO_TOL:.2f}x): the packed "
            "serving path must not lose decode to the dequantized one")
    # quantized-KV long-context decode gate (PR 7), same logic: the int8 /
    # 2-bit cache exists to cut per-token cache traffic, so its decode may
    # not fall below fp decode beyond the tolerance at the longest length
    for name, leg in (fresh.get("long_context") or {}).items():
        if not isinstance(leg, dict):
            continue
        for s, leaf in leg.items():
            r = (leaf or {}).get("decode_vs_fp_ratio") if isinstance(
                leaf, dict) else None
            if r is not None and float(r) > SERVE_RATIO_TOL:
                bad.append(
                    f"BENCH_serve.json: long-context {name} decode at "
                    f"S={s} is {float(r):.2f}x slower than fp (tolerance "
                    f"{SERVE_RATIO_TOL:.2f}x): the quantized KV cache "
                    "must not lose decode to the fp cache")
    # continuous-batching sustained-throughput gate (PR 8): the engine
    # leg serves the same requests as a fixed-batch baseline padded to
    # each wave's longest budget; the engine retiring early and
    # backfilling freed slots is its whole point, so sustaining fewer
    # useful tok/s than the fixed batch (beyond tolerance) is structural
    r = (fresh.get("engine") or {}).get("sustained_vs_fixed_ratio")
    if r is not None and float(r) > SERVE_RATIO_TOL:
        bad.append(
            f"BENCH_serve.json: engine sustained decode is {float(r):.2f}x "
            f"slower than the fixed-batch baseline (tolerance "
            f"{SERVE_RATIO_TOL:.2f}x): continuous batching must not lose "
            "sustained throughput to fixed waves at equal load")
    # chunked-admission gate (PR 9): chunked prefill exists to bound
    # decode stalls while prompts stream in, so it may not cost sustained
    # throughput or tail latency against whole-prompt admission on the
    # same trace beyond the tolerance
    ch = (fresh.get("engine") or {}).get("chunked") or {}
    r = ch.get("chunked_vs_whole_ratio")
    if r is not None and float(r) > SERVE_RATIO_TOL:
        bad.append(
            f"BENCH_serve.json: chunked-prefill admission sustains "
            f"{float(r):.2f}x fewer tok/s than whole-prompt admission "
            f"(tolerance {SERVE_RATIO_TOL:.2f}x): streaming ingestion "
            "must not lose sustained throughput to whole-prompt prefill")
    r = ch.get("p99_vs_whole_ratio")
    if r is not None and float(r) > SERVE_RATIO_TOL:
        bad.append(
            f"BENCH_serve.json: chunked-prefill p99 request latency is "
            f"{float(r):.2f}x the whole-prompt p99 (tolerance "
            f"{SERVE_RATIO_TOL:.2f}x): chunked admission must not regress "
            "tail latency")
    # overload terminality gate (PR 10): under 2x page oversubscription
    # every submission must reach a definite terminal status — a request
    # the engine dropped or wedged on is a correctness failure, not a
    # timing ratio, so this is a hard boolean (no tolerance)
    ovl = fresh.get("overload")
    if isinstance(ovl, dict) and ovl.get("all_terminal") is not True:
        bad.append(
            "BENCH_serve.json: overload.all_terminal is not true — a "
            "request never reached a terminal status under 2x "
            "oversubscription (dropped or hung)")
    return bad


def check_regressions(baselines: dict[str, dict],
                      ran: set[str] | None = None) -> list[str]:
    """Compare fresh BENCH_*.json files against the pre-run snapshot.
    Returns human-readable regression lines (empty = healthy).
    ``ran`` names the benches that actually executed — the serve ratio
    gate only fires when the serve bench produced a fresh result.

    On a regression the pre-run baseline is written back to disk: the
    benches overwrite their JSON unconditionally, and without the restore
    a second run would snapshot the regressed numbers as the new baseline
    and pass — the guard must stay sticky until the slowdown is fixed (or
    the baseline is re-recorded with --no-regression-check)."""
    bad = []
    for name, base in baselines.items():
        path = REPO / name
        if not path.exists():
            continue
        try:
            fresh = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError):
            bad.append(f"{name}: fresh result unreadable")
            continue
        base_t = dict(_timing_fields(base))
        file_bad = []
        for field, now in _timing_fields(fresh):
            was = base_t.get(field)
            if was is None or was <= 0:
                continue
            if now > was * REGRESSION_TOL:
                file_bad.append(
                    f"{name}:{field}: {now:.3f}s vs baseline "
                    f"{was:.3f}s (+{(now / was - 1) * 100:.0f}%, "
                    f"tolerance +{(REGRESSION_TOL - 1) * 100:.0f}%)")
        if file_bad:
            path.write_text(json.dumps(base, indent=2) + "\n")
            file_bad.append(f"{name}: baseline restored (regressed result "
                            "discarded)")
        bad.extend(file_bad)
        if (name == "BENCH_serve.json" and not file_bad
                and (ran is None or "serve" in ran)):
            # ratio gate on the fresh result (no baseline restore: it is
            # not a baseline comparison, it is an invariant of the run)
            bad.extend(check_serve_ratio(fresh))
    return bad


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benches")
    ap.add_argument("--no-regression-check", action="store_true",
                    help="skip the >20%% BENCH_*.json steady-state guard")
    args = ap.parse_args()

    from benchmarks import (fig2_heuristics, fig3_dynamic, fig4_expansion,
                            kernels_bench, pipeline_bench, roofline,
                            serve_bench, table1_chunks, table2_main,
                            table4_calib, table5_bits, table6_vq)

    benches = {
        "table1_chunks": lambda t: table1_chunks.run(table=t),
        "table2_main": lambda t: table2_main.run(table=t),
        "fig2_heuristics": lambda t: fig2_heuristics.run(table=t),
        "fig3_dynamic": lambda t: fig3_dynamic.run(table=t),
        "fig4_expansion": lambda t: fig4_expansion.run(table=t),
        "table4_calib": lambda t: table4_calib.run(table=t),
        "table5_bits": lambda t: table5_bits.run(table=t),
        "table6_vq": lambda t: table6_vq.run(table=t),
        "kernels": lambda t: kernels_bench.run(table=t),
        "pipeline": lambda t: pipeline_bench.run(table=t),
        "serve": lambda t: serve_bench.run(table=t),
        "roofline": lambda t: roofline.run(table=t),
    }
    selected = (args.only.split(",") if args.only else list(benches))
    baselines = snapshot_baselines()
    print("name,us_per_call,derived")
    t0 = time.time()
    completed: set[str] = set()
    errors: list[str] = []
    for name in selected:
        if name not in benches:
            print(f"unknown bench {name!r}", file=sys.stderr)
            continue
        t = Table(name)
        try:
            benches[name](t)
            completed.add(name)
        except Exception as e:  # keep the suite going, fail at the end
            errors.append(f"{name}: {type(e).__name__}: {e}")
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}", flush=True)
    print(f"# total {time.time() - t0:.0f}s", file=sys.stderr)
    if not args.no_regression_check:
        # only benches that actually completed count as having produced a
        # fresh result — a crashed serve bench must not pass the ratio
        # gate against the stale checked-in file
        regressions = check_regressions(baselines, ran=completed)
        if regressions:
            print("\nBENCH GATE FAILURES (steady-state wall-time vs "
                  "baseline; packed/fp decode ratio):", file=sys.stderr)
            for line in regressions:
                print(f"  {line}", file=sys.stderr)
            sys.exit(1)
    if errors:
        # a bench that raised is a failure even with the regression gate
        # skipped: in-bench assertions (serve_bench's zero-ref-fallback
        # mesh check) are gates in their own right — the fake-8-device CI
        # leg runs --no-regression-check and must still be able to fail
        print("\nBENCH ERRORS:", file=sys.stderr)
        for line in errors:
            print(f"  {line}", file=sys.stderr)
        sys.exit(2)


if __name__ == "__main__":
    main()
