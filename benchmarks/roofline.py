"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, derive the three roofline terms from the
compiled dry-run:

    compute    = HLO_FLOPs_per_chip / peak_FLOPs          [s]
    memory     = HLO_bytes_per_chip / HBM_bw              [s]
    collective = link_bytes_per_chip / link_bw            [s]

FLOPs/bytes come from the trip-count-aware HLO parser
(runtime/hlo_analysis) — XLA's cost_analysis counts loop bodies once and
would undercount scanned models by n_layers x.  Collective link bytes use
ring-algorithm estimates per op.  MODEL_FLOPS = 6·N·D (train) or 2·N·D
(inference) with N = active params.

Hardware constants: TPU v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS = Path(__file__).resolve().parent.parent / "results"


def model_flops(rec: dict) -> float:
    """Model-useful FLOPs per step (global)."""
    from repro.configs import get_config, get_shape

    cfg = get_config(rec["arch"])
    n_active = cfg.n_params(active_only=True)
    if rec["shape"] == "rsq_calib":
        # one layer's calibration forward over 256 x 4096 tokens
        return 2.0 * (n_active / cfg.n_layers) * 256 * 4096
    shape = get_shape(rec["shape"])
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token


def analyze_record(rec: dict) -> dict:
    hlo = rec["hlo"]
    chips = 512 if rec["mesh"] == "multi" else 256
    compute = hlo["dot_flops_per_device"] / PEAK_FLOPS
    memory = hlo["bytes_accessed_per_device"] / HBM_BW
    coll = hlo["collective_link_bytes_per_device"] / LINK_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(rec)
    hlo_flops_global = hlo["dot_flops_per_device"] * chips
    useful = mf / hlo_flops_global if hlo_flops_global else 0.0
    # achievable step time >= max(terms); roofline fraction for the
    # *compute* story = compute / bound
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": dominant, "bound_s": bound,
        "model_flops": mf, "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": useful,
        "roofline_fraction": compute / bound if bound else 0.0,
        "peak_mem_gib": rec["memory"]["peak_per_device_bytes"] / 2 ** 30,
        "mfu_bound": mf / chips / PEAK_FLOPS / bound if bound else 0.0,
    }


_MOVES = {
    "compute": ("recompute/remat waste and attention over-compute: raise "
                "useful-FLOPs ratio (less remat, fused attention kernel)"),
    "memory": ("HBM traffic: fuse elementwise chains, quantize weights "
               "(WoQ serving), larger block reuse in matmul tiles"),
    "collective": ("collective bytes: reshard to cut all-gathers "
                   "(sequence-parallel stash, 2D weight sharding), overlap "
                   "collectives with compute, int8-compress gradients"),
}


def what_moves(dominant: str) -> str:
    return _MOVES[dominant]


def load_records(dry_dir: Path | None = None) -> list[dict]:
    if dry_dir is None:
        d2 = RESULTS / "dryrun2"
        d = d2 if d2.exists() else (RESULTS / "dryrun")
    else:
        d = dry_dir
    recs = []
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("status") == "ok" and "hlo" in r:
            recs.append(r)
    return recs


def run(table=None, dry_dir: Path | None = None):
    from benchmarks.common import Table

    table = table or Table("roofline")
    rows = [analyze_record(r) for r in load_records(dry_dir)]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    for r in rows:
        label = f"{r['arch']}/{r['shape']}/{r['mesh']}"
        table.add(
            label, r["bound_s"] * 1e6,
            f"comp={r['compute_s']:.3e}s mem={r['memory_s']:.3e}s "
            f"coll={r['collective_s']:.3e}s dom={r['dominant']} "
            f"useful={r['useful_flops_ratio']:.2f} "
            f"mfu_bound={r['mfu_bound']:.2f}")
    return rows


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute (s) | memory (s) | "
           "collective (s) | dominant | useful/HLO | MFU bound | "
           "peak GiB/dev |\n|---|---|---|---|---|---|---|---|---|---|\n")
    body = "".join(
        f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
        f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | "
        f"{r['collective_s']:.3e} | **{r['dominant']}** | "
        f"{r['useful_flops_ratio']:.2f} | {r['mfu_bound']:.2f} | "
        f"{r['peak_mem_gib']:.2f} |\n"
        for r in rows)
    return hdr + body


if __name__ == "__main__":
    rows = run()
    out = RESULTS / "roofline.md"
    out.write_text(to_markdown(rows))
    print(f"wrote {out}")
