"""Tab. 1 reproduction: quantize using different token chunks.

Paper claim: restricting the reconstruction loss to the FIRST quarter of
tokens beats using all tokens, and beats any later quarter."""
from __future__ import annotations

from repro.core import RSQConfig

from benchmarks.common import Table, get_trained_model, quantize_and_eval

CHUNKS = [("all", 0.0, 1.0), ("q1", 0.0, 0.25), ("q2", 0.25, 0.5),
          ("q3", 0.5, 0.75), ("q4", 0.75, 1.0)]


def run(bits: int = 2, table: Table | None = None) -> dict:
    table = table or Table("table1_chunks")
    model, params, corpus = get_trained_model()
    out = {}
    for label, lo, hi in CHUNKS:
        rsq = RSQConfig(bits=bits, group_size=64, rotate=True,
                        importance="uniform", chunk_lo=lo, chunk_hi=hi)
        res = quantize_and_eval(model, params, corpus, rsq)
        out[label] = res["ppl"]
        table.add(label, res["seconds"] * 1e6, f"ppl={res['ppl']:.3f}")
    derived = (f"first-chunk beats all: {out['q1'] < out['all']}; "
               f"first beats later: "
               f"{out['q1'] < min(out['q2'], out['q3'], out['q4'])}")
    table.add("claims", 0.0, derived)
    return out


if __name__ == "__main__":
    run()
