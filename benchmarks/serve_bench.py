"""Packed-in-HBM serving benchmark (serving-memory + throughput trajectory).

Quantizes a smoke-sized model once (RSQ, 4-bit, ``pack_output``), persists
the packed artifact, then serves it two ways through the *same* model
code:

  * **fp (dequantized)** — ``load_packed_params``: fp weights rebuilt on
    device at load (the pre-PR-4 ``--packed`` behaviour), plain ``x @ w``
    GEMMs.
  * **packed (keep-packed)** — ``load_packed_forward_params``: the param
    tree holds ``PackedWeight`` codes and every projection runs through
    ``quant_matmul``; no fp copy of a quantized weight ever exists.

Reported per path: prefill and decode tok/s plus a gated
``steady_total_s`` (median over interleaved reps of one prefill +
``GEN`` decode steps on persistent jits — dispatch + execute only;
interleaving the two paths decorrelates machine drift from the path
identity, same trick as pipeline_bench's scheduler timing, and the
median resists the multi-second jitter spikes of this shared container),
and the resident weight bytes of the quantized matrices (fp vs packed,
ratio ~= bits/32 at fp32 params plus group-param overhead).  Results
land in ``BENCH_serve.json`` at the repo root; ``benchmarks/run.py``
applies its >20% regression gate to the ``steady_total_s`` fields only —
advisory by construction (the CI bench-guard job is non-blocking): CPU
wall times here swing with container load, and the cross-machine
trajectory lives in the ungated tok/s fields.

Reading the CPU numbers: prefill runs at >= fp parity (the unpack
amortizes over the token dim), while decode lands below fp on this
container — at smoke scale the extra unpack ops' per-op dispatch
dominates the microseconds-sized GEMMs, the same reason kernels_bench
reports rooflines next to interpret-mode wall times.  The portable
claims are the resident-bytes ratio and the modeled TPU decode bound
(``tpu_decode_roofline``): decode is weight-HBM-bound, so packed codes
cap per-token weight traffic at bits/16 of a bf16 model — the win this
refactor exists to unlock.
"""
from __future__ import annotations

import dataclasses
import json
import math
import shutil
import statistics
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.common import Table

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_serve.json"

ARCH = "llama3-8b"
N_LAYERS = 4
D_MODEL = 64
BATCH, PROMPT, GEN = 8, 128, 32
REPS = 9
BITS = 4


def _build():
    from repro.configs import get_config
    from repro.core import RSQConfig, RSQPipeline
    from repro.data.synthetic import SyntheticCorpus
    from repro.models import build_model
    from repro.checkpoint.packed import save_packed_artifact

    cfg = dataclasses.replace(
        get_config(ARCH).reduced(), dtype="float32",
        n_layers=N_LAYERS, d_model=D_MODEL, vocab_size=512)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)
    calib = corpus.sample(jax.random.key(1), 16, 64)
    pipe = RSQPipeline(model, RSQConfig(bits=BITS, rotate=False,
                                        importance="attn_con",
                                        pack_output=True))
    qparams, _ = pipe.run(params, calib, batch_size=8)
    d = tempfile.mkdtemp(prefix="serve_bench_")
    save_packed_artifact(d, pipe.artifact, params=qparams,
                         extra={"arch": cfg.name})
    prompts = corpus.sample(jax.random.key(2), BATCH, PROMPT)
    return model, d, prompts


class _ServeTimer:
    """One serving path's persistent jits + per-rep timings.

    The compile pass runs once up front so every timed rep is the
    dispatch + execute path the packed representation actually changes."""

    def __init__(self, model, params, prompts):
        self.params, self.prompts = params, prompts
        b, t = prompts.shape
        self.t = t
        self.prefill = jax.jit(
            lambda p, x: model.prefill(p, x, cache_len=t + GEN))
        self.step = jax.jit(model.decode_step)
        logits, cache = self.prefill(params, prompts)  # compile
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        jax.block_until_ready(
            self.step(params, cache, tok, jnp.int32(t))[0])
        self.prefill_s: list[float] = []
        self.decode_s: list[float] = []

    def rep(self):
        t0 = time.perf_counter()
        logits, cache = self.prefill(self.params, self.prompts)
        jax.block_until_ready(logits)
        self.prefill_s.append(time.perf_counter() - t0)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        t0 = time.perf_counter()
        pos = self.t
        for _ in range(GEN):
            logits, cache = self.step(self.params, cache, tok,
                                      jnp.int32(pos))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            pos += 1
        jax.block_until_ready(logits)
        self.decode_s.append(time.perf_counter() - t0)

    def stats(self) -> dict:
        b = self.prompts.shape[0]
        p_s = statistics.median(self.prefill_s)
        d_s = statistics.median(self.decode_s)
        return {
            "prefill_s": round(p_s, 4),
            "decode_s": round(d_s, 4),
            "prefill_tok_s": round(b * self.t / p_s, 1),
            "decode_tok_s": round(b * GEN / d_s, 1),
            "steady_total_s": round(p_s + d_s, 4),
        }


def run(table: Table | None = None):
    from repro.checkpoint.packed import (load_packed_forward_params,
                                         load_packed_params)
    from repro.kernels.quant_matmul.ops import PackedWeight
    from repro.launch.serve import resident_weight_bytes

    table = table or Table("serve")
    model, artifact, prompts = _build()
    try:
        deq_params, meta = load_packed_params(artifact)
        pk_params, _ = load_packed_forward_params(artifact)
    finally:
        shutil.rmtree(artifact, ignore_errors=True)

    packed_b, _ = resident_weight_bytes(pk_params)
    itemsize = jnp.dtype(model.dtype).itemsize
    fp_b = sum(
        math.prod(w.w_packed.shape[:-2]) * w.d_in * w.w_packed.shape[-1]
        * itemsize
        for w in jax.tree.leaves(
            pk_params, is_leaf=lambda x: isinstance(x, PackedWeight))
        if isinstance(w, PackedWeight))

    timers = {"fp": _ServeTimer(model, deq_params, prompts),
              "packed": _ServeTimer(model, pk_params, prompts)}
    for _ in range(REPS):  # interleaved: drift hits both paths equally
        for tm in timers.values():
            tm.rep()
    fp, packed = timers["fp"].stats(), timers["packed"].stats()

    ratio = packed_b / fp_b
    table.add("serve_fp_dequant", fp["steady_total_s"] * 1e6,
              f"prefill_tok_s={fp['prefill_tok_s']} "
              f"decode_tok_s={fp['decode_tok_s']}")
    table.add("serve_keep_packed", packed["steady_total_s"] * 1e6,
              f"prefill_tok_s={packed['prefill_tok_s']} "
              f"decode_tok_s={packed['decode_tok_s']}")
    table.add("resident_weight_bytes", 0.0,
              f"fp={fp_b} packed={packed_b} ratio={ratio:.3f} "
              f"(~bits/32 at fp32: {BITS / 32:.3f})")

    # decode is weight-HBM-bound on accelerators: per-token weight traffic
    # caps throughput, so packed codes bound the speedup at 16/bits vs a
    # bf16-resident model (8/bits at this bench's fp32 params)
    payload = {
        "arch": f"{ARCH}-smoke(d={D_MODEL},L={N_LAYERS})",
        "bits": BITS,
        "batch": BATCH, "prompt_len": PROMPT, "gen": GEN,
        "fp": fp,
        "packed": packed,
        "resident_weight_bytes": {
            "fp": int(fp_b), "packed": int(packed_b),
            "ratio": round(ratio, 4),
        },
        "tpu_decode_roofline": {
            "weight_traffic_ratio": round(ratio, 4),
            "bound_speedup_vs_bf16": round(16 / BITS, 2),
        },
        "n_packed_entries": len(meta["entries"]),
        "backend": jax.default_backend(),
    }
    OUT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return table


if __name__ == "__main__":
    run()
