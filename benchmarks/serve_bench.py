"""Packed-in-HBM serving benchmark (serving-memory + throughput trajectory).

Quantizes a smoke-sized model once (RSQ, 4-bit, ``pack_output``), persists
the packed artifact, then serves it two ways through the *same* model
code:

  * **fp (dequantized)** — ``load_packed_params``: fp weights rebuilt on
    device at load (the pre-PR-4 ``--packed`` behaviour), plain ``x @ w``
    GEMMs.
  * **packed (keep-packed)** — ``load_packed_forward_params``: the param
    tree holds ``PackedWeight`` codes and every projection runs through
    ``quant_matmul``; no fp copy of a quantized weight ever exists.

Decode is timed on the **fused scan loop** (``launch.serve`` PR 5): one
jitted ``lax.scan`` device program for all ``GEN`` steps, on-device
greedy sampling, donated KV cache.  The legacy one-dispatch-per-token
loop is timed alongside (``decode_tok_s_python``) so the JSON records
the fusion win — the PR-4 numbers showed packed decode *losing* to fp
(3112 vs 4019 tok/s) purely because per-token Python dispatch swamped the
memory-bound GEMMs the packed kernel accelerates.

Reported per path: prefill and decode tok/s plus a gated
``steady_total_s`` (min over interleaved reps of one prefill +
``GEN`` scan-decoded steps on persistent jits — dispatch + execute only;
interleaving the two paths decorrelates machine drift from the path
identity, same trick as pipeline_bench's scheduler timing, and the
min approximates the uncontended machine under this shared container's
load spikes — see ``_ServeTimer.stats``),
and the resident weight bytes of the quantized matrices (fp vs packed,
ratio ~= bits/32 at fp32 params plus group-param overhead).  Results
land in ``BENCH_serve.json`` at the repo root; ``benchmarks/run.py``
applies its >20% regression gate to the ``steady_total_s`` fields plus
its ``SERVE_RATIO_TOL`` gate to the packed/fp decode ratio
(``decode_vs_fp_ratio``: best packed rep over best fp rep, see the
comment in :func:`run`) — packed decode slower than fp (beyond
tolerance) is a regression of the refactor's whole point, not machine
noise — advisory by construction (the CI bench-guard job is
non-blocking).

A **long-context leg** (PR 7) varies the KV-cache codec instead of the
weight representation: decode tok/s vs cached length (512, 2048 rows) for
the fp, int8 and 2-bit-log caches on one shared param tree, plus the
allocated ``kv_cache_resident_bytes`` and ``kv_bytes_ratio_vs_bf16``
(~ bits/16 plus scale rows).  ``run.py`` gates the quantized-vs-fp decode
ratio at the longest length with the same ``SERVE_RATIO_TOL`` — a
quantized cache that decodes slower than fp defeats its purpose.

An **engine leg** (PR 8) benches the continuous-batching serve engine
(``repro.serving``): the same request set — one prompt length, budgets
cycling 3 short : 1 long — runs through the engine on block-paged
kv8 pools under a Poisson arrival trace and through ``generate`` in
arrival-ordered max_slots-sized waves padded to each wave's longest
budget.  Only
requested tokens count on both sides; ``run.py`` gates
``sustained_vs_fixed_ratio`` (fixed over engine sustained tok/s) at
SERVE_RATIO_TOL — continuous batching must not lose sustained
throughput to the fixed batch at equal load — and the engine wall time
rides the generic ``steady_total_s`` gate.  p50/p99 request latency is
recorded ungated (latency is arrival-pattern-shaped, not a regression
signal at this scale).

PR 9 adds **chunked admission** to the engine leg: the same trace runs a
second time with ``prefill_chunk=ENG_CHUNK`` (prompts stream in
page-aligned chunks interleaved with decode bursts instead of one
whole-prompt prefill per admission), recording ``ttft_p50_s`` /
``ttft_p99_s`` and the engine's cumulative ``admission_stall_s`` for
both admission modes.  ``run.py`` gates ``chunked.chunked_vs_whole_ratio``
(whole-prompt over chunked sustained tok/s) and
``chunked.p99_vs_whole_ratio`` (chunked over whole-prompt p99 latency)
at the same SERVE_RATIO_TOL.

PR 10 adds an **overload leg**: the engine again, but with the page pool
halved against hot demand (2x oversubscription), a bounded queue and one
expired deadline, recording the preemption / shed / deadline counters,
per-status latency percentiles and p99 under overload.  Its gate is
*terminality* — ``run.py`` fails when ``overload.all_terminal`` is false
(a request that never reaches a definite status is a hang, not noise) —
while the timing rides the generic ``steady_total_s`` gate.

With >= 8 devices (CI's fake-8-device matrix entry) an extra **mesh leg**
runs: a kernel-aligned model (every quantized d_out a multiple of
128 x model-axis) is calibrated under a (2 data x 4 model) mesh, served
keep-packed with ``REPRO_QMM_KERNEL=1``, and the run asserts the
shard_map'd Pallas route carried every projection (zero ref-GEMM
fallbacks).  Its timing is recorded ungated (``mesh_total_s``) — it only
exists on multi-device runs, and interpret-mode kernels are a
correctness tool, not a fast path.

The portable claims are the resident-bytes ratio and the modeled TPU
decode bound (``tpu_decode_roofline``): decode is weight-HBM-bound, so
packed codes cap per-token weight traffic at bits/16 of a bf16 model.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import shutil
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.common import Table

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_serve.json"

ARCH = "llama3-8b"
N_LAYERS = 4
D_MODEL = 64
BATCH, PROMPT, GEN = 8, 128, 32
REPS = 9
BITS = 4

# mesh leg (only with >= 8 devices): every quantized d_out must split into
# 128-aligned local tiles across the 4-way model axis for the shard_map'd
# kernel to run
MESH_D_MODEL, MESH_LAYERS, MESH_BATCH, MESH_PROMPT, MESH_GEN = 512, 2, 2, 16, 8
MESH_REPS = 3

# long-context leg (PR 7): decode tok/s vs cached length for the fp, int8
# and 2-bit-log KV caches, same weights throughout — the cache codec is
# the only variable.  Lengths are allocated cache rows (prompt = S - GEN).
LC_BATCH, LC_GEN, LC_REPS = 4, 32, 3
LC_LENGTHS = (512, 2048)

# engine leg (PR 8): continuous batching on paged quantized KV vs the
# fixed batch at equal load.  The workload is the mixed one continuous
# batching targets — mostly short requests with an occasional long one
# (3 short : 1 long in arrival order), so every fixed wave is dragged to
# the long budget and burns (long - short) wasted steps per short
# request while the engine retires shorts and backfills their slots.
# PR 9 runs the engine twice — whole-prompt vs chunked admission
# (--prefill-chunk ENG_CHUNK) — recording ttft/admission-stall for both;
# the 96-token prompt makes chunking non-degenerate (2 chunks/request).
ENG_N_REQ, ENG_PROMPT, ENG_SLOTS, ENG_PAGES = 12, 96, 4, 16
ENG_BURST, ENG_BUDGETS, ENG_RATE, ENG_REPS = 8, (8, 8, 8, 128), 2.0, 5
ENG_CHUNK = 64

# overload leg (PR 10): the same engine with the page pool halved against
# hot demand (max_slots x pages-per-request = 2 x n_pages), a bounded
# queue and one sub-second deadline — preemption-and-requeue, shedding
# and deadline expiry all fire on a real trace.  The leg's gate is
# *terminality*: every submission must end in exactly one definite
# status with the pool quiescent (run.py fails on all_terminal=False);
# p99-under-overload and the preemption/shed counters are recorded
# ungated (they are workload-shaped, not regression signals).
OVL_N_REQ, OVL_BUDGET, OVL_RATE, OVL_REPS = 10, 32, 3.0, 2
OVL_QUEUE_DEPTH = 6


def _quantize_to_artifact(cfg, ctx=None, calib_rows=16, calib_len=64,
                          batch_size=8):
    from repro.core import RSQConfig, RSQPipeline
    from repro.data.synthetic import SyntheticCorpus
    from repro.models import build_model
    from repro.checkpoint.packed import save_packed_artifact

    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)
    calib = corpus.sample(jax.random.key(1), calib_rows, calib_len)
    rsq = RSQConfig(bits=BITS, rotate=False, importance="attn_con",
                    pack_output=True,
                    **({"pack_writeback": "sharded"} if ctx else {}))
    pipe = (RSQPipeline(model, rsq, ctx=ctx) if ctx
            else RSQPipeline(model, rsq))
    qparams, _ = pipe.run(params, calib, batch_size=batch_size)
    d = tempfile.mkdtemp(prefix="serve_bench_")
    save_packed_artifact(d, pipe.artifact, params=qparams,
                         extra={"arch": cfg.name})
    return model, d, corpus


def _build():
    from repro.configs import get_config

    cfg = dataclasses.replace(
        get_config(ARCH).reduced(), dtype="float32",
        n_layers=N_LAYERS, d_model=D_MODEL, vocab_size=512)
    model, d, corpus = _quantize_to_artifact(cfg)
    prompts = corpus.sample(jax.random.key(2), BATCH, PROMPT)
    return model, d, prompts


class _ServeTimer:
    """One serving path's persistent jits + per-rep timings.

    The compile pass runs once up front so every timed rep is the
    dispatch + execute path the packed representation actually changes.
    Decode is the fused scan program (the serving default); the legacy
    python loop is timed alongside for the dispatch-overhead trajectory."""

    def __init__(self, model, params, prompts):
        from repro.launch.serve import _prefill_fn, _scan_decode_fn

        self.params, self.prompts = params, prompts
        b, t = prompts.shape
        self.t = t
        self.key = jax.random.key(0)
        self.prefill = _prefill_fn(model, t + GEN)
        self.decode = _scan_decode_fn(model, GEN, False)
        self.step = jax.jit(model.decode_step, donate_argnums=(1,))
        logits, cache = self._prefill()  # compile all three
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        jax.block_until_ready(
            self.decode(self.params, cache, tok, jnp.int32(t), self.key,
                        jnp.float32(0.0)))
        logits, cache = self._prefill()
        jax.block_until_ready(
            self.step(self.params, cache, tok, jnp.int32(t))[0])
        self.prefill_s: list[float] = []
        self.decode_s: list[float] = []
        self.pyloop_s: list[float] = []

    def _prefill(self):
        return self.prefill(self.params, self.prompts, None, None)

    def rep(self):
        t0 = time.perf_counter()
        logits, cache = self._prefill()
        jax.block_until_ready(logits)
        self.prefill_s.append(time.perf_counter() - t0)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        t0 = time.perf_counter()
        toks = self.decode(self.params, cache, tok, jnp.int32(self.t),
                           self.key, jnp.float32(0.0))
        jax.block_until_ready(toks)
        self.decode_s.append(time.perf_counter() - t0)
        # legacy loop: one jitted dispatch + host round-trip per token.
        # GEN - 1 steps, like the scan program: token 0 comes from the
        # prefill logits on both loops (launch.serve.generate), so the
        # two decode timings credit the same b*GEN tokens to the same
        # number of decode steps.
        logits, cache = self._prefill()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        t0 = time.perf_counter()
        pos = self.t
        for _ in range(GEN - 1):
            logits, cache = self.step(self.params, cache, tok,
                                      jnp.int32(pos))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            pos += 1
        jax.block_until_ready(logits)
        self.pyloop_s.append(time.perf_counter() - t0)

    def stats(self) -> dict:
        # min-of-reps, not median: this container's load spikes stretch
        # individual reps by 50%+ and even the median of 9 interleaved
        # reps swings between runs; the minimum approximates the
        # uncontended machine, which is the quantity the regression gate
        # and the packed/fp ratio are meant to compare (and it is always
        # <= the median-based baselines, so switching cannot fake a
        # regression)
        b = self.prompts.shape[0]
        p_s = min(self.prefill_s)
        d_s = min(self.decode_s)
        py_s = min(self.pyloop_s)
        return {
            "prefill_s": round(p_s, 4),
            "decode_s": round(d_s, 4),
            "prefill_tok_s": round(b * self.t / p_s, 1),
            "decode_tok_s": round(b * GEN / d_s, 1),
            "decode_tok_s_python": round(b * GEN / py_s, 1),
            "steady_total_s": round(p_s + d_s, 4),
        }


def _long_context_leg() -> dict:
    """Decode throughput vs cached length for fp / int8 / 2-bit-log KV.

    One tiny GQA model, one param tree (``kv_bits`` never touches the
    weights); per cached length the three cache codecs run interleaved
    reps of un-timed prefill + timed fused scan decode.  Alongside the
    timings the leg records the allocated cache footprint
    (``kv_cache_resident_bytes`` via ``eval_shape`` — nothing allocated)
    and ``kv_bytes_ratio_vs_bf16``: quantized cache bytes over the same
    cache held in bf16, ~ bits/16 plus the scale rows.  ``run.py`` gates
    ``decode_vs_fp_ratio`` at the longest length with SERVE_RATIO_TOL —
    quantized-KV decode losing to fp decode defeats the codec's purpose
    (less cache traffic per generated token), exactly the packed-weight
    decode gate's logic."""
    from repro.configs import get_config
    from repro.data.synthetic import SyntheticCorpus
    from repro.launch.serve import (_prefill_fn, _scan_decode_fn,
                                    kv_cache_resident_bytes)
    from repro.models import build_model

    base = dataclasses.replace(
        get_config(ARCH).reduced(), dtype="float32",
        n_layers=N_LAYERS, d_model=D_MODEL, vocab_size=512)
    params = jax.jit(build_model(base).init)(jax.random.key(0))
    corpus = SyntheticCorpus(vocab_size=base.vocab_size, seed=0)
    key = jax.random.key(0)

    variants = {"fp": 0, "kv8": 8, "kv2": 2}
    out = {name: {} for name in variants}
    for s in LC_LENGTHS:
        t = s - LC_GEN
        prompts = corpus.sample(jax.random.key(3), LC_BATCH, t)
        legs = {}
        for name, bits in variants.items():
            model = build_model(dataclasses.replace(base, kv_bits=bits))
            legs[name] = (model, _prefill_fn(model, s),
                          _scan_decode_fn(model, LC_GEN, False))
        times = {name: [] for name in variants}
        for rep in range(LC_REPS + 1):  # rep 0 compiles, untimed
            for name, (model, pre, dec) in legs.items():
                logits, cache = pre(params, prompts, None, None)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
                jax.block_until_ready(tok)
                t0 = time.perf_counter()
                toks = dec(params, cache, tok, jnp.int32(t), key,
                           jnp.float32(0.0))
                jax.block_until_ready(toks)
                if rep:
                    times[name].append(time.perf_counter() - t0)
        for name, (model, _, _) in legs.items():
            d_s = min(times[name])
            cache_b = kv_cache_resident_bytes(
                jax.eval_shape(lambda m=model: m.init_cache(LC_BATCH, s)))
            out[name][str(s)] = {
                "decode_s": round(d_s, 4),
                "decode_tok_s": round(LC_BATCH * LC_GEN / d_s, 1),
                "kv_cache_resident_bytes": int(cache_b),
            }
    s_max = str(max(LC_LENGTHS))
    fp_leaf = out["fp"][s_max]
    # this bench runs fp32; a bf16 cache holds the same rows at 2 bytes
    bf16_bytes = fp_leaf["kv_cache_resident_bytes"] // 2
    for name in ("kv8", "kv2"):
        leaf = out[name][s_max]
        leaf["decode_vs_fp_ratio"] = round(
            leaf["decode_s"] / fp_leaf["decode_s"], 4)
        leaf["kv_bytes_ratio_vs_bf16"] = round(
            leaf["kv_cache_resident_bytes"] / bf16_bytes, 4)
    return {
        "arch": f"{ARCH}-smoke(d={D_MODEL},L={N_LAYERS})",
        "batch": LC_BATCH, "gen": LC_GEN, "lengths": list(LC_LENGTHS),
        "decode_loop": "scan",
        **out,
    }


def _engine_leg() -> dict:
    """Continuous batching (serving.Engine, Poisson arrivals) vs the
    fixed-batch scan loop at equal load.

    The same ``ENG_N_REQ`` requests — one shared prompt length, budgets
    cycling 3 short : 1 long — run (a) through the engine on paged kv8
    pools under a Poisson arrival trace and (b) through ``generate`` in
    arrival-ordered ``ENG_SLOTS``-sized waves, each wave padded to its
    longest budget (the fixed shape cannot retire early or backfill a
    freed row).  Only
    the *requested* tokens count toward throughput on both sides, so the
    fixed batch pays for its wasted trailing steps.  ``run.py`` gates
    ``sustained_vs_fixed_ratio`` (fixed tok/s over engine sustained
    tok/s, > 1 = engine slower) at SERVE_RATIO_TOL: continuous batching
    losing sustained throughput to the fixed batch at equal load is a
    regression of the engine's whole point.  ``steady_total_s`` (best
    engine wall over reps) rides the generic wall-time gate.

    The engine runs twice — whole-prompt admission and chunked admission
    (``prefill_chunk=ENG_CHUNK``, two chunks per 96-token prompt) — and
    both record ``ttft_p50_s``/``ttft_p99_s`` and the engine's cumulative
    ``admission_stall_s``.  The ``chunked`` sub-dict carries
    ``chunked_vs_whole_ratio`` (whole sustained tok/s over chunked
    sustained tok/s, > 1 = chunked slower) and ``p99_vs_whole_ratio``
    (chunked p99 latency over whole-prompt p99); ``run.py`` gates both at
    SERVE_RATIO_TOL — chunked admission costing sustained throughput or
    tail latency against whole-prompt admission defeats its purpose."""
    from repro.configs import get_config
    from repro.data.synthetic import SyntheticCorpus
    from repro.launch.serve import generate
    from repro.models import build_model
    from repro.serving import Engine, ServeRequest, poisson_trace, run_trace

    cfg = dataclasses.replace(
        get_config(ARCH).reduced(), dtype="float32",
        n_layers=N_LAYERS, d_model=D_MODEL, vocab_size=512, kv_bits=8)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)
    prompts = corpus.sample(jax.random.key(4), ENG_N_REQ, ENG_PROMPT)
    budgets = [ENG_BUDGETS[i % len(ENG_BUDGETS)] for i in range(ENG_N_REQ)]
    reqs = [ServeRequest(tokens=prompts[i].tolist(),
                         max_new_tokens=budgets[i])
            for i in range(ENG_N_REQ)]
    n_req_tok = sum(budgets)

    max_pages = -(-(ENG_PROMPT + max(ENG_BUDGETS)) // model.codec.page_tokens)

    def engine_run(prefill_chunk=None):
        engine = Engine(model, params, max_slots=ENG_SLOTS,
                        n_pages=ENG_PAGES, max_pages_per_request=max_pages,
                        burst_steps=ENG_BURST, prefill_chunk=prefill_chunk)
        stats = run_trace(engine, poisson_trace(reqs, rate=ENG_RATE,
                                                seed=0))
        assert stats["n_tokens"] == n_req_tok, stats["n_tokens"]
        assert engine.pools.free_pages() == ENG_PAGES, "pages leaked"
        return stats

    # rep 0 of each admission mode compiles its programs, untimed; timed
    # reps interleave the two modes so machine drift hits both equally
    # (the _ServeTimer trick), and each reported metric takes the best
    # rep per side — the uncontended-machine quantity the chunked/whole
    # ratios need on this shared container (a single load spike on one
    # side must not fake or mask a structural regression).
    engine_run()
    engine_run(ENG_CHUNK)
    whole_reps, chunked_reps = [], []
    for _ in range(ENG_REPS):
        whole_reps.append(engine_run())
        chunked_reps.append(engine_run(ENG_CHUNK))

    def best_of(reps):
        best = dict(min(reps, key=lambda s: s["wall_s"]))
        for f in ("p50_latency_s", "p99_latency_s", "ttft_p50_s",
                  "ttft_p99_s", "admission_stall_s"):
            best[f] = min(s[f] for s in reps)
        return best

    best = best_of(whole_reps)
    chunked = best_of(chunked_reps)

    n_gen = max(budgets)
    waves = [prompts[i:i + ENG_SLOTS]
             for i in range(0, ENG_N_REQ, ENG_SLOTS)]
    for w in waves:  # compile pass
        jax.block_until_ready(generate(model, params, w, n_gen))
    fixed_s = None
    for _ in range(ENG_REPS):
        t0 = time.perf_counter()
        for w in waves:
            jax.block_until_ready(generate(model, params, w, n_gen))
        dt = time.perf_counter() - t0
        fixed_s = dt if fixed_s is None else min(fixed_s, dt)
    fixed_tok_s = n_req_tok / fixed_s

    return {
        "arch": f"{ARCH}-smoke(d={D_MODEL},L={N_LAYERS})",
        "kv_bits": 8,
        "n_requests": ENG_N_REQ, "prompt_len": ENG_PROMPT,
        "budgets": list(ENG_BUDGETS), "requested_tokens": n_req_tok,
        "max_slots": ENG_SLOTS, "n_pages": ENG_PAGES,
        "burst_steps": ENG_BURST, "arrival_rate": ENG_RATE,
        "sustained_tok_s": round(best["sustained_tok_s"], 1),
        "p50_latency_s": round(best["p50_latency_s"], 4),
        "p99_latency_s": round(best["p99_latency_s"], 4),
        "ttft_p50_s": round(best["ttft_p50_s"], 4),
        "ttft_p99_s": round(best["ttft_p99_s"], 4),
        "admission_stall_s": round(best["admission_stall_s"], 4),
        "rounds": best["rounds"],
        "steady_total_s": round(best["wall_s"], 4),
        "fixed_batch_tok_s": round(fixed_tok_s, 1),
        "fixed_batch_s": round(fixed_s, 4),
        # > 1 = the engine sustains fewer useful tok/s than fixed waves
        "sustained_vs_fixed_ratio": round(
            fixed_tok_s / best["sustained_tok_s"], 4),
        "chunked": {
            "prefill_chunk": ENG_CHUNK,
            "sustained_tok_s": round(chunked["sustained_tok_s"], 1),
            "p50_latency_s": round(chunked["p50_latency_s"], 4),
            "p99_latency_s": round(chunked["p99_latency_s"], 4),
            "ttft_p50_s": round(chunked["ttft_p50_s"], 4),
            "ttft_p99_s": round(chunked["ttft_p99_s"], 4),
            "admission_stall_s": round(chunked["admission_stall_s"], 4),
            "rounds": chunked["rounds"],
            "steady_total_s": round(chunked["wall_s"], 4),
            # > 1 = chunked admission sustains fewer tok/s than whole-prompt
            "chunked_vs_whole_ratio": round(
                best["sustained_tok_s"] / chunked["sustained_tok_s"], 4),
            # > 1 = chunked admission worsens tail latency vs whole-prompt
            "p99_vs_whole_ratio": round(
                chunked["p99_latency_s"] / max(best["p99_latency_s"], 1e-9),
                4),
        },
    }


def _overload_leg() -> dict:
    """The engine under 2x page oversubscription (serving PR 10).

    Hot demand (``ENG_SLOTS`` x pages-per-request) is twice the pool, the
    queue is bounded (``OVL_QUEUE_DEPTH``) and one request carries an
    already-expired deadline, so a single Poisson trace exercises
    preemption-and-requeue, backpressure shedding and deadline expiry at
    once.  Scheduling is round-based and greedy, so the overload counters
    are deterministic across reps/machines; the in-bench assertions (and
    run.py's ``all_terminal`` gate) pin the robustness contract — every
    submission terminal, pages quiescent, preemption actually exercised —
    while the p99/wall numbers ride the usual advisory gates."""
    from repro.configs import get_config
    from repro.data.synthetic import SyntheticCorpus
    from repro.models import build_model
    from repro.serving import (Engine, SamplingParams, ServeRequest,
                               poisson_trace, run_trace)

    cfg = dataclasses.replace(
        get_config(ARCH).reduced(), dtype="float32",
        n_layers=N_LAYERS, d_model=D_MODEL, vocab_size=512, kv_bits=8)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)
    prompts = corpus.sample(jax.random.key(5), OVL_N_REQ, ENG_PROMPT)
    page = model.codec.page_tokens
    ppr = -(-(ENG_PROMPT + OVL_BUDGET) // page)
    n_pages = ENG_SLOTS * ppr // 2  # hot demand = 2x the pool
    reqs = [ServeRequest(tokens=prompts[i].tolist(),
                         max_new_tokens=OVL_BUDGET,
                         sampling=SamplingParams(
                             deadline_s=1e-6 if i == 4 else 0.0))
            for i in range(OVL_N_REQ)]

    def one_run():
        engine = Engine(model, params, max_slots=ENG_SLOTS,
                        n_pages=n_pages, max_pages_per_request=ppr,
                        burst_steps=ENG_BURST, queue_depth=OVL_QUEUE_DEPTH)
        stats = run_trace(engine, poisson_trace(reqs, rate=OVL_RATE,
                                                seed=0))
        engine.pools.assert_quiescent()
        return stats

    one_run()  # compile pass, untimed
    runs = [one_run() for _ in range(OVL_REPS)]
    best = min(runs, key=lambda s: s["wall_s"])
    all_terminal = all(s["n_requests"] == OVL_N_REQ for s in runs)
    assert all_terminal, "a submission never reached a terminal status"
    assert best["n_preemptions"] >= 1, "2x oversubscription must preempt"
    assert best["n_deadline"] >= 1, "the expired deadline must retire"
    return {
        "n_requests": OVL_N_REQ, "prompt_len": ENG_PROMPT,
        "budget": OVL_BUDGET, "max_slots": ENG_SLOTS,
        "n_pages": n_pages, "pages_per_request": ppr,
        "oversubscription": 2.0, "queue_depth": OVL_QUEUE_DEPTH,
        "arrival_rate": OVL_RATE,
        "all_terminal": all_terminal,
        "n_preemptions": best["n_preemptions"],
        "n_preempted_requests": best["n_preempted_requests"],
        "shed_rate": round(best["n_shed"] / OVL_N_REQ, 4),
        "n_deadline": best["n_deadline"],
        "n_failed": best["n_failed"],
        "statuses": best["statuses"],
        "p99_latency_s": round(best["p99_latency_s"], 4),
        "per_status": best["per_status"],
        "steady_total_s": round(best["wall_s"], 4),
    }


def _mesh_leg() -> dict | None:
    """shard_map'd kernel serving on the fake multi-device mesh (CI's
    fake-8-device bench-guard entry): keep-packed generate with the
    kernel forced, asserting zero ref-GEMM fallbacks.  Ungated timing."""
    if jax.device_count() < 8:
        return None
    from repro.checkpoint.packed import load_packed_forward_params
    from repro.configs import get_config
    from repro.data.synthetic import SyntheticCorpus
    from repro.kernels.quant_matmul import ops
    from repro.launch.serve import generate
    from repro.models import build_model
    from repro.runtime.sharding import ParallelCtx

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh, dp=("data",), tp="model")
    cfg = dataclasses.replace(
        get_config(ARCH).reduced(), dtype="float32", n_layers=MESH_LAYERS,
        d_model=MESH_D_MODEL, n_heads=8, n_kv_heads=8, d_head=0,
        d_ff=MESH_D_MODEL, vocab_size=256)
    _, artifact, corpus = _quantize_to_artifact(
        cfg, ctx=ctx, calib_rows=8, calib_len=32, batch_size=4)
    try:
        model = build_model(cfg, ctx)
        params, _ = load_packed_forward_params(artifact, ctx=ctx)
    finally:
        shutil.rmtree(artifact, ignore_errors=True)
    prompts = corpus.sample(jax.random.key(2), MESH_BATCH, MESH_PROMPT)

    ref_calls = []
    orig_ref = ops.quant_matmul_ref
    ops.quant_matmul_ref = lambda *a, **k: (ref_calls.append(1),
                                            orig_ref(*a, **k))[1]
    env_before = os.environ.get("REPRO_QMM_KERNEL")
    os.environ["REPRO_QMM_KERNEL"] = "1"  # read at trace time
    try:
        out = generate(model, params, prompts, MESH_GEN)  # compile
        jax.block_until_ready(out)
        times = []
        for _ in range(MESH_REPS):
            t0 = time.perf_counter()
            out = generate(model, params, prompts, MESH_GEN)
            jax.block_until_ready(out)
            times.append(time.perf_counter() - t0)
    finally:
        ops.quant_matmul_ref = orig_ref
        if env_before is None:
            del os.environ["REPRO_QMM_KERNEL"]
        else:
            os.environ["REPRO_QMM_KERNEL"] = env_before
    assert not ref_calls, (
        f"{len(ref_calls)} ref-GEMM fallbacks on the mesh leg: the "
        "shard_map'd kernel route must carry every projection")
    return {
        "mesh": "2x4(data,model)",
        "arch": f"{ARCH}-mesh(d={MESH_D_MODEL},L={MESH_LAYERS})",
        "mesh_total_s": round(min(times), 4),
        "ref_gemm_fallbacks": 0,
        "shard_map_kernel": True,
    }


def run(table: Table | None = None):
    from repro.checkpoint.packed import (load_packed_forward_params,
                                         load_packed_params)
    from repro.kernels.quant_matmul.ops import PackedWeight
    from repro.launch.serve import resident_weight_bytes

    table = table or Table("serve")
    model, artifact, prompts = _build()
    try:
        deq_params, meta = load_packed_params(artifact)
        pk_params, _ = load_packed_forward_params(artifact)
    finally:
        shutil.rmtree(artifact, ignore_errors=True)

    packed_b, _ = resident_weight_bytes(pk_params)
    itemsize = jnp.dtype(model.dtype).itemsize
    fp_b = sum(
        math.prod(w.w_packed.shape[:-2]) * w.d_in * w.w_packed.shape[-1]
        * itemsize
        for w in jax.tree.leaves(
            pk_params, is_leaf=lambda x: isinstance(x, PackedWeight))
        if isinstance(w, PackedWeight))

    timers = {"fp": _ServeTimer(model, deq_params, prompts),
              "packed": _ServeTimer(model, pk_params, prompts)}
    for _ in range(REPS):  # interleaved: drift hits both paths equally
        for tm in timers.values():
            tm.rep()
    fp, packed = timers["fp"].stats(), timers["packed"].stats()
    # gated packed/fp decode ratio: best packed rep over best fp rep —
    # the uncontended-machine quantity on both sides.  (Not min over
    # paired reps: a single fp-side load spike would let a real packed
    # regression hide behind that pair.)  A structural regression
    # (ref-GEMM fallback, lost kernel fusion, a re-serialized loop)
    # slows every packed rep including the best one, so it still trips;
    # run.py gates this at its own slightly wider SERVE_RATIO_TOL since
    # even best-of-reps ratios wobble ~20% on this shared container.
    packed["decode_vs_fp_ratio"] = round(
        min(timers["packed"].decode_s) / min(timers["fp"].decode_s), 4)

    ratio = packed_b / fp_b
    table.add("serve_fp_dequant", fp["steady_total_s"] * 1e6,
              f"prefill_tok_s={fp['prefill_tok_s']} "
              f"decode_tok_s={fp['decode_tok_s']}")
    table.add("serve_keep_packed", packed["steady_total_s"] * 1e6,
              f"prefill_tok_s={packed['prefill_tok_s']} "
              f"decode_tok_s={packed['decode_tok_s']}")
    table.add("decode_scan_vs_python", 0.0,
              f"packed scan={packed['decode_tok_s']} "
              f"python={packed['decode_tok_s_python']} tok/s")
    table.add("resident_weight_bytes", 0.0,
              f"fp={fp_b} packed={packed_b} ratio={ratio:.3f} "
              f"(~bits/32 at fp32: {BITS / 32:.3f})")

    # decode is weight-HBM-bound on accelerators: per-token weight traffic
    # caps throughput, so packed codes bound the speedup at 16/bits vs a
    # bf16-resident model (8/bits at this bench's fp32 params)
    payload = {
        "arch": f"{ARCH}-smoke(d={D_MODEL},L={N_LAYERS})",
        "bits": BITS,
        "batch": BATCH, "prompt_len": PROMPT, "gen": GEN,
        "decode_loop": "scan",
        "fp": fp,
        "packed": packed,
        "resident_weight_bytes": {
            "fp": int(fp_b), "packed": int(packed_b),
            "ratio": round(ratio, 4),
        },
        "tpu_decode_roofline": {
            "weight_traffic_ratio": round(ratio, 4),
            "bound_speedup_vs_bf16": round(16 / BITS, 2),
        },
        "n_packed_entries": len(meta["entries"]),
        "backend": jax.default_backend(),
    }
    lc = _long_context_leg()
    payload["long_context"] = lc
    s_max = str(max(LC_LENGTHS))
    table.add("long_ctx_decode_fp", lc["fp"][s_max]["decode_s"] * 1e6,
              f"S={s_max} decode_tok_s={lc['fp'][s_max]['decode_tok_s']}")
    for name in ("kv8", "kv2"):
        leaf = lc[name][s_max]
        table.add(f"long_ctx_decode_{name}", leaf["decode_s"] * 1e6,
                  f"S={s_max} decode_tok_s={leaf['decode_tok_s']} "
                  f"vs_fp={leaf['decode_vs_fp_ratio']} "
                  f"kv_bytes_vs_bf16={leaf['kv_bytes_ratio_vs_bf16']}")
    eng = _engine_leg()
    payload["engine"] = eng
    table.add("engine_sustained", eng["steady_total_s"] * 1e6,
              f"tok_s={eng['sustained_tok_s']} "
              f"fixed={eng['fixed_batch_tok_s']} "
              f"ratio={eng['sustained_vs_fixed_ratio']} "
              f"p50={eng['p50_latency_s']}s p99={eng['p99_latency_s']}s")
    ch = eng["chunked"]
    table.add("engine_chunked_prefill", ch["steady_total_s"] * 1e6,
              f"chunk={ch['prefill_chunk']} tok_s={ch['sustained_tok_s']} "
              f"vs_whole={ch['chunked_vs_whole_ratio']} "
              f"ttft_p50={ch['ttft_p50_s']}s ttft_p99={ch['ttft_p99_s']}s "
              f"stall={ch['admission_stall_s']}s")
    ovl = _overload_leg()
    payload["overload"] = ovl
    table.add("engine_overload", ovl["steady_total_s"] * 1e6,
              f"2x oversub preempts={ovl['n_preemptions']} "
              f"shed_rate={ovl['shed_rate']} "
              f"deadline={ovl['n_deadline']} p99={ovl['p99_latency_s']}s "
              f"all_terminal={ovl['all_terminal']}")
    mesh = _mesh_leg()
    if mesh is not None:
        payload["packed_mesh"] = mesh
        table.add("serve_mesh_shard_map", mesh["mesh_total_s"] * 1e6,
                  f"ref_fallbacks={mesh['ref_gemm_fallbacks']}")
    OUT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return table


if __name__ == "__main__":
    run()
