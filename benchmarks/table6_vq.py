"""Tab. 6 reproduction: vector quantization (LDLQ + E8 lattice).

Paper claim: token-importance scaling transfers to VQ — RSQ(+VQ) beats
QuaRot(+VQ)."""
from __future__ import annotations

from repro.core import RSQConfig

from benchmarks.common import Table, get_trained_model, quantize_and_eval


def run(table: Table | None = None) -> dict:
    table = table or Table("table6_vq")
    model, params, corpus = get_trained_model()
    out = {}
    for name, imp in (("quarot_vq", "uniform"), ("rsq_vq", "attn_con")):
        rsq = RSQConfig(rotate=True, importance=imp, method="ldlq",
                        r_min=0.5)
        ppl = quantize_and_eval(model, params, corpus, rsq)["ppl"]
        out[name] = ppl
        table.add(name, 0.0, f"ppl={ppl:.3f}")
    table.add("claims", 0.0,
              f"rsq_vq<quarot_vq: {out['rsq_vq'] < out['quarot_vq']}")
    return out


if __name__ == "__main__":
    run()
