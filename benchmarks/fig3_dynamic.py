"""Fig. 3 reproduction: the five dynamic strategies across r_min."""
from __future__ import annotations

from repro.core import RSQConfig

from benchmarks.common import Table, get_trained_model, quantize_and_eval

STRATEGIES = ("token_freq", "act_norm", "act_diff", "token_sim", "attn_con")
R_MINS = (0.005, 0.05, 0.5)


def run(bits: int = 2, table: Table | None = None) -> dict:
    table = table or Table("fig3_dynamic")
    model, params, corpus = get_trained_model()
    out = {}
    for strat in STRATEGIES:
        for r_min in R_MINS:
            rsq = RSQConfig(bits=bits, group_size=64, rotate=True,
                            importance=strat, r_min=r_min)
            ppl = quantize_and_eval(model, params, corpus, rsq)["ppl"]
            out[f"{strat}@{r_min}"] = ppl
            table.add(f"{strat}_rmin{r_min}", 0.0, f"ppl={ppl:.3f}")
    best = {s: min(out[f"{s}@{r}"] for r in R_MINS) for s in STRATEGIES}
    ranked = sorted(best, key=best.get)
    table.add("claims", 0.0, f"ranking(best-first)={ranked}")
    return out


if __name__ == "__main__":
    run()
