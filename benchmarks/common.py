"""Shared benchmark harness.

Paper-scale numbers (LLaMA3-8B on WikiText-2) are not reproducible in this
offline CPU container, so every table/figure is validated on a *trained*
small LM over the deterministic synthetic corpus: the claims under test are
the paper's orderings and trends (GPTQ < QuaRot < RSQ, chunk effects,
strategy rankings, bit scaling), not absolute perplexities.  The model is
trained once and cached under results/bench_model.
"""
from __future__ import annotations

import dataclasses
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import RSQConfig, quantize_model
from repro.data.loader import DataLoader
from repro.data.synthetic import SyntheticCorpus
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import cosine_schedule, make_optimizer

RESULTS = Path(__file__).resolve().parent.parent / "results"

BENCH_ARCH_OVERRIDES = dict(
    dtype="float32", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
    d_head=32, d_ff=256, vocab_size=512)

TRAIN_STEPS = 800
CALIB_N, CALIB_T = 32, 128
SEED = 0


def bench_config(arch: str = "llama3-8b"):
    cfg = get_config(arch).reduced()
    over = dict(BENCH_ARCH_OVERRIDES)
    if cfg.family in ("ssm", "hybrid"):
        over.pop("d_head")
    if cfg.uses_moe:
        over["moe_d_ff"] = 128
    return dataclasses.replace(cfg, **over)


def get_trained_model(arch: str = "llama3-8b", steps: int = TRAIN_STEPS,
                      force: bool = False):
    """Train (or load) the benchmark model. Returns (model, params, corpus)."""
    cfg = bench_config(arch)
    model = build_model(cfg)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=SEED)
    ckpt_dir = RESULTS / "bench_model" / arch.replace("/", "_")
    cm = CheckpointManager(ckpt_dir, keep=1)
    like = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        model.param_shapes())
    if not force and cm.latest_step() == steps:
        _, state, _ = cm.restore(like={"params": like})
        return model, state["params"], corpus

    print(f"[bench] training {arch} proxy for {steps} steps...",
          flush=True)
    params = jax.jit(model.init)(jax.random.key(SEED))
    opt = make_optimizer("adamw", cosine_schedule(5e-3, 40, steps),
                         weight_decay=0.01)
    opt_state = jax.jit(opt.init)(params)
    loader = DataLoader(corpus, 16, CALIB_T)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
    t0 = time.time()
    for s in range(steps):
        batch = next(loader)
        params, opt_state, loss = step_fn(params, opt_state, batch,
                                          jnp.int32(s))
        if s % 100 == 0:
            print(f"  step {s}: loss {float(loss):.3f}", flush=True)
    print(f"  trained in {time.time() - t0:.0f}s, final loss "
          f"{float(loss):.3f}", flush=True)
    cm.save(steps, {"params": params}, blocking=True)
    return model, params, corpus


def eval_ppl(model, params, tokens, batch: int = 16) -> float:
    loss_fn = jax.jit(model.loss)
    tot, n = 0.0, 0
    for i in range(0, tokens.shape[0], batch):
        b = tokens[i : i + batch]
        lbl = jnp.roll(b, -1, axis=1)
        tot += float(loss_fn(params, {"tokens": b, "labels": lbl})) * b.shape[0]
        n += b.shape[0]
    return float(jnp.exp(tot / n))


def calib_and_heldout(corpus, n=CALIB_N, t=CALIB_T):
    calib = corpus.sample(jax.random.key(777), n, t)
    heldout = corpus.sample(jax.random.key(999), n, t)
    return calib, heldout


def quantize_and_eval(model, params, corpus, rsq: RSQConfig,
                      batch_size: int = 8) -> dict:
    calib, heldout = calib_and_heldout(corpus)
    t0 = time.time()
    qparams, _ = quantize_model(model, params, calib, rsq,
                                batch_size=batch_size)
    dt = time.time() - t0
    return {"ppl": eval_ppl(model, qparams, heldout),
            "seconds": round(dt, 1)}


class Table:
    """Collects rows; prints the required ``name,us_per_call,derived`` CSV."""

    def __init__(self, name: str):
        self.name = name
        self.rows: list[tuple] = []

    def add(self, label: str, us_per_call: float, derived: str):
        self.rows.append((label, us_per_call, derived))
        print(f"{self.name}/{label},{us_per_call:.1f},{derived}", flush=True)

    def dump(self, fh=sys.stdout):
        for label, us, derived in self.rows:
            print(f"{self.name}/{label},{us:.1f},{derived}", file=fh)
