"""Tab. 5 reproduction: bit-precision sweep.

Paper claims: RSQ <= QuaRot at every precision and the gap grows as bits
shrink."""
from __future__ import annotations

from repro.core import RSQConfig

from benchmarks.common import Table, get_trained_model, quantize_and_eval


def run(table: Table | None = None) -> dict:
    table = table or Table("table5_bits")
    model, params, corpus = get_trained_model()
    out = {}
    for bits in (4, 3, 2):
        for name, imp in (("quarot", "uniform"), ("rsq", "attn_con")):
            rsq = RSQConfig(bits=bits, group_size=64, rotate=True,
                            importance=imp, r_min=0.5)
            ppl = quantize_and_eval(model, params, corpus, rsq)["ppl"]
            out[f"{name}_{bits}b"] = ppl
            table.add(f"{name}_{bits}bit", 0.0, f"ppl={ppl:.3f}")
    gaps = {b: out[f"quarot_{b}b"] - out[f"rsq_{b}b"] for b in (4, 3, 2)}
    table.add("claims", 0.0,
              f"gap(4b)={gaps[4]:.3f} gap(3b)={gaps[3]:.3f} "
              f"gap(2b)={gaps[2]:.3f}; grows at low bits: "
              f"{gaps[2] >= gaps[4]}")
    return out


if __name__ == "__main__":
    run()
