"""Tab. 4 reproduction: robustness across calibration datasets.

Four synthetic "datasets" (different Zipf exponents / Markov seeds stand in
for WikiText / RedPajama / C4 / PTB).  Claim: RSQ < QuaRot on every one."""
from __future__ import annotations

import jax

from repro.core import RSQConfig, quantize_model
from repro.data.synthetic import SyntheticCorpus

from benchmarks.common import (Table, calib_and_heldout, eval_ppl,
                               get_trained_model)

DATASETS = {
    "wiki": dict(seed=0, alpha=1.2),
    "redpj": dict(seed=11, alpha=1.1),
    "c4": dict(seed=22, alpha=1.3),
    "ptb": dict(seed=33, alpha=1.5),
}


def run(bits: int = 2, table: Table | None = None) -> dict:
    table = table or Table("table4_calib")
    model, params, corpus = get_trained_model()
    _, heldout = calib_and_heldout(corpus)
    out = {}
    for ds, kw in DATASETS.items():
        c = SyntheticCorpus(vocab_size=model.cfg.vocab_size,
                            markov_strength=0.75, **kw)
        calib = c.sample(jax.random.key(5), 32, 128)
        for name, imp in (("quarot", "uniform"), ("rsq", "attn_con")):
            rsq = RSQConfig(bits=bits, group_size=64, rotate=True,
                            importance=imp, r_min=0.5)
            qp, _ = quantize_model(model, params, calib, rsq, batch_size=8)
            ppl = eval_ppl(model, qp, heldout)
            out[f"{name}_{ds}"] = ppl
            table.add(f"{name}_{ds}", 0.0, f"ppl={ppl:.3f}")
    wins = sum(out[f"rsq_{d}"] < out[f"quarot_{d}"] for d in DATASETS)
    table.add("claims", 0.0, f"rsq wins {wins}/{len(DATASETS)} datasets")
    return out


if __name__ == "__main__":
    run()
