"""End-to-end calibration-engine benchmark (perf trajectory guard).

Two axes, both on a tiny multi-layer homogeneous model:

  * **trace cache** — quantize once with the fused trace-cached engine (the
    default) and once with the legacy fresh-jit-per-layer baseline
    (``trace_cache=False``): XLA compilation counts (O(distinct metas) vs
    O(layers)) and cold wall time.
  * **layer scheduler** — warm steady-state wall time of the
    ``SequentialScheduler`` vs the ``OverlappedScheduler`` (same compiled
    programs, different dispatch: the overlapped schedule dispatches layer
    i's apply and layer i+1's capture before layer i's solve has finished,
    skips the last layer's dead apply pass, and defers every blocking host
    sync to one end-of-stack drain, where the lock-step schedule blocks
    once per layer).  Interleaved repeat runs (min) so machine drift hits
    both schedulers equally.  On CPU the delta is bounded by host
    wake/dispatch latency per layer; it grows with real device/host sync
    cost on accelerator backends, as does the overlapped scheduler's
    concurrent compile prewarm (a no-op on the CPU backend, whose
    compilations serialize process-wide).

A third, informational axis: **checkpoint overhead** — the same warm
pipeline driven through ``core.resume.QuantizeRunner`` with the densest
cadence (``save_every_layers=1``), reporting ``ckpt_overhead_s`` (time in
commit bookkeeping + layer-solve checkpoint saves) next to the plain warm
wall time, so the cost of fault tolerance stays a measured number rather
than folklore.

Results land in ``BENCH_pipeline.json`` at the repo root so future PRs
have a perf trajectory to regress against.  Timings are split into
compile-inclusive cold fields (``cold_total_s``/``compile_s`` —
informational) and ``steady_total_s`` fields, and ``benchmarks/run.py``
applies its >20% regression gate to the *steady-state* fields only: cold
totals swing with XLA compile noise and machine cache state, which made
the old whole-wall-time gate cry wolf.  Wall times on this container are
CPU numbers; the compile counts and the sequential/overlapped ordering are
the portable claims.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import jax

from repro.configs import get_config
from repro.core import RSQConfig, RSQPipeline
from repro.models import build_model

from benchmarks.common import Table

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_pipeline.json"

N_LAYERS = 4
CALIB_N, CALIB_T = 16, 64
BATCH = 4
WARM_REPS = 7


def _toy_model(d_model: int = 64):
    cfg = dataclasses.replace(
        get_config("llama3-8b").reduced(), dtype="float32",
        n_layers=N_LAYERS, d_model=d_model, vocab_size=256)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    calib = jax.random.randint(jax.random.key(1), (CALIB_N, CALIB_T), 0,
                               cfg.vocab_size)
    return model, params, calib


def _run_engine(model, params, calib, *, trace_cache: bool) -> dict:
    """One cold run (includes XLA compiles) and, for the trace-cached
    engine, one steady-state repeat on the same pipeline.  Timings are
    split so the regression gate (benchmarks/run.py) can key on
    ``steady_total_s`` alone: cold totals carry multi-second compile noise
    (machine- and cache-state-dependent), steady-state is the dispatch +
    execute path that perf PRs actually move.  The per-layer-jit baseline
    recompiles every layer on every run by design, so it reports a cold
    total only."""
    jax.clear_caches()  # process-global jit cache would leak solver
    # compilations from one engine run into the other
    rsq = RSQConfig(bits=4, rotate=False, importance="attn_con",
                    trace_cache=trace_cache, scheduler="sequential")
    pipe = RSQPipeline(model, rsq)
    t0 = time.perf_counter()
    _, report = pipe.run(params, calib, batch_size=BATCH)
    cold_s = time.perf_counter() - t0
    layer_s = [l["seconds"] for l in report["layers"].values()]
    out = {
        "trace_cache": trace_cache,
        "n_layers": len(layer_s),
        "cold_total_s": round(cold_s, 3),
        "per_layer_s": layer_s,
        "mean_layer_s": round(sum(layer_s) / len(layer_s), 3),
        "compiles": dict(pipe.trace_counts),
    }
    if trace_cache:
        t0 = time.perf_counter()
        q, _ = pipe.run(params, calib, batch_size=BATCH)
        jax.block_until_ready(jax.tree.leaves(q))
        out["steady_total_s"] = round(time.perf_counter() - t0, 3)
        out["compile_s"] = round(cold_s - out["steady_total_s"], 3)
    return out


def _warm_schedulers() -> dict:
    """Warm steady-state timing: compile once per scheduler, then time
    interleaved repeat runs on the same pipelines (the per-meta trace cache
    lives on the pipeline, so repeats are dispatch + execute only — exactly
    the path the scheduler controls).  Interleaving decorrelates machine
    drift from the scheduler identity; a d=128 toy keeps each run long
    enough (~0.2 s) that the container's timer jitter stays well below the
    scheduling delta."""
    model, params, calib = _toy_model(d_model=128)
    pipes, times = {}, {}
    for name in ("sequential", "overlapped"):
        rsq = RSQConfig(bits=4, rotate=False, importance="attn_con",
                        scheduler=name)
        pipes[name] = RSQPipeline(model, rsq)
        pipes[name].run(params, calib, batch_size=BATCH)  # compile warm-up
        times[name] = []
    for _ in range(WARM_REPS):
        for name, pipe in pipes.items():
            t0 = time.perf_counter()
            q, _ = pipe.run(params, calib, batch_size=BATCH)
            jax.block_until_ready(jax.tree.leaves(q))
            times[name].append(time.perf_counter() - t0)
    return {
        name: {
            "scheduler": name,
            "steady_total_s": round(min(ts), 4),
            "runs_s": [round(t, 4) for t in ts],
            "compiles": dict(pipes[name].trace_counts),  # warm: 0 retraces
        }
        for name, ts in times.items()
    }


def _ckpt_overhead() -> dict:
    """Warm wall time with vs without layer-solve checkpointing at the
    densest cadence (every layer, + the blocking stack-final save).  Both
    runs reuse one compiled pipeline, so the delta is pure runner cost:
    host syncs for the JSON report, npz serialization, atomic renames."""
    import tempfile

    from repro.checkpoint import CheckpointManager
    from repro.core.resume import QuantizeRunner

    model, params, calib = _toy_model()
    rsq = RSQConfig(bits=4, rotate=False, importance="attn_con",
                    scheduler="sequential")
    pipe = RSQPipeline(model, rsq)
    pipe.run(params, calib, batch_size=BATCH)  # compile warm-up
    t0 = time.perf_counter()
    q, _ = pipe.run(params, calib, batch_size=BATCH)
    jax.block_until_ready(jax.tree.leaves(q))
    plain_s = time.perf_counter() - t0
    with tempfile.TemporaryDirectory() as td:
        runner = QuantizeRunner(pipe, CheckpointManager(td),
                                save_every_layers=1, resume=False)
        t0 = time.perf_counter()
        q, _ = runner.run(params, calib, batch_size=BATCH)
        jax.block_until_ready(jax.tree.leaves(q))
        runner_s = time.perf_counter() - t0
    return {
        "save_every_layers": 1,
        "plain_steady_s": round(plain_s, 4),
        "runner_steady_s": round(runner_s, 4),
        "ckpt_overhead_s": round(runner.ckpt_overhead_s, 4),
    }


def run(table: Table | None = None):
    table = table or Table("pipeline")
    model, params, calib = _toy_model()

    # discarded warm-up: one-time process costs (backend init, primitive
    # lowering caches) otherwise land entirely on whichever engine runs first
    _run_engine(model, params, calib, trace_cache=True)
    fused = _run_engine(model, params, calib, trace_cache=True)
    base = _run_engine(model, params, calib, trace_cache=False)

    table.add(
        "fused_engine", fused["cold_total_s"] * 1e6,
        f"compiles_capture={fused['compiles']['capture']} "
        f"compiles_apply={fused['compiles']['apply']} "
        f"steady_s={fused['steady_total_s']} "
        f"compile_s={fused['compile_s']}")
    table.add(
        "per_layer_jit_baseline", base["cold_total_s"] * 1e6,
        f"compiles_capture={base['compiles']['capture']} "
        f"compiles_apply={base['compiles']['apply']} "
        f"mean_layer_s={base['mean_layer_s']}")
    speedup = base["cold_total_s"] / max(fused["cold_total_s"], 1e-9)
    table.add("fused_vs_baseline", 0.0,
              f"speedup={speedup:.2f}x "
              f"compile_ratio={base['compiles']['capture']}"
              f":{fused['compiles']['capture']}")

    schedulers = _warm_schedulers()
    for name, res in schedulers.items():
        table.add(f"scheduler_{name}_warm", res["steady_total_s"] * 1e6,
                  f"steady_total_s={res['steady_total_s']} "
                  f"retraces={res['compiles']['capture']}")
    overlap_speedup = (schedulers["sequential"]["steady_total_s"]
                       / max(schedulers["overlapped"]["steady_total_s"], 1e-9))
    table.add("overlapped_vs_sequential_warm", 0.0,
              f"speedup={overlap_speedup:.2f}x "
              f"blocking_syncs={N_LAYERS}:1")

    ckpt = _ckpt_overhead()
    table.add("ckpt_overhead", ckpt["ckpt_overhead_s"] * 1e6,
              f"ckpt_overhead_s={ckpt['ckpt_overhead_s']} "
              f"plain_s={ckpt['plain_steady_s']} "
              f"runner_s={ckpt['runner_steady_s']}")

    payload = {"fused": fused, "baseline": base,
               "speedup": round(speedup, 3),
               "schedulers": schedulers,
               "overlap_speedup": round(overlap_speedup, 3),
               # informational (no regression gate): cost of layer-solve
               # checkpointing at the densest cadence
               "ckpt_overhead": ckpt,
               # structural per-run count (deterministic, backend-free):
               # host syncs that block further dispatch — once per layer
               # lock-step vs one end-of-stack drain overlapped
               "blocking_syncs": {"sequential": N_LAYERS, "overlapped": 1},
               "backend": jax.default_backend()}
    OUT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return table


if __name__ == "__main__":
    run()
